//! Hyper-parameter tuning of a learning algorithm — the Snoek et al.
//! (2012) use case the paper's introduction leads with: each evaluation
//! (a full train + validate cycle) is expensive, gradients are
//! unavailable, and results are noisy.
//!
//! The "learner" is an RBF ridge-regression model trained on synthetic
//! data; BO tunes (log regularization, RBF width, #centers) against
//! validation RMSE and is compared with random search at the same budget.
//!
//! Run: `cargo run --release --example hyperparam_tuning`

use limbo::prelude::*;
use limbo::la::{CholeskyFactor, Matrix};

/// Synthetic regression task: y = sin(3x) + 0.5 cos(7x) + noise.
struct Task {
    train: Vec<(f64, f64)>,
    valid: Vec<(f64, f64)>,
}

impl Task {
    fn generate(seed: u64) -> Self {
        let mut rng = Pcg64::seed(seed);
        let mut sample = |n: usize| -> Vec<(f64, f64)> {
            (0..n)
                .map(|_| {
                    let x = rng.uniform(-2.0, 2.0);
                    let y = (3.0 * x).sin() + 0.5 * (7.0 * x).cos() + 0.1 * rng.normal();
                    (x, y)
                })
                .collect()
        };
        Self { train: sample(120), valid: sample(200) }
    }

    /// Train an RBF ridge regressor with the given hyper-parameters and
    /// return the validation RMSE. `u` in [0,1]^3 decodes to:
    /// lambda in [1e-6, 1e1] (log), width in [0.05, 2.0] (log),
    /// centers in {5..60}.
    fn train_eval(&self, u: &[f64]) -> f64 {
        let lambda = 10f64.powf(-6.0 + 7.0 * u[0]);
        let width = (0.05f64.ln() + (2.0f64.ln() - 0.05f64.ln()) * u[1]).exp();
        let m = (5.0 + 55.0 * u[2]).round() as usize;

        // centers: evenly spread over the input range
        let centers: Vec<f64> = (0..m).map(|i| -2.0 + 4.0 * i as f64 / (m - 1) as f64).collect();
        let phi = |x: f64, c: f64| (-((x - c) / width).powi(2)).exp();

        // ridge solve: (Phi^T Phi + lambda I) w = Phi^T y
        let n = self.train.len();
        let mut pt_p = Matrix::zeros(m, m);
        let mut pt_y = vec![0.0; m];
        for &(x, y) in &self.train {
            let feats: Vec<f64> = centers.iter().map(|&c| phi(x, c)).collect();
            for i in 0..m {
                pt_y[i] += feats[i] * y;
                for j in 0..m {
                    pt_p[(i, j)] += feats[i] * feats[j];
                }
            }
        }
        for i in 0..m {
            pt_p[(i, i)] += lambda * n as f64;
        }
        let Ok(chol) = CholeskyFactor::factor(&pt_p) else {
            return 10.0; // numerically broken configuration
        };
        let w = chol.solve(&pt_y);

        // validation RMSE
        let mse: f64 = self
            .valid
            .iter()
            .map(|&(x, y)| {
                let pred: f64 = centers.iter().zip(&w).map(|(&c, &wi)| wi * phi(x, c)).sum();
                (pred - y).powi(2)
            })
            .sum::<f64>()
            / self.valid.len() as f64;
        mse.sqrt()
    }
}

fn main() {
    let task = Task::generate(7);
    let budget = 40;

    // ---- Bayesian optimization (maximize -RMSE) ----
    let mut opt = BoDef::new(3)
        .noise(1e-3)
        .acquisition(Ei::default())
        .init(Lhs { n: 8 })
        .refit(RefitSchedule::Every(5))
        .hp_config(limbo::model::HpOptConfig { restarts: 2, ..Default::default() })
        .iterations(budget - 8)
        .seed(1)
        .build_optimizer();
    let bo_best = opt.optimize(&FnEval::new(3, |u: &[f64]| -task.train_eval(u)));
    let bo_rmse = -bo_best.value;

    // ---- random search at the same budget ----
    let mut rng = Pcg64::seed(1);
    let mut rs_rmse = f64::INFINITY;
    for _ in 0..budget {
        let u = rng.unit_point(3);
        rs_rmse = rs_rmse.min(task.train_eval(&u));
    }

    println!("budget: {budget} train+validate cycles each");
    println!("random search best validation RMSE : {rs_rmse:.4}");
    println!("BO best validation RMSE            : {bo_rmse:.4}");
    let u = bo_best.x;
    println!(
        "BO config: lambda=10^{:.2}, width={:.3}, centers={}",
        -6.0 + 7.0 * u[0],
        (0.05f64.ln() + (2.0f64.ln() - 0.05f64.ln()) * u[1]).exp(),
        (5.0 + 55.0 * u[2]).round()
    );
    assert!(bo_rmse <= rs_rmse * 1.2, "BO should be competitive with random search");
    println!("ok");
}
