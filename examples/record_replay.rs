//! Record a full DE-driven Branin run, replay it bit-identically.
//!
//! A [`RecordingObserver`] rides the event bus of an ask/tell server
//! whose acquisition maximizer is the self-adaptive DE
//! ([`limbo::opt::AdaptiveDe`], via the `inner_de` knob), capturing
//! every proposal/observation plus the per-generation DE state. The
//! capture is saved to a JSONL file, loaded back, and replayed through
//! a **fresh, identically-configured** server: every re-asked proposal
//! is compared bit-for-bit against the recording, so the first
//! divergence (a changed kernel, a perturbed RNG stream, a different
//! maximizer) is reported with its event index and iteration.
//!
//! Run: `cargo run --release --example record_replay`
//! (`LIMBO_SMOKE=1` shrinks the budget to a CI-sized run.)

use limbo::benchfns;
use limbo::opt::AdaptiveDe;
use limbo::prelude::*;
use limbo::stat::RecordingObserver;

/// One server over Branin; every call builds the *same* definition so
/// the replay target is configured identically to the recorded run.
fn build(rec: RecordingObserver, iterations: usize) -> impl Study {
    BoDef::new(2)
        .acquisition(Ei::default())
        .init(Lhs { n: 8 })
        .inner_opt(AdaptiveDe::new(200).with_recorder(rec.de_recorder()))
        .refit(RefitSchedule::Doubling { first: 12 })
        .noise(1e-3)
        .seed(42)
        .iterations(iterations)
        .observer(rec)
        .build_server()
}

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let iterations = if smoke { 10 } else { 30 };
    let total = 8 + iterations;
    let branin = benchfns::by_name("branin", 2).expect("branin is registered");

    // --- record ---------------------------------------------------------
    let rec = RecordingObserver::new();
    let mut srv = build(rec.clone(), iterations);
    for _ in 0..total {
        let x = srv.ask().expect("ask");
        let y = branin.eval(&x);
        srv.tell(&x, y).expect("tell");
    }
    srv.finish().expect("finish");
    let best = srv.best().expect("best").expect("data");
    println!(
        "recorded: {} events, {} DE generations, best={:.6} (accuracy {:.3e})",
        rec.len(),
        rec.de_rows().len(),
        best.1,
        branin.accuracy(best.1)
    );

    // --- save / load ----------------------------------------------------
    let path = std::env::temp_dir().join("limbo_record_replay_example.jsonl");
    rec.save(&path).expect("save capture");
    let loaded = RecordingObserver::load(&path).expect("load capture");
    println!("saved {} events to {}", loaded.len(), path.display());

    // --- replay ---------------------------------------------------------
    let replay_rec = RecordingObserver::new();
    let mut fresh = build(replay_rec.clone(), iterations);
    loaded.replay_into(&mut fresh).expect("bit-identical replay");
    println!("replayed {} events bit-identically through a fresh server", replay_rec.len());

    // the self-adaptation at work: F/CR drift away from their 0.5/0.9
    // initialization as winning parameter settings survive selection
    let rows = rec.de_rows();
    if let (Some(a), Some(b)) = (rows.first(), rows.last()) {
        println!("DE self-adaptation across the captured generations:");
        println!("  first: np={} mean F={:.3} mean CR={:.3}", a.np, a.mean_f, a.mean_cr);
        println!("  last:  np={} mean F={:.3} mean CR={:.3}", b.np, b.mean_f, b.mean_cr);
    }
}
