//! Study manager — a fleet of concurrent Branin optimizations behind
//! one [`StudyManager`], with forced eviction and a crash/recovery.
//!
//! The single-study ask/tell server owns a thread per optimization; a
//! tuning service runs thousands of mostly-idle studies and cannot
//! afford that. The manager inverts the ownership: studies are passive
//! registry state, operations run as jobs on one shared thread pool,
//! and a live-study budget evicts cold studies to disk — from where
//! they rehydrate transparently (snapshot + event-log replay through
//! the live code path, bit-exact) on their next operation. The same
//! machinery survives a process crash: a fresh manager `recover`s every
//! study from its durability directory and the traces continue as if
//! nothing happened.
//!
//! Run: `cargo run --release --example study_manager`
//! (`LIMBO_SMOKE=1` shrinks the fleet to a CI-sized run that still
//! exercises eviction, rehydration and one recovery.)

use std::sync::Arc;
use std::time::Instant;

use limbo::bayes_opt::RefitSchedule;
use limbo::benchfns::Branin;
use limbo::coordinator::{StudyId, StudyManager};
use limbo::pool::ThreadPool;
use limbo::prelude::*;

fn study_def(seed: u64) -> limbo::coordinator::DefaultDenseServer {
    BoDef::service(2)
        .seed(seed)
        .refit(RefitSchedule::Doubling { first: 6 })
        .build_server()
}

fn run_rounds(mgr: &StudyManager, ids: &[StudyId], rounds: usize) {
    let branin = Branin;
    for _ in 0..rounds {
        for &id in ids {
            let x = mgr.ask(id).expect("ask");
            // Branin::eval is already negated onto the unit square: the
            // library convention is maximization, optimum ≈ -0.39789
            let y = branin.eval(&x);
            mgr.tell(id, &x, y).expect("tell");
        }
    }
}

fn fleet_best(mgr: &StudyManager, ids: &[StudyId]) -> (StudyId, f64) {
    ids.iter()
        .filter_map(|&id| mgr.best(id).expect("best").map(|(_, v)| (id, v)))
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("finite"))
        .expect("fleet has data")
}

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let fleet = if smoke { 12 } else { 48 };
    let rounds = if smoke { 8 } else { 20 };
    let max_live = fleet / 4;
    let root = std::env::temp_dir().join("limbo_study_manager_example");
    let _ = std::fs::remove_dir_all(&root);
    let pool = Arc::new(ThreadPool::new(4));
    let t0 = Instant::now();

    // phase 1: a durable fleet under a live-study budget
    println!("fleet of {fleet} Branin studies, live budget {max_live}, pool of 4");
    let mgr = StudyManager::durable(Arc::clone(&pool), &root)
        .expect("durability root")
        .with_max_live(max_live);
    let ids: Vec<StudyId> = (0..fleet)
        .map(|s| {
            let seed = 100 + s as u64;
            mgr.create(move || study_def(seed)).expect("create study")
        })
        .collect();
    run_rounds(&mgr, &ids, rounds);
    let (live, evicted) = mgr.counts();
    println!(
        "after {rounds} rounds: {live} live / {evicted} evicted (budget {max_live}), \
         t={:.2?}",
        t0.elapsed()
    );

    // phase 2: forced eviction is transparent for a durable study
    let victim = ids[0];
    mgr.evict(victim).expect("evict");
    let x = mgr.ask(victim).expect("rehydrates on demand");
    println!("evicted {victim}, next ask rehydrated it: x = ({:.3}, {:.3})", x[0], x[1]);
    let y = Branin.eval(&x);
    mgr.tell(victim, &x, y).expect("tell");

    // phase 3: "crash" — drop the manager without closing anything; the
    // event logs flush on drop, nothing else is saved
    drop(mgr);
    println!("manager dropped mid-run ({} studies lost in memory)", fleet);

    // phase 4: a fresh manager recovers every study from disk and the
    // fleet continues exactly where it stopped
    let mgr = StudyManager::durable(pool, &root).expect("durability root").with_max_live(max_live);
    for &id in &ids {
        mgr.recover(id, {
            let seed = 100 + id.as_u64();
            move || study_def(seed)
        })
        .expect("recover study");
    }
    run_rounds(&mgr, &ids, 2);
    let (id, best) = fleet_best(&mgr, &ids);
    println!(
        "recovered {} studies, 2 more rounds: fleet best {best:.5} (true optimum \
         {:.5}) from {id}",
        ids.len(),
        Branin.optimum()
    );
    for &id in &ids {
        mgr.close(id).expect("close");
    }
    println!("total {:.2?}", t0.elapsed());
    let _ = std::fs::remove_dir_all(&root);
}
