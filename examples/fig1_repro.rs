//! Figure-1 reproduction — the end-to-end experiment driver.
//!
//! Regenerates both panels of the paper's Figure 1: per-test-function box
//! statistics of (a) accuracy `|f(best) - f(x*)|` and (b) wall-clock time,
//! for the statically-dispatched implementation ("limbo") vs the
//! classic-OO comparator ("bayesopt"), with and without hyper-parameter
//! optimization, plus the text's headline speed-up ratios.
//!
//! Protocol (paper): 250 replicates, BayesOpt default parameters
//! (LHS(10) init, ARD Matérn-5/2, EI, DIRECT). Defaults here are scaled
//! down to stay minutes-fast; pass `--full` for the 250-replicate run.
//!
//! Run: `cargo run --release --example fig1_repro -- [--full]
//!       [replicates=N] [iterations=N] [functions=a,b,c] [csv=PATH]`

use std::io::Write;

use limbo::benchfns;
use limbo::coordinator::config::Config;
use limbo::coordinator::experiment::{print_table, speedups, ExperimentRow, ExperimentRunner};
use limbo::coordinator::fig1::{BaselineConfig, Fig1Settings, LimboConfig};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let full = raw.iter().any(|a| a == "--full");
    let kv: Vec<String> = raw.into_iter().filter(|a| a.contains('=')).collect();
    let cfg = Config::from_args(&kv).expect("key=value arguments");

    let replicates = cfg.get_usize("replicates", if full { 250 } else { 30 });
    let iterations = cfg.get_usize("iterations", 40);
    let runner = ExperimentRunner {
        replicates,
        threads: cfg.get_usize(
            "threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        ),
        base_seed: cfg.get_usize("seed", 1000) as u64,
    };
    let functions: Vec<Box<dyn benchfns::TestFunction>> = match cfg.get("functions") {
        Some(names) => names
            .split(',')
            .map(|n| benchfns::by_name(n.trim(), 2).unwrap_or_else(|| panic!("unknown fn {n}")))
            .collect(),
        None => benchfns::figure1_suite(),
    };

    eprintln!(
        "fig1: {} functions x 4 configs x {replicates} replicates, {iterations} iterations each",
        functions.len()
    );

    let base = Fig1Settings { iterations, ..Default::default() };
    let mut rows: Vec<ExperimentRow> = Vec::new();

    // panel 1: without hyper-parameter optimization
    let limbo = LimboConfig::new(base);
    let bayesopt = BaselineConfig::new(base);
    rows.extend(runner.run_grid(&functions, &[&limbo, &bayesopt]));

    // panel 2: with hyper-parameter optimization
    let limbo_hpo = LimboConfig::new(base.with_hpo());
    let bayesopt_hpo = BaselineConfig::new(base.with_hpo());
    rows.extend(runner.run_grid(&functions, &[&limbo_hpo, &bayesopt_hpo]));

    println!("\n=== Figure 1: accuracy & wall-clock (box statistics) ===");
    print_table(&rows);

    println!("\n=== headline ratios (paper: 1.47-1.76x no-HPO, 2.05-2.54x HPO) ===");
    let mut no_hpo: Vec<f64> = Vec::new();
    let mut with_hpo: Vec<f64> = Vec::new();
    for (f, ratio, dacc) in speedups(&rows, "limbo", "bayesopt") {
        println!("  no-HPO  {f:<18} {ratio:>6.2}x   |Δ acc median| = {dacc:.2e}");
        no_hpo.push(ratio);
    }
    for (f, ratio, dacc) in speedups(&rows, "limbo+hpo", "bayesopt+hpo") {
        println!("  HPO     {f:<18} {ratio:>6.2}x   |Δ acc median| = {dacc:.2e}");
        with_hpo.push(ratio);
    }
    let rng = |v: &[f64]| {
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    };
    if !no_hpo.is_empty() {
        let (lo, hi) = rng(&no_hpo);
        println!("\nspeed-up range without HPO: {lo:.2}x – {hi:.2}x (paper: 1.47x – 1.76x)");
    }
    if !with_hpo.is_empty() {
        let (lo, hi) = rng(&with_hpo);
        println!("speed-up range with HPO   : {lo:.2}x – {hi:.2}x (paper: 2.05x – 2.54x)");
    }

    if let Some(path) = cfg.get("csv") {
        let mut f = std::fs::File::create(path).expect("csv file");
        writeln!(
            f,
            "function,config,replicates,acc_min,acc_q1,acc_median,acc_q3,acc_max,\
             time_min,time_q1,time_median,time_q3,time_max"
        )
        .unwrap();
        for r in &rows {
            writeln!(
                f,
                "{},{},{},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e}",
                r.function,
                r.config,
                r.replicates,
                r.accuracy.min,
                r.accuracy.q1,
                r.accuracy.median,
                r.accuracy.q3,
                r.accuracy.max,
                r.wall.min,
                r.wall.q1,
                r.wall.median,
                r.wall.q3,
                r.wall.max
            )
            .unwrap();
        }
        eprintln!("wrote {path}");
    }
}
