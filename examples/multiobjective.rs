//! Multi-objective Bayesian optimization (the paper: "Limbo can support
//! multi-objective optimization" — functors with `dim_out > 1`).
//!
//! ParEGO-style scalarization on the classic ZDT1-like trade-off problem;
//! prints the Pareto front and its 2-D hypervolume.
//!
//! Run: `cargo run --release --example multiobjective`

use limbo::coordinator::multiobj::{Archive, MultiEvaluator, ParEgo};

/// A ZDT1-flavored bi-objective problem on [0,1]^3 (both maximized):
/// f1 = -x0, f2 = -g(x) (1 - sqrt(x0 / g(x))) with g = 1 + 3 mean(x1, x2).
struct Zdt1;

impl MultiEvaluator for Zdt1 {
    fn dim_in(&self) -> usize {
        3
    }
    fn dim_out(&self) -> usize {
        2
    }
    fn eval(&self, x: &[f64]) -> Vec<f64> {
        let g = 1.0 + 3.0 * (x[1] + x[2]) / 2.0;
        let f1 = x[0];
        let f2 = g * (1.0 - (x[0] / g).sqrt());
        vec![-f1, -f2] // minimize both -> maximize the negatives
    }
}

fn main() {
    let mut parego = ParEgo::new(11);
    parego.n_init = 12;
    parego.iterations = 50;
    let archive = parego.optimize(&Zdt1);

    println!("Pareto front after {} evaluations:", 12 + 50);
    let mut front: Vec<_> = archive.front().to_vec();
    front.sort_by(|a, b| a.1[0].partial_cmp(&b.1[0]).unwrap());
    for (x, objs) in &front {
        println!(
            "  f1={:>8.4}  f2={:>8.4}   x=[{:.3}, {:.3}, {:.3}]",
            -objs[0], -objs[1], x[0], x[1], x[2]
        );
    }
    let hv = archive.hypervolume_2d(&[-1.5, -4.5]);
    println!("front size: {}, hypervolume vs (-1.5, -4.5): {hv:.3}", archive.len());

    // sanity: the true front has g = 1 (x1 = x2 = 0); points near it
    // satisfy f2 ~ 1 - sqrt(f1). Check the archive approaches that.
    let near_front = front
        .iter()
        .filter(|(_, o)| {
            let f1 = -o[0];
            let f2 = -o[1];
            (f2 - (1.0 - f1.sqrt())).abs() < 0.35
        })
        .count();
    println!("points within 0.35 of the analytic front: {near_front}/{}", front.len());
    assert!(archive.len() >= 4, "should discover a spread of trade-offs");
    assert!(near_front >= archive.len() / 2, "most of the front should be near-optimal");
    println!("ok");

    // keep Archive's API exercised
    assert!(Archive::dominates(&[1.0, 1.0], &[0.5, 0.5]));
}
