//! Profiling a run — where every millisecond of a Branin optimization
//! goes.
//!
//! Attaches a [`MetricsObserver`] to a bounded Branin run: it switches
//! the `limbo::obs` span registry on, and on stop writes the phase
//! breakdown into the run directory (`meta.dat` TSV lines plus
//! `metrics.json`). The example also brackets the run with its own
//! snapshot pair to print the phase table — calls, total seconds,
//! p50/p95/p99 — and the share of wall time the ask/tell service path
//! accounts for.
//!
//! Run: `cargo run --release --example metrics`
//! (`LIMBO_SMOKE=1` shrinks the budget to a CI-sized run.)

use limbo::benchfns;
use limbo::prelude::*;

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let iterations = if smoke { 20 } else { 60 };
    let branin = benchfns::by_name("branin", 2).expect("branin is registered");
    let dir = std::env::temp_dir().join("limbo_metrics_example");

    // bracket the run ourselves as well, to print the table at the end
    // (the observer's own base snapshot is taken in create())
    limbo::obs::set_enabled(true);
    let base = limbo::obs::snapshot();
    let t0 = std::time::Instant::now();

    let mut opt = BoDef::new(2)
        .bounds(&[(-5.0, 10.0), (0.0, 15.0)])
        .iterations(iterations)
        .refit(RefitSchedule::Doubling { first: 12 })
        .seed(7)
        .observer(RunLogger::create(&dir).expect("run dir"))
        // after RunLogger: its finish truncates meta.dat, the phase
        // breakdown appends second
        .observer(MetricsObserver::create(&dir).expect("run dir"))
        .build_optimizer();
    // benchfns functions take unit-cube inputs and scale internally, so
    // map the Domain's user coordinates back to [0,1]^2 before calling
    let best = opt.optimize(&FnEval::new(2, |x: &[f64]| {
        branin.eval(&[(x[0] + 5.0) / 15.0, x[1] / 15.0])
    }));

    let wall = t0.elapsed().as_secs_f64();
    let delta = limbo::obs::snapshot().delta_since(&base);
    println!(
        "branin: best={:.6} accuracy={:.3e} in {} evaluations",
        best.value,
        branin.accuracy(best.value),
        best.evaluations
    );
    println!("\n{}", delta.render_table(Some(wall)));
    println!(
        "service path (ask+tell spans): {:.1}% of {:.3}s wall",
        100.0 * delta.service_seconds() / wall.max(f64::MIN_POSITIVE),
        wall
    );
    println!("reports: {} (meta.dat phase lines + metrics.json)", dir.display());
}
