//! Bounded domains end to end — optimizing over a real-world box
//! instead of the unit cube.
//!
//! Every model-facing computation in limbo lives on `[0, 1]^d`; before
//! `Domain`, callers optimizing a physical quantity (joint angles,
//! temperatures, the Branin box below) had to hand-normalize inputs and
//! de-normalize every proposal. `BoDef::bounds` attaches the box to the
//! definition and the built optimizer/server speaks user coordinates at
//! every entry point: proposals, observations, the incumbent, and the
//! observer event stream.
//!
//! The objective is the classic Branin function on its native domain
//! `x ∈ [-5, 10], y ∈ [0, 15]` (maximized as `-branin`, optimum
//! ≈ -0.397887 at three minima). A `JsonlObserver` subscribes to the
//! run's event bus and writes one JSON row per event.
//!
//! Run: `cargo run --release --example bounded`
//! (`LIMBO_SMOKE=1` shrinks the budget for CI.)

use limbo::prelude::*;

/// Branin–Hoo in its native coordinates (minimization form).
fn branin(x: f64, y: f64) -> f64 {
    let a = 1.0;
    let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
    let c = 5.0 / std::f64::consts::PI;
    let r = 6.0;
    let s = 10.0;
    let t = 1.0 / (8.0 * std::f64::consts::PI);
    a * (y - b * x * x + c * x - r).powi(2) + s * (1.0 - t) * x.cos() + s
}

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let iterations = if smoke { 25 } else { 60 };
    let events = std::env::temp_dir().join("limbo_bounded_events.jsonl");

    // the definition carries the box; nothing below normalizes anything
    let mut opt = BoDef::new(2)
        .bounds(&[(-5.0, 10.0), (0.0, 15.0)])
        .acquisition(Ei::default())
        .refit(RefitSchedule::Doubling { first: 16 })
        .iterations(iterations)
        .seed(42)
        .observer(JsonlObserver::create(&events).expect("event log"))
        .build_optimizer();

    let best = opt.optimize(&FnEval::new(2, |x: &[f64]| -branin(x[0], x[1])));

    println!("evaluations : {}", best.evaluations);
    println!("best x      : [{:.4}, {:.4}]  (user coordinates)", best.x[0], best.x[1]);
    println!("best value  : {:.6}  (optimum -0.397887)", best.value);
    println!("event log   : {}", events.display());

    // proposals and the incumbent live in the Branin box, not [0,1]^2
    assert!((-5.0..=10.0).contains(&best.x[0]) && (0.0..=15.0).contains(&best.x[1]));
    let floor = if smoke { -5.0 } else { -1.5 };
    assert!(best.value > floor, "should approach the optimum, got {}", best.value);

    let log = std::fs::read_to_string(&events).expect("event log written");
    let observations = log.lines().filter(|l| l.contains(r#""event":"observation""#)).count();
    assert_eq!(observations, best.evaluations, "one JSON row per observation");
    assert!(log.lines().last().unwrap().contains(r#""event":"stopped""#));
    println!("ok");
}
