//! The XLA-artifact GP path end-to-end: the same `BOptimizer` loop running
//! on the AOT-compiled JAX/Pallas graphs instead of the native GP, plus a
//! native-vs-XLA parity check and a fused-UCB acquisition demo.
//!
//! Requires `make artifacts` (Python runs once at build time; this binary
//! never touches Python).
//!
//! Run: `cargo run --release --example xla_backend`

use std::sync::Arc;

use limbo::bayes_opt::{BOptimizer, FnEval};
use limbo::benchfns::{Branin, TestFunction};
use limbo::coordinator::xla_model::XlaGpModel;
use limbo::init::Lhs;
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::opt::Direct;
use limbo::prelude::{Ei, Pcg64};
use limbo::runtime::{find_artifact_dir, RtClient, XlaGp};
use limbo::stop::MaxIterations;

fn main() {
    let Some(dir) = find_artifact_dir() else {
        eprintln!("artifacts/ not found — run `make artifacts` first");
        std::process::exit(1);
    };
    let client = Arc::new(RtClient::cpu().expect("PJRT CPU client"));
    println!("PJRT platform: {}", client.platform_name());
    let backend = Arc::new(XlaGp::new(client, &dir, "matern52").expect("backend"));
    println!(
        "artifacts: kind=matern52, tiers up to {} points, batch {}, d_max {}",
        backend.max_points(),
        backend.batch_size(),
        backend.d_max()
    );

    // ---- parity: native GP vs XLA artifacts on the same data ----
    let mut rng = Pcg64::seed(3);
    let xs: Vec<Vec<f64>> = (0..20).map(|_| rng.unit_point(2)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (5.0 * x[0]).sin() + x[1]).collect();

    let mut native = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
    native.fit(&xs, &ys);
    let mut xla = XlaGpModel::new(backend.clone(), 2);
    xla.loghp = native.xla_loghp();
    xla.fit(&xs, &ys);

    let mut max_dmu = 0.0f64;
    let mut max_dvar = 0.0f64;
    for _ in 0..50 {
        let p = rng.unit_point(2);
        let (mn, vn) = native.predict(&p);
        let (mx, vx) = xla.predict(&p);
        max_dmu = max_dmu.max((mn - mx).abs());
        max_dvar = max_dvar.max((vn - vx).abs());
    }
    println!("native-vs-XLA parity over 50 probes: |Δmu| <= {max_dmu:.2e}, |Δvar| <= {max_dvar:.2e}");
    assert!(max_dmu < 1e-3 && max_dvar < 1e-3, "backends must agree (f32 tolerance)");

    // ---- full BO run on the XLA backend (generic path: any Optimizer
    //      composes with XlaGpModel through the Model trait) ----
    let branin = Branin;
    let model = XlaGpModel::new(backend.clone(), 2);
    let mut opt = BOptimizer::new(
        model,
        Ei::default(),
        Lhs { n: 10 },
        Direct::new(300),
        MaxIterations(30),
        7,
    );
    let best = opt.optimize(&FnEval::new(2, |x: &[f64]| branin.eval(x)));
    println!(
        "XLA-backend BO on branin: best {:.5}, accuracy {:.3e}, {} evals",
        best.value,
        branin.accuracy(best.value),
        best.evaluations
    );

    // ---- same run on the optimized batched-acquisition path: the fused
    //      UCB artifact scores 64 candidates per execution, so each
    //      iteration costs ~8 executions instead of 300 ----
    use limbo::coordinator::batched_opt::BatchedUcbSearch;
    let t0 = std::time::Instant::now();
    let mut model = XlaGpModel::new(backend.clone(), 2);
    let mut brng = Pcg64::seed(7);
    for x in limbo::rng::latin_hypercube(10, 2, &mut brng) {
        let y = branin.eval(&x);
        model.add_sample(&x, y);
    }
    let search = BatchedUcbSearch::default();
    let mut best_v = f64::NEG_INFINITY;
    for _ in 0..30 {
        let cand = search.optimize(&model, 2, &mut brng);
        let y = branin.eval(&cand.x);
        model.add_sample(&cand.x, y);
        best_v = best_v.max(y);
    }
    println!(
        "XLA batched-acquisition BO on branin: accuracy {:.3e}, 40 evals in {:.2}s \
         (512 acq evals/iter at 8 artifact calls each)",
        branin.accuracy(best_v),
        t0.elapsed().as_secs_f64()
    );

    // ---- fused acquisition demo (predict -> UCB in one artifact call) ----
    let mut model = XlaGpModel::new(backend, 2);
    model.fit(&xs, &ys);
    let cands: Vec<Vec<f64>> = (0..64).map(|_| rng.unit_point(2)).collect();
    let fused = model.ucb_batch(&cands, 1.96);
    let unfused: Vec<f64> = model
        .predict_batch(&cands)
        .into_iter()
        .map(|(mu, var)| mu + 1.96 * var.sqrt())
        .collect();
    let dmax = fused
        .iter()
        .zip(&unfused)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("fused-vs-unfused UCB max |Δ| over 64 candidates: {dmax:.2e}");
    assert!(dmax < 1e-3);
    println!("ok");
}
