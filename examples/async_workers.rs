//! Truly asynchronous BO — q workers asking and telling in any
//! interleaving.
//!
//! `BoDef::async_pending(true)` replaces the synchronous constant-liar
//! batch with a pending-point set: every ask registers an outstanding
//! trial, and later proposals fantasize over it (kriging-believer mean
//! lies in a scratch model) until the matching tell retires it. No
//! worker ever waits for another worker's result, and no two concurrent
//! workers are handed duplicate proposals.
//!
//! Four worker threads share one managed study through cloneable
//! [`ManagedStudy`](limbo::coordinator::ManagedStudy) handles; each
//! loops ask → evaluate (with jittered simulated latency) → tell, so
//! tells retire pending trials in a different order than the asks
//! issued them.
//!
//! Run: `cargo run --release --example async_workers`
//! (`LIMBO_SMOKE=1` shrinks the budget for CI.)

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use limbo::bayes_opt::BoDef;
use limbo::coordinator::{Study, StudyManager};
use limbo::opt::RandomPoint;
use limbo::pool::ThreadPool;

/// Quadratic bowl on the unit square, optimum 0 at (0.62, 0.31).
fn objective(x: &[f64]) -> f64 {
    -(x[0] - 0.62).powi(2) - (x[1] - 0.31).powi(2)
}

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let rounds_per_worker = if smoke { 4 } else { 12 };
    const WORKERS: usize = 4;

    let mgr = Arc::new(StudyManager::new(Arc::new(ThreadPool::new(2))));
    let id = mgr
        .create(|| {
            BoDef::service(2)
                .seed(41)
                .async_pending(true)
                .inner_opt(RandomPoint::new(64))
                .build_server()
        })
        .expect("create study");

    thread::scope(|scope| {
        for w in 0..WORKERS {
            let mut study = mgr.study(id);
            scope.spawn(move || {
                for r in 0..rounds_per_worker {
                    let x = study.ask().expect("ask");
                    let y = objective(&x);
                    // jittered evaluation latency: tells come back out of
                    // order relative to the asks that produced them
                    thread::sleep(Duration::from_millis(((w * 7 + r * 3) % 11) as u64));
                    study.tell(&x, y).expect("tell");
                }
            });
        }
    });

    let mut study = mgr.study(id);
    let (bx, by) = study.best().expect("best").expect("observations recorded");
    study.finish().expect("close");

    println!("workers      : {WORKERS} x {rounds_per_worker} rounds");
    println!("best x       : [{:.4}, {:.4}]", bx[0], bx[1]);
    println!("best value   : {by:.6}  (optimum 0 at [0.62, 0.31])");
    assert!(by > -0.5, "asynchronous run should still converge, got {by}");
    println!("ok");
}
