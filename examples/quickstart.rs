//! Quickstart — the paper's first code example, translated to Rust.
//!
//! The C++ original:
//! ```cpp
//! limbo::bayes_opt::BOptimizer<Params> opt;
//! opt.optimize(my_fun());
//! ```
//! maximizes `my_fun(x) = -sum_i x_i^2 sin(2 x_i)` over `[0, 1]^2` with
//! the library defaults. `BoDef` is the `Params` struct analog: a
//! declarative definition that monomorphizes to the same concrete types
//! as hand-composition.
//!
//! Run: `cargo run --release --example quickstart`

use limbo::prelude::*;

fn main() {
    // the functor: dim_in = 2, dim_out = 1
    let my_fun = FnEval::new(2, |x: &[f64]| {
        -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>()
    });

    // default parameters (the `Params` struct of the C++ version):
    // Matérn-5/2 GP, data mean, UCB(0.5), 10 random init samples,
    // parallel-restarted random+Nelder-Mead inner optimizer, 40
    // iterations, doubling-schedule ML-II refits
    let mut opt = BoDef::new(2).seed(42).build_optimizer();
    let best = opt.optimize(&my_fun);

    println!("evaluations : {}", best.evaluations);
    println!("best x      : [{:.4}, {:.4}]", best.x[0], best.x[1]);
    println!("best value  : {:.6}", best.value);
    // on [0,1]^2 the maximum of -x^2 sin(2x) is 0 at x = (0, 0)
    assert!(best.value > -0.02, "should approach the optimum 0");
    println!("ok");
}
