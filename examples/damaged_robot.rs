//! Online gait adaptation after damage — the Cully et al. (2015) scenario
//! that motivates Limbo ("a legged robot learns a new gait after a
//! mechanical damage in about 10-15 trials").
//!
//! The physical robot is simulated: a hexapod with a simple open-loop CPG
//! gait controller (per-leg phase + amplitude parameters, compressed to a
//! 6-D search space). Walking speed is computed from stance kinematics;
//! damage (a broken leg that produces no thrust, plus a weakened
//! neighbor) changes the speed landscape so the pre-damage gait becomes
//! poor, and the optimizer must find a compensatory gait *online* through
//! the ask/tell interface — each "trial" is one episode on the robot.
//!
//! Run: `cargo run --release --example damaged_robot`

use limbo::prelude::*;

/// Simulated hexapod: legs 0..6, tripod-gait CPG controller.
struct Hexapod {
    /// Thrust multiplier per leg (1.0 healthy, 0.0 broken).
    leg_gain: [f64; 6],
}

impl Hexapod {
    fn healthy() -> Self {
        Self { leg_gain: [1.0; 6] }
    }

    /// Leg 1 broken (no thrust), leg 2 weakened (sensor-visible damage is
    /// NOT given to the optimizer — it only sees episode outcomes).
    fn damaged() -> Self {
        let mut r = Self::healthy();
        r.leg_gain[1] = 0.0;
        r.leg_gain[2] = 0.4;
        r
    }

    /// One gait episode. `p` in [0,1]^6: per-leg-pair phase offsets (3) and
    /// amplitudes (3). Returns mean forward speed (m/s-ish units).
    ///
    /// The model: each leg contributes thrust = gain * amp * stance
    /// fraction, but thrust is only useful when the tripod groups
    /// alternate correctly; phase mismatch produces drag and yaw loss.
    fn walk(&self, p: &[f64]) -> f64 {
        assert_eq!(p.len(), 6);
        let phases = [p[0], p[1], p[2]]; // leg pairs (0,3), (1,4), (2,5)
        let amps = [p[3], p[4], p[5]];
        let dt = 0.02;
        let steps = 250; // 5 simulated seconds
        let mut x_vel_sum = 0.0;
        let mut yaw = 0.0f64;
        for t in 0..steps {
            let time = t as f64 * dt;
            let mut thrust_left = 0.0;
            let mut thrust_right = 0.0;
            for leg in 0..6 {
                let pair = leg % 3;
                // tripod target: pairs alternate half a cycle
                let base_phase = if (leg / 3) == 0 { 0.0 } else { 0.5 };
                let phase = phases[pair] + base_phase;
                let duty = (2.0 * std::f64::consts::PI * (time + phase)).sin();
                // stance half of the cycle produces thrust
                let stance = duty.max(0.0);
                let thrust = self.leg_gain[leg] * amps[pair] * stance;
                // legs 0..3 on the left, 3..6 on the right
                if leg < 3 {
                    thrust_left += thrust;
                } else {
                    thrust_right += thrust;
                }
            }
            // asymmetric thrust turns the body; turning wastes speed
            yaw += (thrust_left - thrust_right) * dt * 0.25;
            let forward = (thrust_left + thrust_right) * 0.5 * yaw.cos().max(0.0);
            // drag grows quadratically with amplitude (energy limit)
            let drag = 0.2 * amps.iter().map(|a| a * a).sum::<f64>();
            x_vel_sum += (forward - drag).max(-0.5);
        }
        // scale to O(1) units so a unit-variance GP prior is well matched
        5.0 * x_vel_sum / steps as f64
    }
}

fn main() {
    let reference_gait = [0.25, 0.25, 0.25, 0.8, 0.8, 0.8];

    let healthy = Hexapod::healthy();
    let damaged = Hexapod::damaged();
    let v_healthy = healthy.walk(&reference_gait);
    let v_damaged_ref = damaged.walk(&reference_gait);
    println!("reference gait: healthy speed {v_healthy:.3}, after damage {v_damaged_ref:.3}");
    assert!(v_damaged_ref < v_healthy, "damage must hurt the reference gait");

    // online adaptation: UCB + GP, 15 trials max (the paper's "~2
    // minutes") — one declarative definition, built as an ask/tell
    // server (no init design: the robot seeds the model with the old
    // reference gait instead of random probes)
    let mut server = BoDef::new(6)
        .noise(1e-3)
        .acquisition(Ucb { alpha: 0.3 })
        .inner_opt(RandomPoint::new(512).then(NelderMead::default()).restarts(8, 4))
        .init(NoInit)
        .refit(RefitSchedule::Never)
        .seed(2015)
        .build_server();

    // seed with the (now bad) reference gait — the robot knows what used
    // to work
    server.tell(&reference_gait, v_damaged_ref);

    let mut best = v_damaged_ref;
    for trial in 1..=15 {
        let gait = server.ask();
        let speed = damaged.walk(&gait); // one physical episode
        server.tell(&gait, speed);
        if speed > best {
            best = speed;
        }
        println!("trial {trial:>2}: speed {speed:>7.3}  (best {best:.3})");
    }

    let (gait, speed) = server.best().unwrap();
    println!("\nrecovered gait after 15 trials: speed {speed:.3} (was {v_damaged_ref:.3} post-damage)");
    println!("gait parameters: {gait:?}");
    // a hexapod missing a leg cannot reach healthy speed again; success is
    // a solid improvement over the broken reference gait (Cully 2015
    // reports "a" working compensatory gait, not full recovery)
    assert!(
        speed > v_damaged_ref * 1.2,
        "adaptation should beat the post-damage reference gait by >= 20%: \
         {speed:.3} vs {v_damaged_ref:.3}"
    );
    println!("ok");
}
