//! The paper's second example: swapping components.
//!
//! The C++ original changes two template parameters:
//! ```cpp
//! using Kernel_t = limbo::kernel::MaternFiveHalves<Params>;
//! using GP_t     = limbo::model::GP<Params, Kernel_t, Mean_t>;
//! using Acqui_t  = limbo::acqui::UCB<Params, GP_t>;
//! limbo::bayes_opt::BOptimizer<Params, modelfun<GP_t>, acquifun<Acqui_t>> opt;
//! ```
//! Here the same swap is a different set of generic type arguments — still
//! fully monomorphized, no trait objects anywhere on the hot path.
//!
//! Run: `cargo run --release --example custom_components`

use limbo::prelude::*;
use limbo::bayes_opt::HpSchedule;
use limbo::opt::Cmaes;

fn main() {
    let my_fun = FnEval::new(2, |x: &[f64]| {
        -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>()
    });

    // ---- variant 1: Matérn-5/2 + UCB (the paper's snippet) ----
    let gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-3);
    let mut opt = BOptimizer::new(
        gp,
        Ucb { alpha: 0.5 },
        RandomSampling { n: 10 },
        RandomPoint::new(256).then(NelderMead::default()).restarts(8, 4),
        MaxIterations(30),
        1,
    );
    let best = opt.optimize(&my_fun);
    println!("Matern52 + UCB          : best {:.6} at {:?}", best.value, best.x);

    // ---- variant 2: SE-ARD kernel + EI + CMA-ES inner optimizer,
    //      with periodic hyper-parameter learning (KernelLFOpt) ----
    let mut gp = Gp::new(SquaredExpArd::new(2), DataMean::default(), 1e-3);
    gp.hp_opt.config.restarts = 2;
    let mut opt = BOptimizer::new(
        gp,
        Ei { xi: 0.01 },
        Lhs { n: 10 },
        Cmaes::new(400),
        MaxIterations(30),
        2,
    )
    .with_hp_schedule(HpSchedule::Every(5));
    let best = opt.optimize(&my_fun);
    println!("SE-ARD + EI + CMA-ES/HPO: best {:.6} at {:?}", best.value, best.x);

    // ---- variant 3: GP-UCB + DIRECT (deterministic inner optimizer) ----
    let gp = Gp::new(Matern32::new(2), ZeroMean, 1e-3);
    let mut opt = BOptimizer::new(
        gp,
        GpUcb { delta: 0.1 },
        limbo::init::GridSampling { bins: 3 },
        limbo::opt::Direct::new(400),
        MaxIterations(30),
        3,
    );
    let best = opt.optimize(&my_fun);
    println!("Matern32 + GP-UCB+DIRECT: best {:.6} at {:?}", best.value, best.x);
    println!("ok");
}
