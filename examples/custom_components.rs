//! The paper's second example: swapping components.
//!
//! The C++ original changes two template parameters:
//! ```cpp
//! using Kernel_t = limbo::kernel::MaternFiveHalves<Params>;
//! using GP_t     = limbo::model::GP<Params, Kernel_t, Mean_t>;
//! using Acqui_t  = limbo::acqui::UCB<Params, GP_t>;
//! limbo::bayes_opt::BOptimizer<Params, modelfun<GP_t>, acquifun<Acqui_t>> opt;
//! ```
//! Here the same swap is a different `BoDef` setter call: each setter
//! that replaces a policy replaces a *type parameter* of the definition,
//! so the result is still fully monomorphized — no trait objects
//! anywhere on the hot path.
//!
//! Run: `cargo run --release --example custom_components`

use limbo::prelude::*;
use limbo::opt::Cmaes;

fn main() {
    let my_fun = FnEval::new(2, |x: &[f64]| {
        -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>()
    });

    // ---- variant 1: Matérn-5/2 + UCB (the paper's snippet) ----
    let mut opt = BoDef::new(2)
        .noise(1e-3)
        .acquisition(Ucb { alpha: 0.5 })
        .refit(RefitSchedule::Never)
        .iterations(30)
        .seed(1)
        .build_optimizer();
    let best = opt.optimize(&my_fun);
    println!("Matern52 + UCB          : best {:.6} at {:?}", best.value, best.x);

    // ---- variant 2: SE-ARD kernel + EI + CMA-ES inner optimizer,
    //      with periodic hyper-parameter learning (KernelLFOpt) ----
    let mut opt = BoDef::new(2)
        .kernel(SquaredExpArd::new)
        .noise(1e-3)
        .acquisition(Ei { xi: 0.01 })
        .init(Lhs { n: 10 })
        .inner_opt(Cmaes::new(400))
        .refit(RefitSchedule::Every(5))
        .hp_config(limbo::model::HpOptConfig { restarts: 2, ..Default::default() })
        .iterations(30)
        .seed(2)
        .build_optimizer();
    let best = opt.optimize(&my_fun);
    println!("SE-ARD + EI + CMA-ES/HPO: best {:.6} at {:?}", best.value, best.x);

    // ---- variant 3: GP-UCB + DIRECT (deterministic inner optimizer) ----
    let mut opt = BoDef::new(2)
        .kernel(Matern32::new)
        .mean(ZeroMean)
        .noise(1e-3)
        .acquisition(GpUcb { delta: 0.1 })
        .init(limbo::init::GridSampling { bins: 3 })
        .inner_opt(limbo::opt::Direct::new(400))
        .refit(RefitSchedule::Never)
        .iterations(30)
        .seed(3)
        .build_optimizer();
    let best = opt.optimize(&my_fun);
    println!("Matern32 + GP-UCB+DIRECT: best {:.6} at {:?}", best.value, best.x);
    println!("ok");
}
