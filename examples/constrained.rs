//! Constrained Bayesian optimization end to end — Branin under a disk
//! constraint through the probability-of-feasibility weight.
//!
//! `BoDef::constraints(k)` declares `k` inequality-constraint channels
//! (`>= 0` = feasible); `build_constrained_server` then banks one GP
//! surrogate per channel next to the objective GP and wraps the
//! acquisition in [`PofWeighted`], which multiplies every candidate's
//! base score by its probability of satisfying all channels. Each tell
//! carries the constraint measurement alongside the objective through a
//! typed [`Observation`], so the feasibility model learns from the same
//! samples as the objective model.
//!
//! The objective is the classic Branin function (maximized as
//! `-branin`) with the Gardner-style disk constraint
//! `(x - 2.5)^2 + (y - 7.5)^2 <= 50`: of Branin's three global minima
//! only `(pi, 2.275)` lies inside the disk, so an unconstrained run is
//! free to converge to an infeasible optimum while this one must not.
//!
//! Run: `cargo run --release --example constrained`
//! (`LIMBO_SMOKE=1` shrinks the budget for CI.)

use limbo::prelude::*;

/// Branin–Hoo in its native coordinates (minimization form).
fn branin(x: f64, y: f64) -> f64 {
    let a = 1.0;
    let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
    let c = 5.0 / std::f64::consts::PI;
    let r = 6.0;
    let s = 10.0;
    let t = 1.0 / (8.0 * std::f64::consts::PI);
    a * (y - b * x * x + c * x - r).powi(2) + s * (1.0 - t) * x.cos() + s
}

/// Disk constraint, library convention: `>= 0` = feasible. Keeps one of
/// Branin's three minima inside the feasible region.
fn disk(x: f64, y: f64) -> f64 {
    50.0 - ((x - 2.5).powi(2) + (y - 7.5).powi(2))
}

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let rounds = if smoke { 35 } else { 90 };

    let mut srv = BoDef::new(2)
        .bounds(&[(-5.0, 10.0), (0.0, 15.0)])
        .acquisition(Ei::default())
        .constraints(1)
        .init_samples(10)
        .refit(RefitSchedule::Doubling { first: 8 })
        .seed(7)
        .build_constrained_server();

    let mut best_feasible: Option<(Vec<f64>, f64)> = None;
    let mut n_feasible = 0usize;
    for _ in 0..rounds {
        let x = srv.ask();
        let y = -branin(x[0], x[1]);
        let c = disk(x[0], x[1]);
        if c >= 0.0 {
            n_feasible += 1;
            let improved = match &best_feasible {
                None => true,
                Some((_, incumbent)) => y > *incumbent,
            };
            if improved {
                best_feasible = Some((x.clone(), y));
            }
        }
        srv.tell_observation(&Observation::exact(x, y).with_constraints(vec![c]))
            .expect("one value per declared constraint channel");
    }
    srv.finish();

    let (bx, by) = best_feasible.expect("the run must find at least one feasible point");
    println!("rounds            : {rounds}");
    println!("feasible samples  : {n_feasible}");
    println!("best feasible x   : [{:.4}, {:.4}]", bx[0], bx[1]);
    println!("best feasible val : {by:.6}  (feasible optimum -0.397887)");

    assert!(disk(bx[0], bx[1]) >= 0.0, "incumbent must satisfy the disk constraint");
    let floor = if smoke { -10.0 } else { -2.0 };
    assert!(by > floor, "feasible convergence too weak: {by}");
    println!("ok");
}
