//! Sparse GP — a 2 000-evaluation ask/tell run on the [`AdaptiveModel`]
//! surrogate.
//!
//! The dense GP is exact but pays O(n²) per prediction and O(n³) per
//! refit; at a 2 000-sample budget that dominates the loop. The
//! `AdaptiveModel` starts dense (exact, cheap while small) and migrates
//! to the FITC sparse GP (`model/sgp`) once the observation count crosses
//! its threshold, after which per-iteration cost is governed by the
//! m = 128 inducing points rather than by n.
//!
//! Run: `cargo run --release --example sparse_gp`
//! (`LIMBO_SMOKE=1` shrinks the budget to a CI-sized run that still
//! crosses the dense→sparse migration and one sparse FITC hyper-refit.)

use std::time::Instant;

use limbo::coordinator::AskTellServer;
use limbo::prelude::*;

fn main() {
    let smoke = matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1"));
    let dim = 2;
    let budget = if smoke { 320 } else { 2_000usize };
    // multimodal synthetic target on [0,1]^2: one dominant bump near
    // (0.2, 0.7) plus an oscillating field of local optima
    let f = |x: &[f64]| {
        let a = (x[0] - 0.2) * 3.0;
        let b = (x[1] - 0.7) * 3.0;
        (-(a * a + b * b)).exp() + 0.3 * (8.0 * x[0]).sin() * (7.0 * x[1]).cos()
    };

    let model = AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 1e-3)
        .with_threshold(256)
        .with_sparse_config(SgpConfig { max_inducing: 128, ..SgpConfig::default() });
    // doubling-schedule ML-II refits: dense while small, the exact FITC
    // marginal likelihood once the model has migrated
    // (refit points 40, 80, 160, 320, ... land one refit past the
    // migration threshold even in the smoke run)
    let mut srv =
        AskTellServer::from_core(BoCore::new(model, Ucb::default(), RandomPoint::new(96), dim, 42))
            .with_refit(RefitSchedule::Doubling { first: 40 });

    // profile the whole run: the phase table at the end attributes the
    // wall time to ask/tell service, Cholesky, sparse fit, migration...
    limbo::obs::set_enabled(true);
    let metrics_base = limbo::obs::snapshot();
    let t0 = Instant::now();
    let mut switched_at = None;
    for i in 1..=budget {
        let x = srv.ask();
        let y = f(&x);
        srv.tell(&x, y);
        if switched_at.is_none() && srv.core.model.is_sparse() {
            switched_at = Some(i);
        }
        if i % 250 == 0 {
            let (bx, bv) = srv.best().expect("observations recorded");
            println!(
                "eval {i:>5}  t={:>8.2?}  model={:<6}  best={bv:.4} at ({:.3}, {:.3})",
                t0.elapsed(),
                if srv.core.model.is_sparse() { "sparse" } else { "dense" },
                bx[0],
                bx[1],
            );
        }
    }

    let (bx, bv) = srv.best().expect("observations recorded");
    println!("\ntotal       : {:.2?} for {budget} evaluations", t0.elapsed());
    println!(
        "migration   : dense -> sparse at eval {} (threshold {})",
        switched_at.map_or_else(|| "never".to_string(), |i| i.to_string()),
        srv.core.model.threshold(),
    );
    if let Some(sgp) = srv.core.model.as_sparse() {
        println!(
            "sparse model: n={} observations summarized by m={} inducing points",
            sgp.n_samples(),
            sgp.inducing_points().len(),
        );
    }
    println!("best value  : {bv:.6} at ({:.4}, {:.4})", bx[0], bx[1]);

    let wall = t0.elapsed().as_secs_f64();
    let delta = limbo::obs::snapshot().delta_since(&metrics_base);
    println!("\n{}", delta.render_table(Some(wall)));
    println!(
        "phase coverage: ask+tell spans account for {:.1}% of {:.2}s wall",
        100.0 * delta.service_seconds() / wall.max(f64::MIN_POSITIVE),
        wall
    );
}
