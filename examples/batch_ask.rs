//! q-batch ask/tell: one suggestion server feeding 4 parallel workers,
//! under both batch strategies.
//!
//! The scenario the batched pipeline opens up (ROADMAP): instead of one
//! robot trying one trial at a time, a farm of evaluators runs q trials
//! concurrently. Each round the server proposes `q = 4` points, the
//! workers evaluate them in parallel threads (here: a noisy synthetic
//! objective standing in for 4 physical robots), and every outcome is
//! told back before the next round.
//!
//! Two proposal strategies run back to back
//! ([`limbo::coordinator::BatchStrategy`]):
//!
//! * **constant liar** (default) — q pointwise maximizations with
//!   posterior-mean lies in between: lowest proposal latency, blind to
//!   the joint posterior;
//! * **qEI** — Monte-Carlo multi-point expected improvement over the
//!   joint posterior (frozen common random numbers per round): costs
//!   more proposal compute per round, but the batch is scored as a
//!   *set*, so diversity is bought exactly where the posterior
//!   correlations say it pays. Prefer it when a trial is expensive
//!   relative to the proposal optimization.
//!
//! Run with: `cargo run --release --example batch_ask`

use std::thread;
use std::time::{Duration, Instant};

use limbo::prelude::*;

/// The simulated experiment each worker runs (maximum 0 at (0.7, 0.3));
/// the sleep stands in for the physical trial the paper's robots execute.
fn run_trial(x: &[f64]) -> f64 {
    thread::sleep(Duration::from_millis(5));
    -(x[0] - 0.7).powi(2) - (x[1] - 0.3).powi(2)
}

fn drive(label: &str, strategy: BatchStrategy, rounds: usize) {
    const Q: usize = 4;
    // service defaults (adaptive surrogate, no init design) through the
    // declarative builder, with the batch strategy as part of the
    // definition
    let server = BoDef::service(2).seed(42).batch(strategy).build_adaptive_server().spawn();
    let t0 = Instant::now();

    for round in 0..rounds {
        let batch = server.ask_batch(Q);

        // dispatch the q trials to q parallel workers
        let outcomes: Vec<(Vec<f64>, f64)> = thread::scope(|scope| {
            let workers: Vec<_> = batch
                .into_iter()
                .map(|x| {
                    scope.spawn(move || {
                        let y = run_trial(&x);
                        (x, y)
                    })
                })
                .collect();
            workers.into_iter().map(|w| w.join().expect("worker finished")).collect()
        });

        let trials: Vec<String> = outcomes
            .iter()
            .map(|(x, y)| format!("({:.3}, {:.3}) -> {y:.4}", x[0], x[1]))
            .collect();
        for (x, y) in outcomes {
            server.tell(x, y);
        }
        let best = server.best().expect("observations recorded");
        println!(
            "[{label}] round {round}: trials [{}], incumbent {:.5} at ({:.3}, {:.3})",
            trials.join(", "),
            best.1,
            best.0[0],
            best.0[1]
        );
    }

    let best = server.best().expect("observations recorded");
    println!(
        "[{label}] {} evaluations across {Q} parallel workers in {:.2}s -> best {:.5} at ({:.3}, {:.3})\n",
        rounds * Q,
        t0.elapsed().as_secs_f64(),
        best.1,
        best.0[0],
        best.0[1]
    );
}

fn main() {
    let rounds: usize =
        if matches!(std::env::var("LIMBO_SMOKE").as_deref(), Ok("1")) { 4 } else { 8 };
    drive("constant-liar", BatchStrategy::ConstantLiar, rounds);
    drive("qEI", BatchStrategy::QEi { mc_samples: 256 }, rounds);
}
