#!/usr/bin/env python3
"""CI bench-trajectory gate.

Merges the JSON-lines rows emitted by the smoke benches
(`acqui_opt --smoke` -> target/acqui_opt_batch.json,
`gp_scaling --smoke` -> target/gp_scaling.json,
`batch_propose --smoke` -> target/batch_propose.json,
`fig1_time --smoke` -> target/fig1_time.json,
`kernel_micro --smoke` -> target/kernel_micro.json,
`manager_load --smoke` -> target/manager_load.json) into one
`BENCH_PR.json` document, compares it against the checked-in
`rust/benches/baseline.json`, and fails (exit 1) on a >30%
candidates/sec regression at any batch size.

Gate policy
-----------
* `acqui_batch` rows gate **hard**: `batched_cps` and `pointwise_cps`
  (higher is better) may not drop more than `--max-regression` (default
  0.30) below the baseline at any batch size.
* `gp_scaling` and `batch_propose` rows are tracked warn-only:
  `fit_plus_predict_s` / `propose_s` (lower is better) regressions print
  a warning but never fail the job (wall-clock timings are too noisy on
  shared CI runners for a hard gate).
* `fig1_time` rows track the static-vs-dynamic speed-up `ratio` (higher
  is better), `fig1_scenario` rows track the noisy/constrained Branin
  cells' `seconds` and `(feasible_)regret` (lower is better),
  `fig1_inner_opt` rows track the acquisition-maximizer sweep's
  `seconds` and `regret` (DIRECT vs CMA-ES vs DE, lower is better), and
  `kernel_micro` rows track `gram_blocked_s` (lower is
  better); all warn-only — a ratio falling below the 2x advantage the
  PR pins is a warning, not a hard failure, because full-run wall-clock
  on shared runners is noisy.
* `gp_scaling_phase`, `batch_propose_phase`, and `fig1_time_phase` rows
  (per-phase seconds from the `limbo::obs` span registry) are also
  warn-only; they exist to attribute a headline regression to a phase —
  when `propose_s` or `ratio` warns, the matching phase rows say whether
  the inner optimizer, the qEI MC sampler, or the Cholesky factor
  slowed down.
* If the baseline has `"warn_only": true`, or has no matching row for a
  PR row, everything downgrades to warnings — this is how the gate
  behaves on first landing, while the baseline seeds. With
  `"warn_only": false` the candidates/sec gate is armed and fails the
  job as soon as matching baseline rows exist.
* `--baseline-fallback` names a second rows file (CI passes the
  trunk-cache copy of the seed artifact) used ONLY when the committed
  baseline has no rows: the armed gate then compares against the last
  trunk run instead of silently passing with "baseline still seeding".

Refreshing the baseline
-----------------------
Run the two smoke benches locally (or download `BENCH_PR.json` from a CI
run on the target runner class), then:

    python3 scripts/bench_compare.py \
        --pr rust/target/acqui_opt_batch.json rust/target/gp_scaling.json \
             rust/target/batch_propose.json \
        --write-baseline rust/benches/baseline.json

and commit the result. A freshly written baseline has `warn_only: false`,
arming the hard gate.
"""

import argparse
import json
import sys


def read_rows(paths):
    """Read JSON-lines rows from each existing path (missing files warn)."""
    rows = []
    for path in paths:
        try:
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        rows.append(json.loads(line))
        except FileNotFoundError:
            print(f"WARN: bench output {path} not found (bench skipped?)")
    return rows


def row_key(row):
    """Identity of a bench config across runs."""
    if row.get("bench") == "acqui_batch":
        return ("acqui_batch", row.get("n"), row.get("dim"), row.get("batch"))
    if row.get("bench") == "gp_scaling":
        return ("gp_scaling", row.get("model"), row.get("n"), row.get("m"))
    if row.get("bench") == "batch_propose":
        return ("batch_propose", row.get("strategy"), row.get("n"), row.get("q"))
    if row.get("bench") == "gp_scaling_phase":
        return ("gp_scaling_phase", row.get("model"), row.get("n"), row.get("m"),
                row.get("phase"))
    if row.get("bench") == "batch_propose_phase":
        return ("batch_propose_phase", row.get("strategy"), row.get("n"),
                row.get("q"), row.get("phase"))
    if row.get("bench") == "fig1_time":
        return ("fig1_time", row.get("func"), row.get("dim"), row.get("iters"),
                row.get("hpo"))
    if row.get("bench") == "fig1_time_phase":
        return ("fig1_time_phase", row.get("func"), row.get("dim"),
                row.get("iters"), row.get("hpo"), row.get("phase"))
    if row.get("bench") == "fig1_scenario":
        return ("fig1_scenario", row.get("scenario"), row.get("rounds"))
    if row.get("bench") == "fig1_inner_opt":
        return ("fig1_inner_opt", row.get("inner"), row.get("func"),
                row.get("dim"))
    if row.get("bench") == "kernel_micro":
        return ("kernel_micro", row.get("kernel"), row.get("n"))
    if row.get("bench") == "manager_load":
        return ("manager_load", row.get("mode"), row.get("studies"),
                row.get("rounds"))
    if row.get("bench") == "manager_load_phase":
        return ("manager_load_phase", row.get("mode"), row.get("studies"),
                row.get("phase"))
    return (row.get("bench"), json.dumps(row, sort_keys=True))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pr", nargs="+", default=[], help="PR bench JSON-lines files")
    ap.add_argument("--baseline", help="checked-in baseline.json")
    ap.add_argument("--out", help="merged BENCH_PR.json output path")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="fractional candidates/sec drop that fails the job")
    ap.add_argument("--write-baseline",
                    help="write a fresh baseline from the PR rows and exit")
    ap.add_argument("--baseline-fallback",
                    help="JSON rows file used when the committed baseline "
                         "has no rows (CI passes the trunk-cache copy of "
                         "the seed artifact)")
    args = ap.parse_args()

    pr_rows = read_rows(args.pr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": pr_rows}, f, indent=1)
        print(f"merged {len(pr_rows)} rows -> {args.out}")

    if args.write_baseline:
        with open(args.write_baseline, "w") as f:
            json.dump({
                "_comment": "Recorded by scripts/bench_compare.py "
                            "--write-baseline from a real bench run; refresh "
                            "from CI-runner rows, never hand-edit the numbers.",
                "warn_only": False,
                "rows": pr_rows,
            }, f, indent=1)
        print(f"baseline seeded with {len(pr_rows)} rows -> {args.write_baseline}")
        return 0

    if not args.baseline:
        print("no --baseline given; nothing to compare")
        return 0
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"WARN: baseline {args.baseline} missing; warn-only run")
        baseline = {"warn_only": True, "rows": []}

    warn_only = bool(baseline.get("warn_only", False))
    if not baseline.get("rows") and args.baseline_fallback:
        try:
            with open(args.baseline_fallback) as f:
                doc = json.load(f)
            fb_rows = doc.get("rows", []) if isinstance(doc, dict) else doc
            if fb_rows:
                print(f"baseline has no rows; comparing against fallback "
                      f"{args.baseline_fallback} ({len(fb_rows)} trunk rows)")
                baseline["rows"] = fb_rows
        except FileNotFoundError:
            print(f"WARN: baseline fallback {args.baseline_fallback} not "
                  "found (no trunk cache yet)")
    base_by_key = {row_key(r): r for r in baseline.get("rows", [])}
    failures, warnings = [], []

    for row in pr_rows:
        key = row_key(row)
        base = base_by_key.get(key)
        if base is None:
            warnings.append(f"no baseline for {key} (baseline still seeding?)")
            continue
        if row.get("bench") == "acqui_batch":
            for metric in ("batched_cps", "pointwise_cps"):
                now, then = row.get(metric), base.get(metric)
                # None/<=0 baseline = unusable reference; a 0.0 PR value is
                # a real (total) regression and must NOT skip the gate
                if now is None or then is None or then <= 0:
                    continue
                drop = 1.0 - now / then
                line = (f"{key} {metric}: {then:.0f} -> {now:.0f} cand/s "
                        f"({-drop:+.1%})")
                if drop > args.max_regression:
                    (warnings if warn_only else failures).append(line)
                else:
                    print(f"ok   {line}")
        elif row.get("bench") == "gp_scaling":
            now, then = row.get("fit_plus_predict_s"), base.get("fit_plus_predict_s")
            if now is None or then is None or then <= 0:
                continue
            slowdown = now / then - 1.0
            line = f"{key} fit+predict: {then:.4f}s -> {now:.4f}s ({slowdown:+.1%})"
            if slowdown > args.max_regression:
                warnings.append(line)  # timing rows are warn-only by policy
            else:
                print(f"ok   {line}")
        elif row.get("bench") == "batch_propose":
            # proposal latency: warn-only like the other wall-clock rows
            now, then = row.get("propose_s"), base.get("propose_s")
            if now is None or then is None or then <= 0:
                continue
            slowdown = now / then - 1.0
            line = f"{key} propose: {then:.4f}s -> {now:.4f}s ({slowdown:+.1%})"
            if slowdown > args.max_regression:
                warnings.append(line)
            else:
                print(f"ok   {line}")
        elif row.get("bench") == "fig1_time":
            # static-vs-dynamic speed-up: higher is better, warn-only
            now, then = row.get("ratio"), base.get("ratio")
            if now is None or then is None or then <= 0:
                continue
            drop = 1.0 - now / then
            line = f"{key} speed-up ratio: {then:.2f}x -> {now:.2f}x ({-drop:+.1%})"
            if drop > args.max_regression:
                warnings.append(line)
            else:
                print(f"ok   {line}")
        elif row.get("bench") == "fig1_scenario":
            # generalized-observation cells (noisy / constrained Branin):
            # wall-clock and regret, both warn-only like the other
            # full-run timing rows
            now, then = row.get("seconds"), base.get("seconds")
            if now is not None and then is not None and then > 0:
                slowdown = now / then - 1.0
                line = f"{key} seconds: {then:.4f}s -> {now:.4f}s ({slowdown:+.1%})"
                if slowdown > args.max_regression:
                    warnings.append(line)
                else:
                    print(f"ok   {line}")
            for metric in ("regret", "feasible_regret"):
                now, then = row.get(metric), base.get(metric)
                if now is None or then is None or then <= 0:
                    continue
                growth = now / then - 1.0
                line = f"{key} {metric}: {then:.4f} -> {now:.4f} ({growth:+.1%})"
                if growth > args.max_regression:
                    warnings.append(line)
                else:
                    print(f"ok   {line}")
        elif row.get("bench") == "fig1_inner_opt":
            # acquisition-maximizer sweep (DIRECT vs CMA-ES vs DE):
            # wall-clock and final regret, warn-only like the other
            # full-run rows
            now, then = row.get("seconds"), base.get("seconds")
            if now is not None and then is not None and then > 0:
                slowdown = now / then - 1.0
                line = f"{key} seconds: {then:.4f}s -> {now:.4f}s ({slowdown:+.1%})"
                if slowdown > args.max_regression:
                    warnings.append(line)
                else:
                    print(f"ok   {line}")
            now, then = row.get("regret"), base.get("regret")
            if now is not None and then is not None and then > 0:
                growth = now / then - 1.0
                line = f"{key} regret: {then:.4f} -> {now:.4f} ({growth:+.1%})"
                if growth > args.max_regression:
                    warnings.append(line)
                else:
                    print(f"ok   {line}")
        elif row.get("bench") == "kernel_micro":
            # blocked Gram wall-clock: lower is better, warn-only
            now, then = row.get("gram_blocked_s"), base.get("gram_blocked_s")
            if now is None or then is None or then <= 0:
                continue
            slowdown = now / then - 1.0
            line = f"{key} gram_blocked: {then:.6f}s -> {now:.6f}s ({slowdown:+.1%})"
            if slowdown > args.max_regression:
                warnings.append(line)
            else:
                print(f"ok   {line}")
        elif row.get("bench") == "manager_load":
            # multi-study throughput (higher is better) and ask tail
            # latency (lower is better); wall-clock rows are warn-only
            now, then = row.get("studies_per_sec"), base.get("studies_per_sec")
            if now is not None and then is not None and then > 0:
                drop = 1.0 - now / then
                line = (f"{key} throughput: {then:.0f} -> {now:.0f} "
                        f"studies/s ({-drop:+.1%})")
                if drop > args.max_regression:
                    warnings.append(line)
                else:
                    print(f"ok   {line}")
            now, then = row.get("ask_p99_s"), base.get("ask_p99_s")
            if now is not None and then is not None and then > 0:
                slowdown = now / then - 1.0
                line = (f"{key} ask p99: {then:.5f}s -> {now:.5f}s "
                        f"({slowdown:+.1%})")
                if slowdown > args.max_regression:
                    warnings.append(line)
                else:
                    print(f"ok   {line}")
        elif row.get("bench") in ("gp_scaling_phase", "batch_propose_phase",
                                  "fig1_time_phase", "manager_load_phase"):
            # per-phase attribution rows (warn-only): when a headline row
            # above warns, these say WHICH phase regressed
            now, then = row.get("seconds"), base.get("seconds")
            if now is None or then is None or then <= 0:
                continue
            slowdown = now / then - 1.0
            line = f"{key}: {then:.4f}s -> {now:.4f}s ({slowdown:+.1%})"
            if slowdown > args.max_regression:
                warnings.append(line)
            else:
                print(f"ok   {line}")

    if not warn_only and not base_by_key:
        warnings.append(
            "baseline is armed (warn_only: false) but has no rows yet — "
            "download the bench-baseline-seed artifact from a trunk CI run "
            "and commit it as rust/benches/baseline.json")

    for w in warnings:
        print(f"WARN {w}")
    for f_ in failures:
        print(f"FAIL {f_}")
    if failures:
        print(f"\n{len(failures)} hard bench regression(s) beyond "
              f"{args.max_regression:.0%} — failing the job. If intentional, "
              "refresh the baseline (see --write-baseline).")
        return 1
    print("\nbench-compare gate passed"
          + (" (warn-only: baseline still seeding)" if warn_only else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
