"""Pure-jnp oracles for the Pallas Gram kernels (the L1 correctness signal).

These are the reference implementations every Pallas kernel in
``gram.py`` is checked against (pytest + hypothesis), and they are also
used inside the differentiable LML graph (``model.py``) where autodiff
through ``pallas_call`` is not wanted.

Hyper-parameter conventions (shared with the Rust side):

* ``inv_ls2``  -- per-dimension inverse squared lengthscales ``1/l_d^2``
* ``sigma2``   -- signal variance ``sigma_f^2``

Padded feature dimensions carry constant zeros on both inputs, so they
contribute nothing to any stationary kernel regardless of ``inv_ls2``.
"""

from __future__ import annotations

import jax.numpy as jnp

SQRT5 = 2.2360679774997896
SQRT3 = 1.7320508075688772


def sq_dists(x1: jnp.ndarray, x2: jnp.ndarray, inv_ls2: jnp.ndarray) -> jnp.ndarray:
    """ARD-scaled pairwise squared distances, shape ``[n1, n2]``."""
    diff = x1[:, None, :] - x2[None, :, :]
    return jnp.sum(diff * diff * inv_ls2[None, None, :], axis=-1)


def gram_se_ard(x1, x2, inv_ls2, sigma2):
    """Squared-exponential ARD kernel: ``s2 * exp(-0.5 * r2)``."""
    return sigma2 * jnp.exp(-0.5 * sq_dists(x1, x2, inv_ls2))


def gram_matern52(x1, x2, inv_ls2, sigma2):
    """Matern-5/2 ARD kernel: ``s2 (1 + sqrt5 r + 5/3 r^2) exp(-sqrt5 r)``."""
    r2 = sq_dists(x1, x2, inv_ls2)
    r = jnp.sqrt(jnp.maximum(r2, 1e-30))
    return sigma2 * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-SQRT5 * r)


def gram_matern32(x1, x2, inv_ls2, sigma2):
    """Matern-3/2 ARD kernel: ``s2 (1 + sqrt3 r) exp(-sqrt3 r)``."""
    r2 = sq_dists(x1, x2, inv_ls2)
    r = jnp.sqrt(jnp.maximum(r2, 1e-30))
    return sigma2 * (1.0 + SQRT3 * r) * jnp.exp(-SQRT3 * r)


GRAMS = {
    "se_ard": gram_se_ard,
    "matern52": gram_matern52,
    "matern32": gram_matern32,
}
