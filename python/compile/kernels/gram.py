"""L1 Pallas kernels: tiled Gram-matrix computation for the GP hot spot.

The O(n1*n2*d) Gram matrix (and its cross-covariance sibling) dominates GP
inference cost, so it is the layer-1 kernel.  The kernel is tiled for a TPU
VMEM budget with ``BlockSpec``: the grid walks (row-tile, col-tile) blocks of
the output; each program loads one ``(TN, D)`` block of ``x1`` and one
``(TM, D)`` block of ``x2`` into VMEM and produces a ``(TN, TM)`` output
block.

MXU mapping (the §Hardware-Adaptation story): instead of materializing the
``(TN, TM, D)`` difference tensor, we pre-scale the inputs by
``sqrt(inv_ls2)`` and use the classic expansion

    r2[i, j] = |x1t[i]|^2 + |x2t[j]|^2 - 2 * x1t @ x2t^T

so the inner product runs on the systolic array (``jnp.dot``) rather than
the VPU.  The tiny negative values the expansion can produce are clamped.

``interpret=True`` everywhere: the CPU PJRT runtime cannot execute Mosaic
custom-calls, and interpret-mode lowers to portable HLO that the Rust
runtime replays.  Correctness versus ``ref.py`` is pinned by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and scales).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes.  All capacity tiers (32/64/128/256) and the candidate
# batch (64) are multiples of 32, so no output-block masking is needed.
TILE_N = 32
TILE_M = 32


def _scaled_sq_dists(x1t, x2t):
    """Blockwise ARD squared distances via the MXU-friendly expansion."""
    n1 = jnp.sum(x1t * x1t, axis=-1)  # (TN,)
    n2 = jnp.sum(x2t * x2t, axis=-1)  # (TM,)
    cross = jnp.dot(x1t, x2t.T, preferred_element_type=jnp.float32)
    r2 = n1[:, None] + n2[None, :] - 2.0 * cross
    return jnp.maximum(r2, 0.0)


def _kernel_body(kind, x1_ref, x2_ref, ils_ref, s2_ref, o_ref):
    ils = ils_ref[...]
    scale = jnp.sqrt(ils)[None, :]
    x1t = x1_ref[...] * scale
    x2t = x2_ref[...] * scale
    r2 = _scaled_sq_dists(x1t, x2t)
    s2 = s2_ref[0]
    if kind == "se_ard":
        o_ref[...] = s2 * jnp.exp(-0.5 * r2)
    elif kind == "matern52":
        r = jnp.sqrt(jnp.maximum(r2, 1e-30))
        o_ref[...] = s2 * (1.0 + ref.SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-ref.SQRT5 * r)
    elif kind == "matern32":
        r = jnp.sqrt(jnp.maximum(r2, 1e-30))
        o_ref[...] = s2 * (1.0 + ref.SQRT3 * r) * jnp.exp(-ref.SQRT3 * r)
    else:  # pragma: no cover - guarded by GRAM_KINDS
        raise ValueError(f"unknown kernel kind {kind!r}")


GRAM_KINDS = ("se_ard", "matern52", "matern32")


def gram(kind, x1, x2, inv_ls2, sigma2, *, tile_n=TILE_N, tile_m=TILE_M,
         interpret=True):
    """Tiled Pallas Gram matrix ``K[kind](x1, x2)`` of shape ``[n1, n2]``.

    ``x1: [n1, d]``, ``x2: [n2, d]``, ``inv_ls2: [d]``, ``sigma2: [1]``.
    ``n1`` and ``n2`` must be multiples of the tile sizes (callers pad to
    capacity tiers anyway).
    """
    if kind not in GRAM_KINDS:
        raise ValueError(f"unknown kernel kind {kind!r}")
    n1, d = x1.shape
    n2 = x2.shape[0]
    tile_n = min(tile_n, n1)
    tile_m = min(tile_m, n2)
    if n1 % tile_n or n2 % tile_m:
        raise ValueError(f"gram: ({n1},{n2}) not divisible by ({tile_n},{tile_m})")
    grid = (n1 // tile_n, n2 // tile_m)
    return pl.pallas_call(
        functools.partial(_kernel_body, kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, n2), x1.dtype),
        interpret=interpret,
    )(x1, x2, inv_ls2, sigma2)
