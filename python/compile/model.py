"""L2: the GP compute graphs that get AOT-lowered to HLO artifacts.

Three programs per (kernel kind, capacity tier):

* ``gp_predict``  -- posterior mean/variance of a batch of candidates
                     (forward path; Gram matrices via the L1 Pallas kernel)
* ``gp_ucb``      -- ``gp_predict`` fused with the UCB acquisition
                     ``mu + alpha * sqrt(var)`` (the optimized hot path)
* ``gp_lml_grad`` -- log marginal likelihood + gradient w.r.t. the log
                     hyper-parameters (uses the differentiable ``ref``
                     Gram; ``pallas_call`` has no registered VJP)

Static-shape protocol (shared with the Rust runtime — keep in sync with
``rust/src/runtime/``):

* capacity tier ``n``: training inputs are padded to ``n`` rows with a 0/1
  ``mask``; the masked Gram ``K' = (m m^T) o (K + s_n^2 I) + diag(1 - m)``
  makes padded rows exact no-ops (block-diagonal Cholesky, alpha = 0 there).
* features padded to ``D_MAX`` columns of zeros (stationary kernels ignore
  constant-zero coordinates).
* hyper-parameters: ``loghp[0:D_MAX]`` = log lengthscales, ``loghp[D_MAX]``
  = log sigma_f, ``loghp[D_MAX + 1]`` = log sigma_n.
* the prior-mean *value* ``mean0`` is an input (shape ``[1]``): the Rust
  side evaluates its configurable mean functor (Zero/Constant/Data) and
  passes the scalar, keeping the artifact mean-agnostic for constant-type
  means.

All linear algebra goes through ``linalg`` (pure-HLO ops — see DESIGN.md
§Portability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import linalg
from .kernels import gram as gram_mod
from .kernels import ref

D_MAX = 8
B = 64  # candidate batch size
HP_DIM = D_MAX + 2
TIERS = (32, 64, 128, 256)
VAR_FLOOR = 1e-10


def _split_hp(loghp):
    inv_ls2 = jnp.exp(-2.0 * loghp[:D_MAX])
    sigma2_f = jnp.exp(2.0 * loghp[D_MAX])
    sigma2_n = jnp.exp(2.0 * loghp[D_MAX + 1])
    return inv_ls2, sigma2_f, sigma2_n


def _gram_pallas(kind, x1, x2, inv_ls2, sigma2):
    return gram_mod.gram(kind, x1, x2, inv_ls2, jnp.reshape(sigma2, (1,)))


def _gram_ref(kind, x1, x2, inv_ls2, sigma2):
    return ref.GRAMS[kind](x1, x2, inv_ls2, sigma2)


def _masked_chol_alpha(kind, x, y, mask, loghp, mean0, gram_fn):
    """Shared fit path: masked Gram -> Cholesky -> alpha."""
    inv_ls2, sigma2_f, sigma2_n = _split_hp(loghp)
    kxx = gram_fn(kind, x, x, inv_ls2, sigma2_f)
    mm = mask[:, None] * mask[None, :]
    n = x.shape[0]
    eye = jnp.eye(n, dtype=x.dtype)
    # zero padded rows/cols, put exactly 1 on their diagonal:
    kp = mm * (kxx + sigma2_n * eye) + (1.0 - mask)[:, None] * eye
    l = linalg.cholesky(kp)
    resid = mask * (y - mean0)
    alpha = linalg.spd_solve(l, resid)
    return l, alpha, inv_ls2, sigma2_f, sigma2_n


def gp_predict(kind, x, y, mask, xs, loghp, mean0, *, gram_fn=_gram_pallas):
    """Posterior ``(mu[B], var[B])`` at candidates ``xs`` given masked data."""
    mean0 = jnp.reshape(mean0, ())
    l, alpha, inv_ls2, sigma2_f, _ = _masked_chol_alpha(
        kind, x, y, mask, loghp, mean0, gram_fn)
    ks = gram_fn(kind, x, xs, inv_ls2, sigma2_f) * mask[:, None]  # [n, B]
    mu = mean0 + ks.T @ alpha
    v = linalg.solve_lower(l, ks)  # [n, B]
    var = sigma2_f - jnp.sum(v * v, axis=0)
    return mu, jnp.maximum(var, VAR_FLOOR)


def gp_ucb(kind, x, y, mask, xs, loghp, mean0, alpha_ucb, *, gram_fn=_gram_pallas):
    """Fused predict -> UCB acquisition ``mu + alpha * sqrt(var)``."""
    mu, var = gp_predict(kind, x, y, mask, xs, loghp, mean0, gram_fn=gram_fn)
    return (mu + jnp.reshape(alpha_ucb, ()) * jnp.sqrt(var),)


def gp_lml(kind, x, y, mask, loghp, mean0):
    """Log marginal likelihood of the masked dataset (differentiable)."""
    mean0 = jnp.reshape(mean0, ())
    l, alpha, *_ = _masked_chol_alpha(kind, x, y, mask, loghp, mean0, _gram_ref)
    resid = mask * (y - mean0)
    n_eff = jnp.sum(mask)
    # padded diagonal entries of L are exactly 1 -> log contributes 0
    logdet = jnp.sum(jnp.log(jnp.diagonal(l)))
    return -0.5 * resid @ alpha - logdet - 0.5 * n_eff * jnp.log(2.0 * jnp.pi)


def gp_lml_grad(kind, x, y, mask, loghp, mean0):
    """``(lml[1], dlml/dloghp[HP_DIM])`` for ML-II hyper-parameter fits."""
    val, grad = jax.value_and_grad(
        lambda hp: gp_lml(kind, x, y, mask, hp, mean0))(loghp)
    return jnp.reshape(val, (1,)), grad


# ---------------------------------------------------------------------------
# Program registry used by aot.py
# ---------------------------------------------------------------------------

def arg_specs(program: str, n: int, dtype=jnp.float32):
    """jax.ShapeDtypeStruct argument specs for a program at tier ``n``."""
    f = lambda shape: jax.ShapeDtypeStruct(shape, dtype)
    base = [f((n, D_MAX)), f((n,)), f((n,))]  # x, y, mask
    if program == "predict":
        return base + [f((B, D_MAX)), f((HP_DIM,)), f((1,))]
    if program == "ucb":
        return base + [f((B, D_MAX)), f((HP_DIM,)), f((1,)), f((1,))]
    if program == "lml":
        return base + [f((HP_DIM,)), f((1,))]
    raise ValueError(f"unknown program {program!r}")


def program_fn(program: str, kind: str):
    """The jittable function for a (program, kernel-kind) pair."""
    if program == "predict":
        return lambda x, y, m, xs, hp, m0: gp_predict(kind, x, y, m, xs, hp, m0)
    if program == "ucb":
        return lambda x, y, m, xs, hp, m0, a: gp_ucb(kind, x, y, m, xs, hp, m0, a)
    if program == "lml":
        return lambda x, y, m, hp, m0: gp_lml_grad(kind, x, y, m, hp, m0)
    raise ValueError(f"unknown program {program!r}")
