"""Portable (pure-HLO) linear algebra for the L2 GP graphs.

`jnp.linalg.cholesky` / `lax.linalg.triangular_solve` lower on CPU to
jaxlib FFI custom-calls (``lapack_spotrf_ffi`` etc.) that only exist inside
jaxlib's runtime.  The standalone xla_extension 0.5.1 used by the Rust
``xla`` crate cannot execute those custom-calls, so every artifact we emit
must contain *portable HLO ops only*.  This module implements the linear
algebra the GP needs with ``lax.fori_loop`` + vectorized updates:

* :func:`cholesky`        -- right-looking (outer-product) Cholesky
* :func:`solve_lower`     -- forward substitution  L x = b
* :func:`solve_lower_t`   -- backward substitution L^T x = b
* :func:`spd_solve`       -- A x = b through the two substitutions

Shapes are static; ``b`` may be a vector ``(n,)`` or a matrix ``(n, m)``.
Correctness versus ``jnp.linalg`` is pinned by ``python/tests``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def cholesky(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular Cholesky factor of SPD matrix ``a`` (pure HLO ops).

    Right-looking form: at step ``j`` the trailing submatrix holds the Schur
    complement; we scale column ``j`` and subtract its outer product from the
    strictly-trailing block.  O(n) ``fori_loop`` steps of O(n^2) vector work.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, mat):
        pivot = jnp.sqrt(mat[j, j])
        col = mat[:, j] / pivot
        # zero entries above the diagonal, set the pivot itself
        col = jnp.where(idx > j, col, 0.0)
        col = col.at[j].set(pivot)
        # Schur update of the strictly-trailing block only
        trailing = (idx[:, None] > j) & (idx[None, :] > j)
        mat = mat - jnp.where(trailing, jnp.outer(col, col), 0.0)
        mat = mat.at[:, j].set(col)
        return mat

    out = lax.fori_loop(0, n, body, a)
    return jnp.tril(out)


def solve_lower(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve ``L x = b`` with ``L`` lower triangular (forward substitution)."""
    n = l.shape[0]

    def body(i, x):
        # entries x[j >= i] are still zero, so the dot only sees j < i
        val = (b[i] - l[i, :] @ x) / l[i, i]
        return x.at[i].set(val)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def solve_lower_t(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve ``L^T x = b`` with ``L`` lower triangular (backward substitution)."""
    n = l.shape[0]

    def body(k, x):
        i = n - 1 - k
        val = (b[i] - l[:, i] @ x) / l[i, i]
        return x.at[i].set(val)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def spd_solve(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve ``A x = b`` given the Cholesky factor ``L`` of ``A``."""
    return solve_lower_t(l, solve_lower(l, b))
