"""AOT emission: lower every (program, kernel, tier) graph to HLO text.

Interchange format is HLO *text*, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  Lowered with
``return_tuple=True`` — the Rust side unwraps with ``to_tupleN()``.

Also writes ``artifacts/manifest.txt`` (one line per artifact:
``name program kind n_max d_max b hp_dim path``) which the Rust
``runtime::registry`` parses, plus a handful of golden test vectors
(``artifacts/golden/*.txt``) used by the Rust parity integration test.

Run via ``make artifacts``; python never runs after that.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

KINDS = ("se_ard", "matern52")
PROGRAMS = ("predict", "ucb", "lml")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(program: str, kind: str, n: int) -> str:
    fn = model.program_fn(program, kind)
    specs = model.arg_specs(program, n)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def golden_vectors(outdir: str) -> None:
    """Deterministic test vectors for the Rust parity integration test.

    Layout (all flat, space-separated f32 text): inputs for a tier-32
    se_ard + matern52 predict/ucb/lml call with 7 real points in 2-D,
    plus the expected outputs computed here in python.
    """
    rng = np.random.default_rng(42)
    n, d, n_real, d_real = 32, model.D_MAX, 7, 2
    x = np.zeros((n, d), np.float32)
    x[:n_real, :d_real] = rng.uniform(0.0, 1.0, (n_real, d_real))
    y = np.zeros((n,), np.float32)
    y[:n_real] = rng.normal(0.0, 1.0, n_real)
    mask = np.zeros((n,), np.float32)
    mask[:n_real] = 1.0
    xs = np.zeros((model.B, d), np.float32)
    xs[:, :d_real] = rng.uniform(0.0, 1.0, (model.B, d_real))
    loghp = np.zeros((model.HP_DIM,), np.float32)
    loghp[:d_real] = np.log(0.35)
    loghp[model.D_MAX] = np.log(1.2)       # sigma_f
    loghp[model.D_MAX + 1] = np.log(0.05)  # sigma_n
    mean0 = np.asarray([float(y[:n_real].mean())], np.float32)
    alpha = np.asarray([1.96], np.float32)

    gdir = os.path.join(outdir, "golden")
    os.makedirs(gdir, exist_ok=True)

    def dump(name, arr):
        with open(os.path.join(gdir, name + ".txt"), "w") as f:
            f.write(" ".join(repr(float(v)) for v in np.asarray(arr).ravel()))

    dump("x", x); dump("y", y); dump("mask", mask); dump("xs", xs)
    dump("loghp", loghp); dump("mean0", mean0); dump("alpha_ucb", alpha)
    jx, jy, jm, jxs, jhp, jm0, ja = (
        jnp.asarray(a) for a in (x, y, mask, xs, loghp, mean0, alpha))
    for kind in KINDS:
        mu, var = model.gp_predict(kind, jx, jy, jm, jxs, jhp, jm0)
        (acq,) = model.gp_ucb(kind, jx, jy, jm, jxs, jhp, jm0, ja)
        lml, grad = model.gp_lml_grad(kind, jx, jy, jm, jhp, jm0)
        dump(f"{kind}_mu", mu); dump(f"{kind}_var", var)
        dump(f"{kind}_acq", acq)
        dump(f"{kind}_lml", lml); dump(f"{kind}_grad", grad)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--tiers", default=",".join(str(t) for t in model.TIERS))
    ap.add_argument("--kinds", default=",".join(KINDS))
    ap.add_argument("--programs", default=",".join(PROGRAMS))
    args = ap.parse_args()

    tiers = [int(t) for t in args.tiers.split(",") if t]
    kinds = [k for k in args.kinds.split(",") if k]
    programs = [p for p in args.programs.split(",") if p]
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for program in programs:
        for kind in kinds:
            for n in tiers:
                name = f"{program}_{kind}_n{n}"
                path = f"{name}.hlo.txt"
                text = lower_one(program, kind, n)
                with open(os.path.join(args.out, path), "w") as f:
                    f.write(text)
                manifest.append(
                    f"{name} {program} {kind} {n} {model.D_MAX} {model.B} "
                    f"{model.HP_DIM} {path}")
                print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    golden_vectors(args.out)
    print(f"manifest: {len(manifest)} artifacts; golden vectors written")


if __name__ == "__main__":
    main()
