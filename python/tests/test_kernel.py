"""L1 correctness: the Pallas Gram kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, lengthscales and amplitudes; the Pallas
kernel (interpret=True) must match ``ref.py`` to float32 tolerance for
every kernel kind and tile configuration.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import gram, ref

SIZES = [32, 64, 96, 128]


def _inputs(seed, n1, n2, d, ls_scale, s2):
    rng = np.random.default_rng(seed)
    x1 = jnp.asarray(rng.normal(size=(n1, d)), jnp.float32)
    x2 = jnp.asarray(rng.normal(size=(n2, d)), jnp.float32)
    inv_ls2 = jnp.asarray(rng.uniform(0.1, ls_scale, size=(d,)), jnp.float32)
    sigma2 = jnp.asarray([s2], jnp.float32)
    return x1, x2, inv_ls2, sigma2


@pytest.mark.parametrize("kind", gram.GRAM_KINDS)
@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    n1=st.sampled_from(SIZES),
    n2=st.sampled_from(SIZES),
    d=st.integers(1, 8),
    ls_scale=st.floats(0.2, 5.0),
    s2=st.floats(0.1, 10.0),
)
def test_pallas_matches_ref(kind, seed, n1, n2, d, ls_scale, s2):
    x1, x2, inv_ls2, sigma2 = _inputs(seed, n1, n2, d, ls_scale, s2)
    k = gram.gram(kind, x1, x2, inv_ls2, sigma2)
    kr = ref.GRAMS[kind](x1, x2, inv_ls2, sigma2[0])
    np.testing.assert_allclose(np.asarray(k), np.asarray(kr), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kind", gram.GRAM_KINDS)
def test_diagonal_is_signal_variance(kind):
    x1, _, inv_ls2, sigma2 = _inputs(0, 64, 64, 4, 1.0, 2.5)
    k = gram.gram(kind, x1, x1, inv_ls2, sigma2)
    np.testing.assert_allclose(np.diag(np.asarray(k)), 2.5, rtol=1e-5)


@pytest.mark.parametrize("kind", gram.GRAM_KINDS)
def test_symmetry(kind):
    x1, _, inv_ls2, sigma2 = _inputs(1, 64, 64, 3, 1.0, 1.0)
    k = np.asarray(gram.gram(kind, x1, x1, inv_ls2, sigma2))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kind", gram.GRAM_KINDS)
def test_tile_configs_agree(kind):
    """Different BlockSpec tilings must produce identical results."""
    x1, x2, inv_ls2, sigma2 = _inputs(2, 64, 64, 5, 1.0, 1.3)
    base = np.asarray(gram.gram(kind, x1, x2, inv_ls2, sigma2))
    for tn, tm in [(16, 16), (32, 64), (64, 32), (8, 8)]:
        k = np.asarray(gram.gram(kind, x1, x2, inv_ls2, sigma2, tile_n=tn, tile_m=tm))
        np.testing.assert_allclose(k, base, rtol=1e-6, atol=1e-6)


def test_padded_feature_dims_are_inert():
    """Zero-padded feature columns must not change the Gram matrix."""
    rng = np.random.default_rng(3)
    x_small = jnp.asarray(rng.normal(size=(32, 2)), jnp.float32)
    x_pad = jnp.concatenate([x_small, jnp.zeros((32, 6), jnp.float32)], axis=1)
    ils_small = jnp.asarray([0.7, 1.9], jnp.float32)
    # padded lengthscale entries are arbitrary
    ils_pad = jnp.concatenate([ils_small, jnp.asarray([3.0] * 6, jnp.float32)])
    s2 = jnp.asarray([1.0], jnp.float32)
    for kind in gram.GRAM_KINDS:
        k_small = np.asarray(ref.GRAMS[kind](x_small, x_small, ils_small, 1.0))
        k_pad = np.asarray(gram.gram(kind, x_pad, x_pad, ils_pad, s2))
        np.testing.assert_allclose(k_pad, k_small, rtol=2e-5, atol=2e-5)


def test_rejects_bad_shapes():
    x = jnp.zeros((30, 2), jnp.float32)  # 30 not divisible by the tiles
    ils = jnp.ones((2,), jnp.float32)
    s2 = jnp.ones((1,), jnp.float32)
    with pytest.raises(ValueError):
        gram.gram("se_ard", x, x, ils, s2, tile_n=16, tile_m=16)
    with pytest.raises(ValueError):
        gram.gram("nope", x, x, ils, s2)
