"""L2 graph correctness: masking exactness, GP math vs a dense unpadded
reference, LML gradients vs finite differences, and AOT emission."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import linalg, model
from compile.kernels import ref


def _problem(seed, n_real, d_real, kind="se_ard"):
    rng = np.random.default_rng(seed)
    n, d = 32, model.D_MAX
    x = np.zeros((n, d), np.float32)
    x[:n_real, :d_real] = rng.uniform(0, 1, (n_real, d_real))
    y = np.zeros((n,), np.float32)
    y[:n_real] = rng.normal(size=n_real)
    mask = np.zeros((n,), np.float32)
    mask[:n_real] = 1.0
    xs = np.zeros((model.B, d), np.float32)
    xs[:, :d_real] = rng.uniform(0, 1, (model.B, d_real))
    loghp = np.zeros((model.HP_DIM,), np.float32)
    loghp[:d_real] = np.log(0.4)
    loghp[model.D_MAX] = np.log(1.1)
    loghp[model.D_MAX + 1] = np.log(0.08)
    mean0 = np.asarray([y[:n_real].mean()], np.float32)
    j = jnp.asarray
    return (j(x), j(y), j(mask), j(xs), j(loghp), j(mean0)), (n_real, d_real, kind)


def _dense_reference(x, y, xs, loghp, mean0, n_real, d_real, kind):
    """Unpadded dense GP posterior in float64 (the ground truth)."""
    x = np.asarray(x, np.float64)[:n_real]
    y = np.asarray(y, np.float64)[:n_real]
    xs = np.asarray(xs, np.float64)
    inv_ls2 = np.exp(-2.0 * np.asarray(loghp[:model.D_MAX], np.float64))
    sf2 = float(np.exp(2.0 * loghp[model.D_MAX]))
    sn2 = float(np.exp(2.0 * loghp[model.D_MAX + 1]))
    gram = np.asarray(
        ref.GRAMS[kind](jnp.asarray(x), jnp.asarray(x), jnp.asarray(inv_ls2), sf2),
        np.float64,
    )
    kxx = gram + sn2 * np.eye(n_real)
    ks = np.asarray(
        ref.GRAMS[kind](jnp.asarray(x), jnp.asarray(xs), jnp.asarray(inv_ls2), sf2),
        np.float64,
    )
    m0 = float(mean0[0])
    alpha = np.linalg.solve(kxx, y - m0)
    mu = m0 + ks.T @ alpha
    v = np.linalg.solve(np.linalg.cholesky(kxx), ks)
    var = sf2 - (v * v).sum(axis=0)
    # lml
    sign, logdet = np.linalg.slogdet(kxx)
    lml = -0.5 * (y - m0) @ alpha - 0.5 * logdet - 0.5 * n_real * np.log(2 * np.pi)
    return mu, var, lml


@pytest.mark.parametrize("kind", ["se_ard", "matern52"])
@pytest.mark.parametrize("n_real,d_real", [(1, 1), (7, 2), (20, 6), (32, 8)])
def test_masked_predict_equals_dense(kind, n_real, d_real):
    (x, y, mask, xs, loghp, mean0), _ = _problem(11, n_real, d_real, kind)
    mu, var = model.gp_predict(kind, x, y, mask, xs, loghp, mean0)
    mu_ref, var_ref, _ = _dense_reference(x, y, xs, loghp, mean0, n_real, d_real, kind)
    np.testing.assert_allclose(np.asarray(mu), mu_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(var), var_ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kind", ["se_ard", "matern52"])
def test_masked_lml_equals_dense(kind):
    (x, y, mask, xs, loghp, mean0), (n_real, d_real, _) = _problem(13, 12, 3, kind)
    lml = model.gp_lml(kind, x, y, mask, loghp, mean0)
    _, _, lml_ref = _dense_reference(x, y, xs, loghp, mean0, 12, 3, kind)
    np.testing.assert_allclose(float(lml), lml_ref, rtol=2e-3, atol=2e-3)


def test_mask_position_invariance():
    """Padding rows are inert: growing the pad changes nothing."""
    (x, y, mask, xs, loghp, mean0), _ = _problem(17, 9, 2)
    mu1, var1 = model.gp_predict("se_ard", x, y, mask, xs, loghp, mean0)
    # scribble garbage into padded rows — must not matter
    x2 = x.at[9:].set(123.456)
    y2 = y.at[9:].set(-999.0)
    mu2, var2 = model.gp_predict("se_ard", x2, y2, mask, xs, loghp, mean0)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var1), np.asarray(var2), rtol=1e-5, atol=1e-5)


def test_lml_grad_matches_finite_differences():
    (x, y, mask, _, loghp, mean0), _ = _problem(23, 10, 2)
    _, grad = model.gp_lml_grad("se_ard", x, y, mask, loghp, mean0)
    grad = np.asarray(grad, np.float64)
    eps = 1e-3
    for i in [0, 1, model.D_MAX, model.D_MAX + 1]:
        hp_up = loghp.at[i].add(eps)
        hp_dn = loghp.at[i].add(-eps)
        fd = (float(model.gp_lml("se_ard", x, y, mask, hp_up, mean0))
              - float(model.gp_lml("se_ard", x, y, mask, hp_dn, mean0))) / (2 * eps)
        assert abs(grad[i] - fd) < 5e-2 * (1 + abs(fd)), f"hp[{i}]: {grad[i]} vs {fd}"


def test_fused_ucb_matches_predict():
    (x, y, mask, xs, loghp, mean0), _ = _problem(29, 8, 2)
    alpha = jnp.asarray([1.96], jnp.float32)
    (acq,) = model.gp_ucb("se_ard", x, y, mask, xs, loghp, mean0, alpha)
    mu, var = model.gp_predict("se_ard", x, y, mask, xs, loghp, mean0)
    expected = np.asarray(mu) + 1.96 * np.sqrt(np.asarray(var))
    np.testing.assert_allclose(np.asarray(acq), expected, rtol=1e-5, atol=1e-5)


def test_variance_floor_holds():
    # exact duplicate training/candidate point with tiny noise: var >= floor
    (x, y, mask, xs, loghp, mean0), _ = _problem(31, 5, 2)
    xs = xs.at[0].set(x[0])
    _, var = model.gp_predict("se_ard", x, y, mask, xs, loghp, mean0)
    assert float(var[0]) >= model.VAR_FLOOR


@pytest.mark.parametrize("program", ["predict", "ucb", "lml"])
def test_aot_lowering_emits_portable_hlo(program):
    """The lowered HLO must contain no jaxlib custom-calls (portability)."""
    from compile import aot

    text = aot.lower_one(program, "se_ard", 32)
    assert "ENTRY" in text
    for banned in ["lapack", "custom-call", "custom_call"]:
        assert banned not in text.lower(), f"{program}: HLO contains {banned}"


def test_arg_specs_shapes():
    specs = model.arg_specs("predict", 64)
    assert [tuple(s.shape) for s in specs] == [
        (64, 8), (64,), (64,), (model.B, 8), (model.HP_DIM,), (1,)]
    specs = model.arg_specs("lml", 128)
    assert [tuple(s.shape) for s in specs] == [
        (128, 8), (128,), (128,), (model.HP_DIM,), (1,)]
    with pytest.raises(ValueError):
        model.arg_specs("nope", 32)


def test_portable_cholesky_used_not_lax():
    """Guard: the predict graph goes through our fori_loop Cholesky, whose
    HLO signature is a while-loop, not a cholesky op."""
    from compile import aot

    text = aot.lower_one("predict", "se_ard", 32)
    assert "while" in text, "expected fori_loop Cholesky lowering"
    assert "cholesky" not in text.lower()


def test_jit_roundtrip_runs():
    (x, y, mask, xs, loghp, mean0), _ = _problem(37, 6, 2)
    fn = jax.jit(model.program_fn("predict", "matern52"))
    mu, var = fn(x, y, mask, xs, loghp, mean0)
    assert mu.shape == (model.B,)
    assert var.shape == (model.B,)
    assert bool(jnp.all(var > 0))


def test_linalg_inside_graph_matches_numpy():
    a = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)))
    spd = a @ a.T + 16 * jnp.eye(16)
    l = linalg.cholesky(spd)
    np.testing.assert_allclose(
        np.asarray(l @ l.T), np.asarray(spd), rtol=1e-4, atol=1e-4)
