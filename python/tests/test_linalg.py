"""Portable linalg vs jnp.linalg: the §Portability substrate must agree
with LAPACK-backed reference results on SPD systems.

Note: jax default dtype is float32 (matching the shipped artifacts), so
tolerances are f32-level."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import linalg


def _spd(seed, n):
    rng = np.random.default_rng(seed)
    b = rng.normal(size=(n, n)).astype(np.float64)
    return jnp.asarray(b @ b.T + n * np.eye(n))


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 24))
def test_cholesky_matches_jnp(seed, n):
    a = _spd(seed, n)
    l_ours = linalg.cholesky(a)
    l_ref = jnp.linalg.cholesky(a)
    np.testing.assert_allclose(np.asarray(l_ours), np.asarray(l_ref), rtol=1e-5, atol=1e-6)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 20), m=st.integers(1, 5))
def test_solves_roundtrip(seed, n, m):
    a = _spd(seed, n)
    rng = np.random.default_rng(seed + 1)
    b = jnp.asarray(rng.normal(size=(n, m)))
    l = linalg.cholesky(a)
    x = linalg.spd_solve(l, b)
    np.testing.assert_allclose(np.asarray(a @ x), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_triangular_solves_vector_and_matrix():
    a = _spd(7, 12)
    l = linalg.cholesky(a)
    rng = np.random.default_rng(8)
    bv = jnp.asarray(rng.normal(size=(12,)))
    xv = linalg.solve_lower(l, bv)
    np.testing.assert_allclose(np.asarray(l @ xv), np.asarray(bv), rtol=1e-4, atol=1e-4)
    xt = linalg.solve_lower_t(l, bv)
    np.testing.assert_allclose(np.asarray(l.T @ xt), np.asarray(bv), rtol=1e-4, atol=1e-4)


def test_cholesky_is_lower_triangular():
    a = _spd(9, 10)
    l = np.asarray(linalg.cholesky(a))
    np.testing.assert_allclose(l, np.tril(l))
    assert (np.diag(l) > 0).all()
