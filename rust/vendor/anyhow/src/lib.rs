//! Vendored subset of the `anyhow` API (offline build shim).
//!
//! The hermetic build environment cannot fetch crates.io, so this crate
//! provides exactly the surface `limbo::runtime` uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the [`anyhow!`]/[`bail!`] macros. Dropping the real `anyhow` in via
//! Cargo.toml is a no-op for the rest of the codebase.

use std::error::Error as StdError;
use std::fmt;

/// A string-backed error with an optional source chain.
///
/// Deliberately does **not** implement [`std::error::Error`], mirroring the
/// real `anyhow::Error`, so the blanket `From<E: Error>` below stays
/// coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self { msg: msg.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        let chained = match self.source {
            Some(src) => format!("{context}: {}: {src}", self.msg),
            None => format!("{context}: {}", self.msg),
        };
        Self { msg: chained, source: None }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = &self.source {
            write!(f, ": {src}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    /// Attach a context message to the error.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-evaluated context message to the error.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().contains("reading manifest"));
        assert!(e.to_string().contains("missing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no tier for {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "no tier for 7");
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 42);
            }
            let n: u32 = "17".parse()?; // ParseIntError -> Error via From
            Ok(n)
        }
        assert_eq!(inner(false).unwrap(), 17);
        assert_eq!(inner(true).unwrap_err().to_string(), "boom 42");
    }
}
