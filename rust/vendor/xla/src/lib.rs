//! No-op shim for the `xla` crate (xla-rs PJRT bindings).
//!
//! The hermetic build environment has neither network access nor the PJRT
//! C API, so this crate provides the exact type/method surface
//! `limbo::runtime` compiles against while every entry point that would
//! touch PJRT returns [`Error`] at runtime. All of limbo's XLA code paths
//! already skip cleanly when `artifacts/` is absent or the client fails to
//! initialize, so linking this shim degrades the XLA backend to
//! "unavailable" without a single `cfg` in the main crate. Point the
//! `xla` path dependency at the real xla-rs checkout to re-enable it.

use std::fmt;
use std::path::Path;

/// Error raised by every shim entry point that would need real PJRT.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Shim result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend unavailable (limbo was built against the \
         bundled no-op `xla` shim in rust/vendor/xla; point the Cargo path \
         dependency at the real xla-rs crate to enable artifact execution)"
    ))
}

/// PJRT client handle (always fails to construct in the shim).
pub struct PjRtClient;

impl PjRtClient {
    /// Real crate: create a CPU PJRT client. Shim: always errors.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name reported by PJRT.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Real crate: compile a computation. Shim: always errors.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (never constructed by the shim).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Real crate: parse an HLO text file. Shim: always errors.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable handle (never constructed by the shim).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Real crate: execute with device transfers. Shim: always errors.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (never constructed by the shim).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Real crate: synchronous device-to-host transfer. Shim: always errors.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal. The shim keeps no data: literals only flow *into*
/// `execute`, which always errors before reading them.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Decompose a tuple literal. Shim: always errors (tuples only come
    /// from execution results, which the shim never produces).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a typed vector. Shim: always errors.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_construction_is_infallible() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
