//! GP scaling bench: per-iteration cost of adding a sample + predicting,
//! incremental Cholesky vs full refit, as N grows — plus the dense-vs-
//! sparse sweep that motivates the `model/sgp` subsystem.
//!
//! Expected shape: incremental `add_sample` grows ~O(n^2) while the full
//! refit grows ~O(n^3); the sparse GP's fit grows ~O(n·m^2) and its
//! predict is n-independent, so the dense/sparse gap widens without bound.
//!
//! The sweep section prints one machine-readable JSON row per
//! (model, n, m) config so runs can be diffed across commits:
//! `{"bench":"gp_scaling","model":"sparse","n":4096,"m":128,...}` — the
//! rows are also written to `target/gp_scaling.json`, which CI merges
//! into `BENCH_PR.json` for the bench-trajectory gate
//! (`scripts/bench_compare.py` vs `benches/baseline.json`).
//!
//! After each config's headline timing (taken with metrics **disabled**,
//! so the numbers stay comparable to historic rows), one extra un-timed
//! pass runs with the `limbo::obs` span registry enabled and emits a
//! `"bench":"gp_scaling_phase"` row per active phase — so a regression
//! in `fit_s` can be attributed to Cholesky vs cross-covariance vs the
//! sparse fit itself.
//!
//! Pass `--smoke` (or set `LIMBO_GP_SCALING_QUICK=1`) to cap the sweep at
//! n=1024 — the CI-sized variant.

use std::io::Write as _;
use std::time::Instant;

use limbo::benchlib::{header, Bencher};
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model, SgpConfig, SparseGp};
use limbo::rng::Pcg64;

fn dataset(n: usize, dim: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Pcg64::seed(seed);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (4.0 * x[0]).sin() + x[1]).collect();
    (xs, ys)
}

/// Median wall-clock seconds of `reps` runs of `f` (coarse timer for the
/// expensive large-n configs where the calibrating [`Bencher`] would take
/// minutes).
fn time_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn small_n_section() {
    let b = Bencher::default();
    header("GP scaling (dim=2): add-sample (incremental) vs full refit vs predict");
    for n in [16, 32, 64, 128, 256] {
        let (xs, ys) = dataset(n, 2, 42);

        // incremental add of the n-th point to an (n-1)-point GP
        let mut warm = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        warm.fit(&xs[..n - 1].to_vec(), &ys[..n - 1]);
        let (xn, yn) = (xs[n - 1].clone(), ys[n - 1]);
        b.bench(&format!("add_sample_incremental/n={n}"), || {
            let mut gp = warm.clone();
            gp.add_sample(&xn, yn);
            gp.n_samples()
        });

        // full refit of all n points
        b.bench(&format!("fit_full/n={n}"), || {
            let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
            gp.fit(&xs, &ys);
            gp.n_samples()
        });

        // single-point posterior
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        gp.fit(&xs, &ys);
        let probe = [0.31, 0.77];
        b.bench(&format!("predict/n={n}"), || gp.predict(&probe));
    }
}

fn json_row(
    rows: &mut Vec<String>,
    model: &str,
    n: usize,
    m: usize,
    fit_s: f64,
    predict_s: f64,
    speedup: f64,
) {
    let row = format!(
        "{{\"bench\":\"gp_scaling\",\"model\":\"{model}\",\"n\":{n},\"m\":{m},\
         \"fit_s\":{fit_s:.6},\"predict_s\":{predict_s:.9},\
         \"fit_plus_predict_s\":{:.6},\"speedup_vs_dense\":{speedup:.2}}}",
        fit_s + predict_s
    );
    println!("{row}");
    rows.push(row);
}

/// One extra un-timed pass with the span registry on: attributes the
/// headline seconds (measured above with metrics off) to phases. The
/// probe posterior is profiled through `predict_batch` — spans are
/// batch-granularity by design, per-point `predict` stays span-free.
fn phase_rows(rows: &mut Vec<String>, model: &str, n: usize, m: usize, run: impl FnOnce()) {
    limbo::obs::set_enabled(true);
    let base = limbo::obs::snapshot();
    run();
    let delta = limbo::obs::snapshot().delta_since(&base);
    limbo::obs::set_enabled(false);
    for p in limbo::obs::Phase::ALL {
        let calls = delta.calls(p);
        if calls == 0 {
            continue;
        }
        let row = format!(
            "{{\"bench\":\"gp_scaling_phase\",\"model\":\"{model}\",\"n\":{n},\"m\":{m},\
             \"phase\":\"{}\",\"seconds\":{:.6},\"calls\":{calls}}}",
            p.name(),
            delta.seconds(p)
        );
        println!("{row}");
        rows.push(row);
    }
}

fn sweep_section(quick: bool) -> Vec<String> {
    header("dense vs sparse sweep (dim=2; JSON row per config)");
    let mut rows: Vec<String> = Vec::new();
    let ns: &[usize] = if quick { &[256, 1024] } else { &[256, 1024, 4096] };
    let probes: Vec<Vec<f64>> = {
        let mut rng = Pcg64::seed(7);
        (0..64).map(|_| rng.unit_point(2)).collect()
    };
    for &n in ns {
        let (xs, ys) = dataset(n, 2, 42);
        let reps = match n {
            0..=256 => 5,
            257..=1024 => 3,
            _ => 1,
        };

        // dense reference
        let mut dense = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        let dense_fit = time_median(reps, || {
            let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
            gp.fit(&xs, &ys);
            dense = gp;
        });
        let dense_pred = time_median(reps, || {
            for p in &probes {
                std::hint::black_box(dense.predict(p));
            }
        }) / probes.len() as f64;
        let dense_total = dense_fit + dense_pred;
        json_row(&mut rows, "dense", n, 0, dense_fit, dense_pred, 1.0);
        phase_rows(&mut rows, "dense", n, 0, || {
            let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
            gp.fit(&xs, &ys);
            std::hint::black_box(gp.predict_batch(&probes));
        });

        for &m in &[32usize, 64, 128] {
            let cfg = SgpConfig { max_inducing: m, ..SgpConfig::default() };
            let mut sparse =
                SparseGp::with_config(Matern52::new(2), DataMean::default(), 1e-2, cfg.clone());
            let sparse_fit = time_median(reps, || {
                let mut sgp =
                    SparseGp::with_config(Matern52::new(2), DataMean::default(), 1e-2, cfg.clone());
                sgp.fit(&xs, &ys);
                sparse = sgp;
            });
            let sparse_pred = time_median(reps, || {
                for p in &probes {
                    std::hint::black_box(sparse.predict(p));
                }
            }) / probes.len() as f64;
            let speedup = dense_total / (sparse_fit + sparse_pred);
            json_row(&mut rows, "sparse", n, m, sparse_fit, sparse_pred, speedup);
            phase_rows(&mut rows, "sparse", n, m, || {
                let mut sgp =
                    SparseGp::with_config(Matern52::new(2), DataMean::default(), 1e-2, cfg.clone());
                sgp.fit(&xs, &ys);
                std::hint::black_box(sgp.predict_batch(&probes));
            });
        }
    }
    rows
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let quick = smoke || matches!(std::env::var("LIMBO_GP_SCALING_QUICK").as_deref(), Ok("1"));
    if !smoke {
        small_n_section();
    }
    let rows = sweep_section(quick);

    let path = std::path::Path::new("target").join("gp_scaling.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
