//! GP scaling bench: per-iteration cost of adding a sample + predicting,
//! incremental Cholesky vs full refit, as N grows.
//!
//! Expected shape: incremental `add_sample` grows ~O(n^2) while the full
//! refit grows ~O(n^3) — the reason Limbo stays usable on embedded
//! hardware as the dataset grows.

use limbo::benchlib::{header, Bencher};
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::rng::Pcg64;

fn dataset(n: usize, dim: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Pcg64::seed(seed);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (4.0 * x[0]).sin() + x[1]).collect();
    (xs, ys)
}

fn main() {
    let b = Bencher::default();
    header("GP scaling (dim=2): add-sample (incremental) vs full refit vs predict");
    for n in [16, 32, 64, 128, 256] {
        let (xs, ys) = dataset(n, 2, 42);

        // incremental add of the n-th point to an (n-1)-point GP
        let mut warm = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        warm.fit(&xs[..n - 1].to_vec(), &ys[..n - 1]);
        let (xn, yn) = (xs[n - 1].clone(), ys[n - 1]);
        b.bench(&format!("add_sample_incremental/n={n}"), || {
            let mut gp = warm.clone();
            gp.add_sample(&xn, yn);
            gp.n_samples()
        });

        // full refit of all n points
        b.bench(&format!("fit_full/n={n}"), || {
            let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
            gp.fit(&xs, &ys);
            gp.n_samples()
        });

        // single-point posterior
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        gp.fit(&xs, &ys);
        let probe = [0.31, 0.77];
        b.bench(&format!("predict/n={n}"), || gp.predict(&probe));
    }
}
