//! Optimizer-substrate bench: raw global optimizers (no GP) on the suite —
//! time per full optimization and solution quality at a fixed 500-eval
//! budget. Validates that the from-scratch CMA-ES/DIRECT substrates are
//! usable standalone and quantifies their overhead per evaluation.

use limbo::benchlib::{header, Bencher};
use limbo::benchfns::{Ackley, Branin, Hartmann6, Rastrigin, TestFunction};
use limbo::opt::{Cmaes, Direct, NelderMead, Optimizer, OptimizerExt, RandomPoint};
use limbo::rng::Pcg64;

fn main() {
    let b = Bencher::quick();
    let functions: Vec<Box<dyn TestFunction>> = vec![
        Box::new(Branin),
        Box::new(Ackley::new(2)),
        Box::new(Rastrigin::new(2)),
        Box::new(Hartmann6),
    ];
    for f in &functions {
        header(&format!("raw optimizers on {} ({}-D), 500-eval budget", f.name(), f.dim()));
        let objective = |x: &[f64]| f.eval(x);
        let optimizers: Vec<(&str, Box<dyn Optimizer>)> = vec![
            ("random", Box::new(RandomPoint::new(500))),
            ("direct", Box::new(Direct::new(500))),
            ("cmaes", Box::new(Cmaes::new(500))),
            ("nm_restarts", Box::new(NelderMead::default().restarts(4, 4))),
        ];
        for (name, opt) in &optimizers {
            let mut rng = Pcg64::seed(12);
            b.bench(&format!("{name}/{}", f.name()), || {
                opt.optimize(&objective, f.dim(), &mut rng)
            });
            let mut accs = Vec::new();
            for s in 0..10 {
                let mut rng = Pcg64::seed(200 + s);
                accs.push(f.accuracy(opt.optimize(&objective, f.dim(), &mut rng).value));
            }
            accs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            println!("    -> accuracy: median {:.3e}, worst {:.3e}", accs[5], accs[9]);
        }
    }
}
