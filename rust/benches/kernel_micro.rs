//! Kernel micro-bench: the Gram-matrix hot spot.
//!
//! Compares the native Rust kernel evaluation loop (what `Gp::refit` does)
//! against one full XLA `predict` artifact call (which contains the
//! Pallas-tiled Gram + Cholesky + solves), plus per-pair kernel eval costs
//! for each kernel type — the L1-level numbers behind DESIGN.md §Perf.

use std::sync::Arc;

use limbo::benchlib::{header, Bencher};
use limbo::kernel::{Exponential, Kernel, Matern32, Matern52, SquaredExpArd};
use limbo::la::Matrix;
use limbo::rng::Pcg64;
use limbo::runtime::{find_artifact_dir, RtClient, XlaGp};

fn gram_native<K: Kernel>(kernel: &K, xs: &[Vec<f64>]) -> Matrix {
    let n = xs.len();
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = kernel.eval(&xs[i], &xs[j]);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
    }
    k
}

fn main() {
    let b = Bencher::quick();
    let mut rng = Pcg64::seed(4);

    header("per-pair kernel evaluation (dim=6)");
    let a = rng.unit_point(6);
    let c = rng.unit_point(6);
    let se = SquaredExpArd::new(6);
    let m52 = Matern52::new(6);
    let m32 = Matern32::new(6);
    let ex = Exponential::new(6);
    b.bench("se_ard/pair", || se.eval(&a, &c));
    b.bench("matern52/pair", || m52.eval(&a, &c));
    b.bench("matern32/pair", || m32.eval(&a, &c));
    b.bench("exponential/pair", || ex.eval(&a, &c));

    for n in [64usize, 128, 256] {
        header(&format!("Gram matrix n={n} (dim=2)"));
        let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
        let k2 = Matern52::new(2);
        b.bench(&format!("native_gram/n={n}"), || gram_native(&k2, &xs));

        if let Some(dir) = find_artifact_dir() {
            let client = Arc::new(RtClient::cpu().expect("client"));
            let backend = Arc::new(XlaGp::new(client, &dir, "matern52").expect("backend"));
            let flat: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
            let ys: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
            let cands: Vec<f64> = (0..64 * 2).map(|_| rng.next_f64()).collect();
            let loghp = vec![0.0, 0.0, 0.0, (1e-2f64).ln()];
            // one artifact call = Pallas gram + masked cholesky + solves
            b.bench(&format!("xla_predict_full/n={n}"), || {
                backend.predict(&flat, &ys, 2, &cands, &loghp, 0.0).expect("predict")
            });
        }
    }
}
