//! Kernel micro-bench: the Gram-matrix hot spot.
//!
//! Compares the per-pair native evaluation loop against the blocked
//! `cross_cov` path (what `Gp::refit` now uses), plus one full XLA
//! `predict` artifact call (which contains the Pallas-tiled Gram +
//! Cholesky + solves) and per-pair kernel eval costs for each kernel
//! type — the L1-level numbers behind DESIGN.md §Perf.
//!
//! The Gram section emits one JSON row per size
//! (`{"bench":"kernel_micro","kernel":"matern52","n":...,
//! "gram_pairwise_s":...,"gram_blocked_s":...}`), also written to
//! `target/kernel_micro.json` for the CI bench-trajectory gate. Pass
//! `--smoke` to skip the per-pair and XLA sections.

use std::io::Write as _;
use std::sync::Arc;

use limbo::benchlib::{header, Bencher};
use limbo::kernel::{Exponential, Kernel, Matern32, Matern52, SquaredExpArd};
use limbo::la::Matrix;
use limbo::rng::Pcg64;
use limbo::runtime::{find_artifact_dir, RtClient, XlaGp};

fn gram_native<K: Kernel>(kernel: &K, xs: &[Vec<f64>]) -> Matrix {
    let n = xs.len();
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = kernel.eval(&xs[i], &xs[j]);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
    }
    k
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let b = Bencher::quick();
    let mut rng = Pcg64::seed(4);

    if !smoke {
        header("per-pair kernel evaluation (dim=6)");
        let a = rng.unit_point(6);
        let c = rng.unit_point(6);
        let se = SquaredExpArd::new(6);
        let m52 = Matern52::new(6);
        let m32 = Matern32::new(6);
        let ex = Exponential::new(6);
        b.bench("se_ard/pair", || se.eval(&a, &c));
        b.bench("matern52/pair", || m52.eval(&a, &c));
        b.bench("matern32/pair", || m32.eval(&a, &c));
        b.bench("exponential/pair", || ex.eval(&a, &c));
    }

    let mut rows: Vec<String> = Vec::new();
    let ns: &[usize] = if smoke { &[64, 128] } else { &[64, 128, 256] };
    for &n in ns {
        header(&format!("Gram matrix n={n} (dim=2)"));
        let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
        let k2 = Matern52::new(2);
        let pairwise = b.bench(&format!("pairwise_gram/n={n}"), || gram_native(&k2, &xs));
        let blocked = b.bench(&format!("blocked_gram/n={n}"), || k2.cross_cov(&xs, &xs));
        let row = format!(
            "{{\"bench\":\"kernel_micro\",\"kernel\":\"matern52\",\"n\":{n},\
             \"gram_pairwise_s\":{:.9},\"gram_blocked_s\":{:.9}}}",
            pairwise.per_iter.median, blocked.per_iter.median
        );
        println!("{row}");
        rows.push(row);

        let artifact_dir = if smoke { None } else { find_artifact_dir() };
        if let Some(dir) = artifact_dir {
            let client = Arc::new(RtClient::cpu().expect("client"));
            let backend = Arc::new(XlaGp::new(client, &dir, "matern52").expect("backend"));
            let flat: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
            let ys: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
            let cands: Vec<f64> = (0..64 * 2).map(|_| rng.next_f64()).collect();
            let loghp = vec![0.0, 0.0, 0.0, (1e-2f64).ln()];
            // one artifact call = Pallas gram + masked cholesky + solves
            b.bench(&format!("xla_predict_full/n={n}"), || {
                backend.predict(&flat, &ys, 2, &cands, &loghp, 0.0).expect("predict")
            });
        }
    }

    let path = std::path::Path::new("target").join("kernel_micro.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
