//! Backend ablation: native Rust GP vs the AOT-compiled XLA artifact
//! backend, at every capacity tier, for single-point predict, batched
//! predict (64 candidates), fused UCB, and the LML+gradient used by
//! hyper-parameter fits.
//!
//! Expected shape on CPU: the native f64 GP wins at small N (padding +
//! FFI overhead dominate); the XLA graph amortizes better on the batched
//! paths as N approaches the tier capacity. Skips cleanly when
//! `artifacts/` is absent.

use std::sync::Arc;

use limbo::benchlib::{header, Bencher};
use limbo::coordinator::xla_model::XlaGpModel;
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::rng::Pcg64;
use limbo::runtime::{find_artifact_dir, RtClient, XlaGp};

fn main() {
    let Some(dir) = find_artifact_dir() else {
        eprintln!("skipping backend_compare: artifacts/ not built");
        return;
    };
    let client = Arc::new(RtClient::cpu().expect("PJRT client"));
    let backend = Arc::new(XlaGp::new(client, &dir, "matern52").expect("backend"));
    let b = Bencher::quick();

    for n in [24usize, 56, 120, 250] {
        header(&format!("backend compare at n={n} (dim=2)"));
        let mut rng = Pcg64::seed(9);
        let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (4.0 * x[0]).sin() + x[1]).collect();

        let mut native = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        native.fit(&xs, &ys);
        let mut xla = XlaGpModel::new(backend.clone(), 2);
        xla.loghp = native.xla_loghp();
        xla.fit(&xs, &ys);

        let probe = [0.41, 0.13];
        b.bench(&format!("native/predict1/n={n}"), || native.predict(&probe));
        b.bench(&format!("xla/predict1/n={n}"), || xla.predict(&probe));

        let cands: Vec<Vec<f64>> = (0..64).map(|_| rng.unit_point(2)).collect();
        b.bench(&format!("native/predict64/n={n}"), || native.predict_batch(&cands));
        b.bench(&format!("xla/predict64/n={n}"), || xla.predict_batch(&cands));
        b.bench(&format!("xla/ucb64_fused/n={n}"), || xla.ucb_batch(&cands, 1.96));

        // acquisition maximization on the XLA backend: the batched fused-UCB
        // search (8 rounds x 64 candidates = 512 evals in 8 executions) vs
        // 64 per-point predicts (64 executions)
        let batched = limbo::coordinator::batched_opt::BatchedUcbSearch::default();
        let mut brng = limbo::rng::Pcg64::seed(3);
        b.bench(&format!("xla/acq_batched_512evals/n={n}"), || {
            batched.optimize(&xla, 2, &mut brng)
        });
        b.bench(&format!("xla/acq_perpoint_64evals/n={n}"), || {
            let mut acc = 0.0;
            for c in cands.iter() {
                acc += xla.predict(c).0;
            }
            acc
        });

        b.bench(&format!("native/lml+grad/n={n}"), || {
            (native.log_marginal_likelihood(), native.lml_grad())
        });
        let loghp = xla.loghp.clone();
        b.bench(&format!("xla/lml+grad/n={n}"), || {
            let flat: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
            backend.lml_grad(&flat, &ys, 2, &loghp, 0.0).expect("lml")
        });
    }
}
