//! Figure-1 accuracy-vs-wall-clock sweep: the static `BoDef` engine vs
//! the dynamic `baseline::BayesOptLike` across dimensions (branin/2,
//! hartmann6/6, ackley/10), iteration budgets, and the with/without-HPO
//! panels. The full 250-replicate accuracy study is
//! `examples/fig1_repro.rs`; this bench is the CI-diffable timing grid.
//!
//! Every cell prints one machine-readable JSON row
//! (`{"bench":"fig1_time","func":...,"dim":...,"iters":...,"hpo":...,
//! "limbo_s":...,"bayesopt_s":...,"ratio":...,"de_s":...,"de_acc":...}`
//! — the `de_*` columns are the non-BO comparator: self-adaptive DE on
//! the raw function at the same total evaluation budget) plus per-phase
//! attribution rows (`"bench":"fig1_time_phase"`) from one extra
//! metrics-enabled limbo run, so a ratio regression can be pinned to
//! Cholesky vs cross-covariance vs the inner optimizer. Two
//! `"bench":"fig1_scenario"` rows (noisy Branin, constrained Branin)
//! time the generalized `tell_observation` path — per-trial noise and
//! the PoF-weighted constraint bank — with (feasible-)regret columns.
//! `"bench":"fig1_inner_opt"` rows sweep the acquisition maximizer
//! (DIRECT vs CMA-ES vs DE) at an equal inner-opt evaluation budget
//! across dimensions, reporting wall seconds and final regret — the
//! grid behind the claim that DE holds up where DIRECT stalls (d=10).
//! Rows are also written to `target/fig1_time.json`, which CI merges into
//! `BENCH_PR.json` for the bench-trajectory gate
//! (`scripts/bench_compare.py` vs `benches/baseline.json`).
//!
//! Pass `--smoke` for the CI-sized variant (2 cells, 1 seed).

use std::io::Write as _;
use std::time::Instant;

use limbo::benchfns::by_name;
use limbo::coordinator::experiment::BenchConfig;
use limbo::coordinator::fig1::{
    BaselineConfig, DeBaselineConfig, Fig1Settings, InnerOptConfig, InnerOptKind, LimboConfig,
};

/// One sweep cell: a test function at a given iteration budget, with or
/// without periodic ML-II refits.
struct Cell {
    func: &'static str,
    dim: usize,
    iters: usize,
    hpo: bool,
}

/// Median wall seconds and mean accuracy over `seeds` full runs.
fn time_runs(cfg: &dyn BenchConfig, func: &str, dim: usize, seeds: &[u64]) -> (f64, f64) {
    let f = by_name(func, dim).expect("known test function");
    let mut secs = Vec::new();
    let mut acc = 0.0;
    for &seed in seeds {
        let t0 = Instant::now();
        let out = cfg.run(f.as_ref(), seed);
        secs.push(t0.elapsed().as_secs_f64());
        acc += f.accuracy(out.best_value);
    }
    secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (secs[secs.len() / 2], acc / seeds.len() as f64)
}

/// One extra un-timed limbo run with the span registry on: attributes the
/// headline seconds (measured above with metrics off) to phases.
fn phase_rows(rows: &mut Vec<String>, cell: &Cell, cfg: &LimboConfig, seed: u64) {
    let f = by_name(cell.func, cell.dim).expect("known test function");
    limbo::obs::set_enabled(true);
    let base = limbo::obs::snapshot();
    cfg.run(f.as_ref(), seed);
    let delta = limbo::obs::snapshot().delta_since(&base);
    limbo::obs::set_enabled(false);
    for p in limbo::obs::Phase::ALL {
        let calls = delta.calls(p);
        if calls == 0 {
            continue;
        }
        let row = format!(
            "{{\"bench\":\"fig1_time_phase\",\"func\":\"{}\",\"dim\":{},\"iters\":{},\
             \"hpo\":{},\"phase\":\"{}\",\"seconds\":{:.6},\"calls\":{calls}}}",
            cell.func,
            cell.dim,
            cell.iters,
            cell.hpo,
            p.name(),
            delta.seconds(p)
        );
        println!("{row}");
        rows.push(row);
    }
}

/// Generalized-observation scenario cells: noisy Branin (per-trial
/// noise variances through `tell_observation`) and constrained Branin
/// (Gardner-style disk constraint behind the PoF-weighted model bank).
/// One `"bench":"fig1_scenario"` row per scenario — median wall seconds
/// plus the true-value (feasible) regret of the incumbent — so the
/// generalized tell path rides the same trajectory gate as the plain
/// cells.
fn scenario_rows(rows: &mut Vec<String>, rounds: usize, seeds: &[u64]) {
    use limbo::acqui::Ei;
    use limbo::bayes_opt::{BoDef, Observation, RefitSchedule};
    use limbo::opt::{NelderMead, OptimizerExt, RandomPoint};

    let branin = by_name("branin", 2).expect("known test function");
    let def = |seed: u64| {
        BoDef::new(2)
            .acquisition(Ei::default())
            .init_samples(10)
            .inner_opt(RandomPoint::new(128).then(NelderMead::default()).restarts(4, 2))
            .refit(RefitSchedule::Doubling { first: 8 })
            .seed(seed)
    };

    // noisy Branin: observed values carry a deterministic pseudo-noise
    // perturbation and every tell declares a 1e-2 noise variance, so the
    // heteroskedastic train-Gram path is on the timed loop. Regret is
    // measured against the *true* (unperturbed) values.
    let mut secs = Vec::new();
    let mut regret = 0.0;
    for &seed in seeds {
        let t0 = Instant::now();
        let mut srv = def(seed).build_server();
        let mut best_true = f64::NEG_INFINITY;
        for _ in 0..rounds {
            let x = srv.ask();
            let y_true = branin.eval(&x);
            let jitter = 0.1 * (x[0] * 7919.0 + x[1] * 104_729.0).sin();
            best_true = best_true.max(y_true);
            srv.tell_observation(&Observation::noisy(x, y_true + jitter, 1e-2))
                .expect("noisy tell");
        }
        secs.push(t0.elapsed().as_secs_f64());
        regret += branin.accuracy(best_true);
    }
    secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let row = format!(
        "{{\"bench\":\"fig1_scenario\",\"scenario\":\"noisy_branin\",\"rounds\":{rounds},\
         \"seconds\":{:.4},\"regret\":{:.5},\"seeds\":{}}}",
        secs[secs.len() / 2],
        regret / seeds.len() as f64,
        seeds.len()
    );
    println!("{row}");
    rows.push(row);

    // constrained Branin: the disk constraint (native coordinates) keeps
    // exactly one of the three Branin minima feasible, so the feasible
    // optimum coincides with the global optimum and feasible regret is
    // the plain accuracy statistic restricted to feasible samples.
    let mut secs = Vec::new();
    let mut regret = 0.0;
    for &seed in seeds {
        let t0 = Instant::now();
        let mut srv = def(seed).constraints(1).build_constrained_server();
        let mut best_feasible = f64::NEG_INFINITY;
        for _ in 0..rounds {
            let x = srv.ask();
            let y = branin.eval(&x);
            let (nx, ny) = (-5.0 + 15.0 * x[0], 15.0 * x[1]);
            let c = 50.0 - ((nx - 2.5).powi(2) + (ny - 7.5).powi(2));
            if c >= 0.0 {
                best_feasible = best_feasible.max(y);
            }
            srv.tell_observation(&Observation::exact(x, y).with_constraints(vec![c]))
                .expect("constrained tell");
        }
        secs.push(t0.elapsed().as_secs_f64());
        // no feasible sample in the budget (vanishingly rare): a fixed
        // large regret instead of a NaN/inf row that breaks the JSON
        if best_feasible.is_finite() {
            regret += branin.accuracy(best_feasible);
        } else {
            regret += 100.0;
        }
    }
    secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let row = format!(
        "{{\"bench\":\"fig1_scenario\",\"scenario\":\"constrained_branin\",\"rounds\":{rounds},\
         \"seconds\":{:.4},\"feasible_regret\":{:.5},\"seeds\":{}}}",
        secs[secs.len() / 2],
        regret / seeds.len() as f64,
        seeds.len()
    );
    println!("{row}");
    rows.push(row);
}

/// The acquisition-maximizer sweep: DIRECT vs CMA-ES vs DE as the
/// `BoDef` inner optimizer at an **equal** inner-opt evaluation budget,
/// across dimensions (branin/2, hartmann6/6, ackley/10). One
/// `"bench":"fig1_inner_opt"` row per (maximizer, function) cell —
/// median wall seconds plus mean final regret — so the gate tracks both
/// the cost and the quality of each maximizer. The d=10 row is the
/// acceptance check that DE matches or beats DIRECT where rectangle
/// subdivision stalls; smoke mode runs only that cell.
fn inner_opt_rows(rows: &mut Vec<String>, smoke: bool, seeds: &[u64]) {
    let funcs: &[(&str, usize)] =
        if smoke { &[("ackley", 10)] } else { &[("branin", 2), ("hartmann6", 6), ("ackley", 10)] };
    let iters = if smoke { 10 } else { 20 };
    let inner_evals = if smoke { 200 } else { 300 };
    let settings = Fig1Settings { iterations: iters, inner_evals, ..Default::default() };
    for &(func, dim) in funcs {
        for inner in [InnerOptKind::Direct, InnerOptKind::Cmaes, InnerOptKind::De] {
            let cfg = InnerOptConfig::new(settings, inner);
            let (secs, regret) = time_runs(&cfg, func, dim, seeds);
            let row = format!(
                "{{\"bench\":\"fig1_inner_opt\",\"inner\":\"{}\",\"func\":\"{func}\",\
                 \"dim\":{dim},\"iters\":{iters},\"inner_evals\":{inner_evals},\
                 \"seconds\":{secs:.4},\"regret\":{regret:.5},\"seeds\":{}}}",
                inner.name(),
                seeds.len()
            );
            println!("{row}");
            rows.push(row);
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");

    let cells: Vec<Cell> = if smoke {
        vec![
            Cell { func: "branin", dim: 2, iters: 8, hpo: false },
            Cell { func: "hartmann6", dim: 6, iters: 8, hpo: true },
        ]
    } else {
        let mut v = Vec::new();
        for &(func, dim) in &[("branin", 2usize), ("hartmann6", 6), ("ackley", 10)] {
            for &iters in &[15usize, 30] {
                for &hpo in &[false, true] {
                    v.push(Cell { func, dim, iters, hpo });
                }
            }
        }
        v
    };
    let seeds: &[u64] = if smoke { &[3] } else { &[3, 17, 42] };
    let inner_evals = if smoke { 200 } else { 300 };

    println!(
        "fig1 sweep: {} cells x {} seeds (paper speed-ups: 1.47-1.76x no-HPO, 2.05-2.54x HPO)",
        cells.len(),
        seeds.len()
    );
    let mut rows: Vec<String> = Vec::new();
    let mut ratios = Vec::new();
    let mut ratios_hpo = Vec::new();
    for cell in &cells {
        let mut settings =
            Fig1Settings { iterations: cell.iters, inner_evals, ..Default::default() };
        if cell.hpo {
            settings = settings.with_hpo();
        }
        let limbo = LimboConfig::new(settings);
        let bayesopt = BaselineConfig::new(settings);
        let de = DeBaselineConfig::new(settings);
        let (limbo_s, limbo_acc) = time_runs(&limbo, cell.func, cell.dim, seeds);
        let (bayes_s, bayes_acc) = time_runs(&bayesopt, cell.func, cell.dim, seeds);
        let (de_s, de_acc) = time_runs(&de, cell.func, cell.dim, seeds);
        let ratio = bayes_s / limbo_s;
        if cell.hpo {
            ratios_hpo.push(ratio);
        } else {
            ratios.push(ratio);
        }
        let row = format!(
            "{{\"bench\":\"fig1_time\",\"func\":\"{}\",\"dim\":{},\"iters\":{},\"hpo\":{},\
             \"limbo_s\":{limbo_s:.4},\"bayesopt_s\":{bayes_s:.4},\"ratio\":{ratio:.3},\
             \"limbo_acc\":{limbo_acc:.5},\"bayesopt_acc\":{bayes_acc:.5},\
             \"de_s\":{de_s:.4},\"de_acc\":{de_acc:.5},\"seeds\":{}}}",
            cell.func,
            cell.dim,
            cell.iters,
            cell.hpo,
            seeds.len()
        );
        println!("{row}");
        rows.push(row);
        phase_rows(&mut rows, cell, &limbo, seeds[0]);
    }

    inner_opt_rows(&mut rows, smoke, seeds);

    scenario_rows(&mut rows, if smoke { 15 } else { 40 }, seeds);

    let range = |v: &[f64]| {
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    };
    if !ratios.is_empty() {
        let (lo, hi) = range(&ratios);
        println!("\nspeed-up range no-HPO: {lo:.2}-{hi:.2}x (paper: 1.47-1.76x)");
    }
    if !ratios_hpo.is_empty() {
        let (lo, hi) = range(&ratios_hpo);
        println!("speed-up range HPO:    {lo:.2}-{hi:.2}x (paper: 2.05-2.54x)");
    }

    let path = std::path::Path::new("target").join("fig1_time.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
