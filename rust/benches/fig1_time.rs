//! Figure-1 accuracy-vs-wall-clock sweep: the static `BoDef` engine vs
//! the dynamic `baseline::BayesOptLike` across dimensions (branin/2,
//! hartmann6/6, ackley/10), iteration budgets, and the with/without-HPO
//! panels. The full 250-replicate accuracy study is
//! `examples/fig1_repro.rs`; this bench is the CI-diffable timing grid.
//!
//! Every cell prints one machine-readable JSON row
//! (`{"bench":"fig1_time","func":...,"dim":...,"iters":...,"hpo":...,
//! "limbo_s":...,"bayesopt_s":...,"ratio":...}`) plus per-phase
//! attribution rows (`"bench":"fig1_time_phase"`) from one extra
//! metrics-enabled limbo run, so a ratio regression can be pinned to
//! Cholesky vs cross-covariance vs the inner optimizer. Rows are also
//! written to `target/fig1_time.json`, which CI merges into
//! `BENCH_PR.json` for the bench-trajectory gate
//! (`scripts/bench_compare.py` vs `benches/baseline.json`).
//!
//! Pass `--smoke` for the CI-sized variant (2 cells, 1 seed).

use std::io::Write as _;
use std::time::Instant;

use limbo::benchfns::by_name;
use limbo::coordinator::experiment::BenchConfig;
use limbo::coordinator::fig1::{BaselineConfig, Fig1Settings, LimboConfig};

/// One sweep cell: a test function at a given iteration budget, with or
/// without periodic ML-II refits.
struct Cell {
    func: &'static str,
    dim: usize,
    iters: usize,
    hpo: bool,
}

/// Median wall seconds and mean accuracy over `seeds` full runs.
fn time_runs(cfg: &dyn BenchConfig, func: &str, dim: usize, seeds: &[u64]) -> (f64, f64) {
    let f = by_name(func, dim).expect("known test function");
    let mut secs = Vec::new();
    let mut acc = 0.0;
    for &seed in seeds {
        let t0 = Instant::now();
        let out = cfg.run(f.as_ref(), seed);
        secs.push(t0.elapsed().as_secs_f64());
        acc += f.accuracy(out.best_value);
    }
    secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (secs[secs.len() / 2], acc / seeds.len() as f64)
}

/// One extra un-timed limbo run with the span registry on: attributes the
/// headline seconds (measured above with metrics off) to phases.
fn phase_rows(rows: &mut Vec<String>, cell: &Cell, cfg: &LimboConfig, seed: u64) {
    let f = by_name(cell.func, cell.dim).expect("known test function");
    limbo::obs::set_enabled(true);
    let base = limbo::obs::snapshot();
    cfg.run(f.as_ref(), seed);
    let delta = limbo::obs::snapshot().delta_since(&base);
    limbo::obs::set_enabled(false);
    for p in limbo::obs::Phase::ALL {
        let calls = delta.calls(p);
        if calls == 0 {
            continue;
        }
        let row = format!(
            "{{\"bench\":\"fig1_time_phase\",\"func\":\"{}\",\"dim\":{},\"iters\":{},\
             \"hpo\":{},\"phase\":\"{}\",\"seconds\":{:.6},\"calls\":{calls}}}",
            cell.func,
            cell.dim,
            cell.iters,
            cell.hpo,
            p.name(),
            delta.seconds(p)
        );
        println!("{row}");
        rows.push(row);
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");

    let cells: Vec<Cell> = if smoke {
        vec![
            Cell { func: "branin", dim: 2, iters: 8, hpo: false },
            Cell { func: "hartmann6", dim: 6, iters: 8, hpo: true },
        ]
    } else {
        let mut v = Vec::new();
        for &(func, dim) in &[("branin", 2usize), ("hartmann6", 6), ("ackley", 10)] {
            for &iters in &[15usize, 30] {
                for &hpo in &[false, true] {
                    v.push(Cell { func, dim, iters, hpo });
                }
            }
        }
        v
    };
    let seeds: &[u64] = if smoke { &[3] } else { &[3, 17, 42] };
    let inner_evals = if smoke { 200 } else { 300 };

    println!(
        "fig1 sweep: {} cells x {} seeds (paper speed-ups: 1.47-1.76x no-HPO, 2.05-2.54x HPO)",
        cells.len(),
        seeds.len()
    );
    let mut rows: Vec<String> = Vec::new();
    let mut ratios = Vec::new();
    let mut ratios_hpo = Vec::new();
    for cell in &cells {
        let mut settings =
            Fig1Settings { iterations: cell.iters, inner_evals, ..Default::default() };
        if cell.hpo {
            settings = settings.with_hpo();
        }
        let limbo = LimboConfig::new(settings);
        let bayesopt = BaselineConfig::new(settings);
        let (limbo_s, limbo_acc) = time_runs(&limbo, cell.func, cell.dim, seeds);
        let (bayes_s, bayes_acc) = time_runs(&bayesopt, cell.func, cell.dim, seeds);
        let ratio = bayes_s / limbo_s;
        if cell.hpo {
            ratios_hpo.push(ratio);
        } else {
            ratios.push(ratio);
        }
        let row = format!(
            "{{\"bench\":\"fig1_time\",\"func\":\"{}\",\"dim\":{},\"iters\":{},\"hpo\":{},\
             \"limbo_s\":{limbo_s:.4},\"bayesopt_s\":{bayes_s:.4},\"ratio\":{ratio:.3},\
             \"limbo_acc\":{limbo_acc:.5},\"bayesopt_acc\":{bayes_acc:.5},\"seeds\":{}}}",
            cell.func,
            cell.dim,
            cell.iters,
            cell.hpo,
            seeds.len()
        );
        println!("{row}");
        rows.push(row);
        phase_rows(&mut rows, cell, &limbo, seeds[0]);
    }

    let range = |v: &[f64]| {
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    };
    if !ratios.is_empty() {
        let (lo, hi) = range(&ratios);
        println!("\nspeed-up range no-HPO: {lo:.2}-{hi:.2}x (paper: 1.47-1.76x)");
    }
    if !ratios_hpo.is_empty() {
        let (lo, hi) = range(&ratios_hpo);
        println!("speed-up range HPO:    {lo:.2}-{hi:.2}x (paper: 2.05-2.54x)");
    }

    let path = std::path::Path::new("target").join("fig1_time.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
