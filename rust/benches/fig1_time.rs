//! Figure-1 wall-clock panel, steady-state form: full-run timing of the
//! static vs dynamic implementation per test function (quick protocol —
//! the full 250-replicate study with accuracy panels is
//! `examples/fig1_repro.rs`).

use limbo::benchlib::{header, Bencher};
use limbo::benchfns::{by_name, TestFunction};
use limbo::coordinator::experiment::BenchConfig;
use limbo::coordinator::fig1::{BaselineConfig, Fig1Settings, LimboConfig};

fn main() {
    // single-core-friendly protocol: 4 representative functions, 12
    // iterations, 5 samples (the full study is examples/fig1_repro)
    let b = Bencher { samples: 5, ..Bencher::quick() };
    let settings = Fig1Settings { iterations: 12, inner_evals: 300, ..Default::default() };
    let limbo = LimboConfig::new(settings);
    let bayesopt = BaselineConfig::new(settings);
    let limbo_hpo = LimboConfig::new(settings.with_hpo());
    let bayesopt_hpo = BaselineConfig::new(settings.with_hpo());

    header("fig1 wall-clock (12 iterations/run, quick protocol)");
    let functions: Vec<Box<dyn TestFunction>> = ["branin", "sphere", "ackley", "hartmann3"]
        .iter()
        .map(|n| by_name(n, 2).unwrap())
        .collect();
    let mut ratios = Vec::new();
    let mut ratios_hpo = Vec::new();
    for f in functions {
        let name = f.name().to_string();
        let r1 = b.bench(&format!("limbo/{name}"), || limbo.run(f.as_ref(), 3));
        let r2 = b.bench(&format!("bayesopt/{name}"), || bayesopt.run(f.as_ref(), 3));
        let ratio = r2.per_iter.median / r1.per_iter.median;
        ratios.push(ratio);
        let r3 = b.bench(&format!("limbo+hpo/{name}"), || limbo_hpo.run(f.as_ref(), 3));
        let r4 = b.bench(&format!("bayesopt+hpo/{name}"), || bayesopt_hpo.run(f.as_ref(), 3));
        let ratio_hpo = r4.per_iter.median / r3.per_iter.median;
        ratios_hpo.push(ratio_hpo);
        println!("    -> speed-up: {ratio:.2}x (no HPO), {ratio_hpo:.2}x (HPO)");
    }
    let rng = |v: &[f64]| {
        (v.iter().cloned().fold(f64::INFINITY, f64::min),
         v.iter().cloned().fold(f64::NEG_INFINITY, f64::max))
    };
    let (lo, hi) = rng(&ratios);
    let (lo_h, hi_h) = rng(&ratios_hpo);
    println!("\nspeed-up ranges: {lo:.2}-{hi:.2}x no-HPO (paper 1.47-1.76), {lo_h:.2}-{hi_h:.2}x HPO (paper 2.05-2.54)");
}
