//! Inner-optimizer ablation: time + quality of each acquisition maximizer
//! on a realistic acquisition landscape (UCB over a fitted GP), the design
//! choice DESIGN.md calls out (DIRECT vs CMA-ES vs restarted local search
//! vs random).

use limbo::acqui::{AcquiContext, AcquiFn, Ucb};
use limbo::benchlib::{header, Bencher};
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::opt::{Cmaes, Direct, GridSearch, NelderMead, Optimizer, OptimizerExt, RandomPoint};
use limbo::rng::Pcg64;

fn fitted_gp(dim: usize, n: usize) -> Gp<Matern52, DataMean> {
    let mut rng = Pcg64::seed(17);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
    let ys: Vec<f64> = xs
        .iter()
        .map(|x| x.iter().map(|&v| (6.0 * v).sin()).sum::<f64>())
        .collect();
    let mut gp = Gp::new(Matern52::new(dim), DataMean::default(), 1e-2);
    gp.fit(&xs, &ys);
    gp
}

fn main() {
    let b = Bencher::quick();
    for (dim, n) in [(2usize, 30usize), (6, 60)] {
        header(&format!("acquisition maximization (UCB over {n}-point GP, dim={dim})"));
        let gp = fitted_gp(dim, n);
        let ctx = AcquiContext { iteration: n, best: 1.0, dim };
        let acq = Ucb { alpha: 0.5 };
        let gp_ref = &gp;
        let objective = move |x: &[f64]| acq.eval(gp_ref, x, &ctx);

        let optimizers: Vec<(&str, Box<dyn Optimizer>)> = vec![
            ("random_512", Box::new(RandomPoint::new(512))),
            ("grid", Box::new(GridSearch::new(if dim == 2 { 23 } else { 3 }))),
            ("direct_500", Box::new(Direct::new(500))),
            ("cmaes_500", Box::new(Cmaes::new(500))),
            (
                "rand+nm_x8",
                Box::new(RandomPoint::new(32).then(NelderMead::default()).restarts(8, 4)),
            ),
        ];
        for (name, opt) in &optimizers {
            let mut rng = Pcg64::seed(5);
            let res = b.bench(&format!("{name}/dim={dim}"), || {
                opt.optimize(&objective, dim, &mut rng)
            });
            // quality at fixed budget (median over a few fresh runs)
            let mut vals = Vec::new();
            for s in 0..10 {
                let mut rng = Pcg64::seed(100 + s);
                vals.push(opt.optimize(&objective, dim, &mut rng).value);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            println!(
                "    -> acquisition value found: median {:.4}, worst {:.4} ({} samples/iter)",
                vals[vals.len() / 2],
                vals[0],
                res.iters
            );
        }
    }
}
