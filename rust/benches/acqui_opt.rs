//! Inner-optimizer ablation: time + quality of each acquisition maximizer
//! on a realistic acquisition landscape (UCB over a fitted GP), the design
//! choice DESIGN.md calls out (DIRECT vs CMA-ES vs restarted local search
//! vs random), plus the batched-posterior sweep: point-wise vs batched
//! UCB scoring at batch sizes B ∈ {1, 16, 64, 256}, emitting one JSON row
//! per batch size for the CI bench trajectory.
//!
//! `cargo bench --bench acqui_opt -- --smoke` runs a fast CI-sized variant
//! of the sweep only (smaller GP, fewer samples).

use std::io::Write as _;
use std::time::Duration;

use limbo::acqui::{AcquiContext, AcquiFn, Ucb};
use limbo::benchlib::{header, Bencher};
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::opt::{Cmaes, Direct, GridSearch, NelderMead, Optimizer, OptimizerExt, RandomPoint};
use limbo::rng::Pcg64;

fn fitted_gp(dim: usize, n: usize) -> Gp<Matern52, DataMean> {
    let mut rng = Pcg64::seed(17);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
    let ys: Vec<f64> = xs
        .iter()
        .map(|x| x.iter().map(|&v| (6.0 * v).sin()).sum::<f64>())
        .collect();
    let mut gp = Gp::new(Matern52::new(dim), DataMean::default(), 1e-2);
    gp.fit(&xs, &ys);
    gp
}

fn optimizer_ablation() {
    let b = Bencher::quick();
    for (dim, n) in [(2usize, 30usize), (6, 60)] {
        header(&format!("acquisition maximization (UCB over {n}-point GP, dim={dim})"));
        let gp = fitted_gp(dim, n);
        let ctx = AcquiContext::new(n, 1.0, dim);
        let acq = Ucb { alpha: 0.5 };
        let gp_ref = &gp;
        let objective = move |x: &[f64]| acq.eval(gp_ref, x, &ctx);

        let optimizers: Vec<(&str, Box<dyn Optimizer>)> = vec![
            ("random_512", Box::new(RandomPoint::new(512))),
            ("grid", Box::new(GridSearch::new(if dim == 2 { 23 } else { 3 }))),
            ("direct_500", Box::new(Direct::new(500))),
            ("cmaes_500", Box::new(Cmaes::new(500))),
            (
                "rand+nm_x8",
                Box::new(RandomPoint::new(32).then(NelderMead::default()).restarts(8, 4)),
            ),
        ];
        for (name, opt) in &optimizers {
            let mut rng = Pcg64::seed(5);
            let res = b.bench(&format!("{name}/dim={dim}"), || {
                opt.optimize(&objective, dim, &mut rng)
            });
            // quality at fixed budget (median over a few fresh runs)
            let mut vals = Vec::new();
            for s in 0..10 {
                let mut rng = Pcg64::seed(100 + s);
                vals.push(opt.optimize(&objective, dim, &mut rng).value);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            println!(
                "    -> acquisition value found: median {:.4}, worst {:.4} ({} samples/iter)",
                vals[vals.len() / 2],
                vals[0],
                res.iters
            );
        }
    }
}

/// Point-wise vs batched UCB scoring over a large training set: the
/// batched path pays one cross-covariance block + one multi-RHS solve per
/// batch, the point-wise path re-walks the Cholesky factor per candidate.
/// Emits one JSON row per batch size (candidates/sec both ways) to
/// `target/acqui_opt_batch.json` for the CI artifact.
fn batch_sweep(smoke: bool) {
    let n = if smoke { 128 } else { 512 };
    let dim = 4;
    header(&format!(
        "batched posterior sweep (UCB over {n}-sample GP, dim={dim}, B in 1/16/64/256)"
    ));
    let gp = fitted_gp(dim, n);
    let ctx = AcquiContext::new(n, 1.0, dim);
    let acq = Ucb { alpha: 0.5 };
    let mut rng = Pcg64::seed(23);
    let pool: Vec<Vec<f64>> = (0..256).map(|_| rng.unit_point(dim)).collect();
    let bench = if smoke {
        Bencher {
            warmup: Duration::from_millis(20),
            sample_time: Duration::from_millis(10),
            samples: 5,
        }
    } else {
        Bencher::quick()
    };

    let mut json_rows: Vec<String> = Vec::new();
    for bsize in [1usize, 16, 64, 256] {
        let cands = &pool[..bsize];
        let point = bench.bench(&format!("pointwise/n={n}/B={bsize}"), || {
            let mut acc = 0.0;
            for c in cands {
                acc += acq.eval(&gp, c, &ctx);
            }
            acc
        });
        let batched =
            bench.bench(&format!("batched/n={n}/B={bsize}"), || acq.eval_batch(&gp, cands, &ctx));
        let point_cps = bsize as f64 / point.per_iter.median;
        let batch_cps = bsize as f64 / batched.per_iter.median;
        let speedup = batch_cps / point_cps;
        println!(
            "    -> B={bsize}: {point_cps:.0} vs {batch_cps:.0} candidates/sec ({speedup:.2}x)"
        );
        json_rows.push(format!(
            "{{\"bench\":\"acqui_batch\",\"smoke\":{smoke},\"n\":{n},\"dim\":{dim},\
             \"batch\":{bsize},\"pointwise_cps\":{point_cps:.1},\
             \"batched_cps\":{batch_cps:.1},\"speedup\":{speedup:.3}}}"
        ));
    }

    let path = std::path::Path::new("target").join("acqui_opt_batch.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &json_rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    for row in &json_rows {
        println!("{row}");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    if !smoke {
        optimizer_ablation();
    }
    batch_sweep(smoke);
}
