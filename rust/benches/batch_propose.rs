//! Batch-proposal sweep: constant liar vs joint-posterior Monte-Carlo
//! qEI on `AskTellServer::ask_batch`, at q ∈ {2, 4, 8}.
//!
//! Two columns per (strategy, q) config:
//! * `propose_s` — median wall-clock of one q-point proposal (the
//!   latency a fleet of parallel evaluators waits on);
//! * `qei_score` — the proposed batch's joint qEI under one fixed-seed
//!   reference estimator (higher = better batch; this is the quality the
//!   constant liar trades away by ignoring posterior correlations).
//!
//! One JSON row per config goes to stdout and
//! `target/batch_propose.json`, which CI merges into `BENCH_PR.json`
//! (`scripts/bench_compare.py`; proposal timings are tracked warn-only
//! like the gp_scaling rows). `--smoke` shrinks the training set and rep
//! count to the CI-sized variant.
//!
//! Headline timings run with metrics **disabled**; a final un-timed
//! proposal per config runs with the `limbo::obs` span registry on and
//! emits `"bench":"batch_propose_phase"` rows (inner-optimizer vs qEI MC
//! vs batch acquisition seconds), so a `propose_s` regression points at
//! a phase, not just a strategy.

use std::io::Write as _;
use std::time::Instant;

use limbo::acqui::batch::{BatchAcquiFn, QEi};
use limbo::acqui::{AcquiContext, Ei};
use limbo::bayes_opt::BoDef;
use limbo::benchlib::header;
use limbo::coordinator::{AskTellServer, BatchStrategy};
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::opt::{Chained, NelderMead, ParallelRepeater, RandomPoint};
use limbo::rng::Pcg64;

type BenchServer =
    AskTellServer<Gp<Matern52, DataMean>, Ei, ParallelRepeater<Chained<RandomPoint, NelderMead>>>;

fn fitted_server(n: usize, strategy: BatchStrategy, seed: u64) -> BenchServer {
    let mut rng = Pcg64::seed(17);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (6.0 * x[0]).sin() + x[1] * 0.5).collect();
    // the declarative path the redesign certifies: definition -> server
    let mut srv = BoDef::service(2)
        .noise(1e-2)
        .acquisition(Ei::default())
        .batch(strategy)
        .seed(seed)
        .build_server();
    srv.core.model.fit(&xs, &ys);
    srv.core.refresh_incumbent();
    srv
}

fn median(mut samples: Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let n = if smoke { 64 } else { 256 };
    let reps = if smoke { 3 } else { 7 };
    header(&format!(
        "batch proposal sweep (EI server over {n}-sample GP, dim=2, q in 2/4/8)"
    ));

    let mut json_rows: Vec<String> = Vec::new();
    for q in [2usize, 4, 8] {
        // fixed-seed reference estimator scoring both strategies' batches
        let judge = QEi::new(1024, q, 0x0DDB);
        let mut row_for = |name: &str, strategy: BatchStrategy| {
            let mut srv = fitted_server(n, strategy, 23);
            let mut times = Vec::with_capacity(reps);
            let mut batch = Vec::new();
            for _ in 0..reps {
                let t0 = Instant::now();
                batch = srv.ask_batch(q);
                times.push(t0.elapsed().as_secs_f64());
            }
            let propose_s = median(times);
            let ctx = AcquiContext::new(
                0,
                srv.core.model.best_observation().unwrap_or(f64::NEG_INFINITY),
                2,
            );
            let score = judge.eval_joint(&srv.core.model, &batch, &ctx);
            println!(
                "  {name}/q={q}: {propose_s:.4}s per proposal, reference qEI {score:.4}"
            );
            json_rows.push(format!(
                "{{\"bench\":\"batch_propose\",\"smoke\":{smoke},\"n\":{n},\"dim\":2,\
                 \"q\":{q},\"strategy\":\"{name}\",\"propose_s\":{propose_s:.6},\
                 \"proposals_per_sec\":{:.3},\"qei_score\":{score:.6}}}",
                1.0 / propose_s
            ));
            // one extra un-timed proposal with spans on: attribute
            // propose_s to inner-opt vs qEI MC vs batch acquisition
            limbo::obs::set_enabled(true);
            let base = limbo::obs::snapshot();
            std::hint::black_box(srv.ask_batch(q));
            let delta = limbo::obs::snapshot().delta_since(&base);
            limbo::obs::set_enabled(false);
            for p in limbo::obs::Phase::ALL {
                let calls = delta.calls(p);
                if calls == 0 {
                    continue;
                }
                json_rows.push(format!(
                    "{{\"bench\":\"batch_propose_phase\",\"n\":{n},\"q\":{q},\
                     \"strategy\":\"{name}\",\"phase\":\"{}\",\"seconds\":{:.6},\
                     \"calls\":{calls}}}",
                    p.name(),
                    delta.seconds(p)
                ));
            }
        };
        row_for("constant_liar", BatchStrategy::ConstantLiar);
        row_for("qei", BatchStrategy::QEi { mc_samples: 512 });
    }

    let path = std::path::Path::new("target").join("batch_propose.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &json_rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    for row in &json_rows {
        println!("{row}");
    }
}
