//! Dispatch-cost ablation: the identical BO algorithm (LHS(10) + Matérn-5/2
//! + EI + DIRECT) through the monomorphized `BOptimizer` vs the
//! trait-object `BayesOptLike` — the isolated version of the paper's
//! Figure-1 architecture comparison (same machine, same seeds, same
//! algorithm; only the design style differs).

use limbo::benchlib::{header, Bencher};
use limbo::benchfns::{Branin, Sphere, TestFunction};
use limbo::coordinator::experiment::BenchConfig;
use limbo::coordinator::fig1::{BaselineConfig, Fig1Settings, LimboConfig};

fn main() {
    let b = Bencher::quick();
    header("dispatch cost: static generics vs trait objects (same algorithm)");

    for (fname, f) in [
        ("sphere2", Box::new(Sphere::new(2)) as Box<dyn TestFunction>),
        ("branin", Box::new(Branin)),
    ] {
        for (label, settings) in [
            ("", Fig1Settings { iterations: 20, inner_evals: 300, ..Default::default() }),
            (
                "+hpo",
                Fig1Settings { iterations: 20, inner_evals: 300, ..Default::default() }
                    .with_hpo(),
            ),
        ] {
            let limbo = LimboConfig::new(settings);
            let baseline = BaselineConfig::new(settings);
            let r1 = b.bench(&format!("limbo{label}/{fname}/20iters"), || {
                limbo.run(f.as_ref(), 7)
            });
            let r2 = b.bench(&format!("bayesopt{label}/{fname}/20iters"), || {
                baseline.run(f.as_ref(), 7)
            });
            println!(
                "    -> speed-up {:.2}x (median)",
                r2.per_iter.median / r1.per_iter.median
            );
        }
    }
}
