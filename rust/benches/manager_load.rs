//! Manager load sweep: round-robin ask/tell over many concurrent
//! studies multiplexed through one [`limbo::coordinator::StudyManager`].
//!
//! Two headline columns per configuration:
//! * `studies_per_sec` — completed study-rounds (one ask + one tell)
//!   per second of wall clock, the manager's multiplexing throughput;
//! * `ask_p99_s` — 99th-percentile end-to-end `ask` latency as a client
//!   sees it (checkout + pool dispatch + acquisition + checkin), the
//!   tail a fleet of evaluators actually waits on.
//!
//! Two configurations run: `ephemeral` (all studies stay in memory —
//! pure dispatch overhead) and `durable` with a live-study budget at a
//! quarter of the fleet (every operation beyond the budget pays
//! eviction, event-log append and snapshot/replay rehydration — the
//! restart-survivable deployment). One JSON row per configuration goes
//! to stdout and `target/manager_load.json`, which CI merges into
//! `BENCH_PR.json` (`scripts/bench_compare.py`; tracked warn-only like
//! the other wall-clock rows). `--smoke` shrinks the fleet to the
//! CI-sized variant.
//!
//! The timed loops run with the `limbo::obs` span registry **on** —
//! `"bench":"manager_load_phase"` rows (ask/tell vs snapshot vs replay
//! seconds) attribute a throughput regression to the optimizer itself
//! or to the durability machinery.

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use limbo::bayes_opt::{BoDef, RefitSchedule};
use limbo::benchlib::header;
use limbo::coordinator::StudyManager;
use limbo::obs::Phase;
use limbo::opt::RandomPoint;
use limbo::pool::ThreadPool;

fn objective(study: usize, x: &[f64]) -> f64 {
    let target = (study % 97) as f64 / 96.0;
    -(x[0] - target).powi(2)
}

struct Outcome {
    wall_s: f64,
    ask_p99_s: f64,
    ops: usize,
}

/// Round-robin `rounds` × (ask + tell) over every study.
fn drive(mgr: &StudyManager, ids: &[limbo::coordinator::StudyId], rounds: usize) -> Outcome {
    let mut ask_times = Vec::with_capacity(ids.len() * rounds);
    let t0 = Instant::now();
    for _ in 0..rounds {
        for (s, &id) in ids.iter().enumerate() {
            let ta = Instant::now();
            let x = mgr.ask(id).expect("ask");
            ask_times.push(ta.elapsed().as_secs_f64());
            mgr.tell(id, &x, objective(s, &x)).expect("tell");
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    ask_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99_idx = ((ask_times.len() as f64) * 0.99).ceil() as usize;
    let ask_p99_s = ask_times[p99_idx.clamp(1, ask_times.len()) - 1];
    Outcome { wall_s, ask_p99_s, ops: ask_times.len() * 2 }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let studies = if smoke { 64 } else { 2000 };
    // ≥5 rounds so the Doubling{first:4} refit fires in every study and
    // the durable mode pays real snapshot + replay costs, not just log
    // appends
    let rounds = if smoke { 5 } else { 6 };
    let threads = 4;
    header(&format!(
        "study-manager load ({studies} concurrent 1-D studies, {rounds} ask/tell \
         rounds round-robin, pool={threads})"
    ));
    limbo::obs::set_enabled(true);

    let mut json_rows: Vec<String> = Vec::new();
    let mut run = |mode: &str, mgr: &StudyManager, max_live: usize| {
        let ids: Vec<_> = (0..studies)
            .map(|s| {
                let seed = 9000 + s as u64;
                mgr.create(move || {
                    BoDef::service(1)
                        .seed(seed)
                        .inner_opt(RandomPoint::new(16))
                        .refit(RefitSchedule::Doubling { first: 4 })
                        .build_server()
                })
                .expect("create study")
            })
            .collect();
        let base = limbo::obs::snapshot();
        let out = drive(mgr, &ids, rounds);
        let delta = limbo::obs::snapshot().delta_since(&base);
        let study_rounds = studies * rounds;
        let studies_per_sec = study_rounds as f64 / out.wall_s;
        let (live, evicted) = mgr.counts();
        println!(
            "  {mode:<9} {study_rounds} study-rounds in {:.3}s -> {studies_per_sec:.0} \
             studies/s, ask p99 {:.5}s (live {live}, evicted {evicted})",
            out.wall_s, out.ask_p99_s
        );
        json_rows.push(format!(
            "{{\"bench\":\"manager_load\",\"smoke\":{smoke},\"mode\":\"{mode}\",\
             \"studies\":{studies},\"rounds\":{rounds},\"max_live\":{max_live},\
             \"ops\":{},\"wall_s\":{:.6},\"studies_per_sec\":{studies_per_sec:.3},\
             \"ask_p99_s\":{:.6}}}",
            out.ops, out.wall_s, out.ask_p99_s
        ));
        for p in [Phase::Ask, Phase::Tell, Phase::Refit, Phase::Snapshot, Phase::Replay] {
            json_rows.push(format!(
                "{{\"bench\":\"manager_load_phase\",\"mode\":\"{mode}\",\
                 \"studies\":{studies},\"phase\":\"{}\",\"seconds\":{:.6},\
                 \"calls\":{}}}",
                p.name(),
                delta.seconds(p),
                delta.calls(p)
            ));
        }
    };

    let pool = Arc::new(ThreadPool::new(threads));
    let ephemeral = StudyManager::new(Arc::clone(&pool));
    run("ephemeral", &ephemeral, usize::MAX);
    drop(ephemeral);

    let root = std::env::temp_dir().join("limbo_manager_load_bench");
    let _ = std::fs::remove_dir_all(&root);
    let max_live = (studies / 4).max(1);
    let durable =
        StudyManager::durable(pool, &root).expect("durable root").with_max_live(max_live);
    run("durable", &durable, max_live);
    drop(durable);
    let _ = std::fs::remove_dir_all(&root);

    let path = std::path::Path::new("target").join("manager_load.json");
    let _ = std::fs::create_dir_all("target");
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            for row in &json_rows {
                let _ = writeln!(f, "{row}");
            }
            println!("\nJSON rows written to {}", path.display());
        }
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    for row in &json_rows {
        println!("{row}");
    }
}
