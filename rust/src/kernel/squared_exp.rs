//! Squared-exponential (RBF/Gaussian) kernels, isotropic and ARD.

use super::{ard_r2, scaled_cross_apply, scaled_grad_block, Kernel};
use crate::la::Matrix;

/// ARD squared exponential:
/// `k(a,b) = sigma_f^2 * exp(-0.5 * sum_d (a_d-b_d)^2 / l_d^2)`.
#[derive(Clone, Debug)]
pub struct SquaredExpArd {
    log_ls: Vec<f64>,
    log_sf: f64,
    // hot-loop caches, refreshed by `set_params`
    inv_ls: Vec<f64>,
    sf2: f64,
}

impl SquaredExpArd {
    /// Unit lengthscales and unit signal variance.
    pub fn new(dim: usize) -> Self {
        Self::with_params(vec![0.0; dim], 0.0)
    }

    /// From log lengthscales and log signal std.
    pub fn with_params(log_ls: Vec<f64>, log_sf: f64) -> Self {
        let inv_ls = log_ls.iter().map(|l| (-l).exp()).collect();
        let sf2 = (2.0 * log_sf).exp();
        Self { log_ls, log_sf, inv_ls, sf2 }
    }

    /// Set lengthscales (linear scale).
    pub fn set_lengthscales(&mut self, ls: &[f64]) {
        assert_eq!(ls.len(), self.log_ls.len());
        self.log_ls = ls.iter().map(|l| l.ln()).collect();
        self.inv_ls = ls.iter().map(|l| 1.0 / l).collect();
    }
}

impl Kernel for SquaredExpArd {
    fn dim(&self) -> usize {
        self.log_ls.len()
    }

    fn n_params(&self) -> usize {
        self.log_ls.len() + 1
    }

    fn params(&self) -> Vec<f64> {
        let mut p = self.log_ls.clone();
        p.push(self.log_sf);
        p
    }

    fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        let d = self.log_ls.len();
        self.log_ls.copy_from_slice(&p[..d]);
        self.log_sf = p[d];
        for (inv, l) in self.inv_ls.iter_mut().zip(&self.log_ls) {
            *inv = (-l).exp();
        }
        self.sf2 = (2.0 * self.log_sf).exp();
    }

    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        let r2 = ard_r2(a, b, &self.inv_ls);
        self.sf2 * (-0.5 * r2).exp()
    }

    fn cross_cov(&self, xs: &[Vec<f64>], cands: &[Vec<f64>]) -> Matrix {
        scaled_cross_apply(xs, cands, &self.inv_ls, self.sf2, |r2| (-0.5 * r2).exp())
    }

    fn grad_params(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let d = self.log_ls.len();
        let k = self.eval(a, b);
        for i in 0..d {
            let t = (a[i] - b[i]) * self.inv_ls[i];
            // dk/dlog l_i = k * (a_i-b_i)^2 / l_i^2
            out[i] = k * t * t;
        }
        out[d] = 2.0 * k; // dk/dlog sigma_f
    }

    fn grad_params_block(
        &self,
        xs: &[Vec<f64>],
        cands: &[Vec<f64>],
        weights: &Matrix,
        out: &mut [f64],
    ) {
        // shape = exp(-r²/2); dk/dlog l_d = k·t_d², so shape_dlog = shape
        let shape = |r2: f64| (-0.5 * r2).exp();
        scaled_grad_block(xs, cands, &self.inv_ls, self.sf2, shape, shape, weights, out);
    }

    fn variance(&self) -> f64 {
        self.sf2
    }

    fn kind(&self) -> &'static str {
        "se_ard"
    }

    fn xla_loghp(&self) -> Vec<f64> {
        let mut hp = self.log_ls.clone();
        hp.push(self.log_sf);
        hp
    }
}

/// Isotropic squared exponential: one shared lengthscale.
#[derive(Clone, Debug)]
pub struct SquaredExpIso {
    dim: usize,
    log_l: f64,
    log_sf: f64,
}

impl SquaredExpIso {
    /// Unit lengthscale, unit signal variance.
    pub fn new(dim: usize) -> Self {
        Self { dim, log_l: 0.0, log_sf: 0.0 }
    }
}

impl Kernel for SquaredExpIso {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_params(&self) -> usize {
        2
    }

    fn params(&self) -> Vec<f64> {
        vec![self.log_l, self.log_sf]
    }

    fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), 2);
        self.log_l = p[0];
        self.log_sf = p[1];
    }

    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        let inv_l = (-self.log_l).exp();
        let r2: f64 = a
            .iter()
            .zip(b)
            .map(|(x, y)| {
                let t = (x - y) * inv_l;
                t * t
            })
            .sum();
        self.variance() * (-0.5 * r2).exp()
    }

    fn grad_params(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let inv_l = (-self.log_l).exp();
        let r2: f64 = a
            .iter()
            .zip(b)
            .map(|(x, y)| {
                let t = (x - y) * inv_l;
                t * t
            })
            .sum();
        let k = self.variance() * (-0.5 * r2).exp();
        out[0] = k * r2; // dk/dlog l
        out[1] = 2.0 * k; // dk/dlog sigma_f
    }

    fn variance(&self) -> f64 {
        (2.0 * self.log_sf).exp()
    }

    fn kind(&self) -> &'static str {
        "se_ard" // iso is the ARD artifact with tied lengthscales
    }

    fn xla_loghp(&self) -> Vec<f64> {
        let mut hp = vec![self.log_l; self.dim];
        hp.push(self.log_sf);
        hp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::grad_check;

    #[test]
    fn se_ard_basics() {
        let k = SquaredExpArd::new(2);
        assert_eq!(k.eval(&[0.3, 0.4], &[0.3, 0.4]), 1.0);
        assert!(k.eval(&[0.0, 0.0], &[1.0, 1.0]) < 1.0);
        // symmetric
        let a = [0.1, 0.9];
        let b = [0.7, 0.2];
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
    }

    #[test]
    fn se_ard_lengthscale_effect() {
        let mut k = SquaredExpArd::new(1);
        let near = k.eval(&[0.0], &[0.5]);
        k.set_lengthscales(&[10.0]);
        let far = k.eval(&[0.0], &[0.5]);
        assert!(far > near, "longer lengthscale -> higher correlation");
    }

    #[test]
    fn se_grad_matches_fd() {
        grad_check::run(SquaredExpArd::new, "se_ard-grad");
        grad_check::run(|d| SquaredExpIso::new(d), "se_iso-grad");
    }

    #[test]
    fn iso_equals_ard_with_tied_scales() {
        let mut iso = SquaredExpIso::new(3);
        iso.set_params(&[0.3, 0.1]);
        let mut ard = SquaredExpArd::new(3);
        ard.set_params(&[0.3, 0.3, 0.3, 0.1]);
        let a = [0.2, 0.5, 0.8];
        let b = [0.9, 0.1, 0.4];
        assert!((iso.eval(&a, &b) - ard.eval(&a, &b)).abs() < 1e-14);
    }

    #[test]
    fn params_roundtrip() {
        let mut k = SquaredExpArd::new(2);
        k.set_params(&[0.5, -0.5, 0.2]);
        assert_eq!(k.params(), vec![0.5, -0.5, 0.2]);
        assert_eq!(k.xla_loghp(), vec![0.5, -0.5, 0.2]);
    }
}
