//! Exponential (Ornstein–Uhlenbeck) kernel — rough, non-differentiable
//! sample paths; included for the component-zoo completeness the paper
//! advertises.

use super::{ard_r2, scaled_cross_apply, scaled_grad_block, Kernel};
use crate::la::Matrix;

/// ARD exponential kernel: `sigma_f^2 * exp(-r)` with
/// `r = sqrt(sum_d (a_d-b_d)^2 / l_d^2)`.
#[derive(Clone, Debug)]
pub struct Exponential {
    log_ls: Vec<f64>,
    log_sf: f64,
    // hot-loop caches, refreshed by `set_params`
    inv_ls: Vec<f64>,
    sf2: f64,
}

impl Exponential {
    /// Unit lengthscales and unit signal variance.
    pub fn new(dim: usize) -> Self {
        Self { log_ls: vec![0.0; dim], log_sf: 0.0, inv_ls: vec![1.0; dim], sf2: 1.0 }
    }
}

impl Kernel for Exponential {
    fn dim(&self) -> usize {
        self.log_ls.len()
    }

    fn n_params(&self) -> usize {
        self.log_ls.len() + 1
    }

    fn params(&self) -> Vec<f64> {
        let mut p = self.log_ls.clone();
        p.push(self.log_sf);
        p
    }

    fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        let d = self.log_ls.len();
        self.log_ls.copy_from_slice(&p[..d]);
        self.log_sf = p[d];
        for (inv, l) in self.inv_ls.iter_mut().zip(&self.log_ls) {
            *inv = (-l).exp();
        }
        self.sf2 = (2.0 * self.log_sf).exp();
    }

    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        let r = ard_r2(a, b, &self.inv_ls).sqrt();
        self.sf2 * (-r).exp()
    }

    fn cross_cov(&self, xs: &[Vec<f64>], cands: &[Vec<f64>]) -> Matrix {
        scaled_cross_apply(xs, cands, &self.inv_ls, self.sf2, |r2| (-r2.sqrt()).exp())
    }

    fn grad_params(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let d = self.log_ls.len();
        let r2 = ard_r2(a, b, &self.inv_ls);
        let r = r2.sqrt().max(1e-12); // gradient singular at r = 0
        let k = self.sf2 * (-r).exp();
        for i in 0..d {
            let t = (a[i] - b[i]) * self.inv_ls[i];
            // dk/dlog l_i = k * t_i^2 / r
            out[i] = k * t * t / r;
        }
        out[d] = 2.0 * k;
    }

    fn grad_params_block(
        &self,
        xs: &[Vec<f64>],
        cands: &[Vec<f64>],
        weights: &Matrix,
        out: &mut [f64],
    ) {
        let shape = |r2: f64| (-r2.max(0.0).sqrt()).exp();
        // dk/dlog l_d = k·t_d²/r (clamped at r = 0 like `grad_params`)
        let shape_dlog = |r2: f64| {
            let r = r2.max(0.0).sqrt();
            (-r).exp() / r.max(1e-12)
        };
        scaled_grad_block(xs, cands, &self.inv_ls, self.sf2, shape, shape_dlog, weights, out);
    }

    fn variance(&self) -> f64 {
        self.sf2
    }

    fn kind(&self) -> &'static str {
        "exponential"
    }

    fn xla_loghp(&self) -> Vec<f64> {
        let mut hp = self.log_ls.clone();
        hp.push(self.log_sf);
        hp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing;

    #[test]
    fn basics() {
        let k = Exponential::new(2);
        assert!((k.eval(&[0.5, 0.5], &[0.5, 0.5]) - 1.0).abs() < 1e-14);
        assert!(k.eval(&[0.0, 0.0], &[1.0, 1.0]) < 1.0);
    }

    #[test]
    fn grad_matches_fd_away_from_zero() {
        // avoid r ~ 0 where the OU kernel is non-differentiable
        testing::check(
            "exp-grad",
            0xBEEF,
            32,
            |rng: &mut Pcg64| {
                let mut k = Exponential::new(2);
                k.set_params(&[rng.uniform(-0.5, 0.5), rng.uniform(-0.5, 0.5), rng.uniform(-0.5, 0.5)]);
                let a = rng.unit_point(2);
                let mut b = rng.unit_point(2);
                // enforce separation
                if (a[0] - b[0]).abs() + (a[1] - b[1]).abs() < 0.2 {
                    b[0] += 0.5;
                }
                (k, a, b)
            },
            |(k, a, b)| {
                let mut grad = vec![0.0; 3];
                k.grad_params(a, b, &mut grad);
                let eps = 1e-6;
                let p0 = k.params();
                for i in 0..3 {
                    let mut kp = k.clone();
                    let mut p = p0.clone();
                    p[i] += eps;
                    kp.set_params(&p);
                    let up = kp.eval(a, b);
                    p[i] -= 2.0 * eps;
                    kp.set_params(&p);
                    let dn = kp.eval(a, b);
                    testing::close(grad[i], (up - dn) / (2.0 * eps), 1e-4)?;
                }
                Ok(())
            },
        );
    }
}
