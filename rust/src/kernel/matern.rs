//! Matérn kernels (nu = 3/2 and 5/2) with ARD lengthscales.
//!
//! Matérn-5/2 is the BayesOpt default and the kernel the paper's snippet
//! swaps in (`limbo::kernel::MaternFiveHalves`).

use super::{ard_r2, scaled_cross_apply, scaled_grad_block, Kernel};
use crate::la::Matrix;

const SQRT5: f64 = 2.2360679774997896;
const SQRT3: f64 = 1.7320508075688772;

macro_rules! matern_impl {
    ($name:ident, $kind:literal, $doc:literal) => {
        #[doc = $doc]
        #[derive(Clone, Debug)]
        pub struct $name {
            log_ls: Vec<f64>,
            log_sf: f64,
            // hot-loop caches, refreshed by `set_params`
            inv_ls: Vec<f64>,
            sf2: f64,
        }

        impl $name {
            /// Unit lengthscales and unit signal variance.
            pub fn new(dim: usize) -> Self {
                Self::with_params(vec![0.0; dim], 0.0)
            }

            /// From log lengthscales and log signal std.
            pub fn with_params(log_ls: Vec<f64>, log_sf: f64) -> Self {
                let inv_ls = log_ls.iter().map(|l: &f64| (-l).exp()).collect();
                let sf2 = (2.0 * log_sf).exp();
                Self { log_ls, log_sf, inv_ls, sf2 }
            }
        }

        impl Kernel for $name {
            fn dim(&self) -> usize {
                self.log_ls.len()
            }

            fn n_params(&self) -> usize {
                self.log_ls.len() + 1
            }

            fn params(&self) -> Vec<f64> {
                let mut p = self.log_ls.clone();
                p.push(self.log_sf);
                p
            }

            fn set_params(&mut self, p: &[f64]) {
                assert_eq!(p.len(), self.n_params());
                let d = self.log_ls.len();
                self.log_ls.copy_from_slice(&p[..d]);
                self.log_sf = p[d];
                for (inv, l) in self.inv_ls.iter_mut().zip(&self.log_ls) {
                    *inv = (-l).exp();
                }
                self.sf2 = (2.0 * self.log_sf).exp();
            }

            fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
                let r2 = ard_r2(a, b, &self.inv_ls);
                self.sf2 * $name::shape(r2)
            }

            fn cross_cov(&self, xs: &[Vec<f64>], cands: &[Vec<f64>]) -> Matrix {
                scaled_cross_apply(xs, cands, &self.inv_ls, self.sf2, $name::shape)
            }

            fn grad_params(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
                let d = self.log_ls.len();
                let r2 = ard_r2(a, b, &self.inv_ls);
                let sf2 = self.sf2;
                // per-dim: dk/dlog l_i = sf2 * shape_dlog(r2) * t_i^2
                let coeff = sf2 * $name::shape_dlog(r2);
                for i in 0..d {
                    let t = (a[i] - b[i]) * self.inv_ls[i];
                    out[i] = coeff * t * t;
                }
                out[d] = 2.0 * sf2 * $name::shape(r2);
            }

            fn grad_params_block(
                &self,
                xs: &[Vec<f64>],
                cands: &[Vec<f64>],
                weights: &Matrix,
                out: &mut [f64],
            ) {
                scaled_grad_block(
                    xs,
                    cands,
                    &self.inv_ls,
                    self.sf2,
                    $name::shape,
                    $name::shape_dlog,
                    weights,
                    out,
                );
            }

            fn variance(&self) -> f64 {
                self.sf2
            }

            fn kind(&self) -> &'static str {
                $kind
            }

            fn xla_loghp(&self) -> Vec<f64> {
                let mut hp = self.log_ls.clone();
                hp.push(self.log_sf);
                hp
            }
        }
    };
}

matern_impl!(
    Matern52,
    "matern52",
    "ARD Matérn-5/2: `sigma_f^2 (1 + sqrt5 r + 5/3 r^2) exp(-sqrt5 r)`."
);
matern_impl!(
    Matern32,
    "matern32",
    "ARD Matérn-3/2: `sigma_f^2 (1 + sqrt3 r) exp(-sqrt3 r)`."
);

impl Matern52 {
    #[inline]
    fn shape(r2: f64) -> f64 {
        let r = r2.max(0.0).sqrt();
        (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * (-SQRT5 * r).exp()
    }

    /// `d shape / d log l_i` divided by `t_i^2` — i.e. the common factor
    /// `(5/3)(1 + sqrt5 r) exp(-sqrt5 r)` (the `1/r` from the chain rule
    /// cancels, so this is smooth at `r = 0`).
    #[inline]
    fn shape_dlog(r2: f64) -> f64 {
        let r = r2.max(0.0).sqrt();
        (5.0 / 3.0) * (1.0 + SQRT5 * r) * (-SQRT5 * r).exp()
    }
}

impl Matern32 {
    #[inline]
    fn shape(r2: f64) -> f64 {
        let r = r2.max(0.0).sqrt();
        (1.0 + SQRT3 * r) * (-SQRT3 * r).exp()
    }

    /// Common gradient factor `3 exp(-sqrt3 r)` (smooth at `r = 0`).
    #[inline]
    fn shape_dlog(r2: f64) -> f64 {
        let r = r2.max(0.0).sqrt();
        3.0 * (-SQRT3 * r).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::grad_check;

    #[test]
    fn matern_basics() {
        for dim in [1, 3] {
            let k5 = Matern52::new(dim);
            let k3 = Matern32::new(dim);
            let x = vec![0.4; dim];
            assert!((k5.eval(&x, &x) - 1.0).abs() < 1e-14);
            assert!((k3.eval(&x, &x) - 1.0).abs() < 1e-14);
            let y = vec![0.9; dim];
            assert!(k5.eval(&x, &y) < 1.0);
            // Matern-5/2 is smoother: higher correlation at same distance
            assert!(k5.eval(&x, &y) > k3.eval(&x, &y));
        }
    }

    #[test]
    fn matern_grads_match_fd() {
        grad_check::run(Matern52::new, "matern52-grad");
        grad_check::run(Matern32::new, "matern32-grad");
    }

    #[test]
    fn decays_monotonically() {
        let k = Matern52::new(1);
        let mut prev = f64::INFINITY;
        for step in 0..10 {
            let v = k.eval(&[0.0], &[step as f64 * 0.3]);
            assert!(v < prev || step == 0);
            prev = v;
        }
    }
}
