//! Covariance (kernel) functions — the `limbo::kernel::*` policy family.
//!
//! Every kernel carries its own hyper-parameters in **log space** (the
//! convention the hyper-parameter optimizer works in) and exposes analytic
//! gradients `dk/dlog(theta)` for ML-II fits. Gradients are validated
//! against finite differences by property tests.
//!
//! Conventions shared with the Python L1/L2 side: ARD lengthscales
//! `l_d`, signal std `sigma_f`; `k(x, x) = sigma_f^2` for all stationary
//! kernels here.

mod exponential;
mod matern;
mod squared_exp;

pub use exponential::Exponential;
pub use matern::{Matern32, Matern52};
pub use squared_exp::{SquaredExpArd, SquaredExpIso};

/// A positive-definite covariance function with tunable log-hyper-params.
pub trait Kernel: Clone + Send + Sync + 'static {
    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Number of log-hyper-parameters ([`params`](Self::params) length).
    fn n_params(&self) -> usize;

    /// Current log-hyper-parameters.
    fn params(&self) -> Vec<f64>;

    /// Replace the log-hyper-parameters.
    fn set_params(&mut self, p: &[f64]);

    /// Evaluate `k(a, b)`.
    fn eval(&self, a: &[f64], b: &[f64]) -> f64;

    /// Gradient `dk(a, b) / dlog(theta)` into `out` (length
    /// [`n_params`](Self::n_params)).
    fn grad_params(&self, a: &[f64], b: &[f64], out: &mut [f64]);

    /// Signal variance `k(x, x)`.
    fn variance(&self) -> f64;

    /// Kernel kind name matching the artifact manifest ("se_ard", ...).
    fn kind(&self) -> &'static str;

    /// Log-hyper-params in the XLA artifact layout
    /// `[log l_1 .. log l_d, log sigma_f]` (noise appended by the model).
    fn xla_loghp(&self) -> Vec<f64>;
}

/// ARD-scaled squared distance `sum_d (a_d - b_d)^2 / l_d^2` over
/// *precomputed* inverse lengthscales (shared by all stationary kernels).
/// Kernels cache `1/l_d` at `set_params` time so the per-pair hot loop is
/// mul/add only — no transcendental calls (see EXPERIMENTS.md §Perf).
#[inline]
pub(crate) fn ard_r2(a: &[f64], b: &[f64], inv_ls: &[f64]) -> f64 {
    let mut r2 = 0.0;
    for d in 0..a.len() {
        let t = (a[d] - b[d]) * inv_ls[d];
        r2 += t * t;
    }
    r2
}

#[cfg(test)]
pub(crate) mod grad_check {
    use super::Kernel;
    use crate::rng::Pcg64;
    use crate::testing;

    /// Finite-difference validation of `grad_params` for any kernel.
    pub fn run<K: Kernel + std::fmt::Debug>(make: impl Fn(usize) -> K, name: &str) {
        testing::check(
            name,
            0xC0FFEE,
            48,
            |rng: &mut Pcg64| {
                let dim = 1 + rng.below(4);
                let mut k = make(dim);
                let p: Vec<f64> = (0..k.n_params()).map(|_| rng.uniform(-1.0, 1.0)).collect();
                k.set_params(&p);
                let a = rng.unit_point(dim);
                let b = rng.unit_point(dim);
                (k, a, b)
            },
            |(k, a, b)| {
                let mut grad = vec![0.0; k.n_params()];
                k.grad_params(a, b, &mut grad);
                let eps = 1e-6;
                let p0 = k.params();
                for i in 0..k.n_params() {
                    let mut kp = k.clone();
                    let mut p = p0.clone();
                    p[i] += eps;
                    kp.set_params(&p);
                    let up = kp.eval(a, b);
                    p[i] -= 2.0 * eps;
                    kp.set_params(&p);
                    let dn = kp.eval(a, b);
                    let fd = (up - dn) / (2.0 * eps);
                    testing::close(grad[i], fd, 1e-4)
                        .map_err(|e| format!("param {i}: {e}"))?;
                }
                Ok(())
            },
        );
    }
}
