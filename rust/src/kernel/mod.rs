//! Covariance (kernel) functions — the `limbo::kernel::*` policy family.
//!
//! Every kernel carries its own hyper-parameters in **log space** (the
//! convention the hyper-parameter optimizer works in) and exposes analytic
//! gradients `dk/dlog(theta)` for ML-II fits. Gradients are validated
//! against finite differences by property tests.
//!
//! Conventions shared with the Python L1/L2 side: ARD lengthscales
//! `l_d`, signal std `sigma_f`; `k(x, x) = sigma_f^2` for all stationary
//! kernels here.

mod exponential;
mod matern;
mod squared_exp;

pub use exponential::Exponential;
pub use matern::{Matern32, Matern52};
pub use squared_exp::{SquaredExpArd, SquaredExpIso};

use crate::la::Matrix;

/// A positive-definite covariance function with tunable log-hyper-params.
pub trait Kernel: Clone + Send + Sync + 'static {
    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Number of log-hyper-parameters ([`params`](Self::params) length).
    fn n_params(&self) -> usize;

    /// Current log-hyper-parameters.
    fn params(&self) -> Vec<f64>;

    /// Replace the log-hyper-parameters.
    fn set_params(&mut self, p: &[f64]);

    /// Evaluate `k(a, b)`.
    fn eval(&self, a: &[f64], b: &[f64]) -> f64;

    /// Cross-covariance Gram block `K[i, j] = k(xs[i], cands[j])`
    /// (shape `xs.len() x cands.len()`).
    ///
    /// This is the batched-posterior entry point: `Model::predict_batch`
    /// builds one cross-covariance block per candidate batch instead of
    /// re-walking the training set per point. The default loops over
    /// [`eval`](Self::eval); the stationary kernels override it with a
    /// cache-friendly version that scales both point sets by the inverse
    /// lengthscales once and reuses squared-norm accumulators
    /// (`r^2 = |a'|^2 + |b'|^2 - 2 a'.b'`).
    fn cross_cov(&self, xs: &[Vec<f64>], cands: &[Vec<f64>]) -> Matrix {
        Matrix::from_fn(xs.len(), cands.len(), |i, j| self.eval(&xs[i], &cands[j]))
    }

    /// Gradient `dk(a, b) / dlog(theta)` into `out` (length
    /// [`n_params`](Self::n_params)).
    fn grad_params(&self, a: &[f64], b: &[f64], out: &mut [f64]);

    /// Weighted Gram-block gradient accumulation:
    /// `out[p] += Σ_{i,j} weights[(i, j)] · dk(xs[i], cands[j]) / dθ_p`
    /// (`weights` has shape `xs.len() × cands.len()`; `out` has length
    /// [`n_params`](Self::n_params) and is accumulated into, not reset).
    ///
    /// This is the batched entry point of the exact FITC marginal-
    /// likelihood gradient: the n×m cross block and the m×m inducing
    /// block each contract a precomputed trace-weight matrix against the
    /// kernel's parameter gradients in one pass. The default loops over
    /// [`grad_params`](Self::grad_params); the stationary kernels override
    /// it with the scaled-norm accumulators of
    /// [`cross_cov`](Self::cross_cov) (both point sets scaled by `1/l_d`
    /// once, one dot product per pair, no transcendental calls in the
    /// per-dimension loop).
    fn grad_params_block(
        &self,
        xs: &[Vec<f64>],
        cands: &[Vec<f64>],
        weights: &Matrix,
        out: &mut [f64],
    ) {
        assert_eq!(weights.rows(), xs.len(), "weight rows mismatch");
        assert_eq!(weights.cols(), cands.len(), "weight cols mismatch");
        assert_eq!(out.len(), self.n_params(), "gradient length mismatch");
        let mut dk = vec![0.0; self.n_params()];
        for (i, x) in xs.iter().enumerate() {
            let wrow = weights.row(i);
            for (j, c) in cands.iter().enumerate() {
                let w = wrow[j];
                if w == 0.0 {
                    continue;
                }
                self.grad_params(x, c, &mut dk);
                for (o, &d) in out.iter_mut().zip(&dk) {
                    *o += w * d;
                }
            }
        }
    }

    /// Signal variance `k(x, x)`.
    fn variance(&self) -> f64;

    /// Kernel kind name matching the artifact manifest ("se_ard", ...).
    fn kind(&self) -> &'static str;

    /// Log-hyper-params in the XLA artifact layout
    /// `[log l_1 .. log l_d, log sigma_f]` (noise appended by the model).
    fn xla_loghp(&self) -> Vec<f64>;
}

/// ARD-scaled squared distance `sum_d (a_d - b_d)^2 / l_d^2` over
/// *precomputed* inverse lengthscales (shared by all stationary kernels).
/// Kernels cache `1/l_d` at `set_params` time so the per-pair hot loop is
/// mul/add only — no transcendental calls (see EXPERIMENTS.md §Perf).
#[inline]
pub(crate) fn ard_r2(a: &[f64], b: &[f64], inv_ls: &[f64]) -> f64 {
    let mut r2 = 0.0;
    for d in 0..a.len() {
        let t = (a[d] - b[d]) * inv_ls[d];
        r2 += t * t;
    }
    r2
}

/// Scale a point set by precomputed inverse lengthscales, returning the
/// flattened scaled coordinates and the per-point squared norms — the two
/// reusable accumulators of the batched cross-covariance.
fn scale_points(pts: &[Vec<f64>], inv_ls: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let d = inv_ls.len();
    let mut flat = Vec::with_capacity(pts.len() * d);
    let mut norms = Vec::with_capacity(pts.len());
    for p in pts {
        let mut s = 0.0;
        for (&v, &il) in p.iter().zip(inv_ls) {
            let t = v * il;
            flat.push(t);
            s += t * t;
        }
        norms.push(s);
    }
    (flat, norms)
}

/// Fixed row-panel height of the parallel gradient reduction below: the
/// partial-sum boundaries depend only on this constant (never the thread
/// count), so the merged gradient is bit-stable under `Tune::threads`.
const GRAD_PANEL_ROWS: usize = 64;

/// Shared `grad_params_block` core for the ARD stationary kernels, whose
/// parameter gradients all factor as
/// `dk/dlog l_d = sf² · shape_dlog(r²) · t_d²` and
/// `dk/dlog σ_f = 2 sf² · shape(r²)` over the scaled difference
/// `t = (a − b)/l`. Both point sets are scaled by the inverse
/// lengthscales **once** (the same accumulators as
/// [`scaled_cross_apply`]), then each weighted pair costs one dot
/// product, two shape evaluations, and a mul/add-only per-dimension
/// loop.
///
/// Large blocks reduce fixed-height row panels over scoped threads;
/// the per-panel partials merge in panel-index order, so the summation
/// order is a function of the panel constant alone and results are
/// identical for any [`crate::la::Tune::threads`].
///
/// `out` layout: `[d lengthscale grads..., signal grad]` — accumulated
/// into, matching the [`Kernel::grad_params_block`] contract.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scaled_grad_block(
    xs: &[Vec<f64>],
    cands: &[Vec<f64>],
    inv_ls: &[f64],
    sf2: f64,
    shape: impl Fn(f64) -> f64 + Sync,
    shape_dlog: impl Fn(f64) -> f64 + Sync,
    weights: &Matrix,
    out: &mut [f64],
) {
    assert_eq!(weights.rows(), xs.len(), "weight rows mismatch");
    assert_eq!(weights.cols(), cands.len(), "weight cols mismatch");
    let d = inv_ls.len();
    assert_eq!(out.len(), d + 1, "gradient length mismatch");
    if xs.is_empty() || cands.is_empty() {
        return;
    }
    let (a, a_norms) = scale_points(xs, inv_ls);
    let (b, b_norms) = scale_points(cands, inv_ls);
    let t = crate::la::tune();
    let flops = xs.len().saturating_mul(cands.len()).saturating_mul(2 * d + 24);
    let panels: Vec<usize> = (0..xs.len().div_ceil(GRAD_PANEL_ROWS)).collect();
    let partials =
        crate::pool::parallel_map_hinted(panels, t.threads, flops, t.par_min_flops, |_, pi| {
            let i0 = pi * GRAD_PANEL_ROWS;
            let i1 = (i0 + GRAD_PANEL_ROWS).min(xs.len());
            let mut part = vec![0.0; d + 1];
            for i in i0..i1 {
                let ai = &a[i * d..(i + 1) * d];
                let an = a_norms[i];
                let wrow = weights.row(i);
                for (j, &w) in wrow.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    let bj = &b[j * d..(j + 1) * d];
                    let r2 = (an + b_norms[j] - 2.0 * crate::la::dot(ai, bj)).max(0.0);
                    let coeff = w * sf2 * shape_dlog(r2);
                    for (o, (&av, &bv)) in part[..d].iter_mut().zip(ai.iter().zip(bj)) {
                        let diff = av - bv;
                        *o += coeff * diff * diff;
                    }
                    part[d] += w * 2.0 * sf2 * shape(r2);
                }
            }
            part
        });
    // merge in panel-index order (parallel_map preserves item order)
    for part in partials {
        for (o, &p) in out.iter_mut().zip(&part) {
            *o += p;
        }
    }
}

/// Fused scaled-distance map for the stationary kernels:
/// `out[i][j] = sf² · shape(r²(xs[i], cands[j]))` with
/// `r² = |a'|² + |b'|² − 2 a'·b'` over the inverse-lengthscale-scaled
/// points (clamped at 0 against cancellation). Both point sets are
/// scaled **once**; candidates are walked in [`crate::la::Tune::block`]-
/// sized strips (a strip of scaled candidates stays cache-resident
/// across the panel's rows) and disjoint output row panels fan out over
/// scoped threads. Each pair's arithmetic is fixed, so results are
/// bit-identical to the unblocked sweep for any thread count. Shared by
/// the stationary kernels' `cross_cov` specializations.
pub(crate) fn scaled_cross_apply(
    xs: &[Vec<f64>],
    cands: &[Vec<f64>],
    inv_ls: &[f64],
    sf2: f64,
    shape: impl Fn(f64) -> f64 + Sync,
) -> Matrix {
    let d = inv_ls.len();
    let n = xs.len();
    let m = cands.len();
    let mut out = Matrix::zeros(n, m);
    if n == 0 || m == 0 {
        return out;
    }
    let (a, a_norms) = scale_points(xs, inv_ls);
    let (b, b_norms) = scale_points(cands, inv_ls);
    let t = crate::la::tune();
    // ~2d mul/adds for the dot plus the shape's transcendental per pair
    let flops = n.saturating_mul(m).saturating_mul(2 * d + 16);
    let threads = t.threads_for(flops);
    let rows_per = n.div_ceil(threads);
    let jb = t.block.max(16);
    let tasks: Vec<&mut [f64]> = out.data_mut().chunks_mut(rows_per * m).collect();
    crate::pool::parallel_map_hinted(tasks, threads, flops, t.par_min_flops, |ci, chunk| {
        let i0 = ci * rows_per;
        let rows = chunk.len() / m;
        let mut j0 = 0;
        while j0 < m {
            let j1 = (j0 + jb).min(m);
            for di in 0..rows {
                let i = i0 + di;
                let ai = &a[i * d..(i + 1) * d];
                let an = a_norms[i];
                let orow = &mut chunk[di * m..(di + 1) * m];
                for j in j0..j1 {
                    let bj = &b[j * d..(j + 1) * d];
                    let r2 = (an + b_norms[j] - 2.0 * crate::la::dot(ai, bj)).max(0.0);
                    orow[j] = sf2 * shape(r2);
                }
            }
            j0 = j1;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing;

    /// `cross_cov` (specialized or default) must agree with pairwise
    /// `eval` — the contract every `predict_batch` relies on.
    fn check_cross_cov<K: Kernel + std::fmt::Debug>(make: impl Fn(usize) -> K, name: &str) {
        testing::check(
            name,
            0x5EED,
            32,
            |rng: &mut Pcg64| {
                let dim = 1 + rng.below(4);
                let mut k = make(dim);
                let p: Vec<f64> = (0..k.n_params()).map(|_| rng.uniform(-1.0, 1.0)).collect();
                k.set_params(&p);
                let n = rng.below(8); // includes the empty set
                let b = rng.below(9);
                let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
                let cs: Vec<Vec<f64>> = (0..b).map(|_| rng.unit_point(dim)).collect();
                (k, xs, cs)
            },
            |(k, xs, cs)| {
                let gram = k.cross_cov(xs, cs);
                if (gram.rows(), gram.cols()) != (xs.len(), cs.len()) {
                    return Err(format!("shape {}x{}", gram.rows(), gram.cols()));
                }
                for (i, x) in xs.iter().enumerate() {
                    for (j, c) in cs.iter().enumerate() {
                        testing::close(gram[(i, j)], k.eval(x, c), 1e-12)
                            .map_err(|e| format!("({i},{j}): {e}"))?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cross_cov_matches_pairwise_eval() {
        check_cross_cov(SquaredExpArd::new, "se_ard-cross-cov");
        check_cross_cov(|d| SquaredExpIso::new(d), "se_iso-cross-cov");
        check_cross_cov(Matern52::new, "matern52-cross-cov");
        check_cross_cov(Matern32::new, "matern32-cross-cov");
        check_cross_cov(Exponential::new, "exponential-cross-cov");
    }

    /// `grad_params_block` (specialized or default) must agree with the
    /// naive weighted pairwise `grad_params` accumulation — the contract
    /// the FITC marginal-likelihood gradient relies on.
    fn check_grad_block<K: Kernel + std::fmt::Debug>(make: impl Fn(usize) -> K, name: &str) {
        testing::check(
            name,
            0x6B10C,
            32,
            |rng: &mut Pcg64| {
                let dim = 1 + rng.below(3);
                let mut k = make(dim);
                let p: Vec<f64> = (0..k.n_params()).map(|_| rng.uniform(-0.8, 0.8)).collect();
                k.set_params(&p);
                let n = rng.below(7);
                let b = rng.below(6);
                let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
                let cs: Vec<Vec<f64>> = (0..b).map(|_| rng.unit_point(dim)).collect();
                let w = Matrix::from_fn(n, b, |_, _| rng.uniform(-2.0, 2.0));
                (k, xs, cs, w)
            },
            |(k, xs, cs, w)| {
                let mut got = vec![0.25; k.n_params()]; // nonzero: must accumulate
                k.grad_params_block(xs, cs, w, &mut got);
                let mut want = vec![0.25; k.n_params()];
                let mut dk = vec![0.0; k.n_params()];
                for (i, x) in xs.iter().enumerate() {
                    for (j, c) in cs.iter().enumerate() {
                        k.grad_params(x, c, &mut dk);
                        for (o, &d) in want.iter_mut().zip(&dk) {
                            *o += w[(i, j)] * d;
                        }
                    }
                }
                for (p, (&g, &t)) in got.iter().zip(&want).enumerate() {
                    testing::close(g, t, 1e-9).map_err(|e| format!("param {p}: {e}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn grad_params_block_matches_pairwise() {
        check_grad_block(SquaredExpArd::new, "se_ard-grad-block");
        check_grad_block(|d| SquaredExpIso::new(d), "se_iso-grad-block");
        check_grad_block(Matern52::new, "matern52-grad-block");
        check_grad_block(Matern32::new, "matern32-grad-block");
        check_grad_block(Exponential::new, "exponential-grad-block");
    }
}

#[cfg(test)]
pub(crate) mod grad_check {
    use super::Kernel;
    use crate::rng::Pcg64;
    use crate::testing;

    /// Finite-difference validation of `grad_params` for any kernel.
    pub fn run<K: Kernel + std::fmt::Debug>(make: impl Fn(usize) -> K, name: &str) {
        testing::check(
            name,
            0xC0FFEE,
            48,
            |rng: &mut Pcg64| {
                let dim = 1 + rng.below(4);
                let mut k = make(dim);
                let p: Vec<f64> = (0..k.n_params()).map(|_| rng.uniform(-1.0, 1.0)).collect();
                k.set_params(&p);
                let a = rng.unit_point(dim);
                let b = rng.unit_point(dim);
                (k, a, b)
            },
            |(k, a, b)| {
                let mut grad = vec![0.0; k.n_params()];
                k.grad_params(a, b, &mut grad);
                let eps = 1e-6;
                let p0 = k.params();
                for i in 0..k.n_params() {
                    let mut kp = k.clone();
                    let mut p = p0.clone();
                    p[i] += eps;
                    kp.set_params(&p);
                    let up = kp.eval(a, b);
                    p[i] -= 2.0 * eps;
                    kp.set_params(&p);
                    let dn = kp.eval(a, b);
                    let fd = (up - dn) / (2.0 * eps);
                    testing::close(grad[i], fd, 1e-4)
                        .map_err(|e| format!("param {i}: {e}"))?;
                }
                Ok(())
            },
        );
    }
}
