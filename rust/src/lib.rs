//! # limbo-rs — fast & flexible Bayesian optimization
//!
//! A Rust + JAX + Pallas reproduction of *“Limbo: A Fast and Flexible
//! Library for Bayesian Optimization”* (Cully, Chatzilygeroudis, Allocati,
//! Mouret, 2016). See DESIGN.md for the system inventory and the
//! experiment index, EXPERIMENTS.md for paper-vs-measured results.
//!
//! The paper's point is architectural: every component of a Bayesian
//! optimizer — initializer, model (kernel + mean), acquisition function,
//! inner optimizer, hyper-parameter optimizer, stopping criterion, stats —
//! is a swappable *policy*, composed statically so that flexibility costs
//! nothing at runtime (no virtual dispatch). In this reproduction the
//! composition surface is [`bayes_opt::BoDef`], the analog of the C++
//! `Params` struct: a declarative builder that monomorphizes to concrete
//! types and builds either frontend of the single shared loop engine
//! ([`bayes_opt::BoCore`]) from one definition —
//!
//! ```no_run
//! use limbo::prelude::*;
//!
//! // the quickstart: maximize f over [0,1]^2 with the library defaults
//! let f = FnEval::new(2, |x: &[f64]| {
//!     -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>()
//! });
//! let mut opt = BoDef::new(2).seed(42).build_optimizer();
//! let best = opt.optimize(&f);
//! println!("best {:?} -> {}", best.x, best.value);
//!
//! // the same definition as an ask/tell server over a real-world box
//! let mut srv = BoDef::new(2)
//!     .acquisition(Ei::default())
//!     .refit(RefitSchedule::Doubling { first: 16 })
//!     .bounds(&[(-5.0, 10.0), (0.0, 15.0)])
//!     .seed(42)
//!     .build_server();
//! let x = srv.ask(); // user coordinates — no hand-normalizing
//! srv.tell(&x, -(x[0] * x[0] + x[1]));
//! ```
//!
//! Every entry point — the run-to-completion [`bayes_opt::BOptimizer`],
//! the sync and threaded [`coordinator::AskTellServer`], and the
//! dynamic-dispatch Figure-1 comparator [`baseline::BayesOptLike`] —
//! drives the same [`bayes_opt::BoCore`] propose/observe/refit state
//! machine, and run statistics are [`bayes_opt::Observer`]s on its typed
//! event bus ([`stat::RunLogger`], [`stat::JsonlObserver`],
//! [`stat::TraceHandle`]).
//!
//! The GP compute hot path additionally has an AOT-compiled XLA backend
//! ([`runtime::XlaGp`]): JAX/Pallas graphs are lowered to HLO at build
//! time (`make artifacts`) and executed from Rust via PJRT — Python is
//! never on the optimization path.
//!
//! # Profiling a run
//!
//! Every hot layer is instrumented with phase-level [`obs::Span`] timers
//! feeding a process-wide metrics registry (see [`obs`] for the cost
//! model: one relaxed atomic load when disabled, per-thread shards when
//! enabled). To see where a run's milliseconds go:
//!
//! * attach a [`stat::MetricsObserver`] to any `BoDef` frontend — it
//!   enables timing and writes the per-run phase breakdown into the run
//!   directory's `meta.dat` (TSV lines) and `metrics.json` on stop;
//! * pass `--metrics` to the CLI (`limbo run dim=2 --metrics`) for a
//!   phase table on stderr, or `metrics=true` as a config key;
//! * run `cargo run --release --example metrics` for a worked Branin
//!   breakdown, or bracket your own region with [`obs::snapshot`] and
//!   [`obs::Snapshot::delta_since`];
//! * `benches/gp_scaling.rs` and `benches/batch_propose.rs` emit
//!   per-phase JSON rows so `scripts/bench_compare.py` attributes a
//!   regression to a phase (Cholesky vs. refit vs. acquisition) instead
//!   of a whole bench.
//!
//! Spans never touch the RNG or reorder floating-point work, so traces
//! are bit-identical with metrics on or off (`tests/api_parity.rs`).
//!
//! # Inner optimizers
//!
//! Maximizing the acquisition function is its own global-optimization
//! problem, and [`bayes_opt::BoDef::inner_opt`] makes the maximizer a
//! swappable policy ([`opt::Optimizer`]). Guidance:
//!
//! * [`opt::Direct`] (the BayesOpt default) — deterministic rectangle
//!   subdivision; excellent in low dimension (d ≲ 6) and reproducible
//!   without an RNG, but its center-first trisection stalls on
//!   high-dimensional or deceptive acquisition landscapes.
//! * [`opt::Cmaes`] — covariance-matrix adaptation; strong on smooth
//!   mid-dimensional landscapes (d ≈ 5–20) with moderate
//!   multimodality.
//! * [`opt::AdaptiveDe`] — self-adaptive Differential Evolution
//!   (jDE/JADE-style: per-individual F/CR, current-to-pbest/1 mutation
//!   with an archive, population-size reduction). Batch-first like
//!   CMA-ES (one [`opt::Objective::eval_many`] call per generation, so
//!   the model pays one batched posterior per generation) and the most
//!   robust choice on high-dimensional multimodal landscapes (d ≳ 10);
//!   `BoDef::new(d).inner_de(300)` swaps it in, and
//!   [`opt::DeRecorder`] captures its per-generation state (population
//!   size, best value, mean F/CR).
//!
//! All of them compose with [`opt::OptimizerExt::restarts`] (parallel
//! restarts, bit-reproducible across pool thread counts) and
//! [`opt::OptimizerExt::then`] (global → local chaining). The
//! `fig1_inner_opt` rows of `benches/fig1_time.rs` sweep DIRECT vs
//! CMA-ES vs DE at an equal evaluation budget across dimensions.
//!
//! For forensics, [`stat::RecordingObserver`] captures a full run's
//! event stream (plus the DE generation rows) and
//! [`stat::RecordingObserver::replay_into`] re-drives a fresh,
//! identically-configured study through it, verifying every re-asked
//! proposal bit-for-bit — the first divergence is reported with its
//! event index and iteration, which turns a convergence regression
//! into a bisectable fact (`tests/de_convergence.rs` pins this).
//!
//! # Performance tuning
//!
//! The dense hot kernels (matmul, Cholesky, multi-RHS solves, kernel
//! cross-covariance) are cache-blocked and fanned out over the
//! process-wide [`pool`]; one [`la::Tune`] config controls panel size,
//! thread count, the parallel-dispatch FLOP threshold, and the
//! scalar-fallback cutoff. Defaults come from [`la::Tune::from_env`]
//! (`LIMBO_LA_BLOCK`, `LIMBO_LA_THREADS`, `LIMBO_LA_PAR_MIN`,
//! `LIMBO_LA_SMALL`), and [`la::set_tune`] overrides them at runtime.
//!
//! When the `--metrics` phase table points at a dense phase (`matmul`,
//! `cholesky`, `cross_cov`, or the solve phases), these knobs are the
//! lever: lower `LIMBO_LA_PAR_MIN` to parallelize smaller problems,
//! raise `LIMBO_LA_BLOCK` on cores with larger L1 caches, or pin
//! `LIMBO_LA_THREADS=1` when the surrounding code (e.g. HPO restarts
//! through [`pool::parallel_map`]) already saturates the machine —
//! nested fan-outs queue rather than oversubscribe, but single-threaded
//! inner kernels keep the outer parallelism as the only scheduler.
//!
//! Changing `threads` or `par_min_flops` NEVER changes results, bitwise:
//! parallel fan-outs split disjoint output panels with fixed per-element
//! arithmetic (`tests/api_parity.rs` sweeps 1/2/8 threads through a full
//! optimizer run). `block` and `small` pick different — equally valid —
//! summation orders and are pinned to the scalar references at
//! `<= 1e-12` by `tests/blocked_la.rs`.
//!
//! # Running as a service
//!
//! One optimization is a [`coordinator::AskTellServer`]; a *fleet* of
//! them is a [`coordinator::StudyManager`] — the registry that
//! multiplexes thousands of concurrent studies over one shared [`pool`]
//! and survives restarts:
//!
//! ```no_run
//! use std::sync::Arc;
//! use limbo::coordinator::StudyManager;
//! use limbo::pool::ThreadPool;
//! use limbo::prelude::*;
//!
//! let pool = Arc::new(ThreadPool::new(4));
//! let mgr = StudyManager::durable(pool, "/var/lib/studies")
//!     .expect("durability root")
//!     .with_max_live(256); // LRU-evict cold studies past the budget
//! let id = mgr.create(|| BoDef::service(2).seed(7).build_server())?;
//! let x = mgr.ask(id)?; // typed errors: NotFound / Evicted / Closed / Io
//! mgr.tell(id, &x, -(x[0] * x[0] + x[1]))?;
//! # Ok::<(), limbo::coordinator::StudyError>(())
//! ```
//!
//! Studies are addressed by the opaque [`coordinator::StudyId`] and every
//! operation returns a typed [`coordinator::StudyError`] — no stringly
//! ids, no panicking surface. Durability is event sourcing: each study
//! appends its [`bayes_opt::BoEvent`]s to a JSONL log
//! (17-significant-digit floats) and checkpoints at *refit barriers*,
//! the moments where model state is reproducible bit-for-bit; recovery
//! ([`coordinator::StudyManager::recover`]) replays the log tail through
//! the live code path, so a rehydrated study continues the **exact**
//! trace of the lost one (`tests/study_manager.rs` proves byte-identical
//! event logs across a kill). The [`coordinator::Study`] trait is the
//! common ask/tell vocabulary across all three deployment modes —
//! inline server, spawned [`coordinator::ServerHandle`], managed
//! [`coordinator::ManagedStudy`] — so driver code is generic over where
//! the study runs. `benches/manager_load.rs` tracks multiplexing
//! throughput and tail ask latency in CI.
//!
//! # Scenarios: noisy, constrained, asynchronous
//!
//! Real evaluations are rarely the exact, sequential, unconstrained
//! ideal. The observation path is built around one typed record —
//! [`bayes_opt::Observation`] — so the same ask/tell surface covers all
//! three deviations.
//!
//! **Noisy observations.** Attach a per-trial noise *variance* to any
//! tell; it is added to that observation's diagonal entry of the train
//! Gram (heteroskedastic regression), and once any noise is present the
//! acquisition's incumbent switches from best raw sample to best
//! *predicted mean* — a lucky noise spike must not freeze the
//! improvement threshold:
//!
//! ```no_run
//! use limbo::prelude::*;
//!
//! let mut srv = BoDef::new(1).seed(7).build_server();
//! let x = srv.ask();
//! // y was averaged over few replicates: report its noise variance
//! srv.tell_observation(&Observation::noisy(x, 0.31, 0.05)).unwrap();
//! ```
//!
//! **Constraints.** Declare `k` constraint channels on the definition
//! and build a constrained server: the model becomes a
//! [`model::ModelBank`] (objective + one surrogate per channel, refit
//! together), the acquisition is wrapped in
//! [`acqui::PofWeighted`] (probability-of-feasibility weighting,
//! `>= 0` = feasible), and only feasible observations become the
//! incumbent. Every tell must carry one value per channel:
//!
//! ```no_run
//! use limbo::prelude::*;
//!
//! let mut srv = BoDef::new(2)
//!     .acquisition(Ei::default())
//!     .constraints(1)
//!     .seed(7)
//!     .build_constrained_server();
//! let x = srv.ask();
//! let c = 0.25 - (x[0] - 0.5).powi(2) - (x[1] - 0.5).powi(2);
//! srv.tell_observation(&Observation::exact(x.clone(), -x[0]).with_constraints(vec![c]))
//!     .unwrap();
//! ```
//!
//! **Asynchronous workers.** With `async_pending(true)`, an ask
//! registers its proposal as *pending* and later proposals fantasize
//! over the outstanding set (kriging-believer mean lies into a scratch
//! model), so `q` workers can interleave ask/tell in any order without
//! receiving duplicate points; each tell retires its pending entry:
//!
//! ```no_run
//! use limbo::prelude::*;
//!
//! let handle = BoDef::new(1).seed(7).async_pending(true).build_server().spawn();
//! let (a, b) = (handle.ask(), handle.ask()); // both outstanding at once
//! handle.tell(b, 0.1); // tells may arrive in any order
//! handle.tell(a, 0.4);
//! ```
//!
//! All three compose with durability: the generalized tells serialize
//! through [`stat::JsonlObserver`] (`tell_noisy` / `tell_constrained` /
//! `ask_pending` records), replay through [`stat::ReplayEvent`], and a
//! killed noisy/constrained study recovers bit-exact through the
//! [`coordinator::StudyManager`] snapshot + log-tail path.

pub mod acqui;
pub mod baseline;
pub mod bayes_opt;
pub mod benchfns;
pub mod benchlib;
pub mod coordinator;
pub mod init;
pub mod kernel;
pub mod la;
pub mod mean;
pub mod model;
pub mod obs;
pub mod opt;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod stat;
pub mod stop;
pub mod testing;

/// Convenient re-exports for typical use.
pub mod prelude {
    pub use crate::acqui::{
        AcquiContext, AcquiFn, AcquiObjective, BatchAcquiFn, BatchAcquiObjective, Ei, GpUcb,
        Pi, PofWeighted, QEi, Ucb,
    };
    pub use crate::bayes_opt::{
        BOptimizer, BatchStrategy, Best, BoCore, BoDef, BoError, BoEvent, CoreState, Domain,
        Evaluator, FnEval, Observation, Observer, RefitSchedule,
    };
    pub use crate::benchfns::TestFunction;
    pub use crate::coordinator::{
        AskTellServer, DefaultAskTellServer, DefaultDenseServer, ManagedStudy, ServerHandle,
        Study, StudyError, StudyId, StudyManager,
    };
    pub use crate::init::{Initializer, Lhs, NoInit, RandomSampling};
    pub use crate::kernel::{Kernel, Matern32, Matern52, SquaredExpArd};
    pub use crate::mean::{ConstantMean, DataMean, MeanFn, ZeroMean};
    pub use crate::model::{
        gp::Gp, AdaptiveModel, GpState, Model, ModelBank, ModelState, SgpConfig, SgpState,
        SparseGp, StateModel,
    };
    pub use crate::opt::{
        AdaptiveDe, Cmaes, DeGenRecord, DeRecorder, Direct, NelderMead, Objective, Optimizer,
        OptimizerExt, PopulationSearch, RandomPoint,
    };
    pub use crate::rng::Pcg64;
    pub use crate::stat::{
        JsonlObserver, MetricsObserver, RecordingObserver, ReplayEvent, RunLogger, TraceHandle,
    };
    pub use crate::stop::{MaxIterations, StopCriterion, TargetReached};
}
