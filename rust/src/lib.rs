//! # limbo-rs — fast & flexible Bayesian optimization
//!
//! A Rust + JAX + Pallas reproduction of *“Limbo: A Fast and Flexible
//! Library for Bayesian Optimization”* (Cully, Chatzilygeroudis, Allocati,
//! Mouret, 2016). See DESIGN.md for the system inventory and the
//! experiment index, EXPERIMENTS.md for paper-vs-measured results.
//!
//! The paper's point is architectural: every component of a Bayesian
//! optimizer — initializer, model (kernel + mean), acquisition function,
//! inner optimizer, hyper-parameter optimizer, stopping criterion, stats —
//! is a swappable *policy*, composed statically so that flexibility costs
//! nothing at runtime (no virtual dispatch). The C++ template design maps
//! onto Rust generics: [`bayes_opt::BOptimizer`] is monomorphized over its
//! component types, while [`baseline::BayesOptLike`] is the same algorithm
//! built the classic OO way (trait objects) to reproduce the paper's
//! Figure-1 comparison against BayesOpt.
//!
//! The GP compute hot path additionally has an AOT-compiled XLA backend
//! ([`runtime::XlaGp`]): JAX/Pallas graphs are lowered to HLO at build
//! time (`make artifacts`) and executed from Rust via PJRT — Python is
//! never on the optimization path.

pub mod acqui;
pub mod baseline;
pub mod bayes_opt;
pub mod benchfns;
pub mod benchlib;
pub mod coordinator;
pub mod init;
pub mod kernel;
pub mod la;
pub mod mean;
pub mod model;
pub mod opt;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod stat;
pub mod stop;
pub mod testing;

/// Convenient re-exports for typical use.
pub mod prelude {
    pub use crate::acqui::{
        AcquiContext, AcquiFn, AcquiObjective, BatchAcquiFn, BatchAcquiObjective, Ei, GpUcb,
        Pi, QEi, Ucb,
    };
    pub use crate::bayes_opt::{BOptimizer, Best, Evaluator, FnEval};
    pub use crate::benchfns::TestFunction;
    pub use crate::init::{Initializer, Lhs, RandomSampling};
    pub use crate::kernel::{Kernel, Matern32, Matern52, SquaredExpArd};
    pub use crate::mean::{ConstantMean, DataMean, MeanFn, ZeroMean};
    pub use crate::model::{gp::Gp, AdaptiveModel, GpState, Model, SgpConfig, SgpState, SparseGp};
    pub use crate::opt::{
        Cmaes, Direct, NelderMead, Objective, Optimizer, OptimizerExt, PopulationSearch,
        RandomPoint,
    };
    pub use crate::rng::Pcg64;
    pub use crate::stop::{MaxIterations, StopCriterion, TargetReached};
}
