//! `limbo` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! * `run`    — one BO run on a named test function (`function=branin`,
//!              `iterations=40`, `hpo=true`, `backend=native|xla`,
//!              `seed=1`, `out=<dir>` for stat traces);
//! * `fig1`   — the Figure-1 experiment grid (see `examples/fig1_repro.rs`
//!              for the full driver; this is the quick CLI front-end);
//! * `serve`  — interactive ask/tell loop on stdin/stdout
//!              (`ask` -> point, `tell <y>` -> record, `best`, `quit`);
//! * `info`   — print artifact registry and build info.

use std::sync::Arc;

use limbo::acqui::Ei;
use limbo::bayes_opt::{BOptimizer, BoDef, FnEval, RefitSchedule};
use limbo::benchfns;
use limbo::coordinator::config::Config;
use limbo::coordinator::experiment::{print_table, speedups, ExperimentRunner};
use limbo::coordinator::fig1::{BaselineConfig, Fig1Settings, LimboConfig};
use limbo::coordinator::xla_model::XlaGpModel;
use limbo::init::Lhs;
use limbo::opt::{Direct, NelderMead, OptimizerExt, RandomPoint};
use limbo::runtime::{find_artifact_dir, RtClient, XlaGp};
use limbo::stat::{MetricsObserver, RunLogger};
use limbo::stop::MaxIterations;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // `--metrics` is a bare flag; pull it out before Config parsing
    // (which only accepts key=value pairs). `metrics=true` works too.
    let mut metrics = false;
    args.retain(|a| {
        if a == "--metrics" {
            metrics = true;
            false
        } else {
            true
        }
    });
    let Some(cmd) = args.first().map(String::as_str) else {
        usage();
        return;
    };
    let cfg = Config::from_args(&args[1..]).unwrap_or_else(|e| {
        eprintln!("bad arguments: {e}");
        std::process::exit(2);
    });
    let metrics = metrics || cfg.get_bool("metrics", false);
    let profile = if metrics {
        limbo::obs::set_enabled(true);
        Some((limbo::obs::snapshot(), std::time::Instant::now()))
    } else {
        None
    };
    match cmd {
        "run" => cmd_run(&cfg, metrics),
        "fig1" => cmd_fig1(&cfg),
        "serve" => cmd_serve(&cfg),
        "info" => cmd_info(),
        _ => usage(),
    }
    if let Some((base, start)) = profile {
        let delta = limbo::obs::snapshot().delta_since(&base);
        eprintln!("\n{}", delta.render_table(Some(start.elapsed().as_secs_f64())));
    }
}

fn usage() {
    eprintln!(
        "usage: limbo <run|fig1|serve|info> [key=value ...]\n\
         \n\
         run    function=branin dim=2 iterations=40 init=10 hpo=false \\\n\
         \x20      backend=native|xla seed=1 out=/tmp/run --metrics\n\
         fig1   replicates=30 iterations=40 functions=branin,sphere hpo=both\n\
         serve  dim=2 seed=1    (stdin protocol: ask / tell <y> / best / quit)\n\
         info"
    );
}

fn cmd_run(cfg: &Config, metrics: bool) {
    let name = cfg.get_str("function", "branin");
    let dim = cfg.get_usize("dim", 2);
    let Some(f) = benchfns::by_name(name, dim) else {
        eprintln!("unknown function {name:?}");
        std::process::exit(2);
    };
    let dim = f.dim();
    let iterations = cfg.get_usize("iterations", 40);
    let n_init = cfg.get_usize("init", 10);
    let seed = cfg.get_usize("seed", 1) as u64;
    let hpo = cfg.get_bool("hpo", false);
    let backend = cfg.get_str("backend", "native");

    let eval = FnEval::new(dim, |x: &[f64]| f.eval(x));
    let refit = if hpo { RefitSchedule::Every(5) } else { RefitSchedule::Never };
    let best = match backend {
        "xla" => {
            let dir = find_artifact_dir().expect("artifacts/ not found; run `make artifacts`");
            let client = Arc::new(RtClient::cpu().expect("PJRT client"));
            let gp = Arc::new(XlaGp::new(client, &dir, "matern52").expect("XlaGp"));
            let model = XlaGpModel::new(gp, dim);
            // the XLA adapter is composed explicitly; BoDef builds the
            // native GP surrogates
            let mut opt = BOptimizer::new(
                model,
                Ei::default(),
                Lhs { n: n_init },
                Direct::new(500),
                MaxIterations(iterations),
                seed,
            )
            .with_refit(refit);
            if let Some(dir) = cfg.get("out") {
                let dir = std::path::Path::new(dir);
                opt = opt.with_observer(RunLogger::create(dir).unwrap());
                if metrics {
                    // after RunLogger: its `finish` truncates meta.dat,
                    // the phase breakdown must append second
                    opt = opt.with_observer(MetricsObserver::create(dir).unwrap());
                }
            }
            opt.optimize(&eval)
        }
        _ => {
            let mut def = BoDef::new(dim)
                .noise(1e-2)
                .acquisition(Ei::default())
                .init(Lhs { n: n_init })
                .inner_opt(Direct::new(500))
                .stop(MaxIterations(iterations))
                .refit(refit)
                .seed(seed);
            if let Some(dir) = cfg.get("out") {
                let dir = std::path::Path::new(dir);
                def = def.observer(RunLogger::create(dir).unwrap());
                if metrics {
                    def = def.observer(MetricsObserver::create(dir).unwrap());
                }
            }
            def.build_optimizer().optimize(&eval)
        }
    };
    println!(
        "{name} ({dim}-D, backend={backend}, hpo={hpo}): best={:.6} accuracy={:.3e} evals={} x={:?}",
        best.value,
        f.accuracy(best.value),
        best.evaluations,
        best.x
    );
}

fn cmd_fig1(cfg: &Config) {
    let replicates = cfg.get_usize("replicates", 30);
    let iterations = cfg.get_usize("iterations", 40);
    let hpo_mode = cfg.get_str("hpo", "both");
    let runner = ExperimentRunner {
        replicates,
        threads: cfg.get_usize(
            "threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        ),
        base_seed: cfg.get_usize("seed", 1000) as u64,
    };
    let functions: Vec<Box<dyn benchfns::TestFunction>> = match cfg.get("functions") {
        Some(names) => names
            .split(',')
            .map(|n| benchfns::by_name(n.trim(), 2).unwrap_or_else(|| panic!("unknown fn {n}")))
            .collect(),
        None => benchfns::figure1_suite(),
    };
    let base = Fig1Settings { iterations, ..Default::default() };
    let mut rows = Vec::new();
    if hpo_mode == "both" || hpo_mode == "false" {
        let limbo = LimboConfig::new(base);
        let bayesopt = BaselineConfig::new(base);
        rows.extend(runner.run_grid(&functions, &[&limbo, &bayesopt]));
    }
    if hpo_mode == "both" || hpo_mode == "true" {
        let limbo = LimboConfig::new(base.with_hpo());
        let bayesopt = BaselineConfig::new(base.with_hpo());
        rows.extend(runner.run_grid(&functions, &[&limbo, &bayesopt]));
    }
    print_table(&rows);
    println!("\nspeed-ups (median wall-clock, baseline / limbo):");
    for (f, ratio, dacc) in speedups(&rows, "limbo", "bayesopt")
        .into_iter()
        .chain(speedups(&rows, "limbo+hpo", "bayesopt+hpo"))
    {
        println!("  {f:<18} {ratio:>6.2}x   |Δ accuracy median| = {dacc:.2e}");
    }
}

fn cmd_serve(cfg: &Config) {
    let dim = cfg.get_usize("dim", 2);
    let seed = cfg.get_usize("seed", 1) as u64;
    let handle = BoDef::service(dim)
        .seed(seed)
        .inner_opt(RandomPoint::new(256).then(NelderMead::default()).restarts(4, 2))
        .spawn_server();
    eprintln!("ask/tell server on stdin (dim={dim}): ask | tell <y> | best | quit");
    let stdin = std::io::stdin();
    let mut last_x: Option<Vec<f64>> = None;
    let mut line = String::new();
    loop {
        line.clear();
        if stdin.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts.as_slice() {
            ["ask"] => {
                let x = handle.ask();
                println!("{}", x.iter().map(|v| format!("{v:.6}")).collect::<Vec<_>>().join(" "));
                last_x = Some(x);
            }
            ["tell", y] => match (last_x.take(), y.parse::<f64>()) {
                (Some(x), Ok(y)) => handle.tell(x, y),
                _ => eprintln!("tell requires a prior ask and a numeric value"),
            },
            ["best"] => match handle.best() {
                Some((x, v)) => println!("{v:.6} @ {x:?}"),
                None => println!("no data"),
            },
            ["quit"] | ["exit"] => break,
            _ => eprintln!("unknown command"),
        }
    }
}

fn cmd_info() {
    println!("limbo-rs {} — Limbo (Cully et al. 2016) reproduction", env!("CARGO_PKG_VERSION"));
    match find_artifact_dir() {
        Some(dir) => {
            let reg = limbo::runtime::Registry::load(&dir).expect("manifest");
            println!("artifacts: {} ({} entries)", dir.display(), reg.len());
            for (program, kind) in [
                ("predict", "se_ard"),
                ("predict", "matern52"),
                ("ucb", "matern52"),
                ("lml", "matern52"),
            ] {
                let tiers: Vec<usize> = reg.tiers(program, kind).iter().map(|m| m.n_max).collect();
                println!("  {program}/{kind}: tiers {tiers:?}");
            }
            match RtClient::cpu() {
                Ok(c) => println!("PJRT: platform={} ok", c.platform_name()),
                Err(e) => println!("PJRT: unavailable ({e})"),
            }
        }
        None => println!("artifacts: not built (run `make artifacts`)"),
    }
}
