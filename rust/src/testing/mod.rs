//! Mini property-testing driver (proptest is not available offline).
//!
//! [`check`] runs a property over `cases` randomized inputs drawn from a
//! seeded [`Pcg64`]; on failure it reports the case index and seed so the
//! exact input is reproducible. No shrinking — inputs are kept small by
//! construction instead.

use crate::rng::Pcg64;

/// Number of cases property tests run by default.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` over `cases` generated inputs. `gen` builds an input from the
/// per-case RNG; `prop` returns `Err(reason)` to fail.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Pcg64::seed(seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases} (seed {seed}): {reason}\ninput: {input:?}"
            );
        }
    }
}

/// Convenience: assert two floats are close with a relative-or-absolute tol.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol}, scale {scale})"))
    }
}

/// Convenience: assert all pairs in two slices are close.
pub fn all_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x, y, tol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("sum-commutes", 1, 32, |rng| (rng.next_f64(), rng.next_f64()), |&(a, b)| {
            close(a + b, b + a, 1e-15)
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_reports_failure() {
        check("always-fails", 2, 4, |rng| rng.next_f64(), |_| Err("nope".into()));
    }

    #[test]
    fn close_and_all_close() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 2.0, 1e-9).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-12).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-12).is_err());
    }
}
