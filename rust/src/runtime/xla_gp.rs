//! `XlaGp`: the XLA-artifact GP backend.
//!
//! Wraps the tiered `predict` / `ucb` / `lml` artifacts for one kernel kind
//! and presents padded, batched execution over live (growing) datasets:
//!
//! * training data is padded to the smallest capacity tier `n_max >= n`
//!   with a 0/1 mask (exact — see DESIGN.md "Static shapes"),
//! * features are padded to `d_max` zero columns,
//! * candidate batches are padded to `b` rows (extra rows are discarded).
//!
//! Executables are compiled lazily per tier and cached, so a BO run only
//! pays compilation for the tiers it actually grows through.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::client::{literal_f32, Executable, RtClient};
use super::registry::{ArtifactMeta, Registry};

/// Tiered, lazily-compiled XLA GP backend for one kernel kind.
pub struct XlaGp {
    client: Arc<RtClient>,
    registry: Arc<Registry>,
    kind: String,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl XlaGp {
    /// Create a backend for `kind` ("se_ard" or "matern52") over the
    /// artifacts in `dir`.
    pub fn new(client: Arc<RtClient>, dir: &Path, kind: &str) -> Result<Self> {
        let registry = Arc::new(Registry::load(dir)?);
        Self::with_registry(client, registry, kind)
    }

    /// Create a backend over an already-loaded registry.
    pub fn with_registry(
        client: Arc<RtClient>,
        registry: Arc<Registry>,
        kind: &str,
    ) -> Result<Self> {
        if registry.tiers("predict", kind).is_empty() {
            bail!("no predict artifacts for kernel kind {kind:?}");
        }
        Ok(Self { client, registry, kind: kind.to_string(), cache: Mutex::new(HashMap::new()) })
    }

    /// Largest usable dataset size (capacity of the biggest tier).
    pub fn max_points(&self) -> usize {
        self.registry.tiers("predict", &self.kind).last().map(|m| m.n_max).unwrap_or(0)
    }

    /// Candidate batch size the artifacts were compiled for.
    pub fn batch_size(&self) -> usize {
        self.registry.tiers("predict", &self.kind).first().map(|m| m.b).unwrap_or(0)
    }

    /// Padded feature dimension.
    pub fn d_max(&self) -> usize {
        self.registry.tiers("predict", &self.kind).first().map(|m| m.d_max).unwrap_or(0)
    }

    /// Hyper-parameter vector length (d_max + 2).
    pub fn hp_dim(&self) -> usize {
        self.registry.tiers("predict", &self.kind).first().map(|m| m.hp_dim).unwrap_or(0)
    }

    fn executable(&self, meta: &ArtifactMeta) -> Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&meta.name) {
            return Ok(exe.clone());
        }
        let exe = Arc::new(self.client.load_hlo_text(&meta.path)?);
        cache.insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    fn tier(&self, program: &str, n: usize) -> Result<&ArtifactMeta> {
        self.registry.tier_for(program, &self.kind, n).with_context(|| {
            format!("dataset of {n} points exceeds all {program}/{} tiers", self.kind)
        })
    }

    /// Pad `(x, y)` (row-major `x`, `d` features) into tier-shaped literals.
    fn padded_data(
        &self,
        meta: &ArtifactMeta,
        x: &[f64],
        y: &[f64],
        d: usize,
    ) -> Result<[xla::Literal; 3]> {
        let n = y.len();
        assert_eq!(x.len(), n * d, "x must be n*d row-major");
        assert!(d <= meta.d_max, "dim {d} exceeds artifact d_max {}", meta.d_max);
        let mut xp = vec![0f32; meta.n_max * meta.d_max];
        for i in 0..n {
            for j in 0..d {
                xp[i * meta.d_max + j] = x[i * d + j] as f32;
            }
        }
        let mut yp = vec![0f32; meta.n_max];
        let mut mp = vec![0f32; meta.n_max];
        for i in 0..n {
            yp[i] = y[i] as f32;
            mp[i] = 1.0;
        }
        Ok([
            literal_f32(&xp, &[meta.n_max as i64, meta.d_max as i64])?,
            literal_f32(&yp, &[meta.n_max as i64])?,
            literal_f32(&mp, &[meta.n_max as i64])?,
        ])
    }

    /// Pad a candidate block (`<= b` rows) into a `[b, d_max]` literal.
    fn padded_cands(&self, meta: &ArtifactMeta, xs: &[f64], d: usize) -> Result<xla::Literal> {
        let rows = xs.len() / d;
        assert!(rows <= meta.b, "candidate block {rows} exceeds batch {}", meta.b);
        let mut cp = vec![0f32; meta.b * meta.d_max];
        for i in 0..rows {
            for j in 0..d {
                cp[i * meta.d_max + j] = xs[i * d + j] as f32;
            }
        }
        literal_f32(&cp, &[meta.b as i64, meta.d_max as i64])
    }

    fn padded_hp(&self, meta: &ArtifactMeta, loghp: &[f64], d: usize) -> Result<xla::Literal> {
        // loghp comes in as [log l_1..log l_d, log sigma_f, log sigma_n];
        // pad the lengthscale block out to d_max (padded dims are zero
        // features, so their lengthscale value is irrelevant; use 0.0).
        assert_eq!(loghp.len(), d + 2);
        let mut hp = vec![0f32; meta.hp_dim];
        for j in 0..d {
            hp[j] = loghp[j] as f32;
        }
        hp[meta.hp_dim - 2] = loghp[d] as f32;
        hp[meta.hp_dim - 1] = loghp[d + 1] as f32;
        literal_f32(&hp, &[meta.hp_dim as i64])
    }

    /// Posterior mean/variance for up to `b` candidates.
    ///
    /// `x`: row-major `[n, d]`, `y`: `[n]`, `xs`: row-major `[rows, d]`
    /// with `rows <= b`. Returns `(mu, var)` truncated to `rows`.
    pub fn predict(
        &self,
        x: &[f64],
        y: &[f64],
        d: usize,
        xs: &[f64],
        loghp: &[f64],
        mean0: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let meta = self.tier("predict", y.len())?;
        let exe = self.executable(meta)?;
        let [xl, yl, ml] = self.padded_data(meta, x, y, d)?;
        let args = [
            xl,
            yl,
            ml,
            self.padded_cands(meta, xs, d)?,
            self.padded_hp(meta, loghp, d)?,
            literal_f32(&[mean0 as f32], &[1])?,
        ];
        let out = exe.run_f32(&args)?;
        let rows = xs.len() / d;
        let mu = out[0][..rows].iter().map(|&v| v as f64).collect();
        let var = out[1][..rows].iter().map(|&v| v as f64).collect();
        Ok((mu, var))
    }

    /// Fused UCB acquisition `mu + alpha * sqrt(var)` for up to `b` candidates.
    pub fn ucb(
        &self,
        x: &[f64],
        y: &[f64],
        d: usize,
        xs: &[f64],
        loghp: &[f64],
        mean0: f64,
        alpha: f64,
    ) -> Result<Vec<f64>> {
        let meta = self.tier("ucb", y.len())?;
        let exe = self.executable(meta)?;
        let [xl, yl, ml] = self.padded_data(meta, x, y, d)?;
        let args = [
            xl,
            yl,
            ml,
            self.padded_cands(meta, xs, d)?,
            self.padded_hp(meta, loghp, d)?,
            literal_f32(&[mean0 as f32], &[1])?,
            literal_f32(&[alpha as f32], &[1])?,
        ];
        let out = exe.run_f32(&args)?;
        let rows = xs.len() / d;
        Ok(out[0][..rows].iter().map(|&v| v as f64).collect())
    }

    /// Log marginal likelihood + gradient w.r.t. `loghp` (length `d + 2`:
    /// the padded lengthscale gradient entries are dropped).
    pub fn lml_grad(
        &self,
        x: &[f64],
        y: &[f64],
        d: usize,
        loghp: &[f64],
        mean0: f64,
    ) -> Result<(f64, Vec<f64>)> {
        let meta = self.tier("lml", y.len())?;
        let exe = self.executable(meta)?;
        let [xl, yl, ml] = self.padded_data(meta, x, y, d)?;
        let args = [
            xl,
            yl,
            ml,
            self.padded_hp(meta, loghp, d)?,
            literal_f32(&[mean0 as f32], &[1])?,
        ];
        let out = exe.run_f32(&args)?;
        let lml = out[0][0] as f64;
        let mut grad = Vec::with_capacity(d + 2);
        for j in 0..d {
            grad.push(out[1][j] as f64);
        }
        grad.push(out[1][meta.hp_dim - 2] as f64);
        grad.push(out[1][meta.hp_dim - 1] as f64);
        Ok((lml, grad))
    }
}
