//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! The Rust hot path never touches Python: `make artifacts` lowers the L2
//! JAX graphs once to HLO *text* (see `python/compile/aot.py` for why text,
//! not serialized protos), and this module loads + compiles + executes them.

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// Global serialization lock for every call into the `xla` crate.
///
/// SAFETY rationale for the `unsafe impl Send/Sync` below: the crate's
/// wrappers hold `Rc` handles and raw PJRT pointers, so they are not
/// thread-safe by construction. We never hand those handles out; every
/// entry point in this module takes `XLA_LOCK` for the full duration of
/// the FFI call (compile/execute/transfer), so no two threads ever touch
/// the non-atomic refcounts or the PJRT objects concurrently. The PJRT
/// CPU runtime itself is re-entrant, but we do not rely on that.
static XLA_LOCK: Mutex<()> = Mutex::new(());

/// A PJRT client plus compilation helpers. One per process is plenty; it is
/// cheap to share behind an `Arc`. All calls are serialized on a global
/// lock (see [`XLA_LOCK`]).
pub struct RtClient {
    client: xla::PjRtClient,
}

// SAFETY: see XLA_LOCK — all access to the inner Rc-based handle is
// serialized by the module's global mutex.
unsafe impl Send for RtClient {}
unsafe impl Sync for RtClient {}

impl RtClient {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let _g = XLA_LOCK.lock().unwrap();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform name as reported by PJRT (e.g. "Host").
    pub fn platform_name(&self) -> String {
        let _g = XLA_LOCK.lock().unwrap();
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let _g = XLA_LOCK.lock().unwrap();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled artifact. All L2 programs return a tuple (lowered with
/// `return_tuple=True`), so the result is always decomposed into parts.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: see XLA_LOCK — execution is fully serialized.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with f32 literals and return the tuple elements as f32 vecs.
    pub fn run_f32(&self, args: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let _g = XLA_LOCK.lock().unwrap();
        let result = self.exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        parts
            .iter()
            .map(|p| p.to_vec::<f32>().context("result element to f32 vec"))
            .collect()
    }
}

/// Build an f32 literal of the given logical shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 && dims[0] as usize == data.len() {
        return Ok(lit);
    }
    Ok(lit.reshape(dims)?)
}
