//! XLA/PJRT runtime: loads the AOT-compiled L2 GP graphs and serves them to
//! the coordinator as a drop-in [`crate::model::Model`] backend.
//!
//! Pipeline: `python/compile/aot.py` (build time, once) emits
//! `artifacts/*.hlo.txt` + `manifest.txt`; [`registry::Registry`] indexes
//! them; [`client::RtClient`] compiles them on the PJRT CPU client;
//! [`xla_gp::XlaGp`] pads live datasets into capacity tiers and executes.

pub mod client;
pub mod registry;
pub mod xla_gp;

pub use client::{literal_f32, Executable, RtClient};
pub use registry::{ArtifactMeta, Registry};
pub use xla_gp::XlaGp;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$LIMBO_ARTIFACTS` if set, else walk up
/// from the current directory looking for `artifacts/manifest.txt`.
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("LIMBO_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
