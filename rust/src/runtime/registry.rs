//! Artifact registry: parses `artifacts/manifest.txt` written by
//! `python/compile/aot.py` and resolves (program, kernel, capacity) lookups.
//!
//! Manifest line format (space separated):
//! `name program kind n_max d_max b hp_dim path`

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata for one AOT-compiled HLO artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Unique artifact name, e.g. `predict_se_ard_n32`.
    pub name: String,
    /// Program kind: `predict`, `ucb` or `lml`.
    pub program: String,
    /// GP kernel kind: `se_ard` or `matern52`.
    pub kind: String,
    /// Capacity tier (max training points, padded).
    pub n_max: usize,
    /// Padded feature dimension (D_MAX).
    pub d_max: usize,
    /// Candidate batch size (B).
    pub b: usize,
    /// Hyper-parameter vector length (D_MAX + 2).
    pub hp_dim: usize,
    /// Path to the HLO text file (absolute after load).
    pub path: PathBuf,
}

/// All artifacts found in a directory, indexed by (program, kind).
#[derive(Debug, Default)]
pub struct Registry {
    by_key: HashMap<(String, String), Vec<ArtifactMeta>>,
}

impl Registry {
    /// Parse `<dir>/manifest.txt`. Tier lists are sorted ascending.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut reg = Registry::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 8 {
                bail!("manifest line {}: expected 8 fields, got {}", lineno + 1, f.len());
            }
            let meta = ArtifactMeta {
                name: f[0].to_string(),
                program: f[1].to_string(),
                kind: f[2].to_string(),
                n_max: f[3].parse().context("n_max")?,
                d_max: f[4].parse().context("d_max")?,
                b: f[5].parse().context("b")?,
                hp_dim: f[6].parse().context("hp_dim")?,
                path: dir.join(f[7]),
            };
            reg.by_key
                .entry((meta.program.clone(), meta.kind.clone()))
                .or_default()
                .push(meta);
        }
        for tiers in reg.by_key.values_mut() {
            tiers.sort_by_key(|m| m.n_max);
        }
        Ok(reg)
    }

    /// All tiers for a (program, kind), ascending by capacity.
    pub fn tiers(&self, program: &str, kind: &str) -> &[ArtifactMeta] {
        self.by_key
            .get(&(program.to_string(), kind.to_string()))
            .map(Vec::as_slice)
            .unwrap_or(&[])
    }

    /// Smallest tier with capacity >= `n` (None if `n` exceeds all tiers).
    pub fn tier_for(&self, program: &str, kind: &str, n: usize) -> Option<&ArtifactMeta> {
        self.tiers(program, kind).iter().find(|m| m.n_max >= n)
    }

    /// Number of artifacts in the registry.
    pub fn len(&self) -> usize {
        self.by_key.values().map(Vec::len).sum()
    }

    /// True when no artifacts were found.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_and_sorts_tiers() {
        let dir = std::env::temp_dir().join("limbo_registry_test1");
        write_manifest(
            &dir,
            "predict_se_ard_n64 predict se_ard 64 8 64 10 b.hlo.txt\n\
             predict_se_ard_n32 predict se_ard 32 8 64 10 a.hlo.txt\n",
        );
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.len(), 2);
        let tiers = reg.tiers("predict", "se_ard");
        assert_eq!(tiers[0].n_max, 32);
        assert_eq!(tiers[1].n_max, 64);
        assert_eq!(reg.tier_for("predict", "se_ard", 33).unwrap().n_max, 64);
        assert_eq!(reg.tier_for("predict", "se_ard", 32).unwrap().n_max, 32);
        assert!(reg.tier_for("predict", "se_ard", 65).is_none());
        assert!(reg.tier_for("ucb", "se_ard", 1).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("limbo_registry_test2");
        write_manifest(&dir, "only three fields\n");
        assert!(Registry::load(&dir).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("limbo_registry_test3");
        write_manifest(
            &dir,
            "# comment\n\nucb_se_ard_n32 ucb se_ard 32 8 64 10 u.hlo.txt\n",
        );
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.len(), 1);
    }
}
