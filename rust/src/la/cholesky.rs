//! Cholesky factorization with incremental rank extension.
//!
//! A BO run adds one sample per iteration; refactoring the full `n x n`
//! Gram matrix each time costs O(n^3). [`CholeskyFactor::extend`] appends
//! one (or more) rows/columns to an existing factor in O(n^2) — the trick
//! Limbo's GP uses to stay fast on embedded hardware, and the main L3
//! hot-path optimization of the native GP here.

use crate::la::{dot, Matrix};
use crate::obs::{self, Phase};

/// Column-block width of [`CholeskyFactor::solve_lower_multi`] (a block of
/// RHS columns plus one factor row stay cache-resident while `L` streams).
const SOLVE_COL_BLOCK: usize = 64;

/// Lower-triangular Cholesky factor `L` of an SPD matrix `A = L L^T`.
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Matrix,
}

/// Error returned when a matrix is not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Index of the failing pivot.
    pub pivot: usize,
    /// Value of the failing pivot (<= 0).
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite at pivot {} (value {:.3e})", self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl CholeskyFactor {
    /// Factor a full SPD matrix (O(n^3)).
    ///
    /// Large matrices take the blocked right-looking path: per
    /// [`Tune::block`](crate::la::Tune)-wide panel, a scalar diagonal
    /// factor, a row-parallel triangular panel solve, and a SYRK-style
    /// trailing downdate distributed over disjoint row panels — the
    /// trailing update (where ~all the flops are) streams the finished
    /// panel instead of re-reading whole factor rows, and the parallel
    /// splits never change any element's arithmetic, so results are
    /// thread-count-invariant. Matrices below `Tune::small` (or no
    /// wider than one block) use [`factor_unblocked`](Self::factor_unblocked).
    /// The two paths order the pivot summations differently; parity is
    /// pinned at ≤1e-12 by `tests/blocked_la.rs`.
    pub fn factor(a: &Matrix) -> Result<Self, NotPositiveDefinite> {
        let _span = obs::span(Phase::CholFactor);
        assert_eq!(a.rows(), a.cols(), "cholesky: matrix must be square");
        let n = a.rows();
        let t = crate::la::tune();
        if n < t.small || n <= t.block {
            return Self::factor_unblocked(a);
        }
        let nb = t.block.max(4);
        let mut l = a.clone();
        let mut k0 = 0;
        while k0 < n {
            let k1 = (k0 + nb).min(n);
            let w = k1 - k0;
            // 1) scalar factor of the diagonal block, in place. Earlier
            //    panels' contributions were already subtracted by their
            //    trailing downdates, so the recurrence only spans the
            //    block's own columns [k0, j).
            for i in k0..k1 {
                for j in k0..=i {
                    let s = l[(i, j)] - dot(&l.row(i)[k0..j], &l.row(j)[k0..j]);
                    if i == j {
                        if s <= 0.0 || !s.is_finite() {
                            return Err(NotPositiveDefinite { pivot: i, value: s });
                        }
                        l[(i, j)] = s.sqrt();
                    } else {
                        l[(i, j)] = s / l[(j, j)];
                    }
                }
            }
            if k1 == n {
                break;
            }
            let below = n - k1;
            // snapshot the finished w x w diagonal block so the panel
            // tasks can read it while writing their own rows of `l`
            let mut diag = vec![0.0; w * w];
            for (bi, drow) in diag.chunks_mut(w).enumerate() {
                drow.copy_from_slice(&l.row(k0 + bi)[k0..k1]);
            }
            let rows_per = below.div_ceil(t.threads.max(1));
            // 2) panel solve: L21 L11^T = A21, each task owns disjoint
            //    rows of the panel
            {
                let tail = &mut l.data_mut()[k1 * n..];
                let tasks: Vec<&mut [f64]> = tail.chunks_mut(rows_per * n).collect();
                crate::pool::parallel_map_hinted(
                    tasks,
                    t.threads,
                    below * w * w,
                    t.par_min_flops,
                    |_, chunk| {
                        for row in chunk.chunks_mut(n) {
                            for j in 0..w {
                                let dj = &diag[j * w..j * w + j];
                                let s = row[k0 + j] - dot(&row[k0..k0 + j], dj);
                                row[k0 + j] = s / diag[j * w + j];
                            }
                        }
                    },
                );
            }
            // snapshot the solved panel for the same aliasing reason
            let mut panel = vec![0.0; below * w];
            for (pi, prow) in panel.chunks_mut(w).enumerate() {
                prow.copy_from_slice(&l.row(k1 + pi)[k0..k1]);
            }
            // 3) trailing downdate A22 -= L21 L21^T (lower triangle only),
            //    one dot per touched element, disjoint row panels
            {
                let tail = &mut l.data_mut()[k1 * n..];
                let tasks: Vec<&mut [f64]> = tail.chunks_mut(rows_per * n).collect();
                crate::pool::parallel_map_hinted(
                    tasks,
                    t.threads,
                    below * below * w,
                    t.par_min_flops,
                    |ci, chunk| {
                        let base = ci * rows_per;
                        for (di, row) in chunk.chunks_mut(n).enumerate() {
                            let pr = base + di; // panel-relative row index
                            let pi = &panel[pr * w..(pr + 1) * w];
                            for j in k1..=(k1 + pr) {
                                let pj = &panel[(j - k1) * w..(j - k1 + 1) * w];
                                row[j] -= dot(pi, pj);
                            }
                        }
                    },
                );
            }
            k0 = k1;
        }
        // the working copy started from full A: zero the upper triangle
        for i in 0..n {
            for v in &mut l.row_mut(i)[i + 1..] {
                *v = 0.0;
            }
        }
        Ok(Self { l })
    }

    /// Scalar reference factorization (standard left-looking algorithm).
    /// Small matrices dispatch here from [`factor`](Self::factor); it is
    /// public as the reference implementation the blocked-vs-naive
    /// property tests compare against.
    pub fn factor_unblocked(a: &Matrix) -> Result<Self, NotPositiveDefinite> {
        assert_eq!(a.rows(), a.cols(), "cholesky: matrix must be square");
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = A[i,j] - sum_{k<j} L[i,k] L[j,k]
                let s = a[(i, j)] - dot(&l.row(i)[..j], &l.row(j)[..j]);
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(NotPositiveDefinite { pivot: i, value: s });
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Self { l })
    }

    /// Empty factor (0 x 0), ready for incremental [`extend`](Self::extend).
    pub fn empty() -> Self {
        Self { l: Matrix::zeros(0, 0) }
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// The factor `L` (lower triangular).
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Extend the factor of `A` to the factor of `[[A, b], [b^T, c]]`,
    /// where `b` is the cross-covariance column (`len == dim()`) and `c`
    /// the new diagonal entry. O(n^2).
    ///
    /// Solves `L w = b` (forward substitution), then the new diagonal is
    /// `sqrt(c - |w|^2)`.
    pub fn extend(&mut self, b: &[f64], c: f64) -> Result<(), NotPositiveDefinite> {
        let _span = obs::span(Phase::CholFactor);
        let n = self.dim();
        assert_eq!(b.len(), n, "extend: column length mismatch");
        let w = self.solve_lower(b);
        let d = c - dot(&w, &w);
        if d <= 0.0 || !d.is_finite() {
            return Err(NotPositiveDefinite { pivot: n, value: d });
        }
        // grow the matrix by one row/col
        let mut l = Matrix::zeros(n + 1, n + 1);
        for i in 0..n {
            l.row_mut(i)[..=i].copy_from_slice(&self.l.row(i)[..=i]);
        }
        l.row_mut(n)[..n].copy_from_slice(&w);
        l[(n, n)] = d.sqrt();
        self.l = l;
        Ok(())
    }

    /// Lower-triangular product `y = L z` — the sample path of a
    /// correlated Gaussian draw: with `L L^T = Σ` (factor `Σ` through
    /// [`crate::la::spd_factor_jittered`] when it is a posterior
    /// covariance that may be numerically semi-definite) and
    /// `z ~ N(0, I)`, `μ + L z ~ N(μ, Σ)`. Hot path of the Monte-Carlo
    /// qEI estimator, which reuses one factor across all its common
    /// random numbers.
    pub fn mul_lower(&self, z: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim()];
        self.mul_lower_into(z, &mut y);
        y
    }

    /// [`mul_lower`](Self::mul_lower) into a caller-provided buffer
    /// (allocation-free variant for per-sample loops).
    pub fn mul_lower_into(&self, z: &[f64], y: &mut [f64]) {
        let n = self.dim();
        assert_eq!(z.len(), n);
        assert_eq!(y.len(), n);
        for i in 0..n {
            y[i] = dot(&self.l.row(i)[..=i], &z[..=i]);
        }
    }

    /// Solve `L x = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.dim()];
        self.solve_lower_into(b, &mut x);
        x
    }

    /// Solve `L x = b` into a caller-provided buffer (hot-path variant:
    /// the GP's predict loop reuses scratch instead of allocating).
    pub fn solve_lower_into(&self, b: &[f64], x: &mut [f64]) {
        let n = self.dim();
        assert_eq!(b.len(), n);
        assert_eq!(x.len(), n);
        for i in 0..n {
            let s = b[i] - dot(&self.l.row(i)[..i], &x[..i]);
            x[i] = s / self.l[(i, i)];
        }
    }

    /// Solve `L X = B` for a block of right-hand sides (B is `n x m`,
    /// one RHS per column). Column-blocked forward substitution: each
    /// factor row `L[i, ..i]` is streamed once per column block instead of
    /// once per RHS, so solving m right-hand sides costs one pass over `L`
    /// per block of [`SOLVE_COL_BLOCK`] columns — the hot kernel of the
    /// batched GP posterior (`predict_batch`).
    /// Independent column blocks additionally fan out over scoped
    /// threads: each task solves its block into a local dense panel
    /// (column stripes of the row-major output are not contiguous) with
    /// fixed per-column arithmetic, then the panels are scattered back
    /// sequentially — results are bit-identical for any thread count
    /// (and agree with per-column [`solve_lower`](Self::solve_lower) to
    /// `<= 1e-12`; the unrolled `dot` reduction orders differ).
    pub fn solve_lower_multi(&self, b: &Matrix) -> Matrix {
        let _span = obs::span(Phase::CholSolve);
        let n = self.dim();
        assert_eq!(b.rows(), n, "solve_lower_multi: RHS row mismatch");
        let m = b.cols();
        let mut x = Matrix::zeros(n, m);
        if n == 0 || m == 0 {
            return x;
        }
        let t = crate::la::tune();
        let flops = n.saturating_mul(n).saturating_mul(m) / 2;
        let blocks: Vec<usize> = (0..m.div_ceil(SOLVE_COL_BLOCK)).collect();
        let panels =
            crate::pool::parallel_map_hinted(blocks, t.threads, flops, t.par_min_flops, |_, bi| {
                let c0 = bi * SOLVE_COL_BLOCK;
                self.solve_lower_panel(b, c0, (c0 + SOLVE_COL_BLOCK).min(m))
            });
        scatter_panels(&mut x, &panels);
        x
    }

    /// One column block of the blocked forward substitution, solved into
    /// a local dense `n x (c1-c0)` panel.
    fn solve_lower_panel(&self, b: &Matrix, c0: usize, c1: usize) -> Vec<f64> {
        let n = self.dim();
        let bw = c1 - c0;
        let mut data = vec![0.0; n * bw];
        for i in 0..n {
            let lrow = self.l.row(i);
            // split the flat storage so row i is writable while rows
            // k < i stay readable (forward substitution dependency)
            let (prev, cur) = data.split_at_mut(i * bw);
            let xi = &mut cur[..bw];
            xi.copy_from_slice(&b.row(i)[c0..c1]);
            for (k, &lik) in lrow[..i].iter().enumerate() {
                if lik == 0.0 {
                    continue;
                }
                let xk = &prev[k * bw..(k + 1) * bw];
                for (o, &v) in xi.iter_mut().zip(xk) {
                    *o -= lik * v;
                }
            }
            let inv = 1.0 / lrow[i];
            for o in xi.iter_mut() {
                *o *= inv;
            }
        }
        data
    }

    /// Solve `L^T X = B` for a block of right-hand sides (column-blocked
    /// backward substitution, mirroring
    /// [`solve_lower_multi`](Self::solve_lower_multi)): row `i` of the
    /// result needs rows `k > i`, so the sweep runs bottom-up with the
    /// factor accessed by columns (`L^T[i, k] = L[k, i]`).
    /// Column blocks are independent and fan out over scoped threads
    /// into local panels, exactly like
    /// [`solve_lower_multi`](Self::solve_lower_multi) (same determinism
    /// contract: thread-count-invariant, per-column parity `<= 1e-12`).
    pub fn solve_lower_t_multi(&self, b: &Matrix) -> Matrix {
        let _span = obs::span(Phase::CholSolve);
        let n = self.dim();
        assert_eq!(b.rows(), n, "solve_lower_t_multi: RHS row mismatch");
        let m = b.cols();
        let mut x = Matrix::zeros(n, m);
        if n == 0 || m == 0 {
            return x;
        }
        let t = crate::la::tune();
        let flops = n.saturating_mul(n).saturating_mul(m) / 2;
        let blocks: Vec<usize> = (0..m.div_ceil(SOLVE_COL_BLOCK)).collect();
        let panels =
            crate::pool::parallel_map_hinted(blocks, t.threads, flops, t.par_min_flops, |_, bi| {
                let c0 = bi * SOLVE_COL_BLOCK;
                self.solve_lower_t_panel(b, c0, (c0 + SOLVE_COL_BLOCK).min(m))
            });
        scatter_panels(&mut x, &panels);
        x
    }

    /// One column block of the blocked backward substitution, solved
    /// into a local dense `n x (c1-c0)` panel.
    fn solve_lower_t_panel(&self, b: &Matrix, c0: usize, c1: usize) -> Vec<f64> {
        let n = self.dim();
        let bw = c1 - c0;
        let mut data = vec![0.0; n * bw];
        for i in (0..n).rev() {
            // split the flat storage so row i is writable while rows
            // k > i stay readable (backward substitution dependency)
            let (cur, next) = data.split_at_mut((i + 1) * bw);
            let xi = &mut cur[i * bw..];
            xi.copy_from_slice(&b.row(i)[c0..c1]);
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let xk = &next[(k - i - 1) * bw..(k - i) * bw];
                for (o, &v) in xi.iter_mut().zip(xk) {
                    *o -= lki * v;
                }
            }
            let inv = 1.0 / self.l[(i, i)];
            for o in xi.iter_mut() {
                *o *= inv;
            }
        }
        data
    }

    /// Solve `A X = B` for a block of right-hand sides via the two
    /// triangular multi-solves — the Woodbury-factor workhorse of the
    /// FITC marginal-likelihood gradient (`A^{-1} K_mn`, `K_mm^{-1} K_mn`).
    pub fn solve_multi(&self, b: &Matrix) -> Matrix {
        self.solve_lower_t_multi(&self.solve_lower_multi(b))
    }

    /// Solve `L^T x = b` (backward substitution).
    pub fn solve_lower_t(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.dim()];
        self.solve_lower_t_into(b, &mut x);
        x
    }

    /// Solve `L^T x = b` into a caller-provided buffer (allocation-free
    /// sibling of [`solve_lower_into`](Self::solve_lower_into)).
    pub fn solve_lower_t_into(&self, b: &[f64], x: &mut [f64]) {
        let n = self.dim();
        assert_eq!(b.len(), n);
        assert_eq!(x.len(), n);
        x.copy_from_slice(b);
        self.solve_lower_t_in_place(x);
    }

    /// Backward substitution in place: row `i` only reads entries
    /// `x[j]` with `j > i`, which are already final.
    fn solve_lower_t_in_place(&self, x: &mut [f64]) {
        let n = self.dim();
        for i in (0..n).rev() {
            let mut s = x[i];
            // column access: L^T[i, j] = L[j, i] for j > i
            for j in (i + 1)..n {
                s -= self.l[(j, i)] * x[j];
            }
            x[i] = s / self.l[(i, i)];
        }
    }

    /// Solve `A x = b` via the two substitutions.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.dim()];
        self.solve_into(b, &mut x);
        x
    }

    /// Solve `A x = b` into a caller-provided buffer: forward
    /// substitution into `x`, then backward substitution in place — no
    /// intermediate vector (the scalar paths used to allocate one per
    /// solve; the GP's alpha recompute reuses its own buffer instead).
    pub fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        self.solve_lower_into(b, x);
        self.solve_lower_t_in_place(x);
    }

    /// `log det A = 2 * sum_i log L[i,i]`.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Full inverse `A^{-1} = L^{-T} L^{-1}`.
    ///
    /// Triangular inversion (O(n^3)/6 madds) followed by the symmetric
    /// product (upper triangle computed once, mirrored) — ~3x fewer flops
    /// than solving against `n` unit vectors. Used by the GP's LML
    /// gradient (`tr((alpha alpha^T - K^{-1}) dK/dtheta)`).
    pub fn inverse(&self) -> Matrix {
        let n = self.dim();
        // Linv: forward substitution per column j; rows < j are zero.
        let mut linv = Matrix::zeros(n, n);
        for j in 0..n {
            linv[(j, j)] = 1.0 / self.l[(j, j)];
            for i in (j + 1)..n {
                // x_i = -(sum_{k=j..i-1} L[i,k] x_k) / L[i,i]
                let mut s = 0.0;
                let lrow = self.l.row(i);
                for k in j..i {
                    s += lrow[k] * linv[(k, j)];
                }
                linv[(i, j)] = -s / self.l[(i, i)];
            }
        }
        // A^{-1}[i][j] = sum_{k >= max(i,j)} Linv[k,i] * Linv[k,j]
        let mut out = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let mut s = 0.0;
                for k in j..n {
                    s += linv[(k, i)] * linv[(k, j)];
                }
                out[(i, j)] = s;
                out[(j, i)] = s;
            }
        }
        out
    }

    /// Reconstruct `A = L L^T` (tests / diagnostics).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.dim();
        Matrix::from_fn(n, n, |i, j| {
            let k = i.min(j) + 1;
            dot(&self.l.row(i)[..k], &self.l.row(j)[..k])
        })
    }
}

/// Copy the per-block dense panels produced by the parallel multi-RHS
/// solves back into their column stripes of the row-major output.
fn scatter_panels(x: &mut Matrix, panels: &[Vec<f64>]) {
    let n = x.rows();
    let mut c0 = 0;
    for panel in panels {
        let bw = panel.len() / n;
        for (i, prow) in panel.chunks(bw).enumerate() {
            x.row_mut(i)[c0..c0 + bw].copy_from_slice(prow);
        }
        c0 += bw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Random SPD matrix A = B B^T + n*I.
    fn random_spd(n: usize, rng: &mut Pcg64) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.next_f64() * 2.0 - 1.0);
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Pcg64::seed(7);
        for n in [1, 2, 3, 8, 17, 33] {
            let a = random_spd(n, &mut rng);
            let ch = CholeskyFactor::factor(&a).unwrap();
            assert!(ch.reconstruct().max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Pcg64::seed(11);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let b = a.matvec(&x_true);
        let ch = CholeskyFactor::factor(&a).unwrap();
        let x = ch.solve(&b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn incremental_extend_matches_full_factor() {
        let mut rng = Pcg64::seed(13);
        let n = 20;
        let a = random_spd(n, &mut rng);
        let mut inc = CholeskyFactor::empty();
        for k in 0..n {
            let b: Vec<f64> = (0..k).map(|j| a[(k, j)]).collect();
            inc.extend(&b, a[(k, k)]).unwrap();
        }
        let full = CholeskyFactor::factor(&a).unwrap();
        assert!(inc.l().max_abs_diff(full.l()) < 1e-9);
    }

    #[test]
    fn multi_rhs_solve_matches_per_column() {
        let mut rng = Pcg64::seed(0xBA7C4);
        // spans sizes below, at, and above the column-block width
        for (n, m) in [(1usize, 1usize), (7, 3), (12, 64), (20, 130)] {
            let a = random_spd(n, &mut rng);
            let ch = CholeskyFactor::factor(&a).unwrap();
            let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
            let x = ch.solve_lower_multi(&b);
            assert_eq!((x.rows(), x.cols()), (n, m));
            for j in 0..m {
                let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
                let xj = ch.solve_lower(&col);
                for i in 0..n {
                    assert!(
                        (x[(i, j)] - xj[i]).abs() < 1e-12,
                        "n={n} m={m} entry ({i},{j}): {} vs {}",
                        x[(i, j)],
                        xj[i]
                    );
                }
            }
        }
    }

    #[test]
    fn full_multi_solve_matches_per_column() {
        let mut rng = Pcg64::seed(0xF17C);
        for (n, m) in [(1usize, 2usize), (6, 4), (13, 70)] {
            let a = random_spd(n, &mut rng);
            let ch = CholeskyFactor::factor(&a).unwrap();
            let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
            let x = ch.solve_multi(&b);
            assert_eq!((x.rows(), x.cols()), (n, m));
            for j in 0..m {
                let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
                let xj = ch.solve(&col);
                for i in 0..n {
                    assert!(
                        (x[(i, j)] - xj[i]).abs() < 1e-12,
                        "n={n} m={m} entry ({i},{j}): {} vs {}",
                        x[(i, j)],
                        xj[i]
                    );
                }
            }
        }
    }

    #[test]
    fn mul_lower_inverts_solve_lower() {
        let mut rng = Pcg64::seed(0x5A17);
        for n in [1usize, 4, 11] {
            let a = random_spd(n, &mut rng);
            let ch = CholeskyFactor::factor(&a).unwrap();
            let z: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
            // L (L^{-1} b) == b and L^{-1} (L z) == z
            let y = ch.mul_lower(&z);
            let back = ch.solve_lower(&y);
            for i in 0..n {
                assert!((back[i] - z[i]).abs() < 1e-9, "n={n} i={i}");
            }
            // correlated draws reconstruct the covariance: E[(Lz)(Lz)^T] = A
            // (deterministic check instead: L z against the explicit product)
            let explicit = ch.l().matvec(&z);
            for i in 0..n {
                assert!((y[i] - explicit[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_solves() {
        let mut rng = Pcg64::seed(0x1270);
        let n = 17;
        let a = random_spd(n, &mut rng);
        let ch = CholeskyFactor::factor(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let mut x = vec![0.0; n];
        ch.solve_lower_t_into(&b, &mut x);
        assert_eq!(x, ch.solve_lower_t(&b));
        ch.solve_into(&b, &mut x);
        assert_eq!(x, ch.solve(&b));
        // and the in-place two-phase solve really solves A x = b
        let back = a.matvec(&x);
        for i in 0..n {
            assert!((back[i] - b[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn rejects_non_spd() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(CholeskyFactor::factor(&a).is_err());
    }

    #[test]
    fn extend_rejects_dependent_column() {
        let mut ch = CholeskyFactor::factor(&Matrix::eye(2)).unwrap();
        // b makes the Schur complement zero: c - |w|^2 = 2 - 2 = 0
        let err = ch.extend(&[1.0, 1.0], 2.0).unwrap_err();
        assert_eq!(err.pivot, 2);
    }

    #[test]
    fn log_det_matches_known() {
        // diag(4, 9): det = 36, log det = ln 36
        let a = Matrix::from_rows(2, 2, &[4.0, 0.0, 0.0, 9.0]);
        let ch = CholeskyFactor::factor(&a).unwrap();
        assert!((ch.log_det() - 36.0f64.ln()).abs() < 1e-12);
    }
}
