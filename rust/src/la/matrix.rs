//! Row-major dense f64 matrix with the small operation set the GP stack
//! needs. Kept deliberately simple: contiguous storage, explicit loops,
//! no expression templates — the hot paths that matter are in
//! [`crate::la::cholesky`] and the kernel Gram computation.

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::obs::{self, Phase};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a flat row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_rows: bad length");
        Self { rows, cols, data: data.to_vec() }
    }

    /// Build by evaluating `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable flat row-major data.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix-vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec: dim mismatch");
        (0..self.rows).map(|i| crate::la::dot(self.row(i), x)).collect()
    }

    /// Transposed matrix-vector product `A^T x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t: dim mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += a * xi;
            }
        }
        out
    }

    /// Matrix product `A B`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_accum(other, &mut out);
        out
    }

    /// Gemm-style product `out = A B` into a caller-provided matrix, for
    /// callers forming repeated products that want to reuse the output
    /// allocation. `out` is overwritten and must already have shape
    /// `self.rows x other.cols`.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        out.data.fill(0.0);
        self.matmul_accum(other, out);
    }

    /// `out += A B` over an already-initialized accumulator (shared core
    /// of [`matmul`](Self::matmul) / [`matmul_into`](Self::matmul_into);
    /// `matmul` skips the redundant zero-fill on its fresh buffer).
    ///
    /// Large products take the cache-blocked path: disjoint row panels
    /// of `out` fan out over scoped threads and each panel runs the
    /// k-blocked 4-row register-tiled micro-kernel (`mm_panel`). Every
    /// output element still accumulates over `k` in ascending order into
    /// one accumulator, so the result is invariant to the thread count
    /// and block size — the knobs in [`crate::la::Tune`] are pure
    /// performance knobs here.
    fn matmul_accum(&self, other: &Matrix, out: &mut Matrix) {
        let _span = obs::span(Phase::MatMul);
        assert_eq!(self.cols, other.rows, "matmul: dim mismatch");
        assert_eq!(
            (out.rows, out.cols),
            (self.rows, other.cols),
            "matmul: output shape mismatch"
        );
        let (n, k, m) = (self.rows, self.cols, other.cols);
        if n == 0 || k == 0 || m == 0 {
            return;
        }
        let t = crate::la::tune();
        if n.min(k).min(m) < t.small {
            self.matmul_accum_naive(other, out);
            return;
        }
        let flops = 2usize.saturating_mul(n).saturating_mul(k).saturating_mul(m);
        let threads = t.threads_for(flops);
        let rows_per = n.div_ceil(threads);
        let kb = t.block.max(8);
        let tasks: Vec<&mut [f64]> = out.data.chunks_mut(rows_per * m).collect();
        crate::pool::parallel_map_hinted(tasks, threads, flops, t.par_min_flops, |ci, chunk| {
            let r0 = ci * rows_per;
            let rows = chunk.len() / m;
            mm_panel(&self.data[r0 * k..(r0 + rows) * k], &other.data, chunk, k, m, kb);
        });
    }

    /// Scalar reference for [`matmul_accum`](Self::matmul_accum) (ikj
    /// loop order: stream through `other` rows contiguously). Small
    /// products dispatch here; the blocked-vs-naive property tests pin
    /// the two paths against each other.
    fn matmul_accum_naive(&self, other: &Matrix, out: &mut Matrix) {
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
    }

    /// Squared Euclidean norm of every column: `out[j] = sum_i A[i,j]^2`.
    /// One streaming pass over the row-major data — the batched GP
    /// variance reduction (`sigma^2_j = k(x,x) - |V[:,j]|^2` after a
    /// multi-RHS triangular solve) uses this instead of B column walks.
    pub fn col_squared_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += v * v;
            }
        }
        out
    }

    /// Column Gram matrix `G = A^T A` (`cols x cols`, symmetric).
    ///
    /// One streaming pass over the row-major data, upper triangle
    /// accumulated and mirrored — the B×B posterior-covariance assembly
    /// of the joint batched GP posterior (`Model::predict_joint`), where
    /// the full `V^T V` block generalizes the per-column norms of
    /// [`col_squared_norms`](Self::col_squared_norms). The diagonal is
    /// accumulated in the same row order as `col_squared_norms`, so the
    /// joint covariance diagonal reproduces the batched variances exactly.
    /// Large Grams distribute disjoint row panels of `G` over scoped
    /// threads; each panel streams `A` once with the same r-ascending
    /// per-element accumulation as the scalar loop, so results (and the
    /// diagonal parity above) are bit-identical for any thread count.
    pub fn col_gram(&self) -> Matrix {
        let m = self.cols;
        let mut g = Matrix::zeros(m, m);
        if m == 0 {
            return g;
        }
        let t = crate::la::tune();
        let flops = self.rows.saturating_mul(m).saturating_mul(m);
        let threads = t.threads_for(flops).min(m);
        let rows_per = m.div_ceil(threads);
        {
            let tasks: Vec<&mut [f64]> = g.data.chunks_mut(rows_per * m).collect();
            crate::pool::parallel_map_hinted(tasks, threads, flops, t.par_min_flops, |ci, chunk| {
                let i0 = ci * rows_per;
                for r in 0..self.rows {
                    let row = self.row(r);
                    for (di, grow) in chunk.chunks_mut(m).enumerate() {
                        let i = i0 + di;
                        let vi = row[i];
                        if vi == 0.0 {
                            continue;
                        }
                        for (gij, &vj) in grow[i..].iter_mut().zip(&row[i..]) {
                            *gij += vi * vj;
                        }
                    }
                }
            });
        }
        for i in 0..m {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Max absolute difference to another matrix (for tests).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Is the matrix symmetric to tolerance `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

/// Row-panel micro-kernel of the blocked matmul: `out += A_panel * B`
/// with `A_panel` `rows x k` (`rows = out.len() / m`) and `B` `k x m`,
/// both row-major. `k` is walked in ascending `kb`-sized blocks so a
/// block of `B` rows stays cache-resident, and four output rows share
/// each streamed `B` row (register tile) — the inner `j` loop is
/// unit-stride multiply-add code the compiler autovectorizes. Every
/// output element accumulates over `k` in ascending order, identical to
/// the scalar reference.
fn mm_panel(a: &[f64], b: &[f64], out: &mut [f64], k: usize, m: usize, kb: usize) {
    let rows = out.len() / m;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + kb).min(k);
        let mut i = 0;
        while i + 4 <= rows {
            let (o01, o23) = out[i * m..(i + 4) * m].split_at_mut(2 * m);
            let (o0, o1) = o01.split_at_mut(m);
            let (o2, o3) = o23.split_at_mut(m);
            for kk in k0..k1 {
                let brow = &b[kk * m..(kk + 1) * m];
                let a0 = a[i * k + kk];
                let a1 = a[(i + 1) * k + kk];
                let a2 = a[(i + 2) * k + kk];
                let a3 = a[(i + 3) * k + kk];
                for j in 0..m {
                    let bv = brow[j];
                    o0[j] += a0 * bv;
                    o1[j] += a1 * bv;
                    o2[j] += a2 * bv;
                    o3[j] += a3 * bv;
                }
            }
            i += 4;
        }
        while i < rows {
            let orow = &mut out[i * m..(i + 1) * m];
            for kk in k0..k1 {
                let av = a[i * k + kk];
                let brow = &b[kk * m..(kk + 1) * m];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            i += 1;
        }
        k0 = k1;
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", &self.row(i)[..self.cols.min(8)])?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matvec() {
        let m = Matrix::eye(3);
        assert_eq!(m.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_into_reuses_buffer() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + j) as f64);
        let b = Matrix::from_fn(4, 2, |i, j| (i as f64) - (j as f64));
        let mut out = Matrix::from_fn(3, 2, |_, _| 99.0); // stale contents
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
    }

    #[test]
    fn col_squared_norms_match_naive() {
        let a = Matrix::from_fn(5, 3, |i, j| (i as f64 * 0.7 - j as f64).sin());
        let sq = a.col_squared_norms();
        for j in 0..3 {
            let naive: f64 = (0..5).map(|i| a[(i, j)] * a[(i, j)]).sum();
            assert!((sq[j] - naive).abs() < 1e-14);
        }
    }

    #[test]
    fn col_gram_matches_explicit_product_and_norms() {
        let a = Matrix::from_fn(6, 4, |i, j| ((i * 4 + j) as f64 * 0.61).cos());
        let g = a.col_gram();
        let explicit = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&explicit) < 1e-12);
        assert!(g.is_symmetric(0.0));
        // diagonal must reproduce col_squared_norms bit-for-bit (the
        // joint-posterior diagonal parity contract)
        let norms = a.col_squared_norms();
        for j in 0..4 {
            assert_eq!(g[(j, j)], norms[j]);
        }
        // degenerate shapes
        assert_eq!(Matrix::zeros(0, 3).col_gram(), Matrix::zeros(3, 3));
        assert_eq!(Matrix::zeros(3, 0).col_gram(), Matrix::zeros(0, 0));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + 2 * j) as f64);
        let x = [1.0, -2.0, 0.5];
        assert_eq!(a.matvec_t(&x), a.transpose().matvec(&x));
    }

    #[test]
    fn symmetry_check() {
        let mut a = Matrix::eye(3);
        assert!(a.is_symmetric(0.0));
        a[(0, 1)] = 0.5;
        assert!(!a.is_symmetric(1e-12));
        a[(1, 0)] = 0.5;
        assert!(a.is_symmetric(0.0));
    }
}
