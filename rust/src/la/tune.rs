//! Runtime tuning knobs for the blocked, multithreaded `la` kernels.
//!
//! One process-wide [`Tune`] value steers every blocked kernel in
//! [`crate::la`] and the stationary kernels' scaled-norm paths:
//!
//! * [`block`](Tune::block) — panel width of the blocked Cholesky
//!   factorization, the k-blocking of the matmul micro-kernel, and the
//!   candidate-strip width of the kernel cross-covariance (sized so a
//!   panel of `block x block` doubles stays L1-resident at the default).
//! * [`threads`](Tune::threads) — fork-join width for panel-level work
//!   (disjoint output row/column panels distributed over
//!   [`crate::pool::parallel_map`]). Defaults to the machine
//!   (`available_parallelism`), i.e. the pool size.
//! * [`par_min_flops`](Tune::par_min_flops) — minimum flop estimate
//!   before a kernel fans out at all; below it the panels run inline on
//!   the calling thread (scoped-thread spawn costs tens of microseconds,
//!   which dwarfs a small kernel).
//! * [`small`](Tune::small) — dimension threshold below which the
//!   blocked code paths fall back to the scalar reference loops
//!   entirely.
//!
//! **Determinism contract**: `threads` and `par_min_flops` never change
//! results — the parallel fan-outs only ever split disjoint output
//! panels whose per-element arithmetic (and reduction order, for the
//! gradient panels) is fixed independently of the thread count, so runs
//! are bit-identical across 1/2/N threads (pinned by
//! `tests/api_parity.rs` and `tests/blocked_la.rs`). `block` and `small`
//! select between equally valid but *numerically different* summation
//! orders (blocked vs scalar Cholesky); vary them between experiments,
//! not within a reproducibility-sensitive run.
//!
//! Every knob is overridable from the environment at first use
//! (`LIMBO_LA_THREADS`, `LIMBO_LA_BLOCK`, `LIMBO_LA_PAR_MIN`,
//! `LIMBO_LA_SMALL`) and at runtime via [`set_tune`] (used by the bench
//! and test thread-count sweeps).

use std::sync::RwLock;

/// Tuning knobs for the blocked `la` kernels (see the module docs for
/// the cost model and the determinism contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tune {
    /// Cache-block / panel width (Cholesky panels, matmul k-blocks,
    /// cross-covariance candidate strips).
    pub block: usize,
    /// Fork-join width for panel-parallel kernels (1 = never spawn).
    pub threads: usize,
    /// Minimum estimated flops before a kernel goes parallel.
    pub par_min_flops: usize,
    /// Matrices with every dimension below this use the scalar
    /// reference loops instead of the blocked paths.
    pub small: usize,
}

impl Default for Tune {
    /// Environment-independent defaults: 64-wide blocks (a 64x64 f64
    /// panel is 32 KiB — one L1), machine-sized thread count, ~2 Mflop
    /// parallel threshold, scalar fallback below 64.
    fn default() -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self { block: 64, threads, par_min_flops: 2_000_000, small: 64 }
    }
}

impl Tune {
    /// Defaults with any `LIMBO_LA_*` environment overrides applied.
    pub fn from_env() -> Self {
        let mut t = Self::default();
        if let Some(v) = env_usize("LIMBO_LA_BLOCK") {
            t.block = v.max(1);
        }
        if let Some(v) = env_usize("LIMBO_LA_THREADS") {
            t.threads = v.max(1);
        }
        if let Some(v) = env_usize("LIMBO_LA_PAR_MIN") {
            t.par_min_flops = v;
        }
        if let Some(v) = env_usize("LIMBO_LA_SMALL") {
            t.small = v;
        }
        t
    }

    /// Worker count for a kernel with the given flop estimate: 1 below
    /// [`par_min_flops`](Self::par_min_flops), else
    /// [`threads`](Self::threads).
    pub fn threads_for(&self, flops: usize) -> usize {
        if flops < self.par_min_flops {
            1
        } else {
            self.threads.max(1)
        }
    }
}

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok()?.trim().parse().ok()
}

/// `None` until first read; initialized lazily from [`Tune::from_env`]
/// so env overrides apply however early a kernel runs.
static TUNE: RwLock<Option<Tune>> = RwLock::new(None);

/// The process-wide tuning knobs (initialized from the environment on
/// first read). An uncontended read lock costs nanoseconds — noise next
/// to any kernel large enough to block.
pub fn tune() -> Tune {
    let read = TUNE.read().unwrap_or_else(std::sync::PoisonError::into_inner);
    if let Some(t) = *read {
        return t;
    }
    drop(read);
    let t = Tune::from_env();
    let mut write = TUNE.write().unwrap_or_else(std::sync::PoisonError::into_inner);
    // a racing initializer computed the same value; keep the first
    *write.get_or_insert(t)
}

/// Replace the process-wide tuning knobs (bench/test sweeps; see the
/// module docs for which knobs are safe to vary under reproducibility).
pub fn set_tune(t: Tune) {
    *TUNE.write().unwrap_or_else(std::sync::PoisonError::into_inner) = Some(t);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let t = Tune::default();
        assert!(t.block >= 8);
        assert!(t.threads >= 1);
        assert!(t.small >= 1);
        assert_eq!(t.threads_for(0), 1);
        assert_eq!(t.threads_for(usize::MAX), t.threads);
    }

    #[test]
    fn global_read_is_initialized() {
        // don't mutate the global here: unit tests share the process
        let t = tune();
        assert!(t.threads >= 1 && t.block >= 1);
    }
}
