//! Blocked low-rank kernels for the sparse-GP normal equations.
//!
//! The FITC fit reduces `n` observations against `m « n` inducing points
//! to an m×m system `A = K_mm + Kᵀ diag(w) K` with right-hand side
//! `b = Kᵀ diag(w) v`, where `K` is the n×m cross-covariance. Both
//! reductions stream over the `n` rows once; [`weighted_normal_eqs`]
//! processes them in row blocks so each row of the m×m accumulator is
//! reused across a whole block instead of being re-touched per
//! observation (A-traffic drops from `n·m²` to `(n/block)·m²`).

use crate::la::cholesky::{CholeskyFactor, NotPositiveDefinite};
use crate::la::{axpy, Matrix};

/// Default row-block size for [`weighted_normal_eqs`] (tuned so a block of
/// cross-covariance rows plus one accumulator row stay L1-resident for
/// m ≤ 256).
pub const DEFAULT_BLOCK: usize = 64;

/// Compute `A = Rᵀ diag(w) R` (m×m, symmetric) and `b = Rᵀ diag(w) v`
/// over a row-major `rows` buffer of shape n×m, blocked over rows.
///
/// `w` are the per-row weights (`1/λ_i` in FITC), `v` the per-row values
/// (residuals). `block == 0` falls back to [`DEFAULT_BLOCK`].
pub fn weighted_normal_eqs(
    rows: &[f64],
    m: usize,
    w: &[f64],
    v: &[f64],
    block: usize,
) -> (Matrix, Vec<f64>) {
    let n = w.len();
    assert_eq!(rows.len(), n * m, "rows must be n*m row-major");
    assert_eq!(v.len(), n, "v length mismatch");
    let block = if block == 0 { DEFAULT_BLOCK } else { block };

    let mut a = Matrix::zeros(m, m);
    let mut b = vec![0.0; m];

    let mut start = 0;
    while start < n {
        let end = (start + block).min(n);
        // b += Σ_i w_i v_i r_i over the block (single streaming pass)
        for i in start..end {
            let r = &rows[i * m..(i + 1) * m];
            let c = w[i] * v[i];
            if c != 0.0 {
                axpy(c, r, &mut b);
            }
        }
        // Upper triangle of A: column-row j outer, block rows inner, so
        // a.row(j) stays hot for the whole block (the "blocked" part).
        for j in 0..m {
            let arow = &mut a.row_mut(j)[j..];
            for i in start..end {
                let r = &rows[i * m..(i + 1) * m];
                let c = w[i] * r[j];
                if c != 0.0 {
                    axpy(c, &r[j..], arow);
                }
            }
        }
        start = end;
    }
    // mirror the strict upper triangle
    for j in 0..m {
        for k in (j + 1)..m {
            a[(k, j)] = a[(j, k)];
        }
    }
    (a, b)
}

/// Compute only the weighted Gram reduction `A = Rᵀ diag(w) R` (no
/// right-hand side) over a row-major n×m `rows` buffer. Unlike
/// [`weighted_normal_eqs`] the weights may be negative — the FITC
/// marginal-likelihood gradient reduces the diagonal-correction
/// derivatives `Σ_i W_ii s_i s_iᵀ` through this with the (sign-indefinite)
/// trace weights `W_ii = μ_i² − Σ⁻¹_ii`.
pub fn weighted_gram(rows: &[f64], m: usize, w: &[f64], block: usize) -> Matrix {
    let zeros = vec![0.0; w.len()];
    weighted_normal_eqs(rows, m, w, &zeros, block).0
}

/// Symmetric sandwich solve `K⁻¹ N K⁻¹` through a Cholesky factor of `K`
/// (two full multi-solves; `N` symmetric ⇒ the result is symmetric up to
/// round-off, which is good enough for the trace accumulations it feeds).
///
/// This is the `tr(A⁻¹ dA)`-through-Woodbury helper: the FITC gradient
/// needs `K_mm⁻¹ (Kᵀ diag(v) K) K_mm⁻¹` for the diagonal-correction
/// derivatives, and `K⁻¹ N K⁻¹` contracted against `dK` is exactly
/// `tr(K⁻¹ N K⁻¹ dK)`.
pub fn sandwich_solve(chol: &CholeskyFactor, n_mat: &Matrix) -> Matrix {
    // K⁻¹ N, then (K⁻¹ N) K⁻¹ = (K⁻¹ (K⁻¹ N)ᵀ)ᵀ
    let left = chol.solve_multi(n_mat);
    chol.solve_multi(&left.transpose()).transpose()
}

/// Rank-1 symmetric update `A += c · r rᵀ` (both triangles).
pub fn rank1_update(a: &mut Matrix, c: f64, r: &[f64]) {
    let m = a.rows();
    assert_eq!(a.cols(), m, "rank1_update: square matrix required");
    assert_eq!(r.len(), m, "rank1_update: vector length mismatch");
    for j in 0..m {
        let s = c * r[j];
        if s != 0.0 {
            axpy(s, &r[j..], &mut a.row_mut(j)[j..]);
        }
    }
    for j in 0..m {
        for k in (j + 1)..m {
            a[(k, j)] = a[(j, k)];
        }
    }
}

/// Cholesky-factor an SPD matrix, escalating a diagonal jitter from 1e-10
/// up to `max_jitter` when the matrix is numerically semi-definite
/// (clustered inducing points). Returns the factor and the jitter used.
pub fn spd_factor_jittered(
    a: &Matrix,
    max_jitter: f64,
) -> Result<(CholeskyFactor, f64), NotPositiveDefinite> {
    let n = a.rows();
    let mut jitter = 0.0;
    loop {
        let mut k = a.clone();
        if jitter > 0.0 {
            for i in 0..n {
                k[(i, i)] += jitter;
            }
        }
        match CholeskyFactor::factor(&k) {
            Ok(ch) => return Ok((ch, jitter)),
            Err(_) if jitter < max_jitter => {
                jitter = if jitter == 0.0 { 1e-10 } else { jitter * 10.0 };
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive(rows: &[f64], m: usize, w: &[f64], v: &[f64]) -> (Matrix, Vec<f64>) {
        let n = w.len();
        let mut a = Matrix::zeros(m, m);
        let mut b = vec![0.0; m];
        for i in 0..n {
            let r = &rows[i * m..(i + 1) * m];
            for j in 0..m {
                b[j] += w[i] * v[i] * r[j];
                for k in 0..m {
                    a[(j, k)] += w[i] * r[j] * r[k];
                }
            }
        }
        (a, b)
    }

    #[test]
    fn matches_naive_across_shapes_and_blocks() {
        let mut rng = Pcg64::seed(0x10e);
        for &(n, m) in &[(0usize, 3usize), (1, 1), (5, 3), (64, 8), (130, 16)] {
            let rows: Vec<f64> = (0..n * m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (a0, b0) = naive(&rows, m, &w, &v);
            for block in [1, 7, 64, 0] {
                let (a, b) = weighted_normal_eqs(&rows, m, &w, &v, block);
                assert!(a.max_abs_diff(&a0) < 1e-10, "n={n} m={m} block={block}");
                for j in 0..m {
                    assert!((b[j] - b0[j]).abs() < 1e-10, "b[{j}] n={n} block={block}");
                }
            }
        }
    }

    #[test]
    fn rank1_matches_recompute() {
        let mut rng = Pcg64::seed(0x1a);
        let m = 6;
        let rows: Vec<f64> = (0..4 * m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w: Vec<f64> = (0..4).map(|_| rng.uniform(0.1, 2.0)).collect();
        let v = vec![0.0; 4];
        let (mut a, _) = weighted_normal_eqs(&rows[..3 * m], m, &w[..3], &v[..3], 0);
        rank1_update(&mut a, w[3], &rows[3 * m..]);
        let (a_full, _) = weighted_normal_eqs(&rows, m, &w, &v, 0);
        assert!(a.max_abs_diff(&a_full) < 1e-12);
        assert!(a.is_symmetric(1e-12));
    }

    #[test]
    fn weighted_gram_accepts_negative_weights() {
        let mut rng = Pcg64::seed(0x9e9);
        let (n, m) = (20usize, 5usize);
        let rows: Vec<f64> = (0..n * m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let a = weighted_gram(&rows, m, &w, 7);
        let zeros = vec![0.0; n];
        let (a0, _) = naive(&rows, m, &w, &zeros);
        assert!(a.max_abs_diff(&a0) < 1e-10);
    }

    #[test]
    fn sandwich_solve_matches_explicit_inverse() {
        let mut rng = Pcg64::seed(0x5a17);
        let m = 6;
        // SPD K and a symmetric N
        let b = Matrix::from_fn(m, m, |_, _| rng.uniform(-1.0, 1.0));
        let mut k = b.matmul(&b.transpose());
        for i in 0..m {
            k[(i, i)] += m as f64;
        }
        let mut n_mat = Matrix::from_fn(m, m, |_, _| rng.uniform(-1.0, 1.0));
        for i in 0..m {
            for j in (i + 1)..m {
                let s = 0.5 * (n_mat[(i, j)] + n_mat[(j, i)]);
                n_mat[(i, j)] = s;
                n_mat[(j, i)] = s;
            }
        }
        let ch = CholeskyFactor::factor(&k).unwrap();
        let got = sandwich_solve(&ch, &n_mat);
        let kinv = ch.inverse();
        let want = kinv.matmul(&n_mat).matmul(&kinv);
        assert!(got.max_abs_diff(&want) < 1e-9);
        assert!(got.is_symmetric(1e-9));
    }

    #[test]
    fn jittered_factor_recovers_semidefinite() {
        // rank-deficient: two identical rows/cols
        let a = Matrix::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        assert!(CholeskyFactor::factor(&a).is_err());
        let (ch, jitter) = spd_factor_jittered(&a, 1e-2).unwrap();
        assert!(jitter > 0.0);
        assert_eq!(ch.dim(), 2);
        // hopeless matrices still fail
        let bad = Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]);
        assert!(spd_factor_jittered(&bad, 1e-6).is_err());
    }
}
