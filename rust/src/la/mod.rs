//! Dense linear algebra substrate (the Eigen3 replacement).
//!
//! Everything the GP needs, hand-written and unit/property tested:
//! a row-major [`Matrix`], Cholesky factorization with **incremental
//! rank-extension** (`CholeskyFactor::extend` — the O(n^2) per-iteration
//! trick the native GP relies on), forward/backward substitution, SPD
//! solves, and small vector helpers.
//!
//! f64 throughout: the native GP path is the reference for the f32 XLA
//! artifacts.

pub mod cholesky;
pub mod eig;
pub mod lowrank;
pub mod matrix;
pub mod vecops;

pub use cholesky::CholeskyFactor;
pub use eig::{sym_eig, SymEig};
pub use lowrank::{
    rank1_update, sandwich_solve, spd_factor_jittered, weighted_gram, weighted_normal_eqs,
};
pub use matrix::Matrix;
pub use vecops::{axpy, dot, norm2, scale, sub};
