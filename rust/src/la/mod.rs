//! Dense linear algebra substrate (the Eigen3 replacement).
//!
//! Everything the GP needs, hand-written and unit/property tested:
//! a row-major [`Matrix`], Cholesky factorization with **incremental
//! rank-extension** (`CholeskyFactor::extend` — the O(n^2) per-iteration
//! trick the native GP relies on), forward/backward substitution, SPD
//! solves, and small vector helpers.
//!
//! f64 throughout: the native GP path is the reference for the f32 XLA
//! artifacts.
//!
//! # Blocking and threading cost model
//!
//! The dense hot kernels ([`Matrix::matmul`]/[`Matrix::col_gram`],
//! [`CholeskyFactor::factor`], the multi-RHS substitutions, and the
//! stationary kernels' cross-covariance in [`crate::kernel`]) are
//! cache-blocked, written as unit-stride 4-wide-unrolled loops the
//! compiler autovectorizes, and fan panel-level work out over scoped
//! threads ([`crate::pool::parallel_map`]). The shared cost model:
//!
//! * **Blocking** keeps one `block x block` f64 panel (32 KiB at the
//!   default `block = 64`) L1-resident, so an O(n³) kernel streams each
//!   operand O(n/block) times instead of O(n) times.
//! * **Threading** splits *disjoint output row/column panels* across
//!   workers — never a shared accumulator — so the per-element
//!   arithmetic is fixed and results are bit-identical for any thread
//!   count. Kernels below `par_min_flops` run inline (a scoped spawn
//!   costs more than a small kernel).
//! * **Fallback**: below the `small` dimension threshold the scalar
//!   reference loops run instead (`CholeskyFactor::factor_unblocked`
//!   stays public as the reference implementation).
//!
//! All knobs live in one process-wide [`Tune`] (env-overridable via
//! `LIMBO_LA_*`; see [`tune()`]); blocked-vs-scalar parity is pinned at
//! ≤1e-12 by `tests/blocked_la.rs`.

pub mod cholesky;
pub mod eig;
pub mod lowrank;
pub mod matrix;
pub mod tune;
pub mod vecops;

pub use cholesky::CholeskyFactor;
pub use tune::{set_tune, tune, Tune};
pub use eig::{sym_eig, SymEig};
pub use lowrank::{
    rank1_update, sandwich_solve, spd_factor_jittered, weighted_gram, weighted_normal_eqs,
};
pub use matrix::Matrix;
pub use vecops::{axpy, dot, norm2, scale, sub};
