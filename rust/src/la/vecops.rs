//! Small vector helpers used throughout the stack (no BLAS available).

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than a naive fold and
    // deterministic across runs (fixed association order).
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha` in place.
#[inline]
pub fn scale(x: &mut [f64], alpha: f64) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Elementwise `a - b`.
#[inline]
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.3).collect();
        let b: Vec<f64> = (0..13).map(|i| (13 - i) as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_and_scale() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, [1.5, 2.5, 3.5]);
    }

    #[test]
    fn norm_and_sub() {
        assert_eq!(norm2(&[3.0, 4.0]), 25.0);
        assert_eq!(sub(&[3.0, 4.0], &[1.0, 1.0]), vec![2.0, 3.0]);
    }
}
