//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Only small matrices pass through here (CMA-ES covariance, dim <= ~10),
//! where Jacobi is simple, robust, and accurate.

use crate::la::Matrix;

/// Eigen-decomposition `A = V diag(w) V^T` of a symmetric matrix.
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Eigenvectors as columns of `V`.
    pub vectors: Matrix,
}

/// Jacobi eigenvalue iteration for a symmetric matrix.
pub fn sym_eig(a: &Matrix) -> SymEig {
    assert_eq!(a.rows(), a.cols(), "sym_eig: square matrix required");
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Matrix::eye(n);

    for _sweep in 0..100 {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort ascending by eigenvalue
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(i, i)].partial_cmp(&m[(j, j)]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let vectors = Matrix::from_fn(n, n, |r, c| v[(r, order[c])]);
    SymEig { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(3, 3, &[3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1, 3
        let a = Matrix::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_random_spd() {
        let mut rng = Pcg64::seed(55);
        for n in [2, 4, 7] {
            let b = Matrix::from_fn(n, n, |_, _| rng.next_f64() * 2.0 - 1.0);
            let mut a = b.matmul(&b.transpose());
            for i in 0..n {
                a[(i, i)] += 0.5;
            }
            let e = sym_eig(&a);
            // A = V diag(w) V^T
            let vd = Matrix::from_fn(n, n, |i, j| e.vectors[(i, j)] * e.values[j]);
            let rec = vd.matmul(&e.vectors.transpose());
            assert!(rec.max_abs_diff(&a) < 1e-9, "n={n}");
            // eigenvalues positive for SPD
            assert!(e.values.iter().all(|&w| w > 0.0));
            // V orthogonal
            let vtv = e.vectors.transpose().matmul(&e.vectors);
            assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-9);
        }
    }
}
