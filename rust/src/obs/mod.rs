//! Phase-level spans and a process-wide metrics registry.
//!
//! The paper's headline claim is runtime cost, so the repo needs to see
//! *where* every millisecond goes — Cholesky vs. hyper-refit vs.
//! acquisition optimization — not just end-to-end wall time. This module
//! is the zero-dependency observability layer behind that attribution:
//!
//! * a fixed set of [`Phase`]s (one per hot code path: `la` factor/solve
//!   kernels, dense/sparse fits, gradient evaluations, batch predictions,
//!   acquisition batches, qEI Monte-Carlo sampling, inner-optimizer
//!   restarts, pool queue-wait/execute, and the service-path
//!   `ask`/`tell`/`refit` in `BoCore`), each aggregating a call count, a
//!   total duration, and a log₂-bucketed latency histogram from which
//!   p50/p95/p99 are read;
//! * always-on [`Counter`]s for rare events (refits, restarts, sparse
//!   migrations, MC draws, I/O write failures) and last-write-wins
//!   [`Gauge`]s (model size, inducing count);
//! * RAII [`Span`] timers created by [`span`], recorded into the
//!   calling thread's shard on drop.
//!
//! # Cost model
//!
//! Timing is **off by default**. A [`span`] call with metrics disabled
//! costs exactly one relaxed atomic load (the [`enabled`] check) — no
//! clock read, no TLS access, no allocation — so instrumentation can sit
//! on hot paths permanently. When enabled, each span costs two `Instant`
//! reads plus three relaxed atomic increments on the thread-local shard
//! (uncontended cache lines: every thread owns its shard; the registry
//! only walks them at [`snapshot`] time). Counters and gauges are always
//! on: they mark rare events, and a relaxed `fetch_add` is cheaper than
//! the branch that would gate it.
//!
//! Spans never touch the RNG and never reorder floating-point work, so
//! enabling metrics cannot perturb a deterministic trace —
//! `tests/api_parity.rs` pins this by running the same `BoDef` with
//! metrics on and off and comparing traces bit-for-bit.
//!
//! # Reading the numbers
//!
//! [`snapshot`] sums every live (and dead — the registry keeps shards
//! alive after their thread exits) shard into an immutable [`Snapshot`].
//! Snapshots subtract ([`Snapshot::delta_since`]), so a caller brackets a
//! region of interest with two snapshots and reads the delta:
//!
//! ```
//! use limbo::obs::{self, Phase};
//!
//! let _guard = obs::test_serial_guard(); // doctests share the process
//! obs::set_enabled(true);
//! let base = obs::snapshot();
//! {
//!     let _span = obs::span(Phase::MatMul);
//!     // ... hot work ...
//! }
//! let delta = obs::snapshot().delta_since(&base);
//! assert_eq!(delta.calls(Phase::MatMul), 1);
//! println!("{}", delta.render_table(None));
//! obs::set_enabled(false);
//! ```
//!
//! Three consumers sit on top: `stat::MetricsObserver` snapshots a run's
//! phase breakdown into `meta.dat` + `metrics.json` on the event bus,
//! the CLI exposes `--metrics`, and the scaling benches emit per-phase
//! JSON rows so `scripts/bench_compare.py` can attribute a regression to
//! a phase instead of a whole bench. [`Snapshot::to_prometheus`] renders
//! the text exposition format for the future dashboard.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Number of log₂ latency buckets: bucket `i` holds durations in
/// `[2^i, 2^(i+1))` nanoseconds; `2^39` ns ≈ 9 minutes, longer spans
/// clamp into the last bucket.
const N_BUCKETS: usize = 40;

/// Every instrumented code path. Fixed at compile time so a span is an
/// array index, not a string lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `la`: full Cholesky factorization (and incremental extension).
    CholFactor,
    /// `la`: multi-RHS triangular solves (forward/back substitution).
    CholSolve,
    /// `la`: dense `matmul_into` Gram/product blocks.
    MatMul,
    /// `kernel`/`model`: cross-covariance Gram blocks at model call sites.
    CrossCov,
    /// `model`: dense GP (re)fit — Gram assembly + factorization + alpha.
    DenseFit,
    /// `model`: sparse FITC (re)fit.
    SparseFit,
    /// `model`: log-marginal-likelihood gradient evaluations (dense + FITC).
    LmlGrad,
    /// `model`: batched posterior mean/variance (`predict_batch`).
    PredictBatch,
    /// `model`: joint posterior with full covariance (`predict_joint`).
    PredictJoint,
    /// `model`: dense→sparse migration (`AdaptiveModel`).
    SparseMigrate,
    /// `model`: ML-II hyper-parameter optimization (all restarts).
    HpOpt,
    /// `acqui`: batched acquisition evaluation over a population.
    AcquiBatch,
    /// `acqui`: qEI Monte-Carlo sampling (joint-path draws).
    QeiMc,
    /// `opt`: inner-optimizer multi-restart maximization.
    InnerOpt,
    /// `pool`: time a job waited in the queue before a worker picked it up.
    PoolQueueWait,
    /// `pool`: time a job spent executing on a worker.
    PoolExec,
    /// service: one `ask` (single or batch proposal) in `BoCore`.
    Ask,
    /// service: one `tell` (observe + schedule bookkeeping) in `BoCore`.
    Tell,
    /// service: one scheduled hyper-refit inside `tell`.
    Refit,
    /// manager: capturing a study checkpoint (core + model state).
    Snapshot,
    /// manager: rehydrating a study (snapshot load + event-log replay).
    Replay,
}

impl Phase {
    /// Every phase, in declaration order (indexes the shard arrays).
    pub const ALL: [Phase; 21] = [
        Phase::CholFactor,
        Phase::CholSolve,
        Phase::MatMul,
        Phase::CrossCov,
        Phase::DenseFit,
        Phase::SparseFit,
        Phase::LmlGrad,
        Phase::PredictBatch,
        Phase::PredictJoint,
        Phase::SparseMigrate,
        Phase::HpOpt,
        Phase::AcquiBatch,
        Phase::QeiMc,
        Phase::InnerOpt,
        Phase::PoolQueueWait,
        Phase::PoolExec,
        Phase::Ask,
        Phase::Tell,
        Phase::Refit,
        Phase::Snapshot,
        Phase::Replay,
    ];

    /// Number of phases.
    pub const COUNT: usize = Phase::ALL.len();

    /// Stable snake_case name used in `meta.dat`, `metrics.json`,
    /// Prometheus labels, and bench rows.
    pub fn name(self) -> &'static str {
        match self {
            Phase::CholFactor => "chol_factor",
            Phase::CholSolve => "chol_solve",
            Phase::MatMul => "matmul",
            Phase::CrossCov => "cross_cov",
            Phase::DenseFit => "dense_fit",
            Phase::SparseFit => "sparse_fit",
            Phase::LmlGrad => "lml_grad",
            Phase::PredictBatch => "predict_batch",
            Phase::PredictJoint => "predict_joint",
            Phase::SparseMigrate => "sparse_migrate",
            Phase::HpOpt => "hp_opt",
            Phase::AcquiBatch => "acqui_batch",
            Phase::QeiMc => "qei_mc",
            Phase::InnerOpt => "inner_opt",
            Phase::PoolQueueWait => "pool_queue_wait",
            Phase::PoolExec => "pool_exec",
            Phase::Ask => "ask",
            Phase::Tell => "tell",
            Phase::Refit => "refit",
            Phase::Snapshot => "snapshot",
            Phase::Replay => "replay",
        }
    }
}

/// Monotonic event counters. Always on (not gated by [`enabled`]):
/// they mark rare events and a relaxed `fetch_add` costs less than the
/// branch that would gate it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Scheduled hyper-refits fired by `BoCore`.
    Refits,
    /// ML-II restarts fanned out by the hyper-parameter optimizer.
    HpRestarts,
    /// Inner-optimizer restarts fanned out by `ParallelRepeater`.
    InnerRestarts,
    /// qEI Monte-Carlo path draws (samples × evaluations).
    QeiMcDraws,
    /// Dense→sparse model migrations.
    SparseMigrations,
    /// Jobs submitted to `pool::ThreadPool`.
    PoolJobs,
    /// I/O errors swallowed by the `stat` writers (`RunLogger`,
    /// `JsonlObserver`) — nonzero means run files are incomplete.
    StatWriteFailures,
    /// Torn trailing lines skipped by `stat::ReplayEvent::read_log`
    /// (a crash mid-append left a partial final record).
    ReplayTornLines,
    /// Generations advanced by `opt::AdaptiveDe` (self-adaptive DE).
    DeGenerations,
    /// Objective evaluations spent by `opt::AdaptiveDe` (initial
    /// population + one batch per generation).
    DeEvaluations,
}

impl Counter {
    /// Every counter, in declaration order.
    pub const ALL: [Counter; 10] = [
        Counter::Refits,
        Counter::HpRestarts,
        Counter::InnerRestarts,
        Counter::QeiMcDraws,
        Counter::SparseMigrations,
        Counter::PoolJobs,
        Counter::StatWriteFailures,
        Counter::ReplayTornLines,
        Counter::DeGenerations,
        Counter::DeEvaluations,
    ];

    /// Number of counters.
    pub const COUNT: usize = Counter::ALL.len();

    /// Stable snake_case name.
    pub fn name(self) -> &'static str {
        match self {
            Counter::Refits => "refits",
            Counter::HpRestarts => "hp_restarts",
            Counter::InnerRestarts => "inner_restarts",
            Counter::QeiMcDraws => "qei_mc_draws",
            Counter::SparseMigrations => "sparse_migrations",
            Counter::PoolJobs => "pool_jobs",
            Counter::StatWriteFailures => "stat_write_failures",
            Counter::ReplayTornLines => "replay_torn_lines",
            Counter::DeGenerations => "de_generations",
            Counter::DeEvaluations => "de_evaluations",
        }
    }
}

/// Last-write-wins instantaneous values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// Samples currently held by the service model.
    ModelSamples,
    /// Inducing points of the sparse model (0 while dense).
    InducingPoints,
    /// Studies currently resident in a `StudyManager` registry.
    LiveStudies,
    /// Studies evicted to disk (rehydratable) in a `StudyManager`.
    EvictedStudies,
    /// Proposals currently outstanding (asked, not yet told) in an
    /// async-pending `BoCore`.
    PendingTrials,
}

impl Gauge {
    /// Every gauge, in declaration order.
    pub const ALL: [Gauge; 5] = [
        Gauge::ModelSamples,
        Gauge::InducingPoints,
        Gauge::LiveStudies,
        Gauge::EvictedStudies,
        Gauge::PendingTrials,
    ];

    /// Number of gauges.
    pub const COUNT: usize = Gauge::ALL.len();

    /// Stable snake_case name.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::ModelSamples => "model_samples",
            Gauge::InducingPoints => "inducing_points",
            Gauge::LiveStudies => "live_studies",
            Gauge::EvictedStudies => "evicted_studies",
            Gauge::PendingTrials => "pending_trials",
        }
    }
}

/// Index of the log₂ bucket holding a duration of `ns` nanoseconds.
fn bucket_index(ns: u64) -> usize {
    let idx = 63 - ns.max(1).leading_zeros() as usize;
    idx.min(N_BUCKETS - 1)
}

/// Representative (geometric-midpoint) duration of bucket `i`, seconds.
fn bucket_mid_seconds(i: usize) -> f64 {
    1.5 * (1u64 << i.min(62)) as f64 * 1e-9
}

/// Per-phase aggregation cell on one thread's shard.
struct PhaseCell {
    count: AtomicU64,
    total_ns: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

impl PhaseCell {
    fn new() -> Self {
        Self {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// One thread's private slice of the registry. Threads only ever write
/// their own shard (uncontended cache lines); [`snapshot`] reads all of
/// them with relaxed loads.
struct Shard {
    phases: Vec<PhaseCell>,
    counters: Vec<AtomicU64>,
}

impl Shard {
    fn new() -> Self {
        Self {
            phases: (0..Phase::COUNT).map(|_| PhaseCell::new()).collect(),
            counters: (0..Counter::COUNT).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// The process-wide registry: every thread's shard plus global gauges.
/// Shards are held by `Arc` from both the owning thread and this list,
/// so a thread's numbers survive its exit.
struct Registry {
    shards: Mutex<Vec<Arc<Shard>>>,
    gauges: Vec<AtomicU64>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        shards: Mutex::new(Vec::new()),
        gauges: (0..Gauge::COUNT).map(|_| AtomicU64::new(0)).collect(),
    })
}

fn lock_shards() -> MutexGuard<'static, Vec<Arc<Shard>>> {
    registry().shards.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

thread_local! {
    static SHARD: Arc<Shard> = {
        let shard = Arc::new(Shard::new());
        lock_shards().push(Arc::clone(&shard));
        shard
    };
}

/// Is span timing on? One relaxed atomic load — the entire cost of a
/// disabled [`span`] call.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span timing on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// RAII phase timer: records `elapsed` into the calling thread's shard
/// when dropped (no-op if metrics were disabled at creation).
#[must_use = "a span measures until dropped; binding to `_` drops it immediately"]
pub struct Span {
    phase: Phase,
    start: Option<Instant>,
}

/// Start timing `phase`. Disabled cost: one relaxed atomic load.
#[inline]
pub fn span(phase: Phase) -> Span {
    let start = if enabled() { Some(Instant::now()) } else { None };
    Span { phase, start }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start.take() {
            record_duration(self.phase, t0.elapsed());
        }
    }
}

/// Record a pre-measured duration against `phase` (what [`Span`] does on
/// drop; public for callers that must time across an ownership boundary,
/// e.g. the pool's queue-wait measured from submit to dequeue).
pub fn record_duration(phase: Phase, d: Duration) {
    let ns = d.as_nanos().min(u64::MAX as u128) as u64;
    // try_with: recording from a thread mid-teardown silently drops the
    // sample instead of panicking in a destructor.
    let _ = SHARD.try_with(|s| {
        let cell = &s.phases[phase as usize];
        cell.count.fetch_add(1, Ordering::Relaxed);
        cell.total_ns.fetch_add(ns, Ordering::Relaxed);
        cell.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
    });
}

/// Add `n` to a counter (always on; see [`Counter`]).
pub fn counter_add(c: Counter, n: u64) {
    let _ = SHARD.try_with(|s| {
        s.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    });
}

/// Set a gauge to `v` (always on, last write wins).
pub fn gauge_set(g: Gauge, v: u64) {
    registry().gauges[g as usize].store(v, Ordering::Relaxed);
}

/// Zero every shard and gauge (test helper; concurrent writers may land
/// increments during the sweep).
pub fn reset() {
    let shards = lock_shards();
    for shard in shards.iter() {
        for cell in &shard.phases {
            cell.count.store(0, Ordering::Relaxed);
            cell.total_ns.store(0, Ordering::Relaxed);
            for b in &cell.buckets {
                b.store(0, Ordering::Relaxed);
            }
        }
        for c in &shard.counters {
            c.store(0, Ordering::Relaxed);
        }
    }
    for g in &registry().gauges {
        g.store(0, Ordering::Relaxed);
    }
}

/// Serialize tests (and doctests) that toggle the process-wide
/// [`set_enabled`] flag or assert on absolute registry contents.
#[doc(hidden)]
pub fn test_serial_guard() -> MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Aggregated statistics of one phase (summed over all shards).
#[derive(Clone, Debug)]
pub struct PhaseStats {
    /// Completed spans.
    pub count: u64,
    /// Total time inside the phase, nanoseconds.
    pub total_ns: u64,
    buckets: Vec<u64>,
}

impl PhaseStats {
    fn zero() -> Self {
        Self { count: 0, total_ns: 0, buckets: vec![0; N_BUCKETS] }
    }

    /// Total time inside the phase, seconds.
    pub fn seconds(&self) -> f64 {
        self.total_ns as f64 * 1e-9
    }

    /// Approximate `q`-quantile latency in seconds, read from the log₂
    /// histogram (resolution: one bucket, i.e. a factor of 2).
    pub fn quantile_seconds(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return bucket_mid_seconds(i);
            }
        }
        bucket_mid_seconds(N_BUCKETS - 1)
    }
}

/// Immutable point-in-time aggregate of the whole registry. Subtract two
/// with [`delta_since`](Self::delta_since) to isolate a region.
#[derive(Clone, Debug)]
pub struct Snapshot {
    phases: Vec<PhaseStats>,
    counters: Vec<u64>,
    gauges: Vec<u64>,
}

/// Sum every shard into a [`Snapshot`]. Relaxed reads: concurrent
/// writers may be mid-update, so a snapshot is approximate to within the
/// spans still in flight.
pub fn snapshot() -> Snapshot {
    let mut phases: Vec<PhaseStats> = (0..Phase::COUNT).map(|_| PhaseStats::zero()).collect();
    let mut counters = vec![0u64; Counter::COUNT];
    {
        let shards = lock_shards();
        for shard in shards.iter() {
            for (i, cell) in shard.phases.iter().enumerate() {
                phases[i].count += cell.count.load(Ordering::Relaxed);
                phases[i].total_ns += cell.total_ns.load(Ordering::Relaxed);
                for (b, bucket) in cell.buckets.iter().enumerate() {
                    phases[i].buckets[b] += bucket.load(Ordering::Relaxed);
                }
            }
            for (i, c) in shard.counters.iter().enumerate() {
                counters[i] += c.load(Ordering::Relaxed);
            }
        }
    }
    let gauges = registry().gauges.iter().map(|g| g.load(Ordering::Relaxed)).collect();
    Snapshot { phases, counters, gauges }
}

impl Snapshot {
    /// Stats of one phase.
    pub fn phase(&self, p: Phase) -> &PhaseStats {
        &self.phases[p as usize]
    }

    /// Completed spans of `p`.
    pub fn calls(&self, p: Phase) -> u64 {
        self.phases[p as usize].count
    }

    /// Total seconds inside `p`.
    pub fn seconds(&self, p: Phase) -> f64 {
        self.phases[p as usize].seconds()
    }

    /// Current value of counter `c`.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Current value of gauge `g`.
    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g as usize]
    }

    /// Everything accumulated since `base` (elementwise saturating
    /// subtraction; gauges keep this snapshot's instantaneous values).
    pub fn delta_since(&self, base: &Snapshot) -> Snapshot {
        let phases = self
            .phases
            .iter()
            .zip(&base.phases)
            .map(|(now, then)| PhaseStats {
                count: now.count.saturating_sub(then.count),
                total_ns: now.total_ns.saturating_sub(then.total_ns),
                buckets: now
                    .buckets
                    .iter()
                    .zip(&then.buckets)
                    .map(|(a, b)| a.saturating_sub(*b))
                    .collect(),
            })
            .collect();
        let counters = self
            .counters
            .iter()
            .zip(&base.counters)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        Snapshot { phases, counters, gauges: self.gauges.clone() }
    }

    /// Seconds spent in the service path (`ask` + `tell`; `refit` runs
    /// nested inside `tell`, so it is attributed, not added twice).
    pub fn service_seconds(&self) -> f64 {
        self.seconds(Phase::Ask) + self.seconds(Phase::Tell)
    }

    /// JSON object (`{"phases":[...],"counters":{...},"gauges":{...}}`),
    /// phases with zero calls omitted. Hand-rolled: names are fixed
    /// identifiers, numbers are finite — nothing needs escaping.
    pub fn to_json(&self) -> String {
        let mut phases = Vec::new();
        for p in Phase::ALL {
            let st = self.phase(p);
            if st.count == 0 {
                continue;
            }
            phases.push(format!(
                concat!(
                    r#"{{"phase":"{}","calls":{},"seconds":{:.9},"#,
                    r#""p50_s":{:.9},"p95_s":{:.9},"p99_s":{:.9}}}"#
                ),
                p.name(),
                st.count,
                st.seconds(),
                st.quantile_seconds(0.50),
                st.quantile_seconds(0.95),
                st.quantile_seconds(0.99),
            ));
        }
        let counters: Vec<String> = Counter::ALL
            .iter()
            .map(|&c| format!(r#""{}":{}"#, c.name(), self.counter(c)))
            .collect();
        let gauges: Vec<String> = Gauge::ALL
            .iter()
            .map(|&g| format!(r#""{}":{}"#, g.name(), self.gauge(g)))
            .collect();
        format!(
            r#"{{"phases":[{}],"counters":{{{}}},"gauges":{{{}}}}}"#,
            phases.join(","),
            counters.join(","),
            gauges.join(",")
        )
    }

    /// Prometheus text exposition (the helper behind the future
    /// dashboard): `limbo_phase_seconds_total`/`limbo_phase_calls_total`
    /// per phase, quantile series, plus one series per counter and gauge.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        out.push_str("# TYPE limbo_phase_seconds_total counter\n");
        out.push_str("# TYPE limbo_phase_calls_total counter\n");
        out.push_str("# TYPE limbo_phase_latency_seconds summary\n");
        for p in Phase::ALL {
            let st = self.phase(p);
            if st.count == 0 {
                continue;
            }
            out.push_str(&format!(
                "limbo_phase_seconds_total{{phase=\"{}\"}} {:.9}\n",
                p.name(),
                st.seconds()
            ));
            out.push_str(&format!(
                "limbo_phase_calls_total{{phase=\"{}\"}} {}\n",
                p.name(),
                st.count
            ));
            for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                out.push_str(&format!(
                    "limbo_phase_latency_seconds{{phase=\"{}\",quantile=\"{}\"}} {:.9}\n",
                    p.name(),
                    label,
                    st.quantile_seconds(q)
                ));
            }
        }
        for c in Counter::ALL {
            out.push_str(&format!("# TYPE limbo_{}_total counter\n", c.name()));
            out.push_str(&format!("limbo_{}_total {}\n", c.name(), self.counter(c)));
        }
        for g in Gauge::ALL {
            out.push_str(&format!("# TYPE limbo_{} gauge\n", g.name()));
            out.push_str(&format!("limbo_{} {}\n", g.name(), self.gauge(g)));
        }
        out
    }

    /// Human-readable phase table sorted by total time (descending),
    /// with a `% wall` column when `wall_seconds` is given. Used by the
    /// CLI `--metrics` report and `examples/metrics.rs`.
    pub fn render_table(&self, wall_seconds: Option<f64>) -> String {
        let mut rows: Vec<(Phase, &PhaseStats)> =
            Phase::ALL.iter().map(|&p| (p, self.phase(p))).filter(|(_, s)| s.count > 0).collect();
        rows.sort_by(|a, b| b.1.total_ns.cmp(&a.1.total_ns));
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16} {:>10} {:>12} {:>7} {:>10} {:>10} {:>10}\n",
            "phase", "calls", "seconds", "% wall", "p50", "p95", "p99"
        ));
        for (p, st) in rows {
            let pct = match wall_seconds {
                Some(w) if w > 0.0 => format!("{:>6.1}%", 100.0 * st.seconds() / w),
                _ => "      -".to_string(),
            };
            out.push_str(&format!(
                "{:<16} {:>10} {:>12.6} {} {:>10.3e} {:>10.3e} {:>10.3e}\n",
                p.name(),
                st.count,
                st.seconds(),
                pct,
                st.quantile_seconds(0.50),
                st.quantile_seconds(0.95),
                st.quantile_seconds(0.99),
            ));
        }
        for c in Counter::ALL {
            if self.counter(c) > 0 {
                out.push_str(&format!("counter {:<22} {}\n", c.name(), self.counter(c)));
            }
        }
        for g in Gauge::ALL {
            if self.gauge(g) > 0 {
                out.push_str(&format!("gauge   {:<22} {}\n", g.name(), self.gauge(g)));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Test-isolation note: spans are gated by the process-wide `enabled`
    // flag, and every test that enables it serializes on
    // `test_serial_guard()` — so while `enabled` is off, phases only move
    // through explicit `record_duration` calls and exact assertions are
    // safe. Counters and gauges are always-on and shared with library
    // code running in concurrent tests, so assertions on them are `>=`
    // (or immediate read-back for gauges).

    #[test]
    fn bucket_index_is_floor_log2() {
        assert_eq!(bucket_index(0), 0); // clamped up to 1
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1023), 9);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _guard = test_serial_guard();
        set_enabled(false);
        let base = snapshot();
        for _ in 0..10 {
            let _s = span(Phase::MatMul);
        }
        let delta = snapshot().delta_since(&base);
        assert_eq!(delta.calls(Phase::MatMul), 0);
        assert_eq!(delta.seconds(Phase::MatMul), 0.0);
    }

    #[test]
    fn enabled_span_records_count_and_time() {
        let _guard = test_serial_guard();
        set_enabled(true);
        let base = snapshot();
        for _ in 0..5 {
            let _s = span(Phase::CholFactor);
            std::thread::sleep(Duration::from_micros(200));
        }
        set_enabled(false);
        let delta = snapshot().delta_since(&base);
        assert!(delta.calls(Phase::CholFactor) >= 5, "{}", delta.calls(Phase::CholFactor));
        // 5 × ≥200µs of sleep must register at least ~1ms total
        assert!(delta.seconds(Phase::CholFactor) >= 0.8e-3, "{}", delta.seconds(Phase::CholFactor));
    }

    #[test]
    fn nested_spans_attribute_to_both_phases() {
        let _guard = test_serial_guard();
        set_enabled(false);
        let base = snapshot();
        // spans disabled: drive the same nesting through record_duration
        // to keep the totals exact, then check one live nested pair
        {
            let t_outer = Instant::now();
            std::thread::sleep(Duration::from_micros(200));
            {
                let t_inner = Instant::now();
                std::thread::sleep(Duration::from_micros(200));
                record_duration(Phase::Refit, t_inner.elapsed());
            }
            record_duration(Phase::Tell, t_outer.elapsed());
        }
        let delta = snapshot().delta_since(&base);
        assert_eq!(delta.calls(Phase::Tell), 1);
        assert_eq!(delta.calls(Phase::Refit), 1);
        // the outer phase contains the inner one
        assert!(
            delta.seconds(Phase::Tell) >= delta.seconds(Phase::Refit),
            "outer {} < inner {}",
            delta.seconds(Phase::Tell),
            delta.seconds(Phase::Refit)
        );
    }

    #[test]
    fn quantiles_track_recorded_durations() {
        let _guard = test_serial_guard();
        set_enabled(false);
        let base = snapshot();
        // 90 × 1µs + 10 × 1ms: p50 ~1µs bucket, p99 ~1ms bucket
        for _ in 0..90 {
            record_duration(Phase::LmlGrad, Duration::from_micros(1));
        }
        for _ in 0..10 {
            record_duration(Phase::LmlGrad, Duration::from_millis(1));
        }
        let delta = snapshot().delta_since(&base);
        let st = delta.phase(Phase::LmlGrad);
        assert_eq!(st.count, 100);
        assert_eq!(st.buckets.iter().sum::<u64>(), st.count, "one bucket per sample");
        let p50 = st.quantile_seconds(0.50);
        let p99 = st.quantile_seconds(0.99);
        // log2 buckets: representative within a factor of 2 of the truth
        assert!(p50 > 0.4e-6 && p50 < 3e-6, "p50 {p50}");
        assert!(p99 > 0.4e-3 && p99 < 3e-3, "p99 {p99}");
        assert!(st.quantile_seconds(0.0) <= p50 && p50 <= p99);
    }

    #[test]
    fn counters_and_gauges_are_always_on() {
        let _guard = test_serial_guard();
        set_enabled(false);
        let base = snapshot();
        counter_add(Counter::Refits, 3);
        counter_add(Counter::Refits, 2);
        gauge_set(Gauge::ModelSamples, 123_456);
        let now = snapshot();
        let delta = now.delta_since(&base);
        assert!(delta.counter(Counter::Refits) >= 5, "{}", delta.counter(Counter::Refits));
        assert_eq!(now.gauge(Gauge::ModelSamples), 123_456);
    }

    #[test]
    fn concurrent_updates_through_thread_pool_all_land() {
        let _guard = test_serial_guard();
        set_enabled(false);
        let base = snapshot();
        let pool = crate::pool::ThreadPool::new(4);
        const JOBS: usize = 64;
        for _ in 0..JOBS {
            pool.execute(|| {
                record_duration(Phase::CrossCov, Duration::from_micros(10));
                counter_add(Counter::QeiMcDraws, 2);
            });
        }
        pool.wait_idle();
        let delta = snapshot().delta_since(&base);
        // spans disabled: CrossCov moves only via the jobs above, so the
        // count is exact even with other tests running in parallel
        assert_eq!(delta.calls(Phase::CrossCov), JOBS as u64);
        assert!(delta.counter(Counter::QeiMcDraws) >= 2 * JOBS as u64);
        assert!(delta.counter(Counter::PoolJobs) >= JOBS as u64);
        let st = delta.phase(Phase::CrossCov);
        assert_eq!(st.buckets.iter().sum::<u64>(), st.count);
    }

    #[test]
    fn pool_jobs_report_queue_wait_and_execute_time() {
        let _guard = test_serial_guard();
        set_enabled(true);
        let base = snapshot();
        let pool = crate::pool::ThreadPool::new(2);
        for _ in 0..8 {
            pool.execute(|| std::thread::sleep(Duration::from_micros(500)));
        }
        pool.wait_idle();
        set_enabled(false);
        let delta = snapshot().delta_since(&base);
        assert!(delta.calls(Phase::PoolExec) >= 8, "{}", delta.calls(Phase::PoolExec));
        assert!(delta.calls(Phase::PoolQueueWait) >= 8, "{}", delta.calls(Phase::PoolQueueWait));
        // 8 × ≥500µs of sleep on the workers
        assert!(delta.seconds(Phase::PoolExec) >= 3e-3, "{}", delta.seconds(Phase::PoolExec));
    }

    #[test]
    fn delta_since_isolates_a_region() {
        let _guard = test_serial_guard();
        set_enabled(false);
        record_duration(Phase::Ask, Duration::from_micros(5));
        let base = snapshot();
        record_duration(Phase::Ask, Duration::from_micros(5));
        record_duration(Phase::Ask, Duration::from_micros(5));
        let delta = snapshot().delta_since(&base);
        assert_eq!(delta.calls(Phase::Ask), 2);
    }

    /// Deterministic snapshot for the renderer tests: nothing shared,
    /// nothing racy.
    fn synthetic_snapshot() -> Snapshot {
        let mut phases: Vec<PhaseStats> = (0..Phase::COUNT).map(|_| PhaseStats::zero()).collect();
        let cell = &mut phases[Phase::DenseFit as usize];
        cell.count = 3;
        cell.total_ns = 6_000_000; // 6 ms
        cell.buckets[bucket_index(2_000_000)] = 3;
        let mut counters = vec![0u64; Counter::COUNT];
        counters[Counter::Refits as usize] = 1;
        let mut gauges = vec![0u64; Gauge::COUNT];
        gauges[Gauge::InducingPoints as usize] = 64;
        Snapshot { phases, counters, gauges }
    }

    #[test]
    fn json_renders_recorded_phases_and_omits_idle_ones() {
        let snap = synthetic_snapshot();
        let json = snap.to_json();
        assert!(json.contains(r#""phase":"dense_fit""#), "{json}");
        assert!(json.contains(r#""calls":3"#), "{json}");
        assert!(json.contains(r#""refits":1"#), "{json}");
        assert!(json.contains(r#""inducing_points":64"#), "{json}");
        // zero-call phases are omitted
        assert!(!json.contains("qei_mc"), "{json}");
        assert_eq!(json.matches(r#""phase":"#).count(), 1, "{json}");
    }

    #[test]
    fn prometheus_and_table_render() {
        let snap = synthetic_snapshot();
        let prom = snap.to_prometheus();
        assert!(prom.contains(r#"limbo_phase_calls_total{phase="dense_fit"} 3"#), "{prom}");
        assert!(prom.contains("limbo_refits_total 1"), "{prom}");
        assert!(prom.contains("limbo_inducing_points 64"), "{prom}");
        assert!(prom.contains("# TYPE limbo_phase_seconds_total counter"), "{prom}");
        assert!(
            prom.contains(r#"limbo_phase_latency_seconds{phase="dense_fit",quantile="0.5"}"#),
            "{prom}"
        );
        let table = snap.render_table(Some(0.012));
        assert!(table.contains("dense_fit"), "{table}");
        assert!(table.contains("50.0%"), "6ms of 12ms wall: {table}");
        assert_eq!(snap.service_seconds(), 0.0);
    }
}
