//! Stopping criteria — the `limbo::stop::*` policy family.
//!
//! Criteria inspect the [`StopContext`] snapshot the shared engine
//! exposes ([`crate::bayes_opt::BoCore::stop_context`]); the
//! run-to-completion frontend checks its criterion against it before
//! every model-guided proposal.

/// Snapshot of the run the criteria inspect each iteration.
#[derive(Clone, Copy, Debug)]
pub struct StopContext {
    /// Iterations completed (excluding initialization).
    pub iteration: usize,
    /// Total evaluations (including initialization).
    pub evaluations: usize,
    /// Incumbent best value.
    pub best: f64,
}

/// A stop rule; the loop ends when any active criterion fires.
pub trait StopCriterion: Send + Sync {
    /// Should the run stop now?
    fn stop(&self, ctx: &StopContext) -> bool;
}

/// Stop after a fixed number of iterations (Limbo's `stop::MaxIterations`).
#[derive(Clone, Debug)]
pub struct MaxIterations(pub usize);

impl StopCriterion for MaxIterations {
    fn stop(&self, ctx: &StopContext) -> bool {
        ctx.iteration >= self.0
    }
}

/// Stop once the best value reaches a target (Limbo's
/// `stop::MaxPredictedValue` analogue on observations).
#[derive(Clone, Debug)]
pub struct TargetReached(pub f64);

impl StopCriterion for TargetReached {
    fn stop(&self, ctx: &StopContext) -> bool {
        ctx.best >= self.0
    }
}

/// Stop after a total evaluation budget (init + iterations).
#[derive(Clone, Debug)]
pub struct MaxEvaluations(pub usize);

impl StopCriterion for MaxEvaluations {
    fn stop(&self, ctx: &StopContext) -> bool {
        ctx.evaluations >= self.0
    }
}

/// Fire when *any* of the inner criteria fires.
pub struct AnyOf(pub Vec<Box<dyn StopCriterion>>);

impl StopCriterion for AnyOf {
    fn stop(&self, ctx: &StopContext) -> bool {
        self.0.iter().any(|c| c.stop(ctx))
    }
}

impl<A: StopCriterion, B: StopCriterion> StopCriterion for (A, B) {
    fn stop(&self, ctx: &StopContext) -> bool {
        self.0.stop(ctx) || self.1.stop(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(iteration: usize, best: f64) -> StopContext {
        StopContext { iteration, evaluations: iteration + 10, best }
    }

    #[test]
    fn max_iterations_fires_at_limit() {
        let s = MaxIterations(5);
        assert!(!s.stop(&ctx(4, 0.0)));
        assert!(s.stop(&ctx(5, 0.0)));
    }

    #[test]
    fn target_reached() {
        let s = TargetReached(1.0);
        assert!(!s.stop(&ctx(0, 0.5)));
        assert!(s.stop(&ctx(0, 1.0)));
    }

    #[test]
    fn tuple_composition_is_or() {
        let s = (MaxIterations(5), TargetReached(1.0));
        assert!(s.stop(&ctx(2, 2.0)));
        assert!(s.stop(&ctx(7, 0.0)));
        assert!(!s.stop(&ctx(2, 0.0)));
    }

    #[test]
    fn any_of_dynamic() {
        let s = AnyOf(vec![Box::new(MaxIterations(3)), Box::new(MaxEvaluations(100))]);
        assert!(s.stop(&ctx(3, 0.0)));
        assert!(!s.stop(&ctx(1, 0.0)));
    }
}
