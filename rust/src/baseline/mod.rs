//! The Figure-1 comparator: a BayesOpt-shaped Bayesian optimizer built the
//! classic object-oriented way.
//!
//! The paper's benchmark compares Limbo (policy-based, statically
//! dispatched) against BayesOpt (Martinez-Cantin 2014), a classic C++
//! class-hierarchy library, at **equal algorithmic settings** ("Limbo is
//! configured to reproduce the default parameters of BayesOpt"). We cannot
//! link the original BayesOpt offline, so this module reproduces its
//! *design style* faithfully and measurably:
//!
//! * every component behind a `Box<dyn ...>` (virtual dispatch on each
//!   kernel/mean/acquisition call — the cost Driesen & Hölzle quantify and
//!   the paper's design explicitly avoids),
//! * the GP re-factors the full Gram matrix on every new sample (O(n^3)
//!   per iteration instead of the incremental O(n^2) update),
//! * scratch vectors are allocated per call instead of reused,
//! * predictions stay point-by-point: [`DynGp`] deliberately does **not**
//!   override [`Model::predict_batch`], so population-based inner
//!   optimizers pay one virtual-dispatch `predict` per candidate.
//!
//! The *loop*, however, is the shared [`BoCore`] engine —
//! [`BayesOptLike::optimize`] drives the same propose/observe/refit
//! state machine as [`crate::bayes_opt::BOptimizer`] and the ask/tell
//! server, with trait-object components plugged in ([`DynGp`] implements
//! [`Model`], [`DynAcquiFn`] adapts a boxed [`DynAcqui`]). Accuracy must
//! therefore match the static implementation (pinned by an integration
//! test); only wall-clock differs — the paper's entire point.
//!
//! Algorithmic defaults mirror BayesOpt's: LHS(10) initialization,
//! ARD Matérn-5/2 kernel, Expected Improvement, DIRECT inner optimizer,
//! and (optionally) ML-II hyper-parameter refits on a fixed schedule.

use crate::acqui::{norm_cdf, norm_pdf, AcquiContext, AcquiFn};
use crate::bayes_opt::core::{BoCore, RefitSchedule};
use crate::bayes_opt::{Best, Evaluator};
use crate::la::CholeskyFactor;
use crate::la::Matrix;
use crate::model::Model;
use crate::opt::rprop::{rprop_maximize, RpropParams};
use crate::opt::Direct;
use crate::rng::{latin_hypercube, Pcg64};

/// Object-safe kernel interface (the OO mirror of [`crate::kernel::Kernel`]).
pub trait DynKernel: Send + Sync {
    /// Evaluate `k(a, b)`.
    fn eval(&self, a: &[f64], b: &[f64]) -> f64;
    /// Log-hyper-params.
    fn params(&self) -> Vec<f64>;
    /// Set log-hyper-params.
    fn set_params(&mut self, p: &[f64]);
    /// Gradient w.r.t. log-hyper-params (allocates, OO style).
    fn grad_params(&self, a: &[f64], b: &[f64]) -> Vec<f64>;
    /// Signal variance.
    fn variance(&self) -> f64;
    /// Clone into a box (OO prototype pattern).
    fn clone_box(&self) -> Box<dyn DynKernel>;
}

/// ARD Matérn-5/2, boxed-style (BayesOpt's `kMaternARD5` default).
#[derive(Clone)]
pub struct DynMatern52 {
    log_ls: Vec<f64>,
    log_sf: f64,
}

impl DynMatern52 {
    /// Unit lengthscales/variance.
    pub fn new(dim: usize) -> Self {
        Self { log_ls: vec![0.0; dim], log_sf: 0.0 }
    }
}

const SQRT5: f64 = 2.2360679774997896;

impl DynKernel for DynMatern52 {
    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        // allocates the scaled diff vector each call (OO style)
        let diffs: Vec<f64> = a
            .iter()
            .zip(b)
            .zip(&self.log_ls)
            .map(|((&x, &y), &ll)| (x - y) * (-ll).exp())
            .collect();
        let r2: f64 = diffs.iter().map(|d| d * d).sum();
        let r = r2.sqrt();
        self.variance() * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * (-SQRT5 * r).exp()
    }

    fn params(&self) -> Vec<f64> {
        let mut p = self.log_ls.clone();
        p.push(self.log_sf);
        p
    }

    fn set_params(&mut self, p: &[f64]) {
        let d = self.log_ls.len();
        self.log_ls = p[..d].to_vec();
        self.log_sf = p[d];
    }

    fn grad_params(&self, a: &[f64], b: &[f64]) -> Vec<f64> {
        let d = self.log_ls.len();
        let mut out = vec![0.0; d + 1];
        let diffs: Vec<f64> = a
            .iter()
            .zip(b)
            .zip(&self.log_ls)
            .map(|((&x, &y), &ll)| (x - y) * (-ll).exp())
            .collect();
        let r2: f64 = diffs.iter().map(|t| t * t).sum();
        let r = r2.sqrt();
        let sf2 = self.variance();
        let coeff = sf2 * (5.0 / 3.0) * (1.0 + SQRT5 * r) * (-SQRT5 * r).exp();
        for i in 0..d {
            out[i] = coeff * diffs[i] * diffs[i];
        }
        out[d] = 2.0 * sf2 * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * (-SQRT5 * r).exp();
        out
    }

    fn variance(&self) -> f64 {
        (2.0 * self.log_sf).exp()
    }

    fn clone_box(&self) -> Box<dyn DynKernel> {
        Box::new(self.clone())
    }
}

/// Object-safe acquisition interface.
pub trait DynAcqui: Send + Sync {
    /// Score a candidate from the posterior and the incumbent.
    fn eval(&self, mu: f64, var: f64, best: f64) -> f64;
}

/// Expected Improvement (BayesOpt's `cEI` default criterion).
pub struct DynEi {
    /// Exploration jitter.
    pub xi: f64,
}

impl DynAcqui for DynEi {
    fn eval(&self, mu: f64, var: f64, best: f64) -> f64 {
        let sigma = var.sqrt();
        let best = if best.is_finite() { best } else { 0.0 };
        if sigma < 1e-12 {
            return (mu - best - self.xi).max(0.0);
        }
        let z = (mu - best - self.xi) / sigma;
        (mu - best - self.xi) * norm_cdf(z) + sigma * norm_pdf(z)
    }
}

/// Adapter exposing a boxed [`DynAcqui`] as the [`AcquiFn`] policy the
/// shared core expects: every score goes through the virtual `eval` and
/// a virtual-dispatch point prediction, preserving the OO cost profile
/// inside the unified loop.
pub struct DynAcquiFn {
    inner: Box<dyn DynAcqui>,
}

impl DynAcquiFn {
    /// Wrap a boxed acquisition.
    pub fn new(inner: Box<dyn DynAcqui>) -> Self {
        Self { inner }
    }
}

impl AcquiFn<DynGp> for DynAcquiFn {
    fn eval(&self, model: &DynGp, x: &[f64], ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        self.inner.eval(mu, var, ctx.best())
    }
    // no eval_batch override: the default per-candidate loop is the
    // point — the baseline must not benefit from the batched posterior
}

/// The OO Gaussian process: boxed kernel, full refit on every new sample.
pub struct DynGp {
    kernel: Box<dyn DynKernel>,
    noise_var: f64,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    mean: f64,
    chol: Option<CholeskyFactor>,
    alpha: Vec<f64>,
    /// Rprop iterations per ML-II refit (used by the [`Model`] hook).
    pub hp_iters: usize,
}

impl Clone for DynGp {
    fn clone(&self) -> Self {
        Self {
            kernel: self.kernel.clone_box(),
            noise_var: self.noise_var,
            xs: self.xs.clone(),
            ys: self.ys.clone(),
            mean: self.mean,
            chol: self.chol.clone(),
            alpha: self.alpha.clone(),
            hp_iters: self.hp_iters,
        }
    }
}

impl DynGp {
    /// New empty GP around a boxed kernel.
    pub fn new(kernel: Box<dyn DynKernel>, noise: f64) -> Self {
        Self {
            kernel,
            noise_var: noise * noise,
            xs: Vec::new(),
            ys: Vec::new(),
            mean: 0.0,
            chol: None,
            alpha: Vec::new(),
            hp_iters: 20,
        }
    }

    /// Full Gram rebuild + factorization + alpha.
    pub fn refit(&mut self) {
        let n = self.xs.len();
        if n == 0 {
            self.chol = None;
            return;
        }
        self.mean = self.ys.iter().sum::<f64>() / n as f64;
        let mut jitter = 0.0;
        loop {
            let mut k = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    // full (not triangular) rebuild — the naive OO loop
                    k[(i, j)] = self.kernel.eval(&self.xs[i], &self.xs[j]);
                }
                k[(i, i)] += self.noise_var + jitter;
            }
            match CholeskyFactor::factor(&k) {
                Ok(ch) => {
                    let resid: Vec<f64> = self.ys.iter().map(|&y| y - self.mean).collect();
                    self.alpha = ch.solve(&resid);
                    self.chol = Some(ch);
                    return;
                }
                Err(_) if jitter < 1e-2 => {
                    jitter = if jitter == 0.0 { 1e-10 } else { jitter * 10.0 };
                }
                Err(e) => panic!("baseline GP singular: {e}"),
            }
        }
    }

    /// Log marginal likelihood.
    pub fn lml(&self) -> f64 {
        let Some(chol) = &self.chol else { return 0.0 };
        let n = self.xs.len() as f64;
        let resid: Vec<f64> = self.ys.iter().map(|&y| y - self.mean).collect();
        -0.5 * crate::la::dot(&resid, &self.alpha)
            - 0.5 * chol.log_det()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }

    /// LML gradient w.r.t. kernel log-params (allocating OO loops).
    pub fn lml_grad(&self) -> Vec<f64> {
        let n = self.xs.len();
        let np = self.kernel.params().len();
        let mut grad = vec![0.0; np];
        let Some(chol) = &self.chol else { return grad };
        let mut kinv = Matrix::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = chol.solve(&e);
            for i in 0..n {
                kinv[(i, j)] = col[i];
            }
        }
        for i in 0..n {
            for j in 0..n {
                let w = self.alpha[i] * self.alpha[j] - kinv[(i, j)];
                let dk = self.kernel.grad_params(&self.xs[i], &self.xs[j]);
                for (g, d) in grad.iter_mut().zip(dk) {
                    *g += 0.5 * w * d;
                }
            }
        }
        grad
    }

    /// ML-II refit of the kernel hyper-parameters with Rprop.
    pub fn refit_hyperparams(&mut self, iterations: usize) {
        if self.xs.len() < 2 {
            return;
        }
        let x0 = self.kernel.params();
        let params = RpropParams { iterations, ..RpropParams::default() };
        let best = rprop_maximize(
            |p| {
                self.kernel.set_params(p);
                self.refit();
                (self.lml(), self.lml_grad())
            },
            &x0,
            &params,
            Some((-6.0, 6.0)),
        );
        self.kernel.set_params(&best);
        self.refit();
    }
}

impl Model for DynGp {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.refit();
    }

    /// Add a sample; BayesOpt-style **full** O(n^3) refit.
    fn add_sample(&mut self, x: &[f64], y: f64) {
        self.xs.push(x.to_vec());
        self.ys.push(y);
        self.refit();
    }

    /// Posterior mean/variance (allocates the k* vector each call).
    fn predict(&self, x: &[f64]) -> (f64, f64) {
        let Some(chol) = &self.chol else {
            return (self.mean, self.kernel.variance());
        };
        let ks: Vec<f64> = self.xs.iter().map(|xi| self.kernel.eval(xi, x)).collect();
        let mu = self.mean + crate::la::dot(&ks, &self.alpha);
        let v = chol.solve_lower(&ks);
        let var = (self.kernel.variance() - crate::la::dot(&v, &v)).max(1e-12);
        (mu, var)
    }

    fn n_samples(&self) -> usize {
        self.xs.len()
    }

    /// Input dimension (0 before the first sample — the OO design never
    /// stored it, BayesOpt-style).
    fn dim(&self) -> usize {
        self.xs.first().map_or(0, Vec::len)
    }

    fn best_observation(&self) -> Option<f64> {
        self.ys.iter().copied().filter(|y| y.is_finite()).reduce(f64::max)
    }

    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        crate::model::best_sample_of(&self.xs, &self.ys)
    }

    fn optimize_hyperparams(&mut self) {
        self.refit_hyperparams(self.hp_iters);
    }
}

/// BayesOpt-default configuration knobs.
pub struct BayesOptLikeConfig {
    /// LHS initialization size (BayesOpt `n_init_samples` default 10).
    pub n_init: usize,
    /// Model-guided iterations (BayesOpt `n_iterations`).
    pub iterations: usize,
    /// DIRECT budget per acquisition maximization.
    pub inner_evals: usize,
    /// ML-II hyper-parameter refits: `Some(k)` = every k samples.
    pub hp_every: Option<usize>,
    /// Rprop iterations per hyper-parameter refit.
    pub hp_iters: usize,
    /// Observation noise std.
    pub noise: f64,
}

impl Default for BayesOptLikeConfig {
    fn default() -> Self {
        Self {
            n_init: 10,
            iterations: 40,
            inner_evals: 500,
            hp_every: None,
            hp_iters: 20,
            noise: 1e-2,
        }
    }
}

/// The dynamically-dispatched optimizer (the "BayesOpt" column of Fig. 1):
/// trait-object components driven through the same [`BoCore`] loop as the
/// static implementation.
pub struct BayesOptLike {
    /// Configuration.
    pub config: BayesOptLikeConfig,
    /// RNG.
    pub rng: Pcg64,
}

impl BayesOptLike {
    /// Default configuration with a seed.
    pub fn new(seed: u64) -> Self {
        Self { config: BayesOptLikeConfig::default(), rng: Pcg64::seed(seed) }
    }

    /// Run the OO-component loop on `f` via the shared core.
    pub fn optimize(&mut self, f: &dyn Evaluator) -> Best {
        let dim = f.dim();
        let mut gp = DynGp::new(Box::new(DynMatern52::new(dim)), self.config.noise);
        gp.hp_iters = self.config.hp_iters;
        let acqui = DynAcquiFn::new(Box::new(DynEi { xi: 0.01 }));
        let inner = Direct::new(self.config.inner_evals);
        let refit = match self.config.hp_every {
            Some(k) => RefitSchedule::Every(k),
            None => RefitSchedule::Never,
        };
        let mut core = BoCore::new(gp, acqui, inner, dim, 0).with_refit(refit);
        // continue this instance's RNG stream across optimize() calls
        core.rng = self.rng.clone();

        let design = latin_hypercube(self.config.n_init, dim, &mut core.rng);
        core.seed_design(design);
        while core.init_pending() > 0 {
            let x = core.propose();
            let y = f.eval(&x);
            core.observe(&x, y);
        }
        for _ in 0..self.config.iterations {
            let x = core.propose();
            let y = f.eval(&x);
            core.observe(&x, y);
        }
        core.finish();
        self.rng = core.rng.clone();
        let (x, value) = core.best().unwrap_or_else(|| (vec![0.5; dim], f64::NEG_INFINITY));
        Best { x, value, evaluations: core.evaluations() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayes_opt::FnEval;
    use crate::benchfns::{Branin, TestFunction};

    #[test]
    fn dyn_gp_matches_static_gp_predictions() {
        use crate::kernel::Matern52;
        use crate::mean::DataMean;
        use crate::model::{gp::Gp, Model};
        let mut rng = Pcg64::seed(8);
        let xs: Vec<Vec<f64>> = (0..12).map(|_| rng.unit_point(2)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (4.0 * x[0]).sin() + x[1]).collect();

        let mut dynamic = DynGp::new(Box::new(DynMatern52::new(2)), 1e-2);
        for (x, &y) in xs.iter().zip(&ys) {
            dynamic.add_sample(x, y);
        }
        let mut stat = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        stat.fit(&xs, &ys);

        for probe in [[0.1, 0.9], [0.5, 0.5], [0.77, 0.21]] {
            let (md, vd) = dynamic.predict(&probe);
            let (ms, vs) = stat.predict(&probe);
            assert!((md - ms).abs() < 1e-9, "mu {md} vs {ms}");
            assert!((vd - vs).abs() < 1e-9, "var {vd} vs {vs}");
        }
    }

    #[test]
    fn dyn_gp_model_interface_tracks_best() {
        let mut gp = DynGp::new(Box::new(DynMatern52::new(1)), 1e-2);
        assert_eq!(gp.dim(), 0, "dim unknown before data, OO-style");
        gp.fit(&[vec![0.2], vec![0.7]], &[1.0, 3.0]);
        assert_eq!(gp.dim(), 1);
        assert_eq!(gp.best_observation(), Some(3.0));
        assert_eq!(gp.best_sample(), Some((vec![0.7], 3.0)));
    }

    #[test]
    fn baseline_solves_branin_coarsely() {
        let mut opt = BayesOptLike::new(21);
        opt.config.iterations = 30;
        let branin = Branin;
        let best = opt.optimize(&FnEval::new(2, |x: &[f64]| branin.eval(x)));
        let acc = branin.accuracy(best.value);
        // 40 evaluations with fixed unit hyper-params is a smoke check,
        // not the benchmark protocol (Fig. 1 uses more iterations + HPO)
        assert!(acc < 5.0, "accuracy={acc}");
        assert_eq!(best.evaluations, 40);
    }

    #[test]
    fn hp_refit_path_runs() {
        let mut opt = BayesOptLike::new(5);
        opt.config.iterations = 6;
        opt.config.n_init = 6;
        opt.config.hp_every = Some(2);
        opt.config.hp_iters = 5;
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.3).powi(2)));
        assert!(best.value > -0.05, "best={}", best.value);
    }
}
