//! Standard optimization test functions (the sfu.ca suite the paper's
//! Figure 1 uses), all exposed as **maximization** problems over the unit
//! hypercube: inputs in `[0,1]^d` are scaled to each function's native
//! domain internally, and values are negated.
//!
//! `optimum()` returns the best achievable (maximized) value, so the
//! Figure-1 "accuracy" statistic is `optimum() - best_found` (>= 0).

use std::f64::consts::PI;

/// A benchmark function with known optimum.
pub trait TestFunction: Send + Sync {
    /// Canonical name (used in benchmark tables).
    fn name(&self) -> &'static str;
    /// Input dimensionality.
    fn dim(&self) -> usize;
    /// Evaluate at `u` in `[0,1]^dim` (maximization).
    fn eval(&self, u: &[f64]) -> f64;
    /// The global maximum value (after negation/scaling).
    fn optimum(&self) -> f64;
    /// Accuracy of a result: `optimum - value` (the Figure-1 statistic).
    fn accuracy(&self, value: f64) -> f64 {
        self.optimum() - value
    }
}

#[inline]
fn scale(u: f64, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * u
}

macro_rules! simple_fn {
    ($(#[$meta:meta])* $name:ident, $str:literal, $dim_field:ident) => {
        $(#[$meta])*
        #[derive(Clone, Debug)]
        pub struct $name {
            /// Dimensionality.
            pub $dim_field: usize,
        }
        impl $name {
            /// Construct with dimension `d`.
            pub fn new(d: usize) -> Self {
                Self { $dim_field: d }
            }
        }
    };
}

simple_fn!(
    /// Sphere: `-sum (x_i - 0.5)^2` on the unit cube (optimum 0 at 0.5·1).
    Sphere, "sphere", dim
);

impl TestFunction for Sphere {
    fn name(&self) -> &'static str {
        "sphere"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        -u.iter().map(|&v| (v - 0.5) * (v - 0.5)).sum::<f64>()
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

simple_fn!(
    /// Axis-parallel hyper-ellipsoid on [-5.12, 5.12]^d, negated.
    Ellipsoid, "ellipsoid", dim
);

impl TestFunction for Ellipsoid {
    fn name(&self) -> &'static str {
        "ellipsoid"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        -u.iter()
            .enumerate()
            .map(|(i, &v)| {
                let x = scale(v, -5.12, 5.12);
                (i + 1) as f64 * x * x
            })
            .sum::<f64>()
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

simple_fn!(
    /// Rastrigin on [-5.12, 5.12]^d, negated (global max 0 at the center).
    Rastrigin, "rastrigin", dim
);

impl TestFunction for Rastrigin {
    fn name(&self) -> &'static str {
        "rastrigin"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let a = 10.0;
        -(a * self.dim as f64
            + u.iter()
                .map(|&v| {
                    let x = scale(v, -5.12, 5.12);
                    x * x - a * (2.0 * PI * x).cos()
                })
                .sum::<f64>())
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

simple_fn!(
    /// Ackley on [-32.768, 32.768]^d, negated (global max 0 at the center).
    Ackley, "ackley", dim
);

impl TestFunction for Ackley {
    fn name(&self) -> &'static str {
        "ackley"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let d = self.dim as f64;
        let (mut s1, mut s2) = (0.0, 0.0);
        for &v in u {
            let x = scale(v, -32.768, 32.768);
            s1 += x * x;
            s2 += (2.0 * PI * x).cos();
        }
        -(-20.0 * (-0.2 * (s1 / d).sqrt()).exp() - (s2 / d).exp()
            + 20.0
            + std::f64::consts::E)
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

simple_fn!(
    /// Rosenbrock on [-2.048, 2.048]^d, negated (max 0 at 1·vec).
    Rosenbrock, "rosenbrock", dim
);

impl TestFunction for Rosenbrock {
    fn name(&self) -> &'static str {
        "rosenbrock"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let x: Vec<f64> = u.iter().map(|&v| scale(v, -2.048, 2.048)).collect();
        -(0..self.dim - 1)
            .map(|i| 100.0 * (x[i + 1] - x[i] * x[i]).powi(2) + (1.0 - x[i]).powi(2))
            .sum::<f64>()
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

simple_fn!(
    /// Levy on [-10, 10]^d, negated (max 0 at 1·vec).
    Levy, "levy", dim
);

impl TestFunction for Levy {
    fn name(&self) -> &'static str {
        "levy"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let w: Vec<f64> =
            u.iter().map(|&v| 1.0 + (scale(v, -10.0, 10.0) - 1.0) / 4.0).collect();
        let d = self.dim;
        let mut s = (PI * w[0]).sin().powi(2);
        for i in 0..d - 1 {
            s += (w[i] - 1.0).powi(2) * (1.0 + 10.0 * (PI * w[i] + 1.0).sin().powi(2));
        }
        s += (w[d - 1] - 1.0).powi(2) * (1.0 + (2.0 * PI * w[d - 1]).sin().powi(2));
        -s
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

simple_fn!(
    /// Schwefel on [-500, 500]^d, negated (max 0 at 420.9687·vec).
    Schwefel, "schwefel", dim
);

impl TestFunction for Schwefel {
    fn name(&self) -> &'static str {
        "schwefel"
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let d = self.dim as f64;
        -(418.9829 * d
            - u.iter()
                .map(|&v| {
                    let x = scale(v, -500.0, 500.0);
                    x * x.abs().sqrt().sin()
                })
                .sum::<f64>())
    }
    fn optimum(&self) -> f64 {
        0.0
    }
}

/// Branin (2-D) on [-5,10]x[0,15], negated (max -0.397887).
#[derive(Clone, Debug, Default)]
pub struct Branin;

impl TestFunction for Branin {
    fn name(&self) -> &'static str {
        "branin"
    }
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let x1 = scale(u[0], -5.0, 10.0);
        let x2 = scale(u[1], 0.0, 15.0);
        let a = 1.0;
        let b = 5.1 / (4.0 * PI * PI);
        let c = 5.0 / PI;
        let r = 6.0;
        let s = 10.0;
        let t = 1.0 / (8.0 * PI);
        -(a * (x2 - b * x1 * x1 + c * x1 - r).powi(2) + s * (1.0 - t) * x1.cos() + s)
    }
    fn optimum(&self) -> f64 {
        -0.39788735772973816
    }
}

/// Goldstein–Price (2-D) on [-2,2]^2, negated (max -3).
#[derive(Clone, Debug, Default)]
pub struct GoldsteinPrice;

impl TestFunction for GoldsteinPrice {
    fn name(&self) -> &'static str {
        "goldstein_price"
    }
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let x = scale(u[0], -2.0, 2.0);
        let y = scale(u[1], -2.0, 2.0);
        let a = 1.0
            + (x + y + 1.0).powi(2)
                * (19.0 - 14.0 * x + 3.0 * x * x - 14.0 * y + 6.0 * x * y + 3.0 * y * y);
        let b = 30.0
            + (2.0 * x - 3.0 * y).powi(2)
                * (18.0 - 32.0 * x + 12.0 * x * x + 48.0 * y - 36.0 * x * y + 27.0 * y * y);
        -(a * b)
    }
    fn optimum(&self) -> f64 {
        -3.0
    }
}

/// Six-hump camel (2-D) on [-3,3]x[-2,2], negated (max 1.0316).
#[derive(Clone, Debug, Default)]
pub struct SixHumpCamel;

impl TestFunction for SixHumpCamel {
    fn name(&self) -> &'static str {
        "six_hump_camel"
    }
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let x = scale(u[0], -3.0, 3.0);
        let y = scale(u[1], -2.0, 2.0);
        let x2 = x * x;
        let y2 = y * y;
        -((4.0 - 2.1 * x2 + x2 * x2 / 3.0) * x2 + x * y + (-4.0 + 4.0 * y2) * y2)
    }
    fn optimum(&self) -> f64 {
        1.0316284534898774
    }
}

/// Hartmann-3 on [0,1]^3 (max 3.86278).
#[derive(Clone, Debug, Default)]
pub struct Hartmann3;

const H3_A: [[f64; 3]; 4] =
    [[3.0, 10.0, 30.0], [0.1, 10.0, 35.0], [3.0, 10.0, 30.0], [0.1, 10.0, 35.0]];
const H3_P: [[f64; 3]; 4] = [
    [0.3689, 0.1170, 0.2673],
    [0.4699, 0.4387, 0.7470],
    [0.1091, 0.8732, 0.5547],
    [0.0382, 0.5743, 0.8828],
];
const H_ALPHA: [f64; 4] = [1.0, 1.2, 3.0, 3.2];

impl TestFunction for Hartmann3 {
    fn name(&self) -> &'static str {
        "hartmann3"
    }
    fn dim(&self) -> usize {
        3
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let mut outer = 0.0;
        for i in 0..4 {
            let mut inner = 0.0;
            for j in 0..3 {
                inner += H3_A[i][j] * (u[j] - H3_P[i][j]).powi(2);
            }
            outer += H_ALPHA[i] * (-inner).exp();
        }
        outer
    }
    fn optimum(&self) -> f64 {
        3.86278214782076
    }
}

/// Hartmann-6 on [0,1]^6 (max 3.32237).
#[derive(Clone, Debug, Default)]
pub struct Hartmann6;

const H6_A: [[f64; 6]; 4] = [
    [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
    [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
    [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
    [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
];
const H6_P: [[f64; 6]; 4] = [
    [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
    [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
    [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
    [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
];

impl TestFunction for Hartmann6 {
    fn name(&self) -> &'static str {
        "hartmann6"
    }
    fn dim(&self) -> usize {
        6
    }
    fn eval(&self, u: &[f64]) -> f64 {
        let mut outer = 0.0;
        for i in 0..4 {
            let mut inner = 0.0;
            for j in 0..6 {
                inner += H6_A[i][j] * (u[j] - H6_P[i][j]).powi(2);
            }
            outer += H_ALPHA[i] * (-inner).exp();
        }
        outer
    }
    fn optimum(&self) -> f64 {
        3.322368011391339
    }
}

/// Additive Gaussian observation noise around any test function.
pub struct Noisy<F: TestFunction> {
    /// The underlying function.
    pub inner: F,
    /// Noise std.
    pub sigma: f64,
    rng: std::sync::Mutex<crate::rng::Pcg64>,
}

impl<F: TestFunction> Noisy<F> {
    /// Wrap `inner` with observation noise of std `sigma`.
    pub fn new(inner: F, sigma: f64, seed: u64) -> Self {
        Self { inner, sigma, rng: std::sync::Mutex::new(crate::rng::Pcg64::seed(seed)) }
    }
}

impl<F: TestFunction> TestFunction for Noisy<F> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn eval(&self, u: &[f64]) -> f64 {
        self.inner.eval(u) + self.sigma * self.rng.lock().unwrap().normal()
    }
    fn optimum(&self) -> f64 {
        self.inner.optimum()
    }
}

/// The Figure-1 suite (names and dimensions the paper benchmarks).
pub fn figure1_suite() -> Vec<Box<dyn TestFunction>> {
    vec![
        Box::new(Branin),
        Box::new(Ackley::new(2)),
        Box::new(Ellipsoid::new(2)),
        Box::new(GoldsteinPrice),
        Box::new(SixHumpCamel),
        Box::new(Hartmann3),
        Box::new(Hartmann6),
        Box::new(Rastrigin::new(2)),
        Box::new(Sphere::new(2)),
    ]
}

/// Look up a suite function by name (CLI entry point).
pub fn by_name(name: &str, dim: usize) -> Option<Box<dyn TestFunction>> {
    Some(match name {
        "sphere" => Box::new(Sphere::new(dim)),
        "ellipsoid" => Box::new(Ellipsoid::new(dim)),
        "rastrigin" => Box::new(Rastrigin::new(dim)),
        "ackley" => Box::new(Ackley::new(dim)),
        "rosenbrock" => Box::new(Rosenbrock::new(dim.max(2))),
        "levy" => Box::new(Levy::new(dim)),
        "schwefel" => Box::new(Schwefel::new(dim)),
        "branin" => Box::new(Branin),
        "goldstein_price" => Box::new(GoldsteinPrice),
        "six_hump_camel" => Box::new(SixHumpCamel),
        "hartmann3" => Box::new(Hartmann3),
        "hartmann6" => Box::new(Hartmann6),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every function's claimed optimum must be attained at its known
    /// argmax (in unit coordinates) to high precision.
    #[test]
    fn optima_are_attained() {
        let unit = |x: f64, lo: f64, hi: f64| (x - lo) / (hi - lo);
        let cases: Vec<(Box<dyn TestFunction>, Vec<f64>)> = vec![
            (Box::new(Sphere::new(3)), vec![0.5; 3]),
            (Box::new(Ellipsoid::new(2)), vec![0.5; 2]),
            (Box::new(Rastrigin::new(2)), vec![0.5; 2]),
            (Box::new(Ackley::new(2)), vec![0.5; 2]),
            (
                Box::new(Branin),
                vec![unit(PI, -5.0, 10.0), unit(2.275, 0.0, 15.0)],
            ),
            (
                Box::new(GoldsteinPrice),
                vec![unit(0.0, -2.0, 2.0), unit(-1.0, -2.0, 2.0)],
            ),
            (
                Box::new(SixHumpCamel),
                vec![unit(0.0898, -3.0, 3.0), unit(-0.7126, -2.0, 2.0)],
            ),
            (
                Box::new(Hartmann3),
                vec![0.114614, 0.555649, 0.852547],
            ),
            (
                Box::new(Hartmann6),
                vec![0.20169, 0.150011, 0.476874, 0.275332, 0.311652, 0.6573],
            ),
            (
                Box::new(Rosenbrock::new(2)),
                vec![unit(1.0, -2.048, 2.048); 2],
            ),
            (Box::new(Levy::new(2)), vec![unit(1.0, -10.0, 10.0); 2]),
            (
                Box::new(Schwefel::new(2)),
                vec![unit(420.9687, -500.0, 500.0); 2],
            ),
        ];
        for (f, argmax) in cases {
            let v = f.eval(&argmax);
            assert!(
                (f.optimum() - v).abs() < 1e-3,
                "{}: optimum {} but f(argmax) = {v}",
                f.name(),
                f.optimum()
            );
            assert!(f.accuracy(v) < 1e-3);
        }
    }

    /// No point in a coarse sweep may beat the claimed optimum.
    #[test]
    fn optimum_is_an_upper_bound() {
        for f in figure1_suite() {
            let d = f.dim();
            let mut rng = crate::rng::Pcg64::seed(99);
            for _ in 0..2000 {
                let u = rng.unit_point(d);
                let v = f.eval(&u);
                assert!(
                    v <= f.optimum() + 1e-9,
                    "{} exceeded optimum: {v} > {}",
                    f.name(),
                    f.optimum()
                );
            }
        }
    }

    #[test]
    fn noisy_wrapper_perturbs_but_tracks() {
        let f = Noisy::new(Sphere::new(2), 0.1, 5);
        let v1 = f.eval(&[0.5, 0.5]);
        let v2 = f.eval(&[0.5, 0.5]);
        assert_ne!(v1, v2, "noise should vary");
        assert!(v1.abs() < 1.0 && v2.abs() < 1.0);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("branin", 2).is_some());
        assert_eq!(by_name("hartmann6", 0).unwrap().dim(), 6);
        assert!(by_name("nope", 2).is_none());
    }
}
