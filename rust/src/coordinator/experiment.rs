//! Replicated benchmark runner — the machinery behind Figure 1.
//!
//! Runs `replicates` seeded optimizations per (function, configuration)
//! cell in parallel over the thread pool, collects accuracy
//! (`optimum - best`) and wall-clock samples, and aggregates them into the
//! paper's box-plot statistics (median / quartiles / whiskers).

use std::time::Instant;

use crate::benchlib::Summary;
use crate::benchfns::TestFunction;
use crate::pool::parallel_map_catch;

/// One optimization run's outcome.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Best value found (`NaN` for a failed replicate).
    pub best_value: f64,
    /// Wall-clock seconds for the whole run.
    pub wall_secs: f64,
    /// Function evaluations used.
    pub evaluations: usize,
    /// Panic message if the replicate crashed (filled by the runner; a
    /// failed replicate is excluded from the aggregate statistics and
    /// counted in [`ExperimentRow::failures`]).
    pub failure: Option<String>,
}

impl RunOutcome {
    /// Successful run (wall-clock filled in by the runner).
    pub fn ok(best_value: f64, evaluations: usize) -> Self {
        Self { best_value, wall_secs: 0.0, evaluations, failure: None }
    }

    /// A replicate whose job panicked.
    pub fn failed(message: String) -> Self {
        Self { best_value: f64::NAN, wall_secs: 0.0, evaluations: 0, failure: Some(message) }
    }
}

/// A named, runnable optimizer configuration (one Figure-1 column).
pub trait BenchConfig: Sync {
    /// Column label ("limbo", "bayesopt", ...).
    fn name(&self) -> &str;
    /// Run once on `f` with the given seed, timing included by the caller.
    fn run(&self, f: &dyn TestFunction, seed: u64) -> RunOutcome;
}

/// Aggregated cell of the benchmark table.
#[derive(Clone, Debug)]
pub struct ExperimentRow {
    /// Test-function name.
    pub function: String,
    /// Configuration name.
    pub config: String,
    /// Accuracy statistics (`optimum - best`, lower = better) over the
    /// successful replicates.
    pub accuracy: Summary,
    /// Wall-clock statistics in seconds over the successful replicates.
    pub wall: Summary,
    /// Replicates run.
    pub replicates: usize,
    /// Replicates whose job panicked (surfaced per-job via
    /// [`RunOutcome::failure`], no longer a silent pool counter).
    pub failures: usize,
}

/// The replicated experiment driver.
pub struct ExperimentRunner {
    /// Replicates per cell (the paper uses 250).
    pub replicates: usize,
    /// Worker threads.
    pub threads: usize,
    /// Base seed; replicate `i` uses `base_seed + i`.
    pub base_seed: u64,
}

impl ExperimentRunner {
    /// Typical quick settings (30 replicates across all cores).
    pub fn quick() -> Self {
        Self { replicates: 30, threads: default_threads(), base_seed: 1000 }
    }

    /// The paper's full protocol (250 replicates).
    pub fn full() -> Self {
        Self { replicates: 250, threads: default_threads(), base_seed: 1000 }
    }

    /// Run one (function, config) cell. A replicate that panics becomes a
    /// failed [`RunOutcome`] (message preserved) instead of aborting the
    /// cell; statistics aggregate over the survivors.
    pub fn run_cell(&self, f: &dyn TestFunction, config: &dyn BenchConfig) -> ExperimentRow {
        let seeds: Vec<u64> = (0..self.replicates).map(|i| self.base_seed + i as u64).collect();
        let outcomes: Vec<RunOutcome> = parallel_map_catch(seeds, self.threads, |_, seed| {
            let t0 = Instant::now();
            let mut out = config.run(f, seed);
            out.wall_secs = t0.elapsed().as_secs_f64();
            out
        })
        .into_iter()
        .map(|r| r.unwrap_or_else(RunOutcome::failed))
        .collect();
        let ok: Vec<&RunOutcome> = outcomes.iter().filter(|o| o.failure.is_none()).collect();
        let failures = outcomes.len() - ok.len();
        for o in &outcomes {
            if let Some(msg) = &o.failure {
                eprintln!(
                    "[experiment] {}/{} replicate failed: {msg}",
                    f.name(),
                    config.name()
                );
            }
        }
        let acc: Vec<f64> = ok.iter().map(|o| f.accuracy(o.best_value)).collect();
        let wall: Vec<f64> = ok.iter().map(|o| o.wall_secs).collect();
        ExperimentRow {
            function: f.name().to_string(),
            config: config.name().to_string(),
            accuracy: Summary::from(&acc),
            wall: Summary::from(&wall),
            replicates: self.replicates,
            failures,
        }
    }

    /// Run the full grid (functions × configs).
    pub fn run_grid(
        &self,
        functions: &[Box<dyn TestFunction>],
        configs: &[&dyn BenchConfig],
    ) -> Vec<ExperimentRow> {
        let mut rows = Vec::new();
        for f in functions {
            for c in configs {
                rows.push(self.run_cell(f.as_ref(), *c));
            }
        }
        rows
    }
}

/// Pretty-print the Figure-1 style table plus pairwise speed-ups.
pub fn print_table(rows: &[ExperimentRow]) {
    println!(
        "{:<18} {:<16} {:>9} {:>6} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "function", "config", "reps", "fail", "acc.med", "acc.q1", "acc.q3", "time.med",
        "time.q3"
    );
    for r in rows {
        println!(
            "{:<18} {:<16} {:>9} {:>6} {:>10.2e} {:>10.2e} {:>10.2e} {:>9.3}s {:>9.3}s",
            r.function,
            r.config,
            r.replicates,
            r.failures,
            r.accuracy.median,
            r.accuracy.q1,
            r.accuracy.q3,
            r.wall.median,
            r.wall.q3,
        );
    }
}

/// Median speed-up of `fast` over `slow` per function (paper's headline
/// "Limbo is X times faster" numbers). Returns (function, ratio,
/// delta-median-accuracy) tuples.
pub fn speedups(
    rows: &[ExperimentRow],
    fast: &str,
    slow: &str,
) -> Vec<(String, f64, f64)> {
    let mut out = Vec::new();
    let functions: Vec<String> = {
        let mut v: Vec<String> = Vec::new();
        for r in rows {
            if !v.contains(&r.function) {
                v.push(r.function.clone());
            }
        }
        v
    };
    for f in functions {
        let find = |cfg: &str| rows.iter().find(|r| r.function == f && r.config == cfg);
        if let (Some(a), Some(b)) = (find(fast), find(slow)) {
            out.push((
                f,
                b.wall.median / a.wall.median,
                (a.accuracy.median - b.accuracy.median).abs(),
            ));
        }
    }
    out
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchfns::Sphere;

    struct FakeConfig(&'static str, f64);

    impl BenchConfig for FakeConfig {
        fn name(&self) -> &str {
            self.0
        }
        fn run(&self, _f: &dyn TestFunction, seed: u64) -> RunOutcome {
            // deterministic fake: accuracy depends on seed
            std::thread::sleep(std::time::Duration::from_micros(200));
            RunOutcome::ok(-self.1 * (1.0 + (seed % 5) as f64 * 0.1), 10)
        }
    }

    #[test]
    fn runs_replicates_and_aggregates() {
        let runner = ExperimentRunner { replicates: 10, threads: 4, base_seed: 0 };
        let row = runner.run_cell(&Sphere::new(2), &FakeConfig("fake", 0.5));
        assert_eq!(row.accuracy.n, 10);
        assert_eq!(row.failures, 0);
        assert!(row.accuracy.median > 0.0);
        assert!(row.wall.median > 0.0);
    }

    struct PanickyConfig;

    impl BenchConfig for PanickyConfig {
        fn name(&self) -> &str {
            "panicky"
        }
        fn run(&self, _f: &dyn TestFunction, seed: u64) -> RunOutcome {
            if seed % 3 == 0 {
                panic!("replicate {seed} exploded");
            }
            RunOutcome::ok(-0.25, 5)
        }
    }

    #[test]
    fn panicking_replicates_become_failures_not_aborts() {
        let runner = ExperimentRunner { replicates: 9, threads: 3, base_seed: 0 };
        // seeds 0..9: 0, 3, 6 panic -> 3 failures, 6 survivors
        let row = runner.run_cell(&Sphere::new(2), &PanickyConfig);
        assert_eq!(row.failures, 3);
        assert_eq!(row.replicates, 9);
        assert_eq!(row.accuracy.n, 6, "stats aggregate over survivors only");
        assert!(row.accuracy.median.is_finite());
    }

    #[test]
    fn speedups_pair_rows() {
        let runner = ExperimentRunner { replicates: 4, threads: 2, base_seed: 0 };
        let f = Sphere::new(2);
        let rows = vec![
            runner.run_cell(&f, &FakeConfig("fast", 0.1)),
            runner.run_cell(&f, &FakeConfig("slow", 0.1)),
        ];
        let s = speedups(&rows, "fast", "slow");
        assert_eq!(s.len(), 1);
        assert!(s[0].1 > 0.0);
    }
}
