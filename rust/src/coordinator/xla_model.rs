//! Adapter: the XLA-artifact GP backend as a [`Model`].
//!
//! Keeps the dataset on the Rust side, forwards predictions (batched,
//! padded into capacity tiers) to [`XlaGp`], and runs ML-II refits through
//! the AOT `lml` gradient artifact with the same Rprop the native GP uses.
//! Any kernel/mean/acquisition policy from the zoo composes with it.

use std::sync::Arc;

use crate::model::Model;
use crate::opt::rprop::{rprop_maximize, RpropParams};
use crate::runtime::XlaGp;

/// [`Model`] implementation backed by AOT-compiled XLA artifacts.
/// (`Clone` shares the backend via `Arc` and copies the dataset — cheap
/// enough for the ask/tell constant-liar scratch copy.)
#[derive(Clone)]
pub struct XlaGpModel {
    backend: Arc<XlaGp>,
    dim: usize,
    /// Log-hyper-params `[log l_1..log l_d, log sigma_f, log sigma_n]`.
    pub loghp: Vec<f64>,
    /// Whether refits tune the noise entry too.
    pub learn_noise: bool,
    /// Rprop iterations per [`optimize_hyperparams`](Model::optimize_hyperparams).
    pub hp_iters: usize,
    xs_flat: Vec<f64>,
    ys: Vec<f64>,
    best: Option<f64>,
}

impl XlaGpModel {
    /// New model for problem dimension `dim` over a backend.
    /// Initial hyper-params: unit lengthscales, unit signal, noise 1e-2.
    pub fn new(backend: Arc<XlaGp>, dim: usize) -> Self {
        assert!(dim <= backend.d_max(), "dim exceeds artifact d_max");
        let mut loghp = vec![0.0; dim + 2];
        loghp[dim + 1] = (1e-2f64).ln();
        Self {
            backend,
            dim,
            loghp,
            learn_noise: false,
            hp_iters: 30,
            xs_flat: Vec::new(),
            ys: Vec::new(),
            best: None,
        }
    }

    /// The prior-mean value passed to the artifacts (Data mean: average of
    /// the observations, matching the native default configuration).
    fn mean0(&self) -> f64 {
        if self.ys.is_empty() {
            0.0
        } else {
            self.ys.iter().sum::<f64>() / self.ys.len() as f64
        }
    }

    /// Fused UCB acquisition on a candidate block (the optimized hot path:
    /// one artifact call instead of predict + combine).
    pub fn ucb_batch(&self, xs: &[Vec<f64>], alpha: f64) -> Vec<f64> {
        let b = self.backend.batch_size();
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(b) {
            let flat: Vec<f64> = chunk.iter().flat_map(|x| x.iter().copied()).collect();
            let vals = self
                .backend
                .ucb(&self.xs_flat, &self.ys, self.dim, &flat, &self.loghp, self.mean0(), alpha)
                .expect("xla ucb");
            out.extend(vals);
        }
        out
    }

    /// Backend batch size (for batching-aware inner optimizers).
    pub fn batch_size(&self) -> usize {
        self.backend.batch_size()
    }
}

impl Model for XlaGpModel {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        self.xs_flat.clear();
        for x in xs {
            assert_eq!(x.len(), self.dim);
            self.xs_flat.extend_from_slice(x);
        }
        self.ys = ys.to_vec();
        self.best = ys.iter().cloned().fold(None, |b: Option<f64>, v| {
            Some(b.map_or(v, |b| b.max(v)))
        });
    }

    fn add_sample(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.dim);
        self.xs_flat.extend_from_slice(x);
        self.ys.push(y);
        self.best = Some(self.best.map_or(y, |b| b.max(y)));
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        if self.ys.is_empty() {
            let sf2 = (2.0 * self.loghp[self.dim]).exp();
            return (0.0, sf2);
        }
        let (mu, var) = self
            .backend
            .predict(&self.xs_flat, &self.ys, self.dim, x, &self.loghp, self.mean0())
            .expect("xla predict");
        (mu[0], var[0])
    }

    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        if self.ys.is_empty() {
            let sf2 = (2.0 * self.loghp[self.dim]).exp();
            return vec![(0.0, sf2); xs.len()];
        }
        let b = self.backend.batch_size();
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(b) {
            let flat: Vec<f64> = chunk.iter().flat_map(|x| x.iter().copied()).collect();
            let (mu, var) = self
                .backend
                .predict(&self.xs_flat, &self.ys, self.dim, &flat, &self.loghp, self.mean0())
                .expect("xla predict batch");
            out.extend(mu.into_iter().zip(var));
        }
        out
    }

    fn n_samples(&self) -> usize {
        self.ys.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn best_observation(&self) -> Option<f64> {
        self.best
    }

    fn optimize_hyperparams(&mut self) {
        if self.ys.len() < 2 {
            return;
        }
        let backend = self.backend.clone();
        let (xs, ys, dim, m0) = (self.xs_flat.clone(), self.ys.clone(), self.dim, self.mean0());
        let learn_noise = self.learn_noise;
        let params = RpropParams { iterations: self.hp_iters, ..RpropParams::default() };
        let best = rprop_maximize(
            |p| {
                let (lml, mut grad) =
                    backend.lml_grad(&xs, &ys, dim, p, m0).expect("xla lml");
                if !learn_noise {
                    grad[dim + 1] = 0.0;
                }
                (lml, grad)
            },
            &self.loghp,
            &params,
            Some((-6.0, 6.0)),
        );
        self.loghp = best;
    }
}
