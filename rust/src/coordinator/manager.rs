//! Multi-study coordination: thousands of concurrent optimizations
//! multiplexed over one shared [`ThreadPool`], each durable across
//! process restarts.
//!
//! The ask/tell server ([`super::service`]) scales the *single-study*
//! deployment mode: one optimization, one thread. A hyper-parameter
//! tuning service or a robot fleet runs *thousands* of concurrent
//! studies, most of them idle at any instant — one thread per study
//! wastes memory and scheduler pressure on parked threads. The
//! [`StudyManager`] inverts the ownership: studies are passive state in
//! a registry, client calls check a study out, run the operation as a
//! job on the shared pool, and check it back in. Per-study operations
//! serialize (checkout is exclusive); operations on *different* studies
//! run concurrently up to the pool width.
//!
//! # Identity and errors
//!
//! Studies are addressed by the opaque [`StudyId`] newtype and every
//! fallible operation returns a typed [`StudyError`] — no stringly ids,
//! no panics on the public surface. The [`Study`] trait is the common
//! ask/tell vocabulary implemented by the inline server, the spawned
//! server handle and the managed-study handle, so driver code is
//! generic over the deployment mode.
//!
//! # Durability: event sourcing + refit-barrier snapshots
//!
//! A manager built with [`StudyManager::durable`] gives every study a
//! directory holding an append-only JSONL event log (the exact
//! [`crate::stat::JsonlObserver`] format — 17-significant-digit floats,
//! so a replayed log reproduces the run bit-for-bit) and a periodic
//! snapshot. Recovery is snapshot load + tail replay through the *live*
//! code path: replayed proposals re-run the acquisition maximization
//! (advancing the RNG exactly as the original did), replayed
//! observations re-enter the model, and scheduled refits re-fire on the
//! same counts. No warm-start approximation — the rehydrated study
//! continues the exact trace of the lost one.
//!
//! Snapshots are only taken at a *refit barrier*: the moment right
//! after a scheduled ML-II refit, when the model's live state is — by
//! construction — exactly the state a fresh full fit at the restored
//! hyper-parameters reproduces. (Between refits the dense GP's
//! incremental Cholesky updates drift from a from-scratch factorization
//! at the rounding level; snapshotting there would break bit-exact
//! resume.) The event log covers everything after the barrier.
//!
//! # Eviction
//!
//! Live studies cost memory (a fitted GP, its factorizations). A
//! manager with [`StudyManager::with_max_live`] evicts the
//! least-recently-used durable study over the limit: the live state is
//! dropped (flushing its log) and the slot rehydrates transparently on
//! the next operation. Ephemeral (non-durable) studies are never
//! auto-evicted — an explicit [`StudyManager::evict`] discards them and
//! later operations report [`StudyError::Evicted`]. The
//! [`crate::obs::Gauge::LiveStudies`] / `EvictedStudies` gauges and the
//! [`crate::obs::Phase::Snapshot`] / `Replay` spans make the churn
//! observable.
//!
//! # Threading contract
//!
//! Manager calls block the *calling* thread on a reply channel while
//! the operation runs on the pool; pool workers never wait on other
//! jobs, so any number of client threads is safe. Do not call manager
//! operations from *inside* a job running on the same pool — that
//! reintroduces the worker-waits-on-worker cycle the design avoids.

use std::collections::HashMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};

use crate::acqui::AcquiFn;
use crate::bayes_opt::core::{BoError, BoEvent, CoreState, Observation, Observer};
use crate::model::{ModelState, StateModel};
use crate::obs::{self, Counter, Gauge, Phase};
use crate::opt::Optimizer;
use crate::pool::ThreadPool;
use crate::stat::{JsonlObserver, ReplayEvent};

use super::service::AskTellServer;

/// Opaque study identity: allocated by [`StudyManager::create`],
/// printable (`study-000042` — also the on-disk directory name), and
/// reconstructible after a restart via [`StudyId::from_u64`] for
/// [`StudyManager::recover`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StudyId(u64);

impl StudyId {
    /// The raw numeric id (persist this across restarts).
    pub fn as_u64(self) -> u64 {
        self.0
    }

    /// Rebuild an id from its persisted raw value.
    pub fn from_u64(raw: u64) -> Self {
        Self(raw)
    }
}

impl fmt::Display for StudyId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "study-{:06}", self.0)
    }
}

/// What can go wrong on the study surface. Every public manager and
/// handle operation returns this — no `unwrap`, no stringly errors.
#[derive(Clone, Debug, PartialEq)]
pub enum StudyError {
    /// No study with this id is registered.
    NotFound(StudyId),
    /// The study was evicted and has no durable state to rehydrate
    /// from (ephemeral study + explicit [`StudyManager::evict`]).
    Evicted(StudyId),
    /// The study (or server) was closed and accepts no more operations.
    Closed,
    /// The optimizer rejected the observation before mutating any state
    /// (e.g. [`BoError::ConstraintArity`] — the observation carried the
    /// wrong number of constraint-channel values for the study's model).
    Rejected(BoError),
    /// Durability I/O or log-replay failure (message carries the cause).
    Io(String),
}

impl fmt::Display for StudyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StudyError::NotFound(id) => write!(f, "{id} is not registered"),
            StudyError::Evicted(id) => {
                write!(f, "{id} was evicted and has no durable state to rehydrate")
            }
            StudyError::Closed => write!(f, "study is closed"),
            StudyError::Rejected(e) => write!(f, "observation rejected: {e}"),
            StudyError::Io(msg) => write!(f, "study durability error: {msg}"),
        }
    }
}

impl std::error::Error for StudyError {}

/// The common ask/tell vocabulary across deployment modes: the inline
/// [`AskTellServer`], the spawned [`super::ServerHandle`] and the
/// [`ManagedStudy`] handle all implement it, so a driving loop is
/// generic over *where* the study runs.
pub trait Study {
    /// Next suggested trial point (user coordinates).
    fn ask(&mut self) -> Result<Vec<f64>, StudyError>;

    /// `q` diverse trial points for parallel evaluation.
    fn ask_batch(&mut self, q: usize) -> Result<Vec<Vec<f64>>, StudyError>;

    /// Report an observation (user coordinates).
    fn tell(&mut self, x: &[f64], y: f64) -> Result<(), StudyError>;

    /// Report a generalized [`Observation`] — per-trial noise variance
    /// and/or constraint-channel values ride along with `(x, y)`.
    /// [`StudyError::Rejected`] when the optimizer refuses it (e.g. a
    /// constraint-arity mismatch), before any state mutates.
    fn tell_observation(&mut self, obs: Observation) -> Result<(), StudyError>;

    /// Convenience: report an observation with a per-trial noise
    /// variance (`<= 0` or non-finite noise degrades to an exact tell).
    fn tell_noisy(&mut self, x: &[f64], y: f64, noise: f64) -> Result<(), StudyError> {
        self.tell_observation(Observation::noisy(x.to_vec(), y, noise))
    }

    /// Convenience: report an observation with constraint-channel values
    /// (`>= 0` = feasible; one value per channel of the study's model).
    fn tell_constrained(
        &mut self,
        x: &[f64],
        y: f64,
        constraints: &[f64],
    ) -> Result<(), StudyError> {
        self.tell_observation(
            Observation::exact(x.to_vec(), y).with_constraints(constraints.to_vec()),
        )
    }

    /// Incumbent best `(x, value)`, if any data.
    fn best(&self) -> Result<Option<(Vec<f64>, f64)>, StudyError>;

    /// Signal the end of the run (observers flush).
    fn finish(&mut self) -> Result<(), StudyError>;
}

/// Object-safe erasure of a concrete `AskTellServer<M, A, O>` — the
/// manager stores every study behind this, so one registry multiplexes
/// heterogeneous model/acquisition/optimizer stacks.
pub(crate) trait CoreStudy: Send {
    fn ask(&mut self) -> Vec<f64>;
    fn ask_batch(&mut self, q: usize) -> Vec<Vec<f64>>;
    fn tell(&mut self, x: &[f64], y: f64);
    fn tell_observation(&mut self, obs: &Observation) -> Result<(), BoError>;
    fn best(&self) -> Option<(Vec<f64>, f64)>;
    fn finish(&mut self);
    fn export_core(&self) -> CoreState;
    fn import_core(&mut self, state: CoreState);
    fn capture_model(&self) -> ModelState;
    fn restore_model(&mut self, state: &ModelState) -> Result<(), String>;
    fn hp_refits(&self) -> u64;
    fn set_hp_refits(&mut self, refits: u64);
    fn add_observer(&mut self, observer: Box<dyn Observer>);
}

impl<M, A, O> CoreStudy for AskTellServer<M, A, O>
where
    M: StateModel + Clone + Send + 'static,
    A: AcquiFn<M> + Send + 'static,
    O: Optimizer + Send + 'static,
{
    fn ask(&mut self) -> Vec<f64> {
        // branches into the pending-aware proposal when the definition
        // enabled async_pending — same path as the inline server
        AskTellServer::ask(self)
    }

    fn ask_batch(&mut self, q: usize) -> Vec<Vec<f64>> {
        self.core.propose_batch(q)
    }

    fn tell(&mut self, x: &[f64], y: f64) {
        self.core.observe(x, y);
    }

    fn tell_observation(&mut self, obs: &Observation) -> Result<(), BoError> {
        self.core.try_observe(obs)
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.core.best()
    }

    fn finish(&mut self) {
        self.core.finish();
    }

    fn export_core(&self) -> CoreState {
        self.core.export_state()
    }

    fn import_core(&mut self, state: CoreState) {
        self.core.import_state(state);
    }

    fn capture_model(&self) -> ModelState {
        self.core.model.capture_state()
    }

    fn restore_model(&mut self, state: &ModelState) -> Result<(), String> {
        self.core.model.restore_state(state)
    }

    fn hp_refits(&self) -> u64 {
        self.core.model.hp_refits()
    }

    fn set_hp_refits(&mut self, refits: u64) {
        self.core.model.set_hp_refits(refits);
    }

    fn add_observer(&mut self, observer: Box<dyn Observer>) {
        self.core.add_boxed_observer(observer);
    }
}

/// Type-erased study constructor kept per slot: rehydration re-runs it
/// and overwrites the fresh state with the restored checkpoint.
type StudyFactory = Arc<dyn Fn() -> Box<dyn CoreStudy> + Send + Sync>;

/// Snapshot-barrier sentinel: attached *after* the study's
/// [`JsonlObserver`], it counts every logged event (keeping the
/// snapshot's replay offset aligned with the file) and raises the flag
/// on [`BoEvent::Refit`] — the only moment a snapshot is bit-exact.
struct Sentinel {
    refit: Arc<AtomicBool>,
    events: Arc<AtomicU64>,
}

impl Observer for Sentinel {
    fn on_event(&mut self, event: &BoEvent) {
        self.events.fetch_add(1, Ordering::Relaxed);
        if matches!(event, BoEvent::Refit { .. }) {
            self.refit.store(true, Ordering::Relaxed);
        }
    }
}

/// Where a registered study currently lives.
enum SlotState {
    /// In memory, ready for checkout.
    Live(Box<dyn CoreStudy>),
    /// Not in memory. Durable slots rehydrate on the next operation;
    /// ephemeral ones report [`StudyError::Evicted`].
    Evicted,
    /// Checked out by an operation in flight; waiters block on the
    /// manager's condvar.
    Busy,
    /// Finished for good; operations report [`StudyError::Closed`].
    Closed,
}

/// One registered study: its state, the factory that rebuilds it from
/// its definition, and the durability plumbing.
struct Slot {
    state: SlotState,
    factory: StudyFactory,
    /// Durability directory (`<root>/<study-id>/`); `None` = ephemeral.
    dir: Option<PathBuf>,
    /// LRU clock value of the last checkout.
    last_used: u64,
    /// Set by the [`Sentinel`] when a refit made the state
    /// snapshot-safe; consumed at the next check-in.
    refit_flag: Arc<AtomicBool>,
    /// Events written to the log so far == the replay offset a snapshot
    /// taken now should record.
    events: Arc<AtomicU64>,
}

struct Inner {
    slots: HashMap<StudyId, Slot>,
    next_id: u64,
    /// Monotonic LRU clock.
    tick: u64,
}

impl Inner {
    fn counts(&self) -> (usize, usize) {
        let mut live = 0;
        let mut evicted = 0;
        for slot in self.slots.values() {
            match slot.state {
                SlotState::Live(_) | SlotState::Busy => live += 1,
                SlotState::Evicted => evicted += 1,
                SlotState::Closed => {}
            }
        }
        (live, evicted)
    }

    fn publish_gauges(&self) {
        let (live, evicted) = self.counts();
        obs::gauge_set(Gauge::LiveStudies, live as u64);
        obs::gauge_set(Gauge::EvictedStudies, evicted as u64);
    }
}

/// What `checkout` decided to do after inspecting the slot under the
/// lock (the action itself runs with the lock released or re-acquired).
enum Checkout {
    Wait,
    Got(Box<dyn CoreStudy>),
    Rehydrate {
        factory: StudyFactory,
        dir: PathBuf,
        refit: Arc<AtomicBool>,
        events: Arc<AtomicU64>,
    },
}

/// The multi-study registry: create/recover studies, run ask/tell
/// operations by [`StudyId`] on a shared [`ThreadPool`], evict and
/// rehydrate under a live-study budget. See the module docs for the
/// durability and threading contracts.
pub struct StudyManager {
    pool: Arc<ThreadPool>,
    root: Option<PathBuf>,
    max_live: usize,
    inner: Mutex<Inner>,
    idle: Condvar,
}

fn lock_inner(m: &Mutex<Inner>) -> MutexGuard<'_, Inner> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn io_err(context: &str, e: std::io::Error) -> StudyError {
    StudyError::Io(format!("{context}: {e}"))
}

impl StudyManager {
    /// An ephemeral manager: studies live in memory only, nothing is
    /// written to disk, eviction is manual and lossy.
    pub fn new(pool: Arc<ThreadPool>) -> Self {
        Self {
            pool,
            root: None,
            max_live: usize::MAX,
            inner: Mutex::new(Inner { slots: HashMap::new(), next_id: 0, tick: 0 }),
            idle: Condvar::new(),
        }
    }

    /// A durable manager: every study gets `<root>/<study-id>/` with an
    /// append-only event log and refit-barrier snapshots, survives
    /// restarts via [`recover`](Self::recover), and tolerates LRU
    /// eviction without losing its trace.
    pub fn durable(pool: Arc<ThreadPool>, root: impl Into<PathBuf>) -> Result<Self, StudyError> {
        let root = root.into();
        fs::create_dir_all(&root).map_err(|e| io_err("create durability root", e))?;
        let mut mgr = Self::new(pool);
        mgr.root = Some(root);
        Ok(mgr)
    }

    /// Cap the number of in-memory studies; the least-recently-used
    /// *durable* study over the cap is evicted (ephemeral studies are
    /// never auto-evicted — eviction would lose them).
    pub fn with_max_live(mut self, n: usize) -> Self {
        self.max_live = n.max(1);
        self
    }

    /// The shared pool operations run on.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// `(live, evicted)` study counts (closed studies count as neither).
    pub fn counts(&self) -> (usize, usize) {
        lock_inner(&self.inner).counts()
    }

    /// Register a new study built by `factory` (typically a closure
    /// around a [`crate::bayes_opt::BoDef`], e.g.
    /// `|| BoDef::service(2).seed(7).build_server()`). The factory is
    /// kept: rehydration re-runs it and overwrites the fresh state with
    /// the restored checkpoint, so it must be deterministic in
    /// everything the checkpoint does not cover (kernel, schedules,
    /// inner-optimizer budgets...).
    pub fn create<M, A, O, F>(&self, factory: F) -> Result<StudyId, StudyError>
    where
        F: Fn() -> AskTellServer<M, A, O> + Send + Sync + 'static,
        M: StateModel + Clone + Send + 'static,
        A: AcquiFn<M> + Send + 'static,
        O: Optimizer + Send + 'static,
    {
        let factory: StudyFactory = Arc::new(move || Box::new(factory()) as Box<dyn CoreStudy>);
        let id = {
            let mut inner = lock_inner(&self.inner);
            let id = StudyId(inner.next_id);
            inner.next_id += 1;
            id
        };
        let mut study = factory();
        let refit_flag = Arc::new(AtomicBool::new(false));
        let events = Arc::new(AtomicU64::new(0));
        let dir = match &self.root {
            Some(root) => {
                let dir = root.join(id.to_string());
                fs::create_dir_all(&dir).map_err(|e| io_err("create study dir", e))?;
                let log = JsonlObserver::create(&dir.join("events.jsonl"))
                    .map_err(|e| io_err("create event log", e))?;
                study.add_observer(Box::new(log));
                study.add_observer(Box::new(Sentinel {
                    refit: Arc::clone(&refit_flag),
                    events: Arc::clone(&events),
                }));
                Some(dir)
            }
            None => None,
        };
        let stale = {
            let mut inner = lock_inner(&self.inner);
            let tick = inner.tick;
            inner.tick = tick + 1;
            inner.slots.insert(
                id,
                Slot {
                    state: SlotState::Live(study),
                    factory,
                    dir,
                    last_used: tick,
                    refit_flag,
                    events,
                },
            );
            let stale = Self::over_budget_evictions(&mut inner, self.max_live);
            inner.publish_gauges();
            stale
        };
        drop(stale); // flush evicted logs outside the lock
        Ok(id)
    }

    /// Re-register a study persisted by a previous process under the
    /// same durability root. `factory` must rebuild the same definition
    /// the study was created with. The state is loaded lazily: the
    /// first operation pays the snapshot-load + log-replay cost
    /// (visible as [`Phase::Replay`]).
    pub fn recover<M, A, O, F>(&self, id: StudyId, factory: F) -> Result<(), StudyError>
    where
        F: Fn() -> AskTellServer<M, A, O> + Send + Sync + 'static,
        M: StateModel + Clone + Send + 'static,
        A: AcquiFn<M> + Send + 'static,
        O: Optimizer + Send + 'static,
    {
        let root = self
            .root
            .as_ref()
            .ok_or_else(|| StudyError::Io("recover requires a durable manager".into()))?;
        let dir = root.join(id.to_string());
        if !dir.join("events.jsonl").exists() && !dir.join("snapshot.txt").exists() {
            return Err(StudyError::NotFound(id));
        }
        let factory: StudyFactory = Arc::new(move || Box::new(factory()) as Box<dyn CoreStudy>);
        let mut inner = lock_inner(&self.inner);
        if inner.slots.contains_key(&id) {
            return Err(StudyError::Io(format!("{id} is already registered")));
        }
        inner.next_id = inner.next_id.max(id.0 + 1);
        let tick = inner.tick;
        inner.tick = tick + 1;
        inner.slots.insert(
            id,
            Slot {
                state: SlotState::Evicted,
                factory,
                dir: Some(dir),
                last_used: tick,
                refit_flag: Arc::new(AtomicBool::new(false)),
                events: Arc::new(AtomicU64::new(0)),
            },
        );
        inner.publish_gauges();
        Ok(())
    }

    /// Next suggested trial point for `id`.
    pub fn ask(&self, id: StudyId) -> Result<Vec<f64>, StudyError> {
        self.run_op(id, |s| s.ask())
    }

    /// `q` diverse trial points for `id`.
    pub fn ask_batch(&self, id: StudyId, q: usize) -> Result<Vec<Vec<f64>>, StudyError> {
        self.run_op(id, move |s| s.ask_batch(q))
    }

    /// Report an observation for `id`.
    pub fn tell(&self, id: StudyId, x: &[f64], y: f64) -> Result<(), StudyError> {
        let x = x.to_vec();
        self.run_op(id, move |s| s.tell(&x, y))
    }

    /// Report a generalized [`Observation`] (noisy / constrained) for
    /// `id`. [`StudyError::Rejected`] when the study's optimizer refuses
    /// it (e.g. a constraint-arity mismatch) — the study stays usable.
    pub fn tell_observation(&self, id: StudyId, obs: Observation) -> Result<(), StudyError> {
        self.run_op(id, move |s| s.tell_observation(&obs))?.map_err(StudyError::Rejected)
    }

    /// Incumbent best of `id`.
    pub fn best(&self, id: StudyId) -> Result<Option<(Vec<f64>, f64)>, StudyError> {
        self.run_op(id, |s| s.best())
    }

    /// Finish `id` for good: observers flush (the event log records the
    /// stop), the live state is dropped, and every later operation
    /// reports [`StudyError::Closed`].
    pub fn close(&self, id: StudyId) -> Result<(), StudyError> {
        let mut study = self.checkout(id)?;
        let (tx, rx) = mpsc::channel();
        self.pool.execute(move || {
            study.finish();
            let _ = tx.send(study);
        });
        match rx.recv() {
            Ok(study) => {
                {
                    let mut inner = lock_inner(&self.inner);
                    if let Some(slot) = inner.slots.get_mut(&id) {
                        slot.state = SlotState::Closed;
                    }
                    inner.publish_gauges();
                }
                self.idle.notify_all();
                drop(study); // flush the log outside the lock
                Ok(())
            }
            Err(_) => Err(self.poison(id)),
        }
    }

    /// Drop `id`'s in-memory state now. Durable studies rehydrate
    /// transparently on the next operation; an ephemeral study is gone
    /// and later operations report [`StudyError::Evicted`]. Idempotent
    /// on an already-evicted study.
    pub fn evict(&self, id: StudyId) -> Result<(), StudyError> {
        let mut inner = lock_inner(&self.inner);
        loop {
            let taken = {
                let inner_ref = &mut *inner;
                let slot = inner_ref.slots.get_mut(&id).ok_or(StudyError::NotFound(id))?;
                match std::mem::replace(&mut slot.state, SlotState::Evicted) {
                    SlotState::Closed => {
                        slot.state = SlotState::Closed;
                        return Err(StudyError::Closed);
                    }
                    SlotState::Evicted => return Ok(()),
                    SlotState::Busy => {
                        slot.state = SlotState::Busy;
                        None
                    }
                    SlotState::Live(study) => Some(study),
                }
            };
            match taken {
                None => inner = self.idle.wait(inner).unwrap_or_else(|e| e.into_inner()),
                Some(study) => {
                    inner.publish_gauges();
                    drop(inner);
                    drop(study); // flush the log outside the lock
                    return Ok(());
                }
            }
        }
    }

    /// A cloneable per-study handle implementing [`Study`].
    pub fn study(self: &Arc<Self>, id: StudyId) -> ManagedStudy {
        ManagedStudy { mgr: Arc::clone(self), id }
    }

    /// Check the study out (exclusive), rehydrating an evicted durable
    /// slot from snapshot + log tail.
    fn checkout(&self, id: StudyId) -> Result<Box<dyn CoreStudy>, StudyError> {
        let mut inner = lock_inner(&self.inner);
        loop {
            let decision = {
                let inner_ref = &mut *inner;
                let tick = inner_ref.tick;
                let slot = inner_ref.slots.get_mut(&id).ok_or(StudyError::NotFound(id))?;
                match std::mem::replace(&mut slot.state, SlotState::Busy) {
                    SlotState::Closed => {
                        slot.state = SlotState::Closed;
                        return Err(StudyError::Closed);
                    }
                    SlotState::Busy => Checkout::Wait,
                    SlotState::Evicted => match slot.dir.clone() {
                        None => {
                            slot.state = SlotState::Evicted;
                            return Err(StudyError::Evicted(id));
                        }
                        // leave the slot Busy: concurrent callers park on
                        // the condvar while we rehydrate outside the lock
                        Some(dir) => Checkout::Rehydrate {
                            factory: Arc::clone(&slot.factory),
                            dir,
                            refit: Arc::clone(&slot.refit_flag),
                            events: Arc::clone(&slot.events),
                        },
                    },
                    SlotState::Live(study) => {
                        slot.last_used = tick;
                        inner_ref.tick = tick + 1;
                        Checkout::Got(study)
                    }
                }
            };
            match decision {
                Checkout::Wait => {
                    inner = self.idle.wait(inner).unwrap_or_else(|e| e.into_inner());
                }
                Checkout::Got(study) => return Ok(study),
                Checkout::Rehydrate { factory, dir, refit, events } => {
                    drop(inner);
                    let rehydrated = rehydrate(&factory, &dir, &refit, &events);
                    inner = lock_inner(&self.inner);
                    match rehydrated {
                        Ok((study, false)) => {
                            let inner_ref = &mut *inner;
                            let tick = inner_ref.tick;
                            inner_ref.tick = tick + 1;
                            if let Some(slot) = inner_ref.slots.get_mut(&id) {
                                slot.last_used = tick;
                            }
                            inner.publish_gauges();
                            return Ok(study);
                        }
                        Ok((study, true)) => {
                            // the log ends in `stopped`: the study was
                            // closed before the crash — keep it closed
                            if let Some(slot) = inner.slots.get_mut(&id) {
                                slot.state = SlotState::Closed;
                            }
                            inner.publish_gauges();
                            drop(inner);
                            self.idle.notify_all();
                            drop(study);
                            return Err(StudyError::Closed);
                        }
                        Err(e) => {
                            if let Some(slot) = inner.slots.get_mut(&id) {
                                slot.state = SlotState::Evicted;
                            }
                            drop(inner);
                            self.idle.notify_all();
                            return Err(e);
                        }
                    }
                }
            }
        }
    }

    /// Return a checked-out study, taking the refit-barrier snapshot if
    /// the operation just refitted, then wake waiters and enforce the
    /// live-study budget.
    fn checkin(&self, id: StudyId, study: Box<dyn CoreStudy>) {
        let plumbing = {
            let inner = lock_inner(&self.inner);
            inner.slots.get(&id).map(|slot| {
                (slot.dir.clone(), Arc::clone(&slot.refit_flag), Arc::clone(&slot.events))
            })
        };
        let Some((dir, refit_flag, events)) = plumbing else { return };
        // the slot is still Busy: the state is exclusively ours, nothing
        // can run between the refit that raised the flag and this capture
        if let Some(dir) = dir {
            if refit_flag.swap(false, Ordering::Relaxed) {
                let snapshot = StudySnapshot {
                    core: study.export_core(),
                    model: study.capture_model(),
                    hp_refits: study.hp_refits(),
                    offset: events.load(Ordering::Relaxed),
                };
                // a failed snapshot write is not fatal: the event log
                // still covers the full history, the next refit re-arms
                if snapshot.write(&dir).is_err() {
                    obs::counter_add(Counter::StatWriteFailures, 1);
                }
            }
        }
        let stale = {
            let mut inner = lock_inner(&self.inner);
            if let Some(slot) = inner.slots.get_mut(&id) {
                slot.state = SlotState::Live(study);
            }
            let stale = Self::over_budget_evictions(&mut inner, self.max_live);
            inner.publish_gauges();
            stale
        };
        self.idle.notify_all();
        drop(stale); // flush evicted logs outside the lock
    }

    /// Pop LRU durable live studies until the live count fits the
    /// budget; the returned boxes must be dropped outside the lock.
    fn over_budget_evictions(inner: &mut Inner, max_live: usize) -> Vec<Box<dyn CoreStudy>> {
        let mut dropped = Vec::new();
        loop {
            let (live, _) = inner.counts();
            if live <= max_live {
                return dropped;
            }
            let victim = inner
                .slots
                .iter()
                .filter(|(_, s)| matches!(s.state, SlotState::Live(_)) && s.dir.is_some())
                .min_by_key(|(_, s)| s.last_used)
                .map(|(id, _)| *id);
            let Some(victim) = victim else {
                return dropped; // nothing evictable (ephemeral or busy)
            };
            let slot = inner.slots.get_mut(&victim).expect("victim exists");
            if let SlotState::Live(study) = std::mem::replace(&mut slot.state, SlotState::Evicted)
            {
                dropped.push(study);
            }
        }
    }

    /// Run one operation on the pool with the study checked out.
    fn run_op<R, F>(&self, id: StudyId, f: F) -> Result<R, StudyError>
    where
        R: Send + 'static,
        F: FnOnce(&mut dyn CoreStudy) -> R + Send + 'static,
    {
        let mut study = self.checkout(id)?;
        let (tx, rx) = mpsc::channel();
        self.pool.execute(move || {
            let r = f(study.as_mut());
            let _ = tx.send((study, r));
        });
        match rx.recv() {
            Ok((study, r)) => {
                self.checkin(id, study);
                Ok(r)
            }
            // the job panicked on the pool and the study state is lost
            Err(_) => Err(self.poison(id)),
        }
    }

    /// A pool job lost the study state (panic): close the slot so
    /// waiters fail fast instead of parking forever.
    fn poison(&self, id: StudyId) -> StudyError {
        {
            let mut inner = lock_inner(&self.inner);
            if let Some(slot) = inner.slots.get_mut(&id) {
                slot.state = SlotState::Closed;
            }
            inner.publish_gauges();
        }
        self.idle.notify_all();
        StudyError::Io(format!("{id}: operation panicked on the pool; study closed"))
    }
}

/// Handle binding a [`StudyManager`] to one [`StudyId`]; the managed
/// implementation of [`Study`].
#[derive(Clone)]
pub struct ManagedStudy {
    mgr: Arc<StudyManager>,
    id: StudyId,
}

impl ManagedStudy {
    /// The study this handle addresses.
    pub fn id(&self) -> StudyId {
        self.id
    }
}

impl Study for ManagedStudy {
    fn ask(&mut self) -> Result<Vec<f64>, StudyError> {
        self.mgr.ask(self.id)
    }

    fn ask_batch(&mut self, q: usize) -> Result<Vec<Vec<f64>>, StudyError> {
        self.mgr.ask_batch(self.id, q)
    }

    fn tell(&mut self, x: &[f64], y: f64) -> Result<(), StudyError> {
        self.mgr.tell(self.id, x, y)
    }

    fn tell_observation(&mut self, obs: Observation) -> Result<(), StudyError> {
        self.mgr.tell_observation(self.id, obs)
    }

    fn best(&self) -> Result<Option<(Vec<f64>, f64)>, StudyError> {
        self.mgr.best(self.id)
    }

    fn finish(&mut self) -> Result<(), StudyError> {
        self.mgr.close(self.id)
    }
}

// ---------------------------------------------------------------------
// Durability: snapshot text format and snapshot + log-tail rehydration.
// ---------------------------------------------------------------------

/// A refit-barrier checkpoint: the loop bookkeeping, the model state,
/// the restart-derivation refit counter, and the replay offset (event
/// log lines already covered by this snapshot).
struct StudySnapshot {
    core: CoreState,
    model: ModelState,
    hp_refits: u64,
    offset: u64,
}

/// Exact `f64` as 16 hex digits of its bit pattern — the snapshot is a
/// private format, so bit-exactness beats readability.
fn hex_f64(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn parse_hex_f64(s: &str) -> Result<f64, String> {
    u64::from_str_radix(s.trim(), 16)
        .map(f64::from_bits)
        .map_err(|e| format!("bad hex float {s:?}: {e}"))
}

fn parse_hex_point(s: &str) -> Result<Vec<f64>, String> {
    s.split_whitespace().map(parse_hex_f64).collect()
}

fn parse_hex_u64(s: &str) -> Result<u64, String> {
    u64::from_str_radix(s.trim(), 16).map_err(|e| format!("bad hex integer {s:?}: {e}"))
}

/// `line` must be `"<key> <rest>"`; returns `rest`.
fn field<'a>(line: Option<&'a str>, key: &str) -> Result<&'a str, String> {
    let line = line.ok_or_else(|| format!("snapshot truncated before {key:?}"))?;
    line.strip_prefix(key)
        .and_then(|r| r.strip_prefix(' '))
        .ok_or_else(|| format!("expected {key:?} line, got {line:?}"))
}

fn parse_usize(s: &str) -> Result<usize, String> {
    s.trim().parse().map_err(|e| format!("bad integer {s:?}: {e}"))
}

fn parse_u64(s: &str) -> Result<u64, String> {
    s.trim().parse().map_err(|e| format!("bad integer {s:?}: {e}"))
}

impl StudySnapshot {
    fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let c = &self.core;
        let mut out = String::new();
        out.push_str("limbo-study v2\n");
        let _ = writeln!(out, "dim {}", c.dim);
        let _ = writeln!(out, "offset {}", self.offset);
        let _ = writeln!(out, "hp_refits {}", self.hp_refits);
        let _ = writeln!(out, "init_total {}", c.init_total);
        let _ = writeln!(out, "init_served {}", c.init_served);
        let _ = writeln!(out, "init_observed {}", c.init_observed);
        let _ = writeln!(out, "iteration {}", c.iteration);
        let _ = writeln!(out, "evaluations {}", c.evaluations);
        let _ = writeln!(out, "finished {}", u8::from(c.finished));
        match c.next_refit {
            Some(n) => {
                let _ = writeln!(out, "next_refit {n}");
            }
            None => out.push_str("next_refit none\n"),
        }
        let _ = writeln!(out, "rng {:016x} {:016x}", c.rng.0, c.rng.1);
        match &c.best {
            Some((x, y)) => {
                let xs: Vec<String> = x.iter().map(|&v| hex_f64(v)).collect();
                let _ = writeln!(out, "best {} {}", hex_f64(*y), xs.join(" "));
            }
            None => out.push_str("best none\n"),
        }
        let _ = writeln!(out, "init_queue {}", c.init_queue.len());
        for x in &c.init_queue {
            let xs: Vec<String> = x.iter().map(|&v| hex_f64(v)).collect();
            out.push_str(&xs.join(" "));
            out.push('\n');
        }
        let _ = writeln!(out, "pending {}", c.pending.len());
        for x in &c.pending {
            let xs: Vec<String> = x.iter().map(|&v| hex_f64(v)).collect();
            out.push_str(&xs.join(" "));
            out.push('\n');
        }
        out.push_str("model\n");
        out.push_str(&self.model.to_text());
        out
    }

    fn from_text(text: &str) -> Result<Self, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty snapshot")?;
        // v1 predates the async-pending set (treated as empty); v2 adds
        // the `pending` section between `init_queue` and `model`
        let version: u8 = match header.trim() {
            "limbo-study v1" => 1,
            "limbo-study v2" => 2,
            other => return Err(format!("not a limbo-study snapshot: {other:?}")),
        };
        let dim = parse_usize(field(lines.next(), "dim")?)?;
        let offset = parse_u64(field(lines.next(), "offset")?)?;
        let hp_refits = parse_u64(field(lines.next(), "hp_refits")?)?;
        let init_total = parse_usize(field(lines.next(), "init_total")?)?;
        let init_served = parse_usize(field(lines.next(), "init_served")?)?;
        let init_observed = parse_usize(field(lines.next(), "init_observed")?)?;
        let iteration = parse_usize(field(lines.next(), "iteration")?)?;
        let evaluations = parse_usize(field(lines.next(), "evaluations")?)?;
        let finished = field(lines.next(), "finished")?.trim() == "1";
        let next_refit = match field(lines.next(), "next_refit")?.trim() {
            "none" => None,
            n => Some(parse_usize(n)?),
        };
        let rng_line = field(lines.next(), "rng")?;
        let mut rng_parts = rng_line.split_whitespace();
        let rng_state = parse_hex_u64(rng_parts.next().ok_or("rng missing state")?)?;
        let rng_inc = parse_hex_u64(rng_parts.next().ok_or("rng missing inc")?)?;
        let best_line = field(lines.next(), "best")?;
        let best = if best_line.trim() == "none" {
            None
        } else {
            let mut parts = best_line.split_whitespace();
            let y = parse_hex_f64(parts.next().ok_or("best missing value")?)?;
            let x: Vec<f64> = parts.map(parse_hex_f64).collect::<Result<_, _>>()?;
            Some((x, y))
        };
        let n_queue = parse_usize(field(lines.next(), "init_queue")?)?;
        let mut init_queue = Vec::with_capacity(n_queue);
        for _ in 0..n_queue {
            let row = lines.next().ok_or("snapshot truncated in init_queue")?;
            init_queue.push(parse_hex_point(row)?);
        }
        let mut pending = Vec::new();
        if version >= 2 {
            let n_pending = parse_usize(field(lines.next(), "pending")?)?;
            pending.reserve(n_pending);
            for _ in 0..n_pending {
                let row = lines.next().ok_or("snapshot truncated in pending")?;
                pending.push(parse_hex_point(row)?);
            }
        }
        let model_marker = lines.next().ok_or("snapshot truncated before model")?;
        if model_marker.trim() != "model" {
            return Err(format!("expected \"model\" line, got {model_marker:?}"));
        }
        let model_text: String = lines.collect::<Vec<_>>().join("\n");
        let model = ModelState::from_text(&model_text)?;
        Ok(Self {
            core: CoreState {
                dim,
                init_queue,
                pending,
                init_total,
                init_served,
                init_observed,
                iteration,
                evaluations,
                best,
                next_refit,
                finished,
                rng: (rng_state, rng_inc),
            },
            model,
            hp_refits,
            offset,
        })
    }

    /// Atomic write: tmp file + rename, so a crash mid-write leaves the
    /// previous snapshot intact.
    fn write(&self, dir: &Path) -> std::io::Result<()> {
        let _span = obs::span(Phase::Snapshot);
        let tmp = dir.join("snapshot.tmp");
        fs::write(&tmp, self.to_text())?;
        fs::rename(&tmp, dir.join("snapshot.txt"))
    }
}

/// Rebuild a study from its durability directory: factory → snapshot
/// restore (if one exists) → replay of the event-log tail through the
/// live code path → re-attach the log writer and snapshot sentinel.
/// Returns `(study, closed)`; `closed` means the log ends in `stopped`.
fn rehydrate(
    factory: &StudyFactory,
    dir: &Path,
    refit_flag: &Arc<AtomicBool>,
    events: &Arc<AtomicU64>,
) -> Result<(Box<dyn CoreStudy>, bool), StudyError> {
    let _span = obs::span(Phase::Replay);
    let mut study = factory();
    let snap_path = dir.join("snapshot.txt");
    let mut offset = 0usize;
    if snap_path.exists() {
        let text = fs::read_to_string(&snap_path).map_err(|e| io_err("read snapshot", e))?;
        let snapshot = StudySnapshot::from_text(&text).map_err(StudyError::Io)?;
        study.restore_model(&snapshot.model).map_err(StudyError::Io)?;
        study.set_hp_refits(snapshot.hp_refits);
        study.import_core(snapshot.core);
        offset = snapshot.offset as usize;
    }
    let log_path = dir.join("events.jsonl");
    let log = if log_path.exists() {
        ReplayEvent::read_log(&log_path).map_err(StudyError::Io)?
    } else {
        Vec::new()
    };
    if log.len() < offset {
        return Err(StudyError::Io(format!(
            "event log has {} events but the snapshot covers {offset} — log truncated?",
            log.len()
        )));
    }
    // No observers are attached yet: replay-driven proposals, refits and
    // init-done events are not re-logged, and the file offset stays
    // aligned with the events counter.
    let mut closed = false;
    for event in &log[offset..] {
        match event {
            ReplayEvent::Proposal { q: 1, .. } => {
                let _ = study.ask();
            }
            ReplayEvent::Proposal { q, .. } => {
                let _ = study.ask_batch(*q);
            }
            ReplayEvent::Observation { x, y, .. } => study.tell(x, *y),
            ReplayEvent::TellNoisy { x, y, noise, .. } => study
                .tell_observation(&Observation::noisy(x.clone(), *y, *noise))
                .map_err(|e| StudyError::Io(format!("replay rejected a noisy tell: {e}")))?,
            ReplayEvent::TellConstrained { x, y, noise, constraints, .. } => {
                let base = match noise {
                    Some(nv) => Observation::noisy(x.clone(), *y, *nv),
                    None => Observation::exact(x.clone(), *y),
                };
                study
                    .tell_observation(&base.with_constraints(constraints.clone()))
                    .map_err(|e| {
                        StudyError::Io(format!("replay rejected a constrained tell: {e}"))
                    })?;
            }
            // pending registrations are re-derived by the replayed asks
            // above — the logged record is for audit, not replay
            ReplayEvent::AskPending { .. } => {}
            ReplayEvent::InitDone { .. } | ReplayEvent::Refit { .. } => {}
            ReplayEvent::Stopped { .. } => {
                study.finish();
                closed = true;
            }
        }
    }
    events.store(log.len() as u64, Ordering::Relaxed);
    refit_flag.store(false, Ordering::Relaxed);
    if !closed {
        let log = JsonlObserver::append(&log_path).map_err(|e| io_err("reopen event log", e))?;
        study.add_observer(Box::new(log));
        study.add_observer(Box::new(Sentinel {
            refit: Arc::clone(refit_flag),
            events: Arc::clone(events),
        }));
    }
    Ok((study, closed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ucb;
    use crate::bayes_opt::BoDef;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::Gp;
    use crate::opt::RandomPoint;

    type TestServer = AskTellServer<Gp<Matern52, DataMean>, Ucb, RandomPoint>;

    fn tiny_factory(seed: u64) -> impl Fn() -> TestServer + Send + Sync {
        move || BoDef::service(1).seed(seed).inner_opt(RandomPoint::new(16)).build_server()
    }

    fn pool() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(2))
    }

    #[test]
    fn create_ask_tell_best_round_trip() {
        let mgr = StudyManager::new(pool());
        let id = mgr.create(tiny_factory(7)).expect("create");
        for _ in 0..5 {
            let x = mgr.ask(id).expect("ask");
            assert_eq!(x.len(), 1);
            let y = -(x[0] - 0.4).powi(2);
            mgr.tell(id, &x, y).expect("tell");
        }
        let (_, bv) = mgr.best(id).expect("best").expect("data");
        assert!(bv <= 0.0);
    }

    #[test]
    fn unknown_id_reports_not_found() {
        let mgr = StudyManager::new(pool());
        let bogus = StudyId::from_u64(999);
        assert_eq!(mgr.ask(bogus), Err(StudyError::NotFound(bogus)));
    }

    #[test]
    fn closed_study_rejects_operations() {
        let mgr = StudyManager::new(pool());
        let id = mgr.create(tiny_factory(3)).expect("create");
        let x = mgr.ask(id).expect("ask");
        mgr.tell(id, &x, 1.0).expect("tell");
        mgr.close(id).expect("close");
        assert_eq!(mgr.ask(id), Err(StudyError::Closed));
        assert_eq!(mgr.close(id), Err(StudyError::Closed));
    }

    #[test]
    fn ephemeral_eviction_is_lossy_and_typed() {
        let mgr = StudyManager::new(pool());
        let id = mgr.create(tiny_factory(5)).expect("create");
        mgr.ask(id).expect("ask");
        mgr.evict(id).expect("evict");
        assert_eq!(mgr.ask(id), Err(StudyError::Evicted(id)));
        mgr.evict(id).expect("evict is idempotent");
    }

    #[test]
    fn durable_eviction_rehydrates_transparently() {
        let dir = std::env::temp_dir().join("limbo_mgr_evict_rehydrate");
        let _ = fs::remove_dir_all(&dir);
        let mgr = StudyManager::durable(pool(), &dir).expect("durable");
        let id = mgr.create(tiny_factory(11)).expect("create");
        let mut trace = Vec::new();
        for _ in 0..4 {
            let x = mgr.ask(id).expect("ask");
            let y = -(x[0] - 0.5).powi(2);
            mgr.tell(id, &x, y).expect("tell");
            trace.push((x, y));
        }
        mgr.evict(id).expect("evict");
        assert_eq!(mgr.counts(), (0, 1));
        // the next op rehydrates (replaying the log) and continues
        let x = mgr.ask(id).expect("ask after evict");
        assert_eq!(mgr.counts(), (1, 0));
        // parity: an isolated run of the same definition takes the same
        // trajectory straight through the eviction boundary
        let mut iso = tiny_factory(11)();
        for (tx, ty) in &trace {
            let ix = iso.core.propose();
            assert_eq!(&ix, tx, "pre-eviction trace must match");
            iso.core.observe(&ix, *ty);
        }
        let ix = iso.core.propose();
        assert_eq!(
            ix.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "post-rehydration proposal must be bit-identical"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_respects_the_budget() {
        let dir = std::env::temp_dir().join("limbo_mgr_lru");
        let _ = fs::remove_dir_all(&dir);
        let mgr = StudyManager::durable(pool(), &dir).expect("durable").with_max_live(2);
        let ids: Vec<StudyId> =
            (0..4).map(|i| mgr.create(tiny_factory(20 + i)).expect("create")).collect();
        let (live, evicted) = mgr.counts();
        assert_eq!(live, 2, "budget enforced at create");
        assert_eq!(evicted, 2);
        // every study still serves — evicted ones rehydrate on demand
        for &id in &ids {
            mgr.ask(id).expect("study serves after LRU churn");
        }
        let (live, _) = mgr.counts();
        assert_eq!(live, 2, "budget enforced after rehydration churn");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_text_round_trips() {
        let core = CoreState {
            dim: 2,
            init_queue: vec![vec![0.1, 0.9], vec![std::f64::consts::PI, 1.0 / 3.0]],
            pending: vec![vec![0.5, 0.25], vec![1e-300, -0.0]],
            init_total: 4,
            init_served: 2,
            init_observed: 2,
            iteration: 7,
            evaluations: 9,
            best: Some((vec![0.25, 1e-17], -3.5e-9)),
            next_refit: Some(16),
            finished: false,
            rng: (0xDEAD_BEEF_0123_4567, 0x89AB_CDEF_0000_0001),
        };
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-3);
        crate::model::Model::fit(&mut gp, &[vec![0.1, 0.2], vec![0.8, 0.7]], &[1.0, -0.5]);
        let snapshot = StudySnapshot {
            core: core.clone(),
            model: StateModel::capture_state(&gp),
            hp_refits: 3,
            offset: 41,
        };
        let parsed = StudySnapshot::from_text(&snapshot.to_text()).expect("parse");
        assert_eq!(parsed.core, core);
        assert_eq!(parsed.hp_refits, 3);
        assert_eq!(parsed.offset, 41);
        assert_eq!(parsed.model.n_samples(), 2);
    }
}
