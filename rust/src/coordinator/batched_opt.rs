//! Batch-aware acquisition maximization for the XLA backend.
//!
//! The generic inner optimizers call `Model::predict` point by point; on
//! the XLA backend every call executes a full artifact (Gram + Cholesky +
//! solves), so a 500-evaluation DIRECT pass costs 500 executions. The
//! fused `ucb` artifact scores **64 candidates per execution**, so a
//! batched sampler gets 64x more acquisition evaluations per unit of
//! runtime work — the runtime-layer half of the §Perf story.

use crate::coordinator::xla_model::XlaGpModel;
use crate::opt::Candidate;
use crate::rng::{halton_point, Pcg64};

/// Batched UCB maximizer over an [`XlaGpModel`].
pub struct BatchedUcbSearch {
    /// Rounds of candidate batches (total evals = rounds * batch).
    pub rounds: usize,
    /// UCB exploration weight.
    pub alpha: f64,
    /// Fraction of each batch drawn from a Halton sequence (space filling)
    /// vs uniform random; the final round samples a shrinking box around
    /// the incumbent (cheap local refinement).
    pub halton_fraction: f64,
}

impl Default for BatchedUcbSearch {
    fn default() -> Self {
        Self { rounds: 8, alpha: 0.5, halton_fraction: 0.5 }
    }
}

impl BatchedUcbSearch {
    /// Maximize the fused UCB acquisition; returns the best candidate and
    /// its acquisition value.
    pub fn optimize(&self, model: &XlaGpModel, dim: usize, rng: &mut Pcg64) -> Candidate {
        let b = model.batch_size().max(1);
        let mut best = Candidate { x: vec![0.5; dim], value: f64::NEG_INFINITY };
        let mut halton_idx = rng.below(1 << 16); // decorrelate across calls

        for round in 0..self.rounds.max(1) {
            let mut cands: Vec<Vec<f64>> = Vec::with_capacity(b);
            let local = round + 1 == self.rounds && best.value.is_finite();
            if local {
                // last round: shrink around the incumbent
                let w = 0.1;
                for _ in 0..b {
                    let x: Vec<f64> = best
                        .x
                        .iter()
                        .map(|&v| (v + rng.uniform(-w, w)).clamp(0.0, 1.0))
                        .collect();
                    cands.push(x);
                }
            } else {
                let n_halton = (b as f64 * self.halton_fraction) as usize;
                for _ in 0..n_halton {
                    cands.push(halton_point(halton_idx, dim));
                    halton_idx += 1;
                }
                while cands.len() < b {
                    cands.push(rng.unit_point(dim));
                }
            }
            let vals = model.ucb_batch(&cands, self.alpha);
            for (x, value) in cands.into_iter().zip(vals) {
                if value > best.value {
                    best = Candidate { x, value };
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_parameters_are_sane() {
        let s = BatchedUcbSearch::default();
        assert!(s.rounds >= 1);
        assert!(s.alpha > 0.0);
        assert!((0.0..=1.0).contains(&s.halton_fraction));
    }
}
