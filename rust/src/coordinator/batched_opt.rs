//! Batch-aware acquisition maximization for the XLA backend.
//!
//! Historically this module carried a bespoke sampler because only the
//! XLA backend had a batched posterior. The batch-first refactor moved
//! that machinery into the generic [`PopulationSearch`] inner optimizer
//! (rounds of Halton/uniform populations + a final local round, scored
//! through [`crate::opt::Objective::eval_many`]); [`BatchedUcbSearch`] is
//! now a thin adapter that binds the fused-UCB artifact
//! ([`XlaGpModel::ucb_batch`]) as a batched [`Objective`] and sizes the
//! population to the artifact batch capacity — every round still costs
//! ~1 fused artifact execution per capacity tile, but the sampler itself
//! is shared with the native backends.

use crate::coordinator::xla_model::XlaGpModel;
use crate::opt::{Candidate, Objective, Optimizer, PopulationSearch};
use crate::rng::Pcg64;

/// The fused `ucb` artifact as a maximization [`Objective`]: `eval_many`
/// scores a whole population in one artifact execution per capacity tile
/// (predict + mu + alpha*sigma combine fused on the backend).
struct FusedUcbObjective<'a> {
    model: &'a XlaGpModel,
    alpha: f64,
}

impl Objective for FusedUcbObjective<'_> {
    fn eval(&self, x: &[f64]) -> f64 {
        let one = [x.to_vec()];
        self.model.ucb_batch(&one, self.alpha)[0]
    }

    fn eval_many(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        self.model.ucb_batch(xs, self.alpha)
    }
}

/// Batched UCB maximizer over an [`XlaGpModel`].
pub struct BatchedUcbSearch {
    /// Rounds of candidate batches (total evals = rounds * batch).
    pub rounds: usize,
    /// UCB exploration weight.
    pub alpha: f64,
    /// Fraction of each batch drawn from a Halton sequence (space filling)
    /// vs uniform random; the final round samples a shrinking box around
    /// the incumbent (cheap local refinement).
    pub halton_fraction: f64,
}

impl Default for BatchedUcbSearch {
    fn default() -> Self {
        Self { rounds: 8, alpha: 0.5, halton_fraction: 0.5 }
    }
}

impl BatchedUcbSearch {
    /// Maximize the fused UCB acquisition through the generic population
    /// machinery (populations sized to the artifact batch capacity);
    /// returns the best candidate and its acquisition value.
    pub fn optimize(&self, model: &XlaGpModel, dim: usize, rng: &mut Pcg64) -> Candidate {
        let search = PopulationSearch {
            rounds: self.rounds,
            batch: model.batch_size().max(1),
            halton_fraction: self.halton_fraction,
        };
        let objective = FusedUcbObjective { model, alpha: self.alpha };
        search.optimize(&objective, dim, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_parameters_are_sane() {
        let s = BatchedUcbSearch::default();
        assert!(s.rounds >= 1);
        assert!(s.alpha > 0.0);
        assert!((0.0..=1.0).contains(&s.halton_fraction));
    }
}
