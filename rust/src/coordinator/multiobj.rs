//! Multi-objective Bayesian optimization via ParEGO-style scalarization
//! (Knowles 2006) — the paper notes Limbo "can support multi-objective
//! optimization" through vector-valued functors.
//!
//! Each iteration draws a random weight vector, scalarizes the objectives
//! with the augmented Tchebycheff norm, and runs one acquisition step of a
//! single-objective GP on the scalarized history. A Pareto [`Archive`]
//! keeps the non-dominated set.

use crate::acqui::Ucb;
use crate::bayes_opt::core::BoCore;
use crate::kernel::Matern52;
use crate::mean::DataMean;
use crate::model::{gp::Gp, Model};
use crate::opt::{NelderMead, OptimizerExt, RandomPoint};
use crate::rng::Pcg64;

/// A vector-valued objective (all components maximized).
pub trait MultiEvaluator: Sync {
    /// Input dimension.
    fn dim_in(&self) -> usize;
    /// Number of objectives.
    fn dim_out(&self) -> usize;
    /// Evaluate all objectives.
    fn eval(&self, x: &[f64]) -> Vec<f64>;
}

/// Non-dominated archive (maximization in every objective).
#[derive(Clone, Debug, Default)]
pub struct Archive {
    entries: Vec<(Vec<f64>, Vec<f64>)>, // (x, objectives)
}

impl Archive {
    /// True if `a` dominates `b` (>= everywhere, > somewhere).
    pub fn dominates(a: &[f64], b: &[f64]) -> bool {
        let mut strictly = false;
        for (&ai, &bi) in a.iter().zip(b) {
            if ai < bi {
                return false;
            }
            if ai > bi {
                strictly = true;
            }
        }
        strictly
    }

    /// Insert a point; keeps the archive non-dominated. Returns true if
    /// the point entered the front.
    pub fn insert(&mut self, x: Vec<f64>, objs: Vec<f64>) -> bool {
        if self.entries.iter().any(|(_, o)| Self::dominates(o, &objs) || o == &objs) {
            return false;
        }
        self.entries.retain(|(_, o)| !Self::dominates(&objs, o));
        self.entries.push((x, objs));
        true
    }

    /// The current Pareto front.
    pub fn front(&self) -> &[(Vec<f64>, Vec<f64>)] {
        &self.entries
    }

    /// 2-D hypervolume against a reference point (objectives maximized,
    /// `reference` must be dominated by every front point).
    pub fn hypervolume_2d(&self, reference: &[f64; 2]) -> f64 {
        // sweep descending in obj0; each front point adds the rectangle
        // between its obj1 and the best obj1 seen so far
        let mut pts: Vec<&Vec<f64>> = self.entries.iter().map(|(_, o)| o).collect();
        pts.sort_by(|a, b| b[0].partial_cmp(&a[0]).unwrap());
        let mut hv = 0.0;
        let mut prev_y = reference[1];
        for p in pts {
            let width = p[0] - reference[0];
            let height = p[1] - prev_y;
            if width > 0.0 && height > 0.0 {
                hv += width * height;
                prev_y = p[1];
            }
        }
        hv
    }

    /// Archive size.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the archive empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Augmented Tchebycheff scalarization (maximization form).
pub fn tchebycheff(objs: &[f64], weights: &[f64], rho: f64) -> f64 {
    let weighted: Vec<f64> = objs.iter().zip(weights).map(|(&o, &w)| w * o).collect();
    let min = weighted.iter().cloned().fold(f64::INFINITY, f64::min);
    min + rho * weighted.iter().sum::<f64>()
}

/// ParEGO-style multi-objective optimizer.
pub struct ParEgo {
    /// Initial random samples.
    pub n_init: usize,
    /// Model-guided iterations.
    pub iterations: usize,
    /// Tchebycheff augmentation factor.
    pub rho: f64,
    /// RNG.
    pub rng: Pcg64,
}

impl ParEgo {
    /// Defaults: 10 init, 40 iterations, rho 0.05.
    pub fn new(seed: u64) -> Self {
        Self { n_init: 10, iterations: 40, rho: 0.05, rng: Pcg64::seed(seed) }
    }

    /// Run; returns the final Pareto archive.
    ///
    /// Each iteration re-scalarizes the history under a fresh weight
    /// vector, refits the shared core's GP on it, and asks the core for
    /// one acquisition step — ParEGO owns the scalarization and the
    /// Pareto archive, while the propose/observe machinery is the same
    /// [`BoCore`] every other entry point drives.
    pub fn optimize(&mut self, f: &dyn MultiEvaluator) -> Archive {
        let dim = f.dim_in();
        let k = f.dim_out();
        let mut archive = Archive::default();
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut objs: Vec<Vec<f64>> = Vec::new();

        let mut core = BoCore::new(
            Gp::new(Matern52::new(dim), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(128).then(NelderMead::default()).restarts(4, 2),
            dim,
            0,
        );
        // continue this instance's RNG stream across optimize() calls
        core.rng = self.rng.clone();

        for _ in 0..self.n_init {
            let x = core.rng.unit_point(dim);
            let o = f.eval(&x);
            archive.insert(x.clone(), o.clone());
            xs.push(x);
            objs.push(o);
        }

        for _ in 0..self.iterations {
            // random weight vector on the simplex
            let mut w: Vec<f64> = (0..k).map(|_| -core.rng.next_f64().ln()).collect();
            let sum: f64 = w.iter().sum();
            for wi in w.iter_mut() {
                *wi /= sum;
            }
            // scalarize history, refit the core's GP on it, and re-seed
            // the incumbent so the acquisition thresholds against the
            // *current* scalarization (the previous iteration's
            // observation used different weights)
            let ys: Vec<f64> = objs.iter().map(|o| tchebycheff(o, &w, self.rho)).collect();
            core.model.fit(&xs, &ys);
            core.refresh_incumbent();

            let x = core.propose();
            let o = f.eval(&x);
            archive.insert(x.clone(), o.clone());
            core.observe(&x, tchebycheff(&o, &w, self.rho));
            xs.push(x);
            objs.push(o);
        }
        self.rng = core.rng.clone();
        archive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Schaffer;

    impl MultiEvaluator for Schaffer {
        fn dim_in(&self) -> usize {
            1
        }
        fn dim_out(&self) -> usize {
            2
        }
        fn eval(&self, x: &[f64]) -> Vec<f64> {
            // maximize (-x^2, -(x-2)^2) on x in [0, 2] (scaled from [0,1])
            let t = 2.0 * x[0];
            vec![-(t * t), -((t - 2.0) * (t - 2.0))]
        }
    }

    #[test]
    fn dominance_is_strict_partial_order() {
        assert!(Archive::dominates(&[1.0, 1.0], &[0.0, 0.0]));
        assert!(Archive::dominates(&[1.0, 0.0], &[0.0, 0.0]));
        assert!(!Archive::dominates(&[1.0, -1.0], &[0.0, 0.0]));
        assert!(!Archive::dominates(&[0.0, 0.0], &[0.0, 0.0]));
    }

    #[test]
    fn archive_keeps_only_front() {
        let mut a = Archive::default();
        assert!(a.insert(vec![0.0], vec![0.0, 1.0]));
        assert!(a.insert(vec![1.0], vec![1.0, 0.0]));
        assert!(!a.insert(vec![2.0], vec![-1.0, -1.0]), "dominated point rejected");
        assert!(a.insert(vec![3.0], vec![2.0, 2.0]), "dominating point accepted");
        assert_eq!(a.len(), 1, "front collapsed to the dominating point");
    }

    #[test]
    fn hypervolume_2d_known() {
        let mut a = Archive::default();
        a.insert(vec![0.0], vec![1.0, 2.0]);
        a.insert(vec![1.0], vec![2.0, 1.0]);
        // ref (0,0): rect(2x1) + rect(1x1) = 3
        let hv = a.hypervolume_2d(&[0.0, 0.0]);
        assert!((hv - 3.0).abs() < 1e-12, "hv={hv}");
    }

    #[test]
    fn parego_covers_schaffer_front() {
        let mut pe = ParEgo::new(3);
        pe.iterations = 25;
        let archive = pe.optimize(&Schaffer);
        assert!(archive.len() >= 3, "front size {}", archive.len());
        // end points of the front should be approached: obj0 near 0 and
        // obj1 near 0 both present
        let best0 = archive.front().iter().map(|(_, o)| o[0]).fold(f64::NEG_INFINITY, f64::max);
        let best1 = archive.front().iter().map(|(_, o)| o[1]).fold(f64::NEG_INFINITY, f64::max);
        assert!(best0 > -0.3, "best obj0 {best0}");
        assert!(best1 > -0.3, "best obj1 {best1}");
    }
}
