//! The two Figure-1 configurations.
//!
//! The paper: "Limbo is configured to reproduce the default parameters of
//! BayesOpt" — LHS(10) initialization, ARD Matérn-5/2, Expected
//! Improvement, DIRECT inner optimizer; two variants, with and without
//! hyper-parameter optimization. The *algorithm* is identical across the
//! two columns; only the architecture differs (static generics vs trait
//! objects + full refits), which is exactly what Figure 1 measures.

use crate::acqui::Ei;
use crate::baseline::{BayesOptLike, BayesOptLikeConfig};
use crate::bayes_opt::{BoDef, FnEval, RefitSchedule};
use crate::benchfns::TestFunction;
use crate::coordinator::experiment::{BenchConfig, RunOutcome};
use crate::init::Lhs;
use crate::model::HpOptConfig;
use crate::opt::{AdaptiveDe, Cmaes, Direct, Optimizer};
use crate::rng::Pcg64;
use crate::stop::MaxIterations;

/// Shared algorithmic settings of both columns.
#[derive(Clone, Copy, Debug)]
pub struct Fig1Settings {
    /// LHS initialization size.
    pub n_init: usize,
    /// Model-guided iterations.
    pub iterations: usize,
    /// DIRECT evaluation budget per acquisition maximization.
    pub inner_evals: usize,
    /// ML-II refit period (`None` = the "without HPO" panel).
    pub hp_every: Option<usize>,
    /// Rprop iterations per refit.
    pub hp_iters: usize,
    /// GP observation-noise std.
    pub noise: f64,
}

impl Default for Fig1Settings {
    fn default() -> Self {
        Self {
            n_init: 10,
            iterations: 40,
            inner_evals: 500,
            hp_every: None,
            hp_iters: 20,
            noise: 1e-2,
        }
    }
}

impl Fig1Settings {
    /// The "with hyper-parameter optimization" variant (refit every 5
    /// samples, mirroring BayesOpt's periodic ML-II updates).
    pub fn with_hpo(mut self) -> Self {
        self.hp_every = Some(5);
        self
    }
}

/// The static (policy-based) column: `BOptimizer` monomorphized over the
/// BayesOpt-default components.
pub struct LimboConfig {
    /// Shared settings.
    pub settings: Fig1Settings,
    name: String,
}

impl LimboConfig {
    /// Build the limbo column.
    pub fn new(settings: Fig1Settings) -> Self {
        let name =
            if settings.hp_every.is_some() { "limbo+hpo" } else { "limbo" }.to_string();
        Self { settings, name }
    }
}

impl BenchConfig for LimboConfig {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&self, f: &dyn TestFunction, seed: u64) -> RunOutcome {
        let s = &self.settings;
        let dim = f.dim();
        let refit = match s.hp_every {
            Some(k) => RefitSchedule::Every(k),
            None => RefitSchedule::Never,
        };
        let mut opt = BoDef::new(dim)
            .noise(s.noise)
            .acquisition(Ei::default())
            .init(Lhs { n: s.n_init })
            .inner_opt(Direct::new(s.inner_evals))
            .stop(MaxIterations(s.iterations))
            .refit(refit)
            .hp_config(HpOptConfig { iterations: s.hp_iters, restarts: 1, ..Default::default() })
            .seed(seed)
            .build_optimizer();
        let best = opt.optimize(&FnEval::new(dim, |x: &[f64]| f.eval(x)));
        RunOutcome::ok(best.value, best.evaluations)
    }
}

/// The dynamic (classic-OO) column: [`BayesOptLike`].
pub struct BaselineConfig {
    /// Shared settings.
    pub settings: Fig1Settings,
    name: String,
}

impl BaselineConfig {
    /// Build the baseline column.
    pub fn new(settings: Fig1Settings) -> Self {
        let name =
            if settings.hp_every.is_some() { "bayesopt+hpo" } else { "bayesopt" }.to_string();
        Self { settings, name }
    }
}

impl BenchConfig for BaselineConfig {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&self, f: &dyn TestFunction, seed: u64) -> RunOutcome {
        let s = &self.settings;
        let mut opt = BayesOptLike::new(seed);
        opt.config = BayesOptLikeConfig {
            n_init: s.n_init,
            iterations: s.iterations,
            inner_evals: s.inner_evals,
            hp_every: s.hp_every,
            hp_iters: s.hp_iters,
            noise: s.noise,
        };
        let best = opt.optimize(&FnEval::new(f.dim(), |x: &[f64]| f.eval(x)));
        RunOutcome::ok(best.value, best.evaluations)
    }
}

/// Non-BO comparator: self-adaptive Differential Evolution applied
/// **directly** to the test function, at the same total evaluation
/// budget the BO columns get (`n_init + iterations`). The Fig-1 table's
/// derivative-free control — it shows what the surrogate model buys
/// over a plain population search at equal cost.
pub struct DeBaselineConfig {
    /// Shared settings (only the evaluation budget is used).
    pub settings: Fig1Settings,
}

impl DeBaselineConfig {
    /// Build the DE comparator column.
    pub fn new(settings: Fig1Settings) -> Self {
        Self { settings }
    }
}

impl BenchConfig for DeBaselineConfig {
    fn name(&self) -> &str {
        "de"
    }

    fn run(&self, f: &dyn TestFunction, seed: u64) -> RunOutcome {
        let budget = self.settings.n_init + self.settings.iterations;
        let objective = |x: &[f64]| f.eval(x);
        let mut rng = Pcg64::seed(seed);
        let best = AdaptiveDe::new(budget).optimize(&objective, f.dim(), &mut rng);
        RunOutcome::ok(best.value, budget)
    }
}

/// Which acquisition maximizer an [`InnerOptConfig`] column uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InnerOptKind {
    /// Deterministic rectangle subdivision (the BayesOpt default).
    Direct,
    /// Covariance-matrix-adaptation evolution strategy.
    Cmaes,
    /// Self-adaptive Differential Evolution.
    De,
}

impl InnerOptKind {
    /// Stable lowercase name (the `inner` field of the bench rows).
    pub fn name(self) -> &'static str {
        match self {
            InnerOptKind::Direct => "direct",
            InnerOptKind::Cmaes => "cmaes",
            InnerOptKind::De => "de",
        }
    }
}

/// The inner-optimizer sweep column: the same BO configuration as
/// [`LimboConfig`] with the acquisition maximizer swapped — DIRECT vs
/// CMA-ES vs DE at an **equal inner-opt evaluation budget**
/// (`settings.inner_evals`), so the `fig1_inner_opt` bench rows compare
/// maximizer quality, not budget.
pub struct InnerOptConfig {
    /// Shared settings.
    pub settings: Fig1Settings,
    /// Which maximizer this column runs.
    pub inner: InnerOptKind,
    name: String,
}

impl InnerOptConfig {
    /// Build one sweep column.
    pub fn new(settings: Fig1Settings, inner: InnerOptKind) -> Self {
        let name = format!("limbo+{}", inner.name());
        Self { settings, inner, name }
    }
}

impl BenchConfig for InnerOptConfig {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&self, f: &dyn TestFunction, seed: u64) -> RunOutcome {
        let s = &self.settings;
        let dim = f.dim();
        let refit = match s.hp_every {
            Some(k) => RefitSchedule::Every(k),
            None => RefitSchedule::Never,
        };
        // one builder per arm: each monomorphizes a different BoDef
        macro_rules! run_with {
            ($inner:expr) => {{
                let mut opt = BoDef::new(dim)
                    .noise(s.noise)
                    .acquisition(Ei::default())
                    .init(Lhs { n: s.n_init })
                    .inner_opt($inner)
                    .stop(MaxIterations(s.iterations))
                    .refit(refit)
                    .hp_config(HpOptConfig {
                        iterations: s.hp_iters,
                        restarts: 1,
                        ..Default::default()
                    })
                    .seed(seed)
                    .build_optimizer();
                let best = opt.optimize(&FnEval::new(dim, |x: &[f64]| f.eval(x)));
                RunOutcome::ok(best.value, best.evaluations)
            }};
        }
        match self.inner {
            InnerOptKind::Direct => run_with!(Direct::new(s.inner_evals)),
            InnerOptKind::Cmaes => run_with!(Cmaes::new(s.inner_evals)),
            InnerOptKind::De => run_with!(AdaptiveDe::new(s.inner_evals)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchfns::Branin;

    #[test]
    fn both_columns_reach_similar_accuracy() {
        // the paper's accuracy claim: the two implementations land within
        // ~2e-3 of each other (same algorithm). Single-seed smoke version.
        let s = Fig1Settings { iterations: 25, inner_evals: 300, ..Default::default() };
        let branin = Branin;
        let a = LimboConfig::new(s).run(&branin, 42);
        let b = BaselineConfig::new(s).run(&branin, 42);
        let acc_a = branin.accuracy(a.best_value);
        let acc_b = branin.accuracy(b.best_value);
        // single-seed smoke bounds; the real protocol is examples/fig1_repro
        assert!(acc_a < 5.0, "limbo acc={acc_a}");
        assert!(acc_b < 5.0, "baseline acc={acc_b}");
    }

    #[test]
    fn names_encode_hpo() {
        assert_eq!(LimboConfig::new(Fig1Settings::default()).name(), "limbo");
        assert_eq!(
            LimboConfig::new(Fig1Settings::default().with_hpo()).name(),
            "limbo+hpo"
        );
        assert_eq!(
            BaselineConfig::new(Fig1Settings::default().with_hpo()).name(),
            "bayesopt+hpo"
        );
    }
}
