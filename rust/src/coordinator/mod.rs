//! L3 coordination: everything above a single optimizer run.
//!
//! * [`experiment`] — the replicated benchmark runner behind Figure 1
//!   (replicates × functions × configurations over the thread pool,
//!   quartile aggregation, speed-up tables);
//! * [`fig1`] — the two Figure-1 configurations (static limbo vs the
//!   dyn-dispatch BayesOpt-like baseline, with/without HPO);
//! * [`xla_model`] — adapter exposing [`crate::runtime::XlaGp`] as a
//!   [`crate::model::Model`] so the whole component zoo runs on the
//!   AOT-compiled artifacts;
//! * [`service`] — ask/tell suggestion server (channel-based, the online
//!   adaptation deployment mode: the robot asks for a trial, reports the
//!   outcome, asks again), a thin frontend over the shared
//!   [`crate::bayes_opt::BoCore`] engine with q-point batch proposals
//!   via the constant liar or joint-posterior Monte-Carlo qEI
//!   ([`service::BatchStrategy`]);
//! * [`batched_opt`] — batched UCB acquisition search for the XLA
//!   backend, now a thin adapter over the generic
//!   [`crate::opt::PopulationSearch`] + `eval_many` machinery (still ~64
//!   candidates per artifact execution);
//! * [`manager`] — the multi-study registry: thousands of concurrent
//!   studies multiplexed over one shared [`crate::pool::ThreadPool`]
//!   behind the typed [`manager::StudyId`] / [`manager::Study`] surface,
//!   each durable across restarts via event sourcing + refit-barrier
//!   snapshots and evictable under a live-study budget;
//! * [`config`] — tiny key=value run-configuration parser for the CLI;
//! * [`multiobj`] — ParEGO-style scalarized multi-objective support (the
//!   paper notes "Limbo can support multi-objective optimization").

pub mod batched_opt;
pub mod config;
pub mod experiment;
pub mod fig1;
pub mod manager;
pub mod multiobj;
pub mod service;
pub mod xla_model;

pub use experiment::{ExperimentRunner, ExperimentRow, RunOutcome};
pub use manager::{ManagedStudy, Study, StudyError, StudyId, StudyManager};
pub use service::{
    AskTellServer, BatchStrategy, DefaultAskTellServer, DefaultDenseServer, ServerHandle,
};
pub use xla_model::XlaGpModel;
