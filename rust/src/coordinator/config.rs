//! Tiny `key = value` run-configuration parser (no serde offline).
//!
//! Accepted syntax: one `key = value` per line, `#` comments, blank lines
//! ignored. Typed getters with defaults back the CLI and the experiment
//! drivers.

use std::collections::HashMap;
use std::path::Path;

/// Parsed configuration map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: HashMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut map = HashMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected `key = value`, got {raw:?}", lineno + 1));
            };
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { map })
    }

    /// Parse from a file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Build from CLI `key=value` arguments.
    pub fn from_args(args: &[String]) -> Result<Self, String> {
        Self::parse(&args.join("\n"))
    }

    /// Merge `other` over `self` (other wins).
    pub fn merge(&mut self, other: Config) {
        self.map.extend(other.map);
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    /// Typed lookup with default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Typed lookup with default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Typed lookup with default.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") | Some("on") => true,
            Some("false") | Some("0") | Some("no") | Some("off") => false,
            _ => default,
        }
    }

    /// Typed lookup with default.
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the configuration empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typed_values() {
        let c = Config::parse(
            "# comment\nreplicates = 50\nnoise = 0.01\nhpo = true\nfunction = branin\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("replicates", 1), 50);
        assert_eq!(c.get_f64("noise", 0.0), 0.01);
        assert!(c.get_bool("hpo", false));
        assert_eq!(c.get_str("function", "?"), "branin");
        assert_eq!(c.get_usize("missing", 7), 7);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("not a kv line").is_err());
    }

    #[test]
    fn merge_and_args() {
        let mut a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::from_args(&["y=3".into(), "z=4".into()]).unwrap();
        a.merge(b);
        assert_eq!(a.get_usize("x", 0), 1);
        assert_eq!(a.get_usize("y", 0), 3);
        assert_eq!(a.get_usize("z", 0), 4);
    }

    #[test]
    fn inline_comments_stripped() {
        let c = Config::parse("a = 5 # five").unwrap();
        assert_eq!(c.get_usize("a", 0), 5);
    }
}
