//! Ask/tell suggestion server — the online-adaptation deployment mode.
//!
//! The Cully et al. (2015) scenario the paper motivates: a robot (the
//! client) repeatedly asks the optimizer for the next trial, executes it
//! physically, and reports the observed outcome. The optimizer must answer
//! fast (it runs on the embedded side), so the server owns the model and
//! the acquisition maximization, and communicates over `mpsc` channels
//! from a dedicated thread.
//!
//! [`AskTellServer::ask_batch`] extends the protocol to q-point proposals
//! (constant-liar heuristic), so the server can drive a fleet of parallel
//! evaluators — robot farms, cluster workers — instead of one trial at a
//! time.

use std::sync::mpsc;
use std::thread;

use crate::acqui::{AcquiContext, AcquiFn, AcquiObjective, Ucb};
use crate::kernel::Matern52;
use crate::mean::DataMean;
use crate::model::{AdaptiveModel, Model};
use crate::opt::{Chained, NelderMead, Optimizer, OptimizerExt, ParallelRepeater, RandomPoint};
use crate::rng::Pcg64;

/// Requests a client can send.
enum Request {
    /// Ask for the next point to try.
    Ask(mpsc::Sender<Vec<f64>>),
    /// Ask for `q` diverse points to try in parallel.
    AskBatch(usize, mpsc::Sender<Vec<Vec<f64>>>),
    /// Report an observation.
    Tell(Vec<f64>, f64),
    /// Ask for the incumbent best (x, value).
    Best(mpsc::Sender<Option<(Vec<f64>, f64)>>),
    Shutdown,
}

/// Synchronous ask/tell optimizer state (usable inline, no thread).
pub struct AskTellServer<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// Surrogate model.
    pub model: M,
    /// Acquisition policy.
    pub acquisition: A,
    /// Inner optimizer.
    pub inner_opt: O,
    /// RNG.
    pub rng: Pcg64,
    dim: usize,
    iteration: usize,
    best: Option<(Vec<f64>, f64)>,
    /// Next observation count at which the model re-optimizes its
    /// hyper-parameters (`None` = never). Doubles after each refit.
    next_hp_refit: Option<usize>,
}

/// The default service configuration: an [`AdaptiveModel`] surrogate
/// (dense while small, sparse past its threshold — an always-on ask/tell
/// server accumulates observations indefinitely, so the model must not
/// degrade to O(n³) refits), UCB, random+Nelder-Mead restarts.
pub type DefaultAskTellServer = AskTellServer<
    AdaptiveModel<Matern52, DataMean>,
    Ucb,
    ParallelRepeater<Chained<RandomPoint, NelderMead>>,
>;

impl DefaultAskTellServer {
    /// Service defaults for a `dim`-dimensional problem.
    pub fn with_defaults(dim: usize, seed: u64) -> Self {
        AskTellServer::new(
            AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(128).then(NelderMead::default()).restarts(4, 2),
            dim,
            seed,
        )
        .with_hp_refits(16)
    }
}

impl<M, A, O> AskTellServer<M, A, O>
where
    M: Model + 'static,
    A: AcquiFn<M> + 'static,
    O: Optimizer + 'static,
{
    /// Compose a server.
    pub fn new(model: M, acquisition: A, inner_opt: O, dim: usize, seed: u64) -> Self {
        Self {
            model,
            acquisition,
            inner_opt,
            rng: Pcg64::seed(seed),
            dim,
            iteration: 0,
            best: None,
            next_hp_refit: None,
        }
    }

    /// Enable ML-II hyper-parameter refits on a doubling schedule: the
    /// model re-optimizes when the observation count first reaches
    /// `first`, then at 2·`first`, 4·`first`, ... — O(log n) refits over
    /// an unbounded run. Once the [`AdaptiveModel`] has gone sparse each
    /// refit maximizes the **exact FITC marginal likelihood** (O(n·m²)
    /// per iRprop⁻ step), so the always-on service fits the objective it
    /// actually serves rather than a dense-subset proxy.
    pub fn with_hp_refits(mut self, first: usize) -> Self {
        self.next_hp_refit = Some(first.max(2));
        self
    }

    /// Next suggested trial. Before any data: a random probe.
    pub fn ask(&mut self) -> Vec<f64> {
        if self.model.n_samples() == 0 {
            return self.rng.unit_point(self.dim);
        }
        let ctx = AcquiContext::new(
            self.iteration,
            self.best.as_ref().map(|b| b.1).unwrap_or(f64::NEG_INFINITY),
            self.dim,
        );
        let objective = AcquiObjective::new(&self.model, &self.acquisition, ctx);
        self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
    }

    /// Propose `q` diverse trials to run in parallel, via the constant-
    /// liar heuristic: after each maximization the model is *told its own
    /// posterior mean* at the proposed point (the "lie"), the acquisition
    /// is re-maximized on the lied model, and all lies are rolled back at
    /// the end (the lies go into a scratch clone; `self.model` only ever
    /// sees real [`tell`](Self::tell) observations). Lying flattens the
    /// posterior variance around already-proposed points, steering the
    /// next maximization elsewhere — q distinct, informative trials.
    ///
    /// Before any data: `q` random probes.
    pub fn ask_batch(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let q = q.max(1);
        if self.model.n_samples() == 0 {
            return (0..q).map(|_| self.rng.unit_point(self.dim)).collect();
        }
        let mut liar = self.model.clone();
        let mut lied_best = self.best.as_ref().map(|b| b.1).unwrap_or(f64::NEG_INFINITY);
        let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
        for k in 0..q {
            let ctx = AcquiContext::new(self.iteration + k, lied_best, self.dim);
            let x = {
                let objective = AcquiObjective::new(&liar, &self.acquisition, ctx);
                self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
            };
            // degenerate acquisition landscapes can re-propose an earlier
            // point despite the lie; fall back to a random probe so the
            // batch stays diverse (1e-8 squared distance ~ 1e-4 per axis)
            let duplicate = batch.iter().any(|p| {
                p.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() < 1e-8
            });
            let x = if duplicate { self.rng.unit_point(self.dim) } else { x };
            let (lie, _) = liar.predict(&x);
            liar.add_sample(&x, lie);
            lied_best = lied_best.max(lie);
            batch.push(x);
        }
        batch
    }

    /// Report an observation. May trigger a scheduled hyper-parameter
    /// refit (see [`with_hp_refits`](Self::with_hp_refits)).
    pub fn tell(&mut self, x: &[f64], y: f64) {
        self.model.add_sample(x, y);
        self.iteration += 1;
        if self.best.as_ref().map_or(true, |b| y > b.1) {
            self.best = Some((x.to_vec(), y));
        }
        if let Some(next) = self.next_hp_refit {
            if self.model.n_samples() >= next {
                self.model.optimize_hyperparams();
                self.next_hp_refit = Some(next.saturating_mul(2));
            }
        }
    }

    /// Incumbent best.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.clone()
    }

    /// Move the server onto its own thread; returns a cloneable handle.
    /// (`M: Clone` backs the handle's q-batch
    /// [`ask_batch`](ServerHandle::ask_batch) — the constant liar needs a
    /// scratch copy of the model to lie to.)
    pub fn spawn(mut self) -> ServerHandle
    where
        M: Send + Clone,
        A: Send,
        O: Send,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Ask(reply) => {
                        let _ = reply.send(self.ask());
                    }
                    Request::AskBatch(q, reply) => {
                        let _ = reply.send(self.ask_batch(q));
                    }
                    Request::Tell(x, y) => self.tell(&x, y),
                    Request::Best(reply) => {
                        let _ = reply.send(self.best());
                    }
                    Request::Shutdown => break,
                }
            }
        });
        ServerHandle { tx, join: Some(join) }
    }
}

/// Client handle to a spawned [`AskTellServer`].
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request the next trial point (blocks for the reply).
    pub fn ask(&self) -> Vec<f64> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::Ask(tx)).expect("server alive");
        rx.recv().expect("server replied")
    }

    /// Request `q` diverse trial points for parallel evaluation (blocks
    /// for the reply; see [`AskTellServer::ask_batch`]).
    pub fn ask_batch(&self, q: usize) -> Vec<Vec<f64>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::AskBatch(q, tx)).expect("server alive");
        rx.recv().expect("server replied")
    }

    /// Report an observation (fire and forget).
    pub fn tell(&self, x: Vec<f64>, y: f64) {
        self.tx.send(Request::Tell(x, y)).expect("server alive");
    }

    /// Incumbent best.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::Best(tx)).expect("server alive");
        rx.recv().expect("server replied")
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ucb;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::gp::Gp;
    use crate::opt::{NelderMead, OptimizerExt, RandomPoint};

    fn make_server() -> AskTellServer<
        Gp<Matern52, DataMean>,
        Ucb,
        crate::opt::ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    > {
        AskTellServer::new(
            Gp::new(Matern52::new(1), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(64).then(NelderMead::default()).restarts(2, 2),
            1,
            9,
        )
    }

    #[test]
    fn inline_ask_tell_converges() {
        let mut srv = make_server();
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        for _ in 0..15 {
            let x = srv.ask();
            assert!((0.0..=1.0).contains(&x[0]));
            let y = f(&x);
            srv.tell(&x, y);
        }
        let (bx, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "best={bv} at {bx:?}");
    }

    #[test]
    fn default_server_uses_adaptive_model_and_converges() {
        let mut srv = DefaultAskTellServer::with_defaults(1, 17);
        assert!(!srv.model.is_sparse());
        let f = |x: &[f64]| -(x[0] - 0.8).powi(2);
        for _ in 0..15 {
            let x = srv.ask();
            let y = f(&x);
            srv.tell(&x, y);
        }
        let (_, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "best={bv}");
        assert_eq!(srv.model.n_samples(), 15);
    }

    #[test]
    fn threaded_server_round_trips() {
        let handle = make_server().spawn();
        let f = |x: &[f64]| -(x[0] - 0.25).powi(2);
        for _ in 0..10 {
            let x = handle.ask();
            handle.tell(x.clone(), f(&x));
        }
        let best = handle.best().unwrap();
        assert!(best.1 > -0.05, "best={}", best.1);
    }

    #[test]
    fn ask_batch_proposes_distinct_points_and_rolls_back_lies() {
        let mut srv = make_server();
        let f = |x: &[f64]| -(x[0] - 0.4).powi(2);
        // cold start: q random probes
        assert_eq!(srv.ask_batch(3).len(), 3);
        for x in [[0.1], [0.5], [0.9]] {
            srv.tell(&x, f(&x));
        }
        let n_before = srv.model.n_samples();
        let batch = srv.ask_batch(4);
        assert_eq!(batch.len(), 4);
        // the constant-liar lies must not leak into the real model
        assert_eq!(srv.model.n_samples(), n_before);
        for (i, a) in batch.iter().enumerate() {
            assert!((0.0..=1.0).contains(&a[0]));
            for b in batch.iter().skip(i + 1) {
                let d2: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
                assert!(d2 > 1e-10, "batch points {a:?} and {b:?} coincide");
            }
        }
    }

    #[test]
    fn hp_refit_schedule_fires_on_doubling_counts() {
        let mut rng = crate::rng::Pcg64::seed(31);
        let mut srv = AskTellServer::new(
            Gp::new(Matern52::new(1), DataMean::default(), 0.05),
            Ucb::default(),
            RandomPoint::new(32),
            1,
            7,
        )
        .with_hp_refits(8);
        srv.model.hp_opt.config.restarts = 1;
        srv.model.hp_opt.config.iterations = 10;
        let start_hp = srv.model.hp_vector();
        // short-lengthscale data: ML-II must move the kernel params
        for _ in 0..17 {
            let x = rng.unit_point(1);
            srv.tell(&x, (11.0 * x[0]).sin());
        }
        // refits fired at n = 8 and n = 16 (doubling schedule)
        assert_eq!(srv.model.hp_opt.refits(), 2);
        assert_ne!(srv.model.hp_vector(), start_hp, "refit should move hyper-params");
    }

    #[test]
    fn batched_ask_tell_converges_like_sequential() {
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        // sequential: 16 ask/tell rounds
        let mut seq = make_server();
        for _ in 0..16 {
            let x = seq.ask();
            let y = f(&x);
            seq.tell(&x, y);
        }
        // batched: 4 rounds of q=4 (same total budget) over the handle
        let handle = make_server().spawn();
        for _ in 0..4 {
            for x in handle.ask_batch(4) {
                let y = f(&x);
                handle.tell(x, y);
            }
        }
        let (_, sv) = seq.best().unwrap();
        let (_, bv) = handle.best().unwrap();
        assert!(sv > -0.02, "sequential best={sv}");
        assert!(bv > -0.02, "batched best={bv} should match sequential parity");
    }
}
