//! Ask/tell suggestion server — the online-adaptation deployment mode.
//!
//! The Cully et al. (2015) scenario the paper motivates: a robot (the
//! client) repeatedly asks the optimizer for the next trial, executes it
//! physically, and reports the observed outcome. The optimizer must answer
//! fast (it runs on the embedded side), so the server owns the model and
//! the acquisition maximization, and communicates over `mpsc` channels
//! from a dedicated thread.
//!
//! [`AskTellServer::ask_batch`] extends the protocol to q-point proposals
//! so the server can drive a fleet of parallel evaluators — robot farms,
//! cluster workers — instead of one trial at a time. Two proposal
//! strategies are available ([`BatchStrategy`]):
//!
//! * [`BatchStrategy::ConstantLiar`] (default) — after each pointwise
//!   maximization the model is told its own posterior mean at the
//!   proposed point (the "lie") and the acquisition is re-maximized;
//!   cheap (q ordinary maximizations) and latency-friendly, but the
//!   joint posterior correlation between batch points never enters the
//!   score.
//! * [`BatchStrategy::QEi`] — Monte-Carlo multi-point expected
//!   improvement over the **joint** posterior
//!   ([`crate::acqui::batch::QEi`], common random numbers frozen per
//!   proposal): strongly correlated points share a sample path and score
//!   barely better than one of them, so diversity is rewarded exactly
//!   where the posterior says it matters. Costs roughly
//!   `mc_samples`× more per objective evaluation than a pointwise EI —
//!   pick it when trials are expensive relative to proposal compute
//!   (the regime the paper's robot deployments live in).

use std::sync::mpsc;
use std::thread;

use crate::acqui::batch::{propose_batch_qei, QEi};
use crate::acqui::{AcquiContext, AcquiFn, AcquiObjective, Ucb};
use crate::kernel::Matern52;
use crate::mean::DataMean;
use crate::model::{AdaptiveModel, Model};
use crate::opt::{Chained, NelderMead, Optimizer, OptimizerExt, ParallelRepeater, RandomPoint};
use crate::rng::Pcg64;

/// How [`AskTellServer::ask_batch`] turns one model posterior into `q`
/// parallel trial proposals (see the module docs for the tradeoff).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchStrategy {
    /// Greedy pointwise re-maximization with posterior-mean lies.
    #[default]
    ConstantLiar,
    /// Monte-Carlo joint-posterior qEI with `mc_samples` frozen
    /// antithetic common-random-number draws per proposal round.
    QEi {
        /// MC draws per acquisition evaluation (rounded down to even;
        /// 256–1024 is a good range — noise shrinks as `1/sqrt`).
        mc_samples: usize,
    },
}

/// Requests a client can send.
enum Request {
    /// Ask for the next point to try.
    Ask(mpsc::Sender<Vec<f64>>),
    /// Ask for `q` diverse points to try in parallel.
    AskBatch(usize, mpsc::Sender<Vec<Vec<f64>>>),
    /// Report an observation.
    Tell(Vec<f64>, f64),
    /// Ask for the incumbent best (x, value).
    Best(mpsc::Sender<Option<(Vec<f64>, f64)>>),
    Shutdown,
}

/// Synchronous ask/tell optimizer state (usable inline, no thread).
pub struct AskTellServer<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// Surrogate model.
    pub model: M,
    /// Acquisition policy.
    pub acquisition: A,
    /// Inner optimizer.
    pub inner_opt: O,
    /// RNG.
    pub rng: Pcg64,
    dim: usize,
    iteration: usize,
    best: Option<(Vec<f64>, f64)>,
    /// Next observation count at which the model re-optimizes its
    /// hyper-parameters (`None` = never). Doubles past the current count
    /// after each refit.
    next_hp_refit: Option<usize>,
    /// q-point proposal strategy for [`ask_batch`](Self::ask_batch).
    batch_strategy: BatchStrategy,
}

/// The default service configuration: an [`AdaptiveModel`] surrogate
/// (dense while small, sparse past its threshold — an always-on ask/tell
/// server accumulates observations indefinitely, so the model must not
/// degrade to O(n³) refits), UCB, random+Nelder-Mead restarts.
pub type DefaultAskTellServer = AskTellServer<
    AdaptiveModel<Matern52, DataMean>,
    Ucb,
    ParallelRepeater<Chained<RandomPoint, NelderMead>>,
>;

impl DefaultAskTellServer {
    /// Service defaults for a `dim`-dimensional problem.
    pub fn with_defaults(dim: usize, seed: u64) -> Self {
        AskTellServer::new(
            AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(128).then(NelderMead::default()).restarts(4, 2),
            dim,
            seed,
        )
        .with_hp_refits(16)
    }
}

impl<M, A, O> AskTellServer<M, A, O>
where
    M: Model + 'static,
    A: AcquiFn<M> + 'static,
    O: Optimizer + 'static,
{
    /// Compose a server. A model that already has data (`fit` /
    /// deserialized state) seeds the incumbent: without this, the first
    /// `ask` ran EI/UCB against a `-inf` incumbent and
    /// [`best`](Self::best) lied `None` until the first `tell`.
    pub fn new(model: M, acquisition: A, inner_opt: O, dim: usize, seed: u64) -> Self {
        let best = model.best_sample();
        Self {
            model,
            acquisition,
            inner_opt,
            rng: Pcg64::seed(seed),
            dim,
            iteration: 0,
            best,
            next_hp_refit: None,
            batch_strategy: BatchStrategy::default(),
        }
    }

    /// Select the q-point proposal strategy for
    /// [`ask_batch`](Self::ask_batch).
    pub fn with_batch_strategy(mut self, strategy: BatchStrategy) -> Self {
        self.batch_strategy = strategy;
        self
    }

    /// Incumbent value for the acquisition context: the tracked best,
    /// else the model's own best observation (a pre-fitted model whose
    /// argmax is unknown — e.g. restored value-only state — must still
    /// threshold EI correctly), else `-inf` (no data at all).
    fn incumbent_value(&self) -> f64 {
        self.best
            .as_ref()
            .map(|b| b.1)
            .or_else(|| self.model.best_observation())
            .unwrap_or(f64::NEG_INFINITY)
    }

    /// Enable ML-II hyper-parameter refits on a doubling schedule: the
    /// model re-optimizes when the observation count first reaches
    /// `first`, then at 2·`first`, 4·`first`, ... — O(log n) refits over
    /// an unbounded run. Once the [`AdaptiveModel`] has gone sparse each
    /// refit maximizes the **exact FITC marginal likelihood** (O(n·m²)
    /// per iRprop⁻ step), so the always-on service fits the objective it
    /// actually serves rather than a dense-subset proxy.
    pub fn with_hp_refits(mut self, first: usize) -> Self {
        self.next_hp_refit = Some(first.max(2));
        self
    }

    /// Next suggested trial. Before any data: a random probe.
    pub fn ask(&mut self) -> Vec<f64> {
        if self.model.n_samples() == 0 {
            return self.rng.unit_point(self.dim);
        }
        let ctx = AcquiContext::new(self.iteration, self.incumbent_value(), self.dim);
        let objective = AcquiObjective::new(&self.model, &self.acquisition, ctx);
        self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
    }

    /// Propose `q` diverse trials to run in parallel, using the
    /// configured [`BatchStrategy`] (constant liar by default; see
    /// [`with_batch_strategy`](Self::with_batch_strategy) and the module
    /// docs for the tradeoff). Before any data: `q` random probes.
    pub fn ask_batch(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let q = q.max(1);
        if self.model.n_samples() == 0 {
            return (0..q).map(|_| self.rng.unit_point(self.dim)).collect();
        }
        let batch = match self.batch_strategy {
            BatchStrategy::ConstantLiar => self.ask_batch_constant_liar(q),
            BatchStrategy::QEi { mc_samples } => self.ask_batch_qei(q, mc_samples),
        };
        self.dedupe_batch(batch)
    }

    /// Constant-liar proposals: after each maximization the model is
    /// *told its own posterior mean* at the proposed point (the "lie"),
    /// the acquisition is re-maximized on the lied model, and all lies
    /// are rolled back at the end (the lies go into a scratch clone;
    /// `self.model` only ever sees real [`tell`](Self::tell)
    /// observations). Lying flattens the posterior variance around
    /// already-proposed points, steering the next maximization elsewhere.
    fn ask_batch_constant_liar(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let mut liar = self.model.clone();
        let mut lied_best = self.incumbent_value();
        let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
        for k in 0..q {
            let ctx = AcquiContext::new(self.iteration + k, lied_best, self.dim);
            let x = {
                let objective = AcquiObjective::new(&liar, &self.acquisition, ctx);
                self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
            };
            let (lie, _) = liar.predict(&x);
            liar.add_sample(&x, lie);
            lied_best = lied_best.max(lie);
            batch.push(x);
        }
        batch
    }

    /// Joint-posterior qEI proposals: one frozen-CRN [`QEi`] estimator
    /// per round (fresh seed per call, deterministic within the call),
    /// maximized by greedy marginal gains plus a joint refinement pass
    /// over the flattened `q·d` batch vector
    /// ([`propose_batch_qei`]). The server's pointwise acquisition is
    /// not consulted here — qEI *is* the acquisition for the whole batch.
    fn ask_batch_qei(&mut self, q: usize, mc_samples: usize) -> Vec<Vec<f64>> {
        let ctx = AcquiContext::new(self.iteration, self.incumbent_value(), self.dim);
        let seed = self.rng.next_u64();
        let qei = QEi::new(mc_samples, q, seed);
        propose_batch_qei(&self.model, &qei, &self.inner_opt, ctx, self.dim, q, &mut self.rng)
    }

    /// Degenerate acquisition landscapes can propose (near-)coincident
    /// points despite the lie/joint penalty; replace duplicates with
    /// random probes so the batch stays diverse (1e-8 squared distance
    /// ~ 1e-4 per axis).
    fn dedupe_batch(&mut self, batch: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(batch.len());
        for x in batch {
            let duplicate = out.iter().any(|p| {
                p.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() < 1e-8
            });
            out.push(if duplicate { self.rng.unit_point(self.dim) } else { x });
        }
        out
    }

    /// Report an observation. May trigger a scheduled hyper-parameter
    /// refit (see [`with_hp_refits`](Self::with_hp_refits)).
    pub fn tell(&mut self, x: &[f64], y: f64) {
        self.model.add_sample(x, y);
        self.iteration += 1;
        if self.best.as_ref().map_or(true, |b| y > b.1) {
            self.best = Some((x.to_vec(), y));
        }
        if let Some(next) = self.next_hp_refit {
            if self.model.n_samples() >= next {
                self.model.optimize_hyperparams();
                // advance the schedule past the *current* count: a burst
                // of tells (the ask_batch workflow) or a pre-fitted model
                // can leave n >= 2·next, and a single doubling would then
                // trigger a full ML-II refit on every subsequent tell
                // until the schedule catches up
                let mut next = next;
                while self.model.n_samples() >= next {
                    next = next.saturating_mul(2);
                }
                self.next_hp_refit = Some(next);
            }
        }
    }

    /// Incumbent best.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.clone()
    }

    /// Move the server onto its own thread; returns a cloneable handle.
    /// (`M: Clone` backs the handle's q-batch
    /// [`ask_batch`](ServerHandle::ask_batch) — the constant liar needs a
    /// scratch copy of the model to lie to.)
    pub fn spawn(mut self) -> ServerHandle
    where
        M: Send + Clone,
        A: Send,
        O: Send,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Ask(reply) => {
                        let _ = reply.send(self.ask());
                    }
                    Request::AskBatch(q, reply) => {
                        let _ = reply.send(self.ask_batch(q));
                    }
                    Request::Tell(x, y) => self.tell(&x, y),
                    Request::Best(reply) => {
                        let _ = reply.send(self.best());
                    }
                    Request::Shutdown => break,
                }
            }
        });
        ServerHandle { tx, join: Some(join) }
    }
}

/// Client handle to a spawned [`AskTellServer`].
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request the next trial point (blocks for the reply).
    pub fn ask(&self) -> Vec<f64> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::Ask(tx)).expect("server alive");
        rx.recv().expect("server replied")
    }

    /// Request `q` diverse trial points for parallel evaluation (blocks
    /// for the reply). The proposal strategy is server-side
    /// configuration: select constant liar vs joint-posterior qEI with
    /// [`AskTellServer::with_batch_strategy`] *before*
    /// [`AskTellServer::spawn`].
    pub fn ask_batch(&self, q: usize) -> Vec<Vec<f64>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::AskBatch(q, tx)).expect("server alive");
        rx.recv().expect("server replied")
    }

    /// Report an observation (fire and forget).
    pub fn tell(&self, x: Vec<f64>, y: f64) {
        self.tx.send(Request::Tell(x, y)).expect("server alive");
    }

    /// Incumbent best.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::Best(tx)).expect("server alive");
        rx.recv().expect("server replied")
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ucb;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::gp::Gp;
    use crate::opt::{NelderMead, OptimizerExt, RandomPoint};

    fn make_server() -> AskTellServer<
        Gp<Matern52, DataMean>,
        Ucb,
        crate::opt::ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    > {
        AskTellServer::new(
            Gp::new(Matern52::new(1), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(64).then(NelderMead::default()).restarts(2, 2),
            1,
            9,
        )
    }

    #[test]
    fn inline_ask_tell_converges() {
        let mut srv = make_server();
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        for _ in 0..15 {
            let x = srv.ask();
            assert!((0.0..=1.0).contains(&x[0]));
            let y = f(&x);
            srv.tell(&x, y);
        }
        let (bx, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "best={bv} at {bx:?}");
    }

    #[test]
    fn default_server_uses_adaptive_model_and_converges() {
        let mut srv = DefaultAskTellServer::with_defaults(1, 17);
        assert!(!srv.model.is_sparse());
        let f = |x: &[f64]| -(x[0] - 0.8).powi(2);
        for _ in 0..15 {
            let x = srv.ask();
            let y = f(&x);
            srv.tell(&x, y);
        }
        let (_, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "best={bv}");
        assert_eq!(srv.model.n_samples(), 15);
    }

    #[test]
    fn threaded_server_round_trips() {
        let handle = make_server().spawn();
        let f = |x: &[f64]| -(x[0] - 0.25).powi(2);
        for _ in 0..10 {
            let x = handle.ask();
            handle.tell(x.clone(), f(&x));
        }
        let best = handle.best().unwrap();
        assert!(best.1 > -0.05, "best={}", best.1);
    }

    #[test]
    fn ask_batch_proposes_distinct_points_and_rolls_back_lies() {
        let mut srv = make_server();
        let f = |x: &[f64]| -(x[0] - 0.4).powi(2);
        // cold start: q random probes
        assert_eq!(srv.ask_batch(3).len(), 3);
        for x in [[0.1], [0.5], [0.9]] {
            srv.tell(&x, f(&x));
        }
        let n_before = srv.model.n_samples();
        let batch = srv.ask_batch(4);
        assert_eq!(batch.len(), 4);
        // the constant-liar lies must not leak into the real model
        assert_eq!(srv.model.n_samples(), n_before);
        for (i, a) in batch.iter().enumerate() {
            assert!((0.0..=1.0).contains(&a[0]));
            for b in batch.iter().skip(i + 1) {
                let d2: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
                assert!(d2 > 1e-10, "batch points {a:?} and {b:?} coincide");
            }
        }
    }

    #[test]
    fn prefitted_model_seeds_the_incumbent() {
        // a server wrapped around a model that already has data must not
        // lie `best() == None` / run EI with a -inf incumbent until the
        // first tell
        let mut gp = Gp::new(Matern52::new(1), DataMean::default(), 1e-3);
        gp.fit(&[vec![0.1], vec![0.6], vec![0.9]], &[-5.0, -2.0, -4.0]);
        let mut srv = AskTellServer::new(gp, Ucb::default(), RandomPoint::new(32), 1, 3);
        let (bx, bv) = srv.best().expect("incumbent seeded from the model");
        assert_eq!(bx, vec![0.6]);
        assert_eq!(bv, -2.0);
        assert!((srv.incumbent_value() - -2.0).abs() < 1e-12);
        // ask works immediately with a finite incumbent
        let x = srv.ask();
        assert!((0.0..=1.0).contains(&x[0]));
        // a worse tell must not displace the seeded incumbent
        srv.tell(&[0.3], -9.0);
        assert_eq!(srv.best().unwrap().1, -2.0);
        srv.tell(&[0.55], -1.0);
        assert_eq!(srv.best().unwrap().1, -1.0);
    }

    #[test]
    fn burst_of_tells_triggers_one_refit_not_one_per_tell() {
        // pre-fitted model far past the first refit threshold: the
        // single-doubling schedule used to refit on *every* subsequent
        // tell until `next` caught up with n (O(n·m²) each — exactly the
        // ask_batch(q) burst workflow)
        let mut rng = crate::rng::Pcg64::seed(41);
        let xs: Vec<Vec<f64>> = (0..100).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (7.0 * x[0]).sin()).collect();
        let mut gp = Gp::new(Matern52::new(1), DataMean::default(), 0.05);
        gp.fit(&xs, &ys);
        let mut srv = AskTellServer::new(gp, Ucb::default(), RandomPoint::new(16), 1, 13)
            .with_hp_refits(16);
        srv.model.hp_opt.config.restarts = 1;
        srv.model.hp_opt.config.iterations = 3;
        // a 4-point burst (one ask_batch round's worth of tells)
        for x in [[0.11], [0.31], [0.51], [0.71]] {
            srv.tell(&x, (7.0 * x[0]).sin());
        }
        assert_eq!(
            srv.model.hp_opt.refits(),
            1,
            "one refit for the burst, schedule advanced past n"
        );
        assert_eq!(srv.next_hp_refit, Some(128), "16 doubled past n=101 in one step");
    }

    #[test]
    fn qei_strategy_proposes_distinct_points_and_converges() {
        let f = |x: &[f64]| -(x[0] - 0.4).powi(2);
        let mut srv = make_server().with_batch_strategy(BatchStrategy::QEi { mc_samples: 128 });
        // cold start: q random probes
        assert_eq!(srv.ask_batch(3).len(), 3);
        for x in [[0.1], [0.5], [0.9]] {
            srv.tell(&x, f(&x));
        }
        let n_before = srv.model.n_samples();
        let batch = srv.ask_batch(4);
        assert_eq!(batch.len(), 4);
        // qEI scores the real model read-only: nothing may leak into it
        assert_eq!(srv.model.n_samples(), n_before);
        for (i, a) in batch.iter().enumerate() {
            assert!((0.0..=1.0).contains(&a[0]));
            for b in batch.iter().skip(i + 1) {
                let d2: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
                assert!(d2 > 1e-10, "batch points {a:?} and {b:?} coincide");
            }
        }
        // full loop converges like the constant liar does
        for _ in 0..4 {
            for x in srv.ask_batch(4) {
                let y = f(&x);
                srv.tell(&x, y);
            }
        }
        let (_, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "qEI batched best={bv}");
    }

    #[test]
    fn hp_refit_schedule_fires_on_doubling_counts() {
        let mut rng = crate::rng::Pcg64::seed(31);
        let mut srv = AskTellServer::new(
            Gp::new(Matern52::new(1), DataMean::default(), 0.05),
            Ucb::default(),
            RandomPoint::new(32),
            1,
            7,
        )
        .with_hp_refits(8);
        srv.model.hp_opt.config.restarts = 1;
        srv.model.hp_opt.config.iterations = 10;
        let start_hp = srv.model.hp_vector();
        // short-lengthscale data: ML-II must move the kernel params
        for _ in 0..17 {
            let x = rng.unit_point(1);
            srv.tell(&x, (11.0 * x[0]).sin());
        }
        // refits fired at n = 8 and n = 16 (doubling schedule)
        assert_eq!(srv.model.hp_opt.refits(), 2);
        assert_ne!(srv.model.hp_vector(), start_hp, "refit should move hyper-params");
    }

    #[test]
    fn batched_ask_tell_converges_like_sequential() {
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        // sequential: 16 ask/tell rounds
        let mut seq = make_server();
        for _ in 0..16 {
            let x = seq.ask();
            let y = f(&x);
            seq.tell(&x, y);
        }
        // batched: 4 rounds of q=4 (same total budget) over the handle
        let handle = make_server().spawn();
        for _ in 0..4 {
            for x in handle.ask_batch(4) {
                let y = f(&x);
                handle.tell(x, y);
            }
        }
        let (_, sv) = seq.best().unwrap();
        let (_, bv) = handle.best().unwrap();
        assert!(sv > -0.02, "sequential best={sv}");
        assert!(bv > -0.02, "batched best={bv} should match sequential parity");
    }
}
