//! Ask/tell suggestion server — the online-adaptation deployment mode.
//!
//! The Cully et al. (2015) scenario the paper motivates: a robot (the
//! client) repeatedly asks the optimizer for the next trial, executes it
//! physically, and reports the observed outcome. The optimizer must answer
//! fast (it runs on the embedded side), so the server owns the model and
//! the acquisition maximization, and communicates over `mpsc` channels
//! from a dedicated thread.
//!
//! The loop itself is not implemented here: [`AskTellServer`] is a thin
//! frontend over the shared [`BoCore`] engine — `ask`/`tell` are
//! `propose`/`observe`, so the server, [`crate::bayes_opt::BOptimizer`]
//! and the [`crate::baseline`] comparator all run the *same*
//! propose/observe/refit state machine (same [`RefitSchedule`], same
//! incumbent rules, same [`BatchStrategy`] q-point proposals, same
//! [`crate::bayes_opt::Observer`] event bus). A server built from a
//! [`crate::bayes_opt::BoDef`] additionally serves the definition's
//! initial design from its first asks, making its trace bit-identical
//! to the run-to-completion frontend for the same seed.
//!
//! [`AskTellServer::ask_batch`] extends the protocol to q-point proposals
//! so the server can drive a fleet of parallel evaluators — robot farms,
//! cluster workers — instead of one trial at a time; see
//! [`BatchStrategy`] for the constant-liar vs joint-posterior qEI
//! tradeoff.

use std::sync::mpsc;
use std::thread;

use crate::acqui::{AcquiFn, Ucb};
use crate::bayes_opt::core::{BoCore, BoError, Domain, Observation, Observer, RefitSchedule};
use crate::kernel::Matern52;
use crate::mean::DataMean;
use crate::model::{AdaptiveModel, Gp, Model};
use crate::opt::{Chained, NelderMead, Optimizer, ParallelRepeater, RandomPoint};

use super::manager::{Study, StudyError};

pub use crate::bayes_opt::core::BatchStrategy;

/// Requests a client can send.
enum Request {
    /// Ask for the next point to try.
    Ask(mpsc::Sender<Vec<f64>>),
    /// Ask for `q` diverse points to try in parallel.
    AskBatch(usize, mpsc::Sender<Vec<Vec<f64>>>),
    /// Report an observation.
    Tell(Vec<f64>, f64),
    /// Report a generalized [`Observation`] (noisy / constrained),
    /// acknowledged so arity errors reach the caller.
    TellObs(Box<Observation>, mpsc::Sender<Result<(), BoError>>),
    /// Ask for the incumbent best (x, value).
    Best(mpsc::Sender<Option<(Vec<f64>, f64)>>),
    Shutdown,
}

/// Synchronous ask/tell optimizer state (usable inline, no thread).
pub struct AskTellServer<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// The shared ask/tell engine this server fronts.
    pub core: BoCore<M, A, O>,
}

/// The default service configuration: an [`AdaptiveModel`] surrogate
/// (dense while small, sparse past its threshold — an always-on ask/tell
/// server accumulates observations indefinitely, so the model must not
/// degrade to O(n³) refits), UCB, random+Nelder-Mead restarts.
pub type DefaultAskTellServer = AskTellServer<
    AdaptiveModel<Matern52, DataMean>,
    Ucb,
    ParallelRepeater<Chained<RandomPoint, NelderMead>>,
>;

/// The dense service configuration —
/// `BoDef::service(dim).build_server()` returns this. The named alias
/// keeps [`crate::coordinator::StudyManager`] factory signatures
/// writable without spelling out the optimizer stack.
pub type DefaultDenseServer = AskTellServer<
    Gp<Matern52, DataMean>,
    Ucb,
    ParallelRepeater<Chained<RandomPoint, NelderMead>>,
>;

impl<M, A, O> AskTellServer<M, A, O>
where
    M: Model + 'static,
    A: AcquiFn<M> + 'static,
    O: Optimizer + 'static,
{
    /// Wrap an assembled [`BoCore`] as a server. This is the escape
    /// hatch for configurations [`crate::bayes_opt::BoDef`] does not
    /// express (e.g. a hand-built [`AdaptiveModel`] with custom sparse
    /// thresholds, or a pre-fitted model); everything else should go
    /// through the definition builder —
    /// `BoDef::service(dim).build_server()` — which validates bounds
    /// and seeds the initial design. [`BoCore::new`] seeds the
    /// incumbent from a model that already has data, so a server around
    /// a pre-fitted model never lies `best() == None`.
    pub fn from_core(core: BoCore<M, A, O>) -> Self {
        Self { core }
    }

    /// Select the q-point proposal strategy for
    /// [`ask_batch`](Self::ask_batch).
    pub fn with_batch_strategy(mut self, strategy: BatchStrategy) -> Self {
        self.core = self.core.with_batch_strategy(strategy);
        self
    }

    /// Set the hyper-parameter refit schedule. The service default
    /// (via [`crate::bayes_opt::BoDef`]) is
    /// `RefitSchedule::Doubling { first: 16 }`: O(log n) ML-II refits
    /// over an unbounded run, each maximizing the **exact FITC marginal
    /// likelihood** once the [`AdaptiveModel`] has gone sparse.
    pub fn with_refit(mut self, schedule: RefitSchedule) -> Self {
        self.core = self.core.with_refit(schedule);
        self
    }

    /// Set the search domain (user bounds mapped to the unit cube).
    pub fn with_domain(mut self, domain: Domain) -> Self {
        self.core = self.core.with_domain(domain);
        self
    }

    /// Subscribe a run observer.
    pub fn with_observer(mut self, observer: impl Observer + 'static) -> Self {
        self.core = self.core.with_observer(observer);
        self
    }

    /// Incumbent value for the acquisition context (see
    /// [`BoCore::incumbent_value`]).
    pub fn incumbent_value(&self) -> f64 {
        self.core.incumbent_value()
    }

    /// Next suggested trial: a queued initial-design point if the server
    /// was built from a definition with one, a random probe before any
    /// data, else the acquisition maximizer. When the core runs in
    /// async-pending mode ([`crate::bayes_opt::BoDef::async_pending`]),
    /// the proposal also fantasizes over outstanding trials and registers
    /// itself as pending, so concurrent workers never get duplicates.
    pub fn ask(&mut self) -> Vec<f64>
    where
        M: Clone,
    {
        if self.core.async_pending() {
            self.core.propose_pending()
        } else {
            self.core.propose()
        }
    }

    /// Propose `q` diverse trials to run in parallel, using the
    /// configured [`BatchStrategy`] (constant liar by default; see
    /// [`with_batch_strategy`](Self::with_batch_strategy)). Before any
    /// data: `q` random probes.
    pub fn ask_batch(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        self.core.propose_batch(q)
    }

    /// Report an observation. May trigger a scheduled hyper-parameter
    /// refit (see [`with_refit`](Self::with_refit)).
    pub fn tell(&mut self, x: &[f64], y: f64) {
        self.core.observe(x, y);
    }

    /// Report a generalized [`Observation`] — per-trial noise and/or
    /// constraint-channel values ride along with `(x, y)`. Fails with
    /// [`BoError::ConstraintArity`] (before any state mutates) when the
    /// observation's constraint count does not match the model's.
    pub fn tell_observation(&mut self, obs: &Observation) -> Result<(), BoError> {
        self.core.try_observe(obs)
    }

    /// Incumbent best.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.core.best()
    }

    /// Signal the end of the run to the attached observers
    /// ([`crate::bayes_opt::BoEvent::Stopped`] — file-writing observers
    /// flush on it). Idempotent. A spawned server does this on
    /// shutdown automatically; an inline server's driving loop calls it
    /// when the run is over.
    pub fn finish(&mut self) {
        self.core.finish();
    }

    /// Move the server onto its own thread; returns a cloneable handle.
    /// (`M: Clone` backs the handle's q-batch
    /// [`ask_batch`](ServerHandle::ask_batch) — the constant liar needs a
    /// scratch copy of the model to lie to.)
    pub fn spawn(mut self) -> ServerHandle
    where
        M: Send + Clone,
        A: Send,
        O: Send,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Ask(reply) => {
                        let _ = reply.send(self.ask());
                    }
                    Request::AskBatch(q, reply) => {
                        let _ = reply.send(self.ask_batch(q));
                    }
                    Request::Tell(x, y) => self.tell(&x, y),
                    Request::TellObs(obs, reply) => {
                        let _ = reply.send(self.core.try_observe(&obs));
                    }
                    Request::Best(reply) => {
                        let _ = reply.send(self.best());
                    }
                    Request::Shutdown => break,
                }
            }
            // flush file-writing observers before the thread exits
            self.core.finish();
        });
        ServerHandle { tx, join: Some(join) }
    }
}

/// The inline server *is* a [`Study`]: infallible operations wrapped in
/// `Ok`, so generic driver code runs unchanged against the inline,
/// threaded and managed deployment modes.
impl<M, A, O> Study for AskTellServer<M, A, O>
where
    M: Model + Clone + 'static,
    A: AcquiFn<M> + 'static,
    O: Optimizer + 'static,
{
    fn ask(&mut self) -> Result<Vec<f64>, StudyError> {
        Ok(AskTellServer::ask(self))
    }

    fn ask_batch(&mut self, q: usize) -> Result<Vec<Vec<f64>>, StudyError> {
        Ok(self.core.propose_batch(q))
    }

    fn tell(&mut self, x: &[f64], y: f64) -> Result<(), StudyError> {
        self.core.observe(x, y);
        Ok(())
    }

    fn tell_observation(&mut self, obs: Observation) -> Result<(), StudyError> {
        self.core.try_observe(&obs).map_err(StudyError::Rejected)
    }

    fn best(&self) -> Result<Option<(Vec<f64>, f64)>, StudyError> {
        Ok(self.core.best())
    }

    fn finish(&mut self) -> Result<(), StudyError> {
        self.core.finish();
        Ok(())
    }
}

/// Client handle to a spawned [`AskTellServer`].
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request the next trial point (blocks for the reply). Panics if
    /// the server is gone; see [`try_ask`](Self::try_ask).
    pub fn ask(&self) -> Vec<f64> {
        self.try_ask().expect("server alive")
    }

    /// Fallible [`ask`](Self::ask): [`StudyError::Closed`] once the
    /// server thread has shut down.
    pub fn try_ask(&self) -> Result<Vec<f64>, StudyError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::Ask(tx)).map_err(|_| StudyError::Closed)?;
        rx.recv().map_err(|_| StudyError::Closed)
    }

    /// Request `q` diverse trial points for parallel evaluation (blocks
    /// for the reply). The proposal strategy is server-side
    /// configuration: select constant liar vs joint-posterior qEI with
    /// [`AskTellServer::with_batch_strategy`] *before*
    /// [`AskTellServer::spawn`]. Panics if the server is gone; see
    /// [`try_ask_batch`](Self::try_ask_batch).
    pub fn ask_batch(&self, q: usize) -> Vec<Vec<f64>> {
        self.try_ask_batch(q).expect("server alive")
    }

    /// Fallible [`ask_batch`](Self::ask_batch).
    pub fn try_ask_batch(&self, q: usize) -> Result<Vec<Vec<f64>>, StudyError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::AskBatch(q, tx)).map_err(|_| StudyError::Closed)?;
        rx.recv().map_err(|_| StudyError::Closed)
    }

    /// Report an observation (fire and forget). Panics if the server is
    /// gone; see [`try_tell`](Self::try_tell).
    pub fn tell(&self, x: Vec<f64>, y: f64) {
        self.try_tell(x, y).expect("server alive")
    }

    /// Fallible [`tell`](Self::tell).
    pub fn try_tell(&self, x: Vec<f64>, y: f64) -> Result<(), StudyError> {
        self.tx.send(Request::Tell(x, y)).map_err(|_| StudyError::Closed)
    }

    /// Report a generalized [`Observation`] (blocks for the server's
    /// acknowledgement, unlike the fire-and-forget [`tell`](Self::tell),
    /// so a constraint-arity mistake surfaces as
    /// [`StudyError::Rejected`] instead of vanishing on a worker
    /// thread). [`StudyError::Closed`] once the server is gone.
    pub fn try_tell_observation(&self, obs: Observation) -> Result<(), StudyError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::TellObs(Box::new(obs), tx)).map_err(|_| StudyError::Closed)?;
        rx.recv().map_err(|_| StudyError::Closed)?.map_err(StudyError::Rejected)
    }

    /// Incumbent best. Panics if the server is gone; see
    /// [`try_best`](Self::try_best).
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.try_best().expect("server alive")
    }

    /// Fallible [`best`](Self::best).
    pub fn try_best(&self) -> Result<Option<(Vec<f64>, f64)>, StudyError> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Request::Best(tx)).map_err(|_| StudyError::Closed)?;
        rx.recv().map_err(|_| StudyError::Closed)
    }
}

/// The threaded handle as a [`Study`]: operations after shutdown report
/// [`StudyError::Closed`]. `finish` shuts the server thread down (the
/// exiting thread flushes observers); the eventual [`Drop`] join is a
/// harmless no-op afterwards.
impl Study for ServerHandle {
    fn ask(&mut self) -> Result<Vec<f64>, StudyError> {
        self.try_ask()
    }

    fn ask_batch(&mut self, q: usize) -> Result<Vec<Vec<f64>>, StudyError> {
        self.try_ask_batch(q)
    }

    fn tell(&mut self, x: &[f64], y: f64) -> Result<(), StudyError> {
        self.try_tell(x.to_vec(), y)
    }

    fn tell_observation(&mut self, obs: Observation) -> Result<(), StudyError> {
        self.try_tell_observation(obs)
    }

    fn best(&self) -> Result<Option<(Vec<f64>, f64)>, StudyError> {
        self.try_best()
    }

    fn finish(&mut self) -> Result<(), StudyError> {
        self.tx.send(Request::Shutdown).map_err(|_| StudyError::Closed)
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ucb;
    use crate::bayes_opt::BoDef;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::gp::Gp;
    use crate::opt::{NelderMead, OptimizerExt, RandomPoint};

    fn make_server() -> AskTellServer<
        Gp<Matern52, DataMean>,
        Ucb,
        crate::opt::ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    > {
        BoDef::service(1)
            .seed(9)
            .inner_opt(RandomPoint::new(64).then(NelderMead::default()).restarts(2, 2))
            .build_server()
    }

    #[test]
    fn inline_ask_tell_converges() {
        let mut srv = make_server();
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        for _ in 0..15 {
            let x = srv.ask();
            assert!((0.0..=1.0).contains(&x[0]));
            let y = f(&x);
            srv.tell(&x, y);
        }
        let (bx, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "best={bv} at {bx:?}");
    }

    #[test]
    fn default_server_uses_adaptive_model_and_converges() {
        let mut srv: DefaultAskTellServer = BoDef::service(1).seed(17).build_adaptive_server();
        assert!(!srv.core.model.is_sparse());
        let f = |x: &[f64]| -(x[0] - 0.8).powi(2);
        for _ in 0..15 {
            let x = srv.ask();
            let y = f(&x);
            srv.tell(&x, y);
        }
        let (_, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "best={bv}");
        assert_eq!(srv.core.model.n_samples(), 15);
    }

    #[test]
    fn threaded_server_round_trips() {
        let handle = make_server().spawn();
        let f = |x: &[f64]| -(x[0] - 0.25).powi(2);
        for _ in 0..10 {
            let x = handle.ask();
            handle.tell(x.clone(), f(&x));
        }
        let best = handle.best().unwrap();
        assert!(best.1 > -0.05, "best={}", best.1);
    }

    #[test]
    fn ask_batch_proposes_distinct_points_and_rolls_back_lies() {
        let mut srv = make_server();
        let f = |x: &[f64]| -(x[0] - 0.4).powi(2);
        // cold start: q random probes
        assert_eq!(srv.ask_batch(3).len(), 3);
        for x in [[0.1], [0.5], [0.9]] {
            srv.tell(&x, f(&x));
        }
        let n_before = srv.core.model.n_samples();
        let batch = srv.ask_batch(4);
        assert_eq!(batch.len(), 4);
        // the constant-liar lies must not leak into the real model
        assert_eq!(srv.core.model.n_samples(), n_before);
        for (i, a) in batch.iter().enumerate() {
            assert!((0.0..=1.0).contains(&a[0]));
            for b in batch.iter().skip(i + 1) {
                let d2: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
                assert!(d2 > 1e-10, "batch points {a:?} and {b:?} coincide");
            }
        }
    }

    #[test]
    fn prefitted_model_seeds_the_incumbent() {
        // a server wrapped around a model that already has data must not
        // lie `best() == None` / run EI with a -inf incumbent until the
        // first tell
        let mut gp = Gp::new(Matern52::new(1), DataMean::default(), 1e-3);
        gp.fit(&[vec![0.1], vec![0.6], vec![0.9]], &[-5.0, -2.0, -4.0]);
        let mut srv =
            AskTellServer::from_core(BoCore::new(gp, Ucb::default(), RandomPoint::new(32), 1, 3));
        let (bx, bv) = srv.best().expect("incumbent seeded from the model");
        assert_eq!(bx, vec![0.6]);
        assert_eq!(bv, -2.0);
        assert!((srv.incumbent_value() - -2.0).abs() < 1e-12);
        // ask works immediately with a finite incumbent
        let x = srv.ask();
        assert!((0.0..=1.0).contains(&x[0]));
        // a worse tell must not displace the seeded incumbent
        srv.tell(&[0.3], -9.0);
        assert_eq!(srv.best().unwrap().1, -2.0);
        srv.tell(&[0.55], -1.0);
        assert_eq!(srv.best().unwrap().1, -1.0);
    }

    #[test]
    fn burst_of_tells_triggers_one_refit_not_one_per_tell() {
        // pre-fitted model far past the first refit threshold: the
        // single-doubling schedule used to refit on *every* subsequent
        // tell until `next` caught up with n (O(n·m²) each — exactly the
        // ask_batch(q) burst workflow)
        let mut rng = crate::rng::Pcg64::seed(41);
        let xs: Vec<Vec<f64>> = (0..100).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (7.0 * x[0]).sin()).collect();
        let mut gp = Gp::new(Matern52::new(1), DataMean::default(), 0.05);
        gp.fit(&xs, &ys);
        let mut srv =
            AskTellServer::from_core(BoCore::new(gp, Ucb::default(), RandomPoint::new(16), 1, 13))
                .with_refit(RefitSchedule::Doubling { first: 16 });
        srv.core.model.hp_opt.config.restarts = 1;
        srv.core.model.hp_opt.config.iterations = 3;
        // a 4-point burst (one ask_batch round's worth of tells)
        for x in [[0.11], [0.31], [0.51], [0.71]] {
            srv.tell(&x, (7.0 * x[0]).sin());
        }
        assert_eq!(
            srv.core.model.hp_opt.refits(),
            1,
            "one refit for the burst, schedule advanced past n"
        );
        assert_eq!(srv.core.next_refit(), Some(128), "16 doubled past n=101 in one step");
    }

    #[test]
    fn qei_strategy_proposes_distinct_points_and_converges() {
        let f = |x: &[f64]| -(x[0] - 0.4).powi(2);
        let mut srv = make_server().with_batch_strategy(BatchStrategy::QEi { mc_samples: 128 });
        // cold start: q random probes
        assert_eq!(srv.ask_batch(3).len(), 3);
        for x in [[0.1], [0.5], [0.9]] {
            srv.tell(&x, f(&x));
        }
        let n_before = srv.core.model.n_samples();
        let batch = srv.ask_batch(4);
        assert_eq!(batch.len(), 4);
        // qEI scores the real model read-only: nothing may leak into it
        assert_eq!(srv.core.model.n_samples(), n_before);
        for (i, a) in batch.iter().enumerate() {
            assert!((0.0..=1.0).contains(&a[0]));
            for b in batch.iter().skip(i + 1) {
                let d2: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
                assert!(d2 > 1e-10, "batch points {a:?} and {b:?} coincide");
            }
        }
        // full loop converges like the constant liar does
        for _ in 0..4 {
            for x in srv.ask_batch(4) {
                let y = f(&x);
                srv.tell(&x, y);
            }
        }
        let (_, bv) = srv.best().unwrap();
        assert!(bv > -0.02, "qEI batched best={bv}");
    }

    #[test]
    fn hp_refit_schedule_fires_on_doubling_counts() {
        let mut rng = crate::rng::Pcg64::seed(31);
        let mut srv = BoDef::service(1)
            .noise(0.05)
            .seed(7)
            .inner_opt(RandomPoint::new(32))
            .build_server()
            .with_refit(RefitSchedule::Doubling { first: 8 });
        srv.core.model.hp_opt.config.restarts = 1;
        srv.core.model.hp_opt.config.iterations = 10;
        let start_hp = srv.core.model.hp_vector();
        // short-lengthscale data: ML-II must move the kernel params
        for _ in 0..17 {
            let x = rng.unit_point(1);
            srv.tell(&x, (11.0 * x[0]).sin());
        }
        // refits fired at n = 8 and n = 16 (doubling schedule)
        assert_eq!(srv.core.model.hp_opt.refits(), 2);
        assert_ne!(srv.core.model.hp_vector(), start_hp, "refit should move hyper-params");
    }

    #[test]
    fn handle_rejects_constraint_arity_mismatch_and_survives() {
        let handle = make_server().spawn();
        let obs = Observation::exact(vec![0.5], -1.0).with_constraints(vec![1.0]);
        match handle.try_tell_observation(obs) {
            Err(StudyError::Rejected(BoError::ConstraintArity { expected, got })) => {
                assert_eq!(expected, 0);
                assert_eq!(got, 1);
            }
            other => panic!("expected an arity rejection, got {other:?}"),
        }
        // the rejection must not have wedged or killed the server
        let x = handle.ask();
        handle.tell(x, -0.5);
        assert!(handle.best().is_some());
    }

    #[test]
    fn async_pending_server_interleaves_out_of_order_tells() {
        let mut srv = BoDef::service(1)
            .seed(23)
            .async_pending(true)
            .inner_opt(RandomPoint::new(32).then(NelderMead::default()).restarts(2, 2))
            .build_server();
        let f = |x: &[f64]| -(x[0] - 0.3).powi(2);
        // three asks before any tell — all outstanding at once
        let a = srv.ask();
        let b = srv.ask();
        let c = srv.ask();
        assert_eq!(srv.core.pending_count(), 3);
        // tells arrive out of order; each retires its pending entry
        srv.tell(&c, f(&c));
        srv.tell(&a, f(&a));
        srv.tell(&b, f(&b));
        assert_eq!(srv.core.pending_count(), 0);
        for _ in 0..10 {
            let x = srv.ask();
            srv.tell(&x, f(&x));
        }
        let (_, bv) = srv.best().unwrap();
        assert!(bv > -0.05, "async-pending best={bv}");
    }

    #[test]
    fn batched_ask_tell_converges_like_sequential() {
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        // sequential: 16 ask/tell rounds
        let mut seq = make_server();
        for _ in 0..16 {
            let x = seq.ask();
            let y = f(&x);
            seq.tell(&x, y);
        }
        // batched: 4 rounds of q=4 (same total budget) over the handle
        let handle = make_server().spawn();
        for _ in 0..4 {
            for x in handle.ask_batch(4) {
                let y = f(&x);
                handle.tell(x, y);
            }
        }
        let (_, sv) = seq.best().unwrap();
        let (_, bv) = handle.best().unwrap();
        assert!(sv > -0.02, "sequential best={sv}");
        assert!(bv > -0.02, "batched best={bv} should match sequential parity");
    }
}
