//! CMA-ES (Hansen & Ostermeier 2001) — the covariance-matrix-adaptation
//! evolution strategy, Limbo's recommended global inner optimizer.
//!
//! Full (mu/mu_w, lambda) implementation following Hansen's tutorial:
//! weighted recombination, cumulative step-size adaptation (CSA), rank-1 +
//! rank-mu covariance updates. Boundary handling: samples outside the unit
//! cube are clamped for evaluation (standard repair), while adaptation
//! uses the unrepaired genotypes.

use super::{Candidate, Objective, Optimizer};
use crate::la::{sym_eig, Matrix};
use crate::rng::Pcg64;

/// CMA-ES maximizer on the unit hypercube.
#[derive(Clone, Debug)]
pub struct Cmaes {
    /// Evaluation budget (generations = budget / lambda).
    pub max_evals: usize,
    /// Initial step size (sigma) in unit-cube coordinates.
    pub sigma0: f64,
    /// Population size override (`None` = 4 + 3 ln d).
    pub lambda: Option<usize>,
}

impl Default for Cmaes {
    fn default() -> Self {
        Self { max_evals: 500, sigma0: 0.3, lambda: None }
    }
}

impl Cmaes {
    /// Budgeted constructor.
    pub fn new(max_evals: usize) -> Self {
        Self { max_evals, ..Self::default() }
    }
}

impl Optimizer for Cmaes {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let x0 = rng.unit_point(dim);
        self.optimize_from(f, &x0, rng)
    }

    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        let n = x0.len();
        let nf = n as f64;
        let lambda = self.lambda.unwrap_or(4 + (3.0 * nf.ln()).floor() as usize).max(4);
        let mu = lambda / 2;
        // log-weights
        let mut weights: Vec<f64> =
            (0..mu).map(|i| (mu as f64 + 0.5).ln() - ((i + 1) as f64).ln()).collect();
        let wsum: f64 = weights.iter().sum();
        for w in weights.iter_mut() {
            *w /= wsum;
        }
        let mu_eff = 1.0 / weights.iter().map(|w| w * w).sum::<f64>();

        // strategy constants (Hansen's defaults)
        let cc = (4.0 + mu_eff / nf) / (nf + 4.0 + 2.0 * mu_eff / nf);
        let cs = (mu_eff + 2.0) / (nf + mu_eff + 5.0);
        let c1 = 2.0 / ((nf + 1.3).powi(2) + mu_eff);
        let cmu = (1.0 - c1)
            .min(2.0 * (mu_eff - 2.0 + 1.0 / mu_eff) / ((nf + 2.0).powi(2) + mu_eff));
        let damps = 1.0 + 2.0_f64.max(((mu_eff - 1.0) / (nf + 1.0)).sqrt() - 1.0) + cs;
        let chi_n = nf.sqrt() * (1.0 - 1.0 / (4.0 * nf) + 1.0 / (21.0 * nf * nf));

        let mut mean = x0.to_vec();
        let mut sigma = self.sigma0;
        let mut cov = Matrix::eye(n);
        let mut p_sigma = vec![0.0; n];
        let mut p_c = vec![0.0; n];
        let mut best = Candidate::eval(f, {
            let mut x = mean.clone();
            super::clamp_unit(&mut x);
            x
        });
        let mut evals = 1usize;

        while evals + lambda <= self.max_evals.max(lambda + 1) {
            // eigendecomposition for sampling: C = B diag(D^2) B^T
            let eig = sym_eig(&cov);
            let d_sqrt: Vec<f64> = eig.values.iter().map(|&w| w.max(1e-20).sqrt()).collect();

            // sample lambda offspring: x = mean + sigma * B D z; the whole
            // generation is scored in one eval_many call (the population
            // shape batched acquisition objectives exploit)
            let mut genotypes: Vec<(Vec<f64>, Vec<f64>)> = Vec::with_capacity(lambda);
            let mut population: Vec<Vec<f64>> = Vec::with_capacity(lambda);
            for _ in 0..lambda {
                let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                // y = B D z
                let mut y = vec![0.0; n];
                for i in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += eig.vectors[(i, k)] * d_sqrt[k] * z[k];
                    }
                    y[i] = s;
                }
                let x: Vec<f64> = mean.iter().zip(&y).map(|(&m, &yi)| m + sigma * yi).collect();
                let mut x_eval = x.clone();
                super::clamp_unit(&mut x_eval);
                population.push(x_eval);
                genotypes.push((x, y));
            }
            let values = f.eval_many(&population);
            evals += lambda;
            let mut offspring: Vec<(Vec<f64>, Vec<f64>, f64)> = Vec::with_capacity(lambda);
            for (((x, y), x_eval), value) in
                genotypes.into_iter().zip(population).zip(values)
            {
                if value > best.value {
                    best = Candidate { x: x_eval, value };
                }
                offspring.push((x, y, value));
            }
            // rank by fitness (descending: maximization)
            offspring.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());

            // recombination
            let old_mean = mean.clone();
            for i in 0..n {
                mean[i] = (0..mu).map(|k| weights[k] * offspring[k].0[i]).sum();
            }
            // mean shift in sigma-normalized coordinates
            let y_w: Vec<f64> =
                (0..n).map(|i| (mean[i] - old_mean[i]) / sigma).collect();

            // CSA: p_sigma update needs C^(-1/2) y_w = B D^-1 B^T y_w
            let mut c_inv_sqrt_y = vec![0.0; n];
            for i in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    // (B D^-1 B^T)_{i,j} applied to y_w
                    let mut btyw = 0.0;
                    for j in 0..n {
                        btyw += eig.vectors[(j, k)] * y_w[j];
                    }
                    s += eig.vectors[(i, k)] / d_sqrt[k] * btyw;
                }
                c_inv_sqrt_y[i] = s;
            }
            let cs_fac = (cs * (2.0 - cs) * mu_eff).sqrt();
            for i in 0..n {
                p_sigma[i] = (1.0 - cs) * p_sigma[i] + cs_fac * c_inv_sqrt_y[i];
            }
            let ps_norm = p_sigma.iter().map(|v| v * v).sum::<f64>().sqrt();
            sigma *= ((cs / damps) * (ps_norm / chi_n - 1.0)).exp();
            sigma = sigma.clamp(1e-12, 1.0);

            // covariance: rank-1 (p_c) + rank-mu
            let hsig = if ps_norm
                / (1.0 - (1.0 - cs).powi(2 * (evals / lambda) as i32)).sqrt()
                < (1.4 + 2.0 / (nf + 1.0)) * chi_n
            {
                1.0
            } else {
                0.0
            };
            let cc_fac = (cc * (2.0 - cc) * mu_eff).sqrt();
            for i in 0..n {
                p_c[i] = (1.0 - cc) * p_c[i] + hsig * cc_fac * y_w[i];
            }
            let delta_hsig = (1.0 - hsig) * cc * (2.0 - cc);
            for i in 0..n {
                for j in 0..n {
                    let rank_mu: f64 = (0..mu)
                        .map(|k| weights[k] * offspring[k].1[i] * offspring[k].1[j])
                        .sum();
                    cov[(i, j)] = (1.0 - c1 - cmu + c1 * delta_hsig) * cov[(i, j)]
                        + c1 * p_c[i] * p_c[j]
                        + cmu * rank_mu;
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::{neg_sphere, wiggly};

    #[test]
    fn solves_sphere() {
        let mut rng = Pcg64::seed(10);
        let c = Cmaes::new(800).optimize(&neg_sphere, 4, &mut rng);
        assert!(c.value > -1e-4, "value={}", c.value);
    }

    #[test]
    fn solves_rotated_ellipsoid() {
        // badly conditioned quadratic: needs covariance adaptation
        let f = |x: &[f64]| {
            let u = x[0] - 0.4 + (x[1] - 0.6);
            let v = x[0] - 0.4 - (x[1] - 0.6);
            -(u * u + 100.0 * v * v)
        };
        let mut rng = Pcg64::seed(11);
        let c = Cmaes::new(1500).optimize(&f, 2, &mut rng);
        assert!(c.value > -1e-4, "value={}", c.value);
    }

    #[test]
    fn handles_multimodal_reasonably() {
        let mut rng = Pcg64::seed(12);
        let c = Cmaes::new(600).optimize(&wiggly, 2, &mut rng);
        // global max per dim = 2.32292 (x* = 0.66842) -> 4.6458 total;
        // a single un-restarted run may keep one dim on a local optimum
        // (3.79 = 2.32 + 1.46-boundary), so accept anything above that
        assert!(c.value > 3.7, "value={}", c.value);
    }

    #[test]
    fn stays_in_bounds() {
        let mut rng = Pcg64::seed(13);
        let c = Cmaes::new(300).optimize(&|x: &[f64]| x[0] + x[1], 2, &mut rng);
        assert!(c.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(c.value > 1.9, "boundary max should be found: {}", c.value);
    }
}
