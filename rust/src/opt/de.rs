//! Self-adaptive Differential Evolution (jDE/JADE hybrid) — the
//! population-based acquisition maximizer for higher dimensions, where
//! DIRECT's rectangle subdivision stalls and single-run CMA-ES gets
//! stuck on one basin.
//!
//! Three self-adaptation mechanisms, all standard published technique:
//!
//! * **per-individual F/CR** (Brest et al. 2006, "jDE"): every
//!   individual carries its own mutation factor `F` and crossover rate
//!   `CR`; with probability `tau` each is re-drawn before producing a
//!   trial, and the new values survive only if the trial wins selection
//!   — good control parameters propagate with the genomes that used
//!   them;
//! * **current-to-pbest/1 mutation with an archive** (Zhang & Sanderson
//!   2009, "JADE"): `v = x_i + F (x_pbest − x_i) + F (x_r1 − x_r2)`
//!   where `x_pbest` is drawn from the best `p` fraction and `x_r2` may
//!   come from an archive of recently replaced parents — greedy
//!   direction with preserved diversity;
//! * **linear population-size reduction** (Tanabe & Fukunaga 2014,
//!   "L-SHADE"): the population shrinks from `np0` toward
//!   [`np_min`](AdaptiveDe::np_min) as the evaluation budget is spent,
//!   dropping the worst individuals — broad early exploration, cheap
//!   late exploitation.
//!
//! Every generation is scored with **one** [`Objective::eval_many`]
//! call, so an acquisition objective pays one cross-covariance block
//! and one multi-RHS solve per generation instead of per candidate —
//! the same batch shape [`Cmaes`](super::Cmaes) exploits.
//!
//! Attach a [`DeRecorder`] ([`AdaptiveDe::with_recorder`]) to capture
//! per-generation state (population size, best value, mean F/CR) for
//! the record/replay workflow — [`crate::stat::RecordingObserver`]
//! bundles one with the BO event capture.

use std::sync::{Arc, Mutex};

use super::{Candidate, Objective, Optimizer};
use crate::obs::{self, Counter, Phase};
use crate::rng::Pcg64;

/// Per-generation state snapshot pushed to a [`DeRecorder`].
#[derive(Clone, Debug, PartialEq)]
pub struct DeGenRecord {
    /// Generation index (0 = the initial population evaluation).
    pub generation: usize,
    /// Population size during this generation.
    pub np: usize,
    /// Total objective evaluations spent so far (cumulative).
    pub evaluations: usize,
    /// Best objective value seen so far.
    pub best: f64,
    /// Population mean of the per-individual mutation factors F.
    pub mean_f: f64,
    /// Population mean of the per-individual crossover rates CR.
    pub mean_cr: f64,
}

/// Cloneable sink for [`DeGenRecord`]s: attach one clone to an
/// [`AdaptiveDe`] via [`with_recorder`](AdaptiveDe::with_recorder),
/// read the rows from another after (or during) the run — the same
/// handle pattern as [`crate::stat::TraceHandle`]. Recording never
/// touches the RNG or the floating-point evaluation order, so runs are
/// bit-identical with or without a recorder attached.
#[derive(Clone, Default)]
pub struct DeRecorder {
    rows: Arc<Mutex<Vec<DeGenRecord>>>,
}

impl DeRecorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of the rows recorded so far.
    pub fn rows(&self) -> Vec<DeGenRecord> {
        self.rows.lock().expect("de recorder lock").clone()
    }

    /// Rows recorded so far.
    pub fn len(&self) -> usize {
        self.rows.lock().expect("de recorder lock").len()
    }

    /// True before the first recorded generation.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all recorded rows (e.g. between runs sharing one recorder).
    pub fn clear(&self) {
        self.rows.lock().expect("de recorder lock").clear();
    }

    fn push(&self, row: DeGenRecord) {
        self.rows.lock().expect("de recorder lock").push(row);
    }
}

/// Self-adaptive Differential Evolution maximizer on the unit hypercube.
///
/// Drop-in anywhere [`Cmaes`](super::Cmaes)/[`Direct`](super::Direct)
/// go: as the `BoDef` inner optimizer
/// ([`crate::bayes_opt::BoDef::inner_de`]), inside qEI joint
/// refinement (it implements [`optimize_from`](Optimizer::optimize_from)
/// by injecting the seed point into the initial population), or as a
/// standalone derivative-free baseline over a raw objective.
///
/// Knobs (all have sensible defaults — `AdaptiveDe::new(budget)` is the
/// usual spelling):
///
/// * `max_evals` — total objective-evaluation budget;
/// * `np0` — initial population size (`None` = `5·dim` clamped to
///   `[8, 64]`);
/// * `np_min` — floor of the linear population reduction (4 keeps
///   current-to-pbest/1 well-defined);
/// * `p_best` — fraction of the population eligible as `x_pbest`;
/// * `archive` — keep replaced parents as extra difference-vector
///   donors (capped at the current population size, random eviction);
/// * `tau_f` / `tau_cr` — jDE re-randomization probabilities.
#[derive(Clone)]
pub struct AdaptiveDe {
    /// Evaluation budget (generations ≈ budget / population size).
    pub max_evals: usize,
    /// Initial population size (`None` = `5·dim` clamped to `[8, 64]`).
    pub np0: Option<usize>,
    /// Final population size of the linear reduction schedule.
    pub np_min: usize,
    /// pbest fraction for current-to-pbest/1 mutation.
    pub p_best: f64,
    /// Use the JADE archive of replaced parents.
    pub archive: bool,
    /// jDE: probability of re-drawing an individual's F per trial.
    pub tau_f: f64,
    /// jDE: probability of re-drawing an individual's CR per trial.
    pub tau_cr: f64,
    recorder: Option<DeRecorder>,
}

impl Default for AdaptiveDe {
    fn default() -> Self {
        Self {
            max_evals: 500,
            np0: None,
            np_min: 4,
            p_best: 0.11,
            archive: true,
            tau_f: 0.1,
            tau_cr: 0.1,
            recorder: None,
        }
    }
}

impl AdaptiveDe {
    /// Budgeted constructor with the default self-adaptation knobs.
    pub fn new(max_evals: usize) -> Self {
        Self { max_evals, ..Self::default() }
    }

    /// Attach a per-generation state recorder (a clone of the caller's
    /// handle; see [`DeRecorder`]).
    pub fn with_recorder(mut self, recorder: DeRecorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Effective initial population size for dimension `dim`.
    fn np0_for(&self, dim: usize) -> usize {
        let np = self.np0.unwrap_or((5 * dim.max(1)).clamp(8, 64));
        // never larger than the whole budget allows, never below the floor
        np.min(self.max_evals.max(self.np_min.max(4))).max(self.np_min.max(4))
    }
}

/// Selection score: non-finite objective values (NaN from a degenerate
/// model state, ±inf from an overflowing objective) never win a
/// comparison — the same poison-safety as [`Candidate::max`].
#[inline]
fn score(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        f64::NEG_INFINITY
    }
}

/// One population member: genome, fitness, and its own control params.
#[derive(Clone)]
struct Member {
    x: Vec<f64>,
    value: f64,
    f: f64,
    cr: f64,
}

impl Optimizer for AdaptiveDe {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let x0 = rng.unit_point(dim);
        self.optimize_from(f, &x0, rng)
    }

    /// The seed point `x0` joins the initial population as member 0, so
    /// a caller refining a known good point (the qEI joint-refinement
    /// pass) keeps it as a selection incumbent — it can only be replaced
    /// by something better.
    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        let _span = obs::span(Phase::InnerOpt);
        let dim = x0.len();
        let np0 = self.np0_for(dim);

        // initial population: the seed point plus uniform draws, every
        // member starting from the classic jDE control params
        let mut pop: Vec<Member> = Vec::with_capacity(np0);
        let mut seed = x0.to_vec();
        super::clamp_unit(&mut seed);
        pop.push(Member { x: seed, value: 0.0, f: 0.5, cr: 0.9 });
        for _ in 1..np0 {
            pop.push(Member { x: rng.unit_point(dim), value: 0.0, f: 0.5, cr: 0.9 });
        }
        let points: Vec<Vec<f64>> = pop.iter().map(|m| m.x.clone()).collect();
        let values = f.eval_many(&points);
        assert_eq!(values.len(), pop.len(), "eval_many: value count mismatch");
        for (m, v) in pop.iter_mut().zip(values) {
            m.value = v;
        }
        let mut evals = np0;
        obs::counter_add(Counter::DeEvaluations, np0 as u64);

        let mut best = pop
            .iter()
            .max_by(|a, b| score(a.value).partial_cmp(&score(b.value)).expect("scores are ordered"))
            .map(|m| Candidate { x: m.x.clone(), value: m.value })
            .expect("population is non-empty");

        let mut archive: Vec<Vec<f64>> = Vec::new();
        let mut generation = 0usize;
        self.record(generation, &pop, evals, best.value);

        let np_min = self.np_min.max(4).min(np0);
        loop {
            // linear population-size reduction over the eval budget
            let frac = evals as f64 / self.max_evals.max(1) as f64;
            let np_target = (np0 as f64 - (np0 - np_min) as f64 * frac).round() as usize;
            let np_target = np_target.clamp(np_min, np0);
            if pop.len() > np_target {
                // drop the worst members (stable sort keeps ties in
                // insertion order, so the truncation is deterministic)
                pop.sort_by(|a, b| {
                    score(b.value).partial_cmp(&score(a.value)).expect("scores are ordered")
                });
                pop.truncate(np_target);
                archive.truncate(pop.len().min(archive.len()));
            }
            let np = pop.len();
            if evals + np > self.max_evals {
                break;
            }
            generation += 1;
            obs::counter_add(Counter::DeGenerations, 1);

            // fitness ranking for pbest selection
            let mut order: Vec<usize> = (0..np).collect();
            order.sort_by(|&a, &b| {
                score(pop[b].value).partial_cmp(&score(pop[a].value)).expect("scores are ordered")
            });
            let n_pbest = ((self.p_best * np as f64).ceil() as usize).clamp(1, np);

            // build the whole generation of trials, then score it as one
            // eval_many batch
            let mut trials: Vec<Vec<f64>> = Vec::with_capacity(np);
            let mut params: Vec<(f64, f64)> = Vec::with_capacity(np);
            for i in 0..np {
                // jDE self-adaptation: maybe re-draw this trial's F/CR
                let fi = if rng.uniform(0.0, 1.0) < self.tau_f {
                    0.1 + 0.9 * rng.uniform(0.0, 1.0)
                } else {
                    pop[i].f
                };
                let cri = if rng.uniform(0.0, 1.0) < self.tau_cr {
                    rng.uniform(0.0, 1.0)
                } else {
                    pop[i].cr
                };
                params.push((fi, cri));

                // current-to-pbest/1: greedy direction + one difference
                let pbest = &pop[order[rng.below(n_pbest)]].x;
                let r1 = loop {
                    let r = rng.below(np);
                    if r != i {
                        break r;
                    }
                };
                // r2 may come from the archive (population ∪ archive)
                let pool_len = np + if self.archive { archive.len() } else { 0 };
                let r2 = loop {
                    let r = rng.below(pool_len);
                    if r != i && r != r1 {
                        break r;
                    }
                };
                let x_r2: &[f64] = if r2 < np { &pop[r2].x } else { &archive[r2 - np] };

                let xi = &pop[i].x;
                let mut v: Vec<f64> = (0..dim)
                    .map(|j| {
                        xi[j] + fi * (pbest[j] - xi[j]) + fi * (pop[r1].x[j] - x_r2[j])
                    })
                    .collect();
                // midpoint bound repair: reflect toward the violated
                // bound's midpoint with the parent (standard JADE repair)
                for j in 0..dim {
                    if v[j] < 0.0 {
                        v[j] = xi[j] / 2.0;
                    } else if v[j] > 1.0 {
                        v[j] = (xi[j] + 1.0) / 2.0;
                    }
                }
                // binomial crossover with one forced coordinate
                let j_rand = rng.below(dim);
                let trial: Vec<f64> = (0..dim)
                    .map(|j| {
                        if j == j_rand || rng.uniform(0.0, 1.0) < cri {
                            v[j]
                        } else {
                            xi[j]
                        }
                    })
                    .collect();
                trials.push(trial);
            }

            let values = f.eval_many(&trials);
            assert_eq!(values.len(), np, "eval_many: value count mismatch");
            evals += np;
            obs::counter_add(Counter::DeEvaluations, np as u64);

            // one-to-one selection: the trial replaces its parent only on
            // strict improvement, carrying its control params with it
            for (i, (trial, value)) in trials.into_iter().zip(values).enumerate() {
                if score(value) > score(pop[i].value) {
                    if self.archive {
                        if archive.len() >= np {
                            let evict = rng.below(archive.len());
                            archive.swap_remove(evict);
                        }
                        archive.push(std::mem::take(&mut pop[i].x));
                    }
                    let (fi, cri) = params[i];
                    if score(value) > score(best.value) {
                        best = Candidate { x: trial.clone(), value };
                    }
                    pop[i] = Member { x: trial, value, f: fi, cr: cri };
                }
            }
            self.record(generation, &pop, evals, best.value);
        }
        best
    }
}

impl AdaptiveDe {
    fn record(&self, generation: usize, pop: &[Member], evaluations: usize, best: f64) {
        if let Some(rec) = &self.recorder {
            let np = pop.len();
            let mean_f = pop.iter().map(|m| m.f).sum::<f64>() / np as f64;
            let mean_cr = pop.iter().map(|m| m.cr).sum::<f64>() / np as f64;
            rec.push(DeGenRecord { generation, np, evaluations, best, mean_f, mean_cr });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::{neg_sphere, wiggly};

    #[test]
    fn solves_sphere() {
        let mut rng = Pcg64::seed(20);
        let c = AdaptiveDe::new(2000).optimize(&neg_sphere, 4, &mut rng);
        assert!(c.value > -1e-4, "value={}", c.value);
    }

    #[test]
    fn solves_multimodal() {
        // global max per dim = 2.32292 → 4.6458 total; DE's population
        // should not get stuck on the 3.79 local ridge CMA-ES can land on
        let mut rng = Pcg64::seed(21);
        let c = AdaptiveDe::new(2000).optimize(&wiggly, 2, &mut rng);
        assert!(c.value > 4.5, "value={}", c.value);
    }

    #[test]
    fn stays_in_bounds() {
        let mut rng = Pcg64::seed(22);
        let c = AdaptiveDe::new(600).optimize(&|x: &[f64]| x[0] + x[1], 2, &mut rng);
        assert!(c.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(c.value > 1.9, "boundary max should be found: {}", c.value);
    }

    #[test]
    fn respects_eval_budget() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let f = |x: &[f64]| {
            count.fetch_add(1, Ordering::Relaxed);
            -x[0]
        };
        let mut rng = Pcg64::seed(23);
        AdaptiveDe::new(300).optimize(&f, 3, &mut rng);
        let used = count.load(Ordering::Relaxed);
        assert!(used <= 300, "budget 300, used {used}");
        assert!(used >= 200, "budget mostly spent: used {used}");
    }

    #[test]
    fn is_deterministic_under_fixed_seed() {
        let run = || {
            let mut rng = Pcg64::seed(24);
            AdaptiveDe::new(800).optimize(&wiggly, 3, &mut rng)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(
            a.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn optimize_from_keeps_a_good_seed_point() {
        // the seeded optimum must survive selection: with a tiny budget
        // the returned best can only be the seed or an improvement
        let x0 = vec![0.3; 4];
        let v0 = neg_sphere(&x0);
        let mut rng = Pcg64::seed(25);
        let c = AdaptiveDe::new(40).optimize_from(&neg_sphere, &x0, &mut rng);
        assert!(c.value >= v0, "seed value {v0} lost: {}", c.value);
    }

    #[test]
    fn non_finite_values_never_win() {
        // a poisoned band of the domain returns NaN; the result must be
        // finite and outside it
        let f = |x: &[f64]| {
            if x[0] > 0.5 {
                f64::NAN
            } else {
                x[0]
            }
        };
        let mut rng = Pcg64::seed(26);
        let c = AdaptiveDe::new(400).optimize(&f, 2, &mut rng);
        assert!(c.value.is_finite(), "value={}", c.value);
        assert!(c.x[0] <= 0.5);
    }

    #[test]
    fn recorder_captures_generations_and_adaptation() {
        let rec = DeRecorder::new();
        let mut rng = Pcg64::seed(27);
        AdaptiveDe::new(1500).with_recorder(rec.clone()).optimize(&wiggly, 4, &mut rng);
        let rows = rec.rows();
        assert!(rows.len() > 5, "expected several generations, got {}", rows.len());
        assert_eq!(rows[0].generation, 0);
        // best is monotone non-decreasing, evals strictly increasing
        for w in rows.windows(2) {
            assert!(w[1].best >= w[0].best);
            assert!(w[1].evaluations > w[0].evaluations);
            assert_eq!(w[1].generation, w[0].generation + 1);
        }
        // self-adaptation actually moved the control params off the
        // (0.5, 0.9) jDE initialization
        let last = rows.last().unwrap();
        assert!(
            (last.mean_f - 0.5).abs() > 1e-6 || (last.mean_cr - 0.9).abs() > 1e-6,
            "F/CR never adapted: mean_f={} mean_cr={}",
            last.mean_f,
            last.mean_cr
        );
    }

    #[test]
    fn population_shrinks_over_the_run() {
        let rec = DeRecorder::new();
        let mut rng = Pcg64::seed(28);
        let de = AdaptiveDe { np0: Some(32), np_min: 4, ..AdaptiveDe::new(2000) }
            .with_recorder(rec.clone());
        de.optimize(&neg_sphere, 3, &mut rng);
        let rows = rec.rows();
        assert_eq!(rows.first().unwrap().np, 32);
        assert!(
            rows.last().unwrap().np < 16,
            "population never shrank: final np={}",
            rows.last().unwrap().np
        );
    }
}
