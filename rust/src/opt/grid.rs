//! Exhaustive grid search (Limbo's `opt::GridSearch`).

use super::{Candidate, Objective, Optimizer};
use crate::rng::Pcg64;

/// Full-factorial grid with `bins` points per dimension (cell centers are
/// offset half a step from the boundary so corners are not over-sampled).
#[derive(Clone, Debug)]
pub struct GridSearch {
    /// Grid resolution per dimension.
    pub bins: usize,
    /// Hard cap on total evaluations (guards the `bins^dim` blow-up).
    pub max_evals: usize,
}

impl GridSearch {
    /// `bins` per dimension, default eval cap of 1e6.
    pub fn new(bins: usize) -> Self {
        Self { bins: bins.max(1), max_evals: 1_000_000 }
    }
}

impl Optimizer for GridSearch {
    fn optimize(&self, f: &dyn Objective, dim: usize, _rng: &mut Pcg64) -> Candidate {
        let mut bins = self.bins;
        // shrink resolution until the grid fits the eval budget
        while bins > 1 && (bins as f64).powi(dim as i32) > self.max_evals as f64 {
            bins -= 1;
        }
        let total = (bins as u64).pow(dim as u32) as usize;
        let mut best: Option<Candidate> = None;
        let mut x = vec![0.0; dim];
        for idx in 0..total {
            let mut rem = idx;
            for d in 0..dim {
                let b = rem % bins;
                rem /= bins;
                x[d] = (b as f64 + 0.5) / bins as f64;
            }
            let cand = Candidate::eval(f, x.clone());
            best = Some(match best {
                Some(b) => b.max(cand),
                None => cand,
            });
        }
        best.expect("grid has at least one point")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::neg_sphere;

    #[test]
    fn finds_peak_cell() {
        let mut rng = Pcg64::seed(0);
        let c = GridSearch::new(21).optimize(&neg_sphere, 2, &mut rng);
        for &v in &c.x {
            assert!((v - 0.3).abs() < 0.05, "x={v}");
        }
    }

    #[test]
    fn respects_eval_cap() {
        let mut rng = Pcg64::seed(0);
        let mut g = GridSearch::new(100);
        g.max_evals = 1000;
        // 6-D grid of 100^6 would be 1e12; the cap shrinks bins to 3
        let c = g.optimize(&neg_sphere, 6, &mut rng);
        assert!(c.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn single_bin_evaluates_center() {
        let mut rng = Pcg64::seed(0);
        let c = GridSearch::new(1).optimize(&neg_sphere, 2, &mut rng);
        assert_eq!(c.x, vec![0.5, 0.5]);
    }
}
