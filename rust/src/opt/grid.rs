//! Exhaustive grid search (Limbo's `opt::GridSearch`), evaluated in
//! population chunks through [`Objective::eval_many`] so batched
//! objectives amortize the posterior work without materializing the whole
//! `bins^dim` grid at once.

use super::{best_of_population, Candidate, Objective, Optimizer};
use crate::rng::Pcg64;

/// Grid cells scored per `eval_many` call (bounds peak memory while still
/// amortizing the batched posterior).
const GRID_CHUNK: usize = 4096;

/// Full-factorial grid with `bins` points per dimension (cell centers are
/// offset half a step from the boundary so corners are not over-sampled).
#[derive(Clone, Debug)]
pub struct GridSearch {
    /// Grid resolution per dimension.
    pub bins: usize,
    /// Hard cap on total evaluations (guards the `bins^dim` blow-up).
    pub max_evals: usize,
}

impl GridSearch {
    /// `bins` per dimension, default eval cap of 1e6.
    pub fn new(bins: usize) -> Self {
        Self { bins: bins.max(1), max_evals: 1_000_000 }
    }
}

impl Optimizer for GridSearch {
    fn optimize(&self, f: &dyn Objective, dim: usize, _rng: &mut Pcg64) -> Candidate {
        let mut bins = self.bins;
        // shrink resolution until the grid fits the eval budget
        while bins > 1 && (bins as f64).powi(dim as i32) > self.max_evals as f64 {
            bins -= 1;
        }
        let total = (bins as u64).pow(dim as u32) as usize;
        let mut best: Option<Candidate> = None;
        let mut start = 0usize;
        while start < total {
            let end = (start + GRID_CHUNK).min(total);
            let mut chunk: Vec<Vec<f64>> = Vec::with_capacity(end - start);
            for idx in start..end {
                let mut rem = idx;
                let mut x = vec![0.0; dim];
                for xd in x.iter_mut() {
                    let b = rem % bins;
                    rem /= bins;
                    *xd = (b as f64 + 0.5) / bins as f64;
                }
                chunk.push(x);
            }
            if let Some(cand) = best_of_population(f, chunk) {
                best = Some(match best {
                    Some(b) => b.max(cand),
                    None => cand,
                });
            }
            start = end;
        }
        best.expect("grid has at least one point")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::neg_sphere;

    #[test]
    fn finds_peak_cell() {
        let mut rng = Pcg64::seed(0);
        let c = GridSearch::new(21).optimize(&neg_sphere, 2, &mut rng);
        for &v in &c.x {
            assert!((v - 0.3).abs() < 0.05, "x={v}");
        }
    }

    #[test]
    fn respects_eval_cap() {
        let mut rng = Pcg64::seed(0);
        let mut g = GridSearch::new(100);
        g.max_evals = 1000;
        // 6-D grid of 100^6 would be 1e12; the cap shrinks bins to 3
        let c = g.optimize(&neg_sphere, 6, &mut rng);
        assert!(c.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn single_bin_evaluates_center() {
        let mut rng = Pcg64::seed(0);
        let c = GridSearch::new(1).optimize(&neg_sphere, 2, &mut rng);
        assert_eq!(c.x, vec![0.5, 0.5]);
    }
}
