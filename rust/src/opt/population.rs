//! Round-based population search over [`Objective::eval_many`].
//!
//! The generic batch-first global maximizer: each round proposes a
//! population (a Halton space-filling fraction plus uniform random
//! candidates; the final round samples a shrinking box around the
//! incumbent for cheap local refinement) and scores it in **one**
//! `eval_many` call. Over a batched acquisition objective
//! ([`crate::acqui::AcquiObjective`]) every round costs a single
//! batched-posterior evaluation — one cross-covariance block + one
//! multi-RHS solve on the native GP, or one fused artifact execution per
//! capacity tile on the XLA backend. This subsumes the XLA coordinator's
//! former bespoke `BatchedUcbSearch` sampler
//! ([`crate::coordinator::batched_opt`] is now a thin adapter over it).

use super::{best_of_population, Candidate, Objective, Optimizer};
use crate::rng::{halton_point, Pcg64};

/// Batched global sampler: `rounds` populations of `batch` candidates.
#[derive(Clone, Debug)]
pub struct PopulationSearch {
    /// Rounds of candidate batches (total evals = rounds * batch).
    pub rounds: usize,
    /// Population size per round (match the backend's natural batch size —
    /// e.g. the XLA artifact capacity, or the multi-RHS column block).
    pub batch: usize,
    /// Fraction of each batch drawn from a Halton sequence (space filling)
    /// vs uniform random.
    pub halton_fraction: f64,
}

impl Default for PopulationSearch {
    fn default() -> Self {
        Self { rounds: 8, batch: 64, halton_fraction: 0.5 }
    }
}

impl PopulationSearch {
    /// Budgeted constructor (`rounds * batch` total evaluations).
    pub fn new(rounds: usize, batch: usize) -> Self {
        Self { rounds, batch, ..Self::default() }
    }

    fn run(
        &self,
        f: &dyn Objective,
        dim: usize,
        rng: &mut Pcg64,
        seed: Option<&[f64]>,
    ) -> Candidate {
        let batch = self.batch.max(1);
        let rounds = self.rounds.max(1);
        let mut best = Candidate { x: vec![0.5; dim], value: f64::NEG_INFINITY };
        let mut halton_idx = rng.below(1 << 16); // decorrelate across calls

        for round in 0..rounds {
            let mut cands: Vec<Vec<f64>> = Vec::with_capacity(batch);
            if round == 0 {
                // seed point joins the first population — still exactly
                // one eval_many per round, no lone point-wise eval
                if let Some(x0) = seed {
                    cands.push(x0.to_vec());
                }
            }
            let local = round + 1 == rounds && best.value.is_finite();
            if local {
                // last round: shrink around the incumbent
                let w = 0.1;
                for _ in 0..batch {
                    let x: Vec<f64> = best
                        .x
                        .iter()
                        .map(|&v| (v + rng.uniform(-w, w)).clamp(0.0, 1.0))
                        .collect();
                    cands.push(x);
                }
            } else {
                let n_halton = (batch as f64 * self.halton_fraction) as usize;
                for _ in 0..n_halton {
                    cands.push(halton_point(halton_idx, dim));
                    halton_idx += 1;
                }
                while cands.len() < batch {
                    cands.push(rng.unit_point(dim));
                }
            }
            if let Some(cand) = best_of_population(f, cands) {
                best = best.max(cand);
            }
        }
        best
    }
}

impl Optimizer for PopulationSearch {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        self.run(f, dim, rng, None)
    }

    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        self.run(f, x0.len(), rng, Some(x0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::{neg_sphere, wiggly};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn solves_sphere_and_stays_in_bounds() {
        let mut rng = Pcg64::seed(3);
        let c = PopulationSearch::new(8, 128).optimize(&neg_sphere, 2, &mut rng);
        assert!(c.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(c.value > -0.01, "value={}", c.value);
    }

    #[test]
    fn handles_multimodal_reasonably() {
        let mut rng = Pcg64::seed(4);
        let c = PopulationSearch::new(8, 128).optimize(&wiggly, 2, &mut rng);
        assert!(c.value > 4.0, "value={}", c.value);
    }

    #[test]
    fn evaluates_whole_populations_per_round() {
        struct Counting(AtomicUsize);
        impl Objective for Counting {
            fn eval(&self, x: &[f64]) -> f64 {
                neg_sphere(x)
            }
            fn eval_many(&self, xs: &[Vec<f64>]) -> Vec<f64> {
                self.0.fetch_add(1, Ordering::Relaxed);
                xs.iter().map(|x| self.eval(x)).collect()
            }
        }
        let f = Counting(AtomicUsize::new(0));
        let mut rng = Pcg64::seed(5);
        let _ = PopulationSearch::new(6, 32).optimize(&f, 3, &mut rng);
        // exactly one eval_many call per round — never per candidate
        assert_eq!(f.0.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn from_keeps_good_seed_point() {
        let mut rng = Pcg64::seed(6);
        let c = PopulationSearch::new(2, 8).optimize_from(&neg_sphere, &[0.3, 0.3], &mut rng);
        assert_eq!(c.value, 0.0);
    }
}
