//! Nelder–Mead downhill simplex (bounded to the unit cube by clamping),
//! the standard local refinement stage for chained inner optimizers.

use super::{clamp_unit, Candidate, Objective, Optimizer};
use crate::rng::Pcg64;

/// Nelder–Mead simplex maximizer.
#[derive(Clone, Debug)]
pub struct NelderMead {
    /// Maximum simplex iterations.
    pub max_iters: usize,
    /// Initial simplex edge length.
    pub step: f64,
    /// Convergence tolerance on the value spread.
    pub tol: f64,
}

impl Default for NelderMead {
    fn default() -> Self {
        Self { max_iters: 200, step: 0.1, tol: 1e-9 }
    }
}

const ALPHA: f64 = 1.0; // reflection
const GAMMA: f64 = 2.0; // expansion
const RHO: f64 = 0.5; // contraction
const SIGMA: f64 = 0.5; // shrink

impl Optimizer for NelderMead {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let x0 = rng.unit_point(dim);
        self.optimize_from(f, &x0, rng)
    }

    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], _rng: &mut Pcg64) -> Candidate {
        let dim = x0.len();
        // initial simplex: x0 plus one step along each axis
        let mut simplex: Vec<Candidate> = Vec::with_capacity(dim + 1);
        simplex.push(Candidate::eval(f, x0.to_vec()));
        for d in 0..dim {
            let mut x = x0.to_vec();
            x[d] = if x[d] + self.step <= 1.0 { x[d] + self.step } else { x[d] - self.step };
            simplex.push(Candidate::eval(f, x));
        }

        for _ in 0..self.max_iters {
            // sort descending by value (we maximize)
            simplex.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
            let spread = simplex[0].value - simplex[dim].value;
            if spread.abs() < self.tol {
                break;
            }
            // centroid of all but the worst
            let mut centroid = vec![0.0; dim];
            for c in &simplex[..dim] {
                for (cd, &xd) in centroid.iter_mut().zip(&c.x) {
                    *cd += xd / dim as f64;
                }
            }
            let worst = simplex[dim].clone();
            let point = |t: f64| -> Vec<f64> {
                let mut x: Vec<f64> = centroid
                    .iter()
                    .zip(&worst.x)
                    .map(|(&c, &w)| c + t * (c - w))
                    .collect();
                clamp_unit(&mut x);
                x
            };

            let reflected = Candidate::eval(f, point(ALPHA));
            if reflected.value > simplex[0].value {
                // try to expand
                let expanded = Candidate::eval(f, point(GAMMA));
                simplex[dim] = if expanded.value > reflected.value { expanded } else { reflected };
            } else if reflected.value > simplex[dim - 1].value {
                simplex[dim] = reflected;
            } else {
                // contract towards the centroid
                let contracted = Candidate::eval(f, point(-RHO));
                if contracted.value > worst.value {
                    simplex[dim] = contracted;
                } else {
                    // shrink everything towards the best vertex
                    let best = simplex[0].x.clone();
                    for c in simplex[1..].iter_mut() {
                        let x: Vec<f64> = best
                            .iter()
                            .zip(&c.x)
                            .map(|(&b, &xi)| b + SIGMA * (xi - b))
                            .collect();
                        *c = Candidate::eval(f, x);
                    }
                }
            }
        }
        simplex.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
        simplex.swap_remove(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::{neg_sphere, wiggly};

    #[test]
    fn converges_on_smooth_bowl() {
        let mut rng = Pcg64::seed(3);
        let c = NelderMead::default().optimize_from(&neg_sphere, &[0.9, 0.9, 0.9], &mut rng);
        assert!(c.value > -1e-6, "value={}", c.value);
        for &v in &c.x {
            assert!((v - 0.3).abs() < 1e-3);
        }
    }

    #[test]
    fn stays_in_bounds_on_boundary_peak() {
        // peak of `wiggly` slices is near the upper boundary
        let mut rng = Pcg64::seed(4);
        let c = NelderMead::default().optimize_from(&wiggly, &[0.95], &mut rng);
        assert!((0.0..=1.0).contains(&c.x[0]));
        assert!(c.value >= wiggly(&[0.95]));
    }

    #[test]
    fn improves_over_start_point() {
        let mut rng = Pcg64::seed(5);
        let start = [0.7, 0.1];
        let c = NelderMead::default().optimize_from(&neg_sphere, &start, &mut rng);
        assert!(c.value > neg_sphere(&start));
    }
}
