//! Pure random search (Limbo's `opt::RandomPoint` generalized to a
//! best-of-n sampler; `n = 1` reproduces Limbo's single random point).
//!
//! The whole pool is scored in one [`Objective::eval_many`] call, so a
//! batched acquisition objective evaluates it through the model's batched
//! posterior instead of n independent predicts.

use super::{best_of_population, Candidate, Objective, Optimizer};
use crate::rng::Pcg64;

/// Evaluate `n` uniform random points as one population, return the best.
#[derive(Clone, Debug)]
pub struct RandomPoint {
    /// Number of samples.
    pub n: usize,
}

impl RandomPoint {
    /// Best of `n` uniform draws.
    pub fn new(n: usize) -> Self {
        Self { n: n.max(1) }
    }
}

impl Optimizer for RandomPoint {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let pool: Vec<Vec<f64>> = (0..self.n).map(|_| rng.unit_point(dim)).collect();
        best_of_population(f, pool).expect("n >= 1 samples")
    }

    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        // include the seed point in the pool
        let mut pool: Vec<Vec<f64>> = Vec::with_capacity(self.n + 1);
        pool.push(x0.to_vec());
        pool.extend((0..self.n).map(|_| rng.unit_point(x0.len())));
        best_of_population(f, pool).expect("non-empty pool")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::neg_sphere;

    #[test]
    fn stays_in_bounds_and_improves_with_budget() {
        let mut rng = Pcg64::seed(1);
        let small = RandomPoint::new(4).optimize(&neg_sphere, 2, &mut rng);
        assert!(small.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mut rng = Pcg64::seed(1);
        let big = RandomPoint::new(4096).optimize(&neg_sphere, 2, &mut rng);
        assert!(big.value >= small.value);
        assert!(big.value > -0.02);
    }

    #[test]
    fn from_keeps_good_seed_point() {
        let mut rng = Pcg64::seed(2);
        let c = RandomPoint::new(2).optimize_from(&neg_sphere, &[0.3, 0.3], &mut rng);
        assert_eq!(c.value, 0.0);
    }
}
