//! Pure random search (Limbo's `opt::RandomPoint` generalized to a
//! best-of-n sampler; `n = 1` reproduces Limbo's single random point).

use super::{Candidate, Objective, Optimizer};
use crate::rng::Pcg64;

/// Evaluate `n` uniform random points, return the best.
#[derive(Clone, Debug)]
pub struct RandomPoint {
    /// Number of samples.
    pub n: usize,
}

impl RandomPoint {
    /// Best of `n` uniform draws.
    pub fn new(n: usize) -> Self {
        Self { n: n.max(1) }
    }
}

impl Optimizer for RandomPoint {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let mut best = Candidate::eval(f, rng.unit_point(dim));
        for _ in 1..self.n {
            best = best.max(Candidate::eval(f, rng.unit_point(dim)));
        }
        best
    }

    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        // include the seed point in the pool
        Candidate::eval(f, x0.to_vec()).max(self.optimize(f, x0.len(), rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::neg_sphere;

    #[test]
    fn stays_in_bounds_and_improves_with_budget() {
        let mut rng = Pcg64::seed(1);
        let small = RandomPoint::new(4).optimize(&neg_sphere, 2, &mut rng);
        assert!(small.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mut rng = Pcg64::seed(1);
        let big = RandomPoint::new(4096).optimize(&neg_sphere, 2, &mut rng);
        assert!(big.value >= small.value);
        assert!(big.value > -0.02);
    }

    #[test]
    fn from_keeps_good_seed_point() {
        let mut rng = Pcg64::seed(2);
        let c = RandomPoint::new(2).optimize_from(&neg_sphere, &[0.3, 0.3], &mut rng);
        assert_eq!(c.value, 0.0);
    }
}
