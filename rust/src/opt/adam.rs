//! Adam gradient ascent — an alternative hyper-parameter optimizer
//! (ablation partner for [`crate::opt::rprop`]).

/// Maximize `f` (returning `(value, gradient)`) from `x0` with Adam.
/// Returns the best iterate seen.
pub fn adam_maximize(
    mut f: impl FnMut(&[f64]) -> (f64, Vec<f64>),
    x0: &[f64],
    iterations: usize,
    lr: f64,
    bounds: Option<(f64, f64)>,
) -> Vec<f64> {
    const B1: f64 = 0.9;
    const B2: f64 = 0.999;
    const EPS: f64 = 1e-8;
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut m = vec![0.0; n];
    let mut v = vec![0.0; n];
    let (mut best_x, mut best_val) = (x.clone(), f64::NEG_INFINITY);

    for t in 1..=iterations {
        let (val, grad) = f(&x);
        if val.is_finite() && val > best_val {
            best_val = val;
            best_x = x.clone();
        }
        for i in 0..n {
            let g = if grad[i].is_finite() { grad[i] } else { 0.0 };
            m[i] = B1 * m[i] + (1.0 - B1) * g;
            v[i] = B2 * v[i] + (1.0 - B2) * g * g;
            let mh = m[i] / (1.0 - B1.powi(t as i32));
            let vh = v[i] / (1.0 - B2.powi(t as i32));
            x[i] += lr * mh / (vh.sqrt() + EPS); // ascent
            if let Some((lo, hi)) = bounds {
                x[i] = x[i].clamp(lo, hi);
            }
        }
    }
    let (val, _) = f(&x);
    if val.is_finite() && val > best_val {
        best_x = x;
    }
    best_x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maximizes_quadratic() {
        let f = |x: &[f64]| {
            let v = -(x[0] - 0.7).powi(2);
            (v, vec![-2.0 * (x[0] - 0.7)])
        };
        let best = adam_maximize(f, &[0.0], 500, 0.05, None);
        assert!((best[0] - 0.7).abs() < 1e-2);
    }

    #[test]
    fn bounded_stays_inside() {
        let f = |x: &[f64]| (x[0], vec![1.0]);
        let best = adam_maximize(f, &[0.5], 200, 0.1, Some((0.0, 1.0)));
        assert!((best[0] - 1.0).abs() < 1e-9);
    }
}
