//! iRprop⁻ gradient ascent (Igel & Hüsken 2000) — the hyper-parameter
//! optimizer Limbo itself uses for GP likelihood fits.
//!
//! Rprop adapts a per-coordinate step size from gradient *signs* only,
//! which makes it immune to the poor scaling of the LML landscape
//! (lengthscale axes vs variance axes differ by orders of magnitude).

/// Rprop hyper-parameters.
#[derive(Clone, Debug)]
pub struct RpropParams {
    /// Iterations.
    pub iterations: usize,
    /// Step-size increase factor (eta+).
    pub eta_plus: f64,
    /// Step-size decrease factor (eta-).
    pub eta_minus: f64,
    /// Initial step size.
    pub delta0: f64,
    /// Step-size bounds.
    pub delta_min: f64,
    /// Maximum step size.
    pub delta_max: f64,
}

impl Default for RpropParams {
    fn default() -> Self {
        Self {
            iterations: 100,
            eta_plus: 1.2,
            eta_minus: 0.5,
            delta0: 0.1,
            delta_min: 1e-6,
            delta_max: 1.0,
        }
    }
}

/// Maximize `f` (returning `(value, gradient)`) from `x0` with iRprop⁻.
/// `bounds = Some((lo, hi))` clamps every coordinate. Returns the best
/// iterate seen (not necessarily the last).
pub fn rprop_maximize(
    mut f: impl FnMut(&[f64]) -> (f64, Vec<f64>),
    x0: &[f64],
    params: &RpropParams,
    bounds: Option<(f64, f64)>,
) -> Vec<f64> {
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut delta = vec![params.delta0; n];
    let mut prev_grad = vec![0.0; n];
    let (mut best_x, mut best_val) = (x.clone(), f64::NEG_INFINITY);

    for _ in 0..params.iterations {
        let (val, grad) = f(&x);
        if val.is_finite() && val > best_val {
            best_val = val;
            best_x = x.clone();
        }
        for i in 0..n {
            let g = grad[i];
            if !g.is_finite() {
                prev_grad[i] = 0.0;
                continue;
            }
            let sign_change = prev_grad[i] * g;
            if sign_change > 0.0 {
                delta[i] = (delta[i] * params.eta_plus).min(params.delta_max);
            } else if sign_change < 0.0 {
                delta[i] = (delta[i] * params.eta_minus).max(params.delta_min);
                // iRprop-: forget the gradient after a sign flip
                prev_grad[i] = 0.0;
                continue;
            }
            // ascent: move along the gradient sign
            x[i] += g.signum() * delta[i];
            if let Some((lo, hi)) = bounds {
                x[i] = x[i].clamp(lo, hi);
            }
            prev_grad[i] = g;
        }
    }
    // final evaluation to catch the last iterate
    let (val, _) = f(&x);
    if val.is_finite() && val > best_val {
        best_x = x;
    }
    best_x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maximizes_quadratic() {
        // f(x) = -(x0-1)^2 - 10 (x1+2)^2  (badly scaled on purpose)
        let f = |x: &[f64]| {
            let v = -(x[0] - 1.0).powi(2) - 10.0 * (x[1] + 2.0).powi(2);
            let g = vec![-2.0 * (x[0] - 1.0), -20.0 * (x[1] + 2.0)];
            (v, g)
        };
        let best = rprop_maximize(f, &[0.0, 0.0], &RpropParams::default(), None);
        assert!((best[0] - 1.0).abs() < 1e-2, "x0={}", best[0]);
        assert!((best[1] + 2.0).abs() < 1e-2, "x1={}", best[1]);
    }

    #[test]
    fn respects_bounds() {
        let f = |x: &[f64]| (x[0], vec![1.0]); // push up forever
        let best = rprop_maximize(f, &[0.0], &RpropParams::default(), Some((-1.0, 2.0)));
        assert!(best[0] <= 2.0 + 1e-12);
        assert!((best[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn survives_nan_gradients() {
        let f = |x: &[f64]| {
            if x[0] > 0.5 {
                (f64::NAN, vec![f64::NAN])
            } else {
                (-(x[0] - 0.4).powi(2), vec![-2.0 * (x[0] - 0.4)])
            }
        };
        let best = rprop_maximize(f, &[0.0], &RpropParams::default(), Some((0.0, 1.0)));
        assert!((best[0] - 0.4).abs() < 0.05, "x={}", best[0]);
    }

    #[test]
    fn returns_best_not_last() {
        // value oscillates if steps overshoot; best-seen must win
        let f = |x: &[f64]| (-(x[0]).powi(2), vec![-2.0 * x[0]]);
        let best = rprop_maximize(f, &[3.0], &RpropParams::default(), None);
        assert!(best[0].abs() < 0.1);
    }
}
