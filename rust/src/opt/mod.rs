//! Inner optimizers — the `limbo::opt::*` policy family (the NLOpt
//! replacement).
//!
//! Two roles in a Bayesian optimizer:
//! * maximizing the **acquisition function** over the unit hypercube
//!   (derivative-free, multimodal): [`RandomPoint`], [`GridSearch`],
//!   [`NelderMead`], [`Cmaes`], [`Direct`], [`AdaptiveDe`], composed
//!   with [`ParallelRepeater`] (parallel restarts) and [`Chained`]
//!   (global-then-local, Limbo's "chained" optimizers);
//! * maximizing the **log marginal likelihood** over log-hyper-params
//!   (gradient available): [`rprop`] / [`adam`].
//!
//! All domain-bounded optimizers work on `[0, 1]^dim`; callers scale to
//! native domains ([`crate::benchfns`] does this for the test suite).

pub mod adam;
pub mod cmaes;
pub mod de;
pub mod direct;
pub mod grid;
pub mod nelder_mead;
pub mod population;
pub mod random;
pub mod rprop;

pub use adam::adam_maximize;
pub use cmaes::Cmaes;
pub use de::{AdaptiveDe, DeGenRecord, DeRecorder};
pub use direct::Direct;
pub use grid::GridSearch;
pub use nelder_mead::NelderMead;
pub use population::PopulationSearch;
pub use random::RandomPoint;
pub use rprop::{rprop_maximize, RpropParams};

use crate::obs::{self, Counter, Phase};
use crate::pool;
use crate::rng::Pcg64;

/// A point and its objective value.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    /// Location in `[0, 1]^dim`.
    pub x: Vec<f64>,
    /// Objective value (maximization).
    pub value: f64,
}

impl Candidate {
    /// Evaluate `f` at `x` and wrap.
    pub fn eval(f: &dyn Objective, x: Vec<f64>) -> Self {
        let value = f.eval(&x);
        Self { x, value }
    }

    /// The better (higher-value) of two candidates, poison-safe: NaN and
    /// `+inf` values (a degenerate model state / an overflowing
    /// objective) never survive against a usable challenger. With the
    /// plain `other.value > self.value` comparison a NaN incumbent won
    /// every remaining round (every `>` against NaN is false) and a
    /// `+inf` value beat every finite candidate — either way one
    /// poisoned evaluation hijacked the whole restart fold. `-inf` needs
    /// no special case: it loses any ordinary comparison.
    pub fn max(self, other: Candidate) -> Candidate {
        let self_usable = self.value.is_finite() || self.value == f64::NEG_INFINITY;
        let other_usable = other.value.is_finite() || other.value == f64::NEG_INFINITY;
        match (self_usable, other_usable) {
            (true, false) => self,
            (false, true) => other,
            // both usable: ordinary comparison; both poisoned: at least
            // drop a NaN incumbent in favor of the challenger
            _ => {
                if self.value.is_nan() || other.value > self.value {
                    other
                } else {
                    self
                }
            }
        }
    }
}

/// A maximization objective over `[0, 1]^dim`.
pub trait Objective: Sync {
    /// Evaluate at `x`.
    fn eval(&self, x: &[f64]) -> f64;

    /// Evaluate a whole population at once. The default loops over
    /// [`eval`](Self::eval); batched backends override it — an
    /// acquisition objective ([`crate::acqui::AcquiObjective`]) routes
    /// this through `AcquiFn::eval_batch` → `Model::predict_batch`, so a
    /// population-based optimizer pays one cross-covariance block and one
    /// multi-RHS solve per generation instead of per candidate.
    fn eval_many(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.eval(x)).collect()
    }
}

impl<F: Fn(&[f64]) -> f64 + Sync> Objective for F {
    fn eval(&self, x: &[f64]) -> f64 {
        self(x)
    }
}

/// Evaluate a population through [`Objective::eval_many`] and keep the
/// best candidate (earliest wins ties, matching a sequential
/// [`Candidate::max`] fold). `None` only for an empty population.
///
/// Non-finite values (NaN from a degenerate model state, ±inf from an
/// overflowing objective) are skipped — one poisoned candidate used to
/// stick as the incumbent because every later `value > NaN` comparison
/// is false, hijacking the whole acquisition maximization. If *no*
/// candidate evaluates finite, the first candidate is returned so the
/// contract (`Some` for a non-empty population) still holds.
pub fn best_of_population(f: &dyn Objective, pts: Vec<Vec<f64>>) -> Option<Candidate> {
    let values = f.eval_many(&pts);
    assert_eq!(values.len(), pts.len(), "eval_many: value count mismatch");
    let mut best: Option<Candidate> = None;
    let mut fallback: Option<Candidate> = None;
    for (x, value) in pts.into_iter().zip(values) {
        if !value.is_finite() {
            if fallback.is_none() {
                fallback = Some(Candidate { x, value });
            }
            continue;
        }
        if best.as_ref().map_or(true, |b| value > b.value) {
            best = Some(Candidate { x, value });
        }
    }
    best.or(fallback)
}

/// A derivative-free maximizer over the unit hypercube.
pub trait Optimizer: Send + Sync {
    /// Maximize `f` over `[0, 1]^dim`.
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate;

    /// Maximize starting from `x0` (local methods refine it; global
    /// methods may ignore it — default delegates to [`optimize`](Self::optimize)).
    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        self.optimize(f, x0.len(), rng)
    }
}

/// Combinator helpers on any optimizer (the paper's "several restarts in
/// parallel" and "several internal optimizations chained").
pub trait OptimizerExt: Optimizer + Sized {
    /// Restart `n` times (in parallel over `threads`), keep the best.
    fn restarts(self, n: usize, threads: usize) -> ParallelRepeater<Self> {
        ParallelRepeater { inner: self, n, threads }
    }

    /// Follow with `next`, seeded at this optimizer's result.
    fn then<B: Optimizer>(self, next: B) -> Chained<Self, B> {
        Chained { first: self, second: next }
    }
}

impl<O: Optimizer + Sized> OptimizerExt for O {}

/// Run the inner optimizer `n` times with forked RNG streams (optionally
/// in parallel) and keep the best result.
pub struct ParallelRepeater<O: Optimizer> {
    /// The restarted optimizer.
    pub inner: O,
    /// Number of restarts.
    pub n: usize,
    /// Worker threads (1 = sequential).
    pub threads: usize,
}

impl<O: Optimizer> Optimizer for ParallelRepeater<O> {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let _span = obs::span(Phase::InnerOpt);
        obs::counter_add(Counter::InnerRestarts, self.n.max(1) as u64);
        let rngs: Vec<Pcg64> = (0..self.n.max(1)).map(|i| rng.fork(i as u64)).collect();
        let inner = &self.inner;
        let results = pool::parallel_map(rngs, self.threads, |_, mut r| {
            inner.optimize(f, dim, &mut r)
        });
        results
            .into_iter()
            .reduce(Candidate::max)
            .expect("at least one restart")
    }

    /// Every restart is seeded at `x0` (forwarded to the inner
    /// optimizer's `optimize_from`) — without this override the trait
    /// default silently dropped the seed, so a caller refining a known
    /// good point (e.g. the qEI joint-refinement pass over a greedy
    /// batch) restarted from scratch instead.
    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        let _span = obs::span(Phase::InnerOpt);
        obs::counter_add(Counter::InnerRestarts, self.n.max(1) as u64);
        let rngs: Vec<Pcg64> = (0..self.n.max(1)).map(|i| rng.fork(i as u64)).collect();
        let inner = &self.inner;
        let results = pool::parallel_map(rngs, self.threads, |_, mut r| {
            inner.optimize_from(f, x0, &mut r)
        });
        results
            .into_iter()
            .reduce(Candidate::max)
            .expect("at least one restart")
    }
}

/// Run `first`, then `second` seeded at the result (global -> local).
pub struct Chained<A: Optimizer, B: Optimizer> {
    /// Global stage.
    pub first: A,
    /// Local refinement stage.
    pub second: B,
}

impl<A: Optimizer, B: Optimizer> Optimizer for Chained<A, B> {
    fn optimize(&self, f: &dyn Objective, dim: usize, rng: &mut Pcg64) -> Candidate {
        let c1 = self.first.optimize(f, dim, rng);
        let c2 = self.second.optimize_from(f, &c1.x, rng);
        c1.max(c2)
    }

    fn optimize_from(&self, f: &dyn Objective, x0: &[f64], rng: &mut Pcg64) -> Candidate {
        let c1 = self.first.optimize_from(f, x0, rng);
        let c2 = self.second.optimize_from(f, &c1.x, rng);
        c1.max(c2)
    }
}

/// Clamp a point into the unit hypercube.
#[inline]
pub(crate) fn clamp_unit(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = v.clamp(0.0, 1.0);
    }
}

#[cfg(test)]
pub(crate) mod test_objectives {
    //! Shared objectives for optimizer tests (all maximization on [0,1]^d).

    /// Smooth unimodal: peak 0 at x = 0.3·1.
    pub fn neg_sphere(x: &[f64]) -> f64 {
        -x.iter().map(|&v| (v - 0.3) * (v - 0.3)).sum::<f64>()
    }

    /// Multimodal; per-dim global max 2.32292 at x = 0.66842.
    pub fn wiggly(x: &[f64]) -> f64 {
        x.iter().map(|&v| (12.0 * v).sin() + 2.0 * v).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::test_objectives::*;
    use super::*;

    #[test]
    fn restarts_beat_single_run_on_multimodal() {
        let mut rng = Pcg64::seed(5);
        let single = NelderMead::default().optimize(&wiggly, 2, &mut rng);
        let mut rng = Pcg64::seed(5);
        let multi = NelderMead::default().restarts(16, 4).optimize(&wiggly, 2, &mut rng);
        assert!(multi.value >= single.value - 1e-12);
    }

    #[test]
    fn chained_refines_global_result() {
        let mut rng = Pcg64::seed(6);
        let global = RandomPoint::new(64).optimize(&neg_sphere, 3, &mut rng);
        let mut rng = Pcg64::seed(6);
        let chained = RandomPoint::new(64)
            .then(NelderMead::default())
            .optimize(&neg_sphere, 3, &mut rng);
        assert!(chained.value >= global.value);
        assert!(chained.value > -1e-3, "local stage should nearly reach the peak");
    }

    #[test]
    fn candidate_max_picks_higher() {
        let a = Candidate { x: vec![0.0], value: 1.0 };
        let b = Candidate { x: vec![1.0], value: 2.0 };
        assert_eq!(a.clone().max(b.clone()), b);
    }

    #[test]
    fn candidate_max_is_poison_safe() {
        let good = Candidate { x: vec![0.0], value: 1.0 };
        let nan = Candidate { x: vec![1.0], value: f64::NAN };
        // a NaN incumbent must lose to any usable challenger...
        assert_eq!(nan.clone().max(good.clone()), good);
        // ...and a NaN challenger must never displace a usable incumbent
        assert_eq!(good.clone().max(nan.clone()), good);
        // +inf (overflowing objective) must not hijack the fold either way
        let over = Candidate { x: vec![3.0], value: f64::INFINITY };
        assert_eq!(over.clone().max(good.clone()), good);
        assert_eq!(good.clone().max(over.clone()), good);
        // among poisoned values, a NaN incumbent yields to the challenger
        assert_eq!(nan.max(over.clone()), over);
        // -inf incumbents still lose normally
        let worst = Candidate { x: vec![2.0], value: f64::NEG_INFINITY };
        assert_eq!(worst.clone().max(good.clone()), good);
        assert_eq!(worst.clone().max(worst.clone()), worst);
    }

    #[test]
    fn best_of_population_skips_injected_non_finite_values() {
        use crate::testing;
        testing::check(
            "best-of-population-nan-safe",
            0x4A4E,
            48,
            |rng: &mut Pcg64| {
                let n = 2 + rng.below(20);
                // per-candidate values, then poison a random subset
                let mut values: Vec<f64> =
                    (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
                let n_poison = rng.below(n);
                for _ in 0..n_poison {
                    let i = rng.below(n);
                    values[i] = match rng.below(3) {
                        0 => f64::NAN,
                        1 => f64::INFINITY,
                        _ => f64::NEG_INFINITY,
                    };
                }
                values
            },
            |values| {
                let pts: Vec<Vec<f64>> =
                    (0..values.len()).map(|i| vec![i as f64]).collect();
                let vals = values.clone();
                let f = move |x: &[f64]| vals[x[0] as usize];
                let got = best_of_population(&f, pts).expect("non-empty");
                let finite_max = values
                    .iter()
                    .copied()
                    .filter(|v| v.is_finite())
                    .fold(f64::NEG_INFINITY, f64::max);
                if finite_max.is_finite() {
                    testing::close(got.value, finite_max, 1e-15)
                } else if got.value.is_finite() {
                    Err(format!("no finite value existed but got {}", got.value))
                } else {
                    Ok(()) // all-poisoned population: fallback candidate
                }
            },
        );
    }
}
