//! DIRECT — DIviding RECTangles (Jones, Perttunen & Stuckman 1993):
//! Lipschitzian global optimization without the Lipschitz constant.
//! This is BayesOpt's default acquisition optimizer, so it is also the
//! inner optimizer of the Figure-1 baseline configuration.
//!
//! Implementation notes: hyper-rectangles are tracked by their center,
//! per-dimension third-level (side length `3^-level`), and value.
//! Potentially-optimal rectangles are selected with the standard
//! lower-right convex-hull rule over (diameter, -value) with the
//! epsilon-improvement filter, then trisected along their longest sides.

use super::{Candidate, Objective, Optimizer};
use crate::rng::Pcg64;

#[derive(Clone, Debug)]
struct Rect {
    center: Vec<f64>,
    /// Trisection count per dimension (side_d = 3^-levels[d]).
    levels: Vec<u32>,
    value: f64,
}

impl Rect {
    /// Half-diagonal of the rectangle (the "size" used by DIRECT).
    fn diameter(&self) -> f64 {
        self.levels
            .iter()
            .map(|&l| {
                let side = 3.0_f64.powi(-(l as i32));
                side * side
            })
            .sum::<f64>()
            .sqrt()
            * 0.5
    }
}

/// DIRECT maximizer on the unit hypercube.
#[derive(Clone, Debug)]
pub struct Direct {
    /// Evaluation budget.
    pub max_evals: usize,
    /// Epsilon of the potential-optimality test (Jones' 1e-4 default).
    pub epsilon: f64,
}

impl Default for Direct {
    fn default() -> Self {
        Self { max_evals: 500, epsilon: 1e-4 }
    }
}

impl Direct {
    /// Budgeted constructor.
    pub fn new(max_evals: usize) -> Self {
        Self { max_evals, ..Self::default() }
    }

    /// Indices of potentially-optimal rectangles.
    ///
    /// Rectangle `i` (diameter `d_i`, value `v_i`) is potentially optimal
    /// iff some Lipschitz constant `K > 0` exists with
    /// `v_i + K d_i >= v_j + K d_j` for all `j` and
    /// `v_i + K d_i >= best + eps |best|` (Jones et al., Def. 3.1, in
    /// maximization form). With one candidate per diameter class this is a
    /// direct O(m^2) feasibility test over the class representatives —
    /// `m` (distinct diameters) stays small, and the largest rectangle is
    /// always feasible (`K -> inf`), which preserves global convergence.
    fn potentially_optimal(&self, rects: &[Rect], best: f64) -> Vec<usize> {
        // group by diameter: keep the best rectangle per diameter class
        let mut by_diam: Vec<(f64, usize)> = Vec::new();
        for (i, r) in rects.iter().enumerate() {
            let d = r.diameter();
            match by_diam.iter_mut().find(|(dd, _)| (*dd - d).abs() < 1e-12) {
                Some((_, idx)) => {
                    if r.value > rects[*idx].value {
                        *idx = i;
                    }
                }
                None => by_diam.push((d, i)),
            }
        }
        by_diam.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let m = by_diam.len();
        let mut out: Vec<usize> = Vec::new();
        for i in 0..m {
            let (di, idx_i) = by_diam[i];
            let vi = rects[idx_i].value;
            // lower bound on K from smaller rectangles, upper from larger
            let mut k_lo: f64 = 0.0;
            let mut k_hi = f64::INFINITY;
            for (j, &(dj, idx_j)) in by_diam.iter().enumerate() {
                if j == i {
                    continue;
                }
                let vj = rects[idx_j].value;
                if dj < di {
                    k_lo = k_lo.max((vj - vi) / (di - dj));
                } else {
                    k_hi = k_hi.min((vj - vi) / (dj - di));
                }
            }
            if k_lo > k_hi {
                continue;
            }
            // epsilon rule with the most optimistic feasible K
            let bound = if k_hi.is_finite() { vi + k_hi * di } else { f64::INFINITY };
            if bound >= best + self.epsilon * best.abs().max(1e-8) {
                out.push(idx_i);
            }
        }
        if out.is_empty() {
            // always divide at least the largest rectangle
            if let Some(&(_, idx)) = by_diam.last() {
                out.push(idx);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

impl Optimizer for Direct {
    fn optimize(&self, f: &dyn Objective, dim: usize, _rng: &mut Pcg64) -> Candidate {
        let mut rects = vec![Rect {
            center: vec![0.5; dim],
            levels: vec![0; dim],
            value: f.eval(&vec![0.5; dim]),
        }];
        let mut evals = 1usize;
        let mut best = Candidate { x: rects[0].center.clone(), value: rects[0].value };

        while evals < self.max_evals {
            let selected = self.potentially_optimal(&rects, best.value);
            let mut any_divided = false;
            for &si in selected.iter().rev() {
                if evals >= self.max_evals {
                    break;
                }
                let rect = rects[si].clone();
                // longest sides = minimal level
                let min_level = *rect.levels.iter().min().unwrap();
                let long_dims: Vec<usize> = (0..dim)
                    .filter(|&d| rect.levels[d] == min_level)
                    .collect();
                let delta = 3.0_f64.powi(-(min_level as i32 + 1));

                // sample center +/- delta along each long dimension; the
                // whole rect-center sweep goes through eval_many as one
                // batch (2 probes per affordable dimension)
                let mut dims_used: Vec<usize> = Vec::new();
                let mut probes: Vec<Vec<f64>> = Vec::new();
                for &d in &long_dims {
                    if evals + 2 > self.max_evals {
                        break;
                    }
                    let mut lo = rect.center.clone();
                    lo[d] -= delta;
                    let mut hi = rect.center.clone();
                    hi[d] += delta;
                    probes.push(lo);
                    probes.push(hi);
                    evals += 2;
                    dims_used.push(d);
                }
                if dims_used.is_empty() {
                    continue;
                }
                let values = f.eval_many(&probes);
                let mut trials: Vec<(usize, Rect, Rect)> =
                    Vec::with_capacity(dims_used.len());
                let mut probe_iter = probes.into_iter().zip(values);
                for &d in &dims_used {
                    let (lo, vlo) = probe_iter.next().expect("paired lo probe");
                    let (hi, vhi) = probe_iter.next().expect("paired hi probe");
                    if vlo > best.value {
                        best = Candidate { x: lo.clone(), value: vlo };
                    }
                    if vhi > best.value {
                        best = Candidate { x: hi.clone(), value: vhi };
                    }
                    trials.push((
                        d,
                        Rect { center: lo, levels: rect.levels.clone(), value: vlo },
                        Rect { center: hi, levels: rect.levels.clone(), value: vhi },
                    ));
                }
                any_divided = true;
                // divide in order of best child value (Jones' rule):
                // dimensions with better children get the larger pieces
                trials.sort_by(|a, b| {
                    let wa = a.1.value.max(a.2.value);
                    let wb = b.1.value.max(b.2.value);
                    wb.partial_cmp(&wa).unwrap()
                });
                let mut parent = rect;
                let mut new_rects = Vec::with_capacity(trials.len() * 2);
                for (d, mut lo, mut hi) in trials {
                    parent.levels[d] += 1;
                    lo.levels = parent.levels.clone();
                    hi.levels = parent.levels.clone();
                    new_rects.push(lo);
                    new_rects.push(hi);
                }
                rects[si] = parent;
                rects.extend(new_rects);
            }
            if !any_divided {
                break; // resolution exhausted within budget
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::test_objectives::{neg_sphere, wiggly};

    #[test]
    fn solves_sphere() {
        let mut rng = Pcg64::seed(0);
        let c = Direct::new(600).optimize(&neg_sphere, 2, &mut rng);
        assert!(c.value > -1e-3, "value={}", c.value);
    }

    #[test]
    fn finds_global_optimum_of_multimodal() {
        let mut rng = Pcg64::seed(0);
        let c = Direct::new(800).optimize(&wiggly, 1, &mut rng);
        // global max of sin(12x)+2x on [0,1]: x* = 0.66842, f* = 2.32292
        // (critical points at cos(12x) = -1/6; boundary f(1) = 1.4634)
        assert!(c.value > 2.322, "value={}", c.value);
        assert!((c.x[0] - 0.66842).abs() < 0.01, "x={}", c.x[0]);
    }

    #[test]
    fn deterministic() {
        let mut r1 = Pcg64::seed(1);
        let mut r2 = Pcg64::seed(2);
        let c1 = Direct::new(300).optimize(&neg_sphere, 3, &mut r1);
        let c2 = Direct::new(300).optimize(&neg_sphere, 3, &mut r2);
        assert_eq!(c1.x, c2.x, "DIRECT ignores the RNG");
    }

    #[test]
    fn respects_budget() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let f = |x: &[f64]| {
            count.fetch_add(1, Ordering::Relaxed);
            neg_sphere(x)
        };
        let mut rng = Pcg64::seed(0);
        let _ = Direct::new(100).optimize(&f, 4, &mut rng);
        assert!(count.load(Ordering::Relaxed) <= 101);
    }
}
