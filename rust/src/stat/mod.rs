//! Run statistics writers — the `limbo::stat::*` policy family.
//!
//! [`RunLogger`] writes the standard Limbo run files into a run directory:
//! `samples.dat` (evaluated points), `observations.dat`, `best.dat`
//! (best-so-far trace), and `meta.dat` (dimension, wall time). All files
//! are plain TSV so downstream plotting needs no extra tooling.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// TSV run logger; every write goes through buffered files flushed on drop.
pub struct RunLogger {
    dir: PathBuf,
    samples: BufWriter<File>,
    observations: BufWriter<File>,
    best: BufWriter<File>,
    start: Instant,
}

impl RunLogger {
    /// Create (or truncate) the run files inside `dir`.
    pub fn create(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let open = |name: &str| -> std::io::Result<BufWriter<File>> {
            Ok(BufWriter::new(File::create(dir.join(name))?))
        };
        Ok(Self {
            dir: dir.to_path_buf(),
            samples: open("samples.dat")?,
            observations: open("observations.dat")?,
            best: open("best.dat")?,
            start: Instant::now(),
        })
    }

    /// Record one evaluation.
    pub fn log_sample(&mut self, iteration: usize, x: &[f64], y: f64, best: f64) {
        let xs: Vec<String> = x.iter().map(|v| format!("{v:.10e}")).collect();
        let _ = writeln!(self.samples, "{iteration}\t{}", xs.join("\t"));
        let _ = writeln!(self.observations, "{iteration}\t{y:.10e}");
        let _ = writeln!(
            self.best,
            "{iteration}\t{best:.10e}\t{:.6}",
            self.start.elapsed().as_secs_f64()
        );
    }

    /// Write the run footer (`meta.dat`) and flush everything.
    pub fn finish(&mut self, dim: usize, total_evals: usize) {
        let elapsed = self.start.elapsed().as_secs_f64();
        let _ = std::fs::write(
            self.dir.join("meta.dat"),
            format!("dim\t{dim}\nevaluations\t{total_evals}\nwall_seconds\t{elapsed:.6}\n"),
        );
        let _ = self.samples.flush();
        let _ = self.observations.flush();
        let _ = self.best.flush();
    }

    /// The run directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_all_files() {
        let dir = std::env::temp_dir().join("limbo_stat_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut log = RunLogger::create(&dir).unwrap();
        log.log_sample(0, &[0.1, 0.2], 1.5, 1.5);
        log.log_sample(1, &[0.3, 0.4], 0.5, 1.5);
        log.finish(2, 2);
        for f in ["samples.dat", "observations.dat", "best.dat", "meta.dat"] {
            let content = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(!content.is_empty(), "{f} should not be empty");
        }
        let best = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best.lines().count(), 2);
        let samples = std::fs::read_to_string(dir.join("samples.dat")).unwrap();
        assert!(samples.lines().next().unwrap().starts_with("0\t"));
    }
}
