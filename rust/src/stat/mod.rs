//! Run statistics writers — the `limbo::stat::*` policy family, as
//! observers on the [`BoCore`](crate::bayes_opt::BoCore) event bus.
//!
//! Every writer implements [`Observer`] and subscribes to the typed
//! [`BoEvent`] stream the core dispatches (`InitDone`, `Proposal`,
//! `Observation`, `Refit`, `Stopped`) — the loop never knows who is
//! listening:
//!
//! * [`RunLogger`] writes the standard Limbo run files (`samples.dat`,
//!   `observations.dat`, `best.dat`, `meta.dat`) into a run directory;
//! * [`JsonlObserver`] writes one JSON object per event — the
//!   machine-readable twin of the TSV traces, matching the bench
//!   pipeline's JSON-rows idiom;
//! * [`TraceHandle`] collects the observation trace in memory behind a
//!   cloneable handle (the cross-frontend parity tests compare these
//!   bit-for-bit).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bayes_opt::core::{BoEvent, Observer};

/// TSV run logger; every write goes through buffered files flushed on drop.
pub struct RunLogger {
    dir: PathBuf,
    samples: BufWriter<File>,
    observations: BufWriter<File>,
    best: BufWriter<File>,
    start: Instant,
}

impl RunLogger {
    /// Create (or truncate) the run files inside `dir`.
    pub fn create(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let open = |name: &str| -> std::io::Result<BufWriter<File>> {
            Ok(BufWriter::new(File::create(dir.join(name))?))
        };
        Ok(Self {
            dir: dir.to_path_buf(),
            samples: open("samples.dat")?,
            observations: open("observations.dat")?,
            best: open("best.dat")?,
            start: Instant::now(),
        })
    }

    /// Record one evaluation.
    pub fn log_sample(&mut self, iteration: usize, x: &[f64], y: f64, best: f64) {
        let xs: Vec<String> = x.iter().map(|v| format!("{v:.10e}")).collect();
        let _ = writeln!(self.samples, "{iteration}\t{}", xs.join("\t"));
        let _ = writeln!(self.observations, "{iteration}\t{y:.10e}");
        let _ = writeln!(
            self.best,
            "{iteration}\t{best:.10e}\t{:.6}",
            self.start.elapsed().as_secs_f64()
        );
    }

    /// Write the run footer (`meta.dat`) and flush everything.
    pub fn finish(&mut self, dim: usize, total_evals: usize) {
        let elapsed = self.start.elapsed().as_secs_f64();
        let _ = std::fs::write(
            self.dir.join("meta.dat"),
            format!("dim\t{dim}\nevaluations\t{total_evals}\nwall_seconds\t{elapsed:.6}\n"),
        );
        let _ = self.samples.flush();
        let _ = self.observations.flush();
        let _ = self.best.flush();
    }

    /// The run directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl Observer for RunLogger {
    fn on_event(&mut self, event: &BoEvent) {
        match *event {
            BoEvent::Observation { evaluations, x, y, best } => {
                self.log_sample(evaluations, x, y, best);
            }
            BoEvent::Stopped { dim, evaluations, .. } => self.finish(dim, evaluations),
            _ => {}
        }
    }
}

/// One recorded observation of a run (user coordinates).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRow {
    /// Total observations including this one.
    pub evaluations: usize,
    /// Evaluated point.
    pub x: Vec<f64>,
    /// Observed value.
    pub y: f64,
    /// Incumbent best after this observation.
    pub best: f64,
}

/// In-memory observation trace behind a cloneable handle: subscribe one
/// clone to the run, read the rows from another after (or during) it.
/// The cross-frontend parity tests compare these traces bit-for-bit.
#[derive(Clone, Default)]
pub struct TraceHandle {
    rows: Arc<Mutex<Vec<TraceRow>>>,
}

impl TraceHandle {
    /// An empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of the rows recorded so far.
    pub fn rows(&self) -> Vec<TraceRow> {
        self.rows.lock().expect("trace lock").clone()
    }

    /// Rows recorded so far.
    pub fn len(&self) -> usize {
        self.rows.lock().expect("trace lock").len()
    }

    /// True before the first observation.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Observer for TraceHandle {
    fn on_event(&mut self, event: &BoEvent) {
        if let BoEvent::Observation { evaluations, x, y, best } = *event {
            self.rows
                .lock()
                .expect("trace lock")
                .push(TraceRow { evaluations, x: x.to_vec(), y, best });
        }
    }
}

/// JSON-lines event writer: one compact JSON object per [`BoEvent`],
/// flushed on [`BoEvent::Stopped`]. The machine-readable twin of
/// [`RunLogger`]'s TSV files, in the same rows-of-JSON shape the bench
/// pipeline (`benches/*.rs` → `BENCH_PR.json`) consumes.
pub struct JsonlObserver {
    out: BufWriter<File>,
}

impl JsonlObserver {
    /// Create (or truncate) the event log at `path`.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(Self { out: BufWriter::new(File::create(path)?) })
    }

    /// JSON-safe float: non-finite values (a `-inf` incumbent before
    /// any data, a NaN objective) become `null` — `inf`/`NaN` tokens
    /// would make the whole line unparseable.
    fn fmt_f64(v: f64) -> String {
        if v.is_finite() {
            format!("{v:.10e}")
        } else {
            "null".to_string()
        }
    }

    fn fmt_point(x: &[f64]) -> String {
        let vs: Vec<String> = x.iter().map(|&v| Self::fmt_f64(v)).collect();
        format!("[{}]", vs.join(","))
    }
}

impl Observer for JsonlObserver {
    fn on_event(&mut self, event: &BoEvent) {
        let _ = match *event {
            BoEvent::InitDone { n_samples } => {
                writeln!(self.out, r#"{{"event":"init_done","n_samples":{n_samples}}}"#)
            }
            BoEvent::Proposal { iteration, q, xs } => {
                let pts: Vec<String> = xs.iter().map(|x| Self::fmt_point(x)).collect();
                writeln!(
                    self.out,
                    r#"{{"event":"proposal","iteration":{iteration},"q":{q},"xs":[{}]}}"#,
                    pts.join(",")
                )
            }
            BoEvent::Observation { evaluations, x, y, best } => writeln!(
                self.out,
                r#"{{"event":"observation","evaluations":{evaluations},"x":{},"y":{},"best":{}}}"#,
                Self::fmt_point(x),
                Self::fmt_f64(y),
                Self::fmt_f64(best)
            ),
            BoEvent::Refit { n_samples } => {
                writeln!(self.out, r#"{{"event":"refit","n_samples":{n_samples}}}"#)
            }
            BoEvent::Stopped { dim, evaluations, best } => {
                let r = writeln!(
                    self.out,
                    r#"{{"event":"stopped","dim":{dim},"evaluations":{evaluations},"best":{}}}"#,
                    Self::fmt_f64(best)
                );
                let _ = self.out.flush();
                r
            }
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_all_files() {
        let dir = std::env::temp_dir().join("limbo_stat_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut log = RunLogger::create(&dir).unwrap();
        log.log_sample(0, &[0.1, 0.2], 1.5, 1.5);
        log.log_sample(1, &[0.3, 0.4], 0.5, 1.5);
        log.finish(2, 2);
        for f in ["samples.dat", "observations.dat", "best.dat", "meta.dat"] {
            let content = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(!content.is_empty(), "{f} should not be empty");
        }
        let best = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best.lines().count(), 2);
        let samples = std::fs::read_to_string(dir.join("samples.dat")).unwrap();
        assert!(samples.lines().next().unwrap().starts_with("0\t"));
    }

    #[test]
    fn run_logger_consumes_events() {
        let dir = std::env::temp_dir().join("limbo_stat_observer_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut log = RunLogger::create(&dir).unwrap();
        log.on_event(&BoEvent::Observation { evaluations: 1, x: &[0.4], y: 2.0, best: 2.0 });
        log.on_event(&BoEvent::Refit { n_samples: 1 }); // ignored
        log.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: 2.0 });
        let best = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best.lines().count(), 1);
        let meta = std::fs::read_to_string(dir.join("meta.dat")).unwrap();
        assert!(meta.contains("evaluations\t1"));
    }

    #[test]
    fn trace_handle_records_observations_only() {
        let trace = TraceHandle::new();
        let mut subscriber = trace.clone();
        assert!(trace.is_empty());
        subscriber.on_event(&BoEvent::InitDone { n_samples: 0 });
        subscriber.on_event(&BoEvent::Observation {
            evaluations: 1,
            x: &[0.5, 0.25],
            y: -1.0,
            best: -1.0,
        });
        subscriber.on_event(&BoEvent::Stopped { dim: 2, evaluations: 1, best: -1.0 });
        let rows = trace.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], TraceRow { evaluations: 1, x: vec![0.5, 0.25], y: -1.0, best: -1.0 });
    }

    #[test]
    fn jsonl_observer_writes_one_line_per_event() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_test/events.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut obs = JsonlObserver::create(&path).unwrap();
        let xs = vec![vec![0.5]];
        obs.on_event(&BoEvent::Proposal { iteration: 0, q: 1, xs: &xs });
        obs.on_event(&BoEvent::Observation { evaluations: 1, x: &[0.5], y: 1.0, best: 1.0 });
        obs.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: 1.0 });
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains(r#""event":"proposal""#));
        assert!(lines[1].contains(r#""event":"observation""#));
        assert!(lines[2].contains(r#""event":"stopped""#));
    }

    #[test]
    fn jsonl_observer_writes_null_for_non_finite_values() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_nonfinite/events.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut obs = JsonlObserver::create(&path).unwrap();
        obs.on_event(&BoEvent::Observation {
            evaluations: 1,
            x: &[0.5],
            y: f64::NAN,
            best: f64::NEG_INFINITY,
        });
        obs.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: f64::NEG_INFINITY });
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains(r#""y":null"#), "NaN must serialize as null: {content}");
        assert!(content.contains(r#""best":null"#), "-inf must serialize as null: {content}");
        assert!(!content.contains("inf") && !content.contains("NaN"), "{content}");
    }
}
