//! Run statistics writers — the `limbo::stat::*` policy family, as
//! observers on the [`BoCore`](crate::bayes_opt::BoCore) event bus.
//!
//! Every writer implements [`Observer`] and subscribes to the typed
//! [`BoEvent`] stream the core dispatches (`InitDone`, `Proposal`,
//! `Observation`, `Refit`, `Stopped`) — the loop never knows who is
//! listening:
//!
//! * [`RunLogger`] writes the standard Limbo run files (`samples.dat`,
//!   `observations.dat`, `best.dat`, `meta.dat`) into a run directory;
//! * [`JsonlObserver`] writes one JSON object per event — the
//!   machine-readable twin of the TSV traces, matching the bench
//!   pipeline's JSON-rows idiom;
//! * [`TraceHandle`] collects the observation trace in memory behind a
//!   cloneable handle (the cross-frontend parity tests compare these
//!   bit-for-bit);
//! * [`RecordingObserver`] captures the **full** event stream (plus
//!   per-generation inner-DE state via an embedded
//!   [`DeRecorder`](crate::opt::DeRecorder)) and can
//!   [`replay_into`](RecordingObserver::replay_into) a fresh
//!   identically-configured study — asks are verified bit-for-bit
//!   against the recording, so a convergence regression bisects to the
//!   first diverging proposal;
//! * [`MetricsObserver`] enables the [`crate::obs`] span registry for
//!   the run and writes its phase breakdown (where the milliseconds
//!   went: Cholesky vs. refit vs. acquisition) into `meta.dat` and
//!   `metrics.json` on stop — or on drop, so a panicking run still
//!   reports.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bayes_opt::core::{BoEvent, Observer};
use crate::bayes_opt::Observation;
use crate::coordinator::Study;
use crate::obs::{self, Counter, Phase};
use crate::opt::{DeGenRecord, DeRecorder};

/// TSV run logger; every write goes through buffered files flushed on
/// [`finish`](Self::finish) (and again on drop, so an early-dropped run
/// keeps the rows it logged). I/O errors are never silently swallowed:
/// they are counted in [`write_failures`](Self::write_failures), mirrored
/// to the process-wide [`Counter::StatWriteFailures`], and surfaced as a
/// `write_failures` line in `meta.dat`.
pub struct RunLogger {
    dir: PathBuf,
    samples: BufWriter<File>,
    observations: BufWriter<File>,
    best: BufWriter<File>,
    start: Instant,
    write_failures: u64,
}

impl RunLogger {
    /// Create (or truncate) the run files inside `dir`.
    pub fn create(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let open = |name: &str| -> std::io::Result<BufWriter<File>> {
            Ok(BufWriter::new(File::create(dir.join(name))?))
        };
        Ok(Self {
            dir: dir.to_path_buf(),
            samples: open("samples.dat")?,
            observations: open("observations.dat")?,
            best: open("best.dat")?,
            start: Instant::now(),
            write_failures: 0,
        })
    }

    /// Count one failed write locally and in the process-wide registry.
    fn check<T>(&mut self, r: std::io::Result<T>) {
        if r.is_err() {
            self.write_failures += 1;
            obs::counter_add(Counter::StatWriteFailures, 1);
        }
    }

    /// Writes that failed so far (buffered writes surface errors at
    /// flush time, so the final count is only in after
    /// [`finish`](Self::finish)).
    pub fn write_failures(&self) -> u64 {
        self.write_failures
    }

    /// Record one evaluation.
    pub fn log_sample(&mut self, iteration: usize, x: &[f64], y: f64, best: f64) {
        let xs: Vec<String> = x.iter().map(|v| format!("{v:.10e}")).collect();
        let r = writeln!(self.samples, "{iteration}\t{}", xs.join("\t"));
        self.check(r);
        let r = writeln!(self.observations, "{iteration}\t{y:.10e}");
        self.check(r);
        let r = writeln!(
            self.best,
            "{iteration}\t{best:.10e}\t{:.6}",
            self.start.elapsed().as_secs_f64()
        );
        self.check(r);
    }

    /// Write the run footer (`meta.dat`) and flush everything.
    pub fn finish(&mut self, dim: usize, total_evals: usize) {
        let r = self.samples.flush();
        self.check(r);
        let r = self.observations.flush();
        self.check(r);
        let r = self.best.flush();
        self.check(r);
        let elapsed = self.start.elapsed().as_secs_f64();
        let r = std::fs::write(
            self.dir.join("meta.dat"),
            format!(
                "dim\t{dim}\nevaluations\t{total_evals}\nwall_seconds\t{elapsed:.6}\n\
                 write_failures\t{}\n",
                self.write_failures
            ),
        );
        self.check(r);
    }

    /// The run directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl Drop for RunLogger {
    /// Flush the buffered rows so an early-dropped (or panicking) run
    /// keeps everything it logged, even if `finish` never ran.
    fn drop(&mut self) {
        let r = self.samples.flush();
        self.check(r);
        let r = self.observations.flush();
        self.check(r);
        let r = self.best.flush();
        self.check(r);
    }
}

impl Observer for RunLogger {
    fn on_event(&mut self, event: &BoEvent) {
        match *event {
            BoEvent::Observation { evaluations, x, y, best }
            | BoEvent::TellNoisy { evaluations, x, y, best, .. }
            | BoEvent::TellConstrained { evaluations, x, y, best, .. } => {
                self.log_sample(evaluations, x, y, best);
            }
            BoEvent::Stopped { dim, evaluations, .. } => self.finish(dim, evaluations),
            _ => {}
        }
    }
}

/// One recorded observation of a run (user coordinates).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRow {
    /// Total observations including this one.
    pub evaluations: usize,
    /// Evaluated point.
    pub x: Vec<f64>,
    /// Observed value.
    pub y: f64,
    /// Incumbent best after this observation.
    pub best: f64,
}

/// In-memory observation trace behind a cloneable handle: subscribe one
/// clone to the run, read the rows from another after (or during) it.
/// The cross-frontend parity tests compare these traces bit-for-bit.
#[derive(Clone, Default)]
pub struct TraceHandle {
    rows: Arc<Mutex<Vec<TraceRow>>>,
}

impl TraceHandle {
    /// An empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of the rows recorded so far.
    pub fn rows(&self) -> Vec<TraceRow> {
        self.rows.lock().expect("trace lock").clone()
    }

    /// Rows recorded so far.
    pub fn len(&self) -> usize {
        self.rows.lock().expect("trace lock").len()
    }

    /// True before the first observation.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Observer for TraceHandle {
    fn on_event(&mut self, event: &BoEvent) {
        match *event {
            BoEvent::Observation { evaluations, x, y, best }
            | BoEvent::TellNoisy { evaluations, x, y, best, .. }
            | BoEvent::TellConstrained { evaluations, x, y, best, .. } => {
                self.rows
                    .lock()
                    .expect("trace lock")
                    .push(TraceRow { evaluations, x: x.to_vec(), y, best });
            }
            _ => {}
        }
    }
}

/// JSON-lines event writer: one compact JSON object per [`BoEvent`],
/// flushed on [`BoEvent::Stopped`] **and on drop** — a run that is
/// dropped early (or unwinds out of a panicking evaluation) keeps every
/// buffered event. The machine-readable twin of [`RunLogger`]'s TSV
/// files, in the same rows-of-JSON shape the bench pipeline
/// (`benches/*.rs` → `BENCH_PR.json`) consumes. Write/flush errors are
/// counted in [`Counter::StatWriteFailures`].
pub struct JsonlObserver {
    out: BufWriter<File>,
}

impl JsonlObserver {
    /// Create (or truncate) the event log at `path`.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(Self { out: BufWriter::new(File::create(path)?) })
    }

    /// Open the event log at `path` in append mode — the continuation
    /// writer for a rehydrated study: replayed history stays in the
    /// file, new events extend it, and the log remains one contiguous
    /// record across crash/recover cycles.
    pub fn append(path: &Path) -> std::io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::OpenOptions::new().append(true).create(true).open(path)?;
        Ok(Self { out: BufWriter::new(file) })
    }

    fn flush_counting(&mut self) {
        if self.out.flush().is_err() {
            obs::counter_add(Counter::StatWriteFailures, 1);
        }
    }

    /// JSON-safe float: non-finite values (a `-inf` incumbent before
    /// any data, a NaN objective) become `null` — `inf`/`NaN` tokens
    /// would make the whole line unparseable. 17 significant digits
    /// round-trip every finite `f64` exactly, so a replayed event log
    /// reproduces the run bit-for-bit.
    fn fmt_f64(v: f64) -> String {
        if v.is_finite() {
            format!("{v:.17e}")
        } else {
            "null".to_string()
        }
    }

    fn fmt_point(x: &[f64]) -> String {
        let vs: Vec<String> = x.iter().map(|&v| Self::fmt_f64(v)).collect();
        format!("[{}]", vs.join(","))
    }
}

impl Drop for JsonlObserver {
    /// Flush buffered events so a run that never emitted
    /// [`BoEvent::Stopped`] (early drop, panic unwind, caller bug) still
    /// keeps everything it was told about.
    fn drop(&mut self) {
        self.flush_counting();
    }
}

impl Observer for JsonlObserver {
    fn on_event(&mut self, event: &BoEvent) {
        let r = match *event {
            BoEvent::InitDone { n_samples } => {
                writeln!(self.out, r#"{{"event":"init_done","n_samples":{n_samples}}}"#)
            }
            BoEvent::Proposal { iteration, q, xs } => {
                let pts: Vec<String> = xs.iter().map(|x| Self::fmt_point(x)).collect();
                writeln!(
                    self.out,
                    r#"{{"event":"proposal","iteration":{iteration},"q":{q},"xs":[{}]}}"#,
                    pts.join(",")
                )
            }
            BoEvent::Observation { evaluations, x, y, best } => writeln!(
                self.out,
                r#"{{"event":"observation","evaluations":{evaluations},"x":{},"y":{},"best":{}}}"#,
                Self::fmt_point(x),
                Self::fmt_f64(y),
                Self::fmt_f64(best)
            ),
            BoEvent::TellNoisy { evaluations, x, y, noise, best } => writeln!(
                self.out,
                concat!(
                    r#"{{"event":"tell_noisy","evaluations":{},"x":{},"#,
                    r#""y":{},"noise":{},"best":{}}}"#
                ),
                evaluations,
                Self::fmt_point(x),
                Self::fmt_f64(y),
                Self::fmt_f64(noise),
                Self::fmt_f64(best)
            ),
            BoEvent::TellConstrained { evaluations, x, y, noise, constraints, best } => writeln!(
                self.out,
                concat!(
                    r#"{{"event":"tell_constrained","evaluations":{},"x":{},"#,
                    r#""y":{},"noise":{},"constraints":{},"best":{}}}"#
                ),
                evaluations,
                Self::fmt_point(x),
                Self::fmt_f64(y),
                match noise {
                    Some(nv) => Self::fmt_f64(nv),
                    None => "null".to_string(),
                },
                Self::fmt_point(constraints),
                Self::fmt_f64(best)
            ),
            BoEvent::AskPending { iteration, x } => writeln!(
                self.out,
                r#"{{"event":"ask_pending","iteration":{iteration},"x":{}}}"#,
                Self::fmt_point(x)
            ),
            BoEvent::Refit { n_samples } => {
                writeln!(self.out, r#"{{"event":"refit","n_samples":{n_samples}}}"#)
            }
            BoEvent::Stopped { dim, evaluations, best } => {
                let r = writeln!(
                    self.out,
                    r#"{{"event":"stopped","dim":{dim},"evaluations":{evaluations},"best":{}}}"#,
                    Self::fmt_f64(best)
                );
                self.flush_counting();
                r
            }
        };
        if r.is_err() {
            obs::counter_add(Counter::StatWriteFailures, 1);
        }
    }
}

/// An owned, parsed [`BoEvent`] read back from a [`JsonlObserver`] log —
/// the replay side of study event sourcing. `null` floats (non-finite
/// values at write time) come back as NaN.
#[derive(Clone, Debug, PartialEq)]
pub enum ReplayEvent {
    /// `{"event":"init_done",...}`
    InitDone {
        /// Observations in the model at that point.
        n_samples: usize,
    },
    /// `{"event":"proposal",...}`
    Proposal {
        /// Model-guided iteration counter at proposal time.
        iteration: usize,
        /// Number of points proposed.
        q: usize,
        /// The proposed points.
        xs: Vec<Vec<f64>>,
    },
    /// `{"event":"observation",...}`
    Observation {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: Vec<f64>,
        /// Observed value.
        y: f64,
        /// Incumbent best after this observation.
        best: f64,
    },
    /// `{"event":"tell_noisy",...}`
    TellNoisy {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: Vec<f64>,
        /// Observed value.
        y: f64,
        /// Per-observation noise variance (finite, `> 0`).
        noise: f64,
        /// Incumbent best after this observation.
        best: f64,
    },
    /// `{"event":"tell_constrained",...}`
    TellConstrained {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: Vec<f64>,
        /// Observed objective value.
        y: f64,
        /// Per-observation noise variance, if the tell was also noisy.
        noise: Option<f64>,
        /// Constraint-channel values (`>= 0` = feasible).
        constraints: Vec<f64>,
        /// Incumbent best after this observation.
        best: f64,
    },
    /// `{"event":"ask_pending",...}` — audit record of an asynchronous
    /// pending registration; replay re-derives it from the proposal.
    AskPending {
        /// Iteration counter at proposal time.
        iteration: usize,
        /// The pending point.
        x: Vec<f64>,
    },
    /// `{"event":"refit",...}`
    Refit {
        /// Observations in the model at refit time.
        n_samples: usize,
    },
    /// `{"event":"stopped",...}`
    Stopped {
        /// Problem dimensionality.
        dim: usize,
        /// Total observations.
        evaluations: usize,
        /// Final incumbent best.
        best: f64,
    },
}

/// Raw text of JSON field `key` in `line` (a single flat object as
/// written by [`JsonlObserver`]): everything after `"key":` up to the
/// value's end — bracket-matched for arrays, comma/brace-delimited for
/// scalars.
fn json_field<'a>(line: &'a str, key: &str) -> Result<&'a str, String> {
    let needle = format!("\"{key}\":");
    let start = line.find(&needle).ok_or_else(|| format!("missing field {key:?} in {line:?}"))?
        + needle.len();
    let rest = &line[start..];
    if rest.starts_with('[') {
        let mut depth = 0usize;
        for (i, c) in rest.char_indices() {
            match c {
                '[' => depth += 1,
                ']' => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(&rest[..=i]);
                    }
                }
                _ => {}
            }
        }
        Err(format!("unterminated array for {key:?} in {line:?}"))
    } else if let Some(s) = rest.strip_prefix('"') {
        let end = s.find('"').ok_or_else(|| format!("unterminated string for {key:?}"))?;
        Ok(&s[..end])
    } else {
        let end = rest.find([',', '}']).unwrap_or(rest.len());
        Ok(rest[..end].trim())
    }
}

/// Parse a scalar float field (`null` → NaN).
fn json_f64(line: &str, key: &str) -> Result<f64, String> {
    let raw = json_field(line, key)?;
    if raw == "null" {
        return Ok(f64::NAN);
    }
    raw.parse::<f64>().map_err(|e| format!("bad float {raw:?} for {key:?}: {e}"))
}

/// Parse an unsigned integer field.
fn json_usize(line: &str, key: &str) -> Result<usize, String> {
    let raw = json_field(line, key)?;
    raw.parse::<usize>().map_err(|e| format!("bad integer {raw:?} for {key:?}: {e}"))
}

/// Parse a flat float array field `[a,b,...]` (`null` entries → NaN).
fn json_point(raw: &str) -> Result<Vec<f64>, String> {
    let inner = raw
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("not an array: {raw:?}"))?;
    if inner.trim().is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|v| {
            let v = v.trim();
            if v == "null" {
                Ok(f64::NAN)
            } else {
                v.parse::<f64>().map_err(|e| format!("bad float {v:?}: {e}"))
            }
        })
        .collect()
}

impl ReplayEvent {
    /// Owned copy of a live bus event — the capture side of
    /// [`RecordingObserver`].
    pub fn from_event(event: &BoEvent) -> Self {
        match *event {
            BoEvent::InitDone { n_samples } => ReplayEvent::InitDone { n_samples },
            BoEvent::Proposal { iteration, q, xs } => {
                ReplayEvent::Proposal { iteration, q, xs: xs.to_vec() }
            }
            BoEvent::Observation { evaluations, x, y, best } => {
                ReplayEvent::Observation { evaluations, x: x.to_vec(), y, best }
            }
            BoEvent::TellNoisy { evaluations, x, y, noise, best } => {
                ReplayEvent::TellNoisy { evaluations, x: x.to_vec(), y, noise, best }
            }
            BoEvent::TellConstrained { evaluations, x, y, noise, constraints, best } => {
                ReplayEvent::TellConstrained {
                    evaluations,
                    x: x.to_vec(),
                    y,
                    noise,
                    constraints: constraints.to_vec(),
                    best,
                }
            }
            BoEvent::AskPending { iteration, x } => {
                ReplayEvent::AskPending { iteration, x: x.to_vec() }
            }
            BoEvent::Refit { n_samples } => ReplayEvent::Refit { n_samples },
            BoEvent::Stopped { dim, evaluations, best } => {
                ReplayEvent::Stopped { dim, evaluations, best }
            }
        }
    }

    /// Serialize back to the exact [`JsonlObserver`] line format (17
    /// significant digits, non-finite floats as `null`), so a saved
    /// recording and a live event log are interchangeable inputs to
    /// [`read_log`](Self::read_log). Pinned against the writer in the
    /// module tests — the two formats must never drift.
    pub fn to_json_line(&self) -> String {
        let f = JsonlObserver::fmt_f64;
        let pt = JsonlObserver::fmt_point;
        match self {
            ReplayEvent::InitDone { n_samples } => {
                format!(r#"{{"event":"init_done","n_samples":{n_samples}}}"#)
            }
            ReplayEvent::Proposal { iteration, q, xs } => {
                let pts: Vec<String> = xs.iter().map(|x| pt(x)).collect();
                format!(
                    r#"{{"event":"proposal","iteration":{iteration},"q":{q},"xs":[{}]}}"#,
                    pts.join(",")
                )
            }
            ReplayEvent::Observation { evaluations, x, y, best } => format!(
                r#"{{"event":"observation","evaluations":{evaluations},"x":{},"y":{},"best":{}}}"#,
                pt(x),
                f(*y),
                f(*best)
            ),
            ReplayEvent::TellNoisy { evaluations, x, y, noise, best } => format!(
                concat!(
                    r#"{{"event":"tell_noisy","evaluations":{},"x":{},"#,
                    r#""y":{},"noise":{},"best":{}}}"#
                ),
                evaluations,
                pt(x),
                f(*y),
                f(*noise),
                f(*best)
            ),
            ReplayEvent::TellConstrained { evaluations, x, y, noise, constraints, best } => {
                format!(
                    concat!(
                        r#"{{"event":"tell_constrained","evaluations":{},"x":{},"#,
                        r#""y":{},"noise":{},"constraints":{},"best":{}}}"#
                    ),
                    evaluations,
                    pt(x),
                    f(*y),
                    match noise {
                        Some(nv) => f(*nv),
                        None => "null".to_string(),
                    },
                    pt(constraints),
                    f(*best)
                )
            }
            ReplayEvent::AskPending { iteration, x } => {
                format!(r#"{{"event":"ask_pending","iteration":{iteration},"x":{}}}"#, pt(x))
            }
            ReplayEvent::Refit { n_samples } => {
                format!(r#"{{"event":"refit","n_samples":{n_samples}}}"#)
            }
            ReplayEvent::Stopped { dim, evaluations, best } => format!(
                r#"{{"event":"stopped","dim":{dim},"evaluations":{evaluations},"best":{}}}"#,
                f(*best)
            ),
        }
    }

    /// Parse one [`JsonlObserver`] line.
    pub fn parse_line(line: &str) -> Result<Self, String> {
        match json_field(line, "event")? {
            "init_done" => Ok(ReplayEvent::InitDone { n_samples: json_usize(line, "n_samples")? }),
            "proposal" => {
                let raw = json_field(line, "xs")?;
                let inner = raw
                    .strip_prefix('[')
                    .and_then(|s| s.strip_suffix(']'))
                    .ok_or_else(|| format!("bad xs in {line:?}"))?;
                // split the outer array on top-level commas
                let mut xs = Vec::new();
                let mut depth = 0usize;
                let mut start = 0usize;
                for (i, c) in inner.char_indices() {
                    match c {
                        '[' => depth += 1,
                        ']' => depth -= 1,
                        ',' if depth == 0 => {
                            xs.push(json_point(inner[start..i].trim())?);
                            start = i + 1;
                        }
                        _ => {}
                    }
                }
                if !inner.trim().is_empty() {
                    xs.push(json_point(inner[start..].trim())?);
                }
                Ok(ReplayEvent::Proposal {
                    iteration: json_usize(line, "iteration")?,
                    q: json_usize(line, "q")?,
                    xs,
                })
            }
            "observation" => Ok(ReplayEvent::Observation {
                evaluations: json_usize(line, "evaluations")?,
                x: json_point(json_field(line, "x")?)?,
                y: json_f64(line, "y")?,
                best: json_f64(line, "best")?,
            }),
            "tell_noisy" => Ok(ReplayEvent::TellNoisy {
                evaluations: json_usize(line, "evaluations")?,
                x: json_point(json_field(line, "x")?)?,
                y: json_f64(line, "y")?,
                noise: json_f64(line, "noise")?,
                best: json_f64(line, "best")?,
            }),
            "tell_constrained" => {
                // noise is Option on the write side; `null` (NaN after
                // json_f64) means the tell carried no noise
                let noise = json_f64(line, "noise")?;
                Ok(ReplayEvent::TellConstrained {
                    evaluations: json_usize(line, "evaluations")?,
                    x: json_point(json_field(line, "x")?)?,
                    y: json_f64(line, "y")?,
                    noise: if noise.is_nan() { None } else { Some(noise) },
                    constraints: json_point(json_field(line, "constraints")?)?,
                    best: json_f64(line, "best")?,
                })
            }
            "ask_pending" => Ok(ReplayEvent::AskPending {
                iteration: json_usize(line, "iteration")?,
                x: json_point(json_field(line, "x")?)?,
            }),
            "refit" => Ok(ReplayEvent::Refit { n_samples: json_usize(line, "n_samples")? }),
            "stopped" => Ok(ReplayEvent::Stopped {
                dim: json_usize(line, "dim")?,
                evaluations: json_usize(line, "evaluations")?,
                best: json_f64(line, "best")?,
            }),
            other => Err(format!("unknown event {other:?} in {line:?}")),
        }
    }

    /// Read every event from a [`JsonlObserver`] log file (empty lines
    /// skipped). A missing file is an error; an empty file is `Ok(vec![])`.
    ///
    /// A crash mid-append can tear only the **final** line, so an
    /// unparseable last record is skipped (counted in
    /// [`Counter::ReplayTornLines`] with a warning on stderr) rather
    /// than failing the whole log — that record was never acknowledged
    /// to anyone. An unparseable line anywhere *else* is genuine
    /// corruption and still fails.
    pub fn read_log(path: &Path) -> Result<Vec<ReplayEvent>, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let lines: Vec<&str> = text.lines().map(str::trim).filter(|l| !l.is_empty()).collect();
        let mut events = Vec::with_capacity(lines.len());
        for (i, line) in lines.iter().enumerate() {
            match Self::parse_line(line) {
                Ok(event) => events.push(event),
                Err(e) if i + 1 == lines.len() => {
                    obs::counter_add(Counter::ReplayTornLines, 1);
                    eprintln!("warning: {}: skipping torn final line: {e}", path.display());
                }
                Err(e) => return Err(e),
            }
        }
        Ok(events)
    }
}

/// Full-run capture + deterministic replay, behind a cloneable handle.
///
/// Subscribe one clone to a run (`BoDef::observer(rec.clone())`) and it
/// records **every** [`BoEvent`] as an owned [`ReplayEvent`] — not just
/// the observation trace [`TraceHandle`] keeps. It also carries a
/// [`DeRecorder`]: pass [`de_recorder`](Self::de_recorder) to
/// [`AdaptiveDe::with_recorder`](crate::opt::AdaptiveDe::with_recorder)
/// and the per-generation inner-DE state (population size, best, mean
/// F/CR) lands in the same capture.
///
/// The capture replays through the **live** code path:
/// [`replay_into`](Self::replay_into) drives a fresh,
/// identically-configured [`Study`] through the recorded
/// proposal/observation sequence, verifying each re-asked point
/// bit-for-bit against the recording — the first diverging proposal is
/// reported with its iteration, which is what makes a convergence
/// regression bisectable. [`save`](Self::save)/[`load`](Self::load)
/// round-trip the capture through the [`JsonlObserver`] line format at
/// 17 significant digits, so recordings survive on disk without losing
/// a bit.
///
/// Recording never touches the RNG or the floating-point evaluation
/// order, so runs are bit-identical with or without a recorder
/// attached.
#[derive(Clone, Default)]
pub struct RecordingObserver {
    events: Arc<Mutex<Vec<ReplayEvent>>>,
    de: DeRecorder,
}

impl RecordingObserver {
    /// An empty recording.
    pub fn new() -> Self {
        Self::default()
    }

    /// A recording pre-loaded from a [`JsonlObserver`]-format log file
    /// (e.g. one written by [`save`](Self::save) or by a live
    /// `JsonlObserver`).
    pub fn load(path: &Path) -> Result<Self, String> {
        let events = ReplayEvent::read_log(path)?;
        let rec = Self::new();
        *rec.events.lock().expect("recording lock") = events;
        Ok(rec)
    }

    /// Snapshot of the events recorded so far.
    pub fn events(&self) -> Vec<ReplayEvent> {
        self.events.lock().expect("recording lock").clone()
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        self.events.lock().expect("recording lock").len()
    }

    /// True before the first event.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all recorded events and DE rows (reuse one handle across
    /// runs).
    pub fn clear(&self) {
        self.events.lock().expect("recording lock").clear();
        self.de.clear();
    }

    /// The embedded per-generation DE sink — hand a clone to
    /// [`AdaptiveDe::with_recorder`](crate::opt::AdaptiveDe::with_recorder).
    pub fn de_recorder(&self) -> DeRecorder {
        self.de.clone()
    }

    /// Per-generation DE rows captured so far.
    pub fn de_rows(&self) -> Vec<DeGenRecord> {
        self.de.rows()
    }

    /// Write the capture as a [`JsonlObserver`]-format log (one event
    /// per line, bit-exact floats) — readable back via
    /// [`load`](Self::load) or [`ReplayEvent::read_log`].
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        for event in self.events().iter() {
            writeln!(out, "{}", event.to_json_line())?;
        }
        out.flush()
    }

    /// Drive `study` (a fresh, identically-configured one) through the
    /// recorded run. Each recorded proposal is re-asked and compared
    /// **bit-for-bit**; each recorded observation is re-told with the
    /// recorded value; `Stopped` finishes the study. `Refit`, `InitDone`
    /// and `AskPending` records are skipped — the study re-derives them
    /// (attach another `RecordingObserver` to the replay study and
    /// compare captures to verify those too).
    ///
    /// `Err` carries the first divergence or study error, naming the
    /// event index and iteration — the bisection point.
    pub fn replay_into<S: Study + ?Sized>(&self, study: &mut S) -> Result<(), String> {
        let events = self.events();
        for (idx, event) in events.iter().enumerate() {
            match event {
                ReplayEvent::Proposal { iteration, q, xs } => {
                    let got: Vec<Vec<f64>> = if *q == 1 {
                        vec![study
                            .ask()
                            .map_err(|e| format!("replay ask at event {idx}: {e:?}"))?]
                    } else {
                        study
                            .ask_batch(*q)
                            .map_err(|e| format!("replay ask_batch at event {idx}: {e:?}"))?
                    };
                    for (k, (g, r)) in got.iter().zip(xs.iter()).enumerate() {
                        let same = g.len() == r.len()
                            && g.iter().zip(r).all(|(a, b)| a.to_bits() == b.to_bits());
                        if !same {
                            return Err(format!(
                                "replay diverged at event {idx} (iteration {iteration}, \
                                 point {k}): recorded {r:?}, got {g:?}"
                            ));
                        }
                    }
                }
                ReplayEvent::Observation { x, y, .. } => {
                    study
                        .tell(x, *y)
                        .map_err(|e| format!("replay tell at event {idx}: {e:?}"))?;
                }
                ReplayEvent::TellNoisy { x, y, noise, .. } => {
                    study
                        .tell_noisy(x, *y, *noise)
                        .map_err(|e| format!("replay tell_noisy at event {idx}: {e:?}"))?;
                }
                ReplayEvent::TellConstrained { x, y, noise, constraints, .. } => {
                    let record = match noise {
                        Some(nv) => Observation::noisy(x.clone(), *y, *nv),
                        None => Observation::exact(x.clone(), *y),
                    }
                    .with_constraints(constraints.clone());
                    study
                        .tell_observation(record)
                        .map_err(|e| format!("replay tell_constrained at event {idx}: {e:?}"))?;
                }
                ReplayEvent::Stopped { .. } => {
                    study
                        .finish()
                        .map_err(|e| format!("replay finish at event {idx}: {e:?}"))?;
                }
                ReplayEvent::InitDone { .. }
                | ReplayEvent::Refit { .. }
                | ReplayEvent::AskPending { .. } => {}
            }
        }
        Ok(())
    }
}

impl Observer for RecordingObserver {
    fn on_event(&mut self, event: &BoEvent) {
        self.events.lock().expect("recording lock").push(ReplayEvent::from_event(event));
    }
}

/// Event-bus observer that profiles a run with the [`crate::obs`] span
/// registry and reports where the milliseconds went.
///
/// On creation it enables metrics collection process-wide and takes a
/// base snapshot; on [`BoEvent::Stopped`] (or on drop, if the run never
/// stopped cleanly) it computes the delta over the run and writes
///
/// * a phase breakdown appended to `meta.dat` (`phase.<name>.seconds`,
///   `phase.<name>.calls`, `counter.<name>` TSV lines, plus
///   `phase_wall_seconds` / `phase_service_seconds` / `phase_coverage`);
/// * `metrics.json` — the full [`obs::Snapshot::to_json`] document
///   wrapped with wall-clock and coverage, for machine consumption
///   (Prometheus text exposition is available via
///   [`obs::Snapshot::to_prometheus`]).
///
/// Subscribe it **after** [`RunLogger`]: `RunLogger::finish` truncates
/// `meta.dat` when it handles `Stopped`, and observers run in
/// subscription order, so the phase lines must land second.
///
/// Timing never touches RNG draws or floating-point evaluation order, so
/// runs are bit-identical with and without a `MetricsObserver` attached
/// (enforced by `tests/api_parity.rs`).
pub struct MetricsObserver {
    dir: PathBuf,
    base: obs::Snapshot,
    start: Instant,
    written: bool,
}

impl MetricsObserver {
    /// Enable metrics collection and start profiling now; reports are
    /// written into `dir` when the run stops.
    pub fn create(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        obs::set_enabled(true);
        Ok(Self {
            dir: dir.to_path_buf(),
            base: obs::snapshot(),
            start: Instant::now(),
            written: false,
        })
    }

    /// The run's phase activity so far (delta over the base snapshot).
    pub fn delta(&self) -> obs::Snapshot {
        obs::snapshot().delta_since(&self.base)
    }

    fn write_reports(&mut self) {
        if self.written {
            return;
        }
        self.written = true;
        let delta = self.delta();
        let wall = self.start.elapsed().as_secs_f64();
        let service = delta.service_seconds();
        let coverage = if wall > 0.0 { service / wall } else { 0.0 };

        let mut lines = String::new();
        lines.push_str(&format!("phase_wall_seconds\t{wall:.6}\n"));
        lines.push_str(&format!("phase_service_seconds\t{service:.6}\n"));
        lines.push_str(&format!("phase_coverage\t{coverage:.4}\n"));
        for p in Phase::ALL {
            let calls = delta.calls(p);
            if calls == 0 {
                continue;
            }
            lines.push_str(&format!(
                "phase.{}.seconds\t{:.6}\n",
                p.name(),
                delta.seconds(p)
            ));
            lines.push_str(&format!("phase.{}.calls\t{calls}\n", p.name()));
        }
        for c in Counter::ALL {
            let v = delta.counter(c);
            if v == 0 {
                continue;
            }
            lines.push_str(&format!("counter.{}\t{v}\n", c.name()));
        }
        let appended = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(self.dir.join("meta.dat"))
            .and_then(|mut f| f.write_all(lines.as_bytes()));
        if appended.is_err() {
            obs::counter_add(Counter::StatWriteFailures, 1);
        }

        let json = format!(
            "{{\"wall_seconds\":{wall:.6},\"service_seconds\":{service:.6},\
             \"coverage\":{coverage:.4},\"metrics\":{}}}\n",
            delta.to_json()
        );
        if std::fs::write(self.dir.join("metrics.json"), json).is_err() {
            obs::counter_add(Counter::StatWriteFailures, 1);
        }
    }
}

impl Observer for MetricsObserver {
    fn on_event(&mut self, event: &BoEvent) {
        if let BoEvent::Stopped { .. } = *event {
            self.write_reports();
        }
    }
}

impl Drop for MetricsObserver {
    /// A run that panicked or was dropped mid-flight still reports the
    /// phases it ran.
    fn drop(&mut self) {
        self.write_reports();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_all_files() {
        let dir = std::env::temp_dir().join("limbo_stat_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut log = RunLogger::create(&dir).unwrap();
        log.log_sample(0, &[0.1, 0.2], 1.5, 1.5);
        log.log_sample(1, &[0.3, 0.4], 0.5, 1.5);
        log.finish(2, 2);
        for f in ["samples.dat", "observations.dat", "best.dat", "meta.dat"] {
            let content = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(!content.is_empty(), "{f} should not be empty");
        }
        let best = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best.lines().count(), 2);
        let samples = std::fs::read_to_string(dir.join("samples.dat")).unwrap();
        assert!(samples.lines().next().unwrap().starts_with("0\t"));
    }

    #[test]
    fn run_logger_consumes_events() {
        let dir = std::env::temp_dir().join("limbo_stat_observer_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut log = RunLogger::create(&dir).unwrap();
        log.on_event(&BoEvent::Observation { evaluations: 1, x: &[0.4], y: 2.0, best: 2.0 });
        log.on_event(&BoEvent::Refit { n_samples: 1 }); // ignored
        log.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: 2.0 });
        let best = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best.lines().count(), 1);
        let meta = std::fs::read_to_string(dir.join("meta.dat")).unwrap();
        assert!(meta.contains("evaluations\t1"));
        assert!(meta.contains("write_failures\t0"), "{meta}");
    }

    #[test]
    fn run_logger_counts_write_failures_instead_of_swallowing() {
        let dir = std::env::temp_dir().join("limbo_stat_failure_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut log = RunLogger::create(&dir).unwrap();
        log.log_sample(0, &[0.1], 1.0, 1.0);
        // Yank the directory out from under the logger: the buffered
        // row files stay writable (unlinked-but-open), but `finish`'s
        // meta.dat creation must fail — and be counted, not swallowed.
        std::fs::remove_dir_all(&dir).unwrap();
        let base = obs::snapshot();
        log.finish(1, 1);
        assert!(log.write_failures() >= 1);
        let delta = obs::snapshot().delta_since(&base);
        assert!(
            delta.counter(Counter::StatWriteFailures) >= 1,
            "failure must reach the process-wide registry"
        );
    }

    /// Regression for the `let _ = flush()` swallow: `/dev/full` opens
    /// fine but every flush fails with ENOSPC, which must land in
    /// [`Counter::StatWriteFailures`].
    #[cfg(target_os = "linux")]
    #[test]
    fn jsonl_observer_counts_enospc_on_flush() {
        let base = obs::snapshot();
        let mut writer = JsonlObserver::create(Path::new("/dev/full")).unwrap();
        writer.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: 0.0 });
        drop(writer);
        let delta = obs::snapshot().delta_since(&base);
        assert!(delta.counter(Counter::StatWriteFailures) >= 1);
    }

    #[test]
    fn trace_handle_records_observations_only() {
        let trace = TraceHandle::new();
        let mut subscriber = trace.clone();
        assert!(trace.is_empty());
        subscriber.on_event(&BoEvent::InitDone { n_samples: 0 });
        subscriber.on_event(&BoEvent::Observation {
            evaluations: 1,
            x: &[0.5, 0.25],
            y: -1.0,
            best: -1.0,
        });
        subscriber.on_event(&BoEvent::Stopped { dim: 2, evaluations: 1, best: -1.0 });
        let rows = trace.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], TraceRow { evaluations: 1, x: vec![0.5, 0.25], y: -1.0, best: -1.0 });
    }

    #[test]
    fn jsonl_observer_writes_one_line_per_event() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_test/events.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut obs = JsonlObserver::create(&path).unwrap();
        let xs = vec![vec![0.5]];
        obs.on_event(&BoEvent::Proposal { iteration: 0, q: 1, xs: &xs });
        obs.on_event(&BoEvent::Observation { evaluations: 1, x: &[0.5], y: 1.0, best: 1.0 });
        obs.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: 1.0 });
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains(r#""event":"proposal""#));
        assert!(lines[1].contains(r#""event":"observation""#));
        assert!(lines[2].contains(r#""event":"stopped""#));
    }

    #[test]
    fn jsonl_observer_writes_null_for_non_finite_values() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_nonfinite/events.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut obs = JsonlObserver::create(&path).unwrap();
        obs.on_event(&BoEvent::Observation {
            evaluations: 1,
            x: &[0.5],
            y: f64::NAN,
            best: f64::NEG_INFINITY,
        });
        obs.on_event(&BoEvent::Stopped { dim: 1, evaluations: 1, best: f64::NEG_INFINITY });
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains(r#""y":null"#), "NaN must serialize as null: {content}");
        assert!(content.contains(r#""best":null"#), "-inf must serialize as null: {content}");
        assert!(!content.contains("inf") && !content.contains("NaN"), "{content}");
    }

    /// Regression: events logged before an early drop (no `Stopped`)
    /// must survive — `Drop` flushes the buffer.
    #[test]
    fn jsonl_observer_flushes_buffered_events_on_drop() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_drop/events.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut writer = JsonlObserver::create(&path).unwrap();
            writer.on_event(&BoEvent::InitDone { n_samples: 3 });
            writer.on_event(&BoEvent::Refit { n_samples: 3 });
            // dropped here without ever seeing BoEvent::Stopped
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content.lines().count(), 2, "buffered events lost on drop: {content}");
    }

    /// The write → parse round-trip is exact: `.17e` floats reparse to
    /// the identical bits, so an event log is a faithful replay source.
    #[test]
    fn replay_round_trip_is_bit_exact() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_replay/events.jsonl");
        let _ = std::fs::remove_file(&path);
        let y = 0.123456789012345678_f64.sin() * 1e-7;
        let best = -std::f64::consts::PI;
        let xs = vec![vec![0.1 + 0.2, 1.0 / 3.0], vec![f64::MIN_POSITIVE, 0.9999999999999999]];
        {
            let mut writer = JsonlObserver::create(&path).unwrap();
            writer.on_event(&BoEvent::Proposal { iteration: 3, q: 2, xs: &xs });
            writer.on_event(&BoEvent::Observation { evaluations: 4, x: &xs[0], y, best });
            writer.on_event(&BoEvent::InitDone { n_samples: 4 });
            writer.on_event(&BoEvent::Refit { n_samples: 4 });
            writer.on_event(&BoEvent::Stopped { dim: 2, evaluations: 4, best });
        }
        let events = ReplayEvent::read_log(&path).unwrap();
        assert_eq!(events.len(), 5);
        assert_eq!(events[0], ReplayEvent::Proposal { iteration: 3, q: 2, xs: xs.clone() });
        match &events[1] {
            ReplayEvent::Observation { evaluations, x, y: ry, best: rb } => {
                assert_eq!(*evaluations, 4);
                assert_eq!(
                    x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    xs[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
                assert_eq!(ry.to_bits(), y.to_bits(), "y must round-trip bitwise");
                assert_eq!(rb.to_bits(), best.to_bits(), "best must round-trip bitwise");
            }
            other => panic!("expected observation, got {other:?}"),
        }
        assert_eq!(events[2], ReplayEvent::InitDone { n_samples: 4 });
        assert_eq!(events[3], ReplayEvent::Refit { n_samples: 4 });
        match &events[4] {
            ReplayEvent::Stopped { dim, evaluations, best: rb } => {
                assert_eq!((*dim, *evaluations), (2, 4));
                assert_eq!(rb.to_bits(), best.to_bits());
            }
            other => panic!("expected stopped, got {other:?}"),
        }
    }

    /// The generalized-tell events round-trip through write → parse with
    /// bit-exact floats, like the classic observation does.
    #[test]
    fn noisy_constrained_and_pending_events_round_trip() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_general/events.jsonl");
        let _ = std::fs::remove_file(&path);
        let x = vec![0.1 + 0.2, 1.0 / 7.0];
        let cs = vec![0.16 - 0.01, -1e-9];
        {
            let mut writer = JsonlObserver::create(&path).unwrap();
            writer.on_event(&BoEvent::AskPending { iteration: 2, x: &x });
            writer.on_event(&BoEvent::TellNoisy {
                evaluations: 3,
                x: &x,
                y: -0.25,
                noise: 0.09,
                best: -0.25,
            });
            writer.on_event(&BoEvent::TellConstrained {
                evaluations: 4,
                x: &x,
                y: 1.5,
                noise: None,
                constraints: &cs,
                best: -0.25,
            });
            writer.on_event(&BoEvent::TellConstrained {
                evaluations: 5,
                x: &x,
                y: 2.5,
                noise: Some(0.04),
                constraints: &cs,
                best: -0.25,
            });
        }
        let events = ReplayEvent::read_log(&path).unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0], ReplayEvent::AskPending { iteration: 2, x: x.clone() });
        match &events[1] {
            ReplayEvent::TellNoisy { evaluations, x: rx, y, noise, best } => {
                assert_eq!(*evaluations, 3);
                assert_eq!(
                    rx.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
                assert_eq!((y.to_bits(), noise.to_bits(), best.to_bits()), {
                    ((-0.25f64).to_bits(), 0.09f64.to_bits(), (-0.25f64).to_bits())
                });
            }
            other => panic!("expected tell_noisy, got {other:?}"),
        }
        match &events[2] {
            ReplayEvent::TellConstrained { noise, constraints, .. } => {
                assert_eq!(*noise, None, "null noise must parse back to None");
                assert_eq!(
                    constraints.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    cs.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
            other => panic!("expected tell_constrained, got {other:?}"),
        }
        match &events[3] {
            ReplayEvent::TellConstrained { noise, .. } => {
                assert_eq!(noise.map(f64::to_bits), Some(0.04f64.to_bits()));
            }
            other => panic!("expected tell_constrained, got {other:?}"),
        }
    }

    /// Satellite: a crash mid-append tears only the final line — replay
    /// must skip it (counted), while mid-file garbage still fails.
    #[test]
    fn read_log_skips_a_torn_final_line_but_fails_mid_file() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_torn/events.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut writer = JsonlObserver::create(&path).unwrap();
            writer.on_event(&BoEvent::InitDone { n_samples: 2 });
            writer.on_event(&BoEvent::Observation {
                evaluations: 1,
                x: &[0.25],
                y: -0.5,
                best: -0.5,
            });
        }
        let full = std::fs::read_to_string(&path).unwrap();
        // tear inside the final record's x array, mid-float
        let cut = full.rfind("\"x\":[").unwrap() + 7;
        std::fs::write(&path, &full[..cut]).unwrap();
        let base = obs::snapshot();
        let events = ReplayEvent::read_log(&path).unwrap();
        assert_eq!(events, vec![ReplayEvent::InitDone { n_samples: 2 }]);
        let delta = obs::snapshot().delta_since(&base);
        assert!(delta.counter(Counter::ReplayTornLines) >= 1, "torn line must be counted");
        // the same torn text followed by more records is corruption
        let mut corrupted = full[..cut].to_string();
        corrupted.push('\n');
        corrupted.push_str(r#"{"event":"refit","n_samples":2}"#);
        corrupted.push('\n');
        std::fs::write(&path, &corrupted).unwrap();
        assert!(ReplayEvent::read_log(&path).is_err(), "mid-file tears must still fail");
    }

    /// Append mode extends an existing log instead of truncating it.
    #[test]
    fn jsonl_append_extends_the_log() {
        let path = std::env::temp_dir().join("limbo_stat_jsonl_append/events.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut writer = JsonlObserver::create(&path).unwrap();
            writer.on_event(&BoEvent::InitDone { n_samples: 1 });
        }
        {
            let mut writer = JsonlObserver::append(&path).unwrap();
            writer.on_event(&BoEvent::Refit { n_samples: 2 });
        }
        let events = ReplayEvent::read_log(&path).unwrap();
        assert_eq!(
            events,
            vec![ReplayEvent::InitDone { n_samples: 1 }, ReplayEvent::Refit { n_samples: 2 }]
        );
    }

    #[test]
    fn replay_parses_null_as_nan_and_rejects_garbage() {
        let line = r#"{"event":"observation","evaluations":1,"x":[null],"y":null,"best":1.0e0}"#;
        let ev = ReplayEvent::parse_line(line).unwrap();
        match ev {
            ReplayEvent::Observation { x, y, best, .. } => {
                assert!(x[0].is_nan() && y.is_nan());
                assert_eq!(best, 1.0);
            }
            other => panic!("{other:?}"),
        }
        assert!(ReplayEvent::parse_line(r#"{"event":"wat"}"#).is_err());
        assert!(ReplayEvent::parse_line(r#"{"event":"refit"}"#).is_err());
        assert!(ReplayEvent::parse_line("not json").is_err());
    }

    #[test]
    fn metrics_observer_appends_phase_breakdown_and_writes_json() {
        let _guard = obs::test_serial_guard();
        let dir = std::env::temp_dir().join("limbo_stat_metrics_test");
        let _ = std::fs::remove_dir_all(&dir);
        let prior = obs::enabled();
        {
            let mut metrics = MetricsObserver::create(&dir).unwrap();
            assert!(obs::enabled(), "create() must switch metrics on");
            // Pretend meta.dat already has RunLogger's footer; the phase
            // lines must append after it, not clobber it.
            std::fs::write(dir.join("meta.dat"), "dim\t2\n").unwrap();
            obs::record_duration(Phase::Ask, std::time::Duration::from_millis(4));
            obs::record_duration(Phase::CholFactor, std::time::Duration::from_millis(2));
            metrics.on_event(&BoEvent::Stopped { dim: 2, evaluations: 5, best: 0.0 });
        }
        obs::set_enabled(prior);
        let meta = std::fs::read_to_string(dir.join("meta.dat")).unwrap();
        assert!(meta.starts_with("dim\t2\n"), "must append, not truncate: {meta}");
        assert!(meta.contains("phase_wall_seconds\t"), "{meta}");
        assert!(meta.contains("phase_service_seconds\t"), "{meta}");
        assert!(meta.contains("phase_coverage\t"), "{meta}");
        assert!(meta.contains("phase.ask.seconds\t"), "{meta}");
        assert!(meta.contains("phase.chol_factor.seconds\t"), "{meta}");
        let json = std::fs::read_to_string(dir.join("metrics.json")).unwrap();
        assert!(json.contains(r#""wall_seconds":"#), "{json}");
        assert!(json.contains(r#""service_seconds":"#), "{json}");
        assert!(json.contains(r#""ask""#), "{json}");
    }

    /// A run that panics or is abandoned mid-flight still reports: the
    /// observer's `Drop` writes the files if `Stopped` never arrived.
    #[test]
    fn metrics_observer_reports_on_drop_without_stopped() {
        let _guard = obs::test_serial_guard();
        let dir = std::env::temp_dir().join("limbo_stat_metrics_drop_test");
        let _ = std::fs::remove_dir_all(&dir);
        let prior = obs::enabled();
        {
            let _metrics = MetricsObserver::create(&dir).unwrap();
        }
        obs::set_enabled(prior);
        assert!(dir.join("metrics.json").exists());
        let meta = std::fs::read_to_string(dir.join("meta.dat")).unwrap();
        assert!(meta.contains("phase_wall_seconds\t"), "{meta}");
    }
}
