//! Random-number substrate (no external crates): PCG-XSH-RR 64/32
//! generator, standard distributions, and the low-discrepancy samplers the
//! initializers use (Latin hypercube, Halton).

pub mod distributions;
pub mod pcg;
pub mod quasi;

pub use distributions::normal_pair;
pub use pcg::Pcg64;
pub use quasi::{halton_point, latin_hypercube};
