//! Distribution helpers on top of [`Pcg64`].

use super::Pcg64;

/// A pair of independent standard-normal draws (Box–Muller).
pub fn normal_pair(rng: &mut Pcg64) -> (f64, f64) {
    // avoid log(0)
    let u1 = loop {
        let u = rng.next_f64();
        if u > 0.0 {
            break u;
        }
    };
    let u2 = rng.next_f64();
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f64::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Multivariate normal draw `mean + L z` given a Cholesky factor `L` of the
/// covariance (used by CMA-ES and GP posterior sampling).
pub fn mvn_sample(
    mean: &[f64],
    chol_l: &crate::la::Matrix,
    rng: &mut Pcg64,
) -> Vec<f64> {
    let n = mean.len();
    assert_eq!(chol_l.rows(), n);
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut out = mean.to_vec();
    for i in 0..n {
        out[i] += crate::la::dot(&chol_l.row(i)[..=i], &z[..=i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::Matrix;

    #[test]
    fn normal_pair_is_standard() {
        let mut rng = Pcg64::seed(17);
        let n = 30_000;
        let (mut s, mut s2, mut cross) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let (a, b) = normal_pair(&mut rng);
            s += a + b;
            s2 += a * a + b * b;
            cross += a * b;
        }
        let mean = s / (2 * n) as f64;
        let var = s2 / (2 * n) as f64 - mean * mean;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.05);
        assert!((cross / n as f64).abs() < 0.03, "pairs should be independent");
    }

    #[test]
    fn mvn_covariance_matches() {
        let mut rng = Pcg64::seed(23);
        // cov = [[1, 0.8], [0.8, 1]]
        let l = Matrix::from_rows(2, 2, &[1.0, 0.0, 0.8, 0.6]);
        let n = 40_000;
        let (mut sxy, mut sx, mut sy) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let v = mvn_sample(&[0.0, 0.0], &l, &mut rng);
            sx += v[0];
            sy += v[1];
            sxy += v[0] * v[1];
        }
        let cov = sxy / n as f64 - (sx / n as f64) * (sy / n as f64);
        assert!((cov - 0.8).abs() < 0.05, "cov={cov}");
    }
}
