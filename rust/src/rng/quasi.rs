//! Low-discrepancy sampling: Latin hypercube (the BayesOpt-default
//! initializer) and the Halton sequence (space-filling inner-optimizer
//! seeding).

use super::Pcg64;

/// `n` points in `[0,1]^dim` by Latin hypercube sampling: each dimension is
/// split into `n` strata, each stratum used exactly once (permuted), with
/// uniform jitter inside the stratum.
pub fn latin_hypercube(n: usize, dim: usize, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    let mut points = vec![vec![0.0; dim]; n];
    for d in 0..dim {
        let mut strata: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut strata);
        for (i, &s) in strata.iter().enumerate() {
            points[i][d] = (s as f64 + rng.next_f64()) / n as f64;
        }
    }
    points
}

const PRIMES: [u64; 16] = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53];

/// `index`-th point of the Halton sequence in `[0,1)^dim` (dim <= 16).
pub fn halton_point(index: usize, dim: usize) -> Vec<f64> {
    assert!(dim <= PRIMES.len(), "halton: dim > {}", PRIMES.len());
    (0..dim).map(|d| radical_inverse(index as u64 + 1, PRIMES[d])).collect()
}

fn radical_inverse(mut i: u64, base: u64) -> f64 {
    let mut inv = 0.0;
    let mut frac = 1.0 / base as f64;
    while i > 0 {
        inv += (i % base) as f64 * frac;
        i /= base;
        frac /= base as f64;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lhs_stratification_holds() {
        let mut rng = Pcg64::seed(31);
        let n = 16;
        let pts = latin_hypercube(n, 3, &mut rng);
        for d in 0..3 {
            let mut strata: Vec<usize> =
                pts.iter().map(|p| (p[d] * n as f64).floor() as usize).collect();
            strata.sort_unstable();
            assert_eq!(strata, (0..n).collect::<Vec<_>>(), "dim {d} not stratified");
        }
    }

    #[test]
    fn lhs_in_unit_cube() {
        let mut rng = Pcg64::seed(32);
        for p in latin_hypercube(20, 5, &mut rng) {
            assert!(p.iter().all(|&v| (0.0..1.0).contains(&v)));
        }
    }

    #[test]
    fn halton_base2_prefix() {
        // base-2 radical inverse of 1,2,3,4... = 0.5, 0.25, 0.75, 0.125...
        assert!((halton_point(0, 1)[0] - 0.5).abs() < 1e-12);
        assert!((halton_point(1, 1)[0] - 0.25).abs() < 1e-12);
        assert!((halton_point(2, 1)[0] - 0.75).abs() < 1e-12);
        assert!((halton_point(3, 1)[0] - 0.125).abs() < 1e-12);
    }

    #[test]
    fn halton_covers_space() {
        let n = 256;
        let mut mins = [1.0f64; 2];
        let mut maxs = [0.0f64; 2];
        for i in 0..n {
            let p = halton_point(i, 2);
            for d in 0..2 {
                mins[d] = mins[d].min(p[d]);
                maxs[d] = maxs[d].max(p[d]);
            }
        }
        assert!(mins.iter().all(|&v| v < 0.05));
        assert!(maxs.iter().all(|&v| v > 0.95));
    }
}
