//! PCG-XSH-RR 64/32: small, fast, statistically solid PRNG
//! (O'Neill 2014). 64-bit LCG state, 32-bit xorshift-rotate output.
//!
//! Determinism matters here: benchmark replicates are seeded
//! `base_seed + replicate_index` so every experiment in EXPERIMENTS.md is
//! exactly rerunnable.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Seed with a stream constant derived from the seed itself.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream (odd increment is forced).
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit value (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection, unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal draw (Box–Muller; one value per call, spare cached
    /// by [`crate::rng::distributions::normal_pair`] users when needed).
    pub fn normal(&mut self) -> f64 {
        crate::rng::distributions::normal_pair(self).0
    }

    /// A point uniform in the unit hypercube.
    pub fn unit_point(&mut self, dim: usize) -> Vec<f64> {
        (0..dim).map(|_| self.next_f64()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (distinct stream) for parallel workers.
    pub fn fork(&mut self, worker: u64) -> Pcg64 {
        Pcg64::seed_stream(self.next_u64(), worker.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Raw `(state, increment)` pair — everything the generator is.
    /// Paired with [`from_state`](Self::from_state) for checkpointing:
    /// a restored generator continues the exact output sequence.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a captured `(state, increment)` pair.
    pub fn from_state(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::seed(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(6);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_continues_sequence() {
        let mut rng = Pcg64::seed(11);
        for _ in 0..37 {
            rng.next_u32();
        }
        let (state, inc) = rng.state();
        let mut restored = Pcg64::from_state(state, inc);
        for _ in 0..100 {
            assert_eq!(rng.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn forks_are_decorrelated() {
        let mut root = Pcg64::seed(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }
}
