//! The declarative experiment definition — the Rust analog of the
//! paper's `Params` struct.
//!
//! [`BoDef`] collects every policy and parameter of a Bayesian
//! optimization experiment in one builder and monomorphizes to the same
//! concrete types as hand-composition (each setter that swaps a policy
//! swaps a *type parameter*, so there is zero dynamic dispatch on the
//! hot path). One definition builds either frontend of the shared
//! [`BoCore`](crate::bayes_opt::BoCore) engine:
//!
//! * [`BoDef::build_optimizer`] — a run-to-completion
//!   [`BOptimizer`];
//! * [`BoDef::build_server`] / [`BoDef::spawn_server`] — an ask/tell
//!   [`AskTellServer`] (inline or on its own thread) whose initial
//!   design, refit schedule and batch strategy match the optimizer's
//!   exactly (same seed ⇒ bit-identical traces, see
//!   `tests/api_parity.rs`);
//! * the `*_adaptive_*` variants swap the dense GP for an
//!   [`AdaptiveModel`] that migrates to the sparse inducing-point GP on
//!   large budgets.
//!
//! ```no_run
//! use limbo::prelude::*;
//! let mut opt = BoDef::new(2)
//!     .kernel(Matern52::new)
//!     .acquisition(Ei::default())
//!     .batch(BatchStrategy::QEi { mc_samples: 256 })
//!     .refit(RefitSchedule::Doubling { first: 16 })
//!     .bounds(&[(-5.0, 10.0), (0.0, 15.0)])
//!     .seed(42)
//!     .build_optimizer();
//! let best = opt.optimize(&FnEval::new(2, |x: &[f64]| -(x[0] * x[0] + x[1] * x[1])));
//! ```

use crate::acqui::{AcquiFn, PofWeighted, Ucb};
use crate::bayes_opt::core::{BatchStrategy, BoCore, BoError, Domain, Observer, RefitSchedule};
use crate::bayes_opt::BOptimizer;
use crate::coordinator::service::{AskTellServer, ServerHandle};
use crate::init::{Initializer, NoInit, RandomSampling};
use crate::kernel::{Kernel, Matern52};
use crate::mean::{DataMean, MeanFn};
use crate::model::{gp::Gp, AdaptiveModel, HpOptConfig, ModelBank};
use crate::opt::{Chained, NelderMead, Optimizer, OptimizerExt, ParallelRepeater, RandomPoint};
use crate::stop::{MaxIterations, StopCriterion};

/// The default inner optimizer: 8 parallel restarts of 256 random
/// probes refined by Nelder–Mead.
pub type DefaultInnerOpt = ParallelRepeater<Chained<RandomPoint, NelderMead>>;

/// Declarative definition of a Bayesian-optimization experiment.
///
/// Type parameters are the swappable policies (kernel, mean,
/// acquisition, initializer, inner optimizer, stop criterion); the
/// defaults reproduce the library defaults (Matérn-5/2 GP with data
/// mean, UCB, 10 random init samples, random+Nelder–Mead restarts, 40
/// iterations, doubling ML-II refits from n = 16).
pub struct BoDef<
    K = Matern52,
    Mn = DataMean,
    A = Ucb,
    I = RandomSampling,
    O = DefaultInnerOpt,
    S = MaxIterations,
> {
    dim: usize,
    kernel: K,
    mean: Mn,
    acquisition: A,
    initializer: I,
    inner_opt: O,
    stop: S,
    noise: f64,
    seed: u64,
    refit: RefitSchedule,
    batch: BatchStrategy,
    domain: Domain,
    hp: Option<HpOptConfig>,
    observers: Vec<Box<dyn Observer>>,
    async_pending: bool,
    n_constraints: usize,
}

impl BoDef {
    /// A definition with the library defaults for a `dim`-dimensional
    /// problem over the unit cube (override the box with
    /// [`bounds`](Self::bounds)).
    pub fn new(dim: usize) -> BoDef {
        BoDef {
            dim,
            kernel: Matern52::new(dim),
            mean: DataMean::default(),
            acquisition: Ucb::default(),
            initializer: RandomSampling { n: 10 },
            inner_opt: RandomPoint::new(256).then(NelderMead::default()).restarts(8, 4),
            stop: MaxIterations(40),
            noise: 1e-4,
            seed: 42,
            refit: RefitSchedule::Doubling { first: 16 },
            batch: BatchStrategy::default(),
            domain: Domain::unit(dim),
            hp: None,
            observers: Vec::new(),
            async_pending: false,
            n_constraints: 0,
        }
    }

    /// The always-on service defaults: noise 1e-3, no
    /// initial design (the first asks are random probes / warm-start
    /// tells), a lighter 4×2-restart inner optimizer. Finish with
    /// [`build_adaptive_server`](Self::build_adaptive_server) for the
    /// dense→sparse surrogate an unbounded run needs.
    pub fn service(dim: usize) -> BoDef<Matern52, DataMean, Ucb, NoInit, DefaultInnerOpt> {
        BoDef::new(dim)
            .noise(1e-3)
            .init(NoInit)
            .inner_opt(RandomPoint::new(128).then(NelderMead::default()).restarts(4, 2))
    }
}

impl<K, Mn, A, I, O, S> BoDef<K, Mn, A, I, O, S> {
    /// Swap the kernel; takes a `dim -> kernel` constructor so the
    /// definition's dimensionality is threaded automatically
    /// (`.kernel(Matern52::new)`, `.kernel(SquaredExpArd::new)`, or
    /// `.kernel(|_| my_kernel)` for a pre-built instance).
    pub fn kernel<K2>(self, kernel: impl FnOnce(usize) -> K2) -> BoDef<K2, Mn, A, I, O, S> {
        let kernel = kernel(self.dim);
        BoDef {
            dim: self.dim,
            kernel,
            mean: self.mean,
            acquisition: self.acquisition,
            initializer: self.initializer,
            inner_opt: self.inner_opt,
            stop: self.stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }

    /// Swap the mean function.
    pub fn mean<Mn2>(self, mean: Mn2) -> BoDef<K, Mn2, A, I, O, S> {
        BoDef {
            dim: self.dim,
            kernel: self.kernel,
            mean,
            acquisition: self.acquisition,
            initializer: self.initializer,
            inner_opt: self.inner_opt,
            stop: self.stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }

    /// Swap the acquisition function.
    pub fn acquisition<A2>(self, acquisition: A2) -> BoDef<K, Mn, A2, I, O, S> {
        BoDef {
            dim: self.dim,
            kernel: self.kernel,
            mean: self.mean,
            acquisition,
            initializer: self.initializer,
            inner_opt: self.inner_opt,
            stop: self.stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }

    /// Swap the initial-design generator.
    pub fn init<I2>(self, initializer: I2) -> BoDef<K, Mn, A, I2, O, S> {
        BoDef {
            dim: self.dim,
            kernel: self.kernel,
            mean: self.mean,
            acquisition: self.acquisition,
            initializer,
            inner_opt: self.inner_opt,
            stop: self.stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }

    /// Swap the inner (acquisition-maximizing) optimizer.
    pub fn inner_opt<O2>(self, inner_opt: O2) -> BoDef<K, Mn, A, I, O2, S> {
        BoDef {
            dim: self.dim,
            kernel: self.kernel,
            mean: self.mean,
            acquisition: self.acquisition,
            initializer: self.initializer,
            inner_opt,
            stop: self.stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }

    /// Use self-adaptive Differential Evolution as the acquisition
    /// maximizer with an evaluation budget of `max_evals` (shorthand for
    /// `.inner_opt(AdaptiveDe::new(max_evals))`). DE scores whole
    /// generations through the batched `eval_many` path and holds up in
    /// higher dimensions where DIRECT's rectangle subdivision stalls —
    /// see the "Inner optimizers" section of the crate docs for
    /// dimension guidance.
    pub fn inner_de(self, max_evals: usize) -> BoDef<K, Mn, A, I, crate::opt::AdaptiveDe, S> {
        self.inner_opt(crate::opt::AdaptiveDe::new(max_evals))
    }

    /// Swap the stop criterion (only consulted by the run-to-completion
    /// frontend).
    pub fn stop<S2>(self, stop: S2) -> BoDef<K, Mn, A, I, O, S2> {
        BoDef {
            dim: self.dim,
            kernel: self.kernel,
            mean: self.mean,
            acquisition: self.acquisition,
            initializer: self.initializer,
            inner_opt: self.inner_opt,
            stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }

    /// Stop after `n` model-guided iterations (shorthand for
    /// `.stop(MaxIterations(n))`).
    pub fn iterations(self, n: usize) -> BoDef<K, Mn, A, I, O, MaxIterations> {
        self.stop(MaxIterations(n))
    }

    /// Use `n` i.i.d. random initial samples (shorthand for
    /// `.init(RandomSampling { n })`).
    pub fn init_samples(self, n: usize) -> BoDef<K, Mn, A, RandomSampling, O, S> {
        self.init(RandomSampling { n })
    }

    /// Observation-noise standard deviation of the GP.
    pub fn noise(mut self, noise: f64) -> Self {
        self.noise = noise;
        self
    }

    /// RNG seed (initial design, inner optimizer, random probes).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Hyper-parameter refit schedule.
    pub fn refit(mut self, schedule: RefitSchedule) -> Self {
        self.refit = schedule;
        self
    }

    /// q-point batch proposal strategy.
    pub fn batch(mut self, strategy: BatchStrategy) -> Self {
        self.batch = strategy;
        self
    }

    /// ML-II hyper-opt settings (restarts, iRprop⁻ iterations, ...)
    /// applied to the built surrogate — the declarative form of
    /// reaching into `core.model.hp_opt.config` after building.
    pub fn hp_config(mut self, config: HpOptConfig) -> Self {
        self.hp = Some(config);
        self
    }

    /// Optimize over the box `bounds` instead of the unit cube; every
    /// built frontend then speaks user coordinates (see [`Domain`]).
    ///
    /// # Panics
    /// If `bounds.len()` differs from the definition's dimension or any
    /// bound is invalid. The non-panicking form is
    /// [`try_bounds`](Self::try_bounds).
    pub fn bounds(self, bounds: &[(f64, f64)]) -> Self {
        self.try_bounds(bounds).expect("bounds must cover every dimension with finite hi > lo")
    }

    /// Fallible form of [`bounds`](Self::bounds): a service validating a
    /// client-supplied definition gets a typed [`BoError`] instead of a
    /// panic.
    pub fn try_bounds(self, bounds: &[(f64, f64)]) -> Result<Self, BoError> {
        if bounds.len() != self.dim {
            return Err(BoError::DimMismatch { expected: self.dim, got: bounds.len() });
        }
        let domain = Domain::try_from_bounds(bounds)?;
        Ok(Self { domain, ..self })
    }

    /// Set the search domain directly.
    ///
    /// # Panics
    /// If the domain dimensionality differs from the definition's. The
    /// non-panicking form is [`try_domain`](Self::try_domain).
    pub fn domain(self, domain: Domain) -> Self {
        self.try_domain(domain).expect("Domain dim must match the definition dim")
    }

    /// Fallible form of [`domain`](Self::domain).
    pub fn try_domain(self, domain: Domain) -> Result<Self, BoError> {
        if domain.dim() != self.dim {
            return Err(BoError::DimMismatch { expected: self.dim, got: domain.dim() });
        }
        Ok(Self { domain, ..self })
    }

    /// Subscribe a run observer (repeatable).
    pub fn observer(mut self, observer: impl Observer + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Enable asynchronous pending-point mode: every ask registers a
    /// pending trial that later proposals fantasize over
    /// (kriging-believer mean lies) until the matching tell retires it,
    /// so q workers can ask and tell in any interleaving. With strictly
    /// alternating ask/tell the trace is bit-identical to the
    /// synchronous mode.
    pub fn async_pending(mut self, on: bool) -> Self {
        self.async_pending = on;
        self
    }

    /// Declare `k` inequality-constraint channels (`>= 0` = feasible).
    /// Consumed by [`build_constrained_server`](Self::build_constrained_server),
    /// which banks one surrogate per channel next to the objective and
    /// weights the acquisition by the probability of feasibility; every
    /// tell must then carry exactly `k` constraint values. Ignored by
    /// the unconstrained build paths.
    pub fn constraints(mut self, k: usize) -> Self {
        self.n_constraints = k;
        self
    }

    /// Rewrap the acquisition in place (used by the constrained build
    /// path to compose [`PofWeighted`] around whatever base was set).
    fn map_acquisition<A2>(self, f: impl FnOnce(A) -> A2) -> BoDef<K, Mn, A2, I, O, S> {
        BoDef {
            dim: self.dim,
            kernel: self.kernel,
            mean: self.mean,
            acquisition: f(self.acquisition),
            initializer: self.initializer,
            inner_opt: self.inner_opt,
            stop: self.stop,
            noise: self.noise,
            seed: self.seed,
            refit: self.refit,
            batch: self.batch,
            domain: self.domain,
            hp: self.hp,
            observers: self.observers,
            async_pending: self.async_pending,
            n_constraints: self.n_constraints,
        }
    }
}

impl<K, Mn, A, I, O, S> BoDef<K, Mn, A, I, O, S>
where
    K: Kernel,
    Mn: MeanFn,
    I: Initializer,
    O: Optimizer,
    S: StopCriterion,
{
    /// Assemble the shared engine around `make(kernel, mean, noise,
    /// hp)` — the one place every definition field is threaded into a
    /// core, so the dense and adaptive build paths cannot drift apart.
    fn into_core<M>(self, make: Make<K, Mn, M>) -> (BoCore<M, A, O>, I, S)
    where
        M: crate::model::Model,
        A: AcquiFn<M>,
    {
        let BoDef {
            dim,
            kernel,
            mean,
            acquisition,
            initializer,
            inner_opt,
            stop,
            noise,
            seed,
            refit,
            batch,
            domain,
            hp,
            observers,
            async_pending,
            n_constraints,
        } = self;
        let model = make(kernel, mean, noise, hp, n_constraints);
        let mut core = BoCore::new(model, acquisition, inner_opt, dim, seed)
            .with_domain(domain)
            .with_refit(refit)
            .with_batch_strategy(batch)
            .with_async_pending(async_pending);
        for obs in observers {
            core.add_boxed_observer(obs);
        }
        (core, initializer, stop)
    }

    /// Core + queued init design: the server has no `optimize()` moment
    /// to draw the design, so it is drawn here with the same RNG order
    /// the optimizer frontend uses.
    fn into_server<M>(self, make: Make<K, Mn, M>) -> AskTellServer<M, A, O>
    where
        M: crate::model::Model,
        A: AcquiFn<M>,
    {
        let dim = self.dim;
        let (mut core, initializer, _stop) = self.into_core(make);
        let design = initializer.points(dim, &mut core.rng);
        core.seed_design(design);
        AskTellServer { core }
    }

    /// Build the run-to-completion frontend (dense GP surrogate).
    pub fn build_optimizer(self) -> BOptimizer<Gp<K, Mn>, A, I, O, S>
    where
        A: AcquiFn<Gp<K, Mn>>,
    {
        let (core, initializer, stop) = self.into_core(make_dense);
        BOptimizer { core, initializer, stop }
    }

    /// Build the run-to-completion frontend with an [`AdaptiveModel`]
    /// surrogate (dense while small, sparse past its threshold — for
    /// budgets beyond a few hundred evaluations).
    pub fn build_adaptive_optimizer(self) -> BOptimizer<AdaptiveModel<K, Mn>, A, I, O, S>
    where
        A: AcquiFn<AdaptiveModel<K, Mn>>,
    {
        let (core, initializer, stop) = self.into_core(make_adaptive);
        BOptimizer { core, initializer, stop }
    }

    /// Build the inline ask/tell frontend (dense GP surrogate). The
    /// initial design is queued into the server, so the first asks
    /// serve the same design points the optimizer frontend would
    /// evaluate — the two produce identical traces for the same seed.
    pub fn build_server(self) -> AskTellServer<Gp<K, Mn>, A, O>
    where
        A: AcquiFn<Gp<K, Mn>>,
    {
        self.into_server(make_dense)
    }

    /// Build the inline ask/tell frontend with an [`AdaptiveModel`]
    /// surrogate — the right default for an always-on service that
    /// accumulates observations indefinitely.
    pub fn build_adaptive_server(self) -> AskTellServer<AdaptiveModel<K, Mn>, A, O>
    where
        A: AcquiFn<AdaptiveModel<K, Mn>>,
    {
        self.into_server(make_adaptive)
    }

    /// Build the threaded ask/tell frontend: the server from
    /// [`build_server`](Self::build_server) moved onto its own thread.
    pub fn spawn_server(self) -> ServerHandle
    where
        A: AcquiFn<Gp<K, Mn>> + Send + 'static,
        O: Send + 'static,
        Gp<K, Mn>: Clone + Send + 'static,
    {
        self.build_server().spawn()
    }

    /// Build the **constrained** ask/tell frontend: a [`ModelBank`]
    /// with one dense-GP surrogate per declared constraint channel
    /// (see [`constraints`](Self::constraints)) next to the objective
    /// GP, and the definition's acquisition wrapped in the
    /// probability-of-feasibility weight ([`PofWeighted`]). Every tell
    /// must carry one constraint value per channel (`>= 0` = feasible)
    /// via `tell_constrained` / a typed
    /// [`Observation`](crate::bayes_opt::Observation).
    ///
    /// With zero declared channels the bank degenerates to the plain
    /// objective GP and [`PofWeighted`] passes the base score through
    /// untouched, so the trace is bit-identical to
    /// [`build_server`](Self::build_server).
    pub fn build_constrained_server(
        self,
    ) -> AskTellServer<ModelBank<Gp<K, Mn>>, PofWeighted<A>, O>
    where
        K: Clone,
        Mn: Clone,
        A: AcquiFn<Gp<K, Mn>>,
    {
        self.map_acquisition(PofWeighted::new).into_server(make_dense_bank)
    }

    /// Threaded form of
    /// [`build_constrained_server`](Self::build_constrained_server).
    pub fn spawn_constrained_server(self) -> ServerHandle
    where
        K: Clone + Send + 'static,
        Mn: Clone + Send + 'static,
        A: AcquiFn<Gp<K, Mn>> + Send + 'static,
        O: Send + 'static,
        Gp<K, Mn>: Clone + Send + 'static,
    {
        self.build_constrained_server().spawn()
    }
}

/// Surrogate constructor shape [`BoDef`] builds through: kernel, mean,
/// noise, the optional hyper-opt settings, and the constraint-channel
/// count (ignored by the single-output surrogates).
type Make<K, Mn, M> = fn(K, Mn, f64, Option<HpOptConfig>, usize) -> M;

fn make_dense<K: Kernel, Mn: MeanFn>(
    kernel: K,
    mean: Mn,
    noise: f64,
    hp: Option<HpOptConfig>,
    _constraints: usize,
) -> Gp<K, Mn> {
    let mut gp = Gp::new(kernel, mean, noise);
    if let Some(config) = hp {
        gp.hp_opt.config = config;
    }
    gp
}

fn make_adaptive<K: Kernel, Mn: MeanFn>(
    kernel: K,
    mean: Mn,
    noise: f64,
    hp: Option<HpOptConfig>,
    _constraints: usize,
) -> AdaptiveModel<K, Mn> {
    let model = AdaptiveModel::new(kernel, mean, noise);
    match hp {
        Some(config) => model.with_hp_config(config),
        None => model,
    }
}

fn make_dense_bank<K: Kernel + Clone, Mn: MeanFn + Clone>(
    kernel: K,
    mean: Mn,
    noise: f64,
    hp: Option<HpOptConfig>,
    constraints: usize,
) -> ModelBank<Gp<K, Mn>> {
    let objective = make_dense(kernel.clone(), mean.clone(), noise, hp.clone(), 0);
    let members = (0..constraints)
        .map(|_| make_dense(kernel.clone(), mean.clone(), noise, hp.clone(), 0))
        .collect();
    ModelBank::new(objective, members)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ei;
    use crate::bayes_opt::FnEval;
    use crate::kernel::SquaredExpArd;
    use crate::model::Model;

    #[test]
    fn default_def_matches_library_defaults_and_converges() {
        let mut opt = BoDef::new(1).seed(3).iterations(15).build_optimizer();
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.3).powi(2)));
        assert_eq!(best.evaluations, 25, "10 init + 15 iterations");
        assert!(best.value > -0.01, "best={}", best.value);
    }

    #[test]
    fn swapped_policies_monomorphize_and_converge() {
        let mut opt = BoDef::new(1)
            .kernel(SquaredExpArd::new)
            .acquisition(Ei::default())
            .init(crate::init::Lhs { n: 6 })
            .inner_opt(crate::opt::Cmaes::new(150))
            .refit(RefitSchedule::Never)
            .noise(1e-3)
            .seed(11)
            .iterations(12)
            .build_optimizer();
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.71).powi(2)));
        assert!((best.x[0] - 0.71).abs() < 0.05, "x={:?}", best.x);
    }

    #[test]
    fn server_and_optimizer_share_the_definition() {
        let f = |x: &[f64]| -(x[0] - 0.6).powi(2);
        let def = || BoDef::new(1).seed(9).init_samples(4).refit(RefitSchedule::Never);
        let mut opt = def().iterations(8).build_optimizer();
        let best = opt.optimize(&FnEval::new(1, f));
        let mut srv = def().build_server();
        for _ in 0..12 {
            let x = srv.ask();
            let y = f(&x);
            srv.tell(&x, y);
        }
        // same definition, same seed, same budget: identical outcome
        let (sx, sv) = srv.best().unwrap();
        assert_eq!(best.x, sx);
        assert_eq!(best.value, sv);
    }

    #[test]
    fn bounded_definition_optimizes_in_user_coordinates() {
        let mut opt = BoDef::new(1)
            .bounds(&[(-4.0, 4.0)])
            .seed(5)
            .refit(RefitSchedule::Never)
            .iterations(15)
            .build_optimizer();
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 1.5).powi(2)));
        assert!((best.x[0] - 1.5).abs() < 0.1, "x={:?}", best.x);
        assert!((-4.0..=4.0).contains(&best.x[0]));
    }

    #[test]
    fn hp_config_reaches_the_built_model() {
        let opt = BoDef::new(1)
            .hp_config(HpOptConfig { restarts: 7, iterations: 9, ..Default::default() })
            .build_optimizer();
        assert_eq!(opt.core.model.hp_opt.config.restarts, 7);
        assert_eq!(opt.core.model.hp_opt.config.iterations, 9);
        let srv = BoDef::service(1)
            .hp_config(HpOptConfig { restarts: 5, ..Default::default() })
            .build_adaptive_server();
        assert_eq!(srv.core.model.as_dense().unwrap().hp_opt.config.restarts, 5);
    }

    #[test]
    fn adaptive_server_builds_and_runs() {
        let mut srv = BoDef::new(1).seed(21).init(crate::init::NoInit).build_adaptive_server();
        for _ in 0..8 {
            let x = srv.ask();
            let y = -(x[0] - 0.2).powi(2);
            srv.tell(&x, y);
        }
        assert_eq!(srv.core.model.n_samples(), 8);
        assert!(srv.best().unwrap().1 > -0.1);
    }
}
