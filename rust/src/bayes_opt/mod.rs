//! The Bayesian-optimization template loop — `limbo::bayes_opt::BOptimizer`.
//!
//! `BOptimizer<M, A, I, O, S>` is generic over its five policies (model,
//! acquisition, initializer, inner optimizer, stopping criterion), so the
//! whole optimization loop is **monomorphized**: swapping a component is a
//! type change, not a virtual call — exactly the paper's policy-based C++
//! design mapped to Rust generics. The dynamic-dispatch mirror of this
//! loop lives in [`crate::baseline`] (the Figure-1 comparator).
//!
//! ```no_run
//! use limbo::prelude::*;
//! let f = |x: &[f64]| -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>();
//! let mut opt = BOptimizer::with_defaults(2, 42);
//! let best = opt.optimize(&FnEval::new(2, f));
//! println!("best {:?} -> {}", best.x, best.value);
//! ```

use crate::acqui::{AcquiContext, AcquiFn, AcquiObjective, Ucb};
use crate::init::{Initializer, RandomSampling};
use crate::kernel::Matern52;
use crate::mean::DataMean;
use crate::model::{gp::Gp, AdaptiveModel, Model};
use crate::opt::{NelderMead, Optimizer, OptimizerExt, ParallelRepeater, RandomPoint};
use crate::rng::Pcg64;
use crate::stat::RunLogger;
use crate::stop::{MaxIterations, StopContext, StopCriterion};

/// Result of an optimization run.
#[derive(Clone, Debug, PartialEq)]
pub struct Best {
    /// Best input found (in `[0, 1]^dim`).
    pub x: Vec<f64>,
    /// Best observed value.
    pub value: f64,
    /// Total function evaluations used.
    pub evaluations: usize,
}

/// The function being optimized (the paper's "functor" with
/// `dim_in`/`dim_out`; scalar output here, multi-objective lives in
/// [`crate::coordinator::multiobj`]).
pub trait Evaluator: Sync {
    /// Input dimension.
    fn dim(&self) -> usize;
    /// Evaluate the (possibly expensive, noisy) objective. Maximized.
    fn eval(&self, x: &[f64]) -> f64;
}

/// Wrap a closure as an [`Evaluator`].
pub struct FnEval<F: Fn(&[f64]) -> f64 + Sync> {
    dim: usize,
    f: F,
}

impl<F: Fn(&[f64]) -> f64 + Sync> FnEval<F> {
    /// Closure + input dimension.
    pub fn new(dim: usize, f: F) -> Self {
        Self { dim, f }
    }
}

impl<F: Fn(&[f64]) -> f64 + Sync> Evaluator for FnEval<F> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, x: &[f64]) -> f64 {
        (self.f)(x)
    }
}

/// How often hyper-parameters are re-fit (ML-II) during the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HpSchedule {
    /// Never re-fit (fixed hyper-parameters).
    Never,
    /// Re-fit after every `k`-th new sample.
    Every(usize),
}

/// The statically-composed Bayesian optimizer.
pub struct BOptimizer<M, A, I, O, S>
where
    M: Model,
    A: AcquiFn<M>,
    I: Initializer,
    O: Optimizer,
    S: StopCriterion,
{
    /// Surrogate model (fitted in place during the run).
    pub model: M,
    /// Acquisition function.
    pub acquisition: A,
    /// Initial-design generator.
    pub initializer: I,
    /// Inner optimizer maximizing the acquisition each iteration.
    pub inner_opt: O,
    /// Stop rule.
    pub stop: S,
    /// Hyper-parameter refit schedule.
    pub hp_schedule: HpSchedule,
    /// RNG (seeds the initializer and the inner optimizer).
    pub rng: Pcg64,
    /// Optional run logger (samples/observations/best traces).
    pub stats: Option<RunLogger>,
}

/// The default configuration's concrete type (Matérn-5/2 GP + data mean,
/// UCB, random init, random+Nelder-Mead restarts inner optimizer).
pub type DefaultBOptimizer = BOptimizer<
    Gp<Matern52, DataMean>,
    Ucb,
    RandomSampling,
    ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    MaxIterations,
>;

impl DefaultBOptimizer {
    /// The library defaults the quickstart uses: 10 random init samples,
    /// UCB(0.5), Matérn-5/2 GP with data mean and 1e-10..ish noise,
    /// 8 parallel restarts of random-then-Nelder-Mead, 40 iterations.
    pub fn with_defaults(dim: usize, seed: u64) -> Self {
        BOptimizer {
            model: Gp::new(Matern52::new(dim), DataMean::default(), 1e-4),
            acquisition: Ucb::default(),
            initializer: RandomSampling { n: 10 },
            inner_opt: RandomPoint::new(256).then(NelderMead::default()).restarts(8, 4),
            stop: MaxIterations(40),
            hp_schedule: HpSchedule::Never,
            rng: Pcg64::seed(seed),
            stats: None,
        }
    }
}

/// The large-budget configuration: same policies as
/// [`DefaultBOptimizer`], but the surrogate is an
/// [`AdaptiveModel`] that migrates from the exact dense GP to the sparse
/// inducing-point GP once the evaluation count outgrows the dense regime.
pub type AdaptiveBOptimizer = BOptimizer<
    AdaptiveModel<Matern52, DataMean>,
    Ucb,
    RandomSampling,
    ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    MaxIterations,
>;

impl AdaptiveBOptimizer {
    /// Defaults for runs whose budget exceeds a few hundred evaluations
    /// (`iterations` sets the stop rule; the model switches to sparse on
    /// its own past [`crate::model::sgp::DEFAULT_SPARSE_THRESHOLD`]).
    pub fn with_adaptive_defaults(dim: usize, seed: u64, iterations: usize) -> Self {
        BOptimizer {
            model: AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 1e-4),
            acquisition: Ucb::default(),
            initializer: RandomSampling { n: 10 },
            inner_opt: RandomPoint::new(256).then(NelderMead::default()).restarts(8, 4),
            stop: MaxIterations(iterations),
            hp_schedule: HpSchedule::Never,
            rng: Pcg64::seed(seed),
            stats: None,
        }
    }
}

impl<M, A, I, O, S> BOptimizer<M, A, I, O, S>
where
    M: Model,
    A: AcquiFn<M>,
    I: Initializer,
    O: Optimizer,
    S: StopCriterion,
{
    /// Compose an optimizer from explicit components.
    pub fn new(
        model: M,
        acquisition: A,
        initializer: I,
        inner_opt: O,
        stop: S,
        seed: u64,
    ) -> Self {
        Self {
            model,
            acquisition,
            initializer,
            inner_opt,
            stop,
            hp_schedule: HpSchedule::Never,
            rng: Pcg64::seed(seed),
            stats: None,
        }
    }

    /// Enable periodic ML-II hyper-parameter refits.
    pub fn with_hp_schedule(mut self, schedule: HpSchedule) -> Self {
        self.hp_schedule = schedule;
        self
    }

    /// Attach a run logger.
    pub fn with_stats(mut self, logger: RunLogger) -> Self {
        self.stats = Some(logger);
        self
    }

    /// Run the full loop: initialization, then model-guided sampling until
    /// the stop criterion fires. Returns the best sample found.
    pub fn optimize(&mut self, f: &impl Evaluator) -> Best {
        let dim = f.dim();
        let mut best = Best { x: vec![0.5; dim], value: f64::NEG_INFINITY, evaluations: 0 };
        let mut evals = 0usize;

        // ---- initialization phase ----
        for x in self.initializer.points(dim, &mut self.rng) {
            let y = f.eval(&x);
            evals += 1;
            self.model.add_sample(&x, y);
            if y > best.value {
                best = Best { x: x.clone(), value: y, evaluations: evals };
            }
            if let Some(log) = &mut self.stats {
                log.log_sample(evals, &x, y, best.value);
            }
        }
        if self.hp_schedule != HpSchedule::Never && self.model.n_samples() >= 2 {
            self.model.optimize_hyperparams();
        }

        // ---- model-guided loop ----
        let mut iteration = 0usize;
        loop {
            let ctx = StopContext { iteration, evaluations: evals, best: best.value };
            if self.stop.stop(&ctx) {
                break;
            }
            // batched acquisition objective: population-based inner
            // optimizers score whole generations through eval_many →
            // predict_batch instead of per-point predicts
            let actx = AcquiContext::new(iteration, best.value, dim);
            let objective = AcquiObjective::new(&self.model, &self.acquisition, actx);
            let cand = self.inner_opt.optimize(&objective, dim, &mut self.rng);

            let y = f.eval(&cand.x);
            evals += 1;
            self.model.add_sample(&cand.x, y);
            if y > best.value {
                best = Best { x: cand.x.clone(), value: y, evaluations: evals };
            }
            if let Some(log) = &mut self.stats {
                log.log_sample(evals, &cand.x, y, best.value);
            }
            if let HpSchedule::Every(k) = self.hp_schedule {
                if k > 0 && (iteration + 1) % k == 0 {
                    self.model.optimize_hyperparams();
                }
            }
            iteration += 1;
        }

        best.evaluations = evals;
        if let Some(log) = &mut self.stats {
            log.finish(dim, evals);
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ei;
    use crate::kernel::SquaredExpArd;
    use crate::mean::ZeroMean;
    use crate::opt::Cmaes;
    use crate::stop::TargetReached;

    /// The paper's example function (maximum 0 at x = 0 boundary is NOT
    /// the max; actual max of -x^2 sin(2x) on [0,1]^2... the function is
    /// positive where sin(2x) < 0, i.e. x > pi/2 — outside [0,1], so the
    /// max on [0,1]^2 is at x = 0 with value 0).
    fn my_fun(x: &[f64]) -> f64 {
        -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>()
    }

    #[test]
    fn default_optimizer_solves_paper_example() {
        let mut opt = BOptimizer::with_defaults(2, 7);
        let best = opt.optimize(&FnEval::new(2, my_fun));
        assert!(best.value > -0.01, "best={}", best.value);
        assert_eq!(best.evaluations, 50); // 10 init + 40 iterations
    }

    #[test]
    fn custom_components_compose() {
        // the paper's "swap the kernel and acquisition" snippet, in Rust
        let model = Gp::new(SquaredExpArd::new(1), ZeroMean, 1e-3);
        let mut opt = BOptimizer::new(
            model,
            Ei::default(),
            crate::init::Lhs { n: 5 },
            Cmaes::new(200),
            MaxIterations(15),
            3,
        );
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| {
            -(x[0] - 0.73).powi(2)
        }));
        assert!((best.x[0] - 0.73).abs() < 0.05, "x={:?}", best.x);
    }

    #[test]
    fn adaptive_optimizer_goes_sparse_and_still_converges() {
        let mut opt = AdaptiveBOptimizer::with_adaptive_defaults(1, 13, 30);
        // force an early dense→sparse migration so the sparse path drives
        // most of the run (keeps the test fast)
        opt.model = AdaptiveModel::new(Matern52::new(1), DataMean::default(), 1e-4)
            .with_threshold(15)
            .with_sparse_config(crate::model::SgpConfig {
                max_inducing: 24,
                ..Default::default()
            });
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.37).powi(2)));
        assert!(opt.model.is_sparse(), "model should have migrated");
        assert!(best.value > -0.01, "best={}", best.value);
        assert_eq!(best.evaluations, 40); // 10 init + 30 iterations
    }

    #[test]
    fn target_stop_ends_early() {
        let model = Gp::new(Matern52::new(1), DataMean::default(), 1e-4);
        let mut opt = BOptimizer::new(
            model,
            Ucb::default(),
            RandomSampling { n: 3 },
            RandomPoint::new(64),
            (MaxIterations(100), TargetReached(0.9)),
            11,
        );
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| x[0]));
        assert!(best.value >= 0.9);
        assert!(best.evaluations < 103, "should stop well before 100 iters");
    }

    #[test]
    fn hp_schedule_runs_and_still_converges() {
        let model = Gp::new(SquaredExpArd::new(1), DataMean::default(), 1e-3);
        let mut opt = BOptimizer::new(
            model,
            Ucb::default(),
            RandomSampling { n: 6 },
            RandomPoint::new(128).then(NelderMead::default()),
            MaxIterations(12),
            5,
        )
        .with_hp_schedule(HpSchedule::Every(3));
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.4).powi(2)));
        assert!(best.value > -0.01, "best={}", best.value);
    }

    #[test]
    fn logs_when_stats_attached() {
        let dir = std::env::temp_dir().join("limbo_bo_stats_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut opt = BOptimizer::with_defaults(1, 1);
        opt.stop = MaxIterations(3);
        opt.stats = Some(RunLogger::create(&dir).unwrap());
        let _ = opt.optimize(&FnEval::new(1, |x: &[f64]| -x[0]));
        let best_file = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best_file.lines().count(), 13); // 10 init + 3 iters
    }
}
