//! The Bayesian-optimization template loop — `limbo::bayes_opt`.
//!
//! [`BoCore`] (in [`mod@core`]) is the single ask/tell engine: it owns
//! the loop state machine (initial design → fit → propose → observe →
//! refit → incumbent tracking) and dispatches typed [`BoEvent`]s to
//! [`Observer`]s. [`BOptimizer`] is the run-to-completion frontend over
//! it, generic over its policies (model, acquisition, initializer,
//! inner optimizer, stopping criterion), so the whole loop is
//! **monomorphized**: swapping a component is a type change, not a
//! virtual call — exactly the paper's policy-based C++ design mapped to
//! Rust generics. [`BoDef`] (in [`mod@def`]) is the declarative builder
//! that assembles either this frontend or the ask/tell server from one
//! definition. The dynamic-dispatch mirror lives in [`crate::baseline`]
//! (the Figure-1 comparator) — driving the *same* core.
//!
//! ```no_run
//! use limbo::prelude::*;
//! let f = |x: &[f64]| -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>();
//! let mut opt = BoDef::new(2).seed(42).build_optimizer();
//! let best = opt.optimize(&FnEval::new(2, f));
//! println!("best {:?} -> {}", best.x, best.value);
//! ```

pub mod core;
pub mod def;

pub use self::core::{
    BatchStrategy, BoCore, BoError, BoEvent, CoreState, Domain, Observation, Observer,
    RefitSchedule,
};
pub use self::def::{BoDef, DefaultInnerOpt};

use crate::acqui::{AcquiFn, Ucb};
use crate::init::{Initializer, RandomSampling};
use crate::kernel::Matern52;
use crate::mean::DataMean;
use crate::model::{gp::Gp, AdaptiveModel, Model};
use crate::opt::{NelderMead, Optimizer, ParallelRepeater, RandomPoint};
use crate::stop::{MaxIterations, StopCriterion};

/// Result of an optimization run.
#[derive(Clone, Debug, PartialEq)]
pub struct Best {
    /// Best input found, in user coordinates (the unit cube unless a
    /// [`Domain`] was configured).
    pub x: Vec<f64>,
    /// Best observed value.
    pub value: f64,
    /// Total function evaluations used.
    pub evaluations: usize,
}

/// The function being optimized (the paper's "functor" with
/// `dim_in`/`dim_out`; scalar output here, multi-objective lives in
/// [`crate::coordinator::multiobj`]).
pub trait Evaluator: Sync {
    /// Input dimension.
    fn dim(&self) -> usize;
    /// Evaluate the (possibly expensive, noisy) objective. Maximized.
    fn eval(&self, x: &[f64]) -> f64;
}

/// Wrap a closure as an [`Evaluator`].
pub struct FnEval<F: Fn(&[f64]) -> f64 + Sync> {
    dim: usize,
    f: F,
}

impl<F: Fn(&[f64]) -> f64 + Sync> FnEval<F> {
    /// Closure + input dimension.
    pub fn new(dim: usize, f: F) -> Self {
        Self { dim, f }
    }
}

impl<F: Fn(&[f64]) -> f64 + Sync> Evaluator for FnEval<F> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, x: &[f64]) -> f64 {
        (self.f)(x)
    }
}

/// The statically-composed, run-to-completion Bayesian optimizer: an
/// initializer, a stop criterion and an [`Evaluator`]-driving loop on
/// top of the shared [`BoCore`] engine.
pub struct BOptimizer<M, A, I, O, S>
where
    M: Model,
    A: AcquiFn<M>,
    I: Initializer,
    O: Optimizer,
    S: StopCriterion,
{
    /// The shared ask/tell engine (model, acquisition, inner optimizer,
    /// RNG, incumbent, refit schedule, observers).
    pub core: BoCore<M, A, O>,
    /// Initial-design generator.
    pub initializer: I,
    /// Stop rule.
    pub stop: S,
}

/// The default configuration's concrete type (Matérn-5/2 GP + data mean,
/// UCB, random init, random+Nelder-Mead restarts inner optimizer).
pub type DefaultBOptimizer = BOptimizer<
    Gp<Matern52, DataMean>,
    Ucb,
    RandomSampling,
    ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    MaxIterations,
>;

/// The large-budget configuration: same policies as
/// [`DefaultBOptimizer`], but the surrogate is an
/// [`AdaptiveModel`] that migrates from the exact dense GP to the sparse
/// inducing-point GP once the evaluation count outgrows the dense regime.
pub type AdaptiveBOptimizer = BOptimizer<
    AdaptiveModel<Matern52, DataMean>,
    Ucb,
    RandomSampling,
    ParallelRepeater<crate::opt::Chained<RandomPoint, NelderMead>>,
    MaxIterations,
>;

impl<M, A, I, O, S> BOptimizer<M, A, I, O, S>
where
    M: Model,
    A: AcquiFn<M>,
    I: Initializer,
    O: Optimizer,
    S: StopCriterion,
{
    /// Compose an optimizer from explicit components. (The declarative
    /// route is [`BoDef`], which builds the same concrete types.)
    ///
    /// The problem dimensionality is taken from `model.dim()`; a model
    /// that only learns its dimension from data (e.g. the baseline's
    /// `DynGp`) must be driven through [`BoCore`] directly with an
    /// explicit dimension — [`optimize`](Self::optimize) checks the
    /// evaluator against the core's dimension and panics on a mismatch.
    pub fn new(
        model: M,
        acquisition: A,
        initializer: I,
        inner_opt: O,
        stop: S,
        seed: u64,
    ) -> Self {
        let dim = model.dim();
        Self { core: BoCore::new(model, acquisition, inner_opt, dim, seed), initializer, stop }
    }

    /// Set the hyper-parameter refit schedule.
    pub fn with_refit(mut self, schedule: RefitSchedule) -> Self {
        self.core = self.core.with_refit(schedule);
        self
    }

    /// Set the search domain (user bounds mapped to the unit cube).
    pub fn with_domain(mut self, domain: Domain) -> Self {
        self.core = self.core.with_domain(domain);
        self
    }

    /// Subscribe a run observer.
    pub fn with_observer(mut self, observer: impl Observer + 'static) -> Self {
        self.core = self.core.with_observer(observer);
        self
    }

    /// Run the full loop: initialization, then model-guided sampling until
    /// the stop criterion fires. Returns the best sample found.
    ///
    /// A second call continues the same model but re-runs the full
    /// budget — a fresh initial design is drawn and the stop criterion
    /// sees iteration/evaluation counts relative to the call (the
    /// incumbent, like the model, persists across calls).
    pub fn optimize(&mut self, f: &impl Evaluator) -> Best {
        let dim = self.core.dim();
        assert_eq!(
            f.dim(),
            dim,
            "evaluator dim must match the optimizer dim (a dim-0 core means the \
             model did not know its dimension at construction)"
        );
        let call_start_iterations = self.core.iteration();
        let call_start_evaluations = self.core.evaluations();

        // ---- initialization phase ----
        // (skipped only when a definition-built core already queued a
        // design for this call)
        if self.core.init_pending() == 0 {
            let design = self.initializer.points(dim, &mut self.core.rng);
            self.core.seed_design(design);
        }
        while self.core.init_pending() > 0 {
            let x = self.core.propose();
            let y = f.eval(&x);
            self.core.observe(&x, y);
        }

        // ---- model-guided loop ----
        loop {
            let mut ctx = self.core.stop_context();
            ctx.iteration -= call_start_iterations;
            ctx.evaluations -= call_start_evaluations;
            if self.stop.stop(&ctx) {
                break;
            }
            let x = self.core.propose();
            let y = f.eval(&x);
            self.core.observe(&x, y);
        }

        self.core.finish();
        let midpoint = self.core.domain().from_unit(&vec![0.5; dim]);
        let (x, value) = self.core.best().unwrap_or((midpoint, f64::NEG_INFINITY));
        Best { x, value, evaluations: self.core.evaluations() - call_start_evaluations }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ei;
    use crate::kernel::SquaredExpArd;
    use crate::mean::ZeroMean;
    use crate::model::SgpConfig;
    use crate::opt::{Cmaes, OptimizerExt};
    use crate::stat::RunLogger;
    use crate::stop::TargetReached;

    /// The paper's example function (maximum 0 at x = 0 boundary is NOT
    /// the max; actual max of -x^2 sin(2x) on [0,1]^2... the function is
    /// positive where sin(2x) < 0, i.e. x > pi/2 — outside [0,1], so the
    /// max on [0,1]^2 is at x = 0 with value 0).
    fn my_fun(x: &[f64]) -> f64 {
        -x.iter().map(|&v| v * v * (2.0 * v).sin()).sum::<f64>()
    }

    #[test]
    fn default_optimizer_solves_paper_example() {
        let mut opt = BoDef::new(2).seed(7).build_optimizer();
        let best = opt.optimize(&FnEval::new(2, my_fun));
        assert!(best.value > -0.01, "best={}", best.value);
        assert_eq!(best.evaluations, 50); // 10 init + 40 iterations
    }

    #[test]
    fn custom_components_compose() {
        // the paper's "swap the kernel and acquisition" snippet, in Rust
        let model = Gp::new(SquaredExpArd::new(1), ZeroMean, 1e-3);
        let mut opt = BOptimizer::new(
            model,
            Ei::default(),
            crate::init::Lhs { n: 5 },
            Cmaes::new(200),
            MaxIterations(15),
            3,
        );
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.73).powi(2)));
        assert!((best.x[0] - 0.73).abs() < 0.05, "x={:?}", best.x);
    }

    #[test]
    fn adaptive_optimizer_goes_sparse_and_still_converges() {
        let mut opt = BoDef::new(1).seed(13).iterations(30).build_adaptive_optimizer();
        // force an early dense→sparse migration so the sparse path drives
        // most of the run (keeps the test fast)
        opt.core.model = AdaptiveModel::new(Matern52::new(1), DataMean::default(), 1e-4)
            .with_threshold(15)
            .with_sparse_config(SgpConfig { max_inducing: 24, ..Default::default() });
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.37).powi(2)));
        assert!(opt.core.model.is_sparse(), "model should have migrated");
        assert!(best.value > -0.01, "best={}", best.value);
        assert_eq!(best.evaluations, 40); // 10 init + 30 iterations
    }

    #[test]
    fn optimize_reruns_with_a_fresh_budget() {
        let mut opt = BoDef::new(1)
            .seed(19)
            .init_samples(4)
            .refit(RefitSchedule::Never)
            .iterations(5)
            .build_optimizer();
        let f = FnEval::new(1, |x: &[f64]| -(x[0] - 0.5).powi(2));
        let first = opt.optimize(&f);
        assert_eq!(first.evaluations, 9, "4 init + 5 iterations");
        // a second call must re-run the full budget on the same model,
        // not silently no-op against the exhausted stop criterion
        let second = opt.optimize(&f);
        assert_eq!(second.evaluations, 9, "rerun evaluates a fresh 4 + 5 budget");
        assert_eq!(opt.core.model.n_samples(), 18, "model accumulates across calls");
        assert!(second.value >= first.value, "incumbent persists across calls");
    }

    #[test]
    fn warm_start_tells_do_not_eat_the_init_budget() {
        // out-of-band observations before the design is served must be
        // counted as model-guided, not as init points (the refit
        // schedule and GP-UCB beta depend on the iteration counter)
        let mut core = BoCore::new(
            Gp::new(Matern52::new(1), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(16),
            1,
            5,
        );
        core.seed_design(vec![vec![0.2], vec![0.8]]);
        core.observe(&[0.5], -1.0); // warm start, design still queued
        assert_eq!(core.iteration(), 1, "warm tell is a model-guided iteration");
        let a = core.propose();
        assert_eq!(a, vec![0.2], "design still served in order");
        core.observe(&a, -2.0);
        assert_eq!(core.iteration(), 1, "design observation is not an iteration");
        let b = core.propose();
        core.observe(&b, -3.0);
        assert_eq!(core.init_pending(), 0);
        assert_eq!(core.evaluations(), 3);
    }

    #[test]
    fn target_stop_ends_early() {
        let model = Gp::new(Matern52::new(1), DataMean::default(), 1e-4);
        let mut opt = BOptimizer::new(
            model,
            Ucb::default(),
            RandomSampling { n: 3 },
            RandomPoint::new(64),
            (MaxIterations(100), TargetReached(0.9)),
            11,
        );
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| x[0]));
        assert!(best.value >= 0.9);
        assert!(best.evaluations < 103, "should stop well before 100 iters");
    }

    #[test]
    fn refit_schedule_runs_and_still_converges() {
        let model = Gp::new(SquaredExpArd::new(1), DataMean::default(), 1e-3);
        let mut opt = BOptimizer::new(
            model,
            Ucb::default(),
            RandomSampling { n: 6 },
            RandomPoint::new(128).then(NelderMead::default()),
            MaxIterations(12),
            5,
        )
        .with_refit(RefitSchedule::Every(3));
        let best = opt.optimize(&FnEval::new(1, |x: &[f64]| -(x[0] - 0.4).powi(2)));
        assert!(best.value > -0.01, "best={}", best.value);
    }

    #[test]
    fn logs_when_observer_attached() {
        let dir = std::env::temp_dir().join("limbo_bo_stats_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut opt = BoDef::new(1)
            .seed(1)
            .iterations(3)
            .observer(RunLogger::create(&dir).unwrap())
            .build_optimizer();
        let _ = opt.optimize(&FnEval::new(1, |x: &[f64]| -x[0]));
        let best_file = std::fs::read_to_string(dir.join("best.dat")).unwrap();
        assert_eq!(best_file.lines().count(), 13); // 10 init + 3 iters
        assert!(dir.join("meta.dat").exists(), "Stopped event flushes the footer");
    }
}
