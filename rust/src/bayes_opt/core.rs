//! The single ask/tell engine every public entry point drives.
//!
//! [`BoCore`] owns the full Bayesian-optimization loop state machine —
//! initial design queue, fit, propose (single point or q-batch via
//! [`BatchStrategy`]), observe, [`RefitSchedule`] bookkeeping, incumbent
//! tracking — so that [`crate::bayes_opt::BOptimizer`] (run to
//! completion), [`crate::coordinator::AskTellServer`] (sync and
//! threaded), the [`crate::baseline`] comparator and the coordinator
//! drivers are all thin frontends over *one* implementation instead of
//! carrying divergent private copies of the loop.
//!
//! Two supporting pieces live here as well:
//!
//! * [`Domain`] maps user-facing box bounds to the internal unit cube, so
//!   callers stop hand-normalizing their inputs: every [`BoCore`] entry
//!   point speaks user coordinates, every model-facing computation stays
//!   on `[0, 1]^d`.
//! * [`Observer`] is the paper's `stat` policy family as an event bus:
//!   typed [`BoEvent`]s ([`BoEvent::InitDone`], [`BoEvent::Proposal`],
//!   [`BoEvent::Observation`], [`BoEvent::Refit`], [`BoEvent::Stopped`])
//!   are dispatched from the core, and writers such as
//!   [`crate::stat::RunLogger`] subscribe without touching the loop.

use std::collections::VecDeque;

use crate::acqui::batch::{propose_batch_qei, QEi};
use crate::acqui::{AcquiContext, AcquiFn, AcquiObjective};
use crate::model::Model;
use crate::obs::{Counter, Gauge, Phase};
use crate::opt::Optimizer;
use crate::rng::Pcg64;
use crate::stop::StopContext;

/// How often hyper-parameters are re-fit (ML-II) during a run — the one
/// schedule shared by every entry point (optimizer, ask/tell server,
/// baseline comparator).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RefitSchedule {
    /// Never re-fit (fixed hyper-parameters).
    #[default]
    Never,
    /// Re-fit once right after the initial design, then after every
    /// `k`-th model-guided observation.
    Every(usize),
    /// Re-fit when the observation count first reaches `first`, then at
    /// `2·first`, `4·first`, ... — O(log n) refits over an unbounded
    /// run, the right default for an always-on service.
    Doubling {
        /// Observation count of the first refit (clamped to ≥ 2).
        first: usize,
    },
}

/// How [`BoCore::propose_batch`] turns one model posterior into `q`
/// parallel trial proposals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchStrategy {
    /// Greedy pointwise re-maximization with posterior-mean lies: after
    /// each maximization a scratch clone of the model is told its own
    /// posterior mean at the proposed point, flattening the variance
    /// there so the next maximization is steered elsewhere. Cheap
    /// (q ordinary maximizations) and latency-friendly, but the joint
    /// posterior correlation between batch points never enters the score.
    #[default]
    ConstantLiar,
    /// Monte-Carlo multi-point expected improvement over the **joint**
    /// posterior ([`crate::acqui::batch::QEi`], common random numbers
    /// frozen per proposal): strongly correlated points share a sample
    /// path and score barely better than one of them, so diversity is
    /// rewarded exactly where the posterior says it matters. Costs
    /// roughly `mc_samples`× more per objective evaluation than a
    /// pointwise EI — pick it when trials are expensive relative to
    /// proposal compute.
    QEi {
        /// MC draws per acquisition evaluation (rounded down to even;
        /// 256–1024 is a good range — noise shrinks as `1/sqrt`).
        mc_samples: usize,
    },
}

/// A typed construction error for the fallible `BoDef`/[`Domain`] paths
/// (the panicking setters delegate to these and `expect` the result, so
/// services can validate client-supplied definitions without
/// `catch_unwind`).
#[derive(Clone, Debug, PartialEq)]
pub enum BoError {
    /// A component's dimensionality disagrees with the definition's.
    DimMismatch {
        /// The definition's dimension.
        expected: usize,
        /// The offending component's dimension.
        got: usize,
    },
    /// A box bound is non-finite or inverted (`hi <= lo`).
    InvalidBounds {
        /// Index of the offending dimension.
        index: usize,
        /// Lower bound as supplied.
        lo: f64,
        /// Upper bound as supplied.
        hi: f64,
    },
    /// An [`Observation`] carried the wrong number of constraint-channel
    /// values for the model it was told to (a constrained model requires
    /// exactly one value per channel on **every** tell).
    ConstraintArity {
        /// Constraint channels the model carries.
        expected: usize,
        /// Constraint values the observation carried.
        got: usize,
    },
}

impl std::fmt::Display for BoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BoError::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: definition is {expected}-d, component is {got}-d")
            }
            BoError::InvalidBounds { index, lo, hi } => {
                write!(
                    f,
                    "invalid bounds at dimension {index}: ({lo}, {hi}) — bounds must be \
                     finite with hi > lo"
                )
            }
            BoError::ConstraintArity { expected, got } => {
                write!(
                    f,
                    "constraint arity mismatch: the model has {expected} constraint \
                     channel(s), the observation carried {got} value(s)"
                )
            }
        }
    }
}

impl std::error::Error for BoError {}

/// A rectangular search domain: per-dimension `[lo, hi]` bounds mapped
/// to the internal unit cube.
///
/// Every [`BoCore`] entry point (and therefore every builder-produced
/// optimizer and server) speaks **user coordinates**; the model, the
/// acquisition maximization and the initial design all live on
/// `[0, 1]^d`. The default [`Domain::unit`] is the identity mapping, so
/// unit-cube callers pay nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct Domain {
    lo: Vec<f64>,
    span: Vec<f64>,
    unit: bool,
}

impl Domain {
    /// The identity domain `[0, 1]^dim`.
    pub fn unit(dim: usize) -> Self {
        Self { lo: vec![0.0; dim], span: vec![1.0; dim], unit: true }
    }

    /// A box domain from per-dimension `(lo, hi)` bounds.
    ///
    /// # Panics
    /// If any bound is non-finite or `hi <= lo`. The non-panicking form
    /// is [`try_from_bounds`](Self::try_from_bounds).
    pub fn from_bounds(bounds: &[(f64, f64)]) -> Self {
        Self::try_from_bounds(bounds).expect("Domain bounds must be finite with hi > lo")
    }

    /// A box domain from per-dimension `(lo, hi)` bounds, returning
    /// [`BoError::InvalidBounds`] instead of panicking on a non-finite
    /// or inverted bound.
    pub fn try_from_bounds(bounds: &[(f64, f64)]) -> Result<Self, BoError> {
        let mut lo = Vec::with_capacity(bounds.len());
        let mut span = Vec::with_capacity(bounds.len());
        let mut unit = true;
        for (index, &(l, h)) in bounds.iter().enumerate() {
            if !(l.is_finite() && h.is_finite() && h > l) {
                return Err(BoError::InvalidBounds { index, lo: l, hi: h });
            }
            unit &= l == 0.0 && h == 1.0;
            lo.push(l);
            span.push(h - l);
        }
        Ok(Self { lo, span, unit })
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// True for the identity `[0, 1]^d` mapping.
    pub fn is_unit(&self) -> bool {
        self.unit
    }

    /// Per-dimension `(lo, hi)` bounds.
    pub fn bounds(&self) -> Vec<(f64, f64)> {
        self.lo.iter().zip(&self.span).map(|(&l, &s)| (l, l + s)).collect()
    }

    /// Map a user-coordinate point into the unit cube. Points outside
    /// the box map outside `[0, 1]^d` (no clamping).
    pub fn to_unit(&self, x: &[f64]) -> Vec<f64> {
        if self.unit {
            return x.to_vec();
        }
        x.iter().zip(self.lo.iter().zip(&self.span)).map(|(&v, (&l, &s))| (v - l) / s).collect()
    }

    /// Map a unit-cube point into user coordinates.
    pub fn from_unit(&self, u: &[f64]) -> Vec<f64> {
        if self.unit {
            return u.to_vec();
        }
        u.iter().zip(self.lo.iter().zip(&self.span)).map(|(&v, (&l, &s))| l + v * s).collect()
    }
}

/// One typed observation — the record every `tell` path funnels into.
///
/// The plain `(x, y)` tell is the degenerate case (`noise: None`, no
/// constraint values); the noisy and constrained scenarios attach their
/// extra channels to the same record instead of growing parallel APIs:
///
/// * `noise` is the **variance** of the reporting process for this one
///   observation, added on top of the model's homoskedastic noise
///   (heteroskedastic diagonal). `Some(0.0)` (or any non-positive /
///   non-finite value) is normalized away at the tell boundary, so an
///   "exact" noisy tell takes the *identical* code path — and produces
///   the identical event-log bytes — as a plain tell.
/// * `constraints` carries one value per constraint channel of the
///   model being told (`>= 0` = feasible); the arity is validated
///   against [`Model::n_constraint_channels`] before anything mutates.
#[derive(Clone, Debug, PartialEq)]
pub struct Observation {
    /// Evaluated point (user coordinates).
    pub x: Vec<f64>,
    /// Observed objective value.
    pub y: f64,
    /// Per-observation noise **variance**, if the evaluation was noisy.
    pub noise: Option<f64>,
    /// Constraint-channel values (`>= 0` = feasible); empty for
    /// unconstrained models.
    pub constraints: Vec<f64>,
}

impl Observation {
    /// An exact, unconstrained observation — the classic `(x, y)` tell.
    pub fn exact(x: Vec<f64>, y: f64) -> Self {
        Self { x, y, noise: None, constraints: Vec::new() }
    }

    /// An observation reported with `noise` variance.
    pub fn noisy(x: Vec<f64>, y: f64, noise: f64) -> Self {
        Self { x, y, noise: Some(noise), constraints: Vec::new() }
    }

    /// Attach constraint-channel values (builder form).
    pub fn with_constraints(mut self, constraints: Vec<f64>) -> Self {
        self.constraints = constraints;
        self
    }

    /// The effective per-observation noise after boundary
    /// normalization: non-finite and non-positive variances mean "this
    /// observation is exact".
    pub fn effective_noise(&self) -> Option<f64> {
        self.noise.filter(|&v| v.is_finite() && v > 0.0)
    }

    /// True when every constraint channel reports feasible (vacuously
    /// true for unconstrained observations).
    pub fn is_feasible(&self) -> bool {
        self.constraints.iter().all(|&c| c >= 0.0)
    }
}

/// Typed run events dispatched from [`BoCore`] to its [`Observer`]s.
///
/// All coordinates are **user coordinates** (see [`Domain`]).
#[derive(Clone, Debug)]
pub enum BoEvent<'a> {
    /// The queued initial design has been fully evaluated.
    InitDone {
        /// Observations in the model at this point.
        n_samples: usize,
    },
    /// The core proposed trial point(s) — one event per `propose` /
    /// `propose_batch` call.
    Proposal {
        /// Model-guided iteration counter at proposal time.
        iteration: usize,
        /// Number of points proposed (1 for the single-point path).
        q: usize,
        /// The proposed points.
        xs: &'a [Vec<f64>],
    },
    /// An observation entered the model.
    Observation {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: &'a [f64],
        /// Observed value.
        y: f64,
        /// Incumbent best value after this observation.
        best: f64,
    },
    /// A **noisy** observation entered the model (per-observation noise
    /// variance on the heteroskedastic diagonal).
    TellNoisy {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: &'a [f64],
        /// Observed value.
        y: f64,
        /// Per-observation noise variance (normalized: always finite
        /// and `> 0` — an exact tell emits [`BoEvent::Observation`]).
        noise: f64,
        /// Incumbent best value after this observation.
        best: f64,
    },
    /// A **constrained** observation entered the model (objective value
    /// plus one value per constraint channel).
    TellConstrained {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: &'a [f64],
        /// Observed objective value.
        y: f64,
        /// Per-observation noise variance, if the tell was also noisy.
        noise: Option<f64>,
        /// Constraint-channel values (`>= 0` = feasible).
        constraints: &'a [f64],
        /// Incumbent best value after this observation (only feasible
        /// observations become the incumbent).
        best: f64,
    },
    /// A proposal was registered as **pending** (asynchronous mode):
    /// until its observation arrives, further proposals fantasize over
    /// it via kriging-believer mean lies.
    AskPending {
        /// Model-guided iteration counter at proposal time.
        iteration: usize,
        /// The pending point.
        x: &'a [f64],
    },
    /// The model re-optimized its hyper-parameters (ML-II).
    Refit {
        /// Observations in the model at refit time.
        n_samples: usize,
    },
    /// The run finished (driver-initiated; fired once).
    Stopped {
        /// Problem dimensionality.
        dim: usize,
        /// Total observations.
        evaluations: usize,
        /// Final incumbent best value (`-inf` if no data).
        best: f64,
    },
}

/// A run-statistics sink — the paper's `stat` policy family, decoupled
/// from the loop: [`BoCore`] dispatches [`BoEvent`]s, observers write
/// files, collect traces, or feed dashboards without the loop knowing.
pub trait Observer: Send {
    /// Handle one event. Called synchronously from the loop; keep it
    /// cheap (buffer writes, defer flushes to [`BoEvent::Stopped`]).
    fn on_event(&mut self, event: &BoEvent);
}

/// The loop bookkeeping of a [`BoCore`], captured for checkpointing.
///
/// Everything here is *loop* state — counters, the pending init queue,
/// the incumbent (unit coordinates) and the raw RNG registers. Model
/// state (data + hyper-parameters) is checkpointed separately via
/// [`crate::model::ModelState`]; policies (acquisition, inner optimizer,
/// schedules, domain) are rebuilt from the study's
/// [`crate::bayes_opt::BoDef`]. A core restored from a `CoreState` whose
/// model was restored alongside it continues the exact proposal sequence
/// of the captured run.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreState {
    /// Problem dimensionality (validated on import).
    pub dim: usize,
    /// Queued initial-design points not yet proposed (unit cube).
    pub init_queue: Vec<Vec<f64>>,
    /// Total initial-design points ever queued.
    pub init_total: usize,
    /// Design points handed out so far.
    pub init_served: usize,
    /// Observations attributed to the initial design so far.
    pub init_observed: usize,
    /// Model-guided observations.
    pub iteration: usize,
    /// Total observations.
    pub evaluations: usize,
    /// Incumbent best `(x, y)` in unit coordinates.
    pub best: Option<(Vec<f64>, f64)>,
    /// Next observation count that triggers a doubling-schedule refit.
    pub next_refit: Option<usize>,
    /// Whether `finish` has already fired.
    pub finished: bool,
    /// RNG `(state, increment)` registers.
    pub rng: (u64, u64),
    /// Outstanding pending proposals (unit coordinates, asynchronous
    /// mode): asked but not yet told.
    pub pending: Vec<Vec<f64>>,
}

/// The single ask/tell core: one generic, monomorphized implementation
/// of the propose/observe/refit loop state machine.
///
/// `M`, `A`, `O` are the model, acquisition and inner-optimizer policies
/// (statically dispatched — swapping one is a type change, not a virtual
/// call). Frontends differ only in *who drives* the loop:
///
/// * [`crate::bayes_opt::BOptimizer::optimize`] drives it to completion
///   against an [`crate::bayes_opt::Evaluator`] and a stop criterion;
/// * [`crate::coordinator::AskTellServer`] exposes `propose`/`observe`
///   as `ask`/`tell` (inline or over channels from a server thread);
/// * [`crate::baseline::BayesOptLike`] drives it with trait-object
///   components to reproduce the paper's Figure-1 comparison.
pub struct BoCore<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// Surrogate model (fitted in place; stores unit-cube inputs).
    pub model: M,
    /// Acquisition policy.
    pub acquisition: A,
    /// Inner optimizer maximizing the acquisition each iteration.
    pub inner_opt: O,
    /// RNG (drives the initial design, the inner optimizer and random
    /// probes).
    pub rng: Pcg64,
    dim: usize,
    domain: Domain,
    /// Queued initial-design points (unit cube), served by `propose`
    /// before any acquisition maximization happens.
    init_queue: VecDeque<Vec<f64>>,
    init_total: usize,
    /// Design points handed out by `propose`/`propose_batch` so far.
    init_served: usize,
    /// Observations attributed to the initial design so far: an
    /// observation is an init observation iff a served design point is
    /// still awaiting one — out-of-band warm-start tells are counted as
    /// model-guided even while design points sit in the queue.
    init_observed: usize,
    /// Model-guided observations (excludes the initial design).
    iteration: usize,
    /// Total observations.
    evaluations: usize,
    /// Incumbent best `(x, y)` in unit coordinates.
    best: Option<(Vec<f64>, f64)>,
    refit: RefitSchedule,
    /// Next observation count that triggers a doubling-schedule refit.
    next_refit: Option<usize>,
    batch_strategy: BatchStrategy,
    observers: Vec<Box<dyn Observer>>,
    finished: bool,
    /// Asynchronous mode: proposals register as pending and later
    /// proposals fantasize over them (see
    /// [`propose_pending`](Self::propose_pending)).
    async_pending: bool,
    /// Outstanding pending proposals in unit coordinates (asked, not
    /// yet told). Always empty when `async_pending` is off.
    pending: Vec<Vec<f64>>,
}

impl<M, A, O> BoCore<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// Compose a core from explicit policies. A model that already has
    /// data (`fit` / deserialized state) seeds the incumbent, so the
    /// first proposal never runs EI/UCB against a `-inf` incumbent.
    pub fn new(model: M, acquisition: A, inner_opt: O, dim: usize, seed: u64) -> Self {
        let best = model.best_sample();
        Self {
            model,
            acquisition,
            inner_opt,
            rng: Pcg64::seed(seed),
            dim,
            domain: Domain::unit(dim),
            init_queue: VecDeque::new(),
            init_total: 0,
            init_served: 0,
            init_observed: 0,
            iteration: 0,
            evaluations: 0,
            best,
            refit: RefitSchedule::Never,
            next_refit: None,
            batch_strategy: BatchStrategy::default(),
            observers: Vec::new(),
            finished: false,
            async_pending: false,
            pending: Vec::new(),
        }
    }

    /// Set the search domain (user bounds mapped to the unit cube).
    ///
    /// # Panics
    /// If the domain dimensionality differs from the core's.
    pub fn with_domain(mut self, domain: Domain) -> Self {
        assert_eq!(domain.dim(), self.dim, "Domain dim must match the optimizer dim");
        self.domain = domain;
        self
    }

    /// Set the hyper-parameter refit schedule.
    pub fn with_refit(mut self, schedule: RefitSchedule) -> Self {
        self.refit = schedule;
        self.next_refit = match schedule {
            RefitSchedule::Doubling { first } => Some(first.max(2)),
            _ => None,
        };
        self
    }

    /// Select the q-point proposal strategy for
    /// [`propose_batch`](Self::propose_batch).
    pub fn with_batch_strategy(mut self, strategy: BatchStrategy) -> Self {
        self.batch_strategy = strategy;
        self
    }

    /// Enable asynchronous pending-point mode: drivers route asks
    /// through [`propose_pending`](Self::propose_pending), outstanding
    /// proposals are fantasized over until their tell arrives, and any
    /// ask/tell interleaving from q workers is well-defined.
    pub fn with_async_pending(mut self, on: bool) -> Self {
        self.async_pending = on;
        self
    }

    /// Subscribe an observer to the run's event stream.
    pub fn with_observer(mut self, observer: impl Observer + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Subscribe an observer (in-place form).
    pub fn add_observer(&mut self, observer: impl Observer + 'static) {
        self.observers.push(Box::new(observer));
    }

    /// Subscribe an already-boxed observer (the type-erased form the
    /// [`crate::bayes_opt::BoDef`] builder collects).
    pub fn add_boxed_observer(&mut self, observer: Box<dyn Observer>) {
        self.observers.push(observer);
    }

    /// Queue unit-cube initial-design points; `propose` serves them (in
    /// order) before any acquisition maximization happens.
    pub fn seed_design(&mut self, points: Vec<Vec<f64>>) {
        self.init_total += points.len();
        self.init_queue.extend(points);
    }

    /// Problem dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The search domain.
    pub fn domain(&self) -> &Domain {
        &self.domain
    }

    /// Queued initial-design points not yet proposed.
    pub fn init_pending(&self) -> usize {
        self.init_queue.len()
    }

    /// Model-guided observations so far (excludes the initial design).
    pub fn iteration(&self) -> usize {
        self.iteration
    }

    /// Total observations so far.
    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    /// Next observation count that triggers a doubling-schedule refit.
    pub fn next_refit(&self) -> Option<usize> {
        self.next_refit
    }

    /// The configured q-point proposal strategy.
    pub fn batch_strategy(&self) -> BatchStrategy {
        self.batch_strategy
    }

    /// Whether asynchronous pending-point mode is on.
    pub fn async_pending(&self) -> bool {
        self.async_pending
    }

    /// Outstanding pending proposals (asked but not yet told).
    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Incumbent best `(x, value)` in user coordinates.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.as_ref().map(|(x, y)| (self.domain.from_unit(x), *y))
    }

    /// Incumbent value for the acquisition context: the tracked best,
    /// else the model's own best observation (a pre-fitted model whose
    /// argmax is unknown — e.g. restored value-only state — must still
    /// threshold EI correctly), else `-inf` (no data at all).
    pub fn incumbent_value(&self) -> f64 {
        self.best
            .as_ref()
            .map(|b| b.1)
            .or_else(|| self.model.best_observation())
            .unwrap_or(f64::NEG_INFINITY)
    }

    /// Re-seed the incumbent from the model's stored samples. Drivers
    /// that refit the model on externally rewritten data (e.g. the
    /// ParEGO scalarization changes every iteration) call this so the
    /// acquisition thresholds against the *current* objective.
    pub fn refresh_incumbent(&mut self) {
        self.best = self.model.best_sample();
    }

    /// Snapshot for the stop criteria.
    pub fn stop_context(&self) -> StopContext {
        StopContext {
            iteration: self.iteration,
            evaluations: self.evaluations,
            best: self.incumbent_value(),
        }
    }

    fn emit(observers: &mut [Box<dyn Observer>], event: &BoEvent) {
        for obs in observers.iter_mut() {
            obs.on_event(event);
        }
    }

    /// Next suggested trial (user coordinates): a queued initial-design
    /// point if any remain, a random probe while the model has no data,
    /// else the acquisition maximizer.
    pub fn propose(&mut self) -> Vec<f64> {
        let _span = crate::obs::span(Phase::Ask);
        let unit = if let Some(x) = self.init_queue.pop_front() {
            self.init_served += 1;
            x
        } else if self.model.n_samples() == 0 {
            self.rng.unit_point(self.dim)
        } else {
            self.maximize_acquisition()
        };
        let x = self.domain.from_unit(&unit);
        let xs = [x];
        Self::emit(
            &mut self.observers,
            &BoEvent::Proposal { iteration: self.iteration, q: 1, xs: &xs },
        );
        let [x] = xs;
        x
    }

    fn maximize_acquisition(&mut self) -> Vec<f64> {
        let ctx = AcquiContext::new(self.iteration, self.incumbent_value(), self.dim);
        let objective = AcquiObjective::new(&self.model, &self.acquisition, ctx);
        self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
    }

    /// Next suggested trial in **asynchronous** mode: like
    /// [`propose`](Self::propose), but the proposal is registered as
    /// pending (retired by the matching `tell`) and the acquisition is
    /// maximized over a kriging-believer fantasy of the outstanding
    /// pending points — a scratch clone of the model is told its own
    /// posterior mean at each pending point, so q workers can ask and
    /// tell in any interleaving without the acquisition re-proposing a
    /// point that is already in flight.
    ///
    /// With no outstanding pending point this is computationally
    /// identical to [`propose`](Self::propose) (the clone is skipped),
    /// so a strictly alternating ask/tell sequence reproduces the
    /// synchronous trace bit for bit.
    pub fn propose_pending(&mut self) -> Vec<f64>
    where
        M: Clone,
    {
        let _span = crate::obs::span(Phase::Ask);
        let unit = if let Some(x) = self.init_queue.pop_front() {
            self.init_served += 1;
            x
        } else if self.model.n_samples() == 0 {
            self.rng.unit_point(self.dim)
        } else {
            self.maximize_with_pending()
        };
        let x = self.domain.from_unit(&unit);
        // the retire key must equal what `try_observe` derives from the
        // user-coordinate point we hand out: to_unit(from_unit(u)) is
        // not bitwise `u` on a non-unit domain
        let key = self.domain.to_unit(&x);
        let xs = [x];
        Self::emit(
            &mut self.observers,
            &BoEvent::Proposal { iteration: self.iteration, q: 1, xs: &xs },
        );
        Self::emit(
            &mut self.observers,
            &BoEvent::AskPending { iteration: self.iteration, x: &xs[0] },
        );
        self.pending.push(key);
        crate::obs::gauge_set(Gauge::PendingTrials, self.pending.len() as u64);
        let [x] = xs;
        x
    }

    /// Acquisition maximization over the kriging-believer fantasy: the
    /// believer clone is told its own posterior mean at every pending
    /// point (in registration order), flattening the variance there so
    /// the maximizer steers clear of in-flight trials. Empty pending =
    /// the plain [`maximize_acquisition`](Self::maximize_acquisition)
    /// path, bit for bit.
    fn maximize_with_pending(&mut self) -> Vec<f64>
    where
        M: Clone,
    {
        if self.pending.is_empty() {
            return self.maximize_acquisition();
        }
        let mut believer = self.model.clone();
        let mut lied_best = self.incumbent_value();
        for p in &self.pending {
            let (lie, _) = believer.predict(p);
            believer.add_sample(p, lie);
            lied_best = lied_best.max(lie);
        }
        let ctx = AcquiContext::new(self.iteration, lied_best, self.dim);
        let objective = AcquiObjective::new(&believer, &self.acquisition, ctx);
        self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
    }

    /// Propose `q` diverse trials (user coordinates) to run in parallel,
    /// using the configured [`BatchStrategy`]. Queued initial-design
    /// points are served first; while the model has no data the
    /// remainder are random probes.
    pub fn propose_batch(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let _span = crate::obs::span(Phase::Ask);
        let q = q.max(1);
        let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
        while batch.len() < q {
            if let Some(x) = self.init_queue.pop_front() {
                self.init_served += 1;
                batch.push(x);
            } else {
                break;
            }
        }
        let remaining = q - batch.len();
        if remaining > 0 {
            if self.model.n_samples() == 0 {
                batch.extend((0..remaining).map(|_| self.rng.unit_point(self.dim)));
            } else {
                let proposed = match self.batch_strategy {
                    BatchStrategy::ConstantLiar => self.propose_constant_liar(remaining),
                    BatchStrategy::QEi { mc_samples } => self.propose_qei(remaining, mc_samples),
                };
                batch.extend(proposed);
            }
        }
        // dedupe over the WHOLE batch: an acquisition proposal can land
        // on a still-queued init point (or two init points can collide),
        // and the diversity guarantee covers the batch as a set
        let batch = self.dedupe_batch(batch);
        let batch: Vec<Vec<f64>> = batch.iter().map(|x| self.domain.from_unit(x)).collect();
        Self::emit(
            &mut self.observers,
            &BoEvent::Proposal { iteration: self.iteration, q: batch.len(), xs: &batch },
        );
        if self.async_pending {
            for x in &batch {
                Self::emit(
                    &mut self.observers,
                    &BoEvent::AskPending { iteration: self.iteration, x },
                );
                let key = self.domain.to_unit(x);
                self.pending.push(key);
            }
            crate::obs::gauge_set(Gauge::PendingTrials, self.pending.len() as u64);
        }
        batch
    }

    /// Constant-liar proposals: after each maximization the model is
    /// *told its own posterior mean* at the proposed point (the "lie"),
    /// the acquisition is re-maximized on the lied model, and all lies
    /// are rolled back at the end (the lies go into a scratch clone;
    /// `self.model` only ever sees real observations). Lying flattens
    /// the posterior variance around already-proposed points, steering
    /// the next maximization elsewhere.
    fn propose_constant_liar(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let mut liar = self.model.clone();
        let mut lied_best = self.incumbent_value();
        // asynchronous mode: outstanding pending trials enter the liar
        // first, so a q-batch never re-proposes an in-flight point
        // (empty pending = the classic path, bit for bit)
        for p in &self.pending {
            let (lie, _) = liar.predict(p);
            liar.add_sample(p, lie);
            lied_best = lied_best.max(lie);
        }
        let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
        for k in 0..q {
            let ctx = AcquiContext::new(self.iteration + k, lied_best, self.dim);
            let x = {
                let objective = AcquiObjective::new(&liar, &self.acquisition, ctx);
                self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
            };
            let (lie, _) = liar.predict(&x);
            liar.add_sample(&x, lie);
            lied_best = lied_best.max(lie);
            batch.push(x);
        }
        batch
    }

    /// Joint-posterior qEI proposals: one frozen-CRN [`QEi`] estimator
    /// per round (fresh seed per call, deterministic within the call),
    /// maximized by greedy marginal gains plus a joint refinement pass
    /// over the flattened `q·d` batch vector ([`propose_batch_qei`]).
    /// The pointwise acquisition is not consulted here — qEI *is* the
    /// acquisition for the whole batch.
    fn propose_qei(&mut self, q: usize, mc_samples: usize) -> Vec<Vec<f64>> {
        let ctx = AcquiContext::new(self.iteration, self.incumbent_value(), self.dim);
        let seed = self.rng.next_u64();
        let qei = QEi::new(mc_samples, q, seed);
        propose_batch_qei(&self.model, &qei, &self.inner_opt, ctx, self.dim, q, &mut self.rng)
    }

    /// Degenerate acquisition landscapes can propose (near-)coincident
    /// points despite the lie/joint penalty; replace duplicates with
    /// random probes so the batch stays diverse (1e-8 squared distance
    /// ~ 1e-4 per axis).
    fn dedupe_batch(&mut self, batch: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(batch.len());
        for x in batch {
            let duplicate = out.iter().any(|p| {
                p.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() < 1e-8
            });
            out.push(if duplicate { self.rng.unit_point(self.dim) } else { x });
        }
        out
    }

    /// Report an observation (user coordinates). Updates the model and
    /// the incumbent, advances the iteration/refit bookkeeping, and may
    /// trigger a scheduled ML-II refit.
    ///
    /// An observation counts toward the initial design iff a served
    /// design point is still awaiting its outcome; out-of-band
    /// warm-start observations (a `tell` before any design point was
    /// asked for) are model-guided iterations. The attribution is by
    /// count, not by matching `x`: a warm-start tell interleaved
    /// *between* a design point's ask and its tell is attributed to the
    /// design slot (indistinguishable without comparing coordinates —
    /// warm-start before asking if exact accounting matters).
    pub fn observe(&mut self, x: &[f64], y: f64) {
        self.try_observe(&Observation::exact(x.to_vec(), y)).expect(
            "plain observe on a constrained model — tell one value per constraint \
             channel via try_observe/tell_constrained",
        );
    }

    /// Report one typed [`Observation`] — the single intake every tell
    /// flavor funnels into. Returns [`BoError::ConstraintArity`] (before
    /// anything mutates) when the record's constraint values disagree
    /// with the model's channel count.
    ///
    /// Per-observation noise goes onto the model's heteroskedastic
    /// diagonal ([`Model::add_sample_noisy`]); constraint values feed
    /// the model's constraint channels; only **feasible** observations
    /// can become the incumbent; a pending proposal matching `x` is
    /// retired (asynchronous mode).
    pub fn try_observe(&mut self, obs: &Observation) -> Result<(), BoError> {
        let _span = crate::obs::span(Phase::Tell);
        let expected = self.model.n_constraint_channels();
        if obs.constraints.len() != expected {
            return Err(BoError::ConstraintArity { expected, got: obs.constraints.len() });
        }
        let noise = obs.effective_noise();
        let unit = self.domain.to_unit(&obs.x);
        match noise {
            Some(nv) => self.model.add_sample_noisy(&unit, obs.y, nv),
            None => self.model.add_sample(&unit, obs.y),
        }
        if !obs.constraints.is_empty() {
            self.model.add_constraint_sample(&unit, &obs.constraints);
        }
        if let Some(i) = self.pending.iter().position(|p| p == &unit) {
            self.pending.remove(i);
            crate::obs::gauge_set(Gauge::PendingTrials, self.pending.len() as u64);
        }
        crate::obs::gauge_set(Gauge::ModelSamples, self.model.n_samples() as u64);
        self.evaluations += 1;
        self.finished = false;
        let in_init = self.init_observed < self.init_served;
        if in_init {
            self.init_observed += 1;
        } else {
            self.iteration += 1;
        }
        if obs.y.is_finite()
            && obs.is_feasible()
            && self.best.as_ref().map_or(true, |b| obs.y > b.1)
        {
            self.best = Some((unit, obs.y));
        }
        let best = self.incumbent_value();
        let event = if !obs.constraints.is_empty() {
            BoEvent::TellConstrained {
                evaluations: self.evaluations,
                x: &obs.x,
                y: obs.y,
                noise,
                constraints: &obs.constraints,
                best,
            }
        } else if let Some(nv) = noise {
            BoEvent::TellNoisy {
                evaluations: self.evaluations,
                x: &obs.x,
                y: obs.y,
                noise: nv,
                best,
            }
        } else {
            BoEvent::Observation { evaluations: self.evaluations, x: &obs.x, y: obs.y, best }
        };
        Self::emit(&mut self.observers, &event);
        let init_completed =
            in_init && self.init_observed == self.init_total && self.init_queue.is_empty();
        if init_completed {
            Self::emit(
                &mut self.observers,
                &BoEvent::InitDone { n_samples: self.model.n_samples() },
            );
        }
        self.advance_refit_schedule(in_init, init_completed);
        Ok(())
    }

    /// Apply the refit schedule after one observation.
    fn advance_refit_schedule(&mut self, in_init: bool, init_completed: bool) {
        let n = self.model.n_samples();
        let fire = match self.refit {
            RefitSchedule::Never => false,
            RefitSchedule::Every(k) => {
                if in_init {
                    // refit once right after the initial design
                    init_completed && n >= 2
                } else {
                    k > 0 && self.iteration % k == 0
                }
            }
            RefitSchedule::Doubling { .. } => match self.next_refit {
                Some(next) if n >= next => {
                    // advance past the *current* count: a burst of
                    // observations (the propose_batch workflow) or a
                    // pre-fitted model can leave n >= 2·next, and a
                    // single doubling would then trigger a full ML-II
                    // refit on every subsequent observation until the
                    // schedule catches up
                    let mut next = next;
                    while n >= next {
                        next = next.saturating_mul(2);
                    }
                    self.next_refit = Some(next);
                    true
                }
                _ => false,
            },
        };
        if fire {
            {
                let _span = crate::obs::span(Phase::Refit);
                crate::obs::counter_add(Counter::Refits, 1);
                self.model.optimize_hyperparams();
            }
            Self::emit(&mut self.observers, &BoEvent::Refit { n_samples: n });
        }
    }

    /// Capture the loop bookkeeping for a checkpoint (pure read — the
    /// live run is not perturbed). Pair with the model's own state
    /// capture; see [`CoreState`] for what is and is not covered.
    pub fn export_state(&self) -> CoreState {
        CoreState {
            dim: self.dim,
            init_queue: self.init_queue.iter().cloned().collect(),
            init_total: self.init_total,
            init_served: self.init_served,
            init_observed: self.init_observed,
            iteration: self.iteration,
            evaluations: self.evaluations,
            best: self.best.clone(),
            next_refit: self.next_refit,
            finished: self.finished,
            rng: self.rng.state(),
            pending: self.pending.clone(),
        }
    }

    /// Restore loop bookkeeping captured by
    /// [`export_state`](Self::export_state) into a freshly built core
    /// (same `BoDef`, model restored separately).
    ///
    /// # Panics
    /// If the captured dimensionality differs from the core's.
    pub fn import_state(&mut self, state: CoreState) {
        assert_eq!(state.dim, self.dim, "CoreState dim must match the core dim");
        self.init_queue = state.init_queue.into();
        self.init_total = state.init_total;
        self.init_served = state.init_served;
        self.init_observed = state.init_observed;
        self.iteration = state.iteration;
        self.evaluations = state.evaluations;
        self.best = state.best;
        self.next_refit = state.next_refit;
        self.finished = state.finished;
        self.rng = Pcg64::from_state(state.rng.0, state.rng.1);
        self.pending = state.pending;
    }

    /// Signal the end of the run to the observers (fired once; later
    /// calls are no-ops). Drivers that own a run lifecycle — the
    /// run-to-completion optimizer, the server thread on shutdown —
    /// call this so file-writing observers can flush.
    pub fn finish(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        let event = BoEvent::Stopped {
            dim: self.dim,
            evaluations: self.evaluations,
            best: self.incumbent_value(),
        };
        Self::emit(&mut self.observers, &event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ucb;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::gp::Gp;
    use crate::opt::RandomPoint;
    use std::sync::{Arc, Mutex};

    fn make_core() -> BoCore<Gp<Matern52, DataMean>, Ucb, RandomPoint> {
        BoCore::new(
            Gp::new(Matern52::new(1), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(32),
            1,
            7,
        )
    }

    #[test]
    fn domain_round_trips_and_identity() {
        let d = Domain::from_bounds(&[(-5.0, 10.0), (0.0, 15.0)]);
        assert!(!d.is_unit());
        assert_eq!(d.dim(), 2);
        let u = d.to_unit(&[-5.0, 15.0]);
        assert!((u[0] - 0.0).abs() < 1e-15 && (u[1] - 1.0).abs() < 1e-15);
        let x = d.from_unit(&[0.5, 0.5]);
        assert!((x[0] - 2.5).abs() < 1e-12 && (x[1] - 7.5).abs() < 1e-12);
        let id = Domain::unit(3);
        assert!(id.is_unit());
        assert_eq!(id.from_unit(&[0.25, 0.5, 0.75]), vec![0.25, 0.5, 0.75]);
        assert!(Domain::from_bounds(&[(0.0, 1.0)]).is_unit());
    }

    #[test]
    #[should_panic]
    fn domain_rejects_inverted_bounds() {
        let _ = Domain::from_bounds(&[(1.0, 0.0)]);
    }

    #[test]
    fn init_queue_served_before_acquisition() {
        let mut core = make_core();
        core.seed_design(vec![vec![0.25], vec![0.75]]);
        assert_eq!(core.init_pending(), 2);
        let a = core.propose();
        assert_eq!(a, vec![0.25]);
        core.observe(&a, -1.0);
        assert_eq!(core.iteration(), 0, "init observations are not iterations");
        let b = core.propose();
        assert_eq!(b, vec![0.75]);
        core.observe(&b, 1.0);
        assert_eq!(core.init_pending(), 0);
        assert_eq!(core.best().unwrap().1, 1.0);
        // model-guided from here
        let c = core.propose();
        core.observe(&c, 0.0);
        assert_eq!(core.iteration(), 1);
        assert_eq!(core.evaluations(), 3);
    }

    #[test]
    fn bounded_domain_maps_both_directions() {
        let mut core = make_core().with_domain(Domain::from_bounds(&[(10.0, 20.0)]));
        core.seed_design(vec![vec![0.5]]);
        let x = core.propose();
        assert!((x[0] - 15.0).abs() < 1e-12, "init point mapped to user coords");
        core.observe(&x, 3.0);
        let (bx, bv) = core.best().unwrap();
        assert!((bx[0] - 15.0).abs() < 1e-12);
        assert_eq!(bv, 3.0);
        // proposals stay inside the user box
        for _ in 0..5 {
            let x = core.propose();
            assert!((10.0..=20.0).contains(&x[0]), "proposal {x:?} outside the box");
            core.observe(&x, -(x[0] - 14.0).powi(2));
        }
    }

    #[derive(Clone, Default)]
    struct Counter(Arc<Mutex<(usize, usize, usize, usize, usize)>>);

    impl Observer for Counter {
        fn on_event(&mut self, event: &BoEvent) {
            let mut c = self.0.lock().unwrap();
            match event {
                BoEvent::InitDone { .. } => c.0 += 1,
                BoEvent::Proposal { .. } => c.1 += 1,
                BoEvent::Observation { .. }
                | BoEvent::TellNoisy { .. }
                | BoEvent::TellConstrained { .. } => c.2 += 1,
                BoEvent::Refit { .. } => c.3 += 1,
                BoEvent::Stopped { .. } => c.4 += 1,
                BoEvent::AskPending { .. } => {}
            }
        }
    }

    #[test]
    fn event_bus_fires_the_full_lifecycle() {
        let counter = Counter::default();
        let mut core = make_core().with_refit(RefitSchedule::Doubling { first: 4 });
        core.model.hp_opt.config.restarts = 1;
        core.model.hp_opt.config.iterations = 2;
        core.add_observer(counter.clone());
        core.seed_design(vec![vec![0.2], vec![0.8]]);
        for _ in 0..6 {
            let x = core.propose();
            core.observe(&x, -(x[0] - 0.4).powi(2));
        }
        core.finish();
        core.finish(); // idempotent
        let c = counter.0.lock().unwrap().clone();
        assert_eq!(c.0, 1, "InitDone once");
        assert_eq!(c.1, 6, "one Proposal per propose");
        assert_eq!(c.2, 6, "one Observation per observe");
        assert_eq!(c.3, 1, "Doubling{{4}} refits once at n=4 within 6 evals");
        assert_eq!(c.4, 1, "Stopped exactly once");
    }

    /// An observer that appends `"<name>:<event>"` to a shared log, so a
    /// test can see the interleaving across multiple subscribers.
    struct NamedRecorder {
        name: &'static str,
        log: Arc<Mutex<Vec<String>>>,
    }

    impl Observer for NamedRecorder {
        fn on_event(&mut self, event: &BoEvent) {
            let tag = match event {
                BoEvent::InitDone { .. } => "init_done",
                BoEvent::Proposal { .. } => "proposal",
                BoEvent::Observation { .. } => "observation",
                BoEvent::TellNoisy { .. } => "tell_noisy",
                BoEvent::TellConstrained { .. } => "tell_constrained",
                BoEvent::AskPending { .. } => "ask_pending",
                BoEvent::Refit { .. } => "refit",
                BoEvent::Stopped { .. } => "stopped",
            };
            self.log.lock().unwrap().push(format!("{}:{tag}", self.name));
        }
    }

    /// Observers fire in subscription order, per event. This ordering is
    /// load-bearing: `MetricsObserver` appends its phase breakdown to
    /// the `meta.dat` that `RunLogger::finish` truncates, so "subscribed
    /// after ⇒ runs after" is what keeps both in the file.
    #[test]
    fn observers_dispatch_in_subscription_order() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut core = make_core()
            .with_observer(NamedRecorder { name: "first", log: Arc::clone(&log) })
            .with_observer(NamedRecorder { name: "second", log: Arc::clone(&log) });
        core.seed_design(vec![vec![0.25]]);
        let x = core.propose();
        core.observe(&x, 0.5);
        core.finish();
        let entries = log.lock().unwrap().clone();
        assert!(!entries.is_empty());
        assert_eq!(entries.len() % 2, 0, "every event reaches both: {entries:?}");
        for pair in entries.chunks(2) {
            let (f, s) = (&pair[0], &pair[1]);
            let event = f.strip_prefix("first:").expect("first subscriber fires first");
            assert_eq!(s, &format!("second:{event}"), "same event, in order: {entries:?}");
        }
        assert_eq!(entries.last().unwrap(), "second:stopped");
    }

    #[test]
    fn doubling_schedule_advances_past_bursts() {
        let mut core = make_core().with_refit(RefitSchedule::Doubling { first: 2 });
        core.model.hp_opt.config.restarts = 1;
        core.model.hp_opt.config.iterations = 2;
        for i in 0..5 {
            core.observe(&[0.1 + 0.2 * i as f64], (i as f64).sin());
        }
        assert_eq!(core.next_refit(), Some(8), "2 -> 4 -> 8 after n=5");
    }

    #[test]
    fn nonfinite_observations_never_become_incumbent() {
        let mut core = make_core();
        core.observe(&[0.5], f64::INFINITY);
        core.observe(&[0.6], f64::NAN);
        assert!(core.best().is_none());
        core.observe(&[0.7], -3.0);
        assert_eq!(core.best().unwrap().1, -3.0);
    }

    #[test]
    fn zero_noise_tell_is_the_exact_tell_code_path() {
        // the normalized record must drive the homoskedastic fast path:
        // no per-observation noise is retained, and the emitted event is
        // a plain Observation (checked via the Counter observer above,
        // which tallies the three tell flavors together)
        let mut a = make_core();
        let mut b = make_core();
        for (i, x) in [0.1, 0.4, 0.7].iter().enumerate() {
            a.observe(&[*x], i as f64);
            b.try_observe(&Observation::noisy(vec![*x], i as f64, 0.0)).unwrap();
        }
        assert!(!b.model.has_noisy_observations());
        let (ma, va) = a.model.predict(&[0.5]);
        let (mb, vb) = b.model.predict(&[0.5]);
        assert_eq!(ma.to_bits(), mb.to_bits());
        assert_eq!(va.to_bits(), vb.to_bits());
        assert_eq!(a.rng.state(), b.rng.state());
    }

    #[test]
    fn constraint_arity_is_rejected_before_mutation() {
        let mut core = make_core(); // unconstrained model: 0 channels
        let err = core
            .try_observe(&Observation::exact(vec![0.5], 1.0).with_constraints(vec![0.3]))
            .unwrap_err();
        assert_eq!(err, BoError::ConstraintArity { expected: 0, got: 1 });
        assert_eq!(core.evaluations(), 0, "rejected tell must not count");
        assert_eq!(core.model.n_samples(), 0, "rejected tell must not enter the model");
    }

    #[test]
    fn infeasible_observations_never_become_incumbent() {
        use crate::model::ModelBank;
        let mk = || Gp::new(Matern52::new(1), DataMean::default(), 1e-3);
        let bank = ModelBank::new(mk(), vec![mk()]);
        let mut core = BoCore::new(bank, Ucb::default(), RandomPoint::new(16), 1, 9);
        core.try_observe(&Observation::exact(vec![0.3], 5.0).with_constraints(vec![-0.2]))
            .unwrap();
        assert!(core.best().is_none(), "infeasible can't be the incumbent");
        core.try_observe(&Observation::exact(vec![0.6], 1.0).with_constraints(vec![0.4]))
            .unwrap();
        assert_eq!(core.best().unwrap().1, 1.0, "feasible lower value wins");
        assert_eq!(core.model.constraint(0).n_samples(), 2);
    }

    #[test]
    fn pending_points_register_fantasize_and_retire() {
        let mut core = make_core().with_async_pending(true);
        assert!(core.async_pending());
        // warm up the model so asks are model-guided
        core.observe(&[0.2], -1.0);
        core.observe(&[0.8], 1.0);
        let a = core.propose_pending();
        let b = core.propose_pending();
        let c = core.propose_pending();
        assert_eq!(core.pending_count(), 3);
        // out-of-order retirement: tell b, then c, then a
        core.observe(&b, 0.1);
        assert_eq!(core.pending_count(), 2);
        core.observe(&c, 0.2);
        core.observe(&a, 0.3);
        assert_eq!(core.pending_count(), 0);
        // an out-of-band tell (never asked) leaves pending untouched
        let d = core.propose_pending();
        core.observe(&[0.123], 0.0);
        assert_eq!(core.pending_count(), 1);
        core.observe(&d, 0.0);
        assert_eq!(core.pending_count(), 0);
    }

    #[test]
    fn pending_state_survives_export_import() {
        let mut core = make_core().with_async_pending(true);
        core.observe(&[0.2], -1.0);
        let a = core.propose_pending();
        let state = core.export_state();
        assert_eq!(state.pending.len(), 1);
        let mut fresh = make_core().with_async_pending(true);
        fresh.observe(&[0.2], -1.0);
        fresh.import_state(state);
        assert_eq!(fresh.pending_count(), 1);
        fresh.observe(&a, 0.5);
        assert_eq!(fresh.pending_count(), 0, "restored pending point retires");
    }
}
