//! The single ask/tell engine every public entry point drives.
//!
//! [`BoCore`] owns the full Bayesian-optimization loop state machine —
//! initial design queue, fit, propose (single point or q-batch via
//! [`BatchStrategy`]), observe, [`RefitSchedule`] bookkeeping, incumbent
//! tracking — so that [`crate::bayes_opt::BOptimizer`] (run to
//! completion), [`crate::coordinator::AskTellServer`] (sync and
//! threaded), the [`crate::baseline`] comparator and the coordinator
//! drivers are all thin frontends over *one* implementation instead of
//! carrying divergent private copies of the loop.
//!
//! Two supporting pieces live here as well:
//!
//! * [`Domain`] maps user-facing box bounds to the internal unit cube, so
//!   callers stop hand-normalizing their inputs: every [`BoCore`] entry
//!   point speaks user coordinates, every model-facing computation stays
//!   on `[0, 1]^d`.
//! * [`Observer`] is the paper's `stat` policy family as an event bus:
//!   typed [`BoEvent`]s ([`BoEvent::InitDone`], [`BoEvent::Proposal`],
//!   [`BoEvent::Observation`], [`BoEvent::Refit`], [`BoEvent::Stopped`])
//!   are dispatched from the core, and writers such as
//!   [`crate::stat::RunLogger`] subscribe without touching the loop.

use std::collections::VecDeque;

use crate::acqui::batch::{propose_batch_qei, QEi};
use crate::acqui::{AcquiContext, AcquiFn, AcquiObjective};
use crate::model::Model;
use crate::obs::{Counter, Gauge, Phase};
use crate::opt::Optimizer;
use crate::rng::Pcg64;
use crate::stop::StopContext;

/// How often hyper-parameters are re-fit (ML-II) during a run — the one
/// schedule shared by every entry point (optimizer, ask/tell server,
/// baseline comparator).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RefitSchedule {
    /// Never re-fit (fixed hyper-parameters).
    #[default]
    Never,
    /// Re-fit once right after the initial design, then after every
    /// `k`-th model-guided observation.
    Every(usize),
    /// Re-fit when the observation count first reaches `first`, then at
    /// `2·first`, `4·first`, ... — O(log n) refits over an unbounded
    /// run, the right default for an always-on service.
    Doubling {
        /// Observation count of the first refit (clamped to ≥ 2).
        first: usize,
    },
}

/// How [`BoCore::propose_batch`] turns one model posterior into `q`
/// parallel trial proposals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchStrategy {
    /// Greedy pointwise re-maximization with posterior-mean lies: after
    /// each maximization a scratch clone of the model is told its own
    /// posterior mean at the proposed point, flattening the variance
    /// there so the next maximization is steered elsewhere. Cheap
    /// (q ordinary maximizations) and latency-friendly, but the joint
    /// posterior correlation between batch points never enters the score.
    #[default]
    ConstantLiar,
    /// Monte-Carlo multi-point expected improvement over the **joint**
    /// posterior ([`crate::acqui::batch::QEi`], common random numbers
    /// frozen per proposal): strongly correlated points share a sample
    /// path and score barely better than one of them, so diversity is
    /// rewarded exactly where the posterior says it matters. Costs
    /// roughly `mc_samples`× more per objective evaluation than a
    /// pointwise EI — pick it when trials are expensive relative to
    /// proposal compute.
    QEi {
        /// MC draws per acquisition evaluation (rounded down to even;
        /// 256–1024 is a good range — noise shrinks as `1/sqrt`).
        mc_samples: usize,
    },
}

/// A typed construction error for the fallible `BoDef`/[`Domain`] paths
/// (the panicking setters delegate to these and `expect` the result, so
/// services can validate client-supplied definitions without
/// `catch_unwind`).
#[derive(Clone, Debug, PartialEq)]
pub enum BoError {
    /// A component's dimensionality disagrees with the definition's.
    DimMismatch {
        /// The definition's dimension.
        expected: usize,
        /// The offending component's dimension.
        got: usize,
    },
    /// A box bound is non-finite or inverted (`hi <= lo`).
    InvalidBounds {
        /// Index of the offending dimension.
        index: usize,
        /// Lower bound as supplied.
        lo: f64,
        /// Upper bound as supplied.
        hi: f64,
    },
}

impl std::fmt::Display for BoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BoError::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: definition is {expected}-d, component is {got}-d")
            }
            BoError::InvalidBounds { index, lo, hi } => {
                write!(
                    f,
                    "invalid bounds at dimension {index}: ({lo}, {hi}) — bounds must be \
                     finite with hi > lo"
                )
            }
        }
    }
}

impl std::error::Error for BoError {}

/// A rectangular search domain: per-dimension `[lo, hi]` bounds mapped
/// to the internal unit cube.
///
/// Every [`BoCore`] entry point (and therefore every builder-produced
/// optimizer and server) speaks **user coordinates**; the model, the
/// acquisition maximization and the initial design all live on
/// `[0, 1]^d`. The default [`Domain::unit`] is the identity mapping, so
/// unit-cube callers pay nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct Domain {
    lo: Vec<f64>,
    span: Vec<f64>,
    unit: bool,
}

impl Domain {
    /// The identity domain `[0, 1]^dim`.
    pub fn unit(dim: usize) -> Self {
        Self { lo: vec![0.0; dim], span: vec![1.0; dim], unit: true }
    }

    /// A box domain from per-dimension `(lo, hi)` bounds.
    ///
    /// # Panics
    /// If any bound is non-finite or `hi <= lo`. The non-panicking form
    /// is [`try_from_bounds`](Self::try_from_bounds).
    pub fn from_bounds(bounds: &[(f64, f64)]) -> Self {
        Self::try_from_bounds(bounds).expect("Domain bounds must be finite with hi > lo")
    }

    /// A box domain from per-dimension `(lo, hi)` bounds, returning
    /// [`BoError::InvalidBounds`] instead of panicking on a non-finite
    /// or inverted bound.
    pub fn try_from_bounds(bounds: &[(f64, f64)]) -> Result<Self, BoError> {
        let mut lo = Vec::with_capacity(bounds.len());
        let mut span = Vec::with_capacity(bounds.len());
        let mut unit = true;
        for (index, &(l, h)) in bounds.iter().enumerate() {
            if !(l.is_finite() && h.is_finite() && h > l) {
                return Err(BoError::InvalidBounds { index, lo: l, hi: h });
            }
            unit &= l == 0.0 && h == 1.0;
            lo.push(l);
            span.push(h - l);
        }
        Ok(Self { lo, span, unit })
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// True for the identity `[0, 1]^d` mapping.
    pub fn is_unit(&self) -> bool {
        self.unit
    }

    /// Per-dimension `(lo, hi)` bounds.
    pub fn bounds(&self) -> Vec<(f64, f64)> {
        self.lo.iter().zip(&self.span).map(|(&l, &s)| (l, l + s)).collect()
    }

    /// Map a user-coordinate point into the unit cube. Points outside
    /// the box map outside `[0, 1]^d` (no clamping).
    pub fn to_unit(&self, x: &[f64]) -> Vec<f64> {
        if self.unit {
            return x.to_vec();
        }
        x.iter().zip(self.lo.iter().zip(&self.span)).map(|(&v, (&l, &s))| (v - l) / s).collect()
    }

    /// Map a unit-cube point into user coordinates.
    pub fn from_unit(&self, u: &[f64]) -> Vec<f64> {
        if self.unit {
            return u.to_vec();
        }
        u.iter().zip(self.lo.iter().zip(&self.span)).map(|(&v, (&l, &s))| l + v * s).collect()
    }
}

/// Typed run events dispatched from [`BoCore`] to its [`Observer`]s.
///
/// All coordinates are **user coordinates** (see [`Domain`]).
#[derive(Clone, Debug)]
pub enum BoEvent<'a> {
    /// The queued initial design has been fully evaluated.
    InitDone {
        /// Observations in the model at this point.
        n_samples: usize,
    },
    /// The core proposed trial point(s) — one event per `propose` /
    /// `propose_batch` call.
    Proposal {
        /// Model-guided iteration counter at proposal time.
        iteration: usize,
        /// Number of points proposed (1 for the single-point path).
        q: usize,
        /// The proposed points.
        xs: &'a [Vec<f64>],
    },
    /// An observation entered the model.
    Observation {
        /// Total observations including this one.
        evaluations: usize,
        /// Evaluated point.
        x: &'a [f64],
        /// Observed value.
        y: f64,
        /// Incumbent best value after this observation.
        best: f64,
    },
    /// The model re-optimized its hyper-parameters (ML-II).
    Refit {
        /// Observations in the model at refit time.
        n_samples: usize,
    },
    /// The run finished (driver-initiated; fired once).
    Stopped {
        /// Problem dimensionality.
        dim: usize,
        /// Total observations.
        evaluations: usize,
        /// Final incumbent best value (`-inf` if no data).
        best: f64,
    },
}

/// A run-statistics sink — the paper's `stat` policy family, decoupled
/// from the loop: [`BoCore`] dispatches [`BoEvent`]s, observers write
/// files, collect traces, or feed dashboards without the loop knowing.
pub trait Observer: Send {
    /// Handle one event. Called synchronously from the loop; keep it
    /// cheap (buffer writes, defer flushes to [`BoEvent::Stopped`]).
    fn on_event(&mut self, event: &BoEvent);
}

/// The loop bookkeeping of a [`BoCore`], captured for checkpointing.
///
/// Everything here is *loop* state — counters, the pending init queue,
/// the incumbent (unit coordinates) and the raw RNG registers. Model
/// state (data + hyper-parameters) is checkpointed separately via
/// [`crate::model::ModelState`]; policies (acquisition, inner optimizer,
/// schedules, domain) are rebuilt from the study's
/// [`crate::bayes_opt::BoDef`]. A core restored from a `CoreState` whose
/// model was restored alongside it continues the exact proposal sequence
/// of the captured run.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreState {
    /// Problem dimensionality (validated on import).
    pub dim: usize,
    /// Queued initial-design points not yet proposed (unit cube).
    pub init_queue: Vec<Vec<f64>>,
    /// Total initial-design points ever queued.
    pub init_total: usize,
    /// Design points handed out so far.
    pub init_served: usize,
    /// Observations attributed to the initial design so far.
    pub init_observed: usize,
    /// Model-guided observations.
    pub iteration: usize,
    /// Total observations.
    pub evaluations: usize,
    /// Incumbent best `(x, y)` in unit coordinates.
    pub best: Option<(Vec<f64>, f64)>,
    /// Next observation count that triggers a doubling-schedule refit.
    pub next_refit: Option<usize>,
    /// Whether `finish` has already fired.
    pub finished: bool,
    /// RNG `(state, increment)` registers.
    pub rng: (u64, u64),
}

/// The single ask/tell core: one generic, monomorphized implementation
/// of the propose/observe/refit loop state machine.
///
/// `M`, `A`, `O` are the model, acquisition and inner-optimizer policies
/// (statically dispatched — swapping one is a type change, not a virtual
/// call). Frontends differ only in *who drives* the loop:
///
/// * [`crate::bayes_opt::BOptimizer::optimize`] drives it to completion
///   against an [`crate::bayes_opt::Evaluator`] and a stop criterion;
/// * [`crate::coordinator::AskTellServer`] exposes `propose`/`observe`
///   as `ask`/`tell` (inline or over channels from a server thread);
/// * [`crate::baseline::BayesOptLike`] drives it with trait-object
///   components to reproduce the paper's Figure-1 comparison.
pub struct BoCore<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// Surrogate model (fitted in place; stores unit-cube inputs).
    pub model: M,
    /// Acquisition policy.
    pub acquisition: A,
    /// Inner optimizer maximizing the acquisition each iteration.
    pub inner_opt: O,
    /// RNG (drives the initial design, the inner optimizer and random
    /// probes).
    pub rng: Pcg64,
    dim: usize,
    domain: Domain,
    /// Queued initial-design points (unit cube), served by `propose`
    /// before any acquisition maximization happens.
    init_queue: VecDeque<Vec<f64>>,
    init_total: usize,
    /// Design points handed out by `propose`/`propose_batch` so far.
    init_served: usize,
    /// Observations attributed to the initial design so far: an
    /// observation is an init observation iff a served design point is
    /// still awaiting one — out-of-band warm-start tells are counted as
    /// model-guided even while design points sit in the queue.
    init_observed: usize,
    /// Model-guided observations (excludes the initial design).
    iteration: usize,
    /// Total observations.
    evaluations: usize,
    /// Incumbent best `(x, y)` in unit coordinates.
    best: Option<(Vec<f64>, f64)>,
    refit: RefitSchedule,
    /// Next observation count that triggers a doubling-schedule refit.
    next_refit: Option<usize>,
    batch_strategy: BatchStrategy,
    observers: Vec<Box<dyn Observer>>,
    finished: bool,
}

impl<M, A, O> BoCore<M, A, O>
where
    M: Model,
    A: AcquiFn<M>,
    O: Optimizer,
{
    /// Compose a core from explicit policies. A model that already has
    /// data (`fit` / deserialized state) seeds the incumbent, so the
    /// first proposal never runs EI/UCB against a `-inf` incumbent.
    pub fn new(model: M, acquisition: A, inner_opt: O, dim: usize, seed: u64) -> Self {
        let best = model.best_sample();
        Self {
            model,
            acquisition,
            inner_opt,
            rng: Pcg64::seed(seed),
            dim,
            domain: Domain::unit(dim),
            init_queue: VecDeque::new(),
            init_total: 0,
            init_served: 0,
            init_observed: 0,
            iteration: 0,
            evaluations: 0,
            best,
            refit: RefitSchedule::Never,
            next_refit: None,
            batch_strategy: BatchStrategy::default(),
            observers: Vec::new(),
            finished: false,
        }
    }

    /// Set the search domain (user bounds mapped to the unit cube).
    ///
    /// # Panics
    /// If the domain dimensionality differs from the core's.
    pub fn with_domain(mut self, domain: Domain) -> Self {
        assert_eq!(domain.dim(), self.dim, "Domain dim must match the optimizer dim");
        self.domain = domain;
        self
    }

    /// Set the hyper-parameter refit schedule.
    pub fn with_refit(mut self, schedule: RefitSchedule) -> Self {
        self.refit = schedule;
        self.next_refit = match schedule {
            RefitSchedule::Doubling { first } => Some(first.max(2)),
            _ => None,
        };
        self
    }

    /// Select the q-point proposal strategy for
    /// [`propose_batch`](Self::propose_batch).
    pub fn with_batch_strategy(mut self, strategy: BatchStrategy) -> Self {
        self.batch_strategy = strategy;
        self
    }

    /// Subscribe an observer to the run's event stream.
    pub fn with_observer(mut self, observer: impl Observer + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Subscribe an observer (in-place form).
    pub fn add_observer(&mut self, observer: impl Observer + 'static) {
        self.observers.push(Box::new(observer));
    }

    /// Subscribe an already-boxed observer (the type-erased form the
    /// [`crate::bayes_opt::BoDef`] builder collects).
    pub fn add_boxed_observer(&mut self, observer: Box<dyn Observer>) {
        self.observers.push(observer);
    }

    /// Queue unit-cube initial-design points; `propose` serves them (in
    /// order) before any acquisition maximization happens.
    pub fn seed_design(&mut self, points: Vec<Vec<f64>>) {
        self.init_total += points.len();
        self.init_queue.extend(points);
    }

    /// Problem dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The search domain.
    pub fn domain(&self) -> &Domain {
        &self.domain
    }

    /// Queued initial-design points not yet proposed.
    pub fn init_pending(&self) -> usize {
        self.init_queue.len()
    }

    /// Model-guided observations so far (excludes the initial design).
    pub fn iteration(&self) -> usize {
        self.iteration
    }

    /// Total observations so far.
    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    /// Next observation count that triggers a doubling-schedule refit.
    pub fn next_refit(&self) -> Option<usize> {
        self.next_refit
    }

    /// The configured q-point proposal strategy.
    pub fn batch_strategy(&self) -> BatchStrategy {
        self.batch_strategy
    }

    /// Incumbent best `(x, value)` in user coordinates.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.as_ref().map(|(x, y)| (self.domain.from_unit(x), *y))
    }

    /// Incumbent value for the acquisition context: the tracked best,
    /// else the model's own best observation (a pre-fitted model whose
    /// argmax is unknown — e.g. restored value-only state — must still
    /// threshold EI correctly), else `-inf` (no data at all).
    pub fn incumbent_value(&self) -> f64 {
        self.best
            .as_ref()
            .map(|b| b.1)
            .or_else(|| self.model.best_observation())
            .unwrap_or(f64::NEG_INFINITY)
    }

    /// Re-seed the incumbent from the model's stored samples. Drivers
    /// that refit the model on externally rewritten data (e.g. the
    /// ParEGO scalarization changes every iteration) call this so the
    /// acquisition thresholds against the *current* objective.
    pub fn refresh_incumbent(&mut self) {
        self.best = self.model.best_sample();
    }

    /// Snapshot for the stop criteria.
    pub fn stop_context(&self) -> StopContext {
        StopContext {
            iteration: self.iteration,
            evaluations: self.evaluations,
            best: self.incumbent_value(),
        }
    }

    fn emit(observers: &mut [Box<dyn Observer>], event: &BoEvent) {
        for obs in observers.iter_mut() {
            obs.on_event(event);
        }
    }

    /// Next suggested trial (user coordinates): a queued initial-design
    /// point if any remain, a random probe while the model has no data,
    /// else the acquisition maximizer.
    pub fn propose(&mut self) -> Vec<f64> {
        let _span = crate::obs::span(Phase::Ask);
        let unit = if let Some(x) = self.init_queue.pop_front() {
            self.init_served += 1;
            x
        } else if self.model.n_samples() == 0 {
            self.rng.unit_point(self.dim)
        } else {
            self.maximize_acquisition()
        };
        let x = self.domain.from_unit(&unit);
        let xs = [x];
        Self::emit(
            &mut self.observers,
            &BoEvent::Proposal { iteration: self.iteration, q: 1, xs: &xs },
        );
        let [x] = xs;
        x
    }

    fn maximize_acquisition(&mut self) -> Vec<f64> {
        let ctx = AcquiContext::new(self.iteration, self.incumbent_value(), self.dim);
        let objective = AcquiObjective::new(&self.model, &self.acquisition, ctx);
        self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
    }

    /// Propose `q` diverse trials (user coordinates) to run in parallel,
    /// using the configured [`BatchStrategy`]. Queued initial-design
    /// points are served first; while the model has no data the
    /// remainder are random probes.
    pub fn propose_batch(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let _span = crate::obs::span(Phase::Ask);
        let q = q.max(1);
        let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
        while batch.len() < q {
            if let Some(x) = self.init_queue.pop_front() {
                self.init_served += 1;
                batch.push(x);
            } else {
                break;
            }
        }
        let remaining = q - batch.len();
        if remaining > 0 {
            if self.model.n_samples() == 0 {
                batch.extend((0..remaining).map(|_| self.rng.unit_point(self.dim)));
            } else {
                let proposed = match self.batch_strategy {
                    BatchStrategy::ConstantLiar => self.propose_constant_liar(remaining),
                    BatchStrategy::QEi { mc_samples } => self.propose_qei(remaining, mc_samples),
                };
                batch.extend(proposed);
            }
        }
        // dedupe over the WHOLE batch: an acquisition proposal can land
        // on a still-queued init point (or two init points can collide),
        // and the diversity guarantee covers the batch as a set
        let batch = self.dedupe_batch(batch);
        let batch: Vec<Vec<f64>> = batch.iter().map(|x| self.domain.from_unit(x)).collect();
        Self::emit(
            &mut self.observers,
            &BoEvent::Proposal { iteration: self.iteration, q: batch.len(), xs: &batch },
        );
        batch
    }

    /// Constant-liar proposals: after each maximization the model is
    /// *told its own posterior mean* at the proposed point (the "lie"),
    /// the acquisition is re-maximized on the lied model, and all lies
    /// are rolled back at the end (the lies go into a scratch clone;
    /// `self.model` only ever sees real observations). Lying flattens
    /// the posterior variance around already-proposed points, steering
    /// the next maximization elsewhere.
    fn propose_constant_liar(&mut self, q: usize) -> Vec<Vec<f64>>
    where
        M: Clone,
    {
        let mut liar = self.model.clone();
        let mut lied_best = self.incumbent_value();
        let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
        for k in 0..q {
            let ctx = AcquiContext::new(self.iteration + k, lied_best, self.dim);
            let x = {
                let objective = AcquiObjective::new(&liar, &self.acquisition, ctx);
                self.inner_opt.optimize(&objective, self.dim, &mut self.rng).x
            };
            let (lie, _) = liar.predict(&x);
            liar.add_sample(&x, lie);
            lied_best = lied_best.max(lie);
            batch.push(x);
        }
        batch
    }

    /// Joint-posterior qEI proposals: one frozen-CRN [`QEi`] estimator
    /// per round (fresh seed per call, deterministic within the call),
    /// maximized by greedy marginal gains plus a joint refinement pass
    /// over the flattened `q·d` batch vector ([`propose_batch_qei`]).
    /// The pointwise acquisition is not consulted here — qEI *is* the
    /// acquisition for the whole batch.
    fn propose_qei(&mut self, q: usize, mc_samples: usize) -> Vec<Vec<f64>> {
        let ctx = AcquiContext::new(self.iteration, self.incumbent_value(), self.dim);
        let seed = self.rng.next_u64();
        let qei = QEi::new(mc_samples, q, seed);
        propose_batch_qei(&self.model, &qei, &self.inner_opt, ctx, self.dim, q, &mut self.rng)
    }

    /// Degenerate acquisition landscapes can propose (near-)coincident
    /// points despite the lie/joint penalty; replace duplicates with
    /// random probes so the batch stays diverse (1e-8 squared distance
    /// ~ 1e-4 per axis).
    fn dedupe_batch(&mut self, batch: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(batch.len());
        for x in batch {
            let duplicate = out.iter().any(|p| {
                p.iter().zip(&x).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() < 1e-8
            });
            out.push(if duplicate { self.rng.unit_point(self.dim) } else { x });
        }
        out
    }

    /// Report an observation (user coordinates). Updates the model and
    /// the incumbent, advances the iteration/refit bookkeeping, and may
    /// trigger a scheduled ML-II refit.
    ///
    /// An observation counts toward the initial design iff a served
    /// design point is still awaiting its outcome; out-of-band
    /// warm-start observations (a `tell` before any design point was
    /// asked for) are model-guided iterations. The attribution is by
    /// count, not by matching `x`: a warm-start tell interleaved
    /// *between* a design point's ask and its tell is attributed to the
    /// design slot (indistinguishable without comparing coordinates —
    /// warm-start before asking if exact accounting matters).
    pub fn observe(&mut self, x: &[f64], y: f64) {
        let _span = crate::obs::span(Phase::Tell);
        let unit = self.domain.to_unit(x);
        self.model.add_sample(&unit, y);
        crate::obs::gauge_set(Gauge::ModelSamples, self.model.n_samples() as u64);
        self.evaluations += 1;
        self.finished = false;
        let in_init = self.init_observed < self.init_served;
        if in_init {
            self.init_observed += 1;
        } else {
            self.iteration += 1;
        }
        if y.is_finite() && self.best.as_ref().map_or(true, |b| y > b.1) {
            self.best = Some((unit, y));
        }
        let best = self.incumbent_value();
        Self::emit(
            &mut self.observers,
            &BoEvent::Observation { evaluations: self.evaluations, x, y, best },
        );
        let init_completed =
            in_init && self.init_observed == self.init_total && self.init_queue.is_empty();
        if init_completed {
            Self::emit(
                &mut self.observers,
                &BoEvent::InitDone { n_samples: self.model.n_samples() },
            );
        }
        self.advance_refit_schedule(in_init, init_completed);
    }

    /// Apply the refit schedule after one observation.
    fn advance_refit_schedule(&mut self, in_init: bool, init_completed: bool) {
        let n = self.model.n_samples();
        let fire = match self.refit {
            RefitSchedule::Never => false,
            RefitSchedule::Every(k) => {
                if in_init {
                    // refit once right after the initial design
                    init_completed && n >= 2
                } else {
                    k > 0 && self.iteration % k == 0
                }
            }
            RefitSchedule::Doubling { .. } => match self.next_refit {
                Some(next) if n >= next => {
                    // advance past the *current* count: a burst of
                    // observations (the propose_batch workflow) or a
                    // pre-fitted model can leave n >= 2·next, and a
                    // single doubling would then trigger a full ML-II
                    // refit on every subsequent observation until the
                    // schedule catches up
                    let mut next = next;
                    while n >= next {
                        next = next.saturating_mul(2);
                    }
                    self.next_refit = Some(next);
                    true
                }
                _ => false,
            },
        };
        if fire {
            {
                let _span = crate::obs::span(Phase::Refit);
                crate::obs::counter_add(Counter::Refits, 1);
                self.model.optimize_hyperparams();
            }
            Self::emit(&mut self.observers, &BoEvent::Refit { n_samples: n });
        }
    }

    /// Capture the loop bookkeeping for a checkpoint (pure read — the
    /// live run is not perturbed). Pair with the model's own state
    /// capture; see [`CoreState`] for what is and is not covered.
    pub fn export_state(&self) -> CoreState {
        CoreState {
            dim: self.dim,
            init_queue: self.init_queue.iter().cloned().collect(),
            init_total: self.init_total,
            init_served: self.init_served,
            init_observed: self.init_observed,
            iteration: self.iteration,
            evaluations: self.evaluations,
            best: self.best.clone(),
            next_refit: self.next_refit,
            finished: self.finished,
            rng: self.rng.state(),
        }
    }

    /// Restore loop bookkeeping captured by
    /// [`export_state`](Self::export_state) into a freshly built core
    /// (same `BoDef`, model restored separately).
    ///
    /// # Panics
    /// If the captured dimensionality differs from the core's.
    pub fn import_state(&mut self, state: CoreState) {
        assert_eq!(state.dim, self.dim, "CoreState dim must match the core dim");
        self.init_queue = state.init_queue.into();
        self.init_total = state.init_total;
        self.init_served = state.init_served;
        self.init_observed = state.init_observed;
        self.iteration = state.iteration;
        self.evaluations = state.evaluations;
        self.best = state.best;
        self.next_refit = state.next_refit;
        self.finished = state.finished;
        self.rng = Pcg64::from_state(state.rng.0, state.rng.1);
    }

    /// Signal the end of the run to the observers (fired once; later
    /// calls are no-ops). Drivers that own a run lifecycle — the
    /// run-to-completion optimizer, the server thread on shutdown —
    /// call this so file-writing observers can flush.
    pub fn finish(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        let event = BoEvent::Stopped {
            dim: self.dim,
            evaluations: self.evaluations,
            best: self.incumbent_value(),
        };
        Self::emit(&mut self.observers, &event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::Ucb;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::gp::Gp;
    use crate::opt::RandomPoint;
    use std::sync::{Arc, Mutex};

    fn make_core() -> BoCore<Gp<Matern52, DataMean>, Ucb, RandomPoint> {
        BoCore::new(
            Gp::new(Matern52::new(1), DataMean::default(), 1e-3),
            Ucb::default(),
            RandomPoint::new(32),
            1,
            7,
        )
    }

    #[test]
    fn domain_round_trips_and_identity() {
        let d = Domain::from_bounds(&[(-5.0, 10.0), (0.0, 15.0)]);
        assert!(!d.is_unit());
        assert_eq!(d.dim(), 2);
        let u = d.to_unit(&[-5.0, 15.0]);
        assert!((u[0] - 0.0).abs() < 1e-15 && (u[1] - 1.0).abs() < 1e-15);
        let x = d.from_unit(&[0.5, 0.5]);
        assert!((x[0] - 2.5).abs() < 1e-12 && (x[1] - 7.5).abs() < 1e-12);
        let id = Domain::unit(3);
        assert!(id.is_unit());
        assert_eq!(id.from_unit(&[0.25, 0.5, 0.75]), vec![0.25, 0.5, 0.75]);
        assert!(Domain::from_bounds(&[(0.0, 1.0)]).is_unit());
    }

    #[test]
    #[should_panic]
    fn domain_rejects_inverted_bounds() {
        let _ = Domain::from_bounds(&[(1.0, 0.0)]);
    }

    #[test]
    fn init_queue_served_before_acquisition() {
        let mut core = make_core();
        core.seed_design(vec![vec![0.25], vec![0.75]]);
        assert_eq!(core.init_pending(), 2);
        let a = core.propose();
        assert_eq!(a, vec![0.25]);
        core.observe(&a, -1.0);
        assert_eq!(core.iteration(), 0, "init observations are not iterations");
        let b = core.propose();
        assert_eq!(b, vec![0.75]);
        core.observe(&b, 1.0);
        assert_eq!(core.init_pending(), 0);
        assert_eq!(core.best().unwrap().1, 1.0);
        // model-guided from here
        let c = core.propose();
        core.observe(&c, 0.0);
        assert_eq!(core.iteration(), 1);
        assert_eq!(core.evaluations(), 3);
    }

    #[test]
    fn bounded_domain_maps_both_directions() {
        let mut core = make_core().with_domain(Domain::from_bounds(&[(10.0, 20.0)]));
        core.seed_design(vec![vec![0.5]]);
        let x = core.propose();
        assert!((x[0] - 15.0).abs() < 1e-12, "init point mapped to user coords");
        core.observe(&x, 3.0);
        let (bx, bv) = core.best().unwrap();
        assert!((bx[0] - 15.0).abs() < 1e-12);
        assert_eq!(bv, 3.0);
        // proposals stay inside the user box
        for _ in 0..5 {
            let x = core.propose();
            assert!((10.0..=20.0).contains(&x[0]), "proposal {x:?} outside the box");
            core.observe(&x, -(x[0] - 14.0).powi(2));
        }
    }

    #[derive(Clone, Default)]
    struct Counter(Arc<Mutex<(usize, usize, usize, usize, usize)>>);

    impl Observer for Counter {
        fn on_event(&mut self, event: &BoEvent) {
            let mut c = self.0.lock().unwrap();
            match event {
                BoEvent::InitDone { .. } => c.0 += 1,
                BoEvent::Proposal { .. } => c.1 += 1,
                BoEvent::Observation { .. } => c.2 += 1,
                BoEvent::Refit { .. } => c.3 += 1,
                BoEvent::Stopped { .. } => c.4 += 1,
            }
        }
    }

    #[test]
    fn event_bus_fires_the_full_lifecycle() {
        let counter = Counter::default();
        let mut core = make_core().with_refit(RefitSchedule::Doubling { first: 4 });
        core.model.hp_opt.config.restarts = 1;
        core.model.hp_opt.config.iterations = 2;
        core.add_observer(counter.clone());
        core.seed_design(vec![vec![0.2], vec![0.8]]);
        for _ in 0..6 {
            let x = core.propose();
            core.observe(&x, -(x[0] - 0.4).powi(2));
        }
        core.finish();
        core.finish(); // idempotent
        let c = counter.0.lock().unwrap().clone();
        assert_eq!(c.0, 1, "InitDone once");
        assert_eq!(c.1, 6, "one Proposal per propose");
        assert_eq!(c.2, 6, "one Observation per observe");
        assert_eq!(c.3, 1, "Doubling{{4}} refits once at n=4 within 6 evals");
        assert_eq!(c.4, 1, "Stopped exactly once");
    }

    /// An observer that appends `"<name>:<event>"` to a shared log, so a
    /// test can see the interleaving across multiple subscribers.
    struct NamedRecorder {
        name: &'static str,
        log: Arc<Mutex<Vec<String>>>,
    }

    impl Observer for NamedRecorder {
        fn on_event(&mut self, event: &BoEvent) {
            let tag = match event {
                BoEvent::InitDone { .. } => "init_done",
                BoEvent::Proposal { .. } => "proposal",
                BoEvent::Observation { .. } => "observation",
                BoEvent::Refit { .. } => "refit",
                BoEvent::Stopped { .. } => "stopped",
            };
            self.log.lock().unwrap().push(format!("{}:{tag}", self.name));
        }
    }

    /// Observers fire in subscription order, per event. This ordering is
    /// load-bearing: `MetricsObserver` appends its phase breakdown to
    /// the `meta.dat` that `RunLogger::finish` truncates, so "subscribed
    /// after ⇒ runs after" is what keeps both in the file.
    #[test]
    fn observers_dispatch_in_subscription_order() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut core = make_core()
            .with_observer(NamedRecorder { name: "first", log: Arc::clone(&log) })
            .with_observer(NamedRecorder { name: "second", log: Arc::clone(&log) });
        core.seed_design(vec![vec![0.25]]);
        let x = core.propose();
        core.observe(&x, 0.5);
        core.finish();
        let entries = log.lock().unwrap().clone();
        assert!(!entries.is_empty());
        assert_eq!(entries.len() % 2, 0, "every event reaches both: {entries:?}");
        for pair in entries.chunks(2) {
            let (f, s) = (&pair[0], &pair[1]);
            let event = f.strip_prefix("first:").expect("first subscriber fires first");
            assert_eq!(s, &format!("second:{event}"), "same event, in order: {entries:?}");
        }
        assert_eq!(entries.last().unwrap(), "second:stopped");
    }

    #[test]
    fn doubling_schedule_advances_past_bursts() {
        let mut core = make_core().with_refit(RefitSchedule::Doubling { first: 2 });
        core.model.hp_opt.config.restarts = 1;
        core.model.hp_opt.config.iterations = 2;
        for i in 0..5 {
            core.observe(&[0.1 + 0.2 * i as f64], (i as f64).sin());
        }
        assert_eq!(core.next_refit(), Some(8), "2 -> 4 -> 8 after n=5");
    }

    #[test]
    fn nonfinite_observations_never_become_incumbent() {
        let mut core = make_core();
        core.observe(&[0.5], f64::INFINITY);
        core.observe(&[0.6], f64::NAN);
        assert!(core.best().is_none());
        core.observe(&[0.7], -3.0);
        assert_eq!(core.best().unwrap().1, -3.0);
    }
}
