//! Native Gaussian-process regression with incremental Cholesky updates.
//!
//! The per-iteration cost profile mirrors Limbo's GP:
//! * [`Gp::add_sample`] extends the existing Cholesky factor in O(n^2)
//!   (one forward solve + one new row) instead of refactoring in O(n^3);
//! * [`Gp::predict`] is O(n) for the mean (cached `alpha`) and O(n^2) for
//!   the variance (one forward solve);
//! * hyper-parameter refits ([`Gp::optimize_hyperparams`]) are the only
//!   O(n^3) path, scheduled by the caller.

use crate::kernel::Kernel;
use crate::la::{dot, CholeskyFactor, Matrix};
use crate::mean::MeanFn;
use crate::model::hp_opt::{KernelLFOpt, LmlModel};
use crate::model::Model;
use crate::obs::{self, Phase};

/// Gaussian process with kernel `K`, prior mean `M`.
#[derive(Clone)]
pub struct Gp<K: Kernel, M: MeanFn> {
    kernel: K,
    mean: M,
    /// log sigma_n (observation noise std).
    log_noise: f64,
    /// Whether [`optimize_hyperparams`](Model::optimize_hyperparams) also
    /// tunes the noise.
    pub learn_noise: bool,
    /// Hyper-parameter optimizer settings used by `optimize_hyperparams`.
    pub hp_opt: KernelLFOpt,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    /// Extra per-observation noise variance added to the train diagonal
    /// (heteroskedastic intake). Empty when no observation ever carried
    /// extra noise — the homoskedastic fast path; otherwise kept parallel
    /// to `ys` with `0.0` for exact observations.
    noise_vars: Vec<f64>,
    chol: CholeskyFactor,
    alpha: Vec<f64>,
    best: Option<f64>,
}

impl<K: Kernel, M: MeanFn> Gp<K, M> {
    /// New empty GP. `noise` is the observation noise std `sigma_n`.
    pub fn new(kernel: K, mean: M, noise: f64) -> Self {
        assert!(noise > 0.0, "noise std must be positive");
        Self {
            kernel,
            mean,
            log_noise: noise.ln(),
            learn_noise: false,
            hp_opt: KernelLFOpt::default(),
            xs: Vec::new(),
            ys: Vec::new(),
            noise_vars: Vec::new(),
            chol: CholeskyFactor::empty(),
            alpha: Vec::new(),
            best: None,
        }
    }

    /// Observation noise variance `sigma_n^2`.
    pub fn noise_var(&self) -> f64 {
        (2.0 * self.log_noise).exp()
    }

    /// Set the observation noise std and refit.
    pub fn set_noise(&mut self, noise: f64) {
        assert!(noise > 0.0);
        self.log_noise = noise.ln();
        self.refit();
    }

    /// Borrow the kernel.
    pub fn kernel(&self) -> &K {
        &self.kernel
    }

    /// Borrow the prior mean.
    pub fn mean(&self) -> &M {
        &self.mean
    }

    /// Replace kernel hyper-parameters (log space) and refit.
    pub fn set_kernel_params(&mut self, p: &[f64]) {
        self.kernel.set_params(p);
        self.refit();
    }

    /// Training inputs.
    pub fn samples(&self) -> &[Vec<f64>] {
        &self.xs
    }

    /// Training observations.
    pub fn observations(&self) -> &[f64] {
        &self.ys
    }

    /// Extra per-observation noise variances, parallel to
    /// [`observations`](Self::observations) — or empty when every
    /// observation is homoskedastic (no `add_sample_noisy` ever).
    pub fn observation_noise_vars(&self) -> &[f64] {
        &self.noise_vars
    }

    /// Full refit from `(x, y, extra noise variance)` triples: the
    /// restore/migration path for a heteroskedastic data set. An
    /// all-zero (or empty) `noise_vars` normalizes to the homoskedastic
    /// representation, so the round-trip through
    /// [`observation_noise_vars`](Self::observation_noise_vars) is exact.
    pub fn fit_noisy(&mut self, xs: &[Vec<f64>], ys: &[f64], noise_vars: &[f64]) {
        assert!(
            noise_vars.is_empty() || noise_vars.len() == ys.len(),
            "noise_vars must be empty or parallel to ys"
        );
        if noise_vars.iter().any(|&v| v > 0.0) {
            self.noise_vars = noise_vars.iter().map(|&v| v.max(0.0)).collect();
        } else {
            self.noise_vars.clear();
        }
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.best = ys.iter().cloned().fold(None, |b: Option<f64>, v| {
            Some(b.map_or(v, |b| b.max(v)))
        });
        self.refit();
    }

    /// Prior mean value at `x` (data-dependent means already updated).
    pub fn mean_value(&self, x: &[f64]) -> f64 {
        self.mean.eval(x)
    }

    /// Log-hyper-params in the XLA layout `[log ls.., log sf, log sn]`.
    pub fn xla_loghp(&self) -> Vec<f64> {
        let mut hp = self.kernel.xla_loghp();
        hp.push(self.log_noise);
        hp
    }

    /// Training Gram `K + sigma_n^2 I` via the kernel's blocked
    /// [`cross_cov`](crate::kernel::Kernel::cross_cov) (the scaled-norm
    /// path is bitwise symmetric on identical point sets), with the
    /// diagonal set to the exact `k(x, x) = variance()`: the norm-based
    /// `r²` at `i == j` can be a rounding-level nonzero, which the
    /// non-smooth kernels (exponential) would amplify through `sqrt`.
    fn gram(&self) -> Matrix {
        let n = self.xs.len();
        let mut k = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(&self.xs, &self.xs)
        };
        let kdiag = self.kernel.variance() + self.noise_var();
        for i in 0..n {
            k[(i, i)] = kdiag;
        }
        // heteroskedastic rows widen their own diagonal entry only; the
        // `!= 0.0` guard keeps the homoskedastic path bit-identical
        for (i, &nv) in self.noise_vars.iter().enumerate() {
            if nv != 0.0 {
                k[(i, i)] += nv;
            }
        }
        k
    }

    /// Full O(n^3) refit (Gram + factor + alpha). Falls back to adding
    /// jitter if the Gram matrix is numerically singular.
    pub fn refit(&mut self) {
        let _span = obs::span(Phase::DenseFit);
        let n = self.xs.len();
        self.mean.update(&self.ys);
        if n == 0 {
            self.chol = CholeskyFactor::empty();
            self.alpha.clear();
            return;
        }
        let mut jitter = 0.0;
        loop {
            let mut k = self.gram();
            if jitter > 0.0 {
                for i in 0..n {
                    k[(i, i)] += jitter;
                }
            }
            match CholeskyFactor::factor(&k) {
                Ok(ch) => {
                    self.chol = ch;
                    break;
                }
                Err(_) if jitter < 1e-2 => {
                    jitter = if jitter == 0.0 { 1e-10 } else { jitter * 10.0 };
                }
                Err(e) => panic!("GP Gram matrix irrecoverably singular: {e}"),
            }
        }
        self.recompute_alpha();
    }

    fn recompute_alpha(&mut self) {
        let resid: Vec<f64> =
            self.xs.iter().zip(&self.ys).map(|(x, &y)| y - self.mean.eval(x)).collect();
        // solve_into: forward + in-place backward into the cached alpha
        // buffer, no intermediate allocation
        self.alpha.resize(resid.len(), 0.0);
        self.chol.solve_into(&resid, &mut self.alpha);
    }

    /// Log marginal likelihood of the current fit.
    pub fn log_marginal_likelihood(&self) -> f64 {
        let n = self.xs.len();
        if n == 0 {
            return 0.0;
        }
        let resid: Vec<f64> =
            self.xs.iter().zip(&self.ys).map(|(x, &y)| y - self.mean.eval(x)).collect();
        -0.5 * dot(&resid, &self.alpha)
            - 0.5 * self.chol.log_det()
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }

    /// Gradient of the LML w.r.t. `[kernel log-params..., log sigma_n]`.
    ///
    /// `dLML/dtheta = 0.5 tr((alpha alpha^T - K^-1) dK/dtheta)`.
    /// Hot path of every ML-II refit: `K^-1` comes from the triangular
    /// inverse of the cached Cholesky factor (~3x fewer flops than unit-
    /// vector solves), then the whole trace contracts in one pass through
    /// the kernel's blocked
    /// [`grad_params_block`](crate::kernel::Kernel::grad_params_block)
    /// with the weight matrix `W = 0.5 (alpha alpha^T - K^-1)` — the
    /// stationary kernels scale both point-set copies once and spend one
    /// dot product per pair instead of n²/2 `grad_params` calls. The
    /// noise gradient is the `W` trace times `dK/dlog sigma_n = 2
    /// sigma_n^2 I`. See EXPERIMENTS.md §Perf for the before/after.
    pub fn lml_grad(&self) -> Vec<f64> {
        let _span = obs::span(Phase::LmlGrad);
        let n = self.xs.len();
        let np = self.kernel.n_params();
        let mut grad = vec![0.0; np + 1];
        if n == 0 {
            return grad;
        }
        let kinv = self.chol.inverse();
        let mut w = Matrix::zeros(n, n);
        for i in 0..n {
            let ai = self.alpha[i];
            let krow = kinv.row(i);
            let wrow = w.row_mut(i);
            for (wij, (&aj, &kv)) in wrow.iter_mut().zip(self.alpha.iter().zip(krow)) {
                *wij = 0.5 * (ai * aj - kv);
            }
        }
        self.kernel.grad_params_block(&self.xs, &self.xs, &w, &mut grad[..np]);
        let tr: f64 = (0..n).map(|i| w[(i, i)]).sum();
        grad[np] = tr * 2.0 * self.noise_var();
        grad
    }

    /// Current log-hyper-params `[kernel..., log sigma_n]`.
    pub fn hp_vector(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.log_noise);
        p
    }

    /// Set `[kernel..., log sigma_n]` and refit (noise entry only applied
    /// when [`learn_noise`](Self::learn_noise) is on).
    pub fn set_hp_vector(&mut self, p: &[f64]) {
        let np = self.kernel.n_params();
        self.kernel.set_params(&p[..np]);
        if self.learn_noise {
            self.log_noise = p[np];
        }
        self.refit();
    }
}

impl<K: Kernel, M: MeanFn> Model for Gp<K, M> {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.noise_vars.clear();
        self.best = ys.iter().cloned().fold(None, |b: Option<f64>, v| {
            Some(b.map_or(v, |b| b.max(v)))
        });
        self.refit();
    }

    fn add_sample(&mut self, x: &[f64], y: f64) {
        self.add_sample_noisy(x, y, 0.0);
    }

    fn add_sample_noisy(&mut self, x: &[f64], y: f64, extra_var: f64) {
        assert_eq!(x.len(), self.kernel.dim(), "sample dim mismatch");
        // incremental Cholesky extension: O(n^2)
        let b: Vec<f64> = self.xs.iter().map(|xi| self.kernel.eval(xi, x)).collect();
        let mut c = self.kernel.eval(x, x) + self.noise_var();
        if extra_var > 0.0 {
            c += extra_var;
        }
        // become heteroskedastic lazily: only once the first noisy
        // observation arrives does the parallel variance vector exist
        if extra_var > 0.0 || !self.noise_vars.is_empty() {
            self.noise_vars.resize(self.xs.len(), 0.0);
            self.noise_vars.push(extra_var.max(0.0));
        }
        self.xs.push(x.to_vec());
        self.ys.push(y);
        self.best = Some(self.best.map_or(y, |b| b.max(y)));
        match self.chol.extend(&b, c) {
            Ok(()) => {
                // data-dependent mean moved -> alpha must be recomputed,
                // but the factor is reused (O(n^2) total)
                self.mean.update(&self.ys);
                self.recompute_alpha();
            }
            Err(_) => self.refit(), // numerically degenerate: jittered refit
        }
    }

    fn has_noisy_observations(&self) -> bool {
        !self.noise_vars.is_empty()
    }

    fn best_predicted_mean(&self) -> Option<f64> {
        if self.xs.is_empty() {
            return None;
        }
        self.predict_batch(&self.xs)
            .into_iter()
            .map(|(mu, _)| mu)
            .filter(|mu| mu.is_finite())
            .fold(None, |b: Option<f64>, mu| Some(b.map_or(mu, |b| b.max(mu))))
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        let prior = self.mean.eval(x);
        let n = self.xs.len();
        if n == 0 {
            return (prior, self.kernel.variance());
        }
        // thread-local scratch: the acquisition optimizer calls predict
        // hundreds of times per iteration, so per-call allocation is pure
        // overhead (the baseline deliberately keeps allocating — Fig. 1)
        thread_local! {
            static SCRATCH: std::cell::RefCell<(Vec<f64>, Vec<f64>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
        }
        SCRATCH.with(|cell| {
            let (ks, v) = &mut *cell.borrow_mut();
            ks.clear();
            ks.extend(self.xs.iter().map(|xi| self.kernel.eval(xi, x)));
            let mu = prior + dot(ks, &self.alpha);
            v.resize(n, 0.0);
            self.chol.solve_lower_into(ks, v);
            let var = (self.kernel.variance() - dot(v, v)).max(1e-12);
            (mu, var)
        })
    }

    /// Batched posterior: one cross-covariance Gram block + one multi-RHS
    /// triangular solve for the whole candidate set, instead of `B`
    /// independent O(n^2) solves — `L` streams from memory once per
    /// column block rather than once per candidate (the §Perf lever the
    /// population-based inner optimizers exploit via `eval_many`).
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let _span = obs::span(Phase::PredictBatch);
        let n = self.xs.len();
        if xs.is_empty() {
            return Vec::new();
        }
        if n == 0 {
            return xs.iter().map(|x| (self.mean.eval(x), self.kernel.variance())).collect();
        }
        // K_* : n x B cross-covariance block
        let ks = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(&self.xs, xs)
        };
        // means: K_*^T alpha in one pass
        let mus = ks.matvec_t(&self.alpha);
        // variances: solve L V = K_* once, then column norms
        let v = self.chol.solve_lower_multi(&ks);
        let sq = v.col_squared_norms();
        let prior_var = self.kernel.variance();
        xs.iter()
            .zip(mus.iter().zip(&sq))
            .map(|(x, (&mu, &s))| (self.mean.eval(x) + mu, (prior_var - s).max(1e-12)))
            .collect()
    }

    /// Joint posterior over the batch: mean vector plus the full `B x B`
    /// posterior covariance `K_** - V^T V` with `V = L^{-1} K_*` — the
    /// same cross-covariance block and multi-RHS solve as
    /// [`predict_batch`](Model::predict_batch) plus one `B x B` column
    /// Gram, so the marginal cost of the correlations is O(n·B²). The
    /// diagonal reproduces `predict_batch` exactly (same accumulation
    /// order, same `1e-12` clamp).
    fn predict_joint(&self, xs: &[Vec<f64>]) -> (Vec<f64>, Matrix) {
        let _span = obs::span(Phase::PredictJoint);
        let b = xs.len();
        if b == 0 {
            return (Vec::new(), Matrix::zeros(0, 0));
        }
        let n = self.xs.len();
        // exact prior block K_** (B x B)
        let mut cov = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(xs, xs)
        };
        if n == 0 {
            let mus = xs.iter().map(|x| self.mean.eval(x)).collect();
            for j in 0..b {
                cov[(j, j)] = self.kernel.variance();
            }
            return (mus, cov);
        }
        // K_* : n x B cross-covariance block, shared with predict_batch
        let ks = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(&self.xs, xs)
        };
        let mut mus = ks.matvec_t(&self.alpha);
        for (mu, x) in mus.iter_mut().zip(xs) {
            *mu += self.mean.eval(x);
        }
        // V = L^{-1} K_* once, then the B x B data correction V^T V
        let v = self.chol.solve_lower_multi(&ks);
        let vtv = v.col_gram();
        for (c, &g) in cov.data_mut().iter_mut().zip(vtv.data()) {
            *c -= g;
        }
        // diagonal: the exact predict_batch expression (clamped variance)
        let prior_var = self.kernel.variance();
        for j in 0..b {
            cov[(j, j)] = (prior_var - vtv[(j, j)]).max(1e-12);
        }
        (mus, cov)
    }

    fn n_samples(&self) -> usize {
        self.xs.len()
    }

    fn dim(&self) -> usize {
        self.kernel.dim()
    }

    fn best_observation(&self) -> Option<f64> {
        self.best
    }

    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        crate::model::best_sample_of(&self.xs, &self.ys)
    }

    fn optimize_hyperparams(&mut self) {
        if self.xs.len() < 2 {
            return;
        }
        // take the optimizer out so its refit counter survives the run
        // (a clone would discard the increment and replay restart draws)
        let mut opt = std::mem::take(&mut self.hp_opt);
        opt.run(self);
        self.hp_opt = opt;
    }
}

/// The dense GP fits its exact O(n³) marginal likelihood.
impl<K: Kernel, M: MeanFn> LmlModel for Gp<K, M> {
    fn hp_vector(&self) -> Vec<f64> {
        Gp::hp_vector(self)
    }

    fn apply_hp_vector(&mut self, p: &[f64]) {
        self.set_hp_vector(p);
    }

    fn lml(&self) -> f64 {
        self.log_marginal_likelihood()
    }

    fn lml_grad(&self) -> Vec<f64> {
        Gp::lml_grad(self)
    }

    fn n_samples(&self) -> usize {
        self.xs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Matern52, SquaredExpArd};
    use crate::mean::{DataMean, ZeroMean};
    use crate::rng::Pcg64;
    use crate::testing;

    fn toy_data(n: usize, rng: &mut Pcg64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| (3.0 * x[0]).sin() + (2.0 * x[1]).cos() * 0.5).collect();
        (xs, ys)
    }

    #[test]
    fn interpolates_with_small_noise() {
        let mut rng = Pcg64::seed(100);
        let (xs, ys) = toy_data(15, &mut rng);
        let mut gp = Gp::new(SquaredExpArd::new(2), ZeroMean, 1e-6);
        gp.fit(&xs, &ys);
        for (x, &y) in xs.iter().zip(&ys) {
            let (mu, var) = gp.predict(x);
            assert!((mu - y).abs() < 1e-3, "mu={mu} y={y}");
            assert!(var < 1e-3, "var={var}");
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 1e-4);
        gp.fit(&[vec![0.5]], &[1.0]);
        let (_, var_near) = gp.predict(&[0.5]);
        let (_, var_far) = gp.predict(&[5.0]);
        assert!(var_far > var_near * 100.0);
        assert!((var_far - gp.kernel().variance()).abs() < 1e-6);
    }

    #[test]
    fn empty_gp_returns_prior() {
        let gp = Gp::new(Matern52::new(2), ZeroMean, 0.01);
        let (mu, var) = gp.predict(&[0.3, 0.3]);
        assert_eq!(mu, 0.0);
        assert!((var - 1.0).abs() < 1e-12);
        assert!(gp.best_observation().is_none());
    }

    #[test]
    fn incremental_matches_full_refit() {
        testing::check(
            "gp-incremental==full",
            0xAB,
            16,
            |rng: &mut Pcg64| toy_data(3 + rng.below(12), rng),
            |(xs, ys)| {
                let mut inc = Gp::new(Matern52::new(2), DataMean::default(), 0.01);
                for (x, &y) in xs.iter().zip(ys.iter()) {
                    inc.add_sample(x, y);
                }
                let mut full = Gp::new(Matern52::new(2), DataMean::default(), 0.01);
                full.fit(xs, ys);
                let probe = [0.25, 0.75];
                let (mi, vi) = inc.predict(&probe);
                let (mf, vf) = full.predict(&probe);
                testing::close(mi, mf, 1e-8)?;
                testing::close(vi, vf, 1e-8)?;
                testing::close(
                    inc.log_marginal_likelihood(),
                    full.log_marginal_likelihood(),
                    1e-8,
                )
            },
        );
    }

    #[test]
    fn lml_grad_matches_finite_differences() {
        let mut rng = Pcg64::seed(0x77);
        let (xs, ys) = toy_data(10, &mut rng);
        let mut gp = Gp::new(SquaredExpArd::new(2), ZeroMean, 0.05);
        gp.learn_noise = true;
        gp.fit(&xs, &ys);
        let grad = gp.lml_grad();
        let p0 = gp.hp_vector();
        let eps = 1e-5;
        for i in 0..p0.len() {
            let mut p = p0.clone();
            p[i] += eps;
            gp.set_hp_vector(&p);
            let up = gp.log_marginal_likelihood();
            p[i] -= 2.0 * eps;
            gp.set_hp_vector(&p);
            let dn = gp.log_marginal_likelihood();
            gp.set_hp_vector(&p0);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (grad[i] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "param {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn predict_batch_matches_pointwise() {
        let mut rng = Pcg64::seed(0xBA7);
        let (xs, ys) = toy_data(24, &mut rng);
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 0.05);
        gp.fit(&xs, &ys);
        let cands: Vec<Vec<f64>> = (0..13).map(|_| rng.unit_point(2)).collect();
        let batch = gp.predict_batch(&cands);
        assert_eq!(batch.len(), 13);
        for (j, c) in cands.iter().enumerate() {
            let (mu, var) = gp.predict(c);
            assert!((batch[j].0 - mu).abs() < 1e-10, "mu[{j}]: {} vs {mu}", batch[j].0);
            assert!((batch[j].1 - var).abs() < 1e-10, "var[{j}]: {} vs {var}", batch[j].1);
        }
        // empty model falls back to the prior
        let fresh = Gp::new(Matern52::new(2), ZeroMean, 0.05);
        assert_eq!(fresh.predict_batch(&cands)[0], fresh.predict(&cands[0]));
        assert!(fresh.predict_batch(&[]).is_empty());
    }

    #[test]
    fn predict_joint_diag_matches_batch_and_cov_is_consistent() {
        let mut rng = Pcg64::seed(0x107);
        let (xs, ys) = toy_data(20, &mut rng);
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 0.05);
        gp.fit(&xs, &ys);
        let cands: Vec<Vec<f64>> = (0..9).map(|_| rng.unit_point(2)).collect();
        let (mus, cov) = gp.predict_joint(&cands);
        let batch = gp.predict_batch(&cands);
        assert_eq!((cov.rows(), cov.cols()), (9, 9));
        assert!(cov.is_symmetric(1e-12));
        for j in 0..9 {
            assert!((mus[j] - batch[j].0).abs() < 1e-12, "mu[{j}]");
            assert!((cov[(j, j)] - batch[j].1).abs() < 1e-12, "var[{j}]");
        }
        // a point paired with itself is perfectly correlated: the 2x2
        // joint covariance of [x, x] must be (numerically) rank one
        let x = vec![0.31, 0.62];
        let (_, c2) = gp.predict_joint(&[x.clone(), x]);
        assert!((c2[(0, 0)] - c2[(0, 1)]).abs() < 1e-8);
        assert!((c2[(0, 0)] - c2[(1, 1)]).abs() < 1e-8);
        // empty batch and empty model edge cases
        let (m0, c0) = gp.predict_joint(&[]);
        assert!(m0.is_empty() && c0.rows() == 0);
        let fresh = Gp::new(Matern52::new(2), ZeroMean, 0.05);
        let (mf, cf) = fresh.predict_joint(&cands);
        assert_eq!(mf[0], 0.0);
        assert!((cf[(0, 0)] - fresh.kernel().variance()).abs() < 1e-12);
    }

    #[test]
    fn best_sample_recovers_argmax() {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.01);
        assert!(gp.best_sample().is_none());
        gp.add_sample(&[0.1], 1.0);
        gp.add_sample(&[0.2], 3.0);
        gp.add_sample(&[0.3], 2.0);
        let (x, y) = gp.best_sample().unwrap();
        assert_eq!(x, vec![0.2]);
        assert_eq!(y, 3.0);
    }

    #[test]
    fn best_observation_tracks_max() {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.01);
        gp.add_sample(&[0.1], 1.0);
        gp.add_sample(&[0.2], 3.0);
        gp.add_sample(&[0.3], 2.0);
        assert_eq!(gp.best_observation(), Some(3.0));
    }

    #[test]
    fn duplicate_points_survive_via_jitter_or_noise() {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 1e-3);
        gp.add_sample(&[0.5], 1.0);
        gp.add_sample(&[0.5], 1.1); // duplicate input
        let (mu, _) = gp.predict(&[0.5]);
        assert!((mu - 1.05).abs() < 0.1, "mu={mu} should average duplicates");
    }
}
