//! GP state serialization (Limbo's `gp.save<>()` / `gp.load<>()`):
//! a plain-text format so runs can be checkpointed, resumed, and shipped
//! between the native and XLA backends (both consume the same fields).
//!
//! Format (line-oriented, `#`-comments allowed):
//! ```text
//! limbo-gp v1
//! dim <d>
//! hp <log-hyper-params ... incl. log-noise>
//! n <num samples>
//! x <d floats>      (n lines)
//! y <float>         (n lines)
//! nv <float>        (0 or n lines: extra per-observation noise variance)
//! ```
//!
//! [`SgpState`] extends the same layout for the sparse GP (header
//! `limbo-sgp v1`, plus one `z <d floats>` line per inducing point), so a
//! checkpoint restores the exact online-evolved inducing set rather than
//! re-running the greedy selection. [`BankState`] (header `limbo-bank v1`)
//! wraps a constraint-model bank: a `channels <k>` line followed by the
//! k + 1 self-describing member sections (objective first).

use std::io::Write;
use std::path::Path;

use crate::kernel::Kernel;
use crate::mean::MeanFn;
use crate::model::gp::Gp;
use crate::model::sgp::SparseGp;
use crate::model::Model;

/// Fields shared by the dense and sparse text formats.
struct ParsedBody {
    dim: usize,
    hp: Vec<f64>,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    nv: Vec<f64>,
    zs: Vec<Vec<f64>>,
}

/// Shared line-oriented parser behind [`GpState::from_text`] and
/// [`SgpState::from_text`]: same tags, different header, the sparse
/// format additionally accepts `z` (inducing-point) lines.
fn parse_body(text: &str, expect_header: &str, allow_z: bool) -> Result<ParsedBody, String> {
    let mut lines = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'));
    let header = lines.next().ok_or("empty file")?;
    if header != expect_header {
        return Err(format!("bad header {header:?}"));
    }
    let mut dim = None;
    let mut hp = Vec::new();
    let mut n = None;
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut nv: Vec<f64> = Vec::new();
    let mut zs: Vec<Vec<f64>> = Vec::new();
    for line in lines {
        let mut parts = line.split_whitespace();
        let tag = parts.next().unwrap();
        let rest: Result<Vec<f64>, _> = parts.map(str::parse::<f64>).collect();
        let rest = rest.map_err(|e| format!("parse error on {line:?}: {e}"))?;
        let first = rest.first().copied();
        match tag {
            "dim" => {
                dim = Some(first.ok_or_else(|| format!("missing value on {line:?}"))? as usize);
            }
            "hp" => hp = rest,
            "n" => {
                n = Some(first.ok_or_else(|| format!("missing value on {line:?}"))? as usize);
            }
            "x" => xs.push(rest),
            "y" => ys.push(first.ok_or_else(|| format!("missing value on {line:?}"))?),
            "nv" => nv.push(first.ok_or_else(|| format!("missing value on {line:?}"))?),
            "z" if allow_z => zs.push(rest),
            _ => return Err(format!("unknown tag {tag:?}")),
        }
    }
    let dim = dim.ok_or("missing dim")?;
    let n = n.ok_or("missing n")?;
    if xs.len() != n || ys.len() != n {
        return Err(format!("expected {n} samples, got {}x/{}y", xs.len(), ys.len()));
    }
    if !nv.is_empty() && nv.len() != n {
        return Err(format!("expected 0 or {n} nv lines, got {}", nv.len()));
    }
    if xs.iter().any(|x| x.len() != dim) {
        return Err("sample with wrong dimension".into());
    }
    if zs.iter().any(|z| z.len() != dim) {
        return Err("inducing point with wrong dimension".into());
    }
    Ok(ParsedBody { dim, hp, xs, ys, nv, zs })
}

/// Serializable snapshot of a GP's state.
#[derive(Debug, Clone, PartialEq)]
pub struct GpState {
    /// Input dimension.
    pub dim: usize,
    /// `[kernel log-params..., log sigma_n]`.
    pub hp: Vec<f64>,
    /// Training inputs.
    pub xs: Vec<Vec<f64>>,
    /// Training observations.
    pub ys: Vec<f64>,
    /// Extra per-observation noise variances (empty = homoskedastic).
    pub noise_vars: Vec<f64>,
}

impl GpState {
    /// Capture a GP's state.
    pub fn capture<K: Kernel, M: MeanFn>(gp: &Gp<K, M>) -> Self {
        Self {
            dim: gp.dim(),
            hp: gp.hp_vector(),
            xs: gp.samples().to_vec(),
            ys: gp.observations().to_vec(),
            noise_vars: gp.observation_noise_vars().to_vec(),
        }
    }

    /// Apply this state onto a compatible GP (same dim / param count) and
    /// refit.
    pub fn restore<K: Kernel, M: MeanFn>(&self, gp: &mut Gp<K, M>) -> Result<(), String> {
        if gp.dim() != self.dim {
            return Err(format!("dim mismatch: gp {} vs state {}", gp.dim(), self.dim));
        }
        if gp.hp_vector().len() != self.hp.len() {
            return Err(format!(
                "hyper-param count mismatch: gp {} vs state {}",
                gp.hp_vector().len(),
                self.hp.len()
            ));
        }
        let learn_noise = gp.learn_noise;
        gp.learn_noise = true; // make set_hp_vector apply the stored noise
        gp.set_hp_vector(&self.hp);
        gp.learn_noise = learn_noise;
        gp.fit_noisy(&self.xs, &self.ys, &self.noise_vars);
        Ok(())
    }

    /// Serialize to the text format.
    pub fn to_text(&self) -> String {
        let mut out = String::from("limbo-gp v1\n");
        out.push_str(&format!("dim {}\n", self.dim));
        out.push_str("hp");
        for v in &self.hp {
            out.push_str(&format!(" {v:.17e}"));
        }
        out.push('\n');
        out.push_str(&format!("n {}\n", self.ys.len()));
        for x in &self.xs {
            out.push('x');
            for v in x {
                out.push_str(&format!(" {v:.17e}"));
            }
            out.push('\n');
        }
        for y in &self.ys {
            out.push_str(&format!("y {y:.17e}\n"));
        }
        for v in &self.noise_vars {
            out.push_str(&format!("nv {v:.17e}\n"));
        }
        out
    }

    /// Parse from the text format.
    pub fn from_text(text: &str) -> Result<Self, String> {
        let body = parse_body(text, "limbo-gp v1", false)?;
        Ok(Self { dim: body.dim, hp: body.hp, xs: body.xs, ys: body.ys, noise_vars: body.nv })
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_text().as_bytes())
    }

    /// Read from a file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_text(&text)
    }
}

impl<K: Kernel, M: MeanFn> Gp<K, M> {
    /// Save the GP (hyper-params + data) to a text file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        GpState::capture(self).save(path)
    }

    /// Load state from a text file into this GP (must match dim/params).
    pub fn load(&mut self, path: &Path) -> Result<(), String> {
        GpState::load(path)?.restore(self)
    }
}

/// Serializable snapshot of a [`SparseGp`]: the dense fields plus the
/// inducing set (factors are recomputed on restore — they are a pure
/// function of data, hyper-params, and inducing locations).
#[derive(Debug, Clone, PartialEq)]
pub struct SgpState {
    /// Input dimension.
    pub dim: usize,
    /// `[kernel log-params..., log sigma_n]`.
    pub hp: Vec<f64>,
    /// Training inputs.
    pub xs: Vec<Vec<f64>>,
    /// Training observations.
    pub ys: Vec<f64>,
    /// Extra per-observation noise variances (empty = homoskedastic).
    pub noise_vars: Vec<f64>,
    /// Inducing-point locations.
    pub zs: Vec<Vec<f64>>,
}

impl SgpState {
    /// Capture a sparse GP's state.
    pub fn capture<K: Kernel, M: MeanFn>(sgp: &SparseGp<K, M>) -> Self {
        Self {
            dim: sgp.dim(),
            hp: sgp.hp_vector(),
            xs: sgp.samples().to_vec(),
            ys: sgp.observations().to_vec(),
            noise_vars: sgp.observation_noise_vars().to_vec(),
            zs: sgp.inducing_points().to_vec(),
        }
    }

    /// Apply this state onto a compatible sparse GP (same dim / param
    /// count) and refit with the stored inducing set.
    pub fn restore<K: Kernel, M: MeanFn>(&self, sgp: &mut SparseGp<K, M>) -> Result<(), String> {
        if sgp.dim() != self.dim {
            return Err(format!("dim mismatch: sgp {} vs state {}", sgp.dim(), self.dim));
        }
        if sgp.hp_vector().len() != self.hp.len() {
            return Err(format!(
                "hyper-param count mismatch: sgp {} vs state {}",
                sgp.hp_vector().len(),
                self.hp.len()
            ));
        }
        if self.zs.iter().any(|z| z.len() != self.dim) {
            return Err("inducing point with wrong dimension".into());
        }
        // hyper-params first (no intermediate refit against stale data) —
        // fit_with_inducing performs the single full refit
        sgp.set_hp_vector_no_refit(&self.hp, true);
        sgp.fit_with_inducing_noisy(&self.xs, &self.ys, &self.noise_vars, self.zs.clone());
        Ok(())
    }

    /// Serialize to the text format (`limbo-sgp v1`).
    pub fn to_text(&self) -> String {
        let mut out = String::from("limbo-sgp v1\n");
        out.push_str(&format!("dim {}\n", self.dim));
        out.push_str("hp");
        for v in &self.hp {
            out.push_str(&format!(" {v:.17e}"));
        }
        out.push('\n');
        out.push_str(&format!("n {}\n", self.ys.len()));
        for x in &self.xs {
            out.push('x');
            for v in x {
                out.push_str(&format!(" {v:.17e}"));
            }
            out.push('\n');
        }
        for y in &self.ys {
            out.push_str(&format!("y {y:.17e}\n"));
        }
        for v in &self.noise_vars {
            out.push_str(&format!("nv {v:.17e}\n"));
        }
        for z in &self.zs {
            out.push('z');
            for v in z {
                out.push_str(&format!(" {v:.17e}"));
            }
            out.push('\n');
        }
        out
    }

    /// Parse from the text format.
    pub fn from_text(text: &str) -> Result<Self, String> {
        let body = parse_body(text, "limbo-sgp v1", true)?;
        if body.zs.is_empty() && !body.ys.is_empty() {
            return Err("sparse state with data but no inducing points".into());
        }
        if !body.zs.is_empty() && body.ys.is_empty() {
            return Err("sparse state with inducing points but no data".into());
        }
        Ok(Self {
            dim: body.dim,
            hp: body.hp,
            xs: body.xs,
            ys: body.ys,
            noise_vars: body.nv,
            zs: body.zs,
        })
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_text().as_bytes())
    }

    /// Read from a file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_text(&text)
    }
}

impl<K: Kernel, M: MeanFn> SparseGp<K, M> {
    /// Save the sparse GP (hyper-params + data + inducing set) to a file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        SgpState::capture(self).save(path)
    }

    /// Load state from a text file into this sparse GP (must match
    /// dim/params).
    pub fn load(&mut self, path: &Path) -> Result<(), String> {
        SgpState::load(path)?.restore(self)
    }
}

/// Captured state of a [`crate::model::bank::ModelBank`]: the objective
/// surrogate's state followed by one state per constraint channel. The
/// text format is self-describing — a `limbo-bank v1` header, a
/// `channels <k>` count, then the k + 1 member sections, each opening
/// with its own `limbo-gp v1` / `limbo-sgp v1` header.
#[derive(Debug, Clone, PartialEq)]
pub struct BankState {
    /// Member states: objective first, then one per constraint channel.
    pub members: Vec<ModelState>,
}

impl BankState {
    /// Number of constraint channels (members beyond the objective).
    pub fn channels(&self) -> usize {
        self.members.len().saturating_sub(1)
    }

    /// Serialize to the text format (`limbo-bank v1`).
    pub fn to_text(&self) -> String {
        let mut out = String::from("limbo-bank v1\n");
        out.push_str(&format!("channels {}\n", self.channels()));
        for m in &self.members {
            out.push_str(&m.to_text());
        }
        out
    }

    /// Parse from the text format.
    pub fn from_text(text: &str) -> Result<Self, String> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = lines.next().ok_or("empty file")?;
        if header != "limbo-bank v1" {
            return Err(format!("bad header {header:?}"));
        }
        let channels_line = lines.next().ok_or("missing channels line")?;
        let channels = channels_line
            .strip_prefix("channels ")
            .and_then(|v| v.trim().parse::<usize>().ok())
            .ok_or_else(|| format!("bad channels line {channels_line:?}"))?;
        // split the remainder into member sections on the model headers
        let mut sections: Vec<String> = Vec::new();
        for line in lines {
            if line == "limbo-gp v1" || line == "limbo-sgp v1" {
                sections.push(String::new());
            } else if sections.is_empty() {
                return Err(format!("unexpected line {line:?} before first member"));
            }
            let s = sections.last_mut().expect("section started");
            s.push_str(line);
            s.push('\n');
        }
        if sections.len() != channels + 1 {
            return Err(format!(
                "expected {} member sections, got {}",
                channels + 1,
                sections.len()
            ));
        }
        let members: Result<Vec<ModelState>, String> =
            sections.iter().map(|s| ModelState::from_text(s)).collect();
        Ok(Self { members: members? })
    }
}

/// A captured model state of either representation — what a study
/// checkpoint stores without knowing whether the surrogate had migrated
/// to the sparse form yet. The text round-trip dispatches on the header
/// line (`limbo-gp v1` vs `limbo-sgp v1` vs `limbo-bank v1`), so a
/// snapshot file is self-describing.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelState {
    /// Dense-GP state.
    Dense(GpState),
    /// Sparse-GP state (includes the inducing set).
    Sparse(SgpState),
    /// Constraint-bank state (objective + constraint surrogates).
    Bank(BankState),
}

impl ModelState {
    /// Serialize to the text format of the captured representation.
    pub fn to_text(&self) -> String {
        match self {
            ModelState::Dense(s) => s.to_text(),
            ModelState::Sparse(s) => s.to_text(),
            ModelState::Bank(s) => s.to_text(),
        }
    }

    /// Parse either text format, dispatching on the header line.
    pub fn from_text(text: &str) -> Result<Self, String> {
        let header = text
            .lines()
            .map(str::trim)
            .find(|l| !l.is_empty() && !l.starts_with('#'))
            .ok_or("empty file")?;
        match header {
            "limbo-gp v1" => GpState::from_text(text).map(ModelState::Dense),
            "limbo-sgp v1" => SgpState::from_text(text).map(ModelState::Sparse),
            "limbo-bank v1" => BankState::from_text(text).map(ModelState::Bank),
            other => Err(format!("bad header {other:?}")),
        }
    }

    /// Number of training samples in the captured state (the objective
    /// member's, for a bank).
    pub fn n_samples(&self) -> usize {
        match self {
            ModelState::Dense(s) => s.ys.len(),
            ModelState::Sparse(s) => s.ys.len(),
            ModelState::Bank(s) => s.members.first().map_or(0, |m| m.n_samples()),
        }
    }
}

/// A surrogate whose full state (data + hyper-parameters + any inducing
/// structure) can be captured into a [`ModelState`] and restored from
/// one — the model-side contract of study checkpointing.
///
/// Capture is a pure read. On the dense path, restoring a state that was
/// captured right after a full refit reproduces the live factors
/// **bit-exactly** (`restore` re-runs the same deterministic fit); the
/// sparse path is exact up to factorization round-off (~1e-8).
pub trait StateModel: Model {
    /// Capture the full model state (pure read).
    fn capture_state(&self) -> ModelState;

    /// Restore a captured state (data is refit in place).
    fn restore_state(&mut self, state: &ModelState) -> Result<(), String>;

    /// The ML-II refit counter (feeds the restart-seed stream).
    fn hp_refits(&self) -> u64;

    /// Restore the ML-II refit counter from a checkpoint.
    fn set_hp_refits(&mut self, refits: u64);
}

impl<K: Kernel, M: MeanFn> StateModel for Gp<K, M> {
    fn capture_state(&self) -> ModelState {
        ModelState::Dense(GpState::capture(self))
    }

    fn restore_state(&mut self, state: &ModelState) -> Result<(), String> {
        match state {
            ModelState::Dense(s) => s.restore(self),
            ModelState::Sparse(_) => Err("cannot restore sparse state into a dense GP".into()),
        }
    }

    fn hp_refits(&self) -> u64 {
        self.hp_opt.refits()
    }

    fn set_hp_refits(&mut self, refits: u64) {
        self.hp_opt.set_refits(refits);
    }
}

impl<K: Kernel, M: MeanFn> StateModel for SparseGp<K, M> {
    fn capture_state(&self) -> ModelState {
        ModelState::Sparse(SgpState::capture(self))
    }

    fn restore_state(&mut self, state: &ModelState) -> Result<(), String> {
        match state {
            ModelState::Sparse(s) => s.restore(self),
            ModelState::Dense(_) => Err("cannot restore dense state into a sparse GP".into()),
        }
    }

    fn hp_refits(&self) -> u64 {
        self.hp_opt.refits()
    }

    fn set_hp_refits(&mut self, refits: u64) {
        self.hp_opt.set_refits(refits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::rng::Pcg64;

    fn fitted_gp() -> Gp<Matern52, DataMean> {
        let mut rng = Pcg64::seed(44);
        let xs: Vec<Vec<f64>> = (0..12).map(|_| rng.unit_point(3)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] - (4.0 * x[1]).cos()).collect();
        let mut gp = Gp::new(Matern52::with_params(vec![-0.3, 0.2, 0.0], 0.4), DataMean::default(), 0.03);
        gp.fit(&xs, &ys);
        gp
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let gp = fitted_gp();
        let state = GpState::capture(&gp);
        let parsed = GpState::from_text(&state.to_text()).unwrap();
        assert_eq!(state, parsed);
    }

    #[test]
    fn save_load_preserves_posterior() {
        let dir = std::env::temp_dir().join("limbo_gp_serde");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("gp.txt");
        let gp = fitted_gp();
        gp.save(&path).unwrap();

        let mut fresh = Gp::new(Matern52::new(3), DataMean::default(), 0.5);
        fresh.load(&path).unwrap();
        for probe in [[0.2, 0.8, 0.5], [0.9, 0.1, 0.3]] {
            let (m1, v1) = gp.predict(&probe);
            let (m2, v2) = fresh.predict(&probe);
            assert!((m1 - m2).abs() < 1e-12, "{m1} vs {m2}");
            assert!((v1 - v2).abs() < 1e-12);
        }
        assert!((fresh.noise_var() - gp.noise_var()).abs() < 1e-15);
    }

    #[test]
    fn rejects_mismatched_dim() {
        let gp = fitted_gp();
        let state = GpState::capture(&gp);
        let mut wrong = Gp::new(Matern52::new(2), DataMean::default(), 0.1);
        assert!(state.restore(&mut wrong).is_err());
    }

    #[test]
    fn rejects_corrupt_text() {
        assert!(GpState::from_text("").is_err());
        assert!(GpState::from_text("limbo-gp v2\ndim 1\n").is_err());
        assert!(GpState::from_text("limbo-gp v1\ndim 1\nhp 0 0 0\nn 2\nx 0.5\ny 1.0\n").is_err());
        assert!(GpState::from_text("limbo-gp v1\ndim 1\nhp 0 0 0\nn 1\nx zap\ny 1.0\n").is_err());
    }

    fn fitted_sgp() -> SparseGp<Matern52, DataMean> {
        let mut rng = Pcg64::seed(45);
        let mut sgp = SparseGp::with_config(
            Matern52::with_params(vec![-0.2, 0.1, 0.3], 0.2),
            DataMean::default(),
            0.02,
            crate::model::SgpConfig { max_inducing: 12, ..Default::default() },
        );
        // grow online so the inducing set is the evolved one, not greedy
        for _ in 0..40 {
            let x = rng.unit_point(3);
            let y = x[0] - (4.0 * x[1]).cos() + 0.5 * x[2];
            sgp.add_sample(&x, y);
        }
        sgp
    }

    #[test]
    fn sgp_text_roundtrip_is_exact() {
        let sgp = fitted_sgp();
        let state = SgpState::capture(&sgp);
        assert_eq!(state.zs.len(), 12);
        let parsed = SgpState::from_text(&state.to_text()).unwrap();
        assert_eq!(state, parsed);
    }

    #[test]
    fn sgp_save_load_preserves_posterior_and_inducing_set() {
        let dir = std::env::temp_dir().join("limbo_sgp_serde");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("sgp.txt");
        let sgp = fitted_sgp();
        sgp.save(&path).unwrap();

        let mut fresh = SparseGp::new(Matern52::new(3), DataMean::default(), 0.7);
        fresh.load(&path).unwrap();
        assert_eq!(fresh.inducing_points(), sgp.inducing_points());
        assert_eq!(fresh.n_samples(), sgp.n_samples());
        assert!((fresh.noise_var() - sgp.noise_var()).abs() < 1e-15);
        for probe in [[0.2, 0.8, 0.5], [0.9, 0.1, 0.3], [0.5, 0.5, 0.5]] {
            let (m1, v1) = sgp.predict(&probe);
            let (m2, v2) = fresh.predict(&probe);
            assert!((m1 - m2).abs() < 1e-8, "{m1} vs {m2}");
            assert!((v1 - v2).abs() < 1e-8, "{v1} vs {v2}");
        }
    }

    #[test]
    fn sgp_rejects_mismatch_and_corrupt_text() {
        let sgp = fitted_sgp();
        let state = SgpState::capture(&sgp);
        let mut wrong = SparseGp::new(Matern52::new(2), DataMean::default(), 0.1);
        assert!(state.restore(&mut wrong).is_err());

        assert!(SgpState::from_text("limbo-gp v1\ndim 1\n").is_err());
        // data but no inducing points
        assert!(SgpState::from_text("limbo-sgp v1\ndim 1\nhp 0 0 0\nn 1\nx 0.5\ny 1.0\n").is_err());
        // inducing points but no data
        assert!(SgpState::from_text("limbo-sgp v1\ndim 1\nhp 0 0 0\nn 0\nz 0.5\n").is_err());
        // bare tag lines must error, not panic
        assert!(SgpState::from_text("limbo-sgp v1\ndim\n").is_err());
        assert!(GpState::from_text("limbo-gp v1\ndim 1\nhp 0\nn\n").is_err());
    }
}
