//! GP state serialization (Limbo's `gp.save<>()` / `gp.load<>()`):
//! a plain-text format so runs can be checkpointed, resumed, and shipped
//! between the native and XLA backends (both consume the same fields).
//!
//! Format (line-oriented, `#`-comments allowed):
//! ```text
//! limbo-gp v1
//! dim <d>
//! hp <log-hyper-params ... incl. log-noise>
//! n <num samples>
//! x <d floats>      (n lines)
//! y <float>         (n lines)
//! ```

use std::io::Write;
use std::path::Path;

use crate::kernel::Kernel;
use crate::mean::MeanFn;
use crate::model::gp::Gp;
use crate::model::Model;

/// Serializable snapshot of a GP's state.
#[derive(Debug, Clone, PartialEq)]
pub struct GpState {
    /// Input dimension.
    pub dim: usize,
    /// `[kernel log-params..., log sigma_n]`.
    pub hp: Vec<f64>,
    /// Training inputs.
    pub xs: Vec<Vec<f64>>,
    /// Training observations.
    pub ys: Vec<f64>,
}

impl GpState {
    /// Capture a GP's state.
    pub fn capture<K: Kernel, M: MeanFn>(gp: &Gp<K, M>) -> Self {
        Self {
            dim: gp.dim(),
            hp: gp.hp_vector(),
            xs: gp.samples().to_vec(),
            ys: gp.observations().to_vec(),
        }
    }

    /// Apply this state onto a compatible GP (same dim / param count) and
    /// refit.
    pub fn restore<K: Kernel, M: MeanFn>(&self, gp: &mut Gp<K, M>) -> Result<(), String> {
        if gp.dim() != self.dim {
            return Err(format!("dim mismatch: gp {} vs state {}", gp.dim(), self.dim));
        }
        if gp.hp_vector().len() != self.hp.len() {
            return Err(format!(
                "hyper-param count mismatch: gp {} vs state {}",
                gp.hp_vector().len(),
                self.hp.len()
            ));
        }
        let learn_noise = gp.learn_noise;
        gp.learn_noise = true; // make set_hp_vector apply the stored noise
        gp.set_hp_vector(&self.hp);
        gp.learn_noise = learn_noise;
        gp.fit(&self.xs, &self.ys);
        Ok(())
    }

    /// Serialize to the text format.
    pub fn to_text(&self) -> String {
        let mut out = String::from("limbo-gp v1\n");
        out.push_str(&format!("dim {}\n", self.dim));
        out.push_str("hp");
        for v in &self.hp {
            out.push_str(&format!(" {v:.17e}"));
        }
        out.push('\n');
        out.push_str(&format!("n {}\n", self.ys.len()));
        for x in &self.xs {
            out.push('x');
            for v in x {
                out.push_str(&format!(" {v:.17e}"));
            }
            out.push('\n');
        }
        for y in &self.ys {
            out.push_str(&format!("y {y:.17e}\n"));
        }
        out
    }

    /// Parse from the text format.
    pub fn from_text(text: &str) -> Result<Self, String> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = lines.next().ok_or("empty file")?;
        if header != "limbo-gp v1" {
            return Err(format!("bad header {header:?}"));
        }
        let mut dim = None;
        let mut hp = Vec::new();
        let mut n = None;
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for line in lines {
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let rest: Result<Vec<f64>, _> = parts.map(str::parse::<f64>).collect();
            let rest = rest.map_err(|e| format!("parse error on {line:?}: {e}"))?;
            match tag {
                "dim" => dim = Some(rest[0] as usize),
                "hp" => hp = rest,
                "n" => n = Some(rest[0] as usize),
                "x" => xs.push(rest),
                "y" => ys.push(rest[0]),
                _ => return Err(format!("unknown tag {tag:?}")),
            }
        }
        let dim = dim.ok_or("missing dim")?;
        let n = n.ok_or("missing n")?;
        if xs.len() != n || ys.len() != n {
            return Err(format!("expected {n} samples, got {}x/{}y", xs.len(), ys.len()));
        }
        if xs.iter().any(|x| x.len() != dim) {
            return Err("sample with wrong dimension".into());
        }
        Ok(Self { dim, hp, xs, ys })
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_text().as_bytes())
    }

    /// Read from a file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_text(&text)
    }
}

impl<K: Kernel, M: MeanFn> Gp<K, M> {
    /// Save the GP (hyper-params + data) to a text file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        GpState::capture(self).save(path)
    }

    /// Load state from a text file into this GP (must match dim/params).
    pub fn load(&mut self, path: &Path) -> Result<(), String> {
        GpState::load(path)?.restore(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::rng::Pcg64;

    fn fitted_gp() -> Gp<Matern52, DataMean> {
        let mut rng = Pcg64::seed(44);
        let xs: Vec<Vec<f64>> = (0..12).map(|_| rng.unit_point(3)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] - (4.0 * x[1]).cos()).collect();
        let mut gp = Gp::new(Matern52::with_params(vec![-0.3, 0.2, 0.0], 0.4), DataMean::default(), 0.03);
        gp.fit(&xs, &ys);
        gp
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let gp = fitted_gp();
        let state = GpState::capture(&gp);
        let parsed = GpState::from_text(&state.to_text()).unwrap();
        assert_eq!(state, parsed);
    }

    #[test]
    fn save_load_preserves_posterior() {
        let dir = std::env::temp_dir().join("limbo_gp_serde");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("gp.txt");
        let gp = fitted_gp();
        gp.save(&path).unwrap();

        let mut fresh = Gp::new(Matern52::new(3), DataMean::default(), 0.5);
        fresh.load(&path).unwrap();
        for probe in [[0.2, 0.8, 0.5], [0.9, 0.1, 0.3]] {
            let (m1, v1) = gp.predict(&probe);
            let (m2, v2) = fresh.predict(&probe);
            assert!((m1 - m2).abs() < 1e-12, "{m1} vs {m2}");
            assert!((v1 - v2).abs() < 1e-12);
        }
        assert!((fresh.noise_var() - gp.noise_var()).abs() < 1e-15);
    }

    #[test]
    fn rejects_mismatched_dim() {
        let gp = fitted_gp();
        let state = GpState::capture(&gp);
        let mut wrong = Gp::new(Matern52::new(2), DataMean::default(), 0.1);
        assert!(state.restore(&mut wrong).is_err());
    }

    #[test]
    fn rejects_corrupt_text() {
        assert!(GpState::from_text("").is_err());
        assert!(GpState::from_text("limbo-gp v2\ndim 1\n").is_err());
        assert!(GpState::from_text("limbo-gp v1\ndim 1\nhp 0 0 0\nn 2\nx 0.5\ny 1.0\n").is_err());
        assert!(GpState::from_text("limbo-gp v1\ndim 1\nhp 0 0 0\nn 1\nx zap\ny 1.0\n").is_err());
    }
}
