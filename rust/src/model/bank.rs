//! Constraint-model bank — one surrogate per output channel.
//!
//! Constrained BO needs a posterior over each inequality constraint as
//! well as the objective (probability-of-feasibility weighting, see
//! [`crate::acqui::constrained::PofWeighted`]). [`ModelBank`] packages
//! an objective surrogate plus one surrogate per constraint channel
//! behind the plain [`Model`] trait: every single-output operation
//! (predict, fit, incumbent bookkeeping) delegates to the objective
//! member, so a bank drops into [`crate::bayes_opt::BoCore`] unchanged,
//! while the constraint intake
//! ([`Model::add_constraint_sample`]) and the joint refit
//! ([`Model::optimize_hyperparams`]) fan out across all members at the
//! same refit barrier.
//!
//! The feasibility convention matches the related libraries: a
//! constraint channel value `>= 0` is feasible.

use crate::la::Matrix;
use crate::model::serde::{BankState, ModelState, StateModel};
use crate::model::Model;

/// An objective surrogate plus one surrogate per constraint channel.
///
/// All members share the same input space; constraint surrogates are
/// fed through [`Model::add_constraint_sample`] with one value per
/// channel, paired with the objective observation at the same `x`.
#[derive(Clone)]
pub struct ModelBank<M> {
    /// The objective surrogate — the model every single-output
    /// delegation targets.
    pub objective: M,
    /// One surrogate per constraint channel (value `>= 0` = feasible).
    pub constraints: Vec<M>,
}

impl<M: Model> ModelBank<M> {
    /// Bank an objective model with `constraints` channel surrogates
    /// (typically clones of the objective's empty configuration).
    pub fn new(objective: M, constraints: Vec<M>) -> Self {
        Self { objective, constraints }
    }

    /// Borrow the constraint surrogate for channel `j`.
    pub fn constraint(&self, j: usize) -> &M {
        &self.constraints[j]
    }
}

impl<M: Model> Model for ModelBank<M> {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        self.objective.fit(xs, ys);
    }

    fn add_sample(&mut self, x: &[f64], y: f64) {
        self.objective.add_sample(x, y);
    }

    fn add_sample_noisy(&mut self, x: &[f64], y: f64, extra_var: f64) {
        self.objective.add_sample_noisy(x, y, extra_var);
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        self.objective.predict(x)
    }

    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        self.objective.predict_batch(xs)
    }

    fn predict_joint(&self, xs: &[Vec<f64>]) -> (Vec<f64>, Matrix) {
        self.objective.predict_joint(xs)
    }

    fn n_samples(&self) -> usize {
        self.objective.n_samples()
    }

    fn dim(&self) -> usize {
        self.objective.dim()
    }

    fn best_observation(&self) -> Option<f64> {
        self.objective.best_observation()
    }

    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        self.objective.best_sample()
    }

    fn has_noisy_observations(&self) -> bool {
        self.objective.has_noisy_observations()
    }

    fn best_predicted_mean(&self) -> Option<f64> {
        self.objective.best_predicted_mean()
    }

    fn n_constraint_channels(&self) -> usize {
        self.constraints.len()
    }

    fn add_constraint_sample(&mut self, x: &[f64], cs: &[f64]) {
        assert_eq!(
            cs.len(),
            self.constraints.len(),
            "constraint arity mismatch (validated by the caller)"
        );
        for (m, &c) in self.constraints.iter_mut().zip(cs) {
            m.add_sample(x, c);
        }
    }

    /// Joint refit at the refit barrier: objective first, then every
    /// constraint surrogate — all members see the same barrier, so a
    /// checkpoint taken here is reproducible for the whole bank.
    fn optimize_hyperparams(&mut self) {
        self.objective.optimize_hyperparams();
        for m in &mut self.constraints {
            m.optimize_hyperparams();
        }
    }
}

impl<M: StateModel> StateModel for ModelBank<M> {
    fn capture_state(&self) -> ModelState {
        let mut members = Vec::with_capacity(1 + self.constraints.len());
        members.push(self.objective.capture_state());
        for m in &self.constraints {
            members.push(m.capture_state());
        }
        ModelState::Bank(BankState { members })
    }

    fn restore_state(&mut self, state: &ModelState) -> Result<(), String> {
        let bank = match state {
            ModelState::Bank(b) => b,
            _ => return Err("cannot restore a non-bank state into a model bank".into()),
        };
        if bank.members.len() != 1 + self.constraints.len() {
            return Err(format!(
                "bank arity mismatch: model has {} channels, state has {}",
                self.constraints.len(),
                bank.channels()
            ));
        }
        self.objective.restore_state(&bank.members[0])?;
        for (m, s) in self.constraints.iter_mut().zip(&bank.members[1..]) {
            m.restore_state(s)?;
        }
        Ok(())
    }

    fn hp_refits(&self) -> u64 {
        // members refit in lockstep at the shared barrier, so the
        // objective's counter stands for the whole bank
        self.objective.hp_refits()
    }

    fn set_hp_refits(&mut self, refits: u64) {
        self.objective.set_hp_refits(refits);
        for m in &mut self.constraints {
            m.set_hp_refits(refits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Matern52;
    use crate::mean::ZeroMean;
    use crate::model::gp::Gp;
    use crate::rng::Pcg64;

    fn bank_with_disk_constraint() -> ModelBank<Gp<Matern52, ZeroMean>> {
        let mk = || Gp::new(Matern52::new(2), ZeroMean, 0.01);
        let mut bank = ModelBank::new(mk(), vec![mk()]);
        let mut rng = Pcg64::seed(0xBA2);
        for _ in 0..25 {
            let x = rng.unit_point(2);
            let y = -(x[0] - 0.3).powi(2) - (x[1] - 0.7).powi(2);
            // feasible (>= 0) inside the disk of radius 0.4 around center
            let c = 0.16 - (x[0] - 0.5).powi(2) - (x[1] - 0.5).powi(2);
            bank.add_sample(&x, y);
            bank.add_constraint_sample(&x, &[c]);
        }
        bank
    }

    #[test]
    fn delegates_objective_and_learns_constraint() {
        let bank = bank_with_disk_constraint();
        assert_eq!(bank.n_constraint_channels(), 1);
        assert_eq!(bank.n_samples(), 25);
        assert_eq!(bank.constraint(0).n_samples(), 25);
        // the constraint surrogate learned the disk: center feasible,
        // corner infeasible
        let (c_in, _) = bank.constraint(0).predict(&[0.5, 0.5]);
        let (c_out, _) = bank.constraint(0).predict(&[0.02, 0.02]);
        assert!(c_in > 0.0, "center should predict feasible: {c_in}");
        assert!(c_out < 0.0, "corner should predict infeasible: {c_out}");
        // objective delegation is exact
        let (mu_bank, var_bank) = bank.predict(&[0.4, 0.6]);
        let (mu_obj, var_obj) = bank.objective.predict(&[0.4, 0.6]);
        assert_eq!(mu_bank.to_bits(), mu_obj.to_bits());
        assert_eq!(var_bank.to_bits(), var_obj.to_bits());
    }

    #[test]
    fn state_roundtrip_restores_every_member() {
        let bank = bank_with_disk_constraint();
        let state = bank.capture_state();
        let text = state.to_text();
        let parsed = ModelState::from_text(&text).unwrap();
        assert_eq!(state, parsed);

        let mk = || Gp::new(Matern52::new(2), ZeroMean, 0.01);
        let mut fresh = ModelBank::new(mk(), vec![mk()]);
        fresh.restore_state(&parsed).unwrap();
        for probe in [[0.5, 0.5], [0.1, 0.9]] {
            let (m1, v1) = bank.predict(&probe);
            let (m2, v2) = fresh.predict(&probe);
            assert!((m1 - m2).abs() < 1e-12 && (v1 - v2).abs() < 1e-12);
            let (c1, _) = bank.constraint(0).predict(&probe);
            let (c2, _) = fresh.constraint(0).predict(&probe);
            assert!((c1 - c2).abs() < 1e-12, "{c1} vs {c2}");
        }

        // arity mismatch is a typed error, not a panic
        let mut wrong = ModelBank::new(mk(), vec![mk(), mk()]);
        assert!(wrong.restore_state(&parsed).is_err());
    }
}
