//! Inducing-point selection for the sparse GP.
//!
//! Two entry points, matching the two phases of a BO run:
//! * [`InducingSet::rebuild`] — greedy max-min (farthest-point traversal)
//!   selection from a full observation set, used by batch fits. O(n·m)
//!   distance evaluations, deterministic (starts from index 0).
//! * [`InducingSet::offer`] — fixed-budget online update used by
//!   `add_sample`: while under budget every novel point is admitted; at
//!   budget the candidate replaces its *nearest* inducing point iff doing
//!   so increases the set's spread (the candidate is farther from the rest
//!   of the set than the point it evicts). O(m) per offer.

/// Squared Euclidean distance.
#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Result of [`InducingSet::offer`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InducingUpdate {
    /// The candidate was appended (set was under budget).
    Added,
    /// The candidate replaced the inducing point at this index.
    Swapped(usize),
    /// The set is unchanged (candidate duplicates or does not improve it).
    Unchanged,
}

/// A budgeted set of inducing-point locations.
#[derive(Clone, Debug)]
pub struct InducingSet {
    budget: usize,
    points: Vec<Vec<f64>>,
}

impl InducingSet {
    /// Empty set with a fixed budget `m`.
    pub fn new(budget: usize) -> Self {
        assert!(budget > 0, "inducing budget must be positive");
        Self { budget, points: Vec::new() }
    }

    /// Maximum number of inducing points.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Current number of inducing points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Is the set empty?
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Has the set reached its budget?
    pub fn is_full(&self) -> bool {
        self.points.len() >= self.budget
    }

    /// The inducing locations.
    pub fn points(&self) -> &[Vec<f64>] {
        &self.points
    }

    /// Remove every inducing point (budget unchanged).
    pub fn clear(&mut self) {
        self.points.clear();
    }

    /// Replace the set wholesale (checkpoint restore); grows the budget if
    /// the given set exceeds it.
    pub fn set_points(&mut self, points: Vec<Vec<f64>>) {
        self.budget = self.budget.max(points.len());
        self.points = points;
    }

    /// Greedy max-min selection of `min(budget, n)` points from `xs`:
    /// start at `xs[0]`, then repeatedly take the observation farthest
    /// from the current set. Stops early if only duplicates remain.
    pub fn rebuild(&mut self, xs: &[Vec<f64>]) {
        self.points.clear();
        if xs.is_empty() {
            return;
        }
        let m = self.budget.min(xs.len());
        self.points.push(xs[0].clone());
        // min squared distance from each observation to the chosen set
        let mut mind: Vec<f64> = xs.iter().map(|x| dist2(x, &xs[0])).collect();
        while self.points.len() < m {
            let (mut best_i, mut best_d) = (0usize, 0.0f64);
            for (i, &d) in mind.iter().enumerate() {
                if d > best_d {
                    best_d = d;
                    best_i = i;
                }
            }
            if best_d <= 0.0 {
                break; // everything left coincides with a chosen point
            }
            self.points.push(xs[best_i].clone());
            for (d, x) in mind.iter_mut().zip(xs) {
                let nd = dist2(x, &xs[best_i]);
                if nd < *d {
                    *d = nd;
                }
            }
        }
    }

    /// Offer a new observation location to the set (online update).
    pub fn offer(&mut self, x: &[f64]) -> InducingUpdate {
        if self.points.is_empty() {
            self.points.push(x.to_vec());
            return InducingUpdate::Added;
        }
        // nearest inducing point to the candidate
        let (mut j, mut d_xj) = (0usize, f64::INFINITY);
        for (k, z) in self.points.iter().enumerate() {
            let d = dist2(x, z);
            if d < d_xj {
                d_xj = d;
                j = k;
            }
        }
        if d_xj <= 0.0 {
            return InducingUpdate::Unchanged; // exact duplicate
        }
        if !self.is_full() {
            self.points.push(x.to_vec());
            return InducingUpdate::Added;
        }
        if self.points.len() < 2 {
            return InducingUpdate::Unchanged; // budget 1: keep the seed
        }
        // replace-nearest rule: evict z_j iff the candidate is farther
        // from the rest of the set than z_j is (spread strictly improves)
        let mut d_x_rest = f64::INFINITY;
        let mut d_j_rest = f64::INFINITY;
        for (k, z) in self.points.iter().enumerate() {
            if k == j {
                continue;
            }
            d_x_rest = d_x_rest.min(dist2(x, z));
            d_j_rest = d_j_rest.min(dist2(&self.points[j], z));
        }
        if d_x_rest > d_j_rest {
            self.points[j] = x.to_vec();
            InducingUpdate::Swapped(j)
        } else {
            InducingUpdate::Unchanged
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn min_gap(points: &[Vec<f64>]) -> f64 {
        let mut g = f64::INFINITY;
        for i in 0..points.len() {
            for j in (i + 1)..points.len() {
                g = g.min(dist2(&points[i], &points[j]));
            }
        }
        g
    }

    #[test]
    fn rebuild_picks_spread_points_on_a_line() {
        let xs: Vec<Vec<f64>> = (0..11).map(|i| vec![i as f64 / 10.0]).collect();
        let mut set = InducingSet::new(3);
        set.rebuild(&xs);
        assert_eq!(set.len(), 3);
        // farthest-point from x=0 picks both endpoints then the middle
        let mut got: Vec<f64> = set.points().iter().map(|p| p[0]).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn rebuild_respects_budget_and_duplicates() {
        let xs = vec![vec![0.3, 0.3]; 7];
        let mut set = InducingSet::new(4);
        set.rebuild(&xs);
        assert_eq!(set.len(), 1, "identical points collapse to one");

        let mut rng = Pcg64::seed(5);
        let xs: Vec<Vec<f64>> = (0..50).map(|_| rng.unit_point(3)).collect();
        set.rebuild(&xs);
        assert_eq!(set.len(), 4);
    }

    #[test]
    fn offer_grows_until_budget_then_swaps_to_improve_spread() {
        let mut set = InducingSet::new(3);
        assert_eq!(set.offer(&[0.0]), InducingUpdate::Added);
        assert_eq!(set.offer(&[0.1]), InducingUpdate::Added);
        assert_eq!(set.offer(&[0.2]), InducingUpdate::Added);
        assert!(set.is_full());
        let before = min_gap(set.points());
        // 1.0 is far from everything: must evict its nearest point (0.2)
        assert_eq!(set.offer(&[1.0]), InducingUpdate::Swapped(2));
        assert!(min_gap(set.points()) >= before);
        // a point crammed between two existing ones does not help
        assert_eq!(set.offer(&[0.05]), InducingUpdate::Unchanged);
        // duplicates never enter
        assert_eq!(set.offer(&[1.0]), InducingUpdate::Unchanged);
    }

    #[test]
    fn offer_sequence_keeps_spread_nondecreasing() {
        let mut rng = Pcg64::seed(0x5e7);
        let mut set = InducingSet::new(8);
        for _ in 0..16 {
            set.offer(&rng.unit_point(2));
        }
        assert!(set.is_full());
        let mut gap = min_gap(set.points());
        for _ in 0..200 {
            let x = rng.unit_point(2);
            if let InducingUpdate::Swapped(_) = set.offer(&x) {
                let ng = min_gap(set.points());
                assert!(ng >= gap - 1e-15, "swap reduced spread: {gap} -> {ng}");
                gap = ng;
            }
        }
    }

    #[test]
    fn set_points_overrides_budget() {
        let mut set = InducingSet::new(2);
        set.set_points(vec![vec![0.0], vec![0.5], vec![1.0]]);
        assert_eq!(set.len(), 3);
        assert_eq!(set.budget(), 3);
    }
}
