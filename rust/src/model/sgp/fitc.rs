//! FITC sparse GP regression (Subset-of-Regressors mean, FITC variance).
//!
//! See the module docs in [`crate::model::sgp`] for the equations and the
//! complexity table. Implementation outline, with `n` observations and
//! `m` inducing points (`m << n`):
//!
//! * batch fit: one `m x m` Cholesky of `K_mm`, one streaming pass over
//!   the `n` cross-covariance rows ([`crate::la::weighted_normal_eqs`])
//!   to form `A = K_mm + K_mn Λ⁻¹ K_nm` and `b = K_mn Λ⁻¹ r`, one
//!   `m x m` Cholesky of `A` — O(n·m²) total;
//! * incremental `add_sample` (inducing set unchanged): rank-1 update of
//!   `A`, O(n·m) right-hand-side refresh, O(m³) refactor — independent of
//!   the O(n·m²) batch path and `m/1`-ish cheaper than it;
//! * predict: O(m) mean (cached `alpha`), O(m²) variance (two triangular
//!   solves).

use crate::kernel::Kernel;
use crate::la::{axpy, dot, rank1_update, spd_factor_jittered, weighted_normal_eqs};
use crate::la::{CholeskyFactor, Matrix};
use crate::mean::MeanFn;
use crate::model::gp::Gp;
use crate::model::sgp::inducing::{InducingSet, InducingUpdate};
use crate::model::Model;

/// Tunables for [`SparseGp`].
#[derive(Clone, Debug)]
pub struct SgpConfig {
    /// Inducing-point budget `m`.
    pub max_inducing: usize,
    /// Maximum diagonal jitter tried when `K_mm` / `A` are numerically
    /// semi-definite (clustered inducing points).
    pub max_jitter: f64,
    /// Row-block size for the normal-equation pass (0 = library default).
    pub block: usize,
    /// Cap on the data subset used by the dense hyper-parameter proxy fit
    /// in `optimize_hyperparams` (ML-II on the full set would be O(n³)).
    pub hp_subset: usize,
}

impl Default for SgpConfig {
    fn default() -> Self {
        Self { max_inducing: 128, max_jitter: 1e-2, block: 0, hp_subset: 256 }
    }
}

/// Sparse (inducing-point) Gaussian process with kernel `K`, prior mean `M`.
#[derive(Clone)]
pub struct SparseGp<K: Kernel, M: MeanFn> {
    kernel: K,
    mean: M,
    /// log sigma_n (observation noise std).
    log_noise: f64,
    /// Whether `optimize_hyperparams` also tunes the noise.
    pub learn_noise: bool,
    /// Tunables.
    pub config: SgpConfig,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    best: Option<f64>,
    inducing: InducingSet,
    /// chol(K_mm + jitter I)
    l_mm: CholeskyFactor,
    /// A = K_mm + jitter I + sum_i w_i k_i k_i^T (kept raw for rank-1 adds)
    a_raw: Matrix,
    /// chol(A + possible extra jitter)
    l_a: CholeskyFactor,
    /// Cross-covariance rows K_nm, row-major n x m.
    rows: Vec<f64>,
    /// Per-observation FITC weights w_i = 1 / lambda_i.
    w: Vec<f64>,
    /// alpha = A^{-1} b; posterior mean is m(x) + k_*^T alpha.
    alpha: Vec<f64>,
}

impl<K: Kernel, M: MeanFn> SparseGp<K, M> {
    /// New empty sparse GP with the default [`SgpConfig`]. `noise` is the
    /// observation noise std `sigma_n`.
    pub fn new(kernel: K, mean: M, noise: f64) -> Self {
        Self::with_config(kernel, mean, noise, SgpConfig::default())
    }

    /// New empty sparse GP with an explicit configuration.
    pub fn with_config(kernel: K, mean: M, noise: f64, config: SgpConfig) -> Self {
        assert!(noise > 0.0, "noise std must be positive");
        assert!(config.max_inducing > 0, "max_inducing must be positive");
        let inducing = InducingSet::new(config.max_inducing);
        Self {
            kernel,
            mean,
            log_noise: noise.ln(),
            learn_noise: false,
            config,
            xs: Vec::new(),
            ys: Vec::new(),
            best: None,
            inducing,
            l_mm: CholeskyFactor::empty(),
            a_raw: Matrix::zeros(0, 0),
            l_a: CholeskyFactor::empty(),
            rows: Vec::new(),
            w: Vec::new(),
            alpha: Vec::new(),
        }
    }

    /// Build a sparse GP from a fitted dense GP (same kernel/mean state,
    /// current hyper-parameters), refitting on its data.
    pub fn from_dense(gp: &Gp<K, M>, config: SgpConfig) -> Self {
        let (kernel, mean) = (gp.kernel().clone(), gp.mean().clone());
        let mut sgp = Self::with_config(kernel, mean, gp.noise_var().sqrt(), config);
        sgp.learn_noise = gp.learn_noise;
        sgp.fit(gp.samples(), gp.observations());
        sgp
    }

    /// Observation noise variance `sigma_n^2`.
    pub fn noise_var(&self) -> f64 {
        (2.0 * self.log_noise).exp()
    }

    /// Borrow the kernel.
    pub fn kernel(&self) -> &K {
        &self.kernel
    }

    /// Borrow the prior mean.
    pub fn mean(&self) -> &M {
        &self.mean
    }

    /// Training inputs.
    pub fn samples(&self) -> &[Vec<f64>] {
        &self.xs
    }

    /// Training observations.
    pub fn observations(&self) -> &[f64] {
        &self.ys
    }

    /// Current inducing-point locations.
    pub fn inducing_points(&self) -> &[Vec<f64>] {
        self.inducing.points()
    }

    /// Current log-hyper-params `[kernel..., log sigma_n]`.
    pub fn hp_vector(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.log_noise);
        p
    }

    /// Set `[kernel..., log sigma_n]` and refit, keeping the current
    /// inducing set (noise entry only applied when `learn_noise` is on —
    /// pass `force_noise` to override, e.g. on checkpoint restore).
    pub fn set_hp_vector(&mut self, p: &[f64], force_noise: bool) {
        self.set_hp_vector_no_refit(p, force_noise);
        self.refit_keep_inducing();
    }

    /// Hyper-param write without the refit, for callers that refit
    /// immediately afterwards anyway (checkpoint restore).
    pub(crate) fn set_hp_vector_no_refit(&mut self, p: &[f64], force_noise: bool) {
        let np = self.kernel.n_params();
        self.kernel.set_params(&p[..np]);
        if self.learn_noise || force_noise {
            self.log_noise = p[np];
        }
    }

    /// Fit with an explicitly chosen inducing set (checkpoint restore /
    /// expert use); skips the greedy selection.
    pub fn fit_with_inducing(&mut self, xs: &[Vec<f64>], ys: &[f64], zs: Vec<Vec<f64>>) {
        assert_eq!(xs.len(), ys.len());
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.best =
            ys.iter().cloned().fold(None, |b: Option<f64>, v| Some(b.map_or(v, |b| b.max(v))));
        self.inducing.set_points(zs);
        self.refit_keep_inducing();
    }

    /// Refit all factors from the current data, keeping the inducing set.
    pub fn refit_keep_inducing(&mut self) {
        self.refit_inner(false);
    }

    /// Full refit including greedy re-selection of the inducing set.
    pub fn refit(&mut self) {
        self.refit_inner(true);
    }

    fn clear_factors(&mut self) {
        self.l_mm = CholeskyFactor::empty();
        self.a_raw = Matrix::zeros(0, 0);
        self.l_a = CholeskyFactor::empty();
        self.rows.clear();
        self.w.clear();
        self.alpha.clear();
    }

    fn refit_inner(&mut self, rebuild_inducing: bool) {
        self.mean.update(&self.ys);
        let n = self.xs.len();
        if n == 0 {
            // invariant: a non-empty inducing set implies fitted factors
            // (predict branches on m > 0), so it must go too
            self.inducing.clear();
            self.clear_factors();
            return;
        }
        if rebuild_inducing || self.inducing.is_empty() {
            self.inducing.rebuild(&self.xs);
        }
        let m = self.inducing.len();
        let noise = self.noise_var();
        let max_jitter = self.config.max_jitter;

        // K_mm (+ jitter escalated until SPD)
        let zs = self.inducing.points();
        let mut kmm = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..=i {
                let v = self.kernel.eval(&zs[i], &zs[j]);
                kmm[(i, j)] = v;
                kmm[(j, i)] = v;
            }
        }
        let (l_mm, jitter) = spd_factor_jittered(&kmm, max_jitter)
            .expect("sparse GP: K_mm irrecoverably singular");
        if jitter > 0.0 {
            for i in 0..m {
                kmm[(i, i)] += jitter;
            }
        }

        // cross-covariance rows, FITC weights, residuals
        let mut rows = Vec::with_capacity(n * m);
        let mut w = Vec::with_capacity(n);
        let mut resid = Vec::with_capacity(n);
        let mut scratch = vec![0.0; m];
        for (x, &y) in self.xs.iter().zip(&self.ys) {
            let start = rows.len();
            for z in zs {
                rows.push(self.kernel.eval(x, z));
            }
            l_mm.solve_lower_into(&rows[start..start + m], &mut scratch);
            let q = dot(&scratch, &scratch);
            let lambda = (self.kernel.eval(x, x) - q).max(0.0) + noise;
            w.push(1.0 / lambda);
            resid.push(y - self.mean.eval(x));
        }

        // A = K_mm + sum_i w_i k_i k_i^T ; b = sum_i w_i r_i k_i
        let (mut a_raw, b) = weighted_normal_eqs(&rows, m, &w, &resid, self.config.block);
        for (a, &k) in a_raw.data_mut().iter_mut().zip(kmm.data()) {
            *a += k;
        }
        let (l_a, _) = spd_factor_jittered(&a_raw, max_jitter)
            .expect("sparse GP: normal-equation matrix irrecoverably singular");
        let alpha = l_a.solve(&b);

        self.l_mm = l_mm;
        self.a_raw = a_raw;
        self.l_a = l_a;
        self.rows = rows;
        self.w = w;
        self.alpha = alpha;
    }

    /// Recompute `b` from stored rows/weights and current residuals, then
    /// `alpha = A^{-1} b`. O(n·m + m³). Exact for any [`MeanFn`].
    fn recompute_alpha(&mut self) {
        let m = self.inducing.len();
        let mut b = vec![0.0; m];
        for (i, x) in self.xs.iter().enumerate() {
            let c = self.w[i] * (self.ys[i] - self.mean.eval(x));
            if c != 0.0 {
                axpy(c, &self.rows[i * m..(i + 1) * m], &mut b);
            }
        }
        let (l_a, _) = spd_factor_jittered(&self.a_raw, self.config.max_jitter)
            .expect("sparse GP: normal-equation matrix irrecoverably singular");
        self.alpha = l_a.solve(&b);
        self.l_a = l_a;
    }
}

impl<K: Kernel, M: MeanFn> Model for SparseGp<K, M> {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.best =
            ys.iter().cloned().fold(None, |b: Option<f64>, v| Some(b.map_or(v, |b| b.max(v))));
        self.refit_inner(true);
    }

    fn add_sample(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.kernel.dim(), "sample dim mismatch");
        self.xs.push(x.to_vec());
        self.ys.push(y);
        self.best = Some(self.best.map_or(y, |b| b.max(y)));

        if !self.inducing.is_full() {
            // growth phase: every novel location becomes an inducing point
            // (FITC with Z == X is the exact GP), factors rebuilt in
            // O(n·m²) at most `m` times over the whole run
            self.inducing.offer(x);
            self.refit_keep_inducing();
            return;
        }
        match self.inducing.offer(x) {
            InducingUpdate::Added | InducingUpdate::Swapped(_) => {
                // the set changed: cross-covariances against the evicted
                // point are stale, rebuild the factors
                self.refit_keep_inducing();
            }
            InducingUpdate::Unchanged => {
                // incremental path: rank-1 A update + O(n·m) rhs refresh
                let m = self.inducing.len();
                let zs = self.inducing.points();
                let mut k_new = Vec::with_capacity(m);
                for z in zs {
                    k_new.push(self.kernel.eval(x, z));
                }
                let mut v = vec![0.0; m];
                self.l_mm.solve_lower_into(&k_new, &mut v);
                let q = dot(&v, &v);
                let lambda = (self.kernel.eval(x, x) - q).max(0.0) + self.noise_var();
                let w_new = 1.0 / lambda;
                rank1_update(&mut self.a_raw, w_new, &k_new);
                self.rows.extend_from_slice(&k_new);
                self.w.push(w_new);
                self.mean.update(&self.ys);
                self.recompute_alpha();
            }
        }
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        let prior = self.mean.eval(x);
        let m = self.inducing.len();
        if m == 0 {
            return (prior, self.kernel.variance());
        }
        // thread-local scratch: the acquisition optimizer calls predict
        // hundreds of times per iteration (same rationale as the dense GP)
        thread_local! {
            static SCRATCH: std::cell::RefCell<(Vec<f64>, Vec<f64>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
        }
        SCRATCH.with(|cell| {
            let (ks, v) = &mut *cell.borrow_mut();
            ks.clear();
            ks.extend(self.inducing.points().iter().map(|z| self.kernel.eval(z, x)));
            let mu = prior + dot(ks, &self.alpha);
            v.resize(m, 0.0);
            // q_** = k_*^T K_mm^{-1} k_*
            self.l_mm.solve_lower_into(ks, v);
            let q_star = dot(v, v);
            // correction k_*^T A^{-1} k_*
            self.l_a.solve_lower_into(ks, v);
            let corr = dot(v, v);
            let var = (self.kernel.eval(x, x) - q_star + corr).max(1e-12);
            (mu, var)
        })
    }

    /// Batched posterior: one `m x B` cross-covariance feature block and
    /// two multi-RHS `m x m` triangular solves for the whole candidate
    /// set (vs. `2B` independent solves point-wise) — the sparse half of
    /// the batch-first pipeline.
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let m = self.inducing.len();
        if xs.is_empty() {
            return Vec::new();
        }
        if m == 0 {
            return xs.iter().map(|x| (self.mean.eval(x), self.kernel.variance())).collect();
        }
        // K_* : m x B feature block against the inducing set
        let ks = self.kernel.cross_cov(self.inducing.points(), xs);
        let mus = ks.matvec_t(&self.alpha);
        // q_** = k_*^T K_mm^{-1} k_* and the A^{-1} correction, batched
        let q_star = self.l_mm.solve_lower_multi(&ks).col_squared_norms();
        let corr = self.l_a.solve_lower_multi(&ks).col_squared_norms();
        xs.iter()
            .enumerate()
            .map(|(j, x)| {
                let mu = self.mean.eval(x) + mus[j];
                let var = (self.kernel.eval(x, x) - q_star[j] + corr[j]).max(1e-12);
                (mu, var)
            })
            .collect()
    }

    fn n_samples(&self) -> usize {
        self.xs.len()
    }

    fn dim(&self) -> usize {
        self.kernel.dim()
    }

    fn best_observation(&self) -> Option<f64> {
        self.best
    }

    /// ML-II via a dense proxy GP on a strided data subset (capped at
    /// `config.hp_subset`): optimizing the exact FITC likelihood would
    /// need bespoke gradients, while the subset proxy reuses the dense
    /// machinery and is the standard practical compromise.
    fn optimize_hyperparams(&mut self) {
        let n = self.xs.len();
        if n < 2 {
            return;
        }
        let cap = self.config.hp_subset.max(8);
        let stride = n.div_ceil(cap);
        let sx: Vec<Vec<f64>> = self.xs.iter().step_by(stride).cloned().collect();
        let sy: Vec<f64> = self.ys.iter().step_by(stride).cloned().collect();
        let mut proxy = Gp::new(self.kernel.clone(), self.mean.clone(), self.noise_var().sqrt());
        proxy.learn_noise = self.learn_noise;
        proxy.fit(&sx, &sy);
        proxy.optimize_hyperparams();
        self.kernel.set_params(&proxy.kernel().params());
        if self.learn_noise {
            self.log_noise = 0.5 * proxy.noise_var().ln();
        }
        self.refit_keep_inducing();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Matern52, SquaredExpArd};
    use crate::mean::{DataMean, ZeroMean};
    use crate::rng::Pcg64;

    fn smooth_data(n: usize, dim: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| (3.0 * x[0]).sin() + x.iter().sum::<f64>() * 0.5).collect();
        (xs, ys)
    }

    #[test]
    fn exact_when_inducing_covers_data() {
        // m >= n: FITC with Z == X must reproduce the dense GP closely
        let (xs, ys) = smooth_data(24, 2, 1);
        let mut dense = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        dense.fit(&xs, &ys);
        let mut sparse = SparseGp::with_config(
            Matern52::new(2),
            DataMean::default(),
            1e-2,
            SgpConfig { max_inducing: 64, ..SgpConfig::default() },
        );
        sparse.fit(&xs, &ys);
        assert_eq!(sparse.inducing_points().len(), 24);
        let mut rng = Pcg64::seed(2);
        for _ in 0..20 {
            let p = rng.unit_point(2);
            let (md, vd) = dense.predict(&p);
            let (ms, vs) = sparse.predict(&p);
            assert!((md - ms).abs() < 1e-4, "mean {md} vs {ms}");
            assert!((vd - vs).abs() < 1e-4, "var {vd} vs {vs}");
        }
    }

    #[test]
    fn approximates_dense_with_few_inducing_points() {
        let (xs, ys) = smooth_data(200, 2, 3);
        let mut dense = Gp::new(SquaredExpArd::new(2), ZeroMean, 0.05);
        dense.fit(&xs, &ys);
        let mut sparse = SparseGp::with_config(
            SquaredExpArd::new(2),
            ZeroMean,
            0.05,
            SgpConfig { max_inducing: 40, ..SgpConfig::default() },
        );
        sparse.fit(&xs, &ys);
        let mut rng = Pcg64::seed(4);
        let mut se = 0.0;
        let probes = 100;
        for _ in 0..probes {
            let p = rng.unit_point(2);
            let (md, _) = dense.predict(&p);
            let (ms, vs) = sparse.predict(&p);
            se += (md - ms) * (md - ms);
            assert!(vs.is_finite() && vs > 0.0);
        }
        let rmse = (se / probes as f64).sqrt();
        assert!(rmse < 0.05, "sparse-vs-dense rmse {rmse}");
    }

    #[test]
    fn incremental_add_matches_refit() {
        let (xs, ys) = smooth_data(80, 2, 7);
        let cfg = SgpConfig { max_inducing: 16, ..SgpConfig::default() };
        let mut inc = SparseGp::with_config(Matern52::new(2), DataMean::default(), 0.05, cfg);
        for (x, &y) in xs.iter().zip(&ys) {
            inc.add_sample(x, y);
        }
        // same data + same inducing set, factors rebuilt from scratch
        let mut batch = inc.clone();
        batch.refit_keep_inducing();
        let mut rng = Pcg64::seed(8);
        for _ in 0..20 {
            let p = rng.unit_point(2);
            let (mi, vi) = inc.predict(&p);
            let (mb, vb) = batch.predict(&p);
            assert!((mi - mb).abs() < 1e-7, "mean {mi} vs {mb}");
            assert!((vi - vb).abs() < 1e-7, "var {vi} vs {vb}");
        }
    }

    #[test]
    fn predict_batch_matches_pointwise() {
        let (xs, ys) = smooth_data(120, 2, 11);
        let mut sgp = SparseGp::with_config(
            Matern52::new(2),
            DataMean::default(),
            0.05,
            SgpConfig { max_inducing: 24, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        let mut rng = Pcg64::seed(12);
        let cands: Vec<Vec<f64>> = (0..37).map(|_| rng.unit_point(2)).collect();
        let batch = sgp.predict_batch(&cands);
        for (j, c) in cands.iter().enumerate() {
            let (mu, var) = sgp.predict(c);
            assert!((batch[j].0 - mu).abs() < 1e-10, "mu[{j}]: {} vs {mu}", batch[j].0);
            assert!((batch[j].1 - var).abs() < 1e-10, "var[{j}]: {} vs {var}", batch[j].1);
        }
        // empty model falls back to the prior
        let fresh = SparseGp::new(Matern52::new(2), ZeroMean, 0.05);
        assert_eq!(fresh.predict_batch(&cands)[0], fresh.predict(&cands[0]));
    }

    #[test]
    fn empty_and_tiny_states() {
        let sgp = SparseGp::new(Matern52::new(2), ZeroMean, 0.01);
        let (mu, var) = sgp.predict(&[0.4, 0.4]);
        assert_eq!(mu, 0.0);
        assert!((var - 1.0).abs() < 1e-12);
        assert!(sgp.best_observation().is_none());

        let mut sgp = SparseGp::new(Matern52::new(1), ZeroMean, 0.01);
        sgp.add_sample(&[0.5], 2.0);
        let (mu, var) = sgp.predict(&[0.5]);
        assert!((mu - 2.0).abs() < 0.1, "mu={mu}");
        assert!(var < 0.1);
        assert_eq!(sgp.best_observation(), Some(2.0));
    }

    #[test]
    fn best_observation_tracks_max_and_duplicates_survive() {
        let mut sgp = SparseGp::new(SquaredExpArd::new(1), ZeroMean, 1e-3);
        sgp.add_sample(&[0.1], 1.0);
        sgp.add_sample(&[0.2], 3.0);
        sgp.add_sample(&[0.2], 2.9); // duplicate input
        assert_eq!(sgp.best_observation(), Some(3.0));
        let (mu, _) = sgp.predict(&[0.2]);
        assert!((mu - 2.95).abs() < 0.2, "mu={mu}");
    }

    #[test]
    fn hyperparam_proxy_improves_fit() {
        let mut rng = Pcg64::seed(2024);
        let xs: Vec<Vec<f64>> = (0..60).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (12.0 * x[0]).sin()).collect();
        let mut sgp = SparseGp::with_config(
            SquaredExpArd::with_params(vec![2.0], 0.0),
            ZeroMean,
            0.05,
            SgpConfig { max_inducing: 30, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        sgp.optimize_hyperparams();
        let fitted_l = sgp.kernel().params()[0].exp();
        assert!(fitted_l < 1.0, "fitted lengthscale {fitted_l} should shrink");
        // posterior should now track the fast oscillation
        let (mu, _) = sgp.predict(&[0.13]);
        assert!((mu - (12.0f64 * 0.13).sin()).abs() < 0.3, "mu={mu}");
    }
}
