//! FITC sparse GP regression (Subset-of-Regressors mean, FITC variance).
//!
//! See the module docs in [`crate::model::sgp`] for the equations and the
//! complexity table. Implementation outline, with `n` observations and
//! `m` inducing points (`m << n`):
//!
//! * batch fit: one `m x m` Cholesky of `K_mm`, one streaming pass over
//!   the `n` cross-covariance rows ([`crate::la::weighted_normal_eqs`])
//!   to form `A = K_mm + K_mn Λ⁻¹ K_nm` and `b = K_mn Λ⁻¹ r`, one
//!   `m x m` Cholesky of `A` — O(n·m²) total;
//! * incremental `add_sample` (inducing set unchanged): rank-1 update of
//!   `A`, O(n·m) right-hand-side refresh, O(m³) refactor — independent of
//!   the O(n·m²) batch path and `m/1`-ish cheaper than it;
//! * predict: O(m) mean (cached `alpha`), O(m²) variance (two triangular
//!   solves).

use crate::kernel::Kernel;
use crate::la::{
    axpy, dot, rank1_update, sandwich_solve, spd_factor_jittered, weighted_gram,
    weighted_normal_eqs,
};
use crate::la::{CholeskyFactor, Matrix};
use crate::mean::MeanFn;
use crate::model::gp::Gp;
use crate::model::hp_opt::{KernelLFOpt, LmlModel};
use crate::model::sgp::inducing::{InducingSet, InducingUpdate};
use crate::model::Model;
use crate::obs::{self, Counter, Gauge, Phase};

/// Tunables for [`SparseGp`].
#[derive(Clone, Debug)]
pub struct SgpConfig {
    /// Inducing-point budget `m`.
    pub max_inducing: usize,
    /// Maximum diagonal jitter tried when `K_mm` / `A` are numerically
    /// semi-definite (clustered inducing points).
    pub max_jitter: f64,
    /// Row-block size for the normal-equation pass (0 = library default).
    pub block: usize,
}

impl Default for SgpConfig {
    fn default() -> Self {
        Self { max_inducing: 128, max_jitter: 1e-2, block: 0 }
    }
}

/// Sparse (inducing-point) Gaussian process with kernel `K`, prior mean `M`.
#[derive(Clone)]
pub struct SparseGp<K: Kernel, M: MeanFn> {
    kernel: K,
    mean: M,
    /// log sigma_n (observation noise std).
    log_noise: f64,
    /// Whether `optimize_hyperparams` also tunes the noise.
    pub learn_noise: bool,
    /// Hyper-parameter optimizer settings used by `optimize_hyperparams`
    /// (fits the exact FITC marginal likelihood — see
    /// [`log_marginal_likelihood`](Self::log_marginal_likelihood)).
    pub hp_opt: KernelLFOpt,
    /// Tunables.
    pub config: SgpConfig,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    /// Extra per-observation noise variance added to FITC's Λ diagonal
    /// (heteroskedastic intake). Empty when no observation ever carried
    /// extra noise; otherwise parallel to `ys` with `0.0` for exact rows.
    noise_vars: Vec<f64>,
    best: Option<f64>,
    inducing: InducingSet,
    /// chol(K_mm + jitter I)
    l_mm: CholeskyFactor,
    /// A = K_mm + jitter I + sum_i w_i k_i k_i^T (kept raw for rank-1 adds)
    a_raw: Matrix,
    /// chol(A + possible extra jitter)
    l_a: CholeskyFactor,
    /// Cross-covariance rows K_nm, row-major n x m.
    rows: Vec<f64>,
    /// Per-observation FITC weights w_i = 1 / lambda_i.
    w: Vec<f64>,
    /// alpha = A^{-1} b; posterior mean is m(x) + k_*^T alpha.
    alpha: Vec<f64>,
}

impl<K: Kernel, M: MeanFn> SparseGp<K, M> {
    /// New empty sparse GP with the default [`SgpConfig`]. `noise` is the
    /// observation noise std `sigma_n`.
    pub fn new(kernel: K, mean: M, noise: f64) -> Self {
        Self::with_config(kernel, mean, noise, SgpConfig::default())
    }

    /// New empty sparse GP with an explicit configuration.
    pub fn with_config(kernel: K, mean: M, noise: f64, config: SgpConfig) -> Self {
        assert!(noise > 0.0, "noise std must be positive");
        assert!(config.max_inducing > 0, "max_inducing must be positive");
        let inducing = InducingSet::new(config.max_inducing);
        Self {
            kernel,
            mean,
            log_noise: noise.ln(),
            learn_noise: false,
            hp_opt: KernelLFOpt::default(),
            config,
            xs: Vec::new(),
            ys: Vec::new(),
            noise_vars: Vec::new(),
            best: None,
            inducing,
            l_mm: CholeskyFactor::empty(),
            a_raw: Matrix::zeros(0, 0),
            l_a: CholeskyFactor::empty(),
            rows: Vec::new(),
            w: Vec::new(),
            alpha: Vec::new(),
        }
    }

    /// Build a sparse GP from a fitted dense GP (same kernel/mean state,
    /// current hyper-parameters), refitting on its data.
    pub fn from_dense(gp: &Gp<K, M>, config: SgpConfig) -> Self {
        let _span = obs::span(Phase::SparseMigrate);
        obs::counter_add(Counter::SparseMigrations, 1);
        let (kernel, mean) = (gp.kernel().clone(), gp.mean().clone());
        let mut sgp = Self::with_config(kernel, mean, gp.noise_var().sqrt(), config);
        sgp.learn_noise = gp.learn_noise;
        // carry the optimizer across the dense→sparse migration so its
        // settings and refit counter (restart-seed stream) survive
        sgp.hp_opt = gp.hp_opt.clone();
        sgp.fit_noisy(gp.samples(), gp.observations(), gp.observation_noise_vars());
        sgp
    }

    /// Observation noise variance `sigma_n^2`.
    pub fn noise_var(&self) -> f64 {
        (2.0 * self.log_noise).exp()
    }

    /// Borrow the kernel.
    pub fn kernel(&self) -> &K {
        &self.kernel
    }

    /// Borrow the prior mean.
    pub fn mean(&self) -> &M {
        &self.mean
    }

    /// Training inputs.
    pub fn samples(&self) -> &[Vec<f64>] {
        &self.xs
    }

    /// Training observations.
    pub fn observations(&self) -> &[f64] {
        &self.ys
    }

    /// Extra per-observation noise variances, parallel to
    /// [`observations`](Self::observations) — or empty when every
    /// observation is homoskedastic.
    pub fn observation_noise_vars(&self) -> &[f64] {
        &self.noise_vars
    }

    /// Full refit from `(x, y, extra noise variance)` triples — the
    /// restore/migration path for a heteroskedastic data set. An all-zero
    /// (or empty) `noise_vars` normalizes to the homoskedastic
    /// representation.
    pub fn fit_noisy(&mut self, xs: &[Vec<f64>], ys: &[f64], noise_vars: &[f64]) {
        assert!(
            noise_vars.is_empty() || noise_vars.len() == ys.len(),
            "noise_vars must be empty or parallel to ys"
        );
        if noise_vars.iter().any(|&v| v > 0.0) {
            self.noise_vars = noise_vars.iter().map(|&v| v.max(0.0)).collect();
        } else {
            self.noise_vars.clear();
        }
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.best =
            ys.iter().cloned().fold(None, |b: Option<f64>, v| Some(b.map_or(v, |b| b.max(v))));
        self.refit_inner(true);
    }

    /// Current inducing-point locations.
    pub fn inducing_points(&self) -> &[Vec<f64>] {
        self.inducing.points()
    }

    /// Current log-hyper-params `[kernel..., log sigma_n]`.
    pub fn hp_vector(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.log_noise);
        p
    }

    /// Set `[kernel..., log sigma_n]` and refit, keeping the current
    /// inducing set (noise entry only applied when `learn_noise` is on —
    /// pass `force_noise` to override, e.g. on checkpoint restore).
    pub fn set_hp_vector(&mut self, p: &[f64], force_noise: bool) {
        self.set_hp_vector_no_refit(p, force_noise);
        self.refit_keep_inducing();
    }

    /// Hyper-param write without the refit, for callers that refit
    /// immediately afterwards anyway (checkpoint restore).
    pub(crate) fn set_hp_vector_no_refit(&mut self, p: &[f64], force_noise: bool) {
        let np = self.kernel.n_params();
        self.kernel.set_params(&p[..np]);
        if self.learn_noise || force_noise {
            self.log_noise = p[np];
        }
    }

    /// Fit with an explicitly chosen inducing set (checkpoint restore /
    /// expert use); skips the greedy selection.
    pub fn fit_with_inducing(&mut self, xs: &[Vec<f64>], ys: &[f64], zs: Vec<Vec<f64>>) {
        self.fit_with_inducing_noisy(xs, ys, &[], zs);
    }

    /// [`fit_with_inducing`](Self::fit_with_inducing) carrying extra
    /// per-observation noise variances (empty = homoskedastic) — the
    /// checkpoint-restore path for heteroskedastic studies.
    pub fn fit_with_inducing_noisy(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        noise_vars: &[f64],
        zs: Vec<Vec<f64>>,
    ) {
        assert_eq!(xs.len(), ys.len());
        assert!(
            noise_vars.is_empty() || noise_vars.len() == ys.len(),
            "noise_vars must be empty or parallel to ys"
        );
        if noise_vars.iter().any(|&v| v > 0.0) {
            self.noise_vars = noise_vars.iter().map(|&v| v.max(0.0)).collect();
        } else {
            self.noise_vars.clear();
        }
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.best =
            ys.iter().cloned().fold(None, |b: Option<f64>, v| Some(b.map_or(v, |b| b.max(v))));
        self.inducing.set_points(zs);
        self.refit_keep_inducing();
    }

    /// Refit all factors from the current data, keeping the inducing set.
    pub fn refit_keep_inducing(&mut self) {
        self.refit_inner(false);
    }

    /// Full refit including greedy re-selection of the inducing set.
    pub fn refit(&mut self) {
        self.refit_inner(true);
    }

    fn clear_factors(&mut self) {
        self.l_mm = CholeskyFactor::empty();
        self.a_raw = Matrix::zeros(0, 0);
        self.l_a = CholeskyFactor::empty();
        self.rows.clear();
        self.w.clear();
        self.alpha.clear();
    }

    fn refit_inner(&mut self, rebuild_inducing: bool) {
        let _span = obs::span(Phase::SparseFit);
        self.mean.update(&self.ys);
        let n = self.xs.len();
        if n == 0 {
            // invariant: a non-empty inducing set implies fitted factors
            // (predict branches on m > 0), so it must go too
            self.inducing.clear();
            self.clear_factors();
            return;
        }
        if rebuild_inducing || self.inducing.is_empty() {
            self.inducing.rebuild(&self.xs);
        }
        let m = self.inducing.len();
        let noise = self.noise_var();
        let max_jitter = self.config.max_jitter;

        // K_mm (+ jitter escalated until SPD)
        let zs = self.inducing.points();
        let mut kmm = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..=i {
                let v = self.kernel.eval(&zs[i], &zs[j]);
                kmm[(i, j)] = v;
                kmm[(j, i)] = v;
            }
        }
        let (l_mm, jitter) = spd_factor_jittered(&kmm, max_jitter)
            .expect("sparse GP: K_mm irrecoverably singular");
        if jitter > 0.0 {
            for i in 0..m {
                kmm[(i, i)] += jitter;
            }
        }

        // cross-covariance rows, FITC weights, residuals
        let mut rows = Vec::with_capacity(n * m);
        let mut w = Vec::with_capacity(n);
        let mut resid = Vec::with_capacity(n);
        let mut scratch = vec![0.0; m];
        for (i, (x, &y)) in self.xs.iter().zip(&self.ys).enumerate() {
            let start = rows.len();
            for z in zs {
                rows.push(self.kernel.eval(x, z));
            }
            l_mm.solve_lower_into(&rows[start..start + m], &mut scratch);
            let q = dot(&scratch, &scratch);
            let mut lambda = (self.kernel.eval(x, x) - q).max(0.0) + noise;
            // heteroskedastic rows widen their own Λ entry only; the
            // `!= 0.0` guard keeps the homoskedastic path bit-identical
            if let Some(&nv) = self.noise_vars.get(i) {
                if nv != 0.0 {
                    lambda += nv;
                }
            }
            w.push(1.0 / lambda);
            resid.push(y - self.mean.eval(x));
        }

        // A = K_mm + sum_i w_i k_i k_i^T ; b = sum_i w_i r_i k_i
        let (mut a_raw, b) = weighted_normal_eqs(&rows, m, &w, &resid, self.config.block);
        for (a, &k) in a_raw.data_mut().iter_mut().zip(kmm.data()) {
            *a += k;
        }
        let (l_a, _) = spd_factor_jittered(&a_raw, max_jitter)
            .expect("sparse GP: normal-equation matrix irrecoverably singular");
        let alpha = l_a.solve(&b);

        self.l_mm = l_mm;
        self.a_raw = a_raw;
        self.l_a = l_a;
        self.rows = rows;
        self.w = w;
        self.alpha = alpha;
        obs::gauge_set(Gauge::InducingPoints, m as u64);
    }

    /// Exact FITC log marginal likelihood of the current fit,
    /// `log N(y | m(X), Q_nn + Λ)` with `Q_nn = K_nm K_mm⁻¹ K_mn`,
    /// computed from the cached Woodbury factors in O(n·m):
    ///
    /// ```text
    /// rᵀ Σ⁻¹ r  = Σ_i w_i r_i² − bᵀ A⁻¹ b          (b = K_mn Λ⁻¹ r)
    /// log|Σ|    = log|A| − log|K_mm| + Σ_i log λ_i
    /// ```
    pub fn log_marginal_likelihood(&self) -> f64 {
        let n = self.xs.len();
        if n == 0 {
            return 0.0;
        }
        let m = self.inducing.len();
        let mut quad = 0.0;
        let mut logdet_lambda = 0.0;
        let mut b = vec![0.0; m];
        for (i, x) in self.xs.iter().enumerate() {
            let r = self.ys[i] - self.mean.eval(x);
            let w = self.w[i];
            quad += w * r * r;
            logdet_lambda -= w.ln();
            if w * r != 0.0 {
                axpy(w * r, &self.rows[i * m..(i + 1) * m], &mut b);
            }
        }
        quad -= dot(&b, &self.alpha);
        let logdet = self.l_a.log_det() - self.l_mm.log_det() + logdet_lambda;
        -0.5 * quad - 0.5 * logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }

    /// Analytic gradient of the exact FITC LML w.r.t.
    /// `[kernel log-params..., log sigma_n]`, in O(n·m² + m³) plus
    /// O(n·m + m²) batched kernel-gradient evaluations.
    ///
    /// With `μ = Σ⁻¹ r` the gradient is `½ tr((μμᵀ − Σ⁻¹) dΣ)`; pushing
    /// the trace through the Woodbury factors collapses everything onto
    /// three weight sets contracted against kernel-gradient blocks
    /// (validated against finite differences and the dense GP at m = n):
    ///
    /// * per-point diagonal weights `v_i = μ_i² − Σ⁻¹_ii` on `dk(x_i, x_i)`
    ///   (and `σ_n² Σ_i v_i` for the log-noise entry),
    /// * an n×m cross block `U = μγᵀ − Λ⁻¹T ᵀ − diag(v) Sᵀ` on
    ///   `dk(x_i, z_j)`, where `T = A⁻¹K_mn`, `S = K_mm⁻¹K_mn`, `γ = Sμ`,
    /// * an m×m inducing block
    ///   `½ (S diag(v) Sᵀ − γγᵀ + K_mm⁻¹ − A⁻¹)` on `dk(z_j, z_k)`.
    pub fn lml_grad(&self) -> Vec<f64> {
        let _span = obs::span(Phase::LmlGrad);
        let n = self.xs.len();
        let np = self.kernel.n_params();
        let mut grad = vec![0.0; np + 1];
        if n == 0 {
            return grad;
        }
        let m = self.inducing.len();
        let zs = self.inducing.points();

        // K_mn (m x n): column i is k_i = k(Z, x_i)
        let mut kmn = Matrix::zeros(m, n);
        for i in 0..n {
            for (j, &v) in self.rows[i * m..(i + 1) * m].iter().enumerate() {
                kmn[(j, i)] = v;
            }
        }
        // Woodbury factors: one blocked multi-solve per m×m factor
        let t = self.l_a.solve_multi(&kmn); // A⁻¹ K_mn
        let s = self.l_mm.solve_multi(&kmn); // K_mm⁻¹ K_mn

        // μ = Σ⁻¹ r through Woodbury: μ_i = w_i (r_i − k_iᵀ α)
        let mut mu = vec![0.0; n];
        for (i, x) in self.xs.iter().enumerate() {
            let ki = &self.rows[i * m..(i + 1) * m];
            mu[i] = self.w[i] * (self.ys[i] - self.mean.eval(x) - dot(ki, &self.alpha));
        }
        let gamma = s.matvec(&mu);

        // diagonal trace weights v_i = μ_i² − Σ⁻¹_ii,
        // Σ⁻¹_ii = w_i − w_i² k_iᵀ A⁻¹ k_i
        let mut v = vec![0.0; n];
        for i in 0..n {
            let ki = &self.rows[i * m..(i + 1) * m];
            let mut kt = 0.0;
            for (j, &kv) in ki.iter().enumerate() {
                kt += kv * t[(j, i)];
            }
            v[i] = mu[i] * mu[i] - self.w[i] + self.w[i] * self.w[i] * kt;
        }

        // cross-block weights U (n x m)
        let mut u = Matrix::zeros(n, m);
        for i in 0..n {
            let urow = u.row_mut(i);
            for (j, o) in urow.iter_mut().enumerate() {
                *o = mu[i] * gamma[j] - self.w[i] * t[(j, i)] - v[i] * s[(j, i)];
            }
        }

        // inducing-block weights ½ (D − γγᵀ + K_mm⁻¹ − A⁻¹) with
        // D = K_mm⁻¹ (K_mn diag(v) K_nm) K_mm⁻¹ (diagonal-correction part)
        let d_inner = weighted_gram(&self.rows, m, &v, self.config.block);
        let d = sandwich_solve(&self.l_mm, &d_inner);
        let kmm_inv = self.l_mm.inverse();
        let a_inv = self.l_a.inverse();
        let mut wmm = Matrix::zeros(m, m);
        for j in 0..m {
            let wrow = wmm.row_mut(j);
            for (k, o) in wrow.iter_mut().enumerate() {
                *o = 0.5
                    * (d[(j, k)] - gamma[j] * gamma[k] + kmm_inv[(j, k)] - a_inv[(j, k)]);
            }
        }

        // contract the three weight sets against kernel gradients
        let mut dk = vec![0.0; np];
        for (i, x) in self.xs.iter().enumerate() {
            if v[i] == 0.0 {
                continue;
            }
            self.kernel.grad_params(x, x, &mut dk);
            for (g, &dv) in grad[..np].iter_mut().zip(&dk) {
                *g += 0.5 * v[i] * dv;
            }
        }
        self.kernel.grad_params_block(&self.xs, zs, &u, &mut grad[..np]);
        self.kernel.grad_params_block(zs, zs, &wmm, &mut grad[..np]);
        // dλ_i/dlog σ_n = 2 σ_n², so the noise entry is σ_n² Σ_i v_i
        grad[np] = self.noise_var() * v.iter().sum::<f64>();
        grad
    }

    /// Recompute `b` from stored rows/weights and current residuals, then
    /// `alpha = A^{-1} b`. O(n·m + m³). Exact for any [`MeanFn`].
    fn recompute_alpha(&mut self) {
        let m = self.inducing.len();
        let mut b = vec![0.0; m];
        for (i, x) in self.xs.iter().enumerate() {
            let c = self.w[i] * (self.ys[i] - self.mean.eval(x));
            if c != 0.0 {
                axpy(c, &self.rows[i * m..(i + 1) * m], &mut b);
            }
        }
        let (l_a, _) = spd_factor_jittered(&self.a_raw, self.config.max_jitter)
            .expect("sparse GP: normal-equation matrix irrecoverably singular");
        self.alpha = l_a.solve(&b);
        self.l_a = l_a;
    }
}

impl<K: Kernel, M: MeanFn> Model for SparseGp<K, M> {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.noise_vars.clear();
        self.best =
            ys.iter().cloned().fold(None, |b: Option<f64>, v| Some(b.map_or(v, |b| b.max(v))));
        self.refit_inner(true);
    }

    fn add_sample(&mut self, x: &[f64], y: f64) {
        self.add_sample_noisy(x, y, 0.0);
    }

    fn add_sample_noisy(&mut self, x: &[f64], y: f64, extra_var: f64) {
        assert_eq!(x.len(), self.kernel.dim(), "sample dim mismatch");
        // become heteroskedastic lazily: only once the first noisy
        // observation arrives does the parallel variance vector exist
        if extra_var > 0.0 || !self.noise_vars.is_empty() {
            self.noise_vars.resize(self.xs.len(), 0.0);
            self.noise_vars.push(extra_var.max(0.0));
        }
        self.xs.push(x.to_vec());
        self.ys.push(y);
        self.best = Some(self.best.map_or(y, |b| b.max(y)));

        if !self.inducing.is_full() {
            // growth phase: every novel location becomes an inducing point
            // (FITC with Z == X is the exact GP), factors rebuilt in
            // O(n·m²) at most `m` times over the whole run
            self.inducing.offer(x);
            self.refit_keep_inducing();
            return;
        }
        match self.inducing.offer(x) {
            InducingUpdate::Added | InducingUpdate::Swapped(_) => {
                // the set changed: cross-covariances against the evicted
                // point are stale, rebuild the factors
                self.refit_keep_inducing();
            }
            InducingUpdate::Unchanged => {
                // incremental path: rank-1 A update + O(n·m) rhs refresh
                let m = self.inducing.len();
                let zs = self.inducing.points();
                let mut k_new = Vec::with_capacity(m);
                for z in zs {
                    k_new.push(self.kernel.eval(x, z));
                }
                let mut v = vec![0.0; m];
                self.l_mm.solve_lower_into(&k_new, &mut v);
                let q = dot(&v, &v);
                let mut lambda = (self.kernel.eval(x, x) - q).max(0.0) + self.noise_var();
                if extra_var > 0.0 {
                    lambda += extra_var;
                }
                let w_new = 1.0 / lambda;
                rank1_update(&mut self.a_raw, w_new, &k_new);
                self.rows.extend_from_slice(&k_new);
                self.w.push(w_new);
                self.mean.update(&self.ys);
                self.recompute_alpha();
            }
        }
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        let prior = self.mean.eval(x);
        let m = self.inducing.len();
        if m == 0 {
            return (prior, self.kernel.variance());
        }
        // thread-local scratch: the acquisition optimizer calls predict
        // hundreds of times per iteration (same rationale as the dense GP)
        thread_local! {
            static SCRATCH: std::cell::RefCell<(Vec<f64>, Vec<f64>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
        }
        SCRATCH.with(|cell| {
            let (ks, v) = &mut *cell.borrow_mut();
            ks.clear();
            ks.extend(self.inducing.points().iter().map(|z| self.kernel.eval(z, x)));
            let mu = prior + dot(ks, &self.alpha);
            v.resize(m, 0.0);
            // q_** = k_*^T K_mm^{-1} k_*
            self.l_mm.solve_lower_into(ks, v);
            let q_star = dot(v, v);
            // correction k_*^T A^{-1} k_*
            self.l_a.solve_lower_into(ks, v);
            let corr = dot(v, v);
            let var = (self.kernel.eval(x, x) - q_star + corr).max(1e-12);
            (mu, var)
        })
    }

    /// Batched posterior: one `m x B` cross-covariance feature block and
    /// two multi-RHS `m x m` triangular solves for the whole candidate
    /// set (vs. `2B` independent solves point-wise) — the sparse half of
    /// the batch-first pipeline.
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let _span = obs::span(Phase::PredictBatch);
        let m = self.inducing.len();
        if xs.is_empty() {
            return Vec::new();
        }
        if m == 0 {
            return xs.iter().map(|x| (self.mean.eval(x), self.kernel.variance())).collect();
        }
        // K_* : m x B feature block against the inducing set
        let ks = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(self.inducing.points(), xs)
        };
        let mus = ks.matvec_t(&self.alpha);
        // q_** = k_*^T K_mm^{-1} k_* and the A^{-1} correction, batched
        let q_star = self.l_mm.solve_lower_multi(&ks).col_squared_norms();
        let corr = self.l_a.solve_lower_multi(&ks).col_squared_norms();
        xs.iter()
            .enumerate()
            .map(|(j, x)| {
                let mu = self.mean.eval(x) + mus[j];
                let var = (self.kernel.eval(x, x) - q_star[j] + corr[j]).max(1e-12);
                (mu, var)
            })
            .collect()
    }

    /// Joint posterior over the batch from the cached Woodbury factors:
    /// `Σ_* = K_** − K_*m K_mm⁻¹ K_m* + K_*m A⁻¹ K_m*` — the exact prior
    /// block minus the Nyström projection plus the FITC data correction,
    /// assembled from the same `m x B` feature block and two multi-RHS
    /// solves as [`predict_batch`](Model::predict_batch) plus two `B x B`
    /// column Grams. Both subtracted/added terms are PSD quadratic forms,
    /// so the result is PSD up to round-off; the diagonal reproduces
    /// `predict_batch` exactly (same accumulation order, same clamp).
    fn predict_joint(&self, xs: &[Vec<f64>]) -> (Vec<f64>, Matrix) {
        let _span = obs::span(Phase::PredictJoint);
        let b = xs.len();
        if b == 0 {
            return (Vec::new(), Matrix::zeros(0, 0));
        }
        let m = self.inducing.len();
        // exact prior block K_** (B x B)
        let mut cov = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(xs, xs)
        };
        if m == 0 {
            let mus = xs.iter().map(|x| self.mean.eval(x)).collect();
            for j in 0..b {
                cov[(j, j)] = self.kernel.variance();
            }
            return (mus, cov);
        }
        // K_* : m x B feature block against the inducing set
        let ks = {
            let _cc = obs::span(Phase::CrossCov);
            self.kernel.cross_cov(self.inducing.points(), xs)
        };
        let mut mus = ks.matvec_t(&self.alpha);
        for (mu, x) in mus.iter_mut().zip(xs) {
            *mu += self.mean.eval(x);
        }
        // Nyström projection Q_** = (L_mm^{-1}K_*)^T (L_mm^{-1}K_*) and
        // the A^{-1} correction, each one multi-solve + one column Gram
        let gq = self.l_mm.solve_lower_multi(&ks).col_gram();
        let gc = self.l_a.solve_lower_multi(&ks).col_gram();
        for ((c, &q), &a) in cov.data_mut().iter_mut().zip(gq.data()).zip(gc.data()) {
            *c += a - q;
        }
        // diagonal: the exact predict_batch expression (clamped variance)
        for (j, x) in xs.iter().enumerate() {
            cov[(j, j)] = (self.kernel.eval(x, x) - gq[(j, j)] + gc[(j, j)]).max(1e-12);
        }
        (mus, cov)
    }

    fn n_samples(&self) -> usize {
        self.xs.len()
    }

    fn dim(&self) -> usize {
        self.kernel.dim()
    }

    fn best_observation(&self) -> Option<f64> {
        self.best
    }

    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        crate::model::best_sample_of(&self.xs, &self.ys)
    }

    fn has_noisy_observations(&self) -> bool {
        !self.noise_vars.is_empty()
    }

    fn best_predicted_mean(&self) -> Option<f64> {
        if self.xs.is_empty() {
            return None;
        }
        self.predict_batch(&self.xs)
            .into_iter()
            .map(|(mu, _)| mu)
            .filter(|mu| mu.is_finite())
            .fold(None, |b: Option<f64>, mu| Some(b.map_or(mu, |b| b.max(mu))))
    }

    /// ML-II on the **exact FITC marginal likelihood** — the inducing set
    /// is held fixed while iRprop⁻ climbs the analytic
    /// [`lml_grad`](Self::lml_grad), each step an O(n·m²) refit instead
    /// of the dense O(n³). Restarts fan out in parallel on clones.
    fn optimize_hyperparams(&mut self) {
        if self.xs.len() < 2 {
            return;
        }
        // take the optimizer out so its refit counter survives the run
        let mut opt = std::mem::take(&mut self.hp_opt);
        opt.run(self);
        self.hp_opt = opt;
    }
}

/// The sparse GP fits the exact FITC marginal likelihood (O(n·m²) per
/// evaluation), keeping its current inducing set across the fit.
impl<K: Kernel, M: MeanFn> LmlModel for SparseGp<K, M> {
    fn hp_vector(&self) -> Vec<f64> {
        SparseGp::hp_vector(self)
    }

    fn apply_hp_vector(&mut self, p: &[f64]) {
        self.set_hp_vector(p, false);
    }

    fn lml(&self) -> f64 {
        self.log_marginal_likelihood()
    }

    fn lml_grad(&self) -> Vec<f64> {
        SparseGp::lml_grad(self)
    }

    fn n_samples(&self) -> usize {
        self.xs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Matern52, SquaredExpArd};
    use crate::mean::{DataMean, ZeroMean};
    use crate::rng::Pcg64;

    fn smooth_data(n: usize, dim: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| (3.0 * x[0]).sin() + x.iter().sum::<f64>() * 0.5).collect();
        (xs, ys)
    }

    #[test]
    fn exact_when_inducing_covers_data() {
        // m >= n: FITC with Z == X must reproduce the dense GP closely
        let (xs, ys) = smooth_data(24, 2, 1);
        let mut dense = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        dense.fit(&xs, &ys);
        let mut sparse = SparseGp::with_config(
            Matern52::new(2),
            DataMean::default(),
            1e-2,
            SgpConfig { max_inducing: 64, ..SgpConfig::default() },
        );
        sparse.fit(&xs, &ys);
        assert_eq!(sparse.inducing_points().len(), 24);
        let mut rng = Pcg64::seed(2);
        for _ in 0..20 {
            let p = rng.unit_point(2);
            let (md, vd) = dense.predict(&p);
            let (ms, vs) = sparse.predict(&p);
            assert!((md - ms).abs() < 1e-4, "mean {md} vs {ms}");
            assert!((vd - vs).abs() < 1e-4, "var {vd} vs {vs}");
        }
    }

    #[test]
    fn approximates_dense_with_few_inducing_points() {
        let (xs, ys) = smooth_data(200, 2, 3);
        let mut dense = Gp::new(SquaredExpArd::new(2), ZeroMean, 0.05);
        dense.fit(&xs, &ys);
        let mut sparse = SparseGp::with_config(
            SquaredExpArd::new(2),
            ZeroMean,
            0.05,
            SgpConfig { max_inducing: 40, ..SgpConfig::default() },
        );
        sparse.fit(&xs, &ys);
        let mut rng = Pcg64::seed(4);
        let mut se = 0.0;
        let probes = 100;
        for _ in 0..probes {
            let p = rng.unit_point(2);
            let (md, _) = dense.predict(&p);
            let (ms, vs) = sparse.predict(&p);
            se += (md - ms) * (md - ms);
            assert!(vs.is_finite() && vs > 0.0);
        }
        let rmse = (se / probes as f64).sqrt();
        assert!(rmse < 0.05, "sparse-vs-dense rmse {rmse}");
    }

    #[test]
    fn incremental_add_matches_refit() {
        let (xs, ys) = smooth_data(80, 2, 7);
        let cfg = SgpConfig { max_inducing: 16, ..SgpConfig::default() };
        let mut inc = SparseGp::with_config(Matern52::new(2), DataMean::default(), 0.05, cfg);
        for (x, &y) in xs.iter().zip(&ys) {
            inc.add_sample(x, y);
        }
        // same data + same inducing set, factors rebuilt from scratch
        let mut batch = inc.clone();
        batch.refit_keep_inducing();
        let mut rng = Pcg64::seed(8);
        for _ in 0..20 {
            let p = rng.unit_point(2);
            let (mi, vi) = inc.predict(&p);
            let (mb, vb) = batch.predict(&p);
            assert!((mi - mb).abs() < 1e-7, "mean {mi} vs {mb}");
            assert!((vi - vb).abs() < 1e-7, "var {vi} vs {vb}");
        }
    }

    #[test]
    fn predict_batch_matches_pointwise() {
        let (xs, ys) = smooth_data(120, 2, 11);
        let mut sgp = SparseGp::with_config(
            Matern52::new(2),
            DataMean::default(),
            0.05,
            SgpConfig { max_inducing: 24, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        let mut rng = Pcg64::seed(12);
        let cands: Vec<Vec<f64>> = (0..37).map(|_| rng.unit_point(2)).collect();
        let batch = sgp.predict_batch(&cands);
        for (j, c) in cands.iter().enumerate() {
            let (mu, var) = sgp.predict(c);
            assert!((batch[j].0 - mu).abs() < 1e-10, "mu[{j}]: {} vs {mu}", batch[j].0);
            assert!((batch[j].1 - var).abs() < 1e-10, "var[{j}]: {} vs {var}", batch[j].1);
        }
        // empty model falls back to the prior
        let fresh = SparseGp::new(Matern52::new(2), ZeroMean, 0.05);
        assert_eq!(fresh.predict_batch(&cands)[0], fresh.predict(&cands[0]));
    }

    #[test]
    fn predict_joint_diag_matches_batch_and_is_symmetric() {
        let (xs, ys) = smooth_data(90, 2, 0x10E);
        let mut sgp = SparseGp::with_config(
            Matern52::new(2),
            DataMean::default(),
            0.05,
            SgpConfig { max_inducing: 20, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        let mut rng = Pcg64::seed(0x10F);
        let cands: Vec<Vec<f64>> = (0..11).map(|_| rng.unit_point(2)).collect();
        let (mus, cov) = sgp.predict_joint(&cands);
        let batch = sgp.predict_batch(&cands);
        assert!(cov.is_symmetric(1e-12));
        for j in 0..11 {
            assert!((mus[j] - batch[j].0).abs() < 1e-12, "mu[{j}]");
            assert!((cov[(j, j)] - batch[j].1).abs() < 1e-12, "var[{j}]");
        }
        // duplicated candidate -> (numerically) perfectly correlated pair
        let x = vec![0.4, 0.7];
        let (_, c2) = sgp.predict_joint(&[x.clone(), x]);
        assert!((c2[(0, 0)] - c2[(0, 1)]).abs() < 1e-8);
        // empty model falls back to the prior diag
        let fresh = SparseGp::new(Matern52::new(2), ZeroMean, 0.05);
        let (mf, cf) = fresh.predict_joint(&cands);
        assert_eq!(mf[0], 0.0);
        assert!((cf[(0, 0)] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_tiny_states() {
        let sgp = SparseGp::new(Matern52::new(2), ZeroMean, 0.01);
        let (mu, var) = sgp.predict(&[0.4, 0.4]);
        assert_eq!(mu, 0.0);
        assert!((var - 1.0).abs() < 1e-12);
        assert!(sgp.best_observation().is_none());

        let mut sgp = SparseGp::new(Matern52::new(1), ZeroMean, 0.01);
        sgp.add_sample(&[0.5], 2.0);
        let (mu, var) = sgp.predict(&[0.5]);
        assert!((mu - 2.0).abs() < 0.1, "mu={mu}");
        assert!(var < 0.1);
        assert_eq!(sgp.best_observation(), Some(2.0));
    }

    #[test]
    fn best_observation_tracks_max_and_duplicates_survive() {
        let mut sgp = SparseGp::new(SquaredExpArd::new(1), ZeroMean, 1e-3);
        sgp.add_sample(&[0.1], 1.0);
        sgp.add_sample(&[0.2], 3.0);
        sgp.add_sample(&[0.2], 2.9); // duplicate input
        assert_eq!(sgp.best_observation(), Some(3.0));
        let (mu, _) = sgp.predict(&[0.2]);
        assert!((mu - 2.95).abs() < 0.2, "mu={mu}");
    }

    /// FD validation of the exact FITC `lml_grad` (mirrors
    /// `kernel::grad_check` / the dense GP's FD test). m < n so the
    /// diagonal correction λ is strictly positive (no clamp activity).
    #[test]
    fn fitc_lml_grad_matches_finite_differences() {
        let (xs, ys) = smooth_data(30, 2, 0x77);
        let mut sgp = SparseGp::with_config(
            SquaredExpArd::new(2),
            ZeroMean,
            0.1,
            SgpConfig { max_inducing: 12, ..SgpConfig::default() },
        );
        sgp.learn_noise = true;
        sgp.fit(&xs, &ys);
        let grad = sgp.lml_grad();
        let p0 = sgp.hp_vector();
        // eps large enough that the O(n·m²) pipeline's round-off does not
        // dominate the central difference (validated against a NumPy
        // mirror of the same factor layout)
        let eps = 1e-4;
        for i in 0..p0.len() {
            let mut p = p0.clone();
            p[i] += eps;
            sgp.set_hp_vector(&p, true);
            let up = sgp.log_marginal_likelihood();
            p[i] -= 2.0 * eps;
            sgp.set_hp_vector(&p, true);
            let dn = sgp.log_marginal_likelihood();
            sgp.set_hp_vector(&p0, true);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (grad[i] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "param {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    /// With m = n inducing points (Z == X) FITC **is** the dense GP:
    /// LML and gradient must match the dense values to 1e-8.
    #[test]
    fn fitc_lml_and_grad_match_dense_at_full_inducing() {
        let (xs, ys) = smooth_data(12, 2, 9);
        // small n and noise 0.3 keep Σ (and A) well-conditioned so the
        // Woodbury route agrees with the dense route beyond the 1e-8
        // target (validated margin ~1e-9 on a NumPy mirror)
        let mut dense = Gp::new(Matern52::new(2), ZeroMean, 0.3);
        dense.fit(&xs, &ys);
        let mut sparse = SparseGp::with_config(
            Matern52::new(2),
            ZeroMean,
            0.3,
            SgpConfig { max_inducing: 32, ..SgpConfig::default() },
        );
        sparse.fit(&xs, &ys);
        assert_eq!(sparse.inducing_points().len(), 12);

        let lml_d = dense.log_marginal_likelihood();
        let lml_s = sparse.log_marginal_likelihood();
        assert!((lml_d - lml_s).abs() <= 1e-8, "lml {lml_d} vs {lml_s}");

        let gd = dense.lml_grad();
        let gs = sparse.lml_grad();
        assert_eq!(gd.len(), gs.len());
        for (i, (d, s)) in gd.iter().zip(&gs).enumerate() {
            assert!(
                (d - s).abs() <= 1e-8 * (1.0 + d.abs()),
                "grad[{i}]: dense {d} vs fitc {s}"
            );
        }
    }

    #[test]
    fn exact_hyperopt_improves_fitc_lml() {
        let mut rng = Pcg64::seed(2024);
        let xs: Vec<Vec<f64>> = (0..60).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (12.0 * x[0]).sin()).collect();
        let mut sgp = SparseGp::with_config(
            SquaredExpArd::with_params(vec![2.0], 0.0),
            ZeroMean,
            0.05,
            SgpConfig { max_inducing: 30, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        let before = sgp.log_marginal_likelihood();
        sgp.optimize_hyperparams();
        let after = sgp.log_marginal_likelihood();
        assert!(after > before + 1.0, "FITC LML should improve: {before} -> {after}");
        let fitted_l = sgp.kernel().params()[0].exp();
        assert!(fitted_l < 1.0, "fitted lengthscale {fitted_l} should shrink");
        // posterior should now track the fast oscillation
        let (mu, _) = sgp.predict(&[0.13]);
        assert!((mu - (12.0f64 * 0.13).sin()).abs() < 0.3, "mu={mu}");
        // the optimizer's refit counter advanced (fresh restart streams)
        assert_eq!(sgp.hp_opt.refits(), 1);
    }
}
